#!/usr/bin/env python3
"""Fail CI on broken relative links in the repo's markdown docs.

Scans README.md, DESIGN.md, ROADMAP.md, CHANGES.md and docs/*.md for
``[text](target)`` links. External targets (http/https/mailto) are ignored;
relative targets must resolve to an existing file/directory, and a
``#fragment`` on a markdown target must match a heading in that file (GitHub
anchor slug rules: lowercase, punctuation stripped, spaces to hyphens).

Usage: python scripts/check_links.py  (exits 1 listing every broken link)
"""
from __future__ import annotations

import pathlib
import re
import sys

ROOT = pathlib.Path(__file__).resolve().parent.parent
DOCS = [p for p in (
    [ROOT / n for n in ("README.md", "DESIGN.md", "ROADMAP.md", "CHANGES.md")]
    + sorted((ROOT / "docs").glob("*.md"))
) if p.exists()]

# target, optionally followed by a quoted link title: [text](path "title")
LINK_RE = re.compile(r"\[[^\]]*\]\(\s*([^)\s]+)(?:\s+\"[^\"]*\")?\s*\)")
HEADING_RE = re.compile(r"^#{1,6}\s+(.*)$", re.MULTILINE)


def slugify(heading: str) -> str:
    """GitHub's anchor slug: drop non-word chars, spaces become hyphens."""
    text = re.sub(r"[`*_]", "", heading.strip()).lower()
    text = re.sub(r"[^\w\- ]", "", text)
    return text.replace(" ", "-")


def anchors_of(path: pathlib.Path) -> set[str]:
    """All heading anchors, with GitHub's ``-1``/``-2`` suffixes for
    duplicate headings."""
    out: set[str] = set()
    seen: dict[str, int] = {}
    for h in HEADING_RE.findall(path.read_text()):
        slug = slugify(h)
        n = seen.get(slug, 0)
        seen[slug] = n + 1
        out.add(slug if n == 0 else f"{slug}-{n}")
    return out


def check(path: pathlib.Path) -> list[str]:
    errors = []
    for target in LINK_RE.findall(path.read_text()):
        if target.startswith(("http://", "https://", "mailto:")):
            continue
        base, _, frag = target.partition("#")
        dest = (path.parent / base).resolve() if base else path
        if not dest.exists():
            errors.append(f"{path.relative_to(ROOT)}: broken link -> {target}")
            continue
        if frag and dest.suffix == ".md":
            if slugify(frag) not in anchors_of(dest):
                errors.append(f"{path.relative_to(ROOT)}: missing anchor "
                              f"#{frag} in {base or path.name}")
    return errors


def main() -> None:
    errors = [e for doc in DOCS for e in check(doc)]
    for e in errors:
        print(e)
    if errors:
        sys.exit(1)
    print(f"checked {len(DOCS)} docs, all relative links resolve")


if __name__ == "__main__":
    main()
