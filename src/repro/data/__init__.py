from repro.data.pipeline import (InputShape, SHAPES, make_batch,
                                 input_specs, synthetic_batch_iterator)

__all__ = ["InputShape", "SHAPES", "make_batch", "input_specs",
           "synthetic_batch_iterator"]
