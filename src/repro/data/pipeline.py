"""Deterministic synthetic data pipeline + ShapeDtypeStruct input specs.

The four assigned input shapes are defined here. ``input_specs`` produces the
no-allocation stand-ins used by the multi-pod dry-run; ``make_batch`` produces
real (deterministic) arrays for the CPU smoke tests and examples.

Frontend carve-out: for [audio]/[vlm] architectures the modality encoder is
stubbed — specs provide frame/patch *embeddings* of the right shape directly.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.config import ArchConfig


@dataclasses.dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str                    # train | prefill | decode


SHAPES: dict[str, InputShape] = {
    "train_4k": InputShape("train_4k", 4096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32_768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524_288, 1, "decode"),
}


def _token_spec(shape, dtype=jnp.int32):
    return jax.ShapeDtypeStruct(shape, dtype)


def input_specs(cfg: ArchConfig, shape: InputShape, *,
                dtype=jnp.bfloat16) -> dict:
    """ShapeDtypeStruct stand-ins for every model input of this step kind."""
    B, S = shape.global_batch, shape.seq_len
    if shape.kind in ("train", "prefill"):
        if cfg.frontend == "audio":
            specs = {"frames": jax.ShapeDtypeStruct((B, S, cfg.d_model), dtype)}
        elif cfg.frontend == "vision":
            P = cfg.num_patches
            specs = {"tokens": _token_spec((B, S - P)),
                     "patch_embeds": jax.ShapeDtypeStruct((B, P, cfg.d_model),
                                                          dtype)}
        else:
            specs = {"tokens": _token_spec((B, S))}
        if shape.kind == "train":
            specs["labels"] = _token_spec((B, S))
        return specs
    # decode: one token + position (cache comes separately)
    return {"token": _token_spec((B,)), "pos": _token_spec((), jnp.int32)}


def make_batch(cfg: ArchConfig, shape: InputShape, seed: int = 0, *,
               dtype=jnp.float32) -> dict:
    """Real deterministic arrays matching input_specs."""
    rng = np.random.default_rng(seed)
    B, S = shape.global_batch, shape.seq_len
    out: dict = {}
    if shape.kind in ("train", "prefill"):
        if cfg.frontend == "audio":
            out["frames"] = jnp.asarray(
                rng.standard_normal((B, S, cfg.d_model), dtype=np.float32),
                dtype=dtype)
        elif cfg.frontend == "vision":
            P = cfg.num_patches
            out["tokens"] = jnp.asarray(
                rng.integers(0, cfg.vocab_size, (B, S - P)), dtype=jnp.int32)
            out["patch_embeds"] = jnp.asarray(
                rng.standard_normal((B, P, cfg.d_model), dtype=np.float32),
                dtype=dtype)
        else:
            out["tokens"] = jnp.asarray(
                rng.integers(0, cfg.vocab_size, (B, S)), dtype=jnp.int32)
        if shape.kind == "train":
            labels = rng.integers(0, cfg.vocab_size, (B, S))
            if cfg.frontend == "vision":
                labels[:, : cfg.num_patches] = -100      # no loss on patches
            if cfg.frontend == "audio":
                # masked prediction: loss on a random 8% of frames
                mask = rng.random((B, S)) < 0.08
                labels = np.where(mask, labels % cfg.vocab_size, -100)
            out["labels"] = jnp.asarray(labels, dtype=jnp.int32)
    else:
        out["token"] = jnp.asarray(rng.integers(0, cfg.vocab_size, (B,)),
                                   dtype=jnp.int32)
        out["pos"] = jnp.asarray(min(128, shape.seq_len - 1), dtype=jnp.int32)
    return out


def synthetic_batch_iterator(cfg: ArchConfig, shape: InputShape, *,
                             dtype=jnp.float32, start_seed: int = 0):
    """Endless deterministic stream of training batches."""
    seed = start_seed
    while True:
        yield make_batch(cfg, shape, seed=seed, dtype=dtype)
        seed += 1
