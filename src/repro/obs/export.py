"""Exporter bridge: telemetry and traces out of the process, losslessly.

The :class:`~repro.obs.metrics.TelemetryHub` and
:class:`~repro.obs.trace.Tracer` keep everything in memory; production
observability needs the same data in formats real tooling reads. Modeled on
OpenFilter's OpenTelemetry bridge (PAPERS.md), two exporters plus an
aggregation layer:

* :class:`JsonlMetricExporter` — an OTLP-ish newline-delimited JSON metric
  exporter. Subscribe it to a hub and every emitted point is written as one
  JSON line (``{"t", "name", "value", "attrs"}``) at emit time — incremental
  export, no buffering, tail-able mid-run. ``load_jsonl_metrics`` reads the
  file back into the exact :class:`MetricPoint` stream (floats round-trip
  bit-exactly through JSON's repr-based encoding).
* :func:`chrome_trace` / :func:`spans_from_chrome_trace` — ``Tracer`` span
  trees as Chrome-trace-format JSON (the ``chrome://tracing`` / Perfetto
  ``traceEvents`` schema), using paired ``B``/``E`` duration events whose
  nesting *is* the span stack. Replan/recalibrate/solver spans become
  viewable in a real trace UI; the reader reconstructs the span tree
  losslessly (exact ``t``/``wall_ms``/attrs ride in ``args``).
* :class:`Counter` / :class:`Gauge` / :class:`Histogram` behind a
  :class:`MetricAggregator` — a pull-side aggregation layer registered on
  the hub: exact percentiles (p50/p95/p99 over e.g. solver ``wall_ms`` and
  per-tick SLO) without scraping the raw point stream.
"""
from __future__ import annotations

import json
import os
from typing import IO, Iterable, Mapping, Optional, Sequence, Union

from repro.obs.metrics import MetricPoint, TelemetryHub
from repro.obs.trace import Span, Tracer

# ---------------------------------------------------------------------------
# JSONL metric exporter (OTLP-ish newline-delimited points)
# ---------------------------------------------------------------------------

_esc = json.encoder.encode_basestring_ascii   # C string escaper


def _jnum(x) -> str:
    """A number exactly as ``json.dumps`` renders it (float repr; the
    non-finite spellings match Python's non-strict JSON dialect)."""
    if type(x) is int:
        return repr(x)
    x = float(x)
    if x != x:
        return "NaN"
    if x == float("inf"):
        return "Infinity"
    if x == float("-inf"):
        return "-Infinity"
    return repr(x)


class JsonlMetricExporter:
    """Hub subscriber writing one JSON line per :class:`MetricPoint`.

    ``hub.subscribe(exporter)`` streams points to ``path`` (or any writable
    file object) as they are emitted. The line schema mirrors
    ``TelemetryHub.to_rows()`` — ``{"t", "name", "value", "attrs"}`` — so the
    file is also directly loadable as JSONL by pandas/jq/OTel collectors.
    Use as a context manager, or ``close()`` explicitly; points written
    before a crash are already on disk (the export is incremental).
    """

    def __init__(self, sink: Union[str, os.PathLike, IO[str]]) -> None:
        if hasattr(sink, "write"):
            self._fh: IO[str] = sink            # caller-owned file object
            self._owns = False
        else:
            self._fh = open(sink, "w", encoding="utf-8")
            self._owns = True
        self.written = 0

    def __call__(self, point: MetricPoint) -> None:
        # hand-rolled line, byte-identical to
        # json.dumps({...}, sort_keys=True): this runs once per emitted
        # point on the event loop's critical path, and the generic encoder
        # is ~3x slower than escaping the four known fields directly
        # (point.attrs is already sorted; "attrs" < "name" < "t" < "value")
        a = point.attrs
        attrs = ("{" + ", ".join(
            _esc(k) + ": " + _esc(v) for k, v in a) + "}") if a else "{}"
        self._fh.write(
            '{"attrs": ' + attrs + ', "name": ' + _esc(point.name) +
            ', "t": ' + _jnum(point.t) +
            ', "value": ' + _jnum(point.value) + "}\n")
        self.written += 1

    def close(self) -> None:
        if self._owns and not self._fh.closed:
            self._fh.close()

    def __enter__(self) -> "JsonlMetricExporter":
        return self

    def __exit__(self, *exc) -> None:
        self.close()


def load_jsonl_metrics(
        source: Union[str, os.PathLike, IO[str]]) -> list[MetricPoint]:
    """Read a :class:`JsonlMetricExporter` file back into points.

    The round trip is lossless: ``load_jsonl_metrics(path) == hub.points``
    for the hub the exporter was subscribed to (JSON floats are repr-encoded,
    so ``float → text → float`` is bit-exact)."""
    if hasattr(source, "read"):
        lines = source.read().splitlines()
    else:
        with open(source, "r", encoding="utf-8") as fh:
            lines = fh.read().splitlines()
    out: list[MetricPoint] = []
    for line in lines:
        if not line.strip():
            continue
        row = json.loads(line)
        out.append(MetricPoint(
            t=row["t"], name=row["name"], value=row["value"],
            attrs=tuple(sorted((k, str(v))
                               for k, v in row["attrs"].items()))))
    return out


# ---------------------------------------------------------------------------
# Chrome-trace-format exporter (chrome://tracing / Perfetto "traceEvents")
# ---------------------------------------------------------------------------

_TRACE_PID = 1          # one simulated fleet = one "process" in the UI


def _emit_span(span: Span, events: list[dict], cursor_us: float,
               tid: int) -> float:
    """Append the B/E event pair for ``span`` (children nested between),
    returning the cursor after the span. The synthesized ``ts`` timeline
    lays children out sequentially inside their parent — a span's recorded
    ``wall_ms`` includes its children's, so containment holds and the trace
    UI renders the tree; the *exact* values ride in ``args``."""
    dur_us = span.wall_ms * 1e3
    child_us = sum(c.wall_ms for c in span.children) * 1e3
    dur_us = max(dur_us, child_us)        # float-rounding guard: contain kids
    events.append({
        "ph": "B", "name": span.name, "pid": _TRACE_PID, "tid": tid,
        "ts": cursor_us, "cat": "replan",
        "args": {"t": span.t, "wall_ms": span.wall_ms,
                 "attrs": dict(span.attrs)},
    })
    child_cursor = cursor_us
    for child in span.children:
        child_cursor = _emit_span(child, events, child_cursor, tid)
    events.append({"ph": "E", "name": span.name, "pid": _TRACE_PID,
                   "tid": tid, "ts": cursor_us + dur_us, "cat": "replan"})
    return cursor_us + dur_us


def chrome_trace(tracer_or_spans: Union[Tracer, Sequence[Span]]) -> dict:
    """A ``chrome://tracing``-loadable document for a tracer's span trees.

    Root spans are laid out sequentially on one thread track; nesting uses
    paired ``B``/``E`` duration events, whose stack discipline mirrors the
    tracer's call stack exactly. Load the written file in
    ``chrome://tracing`` or https://ui.perfetto.dev to browse replan /
    recalibrate / solver spans on a zoomable timeline."""
    spans = (tracer_or_spans.spans if isinstance(tracer_or_spans, Tracer)
             else list(tracer_or_spans))
    events: list[dict] = []
    cursor = 0.0
    for root in spans:
        cursor = _emit_span(root, events, cursor, tid=1)
    return {
        "traceEvents": events,
        "displayTimeUnit": "ms",
        "otherData": {"source": "repro.obs", "spans": len(spans)},
    }


def write_chrome_trace(path: Union[str, os.PathLike],
                       tracer_or_spans: Union[Tracer, Sequence[Span]]) -> int:
    """Write :func:`chrome_trace` JSON to ``path``; returns the event count."""
    doc = chrome_trace(tracer_or_spans)
    with open(path, "w", encoding="utf-8") as fh:
        json.dump(doc, fh, sort_keys=True)
    return len(doc["traceEvents"])


def spans_from_chrome_trace(
        source: Union[str, os.PathLike, Mapping, IO[str]]) -> list[Span]:
    """Reconstruct the span trees from a :func:`chrome_trace` document.

    Replays the ``B``/``E`` event stack in file order; ``name``, simulated
    ``t``, exact ``wall_ms``, attrs, and the child structure all round-trip
    losslessly (asserted by ``benchmarks/obs_export.py``)."""
    if hasattr(source, "read"):
        doc = json.load(source)
    elif isinstance(source, Mapping):
        doc = source
    else:
        with open(source, "r", encoding="utf-8") as fh:
            doc = json.load(fh)
    roots: list[Span] = []
    stack: list[Span] = []
    for e in doc["traceEvents"]:
        if e["ph"] == "B":
            args = e.get("args", {})
            sp = Span(name=e["name"], t=args.get("t", 0.0),
                      wall_ms=args.get("wall_ms", 0.0),
                      attrs=dict(args.get("attrs", {})))
            if stack:
                stack[-1].children.append(sp)
            else:
                roots.append(sp)
            stack.append(sp)
        elif e["ph"] == "E":
            if not stack or stack[-1].name != e["name"]:
                raise ValueError(
                    f"unbalanced trace: E {e['name']!r} does not close "
                    f"{stack[-1].name if stack else 'an empty stack'!r}")
            stack.pop()
    if stack:
        raise ValueError(f"unbalanced trace: {len(stack)} spans never closed")
    return roots


# ---------------------------------------------------------------------------
# Aggregation layer: Counter / Gauge / Histogram on the hub
# ---------------------------------------------------------------------------


class Counter:
    """Monotonic sum of observed values (e.g. preemption counts)."""

    def __init__(self, name: str) -> None:
        self.name = name
        self.total = 0.0
        self.n = 0

    def observe(self, value: float) -> None:
        self.total += value
        self.n += 1

    def summary(self) -> dict:
        return {"kind": "counter", "total": self.total, "points": self.n}


class Gauge:
    """Last-value-wins (e.g. live instance count)."""

    def __init__(self, name: str) -> None:
        self.name = name
        self.value: Optional[float] = None
        self.t: Optional[float] = None
        self.n = 0

    def observe(self, value: float, t: Optional[float] = None) -> None:
        self.value = value
        self.t = t
        self.n += 1

    def summary(self) -> dict:
        return {"kind": "gauge", "value": self.value, "t": self.t,
                "points": self.n}


class Histogram:
    """Exact distribution of observed values.

    Keeps every sample (fleet runs emit thousands of points, not millions),
    so percentiles are *exact* — the nearest-rank p50/p95/p99 the benchmark
    gates quote — rather than bucket-approximated."""

    def __init__(self, name: str) -> None:
        self.name = name
        self.values: list[float] = []

    def observe(self, value: float) -> None:
        self.values.append(value)

    def percentile(self, p: float) -> Optional[float]:
        """Exact nearest-rank percentile; None on an empty histogram."""
        if not self.values:
            return None
        ordered = sorted(self.values)
        k = max(0, min(len(ordered) - 1,
                       int(round(p * (len(ordered) - 1)))))
        return ordered[k]

    def summary(self) -> dict:
        if not self.values:
            return {"kind": "histogram", "count": 0}
        return {
            "kind": "histogram", "count": len(self.values),
            "sum": sum(self.values),
            "min": min(self.values), "max": max(self.values),
            "mean": sum(self.values) / len(self.values),
            "p50": self.percentile(0.50),
            "p95": self.percentile(0.95),
            "p99": self.percentile(0.99),
        }


class MetricAggregator:
    """Routes hub points into registered instruments by metric name.

    ``agg = MetricAggregator(hub)`` subscribes itself; register instruments
    (``agg.histogram("replan.wall_ms")``, ``agg.gauge("fleet.slo")``) and
    read ``agg.summary()`` at any time — including mid-run, since routing
    happens synchronously at emit time. Unregistered names pass through
    untouched (the raw stream still lives on the hub)."""

    def __init__(self, hub: Optional[TelemetryHub] = None) -> None:
        self.instruments: dict[str, Union[Counter, Gauge, Histogram]] = {}
        if hub is not None:
            hub.subscribe(self)

    def counter(self, name: str) -> Counter:
        return self._register(name, Counter(name))

    def gauge(self, name: str) -> Gauge:
        return self._register(name, Gauge(name))

    def histogram(self, name: str) -> Histogram:
        return self._register(name, Histogram(name))

    def _register(self, name, inst):
        if name in self.instruments:
            existing = self.instruments[name]
            if type(existing) is not type(inst):
                raise ValueError(
                    f"metric {name!r} already registered as "
                    f"{type(existing).__name__}")
            return existing
        self.instruments[name] = inst
        return inst

    def __call__(self, point: MetricPoint) -> None:
        inst = self.instruments.get(point.name)
        if inst is None:
            return
        if isinstance(inst, Gauge):
            inst.observe(point.value, point.t)
        else:
            inst.observe(point.value)

    def summary(self) -> dict:
        """JSON-ready per-instrument summaries (benchmark artifacts)."""
        return {name: inst.summary()
                for name, inst in sorted(self.instruments.items())}


def hub_with_exporters(
        jsonl_path: Optional[Union[str, os.PathLike]] = None,
        histograms: Iterable[str] = ("replan.wall_ms", "fleet.slo"),
) -> tuple[TelemetryHub, Optional[JsonlMetricExporter], MetricAggregator]:
    """Convenience wiring: a hub with a JSONL exporter (when ``jsonl_path``
    is given) and an aggregator with histograms over ``histograms``."""
    hub = TelemetryHub()
    exporter = None
    if jsonl_path is not None:
        exporter = JsonlMetricExporter(jsonl_path)
        hub.subscribe(exporter)
    agg = MetricAggregator(hub)
    for name in histograms:
        agg.histogram(name)
    return hub, exporter, agg
