"""Calibration drift detection: measured rates vs the active calibration.

The packing loop plans from a :class:`~repro.sim.ledger.ServiceCalibration`
profiled at startup; the serving layer's *measured* rates move underneath it
(codec changes, scene load, noisy neighbors). The detector compares each
observation window's measured tokens/s per stream against the calibrated
rate and declares drift when the mean relative error exceeds
``rel_threshold`` for ``hold_ticks`` *consecutive* observations — one bad
window is noise, K held windows are a regression.

Two deliberate asymmetries guard against phantom drift (the failure modes
fixed alongside this detector):

* an **empty measurement** (idle engine — ``measured_rates()`` is ``{}``,
  the engine's ``report()`` SLO is ``None``) carries no drift evidence: the
  streak neither grows nor resets, and the verdict is "no data", never
  "no drift";
* streams absent from the calibration with no ``default_rate`` are skipped —
  an unprofiled stream cannot contradict a profile it is not part of.
"""
from __future__ import annotations

import dataclasses
from typing import Mapping


@dataclasses.dataclass(frozen=True)
class DriftConfig:
    """Detection knobs.

    ``rel_threshold`` — mean |measured − calibrated| / calibrated above
    which a window counts as drifting. ``hold_ticks`` — consecutive
    drifting windows before the detector fires (K). ``min_rate`` —
    calibrated rates at or below this (tokens/s) are ignored rather than
    divided by.
    """

    rel_threshold: float = 0.25
    hold_ticks: int = 3
    min_rate: float = 1e-9


@dataclasses.dataclass(frozen=True)
class DriftVerdict:
    """One observation window's outcome.

    ``drifting`` — this window exceeded the threshold; ``fired`` — the
    streak reached ``hold_ticks`` and recalibration should trigger;
    ``n_streams`` — streams actually compared (0 = no evidence either way).
    """

    t: float
    rel_error: float
    max_rel_error: float
    streak: int
    drifting: bool
    fired: bool
    n_streams: int


class DriftDetector:
    """Streak-counting comparator of measured rates vs the calibration."""

    def __init__(self, config: DriftConfig = DriftConfig()) -> None:
        self.config = config
        self.streak = 0
        self.history: list[DriftVerdict] = []

    def observe(self, t: float, measured: Mapping[str, float],
                calibration) -> DriftVerdict:
        """Compare one measurement window against the active calibration.

        ``measured`` is a ``measured_rates()``-shaped dict (tokens/s per
        stream); ``calibration`` any object with ``rates_tokens_per_s`` and
        ``default_rate`` (i.e. :class:`~repro.sim.ledger.ServiceCalibration`).
        """
        cfg = self.config
        errors: list[float] = []
        for sid in sorted(measured):
            cal = calibration.rates_tokens_per_s.get(
                sid, calibration.default_rate)
            if cal is None or cal <= cfg.min_rate:
                continue
            errors.append(abs(measured[sid] - cal) / cal)

        if not errors:
            # no evidence: an idle engine must not look like perfect health
            # (streak preserved) nor like drift (streak not grown)
            verdict = DriftVerdict(t=t, rel_error=0.0, max_rel_error=0.0,
                                   streak=self.streak, drifting=False,
                                   fired=False, n_streams=0)
        else:
            rel = sum(errors) / len(errors)
            drifting = rel > cfg.rel_threshold
            self.streak = self.streak + 1 if drifting else 0
            verdict = DriftVerdict(t=t, rel_error=rel,
                                   max_rel_error=max(errors),
                                   streak=self.streak, drifting=drifting,
                                   fired=self.streak >= cfg.hold_ticks,
                                   n_streams=len(errors))
        self.history.append(verdict)
        return verdict

    def reset(self) -> None:
        """Forget the streak (called after a recalibration adopts the
        measured rates — the error is zero by construction)."""
        self.streak = 0
