"""Ground-truth serving rates for the simulator, and the probe that reads them.

In a real deployment the "truth" is the serving fleet itself and the probe
is ``ContinuousBatchingEngine.windowed_rates()``. In the simulator the truth
must be modeled: :class:`DriftingService` holds each stream's sustainable
tokens/s as a piecewise-constant function of simulated time — a base
profile plus :class:`RateShift` events (a codec regression at noon, a noisy
neighbor on one camera group). The fleet simulator caps analyzed frames by
this *true* rate, while policies plan from whatever
:class:`~repro.sim.ledger.ServiceCalibration` they believe — the gap
between the two is exactly what the drift detector measures.

Deliberately exact (no measurement noise): benchmark gates and golden
ledgers need determinism, and the detector's threshold/hold machinery is
what absorbs noise in a real deployment.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Mapping, Optional, Sequence

from repro.sim.ledger import ServiceCalibration


@dataclasses.dataclass(frozen=True)
class RateShift:
    """A step change in true serving rates at ``at_h`` (simulated hours):
    every affected stream's rate is multiplied by ``factor`` from then on.
    ``streams=None`` affects the whole fleet."""

    at_h: float
    factor: float
    streams: Optional[frozenset[str]] = None

    def applies_to(self, stream_id: str) -> bool:
        return self.streams is None or stream_id in self.streams


class DriftingService:
    """True per-stream serving rates over time (tokens/s), plus the probe.

    ``measure(t)`` is what a live engine's windowed export would report at
    ``t``; ``frame_rate_cap(sid, t)`` is the frames/s the serving layer
    actually sustains (rate ÷ tokens-per-frame) — the fleet simulator's
    accounting cap. ``initial_calibration()`` is the profile-once-at-startup
    belief every policy begins with.
    """

    def __init__(self, base_rates_tokens_per_s: Mapping[str, float], *,
                 tokens_per_frame: float = 8.0,
                 shifts: Sequence[RateShift] = (),
                 default_rate: Optional[float] = None) -> None:
        self.base_rates = dict(base_rates_tokens_per_s)
        self.tokens_per_frame = tokens_per_frame
        self.shifts = tuple(sorted(shifts, key=lambda s: s.at_h))
        self.default_rate = default_rate

    def _rate(self, stream_id: str, t_h: float) -> Optional[float]:
        rate = self.base_rates.get(stream_id, self.default_rate)
        if rate is None:
            return None
        for shift in self.shifts:
            if shift.at_h <= t_h and shift.applies_to(stream_id):
                rate *= shift.factor
        return rate

    def rates_at(self, t_h: float) -> dict[str, float]:
        """True tokens/s per known stream at simulated hour ``t_h``."""
        return {sid: self._rate(sid, t_h) for sid in sorted(self.base_rates)}

    def measure(self, t_h: float) -> dict[str, float]:
        """The exact probe: the instantaneous true rates at ``t_h``."""
        return self.rates_at(t_h)

    def mean_rates(self, t0_h: float, t1_h: float) -> dict[str, float]:
        """Time-averaged true tokens/s over the window ``[t0_h, t1_h]``.

        This is what a live engine's ``windowed_rates()`` delta export
        reports for the window: a shift landing mid-window shows up at its
        time-weighted magnitude (and at full magnitude one window later),
        unlike the instantaneous ``measure()`` probe. Piecewise-constant
        integration over the shift breakpoints — exact, no sampling."""
        if t1_h <= t0_h:
            return self.rates_at(t1_h)
        edges = [t0_h] + [s.at_h for s in self.shifts
                          if t0_h < s.at_h < t1_h] + [t1_h]
        span = t1_h - t0_h
        out: dict[str, float] = {}
        for sid in sorted(self.base_rates):
            total = 0.0
            for a, b in zip(edges, edges[1:]):
                rate = self._rate(sid, a)
                if rate is None:
                    total = None
                    break
                total += rate * (b - a)
            if total is not None:
                out[sid] = total / span
        return out

    def frame_rate_cap(self, stream_id: str, t_h: float) -> float:
        """Frames/s the serving layer sustains for this stream right now
        (inf for streams the service has never seen and has no default
        for — same convention as ``ServiceCalibration``)."""
        rate = self._rate(stream_id, t_h)
        if rate is None:
            return math.inf
        return rate / self.tokens_per_frame

    def calibration_at(self, t_h: float) -> ServiceCalibration:
        """A calibration profiled from the rates in force at ``t_h``."""
        rates = self.rates_at(t_h)
        default = (sum(rates.values()) / len(rates)) if rates else None
        return ServiceCalibration(tokens_per_frame=self.tokens_per_frame,
                                  rates_tokens_per_s=rates,
                                  default_rate=default)

    def initial_calibration(self) -> ServiceCalibration:
        """The startup profile (t = 0) — the belief a non-recalibrating
        policy keeps forever."""
        return self.calibration_at(0.0)
