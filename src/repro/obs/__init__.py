"""Observability layer (BEYOND-PAPER): the profile→pack→observe loop, closed.

The paper's manager profiles serving throughput once at startup and packs
from that calibration forever. This package makes the loop continuous:

* ``metrics``      — :class:`TelemetryHub`, a streaming metric export: the
                     fleet simulator's event loop pushes per-tick points
                     (named after OpenTelemetry conventions) to subscribers
                     *as they happen*, instead of post-hoc ``Ledger`` reads.
* ``trace``        — :class:`Tracer` / :class:`Span`, per-replan trace
                     spans (simulated time + wall-clock duration + decision
                     attributes, nested recalibrate → replan).
* ``drift``        — :class:`DriftDetector`, comparing measured engine
                     rates against the active
                     :class:`~repro.sim.ledger.ServiceCalibration` and
                     firing when the relative error holds past a threshold
                     for K consecutive ticks.
* ``probe``        — :class:`DriftingService`, the simulator's ground-truth
                     serving rates over time (with injected regressions)
                     plus the measurement probe a real deployment would get
                     from ``ContinuousBatchingEngine.windowed_rates()``.
* ``recalibrate``  — :class:`RecalibratingPolicy`, wrapping any autoscaling
                     policy: re-profiles on drift and forces a
                     min-migration repair replan through the existing
                     ``core/repair.py`` machinery.
* ``export``       — the exporter bridge: :class:`JsonlMetricExporter`
                     (OTLP-ish newline-delimited JSON, a hub subscriber),
                     :func:`chrome_trace` / :func:`write_chrome_trace`
                     (span trees as ``chrome://tracing`` JSON), and the
                     :class:`MetricAggregator` with Counter / Gauge /
                     Histogram instruments (exact p50/p95/p99).
* ``regional``     — per-region live drift: :class:`WindowedServiceProbe`
                     (``windowed_rates()`` delta-export semantics over the
                     simulated truth), :class:`EngineWindowProbe` (real
                     per-region engines), :class:`RegionalDriftDetector`
                     (one streak per group) and
                     :class:`RegionalRecalibratingPolicy` (re-profile only
                     the drifted group, repair scoped to its bins).

``benchmarks/drift_recalibration.py`` gates the fleet-wide loop on
``drifting_scene``; ``benchmarks/obs_export.py`` gates the exporters and
the per-group loop on ``regional_drift``.
"""
from repro.obs.drift import DriftConfig, DriftDetector, DriftVerdict
from repro.obs.export import (Counter, Gauge, Histogram, JsonlMetricExporter,
                              MetricAggregator, chrome_trace,
                              hub_with_exporters, load_jsonl_metrics,
                              spans_from_chrome_trace, write_chrome_trace)
from repro.obs.metrics import MetricPoint, TelemetryHub
from repro.obs.probe import DriftingService, RateShift
from repro.obs.recalibrate import RecalibratingPolicy
from repro.obs.regional import (EngineWindowProbe, RegionalDriftDetector,
                                RegionalRecalibratingPolicy, RegionalVerdict,
                                WindowedServiceProbe, camera_region_groups)
from repro.obs.trace import Span, Tracer

__all__ = [
    "Counter", "DriftConfig", "DriftDetector", "DriftVerdict",
    "DriftingService", "EngineWindowProbe", "Gauge", "Histogram",
    "JsonlMetricExporter", "MetricAggregator", "MetricPoint", "RateShift",
    "RecalibratingPolicy", "RegionalDriftDetector",
    "RegionalRecalibratingPolicy", "RegionalVerdict", "Span", "TelemetryHub",
    "Tracer", "WindowedServiceProbe", "camera_region_groups", "chrome_trace",
    "hub_with_exporters", "load_jsonl_metrics", "spans_from_chrome_trace",
    "write_chrome_trace",
]
