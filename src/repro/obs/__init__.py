"""Observability layer (BEYOND-PAPER): the profile→pack→observe loop, closed.

The paper's manager profiles serving throughput once at startup and packs
from that calibration forever. This package makes the loop continuous:

* ``metrics``      — :class:`TelemetryHub`, a streaming metric export: the
                     fleet simulator's event loop pushes per-tick points
                     (named after OpenTelemetry conventions) to subscribers
                     *as they happen*, instead of post-hoc ``Ledger`` reads.
* ``trace``        — :class:`Tracer` / :class:`Span`, per-replan trace
                     spans (simulated time + wall-clock duration + decision
                     attributes, nested recalibrate → replan).
* ``drift``        — :class:`DriftDetector`, comparing measured engine
                     rates against the active
                     :class:`~repro.sim.ledger.ServiceCalibration` and
                     firing when the relative error holds past a threshold
                     for K consecutive ticks.
* ``probe``        — :class:`DriftingService`, the simulator's ground-truth
                     serving rates over time (with injected regressions)
                     plus the measurement probe a real deployment would get
                     from ``ContinuousBatchingEngine.windowed_rates()``.
* ``recalibrate``  — :class:`RecalibratingPolicy`, wrapping any autoscaling
                     policy: re-profiles on drift and forces a
                     min-migration repair replan through the existing
                     ``core/repair.py`` machinery.

``benchmarks/drift_recalibration.py`` gates the outcome: on the
``drifting_scene`` scenario, online recalibration beats a stale-calibration
baseline on cost at equal-or-better SLO.
"""
from repro.obs.drift import DriftConfig, DriftDetector, DriftVerdict
from repro.obs.metrics import MetricPoint, TelemetryHub
from repro.obs.probe import DriftingService, RateShift
from repro.obs.recalibrate import RecalibratingPolicy
from repro.obs.trace import Span, Tracer

__all__ = [
    "DriftConfig", "DriftDetector", "DriftVerdict", "DriftingService",
    "MetricPoint", "RateShift", "RecalibratingPolicy", "Span",
    "TelemetryHub", "Tracer",
]
