"""Online recalibration: re-profile on drift, repair-replan, move on.

:class:`RecalibratingPolicy` wraps any autoscaling policy and closes the
profile→pack→observe loop each tick:

1. **observe** — read the service probe's measured rates for this window
   and export them (plus the drift error) to the telemetry hub;
2. **detect** — feed the measurement to the :class:`DriftDetector` against
   the *active* calibration;
3. **recalibrate** — when the detector fires, adopt the measured rates as
   the new calibration (re-profiling) and force a replan, flagged on the
   adaptive event trace as recalibration-triggered; with a repair-mode
   inner policy the replan runs through ``core/repair.py`` — feasible
   placements stay put, the budget/defrag machinery converts the corrected
   belief into a cheaper packing;
4. **pack** — hand the inner policy the demanded streams with each rate
   clamped to the calibrated sustainable frames/s: capacity the serving
   layer cannot absorb is not worth renting.

The wrapper is transparent to the fleet simulator: it forwards ``name``,
``adaptive``, ``bids`` and ``attach_market``, and exposes ``last_drift``
(the verdict backing the ledger's calibration-error column).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional, Sequence

from repro.core.strategies import Plan
from repro.core.workload import Stream
from repro.obs.drift import DriftDetector, DriftVerdict
from repro.obs.metrics import TelemetryHub
from repro.obs.trace import Tracer
from repro.sim.ledger import ServiceCalibration


class RecalibratingPolicy:
    """Drift-aware wrapper over an autoscaling policy (module doc above).

    ``service`` is the ground truth (``tokens_per_frame``, the startup
    profile); ``probe`` is the measurement source — anything with
    ``measure(t) -> {stream_id: tokens/s}``. By default the service itself
    is the probe (the exact instantaneous read); pass a
    :class:`~repro.obs.regional.WindowedServiceProbe` for live
    ``windowed_rates()`` delta-export semantics, or an adapter over real
    engines. The initial belief is ``calibration`` if given, else the
    service's startup profile (``initial_calibration()``).
    """

    def __init__(self, inner, service, *,
                 detector: Optional[DriftDetector] = None,
                 telemetry: Optional[TelemetryHub] = None,
                 tracer: Optional[Tracer] = None,
                 calibration: Optional[ServiceCalibration] = None,
                 probe=None) -> None:
        self.inner = inner
        self.name = f"recal-{inner.name}"
        self.service = service
        self.probe = probe if probe is not None else service
        self.detector = detector or DriftDetector()
        self.telemetry = telemetry or TelemetryHub()
        self.tracer = tracer or Tracer()
        self.calibration = (calibration if calibration is not None
                            else service.initial_calibration())
        self.last_drift: Optional[DriftVerdict] = None
        self.recalibrations: list[float] = []     # simulated hours fired at

    # -- fleet-simulator plumbing (forwarded to the wrapped policy) ----------

    @property
    def adaptive(self):
        return getattr(self.inner, "adaptive", None)

    @property
    def bids(self):
        return getattr(self.inner, "bids", None)

    def attach_market(self, market, dt_h: float, boot_delay_h: float) -> None:
        attach = getattr(self.inner, "attach_market", None)
        if attach is not None:
            attach(market, dt_h, boot_delay_h)

    # -- the loop ------------------------------------------------------------

    def _clamped(self, streams: Sequence[Stream]) -> list[Stream]:
        """Demanded streams with rates clamped to the calibrated sustainable
        frames/s (floored at 3 decimals so the cap stays a hard ceiling)."""
        out = []
        for s in streams:
            cap = self.calibration.frame_rate_cap(s.stream_id)
            if cap < s.fps:
                out.append(dataclasses.replace(
                    s, fps=math.floor(cap * 1000) / 1000))
            else:
                out.append(s)
        return out

    def _recalibrate(self, t: float, measured: dict) -> None:
        rates = dict(measured)
        default = (sum(rates.values()) / len(rates)) if rates else None
        self.calibration = ServiceCalibration(
            tokens_per_frame=self.service.tokens_per_frame,
            rates_tokens_per_s=rates, default_rate=default)
        self.detector.reset()
        self.recalibrations.append(t)
        if self.adaptive is not None:
            self.adaptive.flag_recalibration()

    def decide(self, t: float, streams: Sequence[Stream], *,
               preempted: bool = False) -> Plan:
        measured = self.probe.measure(t)
        verdict = self.detector.observe(t, measured, self.calibration)
        self.last_drift = verdict
        self.telemetry.emit(t, "drift.rel_error", verdict.rel_error)
        self.telemetry.emit(t, "drift.streak", verdict.streak)

        recalibrated = False
        if verdict.fired:
            with self.tracer.span("recalibrate", t=t,
                                  rel_error=round(verdict.rel_error, 6),
                                  streak=verdict.streak) as sp:
                self._recalibrate(t, measured)
                recalibrated = True
                self.telemetry.emit(t, "drift.recalibrations",
                                    len(self.recalibrations))
                plan = self._decide_inner(t, streams,
                                          preempted=preempted, force=True)
                sp.attrs["plan_cost_usd_per_h"] = round(plan.hourly_cost, 6)
        if not recalibrated:
            plan = self._decide_inner(t, streams, preempted=preempted)
        self.telemetry.emit(t, "plan.cost.usd_per_h", plan.hourly_cost)
        return plan

    def _decide_inner(self, t: float, streams: Sequence[Stream], *,
                      preempted: bool, force: bool = False) -> Plan:
        with self.tracer.span("replan.decide", t=t) as sp:
            plan = self.inner.decide(t, self._clamped(streams),
                                     preempted=preempted or force)
            events = getattr(self.adaptive, "events", None)
            if events:
                sp.attrs["action"] = events[-1].action
                sp.attrs["migrations"] = events[-1].migrations
        # the span's wall clock is the solver's true cost — export it so a
        # hub-side Histogram can report exact p50/p95/p99 per run
        self.telemetry.emit(t, "replan.wall_ms", sp.wall_ms)
        return plan
