"""Per-region live drift and per-group recalibration.

PR 6's loop is fleet-wide: one detector over one probe, and a firing
re-profiles and replans the *entire* fleet. Jain et al.'s large-deployment
argument (PAPERS.md) says drift is regional — a codec rollout hits one
city's cameras, a noisy neighbor one zone's engines — so this module splits
every stage of the loop by stream group:

* **probe** — :class:`WindowedServiceProbe` adapts the simulator's ground
  truth into the *live* delta-export semantics of
  ``ContinuousBatchingEngine.windowed_rates()`` (time-averaged tokens/s
  since the previous poll), and :class:`EngineWindowProbe` is the
  real-deployment bridge: one serving engine per region, their
  ``windowed_rates()`` merged into a single measurement with the region
  remembered per stream.
* **detect** — :class:`RegionalDriftDetector` runs one
  :class:`~repro.obs.drift.DriftDetector` streak per group, so a regression
  in one region fires only that region's detector; a healthy region's
  streak is never polluted (nor masked) by a drifting neighbor.
* **recalibrate** — :class:`RegionalRecalibratingPolicy` re-profiles *only
  the fired groups'* streams, merges the partial measurement into the
  active :class:`~repro.sim.ledger.ServiceCalibration`, and forces a
  min-migration repair **scoped to the affected bins** (see
  ``core/repair.py``'s ``scope``) — the healthy regions' placements are
  never consolidation fodder and the defrag escape hatch (a global
  reshuffle) is out of scope for a partial recalibration.

``benchmarks/obs_export.py`` gates the outcome on the ``regional_drift``
scenario: per-group recalibration matches or beats fleet-wide recalibration
on cost with strictly fewer migrations, and only the drifted region's
detector fires.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Iterable, Mapping, Optional, Sequence

from repro.obs.drift import DriftConfig, DriftDetector, DriftVerdict
from repro.obs.metrics import TelemetryHub
from repro.obs.recalibrate import RecalibratingPolicy
from repro.obs.trace import Tracer
from repro.sim.ledger import ServiceCalibration

GroupFn = Callable[[str], str]


# ---------------------------------------------------------------------------
# Probes: the live windowed_rates() feed
# ---------------------------------------------------------------------------


class WindowedServiceProbe:
    """``windowed_rates()``-shaped probe over a ground-truth service.

    Wraps an :class:`~repro.obs.probe.DriftingService` and reports, per
    poll, each stream's *time-averaged* tokens/s since the previous poll —
    exactly the delta-export semantics of a live engine's
    ``windowed_rates()``, rather than the instantaneous snapshot of the
    exact probe. A mid-window regression therefore appears at its
    time-weighted magnitude first and at full magnitude one poll later,
    which is what a real deployment's detector sees. The first poll (no
    window yet) reports the instantaneous rates.
    """

    def __init__(self, service) -> None:
        self.service = service
        self._last_poll: Optional[float] = None

    @property
    def tokens_per_frame(self) -> float:
        return self.service.tokens_per_frame

    def initial_calibration(self) -> ServiceCalibration:
        return self.service.initial_calibration()

    def measure(self, t: float) -> dict[str, float]:
        t0, self._last_poll = self._last_poll, t
        if t0 is None or t <= t0:
            return self.service.rates_at(t)
        return self.service.mean_rates(t0, t)


class EngineWindowProbe:
    """The real-deployment bridge: per-region serving engines, one probe.

    ``engines`` maps a region (group) name to anything exposing
    ``windowed_rates()`` and ``measured_rates()`` — a
    :class:`~repro.serving.engine.ContinuousBatchingEngine` per region.
    ``measure(t)`` merges every engine's delta export into one
    ``{stream_id: tokens/s}`` measurement, remembering which region served
    each stream; ``group_of`` is then the grouping function a
    :class:`RegionalDriftDetector` partitions by. Streams idle in every
    engine this window are simply absent — no data, not zero throughput —
    so the per-group detectors treat silence as no evidence.
    """

    def __init__(self, engines: Mapping[str, object], *,
                 tokens_per_frame: float = 8.0) -> None:
        self.engines = dict(engines)
        self.tokens_per_frame = tokens_per_frame
        self._region_of: dict[str, str] = {}

    def measure(self, t: float) -> dict[str, float]:
        merged: dict[str, float] = {}
        for region in sorted(self.engines):
            for sid, rate in self.engines[region].windowed_rates().items():
                merged[sid] = rate
                self._region_of[sid] = region
        return merged

    def group_of(self, stream_id: str) -> str:
        return self._region_of.get(stream_id, "unknown")

    def initial_calibration(self) -> ServiceCalibration:
        """Startup profile from every engine's lifetime ``measured_rates()``
        (profile-once, the belief a non-recalibrating policy keeps)."""
        rates: dict[str, float] = {}
        for region in sorted(self.engines):
            for sid, rate in self.engines[region].measured_rates().items():
                rates[sid] = rate
                self._region_of[sid] = region
        default = (sum(rates.values()) / len(rates)) if rates else None
        return ServiceCalibration(tokens_per_frame=self.tokens_per_frame,
                                  rates_tokens_per_s=rates,
                                  default_rate=default)


# ---------------------------------------------------------------------------
# Per-group detection
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class RegionalVerdict:
    """One observation window, partitioned by group.

    ``verdicts`` holds each group's own :class:`DriftVerdict` (independent
    streaks); ``fired_groups`` the groups whose streak reached the hold this
    window. The aggregate fields (``rel_error`` is the stream-weighted mean
    over groups with data) make the verdict a drop-in for the fleet-wide
    one where a single number is expected (ledger column, telemetry)."""

    t: float
    verdicts: Mapping[str, DriftVerdict]
    fired_groups: tuple[str, ...]
    rel_error: float
    max_rel_error: float
    n_streams: int

    @property
    def fired(self) -> bool:
        return bool(self.fired_groups)

    @property
    def drifting(self) -> bool:
        return any(v.drifting for v in self.verdicts.values())

    @property
    def streak(self) -> int:
        return max((v.streak for v in self.verdicts.values()), default=0)


class RegionalDriftDetector:
    """One independent drift streak per stream group (region).

    ``group_of`` maps a stream id to its group; measurements are partitioned
    by it and each partition feeds that group's own
    :class:`DriftDetector` — a regression in one region can neither fire a
    healthy region's detector nor be diluted below threshold by the healthy
    regions' zero error (the failure mode of a fleet-wide mean). Groups may
    be declared up front (``groups=...``) or discovered from measurements.
    """

    def __init__(self, group_of: GroupFn,
                 config: DriftConfig = DriftConfig(), *,
                 groups: Iterable[str] = ()) -> None:
        self.group_of = group_of
        self.config = config
        self.detectors: dict[str, DriftDetector] = {
            g: DriftDetector(config) for g in groups}
        self.history: list[RegionalVerdict] = []
        self.firings: list[tuple[float, str]] = []   # (t, group), in order

    def detector(self, group: str) -> DriftDetector:
        if group not in self.detectors:
            self.detectors[group] = DriftDetector(self.config)
        return self.detectors[group]

    def observe(self, t: float, measured: Mapping[str, float],
                calibration) -> RegionalVerdict:
        partitions: dict[str, dict[str, float]] = {}
        for sid in sorted(measured):
            partitions.setdefault(self.group_of(sid), {})[sid] = measured[sid]
        verdicts: dict[str, DriftVerdict] = {}
        fired: list[str] = []
        for group in sorted(set(self.detectors) | set(partitions)):
            # a group with no data this window still observes {}: no
            # evidence, streak preserved (same convention as fleet-wide)
            v = self.detector(group).observe(t, partitions.get(group, {}),
                                             calibration)
            verdicts[group] = v
            if v.fired:
                fired.append(group)
                self.firings.append((t, group))
        n = sum(v.n_streams for v in verdicts.values())
        rel = (sum(v.rel_error * v.n_streams for v in verdicts.values()) / n
               if n else 0.0)
        verdict = RegionalVerdict(
            t=t, verdicts=verdicts, fired_groups=tuple(fired),
            rel_error=rel,
            max_rel_error=max((v.max_rel_error for v in verdicts.values()),
                              default=0.0),
            n_streams=n)
        self.history.append(verdict)
        return verdict

    def reset(self, group: Optional[str] = None) -> None:
        """Forget the streak of one group (after its partial recalibration)
        or of every group (``group=None``)."""
        if group is None:
            for det in self.detectors.values():
                det.reset()
        elif group in self.detectors:
            self.detectors[group].reset()

    def fired_groups(self) -> tuple[str, ...]:
        """Every group that has ever fired, in first-firing order."""
        seen: list[str] = []
        for _, g in self.firings:
            if g not in seen:
                seen.append(g)
        return tuple(seen)


# ---------------------------------------------------------------------------
# Per-group recalibration
# ---------------------------------------------------------------------------


class RegionalRecalibratingPolicy(RecalibratingPolicy):
    """Drift-aware policy wrapper with per-group scope (module doc above).

    Differences from the fleet-wide :class:`RecalibratingPolicy`:

    * the measurement source defaults to a :class:`WindowedServiceProbe`
      over ``service`` — the live ``windowed_rates()`` semantics — and any
      object with ``measure(t)`` (e.g. an :class:`EngineWindowProbe` over
      real per-region engines) can be passed as ``probe``;
    * detection runs a :class:`RegionalDriftDetector`, so only the drifted
      group's streak fires;
    * a firing re-profiles *only the fired groups' streams*, merging the
      partial measurement into the active calibration (healthy groups keep
      their profile untouched), and the forced replan is a min-migration
      repair **scoped to the affected bins** via
      ``AdaptiveManager.flag_recalibration(scope=...)``.
    """

    def __init__(self, inner, service, *, group_of: GroupFn,
                 config: DriftConfig = DriftConfig(),
                 detector: Optional[RegionalDriftDetector] = None,
                 probe=None,
                 telemetry: Optional[TelemetryHub] = None,
                 tracer: Optional[Tracer] = None,
                 calibration: Optional[ServiceCalibration] = None,
                 groups: Iterable[str] = ()) -> None:
        probe = probe if probe is not None else WindowedServiceProbe(service)
        super().__init__(inner, service, detector=DriftDetector(config),
                         telemetry=telemetry, tracer=tracer,
                         calibration=calibration, probe=probe)
        self.name = f"regional-recal-{inner.name}"
        self.group_of = group_of
        self.regional = (detector if detector is not None
                         else RegionalDriftDetector(group_of, config,
                                                    groups=groups))
        # (t, fired groups) per recalibration — the benchmark's scoping gate
        self.recal_groups: list[tuple[float, tuple[str, ...]]] = []

    # -- the per-group loop --------------------------------------------------

    def _recalibrate_groups(self, t: float, measured: Mapping[str, float],
                            groups: Sequence[str]) -> frozenset[str]:
        """Partial re-profile: adopt the measured rates of the fired groups'
        streams only, merged into the active calibration. Returns the
        re-profiled stream ids (the repair scope)."""
        fired = set(groups)
        scoped = frozenset(sid for sid in measured
                           if self.group_of(sid) in fired)
        rates = dict(self.calibration.rates_tokens_per_s)
        for sid in scoped:
            rates[sid] = measured[sid]
        default = (sum(rates.values()) / len(rates)) if rates else None
        self.calibration = ServiceCalibration(
            tokens_per_frame=self.service.tokens_per_frame,
            rates_tokens_per_s=rates, default_rate=default)
        for g in groups:
            self.regional.reset(g)
        self.recalibrations.append(t)
        self.recal_groups.append((t, tuple(sorted(groups))))
        if self.adaptive is not None:
            self.adaptive.flag_recalibration(scope=scoped)
        return scoped

    def decide(self, t: float, streams, *, preempted: bool = False):
        measured = self.probe.measure(t)
        verdict = self.regional.observe(t, measured, self.calibration)
        self.last_drift = verdict
        self.telemetry.emit(t, "drift.rel_error", verdict.rel_error)
        for group, v in sorted(verdict.verdicts.items()):
            self.telemetry.emit(t, "drift.rel_error", v.rel_error,
                                region=group)
            self.telemetry.emit(t, "drift.streak", v.streak, region=group)

        recalibrated = False
        if verdict.fired_groups:
            with self.tracer.span(
                    "recalibrate", t=t,
                    regions=",".join(verdict.fired_groups),
                    rel_error=round(verdict.rel_error, 6)) as sp:
                scoped = self._recalibrate_groups(t, measured,
                                                 verdict.fired_groups)
                recalibrated = True
                sp.attrs["scoped_streams"] = len(scoped)
                self.telemetry.emit(t, "drift.recalibrations",
                                    len(self.recalibrations),
                                    regions=",".join(verdict.fired_groups))
                plan = self._decide_inner(t, streams,
                                          preempted=preempted, force=True)
                sp.attrs["plan_cost_usd_per_h"] = round(plan.hourly_cost, 6)
        if not recalibrated:
            plan = self._decide_inner(t, streams, preempted=preempted)
        self.telemetry.emit(t, "plan.cost.usd_per_h", plan.hourly_cost)
        return plan


def camera_region_groups(streams_or_specs, *,
                         regions=None) -> dict[str, str]:
    """stream_id -> nearest datacenter region, from each stream's camera.

    Convenience for building scenario group maps: anything with
    ``stream_id`` and ``camera`` attributes works (``Stream``,
    ``CameraSpec``)."""
    from repro.core import geo
    regions = list(regions) if regions is not None \
        else sorted(geo.DATACENTERS)
    out: dict[str, str] = {}
    for s in streams_or_specs:
        cam = getattr(s, "camera", None)
        out[s.stream_id] = (geo.nearest_region(cam, regions)
                            if cam is not None else "unknown")
    return out
