"""Streaming metric export: the event loop's live signal, not a post-hoc read.

The fleet simulator's :class:`~repro.sim.ledger.Ledger` is a DataFrame-shaped
record you inspect *after* the run; a production fleet needs signals *during*
it. :class:`TelemetryHub` is that bridge, modeled on OpenFilter's
observability layer and its OpenTelemetry exporter: producers ``emit()``
named points as simulated time advances, and subscribers (dashboards, the
drift detector, a JSON exporter) receive every point synchronously at emit
time — incremental export, no buffering required to observe the run live.

Metric names follow OTel-ish dotted conventions; the full catalog exported
by the simulator is documented in docs/observability.md ("The hub and the
metric catalog"). Everything is plain data: points are frozen, the hub keeps
an append-only list, and ``series(name)`` gives the per-metric time series
for tests and plots.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional


@dataclasses.dataclass(frozen=True)
class MetricPoint:
    """One exported measurement at simulated time ``t`` (hours).

    ``attrs`` are sorted key/value labels (e.g. ``market="spot"``), kept as
    a tuple so points stay hashable and comparable in tests.
    """

    t: float
    name: str
    value: float
    attrs: tuple[tuple[str, str], ...] = ()

    def attr(self, key: str) -> Optional[str]:
        for k, v in self.attrs:
            if k == key:
                return v
        return None


Subscriber = Callable[[MetricPoint], None]


class TelemetryHub:
    """Append-only stream of :class:`MetricPoint` with push subscribers.

    ``emit()`` is the producer API (the fleet event loop, the cluster's
    boot/terminate hooks, the recalibrating policy); ``subscribe()`` is the
    consumer API — callbacks run synchronously in emit order, so a consumer
    observes the simulation *as it happens* rather than after ``run()``
    returns. ``latest``/``series`` are pull-side conveniences over the same
    stream.
    """

    def __init__(self) -> None:
        self.points: list[MetricPoint] = []
        self._latest: dict[str, MetricPoint] = {}
        self._subscribers: list[Subscriber] = []
        # (t, subscriber repr, error repr) per delivery failure — a raising
        # subscriber (an exporter hitting a closed file, a flaky dashboard
        # callback) must never abort the producer's event loop
        self.subscriber_failures: list[tuple[float, str, str]] = []

    def subscribe(self, fn: Subscriber) -> None:
        """Register a callback invoked synchronously on every emit."""
        self._subscribers.append(fn)

    def emit(self, t: float, name: str, value: float, **attrs: str) -> MetricPoint:
        point = MetricPoint(t=t, name=name, value=float(value),
                            attrs=tuple(sorted((k, str(v))
                                               for k, v in attrs.items()))
                            if attrs else ())
        self.points.append(point)
        self._latest[name] = point
        for fn in self._subscribers:
            # subscriber isolation: one raising consumer must not abort the
            # fleet event loop nor starve the remaining subscribers — record
            # the failure and keep delivering
            try:
                fn(point)
            except Exception as e:            # noqa: BLE001 - isolation point
                self.subscriber_failures.append(
                    (t, getattr(fn, "__qualname__", None) or repr(fn),
                     f"{type(e).__name__}: {e}"))
        return point

    # -- pull-side views ------------------------------------------------------

    def latest(self, name: str) -> Optional[float]:
        """Most recent value of a metric (None if never emitted)."""
        point = self._latest.get(name)
        return None if point is None else point.value

    def series(self, name: str) -> list[tuple[float, float]]:
        """The (t, value) time series of one metric, in emit order."""
        return [(p.t, p.value) for p in self.points if p.name == name]

    def names(self) -> list[str]:
        """Every metric name seen so far, sorted."""
        return sorted({p.name for p in self.points})

    def to_rows(self) -> list[dict]:
        """JSON-ready rows (benchmark artifacts serialize these)."""
        return [{"t": p.t, "name": p.name, "value": p.value,
                 "attrs": dict(p.attrs)} for p in self.points]
