"""Per-replan trace spans: what the control loop decided, and why, as a tree.

A replan is not one event but a small causal chain — drift fired, the
calibration was rebuilt, the repair planner ran, the defrag hatch maybe
fired. Spans capture that chain the way an OpenTelemetry trace would:
each span carries the *simulated* time it happened at, its *wall-clock*
duration (the real solver cost), free-form attributes, and child spans
(``recalibrate`` nests the ``replan`` it forces). The tracer keeps finished
root spans in order; tests and benchmark artifacts read them back.
"""
from __future__ import annotations

import contextlib
import dataclasses
import time
from typing import Iterator, Optional


@dataclasses.dataclass
class Span:
    """One traced operation at simulated time ``t`` (hours).

    ``wall_ms`` is the real time spent inside the span (solver calls are
    the control loop's true cost); ``attrs`` may be set while the span is
    open (e.g. the replan action chosen); ``children`` are spans opened
    while this one was active.
    """

    name: str
    t: float
    wall_ms: float = 0.0
    attrs: dict = dataclasses.field(default_factory=dict)
    children: list["Span"] = dataclasses.field(default_factory=list)


class Tracer:
    """Collects spans; nesting follows the runtime call stack."""

    def __init__(self) -> None:
        self.spans: list[Span] = []          # finished *root* spans, in order
        self._stack: list[Span] = []

    @contextlib.contextmanager
    def span(self, name: str, t: float = 0.0, **attrs) -> Iterator[Span]:
        sp = Span(name=name, t=t, attrs=dict(attrs))
        parent = self._stack[-1] if self._stack else None
        self._stack.append(sp)
        t0 = time.perf_counter()
        try:
            yield sp
        except BaseException as e:
            # a failing body (a solver call blowing up mid-replan) still
            # finalizes: mark the span, let the finally clause attach it to
            # its parent, and re-raise — the rest of the trace survives
            sp.attrs.setdefault("error", f"{type(e).__name__}: {e}")
            raise
        finally:
            sp.wall_ms = (time.perf_counter() - t0) * 1e3
            self._stack.pop()
            if parent is not None:
                parent.children.append(sp)
            else:
                self.spans.append(sp)

    def find(self, name: str) -> list[Span]:
        """All finished spans with this name, depth-first."""
        out: list[Span] = []

        def walk(sp: Span) -> None:
            if sp.name == name:
                out.append(sp)
            for child in sp.children:
                walk(child)

        for sp in self.spans:
            walk(sp)
        return out

    def to_rows(self, spans: Optional[list[Span]] = None,
                depth: int = 0) -> list[dict]:
        """JSON-ready rows, depth-annotated (pre-order)."""
        rows: list[dict] = []
        for sp in (self.spans if spans is None else spans):
            rows.append({"name": sp.name, "t": sp.t,
                         "wall_ms": round(sp.wall_ms, 3),
                         "depth": depth, "attrs": dict(sp.attrs)})
            rows.extend(self.to_rows(sp.children, depth + 1))
        return rows
