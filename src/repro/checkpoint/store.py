"""Flat-npz checkpointing with pytree structure preserved via key paths.

Good enough for single-host runs and tests; sharded arrays are gathered
(fine at smoke scale — production would swap in tensorstore/orbax behind the
same two functions).
"""
from __future__ import annotations

import json
import os
from typing import Any

import jax
import numpy as np

Pytree = Any


def _flatten_with_paths(tree: Pytree) -> dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(_path_str(p) for p in path)
        flat[key] = np.asarray(leaf)
    return flat


def _path_str(p) -> str:
    if hasattr(p, "key"):
        return str(p.key)
    if hasattr(p, "idx"):
        return f"[{p.idx}]"
    return str(p)


def save_checkpoint(path: str, tree: Pytree, meta: dict | None = None) -> None:
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    flat = _flatten_with_paths(tree)
    np.savez(path, **flat)
    if meta is not None:
        with open(path + ".meta.json", "w") as f:
            json.dump(meta, f, indent=2, default=str)


def restore_checkpoint(path: str, like: Pytree) -> Pytree:
    """Restore into the structure of ``like`` (shapes/dtypes must match)."""
    data = np.load(path if path.endswith(".npz") else path + ".npz")
    paths, treedef = jax.tree_util.tree_flatten_with_path(like)
    leaves = []
    for path_elems, leaf in paths:
        key = "/".join(_path_str(p) for p in path_elems)
        arr = data[key]
        if tuple(arr.shape) != tuple(leaf.shape):
            raise ValueError(f"{key}: shape {arr.shape} != {leaf.shape}")
        leaves.append(jax.numpy.asarray(arr, dtype=leaf.dtype))
    return jax.tree_util.tree_unflatten(treedef, leaves)
