"""Simulated cluster: rented instances, boot delays, and the spot market.

The planner emits a :class:`~repro.core.strategies.Plan` (bins of streams on
(type, location) choices); the cluster is the *physical* side of that plan —
instances take time to boot, keep running until terminated, and, when rented
on the spot market, can be reclaimed mid-tick by a preemption event. Capacity
accounting (instance-hours by region/type/market) feeds the ledger.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Iterable, Optional

import numpy as np

# canonical market names live in core (the planner labels bins with them)
from repro.core.markets import ONDEMAND, SPOT, SPOT_KEY_SUFFIX
from repro.core.strategies import Plan


@dataclasses.dataclass
class SimInstance:
    """One rented instance over its lifetime in simulated hours."""

    instance_id: str
    type_name: str
    location: str
    price: float                      # on-demand $/h reference price
    market: str = ONDEMAND
    boot_t: float = 0.0               # when the rental started (billing start)
    ready_t: float = 0.0              # boot_t + boot delay (service start)
    terminated_t: Optional[float] = None
    preempted: bool = False
    bid: Optional[float] = None       # spot bid, $/h; None = legacy spot
                                      # (hazard-governed) or on-demand

    def _overlap(self, start: float, t0: float, t1: float) -> float:
        end = self.terminated_t if self.terminated_t is not None else math.inf
        return max(0.0, min(t1, end) - max(t0, start))

    def billed_hours(self, t0: float, t1: float) -> float:
        """Hours billed in [t0, t1): clouds charge from launch, not readiness."""
        return self._overlap(self.boot_t, t0, t1)

    def running_fraction(self, t0: float, t1: float) -> float:
        """Fraction of [t0, t1) the instance could actually serve streams."""
        if t1 <= t0:
            return 0.0
        return self._overlap(self.ready_t, t0, t1) / (t1 - t0)


class SpotMarket:
    """Per-region spot prices as a clamped multiplicative random walk, plus a
    constant preemption hazard for spot instances.

    ``multiplier(region)`` is the current spot/on-demand price ratio. The
    walk is seeded, so the whole price history is a pure function of the
    seed — two runs of a scenario see identical markets. The walk and the
    preemption draws use *separate* generators: the market is exogenous, so
    the price history must not depend on how many instances a policy happens
    to hold (otherwise two policies under one seed would face different
    prices and their ledgers would not be comparable).
    """

    def __init__(self, regions: Iterable[str], *, discount: float = 0.35,
                 volatility: float = 0.15, hazard_per_h: float = 0.08,
                 seed: int = 0) -> None:
        self.discount = discount
        self.volatility = volatility
        self.hazard_per_h = hazard_per_h
        self._walk = {r: 1.0 for r in sorted(regions)}
        self._rng = np.random.default_rng(seed)
        self._preempt_rng = np.random.default_rng(seed + 7919)
        # full multiplier history, one snapshot per step(): the
        # exogenous-prices fixture — two policies under one seed must
        # observe identical series (tests/test_markets_properties.py)
        self.price_history: list[dict[str, float]] = [self.multipliers()]

    def multiplier(self, region: str) -> float:
        return self.discount * self._walk.get(region, 1.0)

    def multipliers(self) -> dict[str, float]:
        """Current spot/on-demand price ratio per region (the planner's
        view of the market; feeds ``core.markets.quotes``)."""
        return {r: self.discount * w for r, w in sorted(self._walk.items())}

    def spot_rate(self, inst: SimInstance) -> float:
        """Current spot $/hour for an instance (list price x multiplier)."""
        return inst.price * self.multiplier(inst.location)

    def step(self, dt_h: float) -> None:
        """Advance every region's price walk by dt hours."""
        sigma = self.volatility * math.sqrt(max(dt_h, 1e-9))
        for r in sorted(self._walk):
            self._walk[r] = float(np.clip(
                self._walk[r] * math.exp(self._rng.normal(0.0, sigma)),
                0.5, 2.5))
        self.price_history.append(self.multipliers())

    def draw_preemptions(self, t: float, dt_h: float,
                         spot_instances: Iterable[SimInstance]
                         ) -> list[tuple[float, str]]:
        """(time, instance_id) reclaim events inside [t, t + dt).

        Preemption probability over the interval follows an exponential
        hazard scaled by the price walk: when the region's spot price runs
        hot, reclaims are more likely — the classic spot failure mode.

        Bid-carrying instances are skipped entirely: their reclaims are a
        deterministic function of bid vs price (:meth:`outbid`) and must
        consume no randomness — otherwise how many bids a policy holds
        would shift the preemption draws of the legacy hazard instances,
        breaking ledger comparability across policies under one seed.
        """
        out: list[tuple[float, str]] = []
        for inst in spot_instances:
            if inst.bid is not None:
                continue
            hazard = self.hazard_per_h * self._walk.get(inst.location, 1.0)
            p = 1.0 - math.exp(-hazard * dt_h)
            if self._preempt_rng.random() < p:
                out.append((t + float(self._preempt_rng.uniform(0.0, dt_h)),
                            inst.instance_id))
        return out

    def outbid(self, spot_instances: Iterable[SimInstance]
               ) -> list[str]:
        """Instance ids whose bid the market just rose above.

        The market preempts *exactly* the underwater instances: bid >=
        current spot price means the instance survives the whole interval
        — guaranteed, not probabilistic (property-tested). Deterministic:
        consumes no randomness, so prices and preemption draws stay
        exogenous to the bidding policy."""
        return [inst.instance_id for inst in spot_instances
                if inst.bid is not None
                and self.spot_rate(inst) > inst.bid + 1e-12]


class Cluster:
    """Tracks rented instances and reconciles them against each new plan."""

    def __init__(self, *, boot_delay_h: float = 0.05,
                 spot_fraction: float = 0.0, seed: int = 0,
                 telemetry=None) -> None:
        self.boot_delay_h = boot_delay_h
        self.spot_fraction = spot_fraction
        self.instances: dict[str, SimInstance] = {}
        self._counter = 0
        self._rng = np.random.default_rng(seed)
        self._prev_assignment: dict[str, str] = {}   # stream_id -> instance_id
        # optional obs.TelemetryHub: lifecycle events stream out as metric
        # points (cluster.instance.boot / .terminate); None = zero overhead
        self.telemetry = telemetry

    # -- queries -------------------------------------------------------------

    def live(self) -> list[SimInstance]:
        return [i for i in self.instances.values() if i.terminated_t is None]

    def live_spot(self) -> list[SimInstance]:
        return [i for i in self.live() if i.market == SPOT]

    def get(self, instance_id: str) -> SimInstance:
        return self.instances[instance_id]

    # -- lifecycle -----------------------------------------------------------

    def _boot(self, t: float, choice_key: str, type_name: str, location: str,
              price: float, market: Optional[str] = None,
              bid: Optional[float] = None) -> SimInstance:
        if market is None:
            # legacy mode: the market is drawn per boot (spot_fraction);
            # market-aware plans pass it explicitly and consume no RNG
            market = SPOT if (self.spot_fraction > 0 and
                              self._rng.random() < self.spot_fraction) \
                else ONDEMAND
        self._counter += 1
        inst = SimInstance(
            instance_id=f"{choice_key}#{self._counter}",
            type_name=type_name, location=location, price=price,
            market=market, boot_t=t, ready_t=t + self.boot_delay_h, bid=bid)
        self.instances[inst.instance_id] = inst
        if self.telemetry is not None:
            self.telemetry.emit(t, "cluster.instance.boot", 1.0,
                                instance=inst.instance_id,
                                type=type_name, location=location,
                                market=market)
        return inst

    def terminate(self, instance_id: str, t: float,
                  preempted: bool = False) -> None:
        """Schedule termination at ``t`` (which may be in the future, for
        drains). An earlier termination — e.g. a preemption landing during a
        drain — wins; a later one never extends a lifetime."""
        inst = self.instances[instance_id]
        if inst.terminated_t is None or t < inst.terminated_t:
            first = inst.terminated_t is None
            inst.terminated_t = t
            inst.preempted = preempted or inst.preempted
            if self.telemetry is not None and first:
                self.telemetry.emit(t, "cluster.instance.terminate", 1.0,
                                    instance=inst.instance_id,
                                    type=inst.type_name,
                                    location=inst.location,
                                    market=inst.market,
                                    preempted=str(inst.preempted))

    def reconcile(self, t: float, plan: Plan,
                  drain_h: float = 0.0,
                  bids: Optional[dict] = None) -> dict[str, str]:
        """Make the physical fleet match the plan; map streams to instances.

        Matching is *sticky*: a bin goes to the live instance of its (type,
        location) choice that already hosts the most of its streams (by the
        previous reconcile's assignment), so stable plans produce stable
        placements — a single preemption no longer shifts every later bin of
        that key onto a different machine. Bins and instances left unmatched
        pair up oldest-first, so scale-down still retires the newest rentals.
        Missing instances boot now (ready after the boot delay); surplus ones
        drain for ``drain_h`` before terminating (make-before-break: the old
        placement keeps serving while replacements boot — billed, like any
        lame-duck VM). Returns ``{stream_id: instance_id}`` for the ledger.

        ``bids`` switches on market-aware reconciliation for mixed plans
        (bins labeled via ``Choice.market``): instances are matched within
        their market (a spot rental never serves an on-demand bin), spot
        bins boot SPOT instances carrying the policy's ``(type_name,
        location)`` bid, and no boot consumes market RNG. The instance's
        ``price`` stays the on-demand list price — spot billing applies the
        market multiplier at accrual time, and the bid only controls
        reclaims.
        """
        market_aware = bids is not None
        ondemand_ref: dict[tuple[str, str], float] = {}
        if market_aware:
            for c in plan.problem.choices:
                if c.market == ONDEMAND:
                    ondemand_ref[(c.type_name, c.location)] = c.price

        by_key: dict[str, list] = {}
        for b in plan.solution.bins:
            ch = plan.problem.choices[b.choice]
            by_key.setdefault(ch.key, []).append((b, ch))

        live_by_key: dict[str, list[SimInstance]] = {}
        for inst in self.live():
            key = f"{inst.type_name}@{inst.location}"
            if market_aware and inst.market == SPOT:
                key += SPOT_KEY_SUFFIX
            live_by_key.setdefault(key, []).append(inst)
        for insts in live_by_key.values():
            insts.sort(key=lambda i: (i.boot_t, i.instance_id))

        assignment: dict[str, str] = {}
        for key in sorted(by_key):
            bins = by_key[key]
            have = live_by_key.get(key, [])
            # vote: how many of each bin's streams already live on each
            # candidate instance (per the previous assignment)?
            votes: list[tuple[int, int, int]] = []      # (-count, bin#, inst#)
            for n, (b, _) in enumerate(bins):
                tally: dict[str, int] = {}
                for i in b.items:
                    iid = self._prev_assignment.get(plan.problem.items[i].key)
                    if iid is not None:
                        tally[iid] = tally.get(iid, 0) + 1
                for m, inst in enumerate(have):
                    c = tally.get(inst.instance_id, 0)
                    if c > 0:
                        votes.append((-c, n, m))
            votes.sort()
            matched_bin: dict[int, SimInstance] = {}
            taken: set[int] = set()
            for negc, n, m in votes:
                if n in matched_bin or m in taken:
                    continue
                matched_bin[n] = have[m]
                taken.add(m)
            # leftovers pair oldest-first, then boot
            free = [inst for m, inst in enumerate(have) if m not in taken]
            for n, (b, ch) in enumerate(bins):
                inst = matched_bin.get(n)
                if inst is None and free:
                    inst = free.pop(0)
                elif inst is None and market_aware:
                    ref = ondemand_ref.get((ch.type_name, ch.location),
                                           ch.price)
                    inst = self._boot(
                        t, ch.key, ch.type_name, ch.location, ref,
                        market=ch.market,
                        bid=(bids.get((ch.type_name, ch.location))
                             if ch.market == SPOT else None))
                elif inst is None:
                    inst = self._boot(
                        t, ch.key, ch.type_name, ch.location, ch.price)
                for i in b.items:
                    assignment[plan.problem.items[i].key] = inst.instance_id
            for extra in free:
                self.terminate(extra.instance_id, t + drain_h)
        for key, insts in live_by_key.items():
            if key not in by_key:
                for inst in insts:
                    self.terminate(inst.instance_id, t + drain_h)
        self._prev_assignment = assignment
        return assignment

    # -- capacity / billing --------------------------------------------------

    def accrue(self, t0: float, t1: float,
               market: Optional[SpotMarket] = None
               ) -> tuple[float, dict[tuple[str, str, str], float],
                          dict[str, float]]:
        """Cost and instance-hours accrued over [t0, t1).

        Spot instances bill at the market's current multiplier (you pay the
        market price, never your bid); on-demand at the catalog price.
        Returns (dollars, {(location, type, market): hours},
        {market: dollars}) — the last is the ledger's spot vs on-demand
        spend split.
        """
        cost = 0.0
        hours: dict[tuple[str, str, str], float] = {}
        by_market: dict[str, float] = {ONDEMAND: 0.0, SPOT: 0.0}
        # dict insertion order (boot order) is deterministic; skipping
        # long-terminated instances keeps per-tick billing O(live + recent)
        for inst in self.instances.values():
            if inst.terminated_t is not None and inst.terminated_t <= t0:
                continue
            h = inst.billed_hours(t0, t1)
            if h <= 0:
                continue
            rate = inst.price
            if inst.market == SPOT and market is not None:
                rate *= market.multiplier(inst.location)
            cost += rate * h
            by_market[inst.market] = by_market.get(inst.market, 0.0) + rate * h
            k = (inst.location, inst.type_name, inst.market)
            hours[k] = hours.get(k, 0.0) + h
        return cost, hours, by_market
