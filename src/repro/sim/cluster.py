"""Simulated cluster: rented instances, boot delays, and the spot market.

The planner emits a :class:`~repro.core.strategies.Plan` (bins of streams on
(type, location) choices); the cluster is the *physical* side of that plan —
instances take time to boot, keep running until terminated, and, when rented
on the spot market, can be reclaimed mid-tick by a preemption event. Capacity
accounting (instance-hours by region/type/market) feeds the ledger.

Instance state is stored *columnar* (struct-of-arrays): parallel
boot/ready/terminated/price arrays in boot order, with the classic
:class:`SimInstance` dataclass constructed lazily as a cached view at the
API edge (``cluster.instances[iid]``, ``live()``). Billing
(:meth:`Cluster.accrue`) and batch preemptions
(:meth:`Cluster.terminate_batch`) are single numpy passes over the columns,
and :meth:`Cluster.retire` seals long-terminated rows into a per-(location,
type, market) hours aggregate so per-tick work tracks the *live* fleet, not
every instance ever booted. All of it is bit-identical to the historical
per-object loops (tests/test_columnar_parity.py, tests/test_golden_ledgers).
"""
from __future__ import annotations

import dataclasses
import math
from collections.abc import Mapping
from typing import Iterable, Optional

import numpy as np

# canonical market names live in core (the planner labels bins with them)
from repro.core.markets import ONDEMAND, SPOT, SPOT_KEY_SUFFIX
from repro.core.strategies import Plan

_INF = math.inf


@dataclasses.dataclass
class SimInstance:
    """One rented instance over its lifetime in simulated hours."""

    instance_id: str
    type_name: str
    location: str
    price: float                      # on-demand $/h reference price
    market: str = ONDEMAND
    boot_t: float = 0.0               # when the rental started (billing start)
    ready_t: float = 0.0              # boot_t + boot delay (service start)
    terminated_t: Optional[float] = None
    preempted: bool = False
    bid: Optional[float] = None       # spot bid, $/h; None = legacy spot
                                      # (hazard-governed) or on-demand

    def _overlap(self, start: float, t0: float, t1: float) -> float:
        end = self.terminated_t if self.terminated_t is not None else math.inf
        return max(0.0, min(t1, end) - max(t0, start))

    def billed_hours(self, t0: float, t1: float) -> float:
        """Hours billed in [t0, t1): clouds charge from launch, not readiness."""
        return self._overlap(self.boot_t, t0, t1)

    def running_fraction(self, t0: float, t1: float) -> float:
        """Fraction of [t0, t1) the instance could actually serve streams."""
        if t1 <= t0:
            return 0.0
        return self._overlap(self.ready_t, t0, t1) / (t1 - t0)


class SpotMarket:
    """Per-region spot prices as a clamped multiplicative random walk, plus a
    constant preemption hazard for spot instances.

    ``multiplier(region)`` is the current spot/on-demand price ratio. The
    walk is seeded, so the whole price history is a pure function of the
    seed — two runs of a scenario see identical markets. The walk and the
    preemption draws use *separate* generators: the market is exogenous, so
    the price history must not depend on how many instances a policy happens
    to hold (otherwise two policies under one seed would face different
    prices and their ledgers would not be comparable).
    """

    def __init__(self, regions: Iterable[str], *, discount: float = 0.35,
                 volatility: float = 0.15, hazard_per_h: float = 0.08,
                 seed: int = 0, history_limit: Optional[int] = 4096) -> None:
        self.discount = discount
        self.volatility = volatility
        self.hazard_per_h = hazard_per_h
        self._walk = {r: 1.0 for r in sorted(regions)}
        self._rng = np.random.default_rng(seed)
        self._preempt_rng = np.random.default_rng(seed + 7919)
        # multiplier history, one snapshot per step(): the exogenous-prices
        # fixture — two policies under one seed must observe identical
        # series (tests/test_markets_properties.py). Bounded to the most
        # recent ``history_limit`` snapshots so an open-ended run does not
        # grow without bound (None = unbounded; bidding policies only look
        # back a few steps).
        self.history_limit = history_limit
        self.price_history: list[dict[str, float]] = [self.multipliers()]

    def multiplier(self, region: str) -> float:
        return self.discount * self._walk.get(region, 1.0)

    def multipliers(self) -> dict[str, float]:
        """Current spot/on-demand price ratio per region (the planner's
        view of the market; feeds ``core.markets.quotes``)."""
        return {r: self.discount * w for r, w in sorted(self._walk.items())}

    def spot_rate(self, inst: SimInstance) -> float:
        """Current spot $/hour for an instance (list price x multiplier)."""
        return inst.price * self.multiplier(inst.location)

    def step(self, dt_h: float) -> None:
        """Advance every region's price walk by dt hours."""
        sigma = self.volatility * math.sqrt(max(dt_h, 1e-9))
        for r in sorted(self._walk):
            self._walk[r] = float(np.clip(
                self._walk[r] * math.exp(self._rng.normal(0.0, sigma)),
                0.5, 2.5))
        self.price_history.append(self.multipliers())
        if self.history_limit is not None \
                and len(self.price_history) > self.history_limit:
            del self.price_history[:len(self.price_history)
                                   - self.history_limit]

    def draw_preemptions(self, t: float, dt_h: float,
                         spot_instances: Iterable[SimInstance]
                         ) -> list[tuple[float, str]]:
        """(time, instance_id) reclaim events inside [t, t + dt).

        Preemption probability over the interval follows an exponential
        hazard scaled by the price walk: when the region's spot price runs
        hot, reclaims are more likely — the classic spot failure mode.

        Bid-carrying instances are skipped entirely: their reclaims are a
        deterministic function of bid vs price (:meth:`outbid`) and must
        consume no randomness — otherwise how many bids a policy holds
        would shift the preemption draws of the legacy hazard instances,
        breaking ledger comparability across policies under one seed.
        """
        out: list[tuple[float, str]] = []
        for inst in spot_instances:
            if inst.bid is not None:
                continue
            hazard = self.hazard_per_h * self._walk.get(inst.location, 1.0)
            p = 1.0 - math.exp(-hazard * dt_h)
            if self._preempt_rng.random() < p:
                out.append((t + float(self._preempt_rng.uniform(0.0, dt_h)),
                            inst.instance_id))
        return out

    def outbid(self, spot_instances: Iterable[SimInstance]
               ) -> list[str]:
        """Instance ids whose bid the market just rose above.

        The market preempts *exactly* the underwater instances: bid >=
        current spot price means the instance survives the whole interval
        — guaranteed, not probabilistic (property-tested). Deterministic:
        consumes no randomness, so prices and preemption draws stay
        exogenous to the bidding policy."""
        return [inst.instance_id for inst in spot_instances
                if inst.bid is not None
                and self.spot_rate(inst) > inst.bid + 1e-12]


class _InstanceMap(Mapping):
    """Read-only ``{instance_id: SimInstance}`` view over the columns.

    Views are constructed lazily and cached; lifecycle mutations
    (terminate, drain-cancel) update cached views in place, so a held
    reference always reflects the columns. Retired instances disappear."""

    __slots__ = ("_c",)

    def __init__(self, cluster: "Cluster") -> None:
        self._c = cluster

    def __getitem__(self, instance_id: str) -> SimInstance:
        return self._c._view(self._c._row[instance_id])

    def get(self, instance_id: str, default=None):
        row = self._c._row.get(instance_id)
        return self._c._view(row) if row is not None else default

    def __contains__(self, instance_id) -> bool:
        return instance_id in self._c._row

    def __len__(self) -> int:
        return self._c._n

    def __iter__(self):
        return iter(list(self._c._ids))

    def values(self):
        c = self._c
        return [c._view(r) for r in range(c._n)]     # boot order

    def items(self):
        return [(v.instance_id, v) for v in self.values()]


class Cluster:
    """Tracks rented instances and reconciles them against each new plan."""

    def __init__(self, *, boot_delay_h: float = 0.05,
                 spot_fraction: float = 0.0, seed: int = 0,
                 telemetry=None) -> None:
        self.boot_delay_h = boot_delay_h
        self.spot_fraction = spot_fraction
        self._counter = 0
        self._rng = np.random.default_rng(seed)
        # previous stream->instance assignment, in exactly one of two
        # representations (the other is derived lazily at path changes):
        # a dict keyed by stream id (object path), or (ids list, row array)
        # aligned to a StreamColumns id list (columnar path).
        self._prev_assignment: Optional[dict[str, str]] = {}
        self._prev_cols: Optional[tuple[list, np.ndarray]] = None
        # optional obs.TelemetryHub: lifecycle events stream out as metric
        # points (cluster.instance.boot / .terminate); None = zero overhead
        self.telemetry = telemetry

        # -- columnar instance state (boot order; _n rows live in arrays of
        # capacity _cap, grown by doubling) ---------------------------------
        self._n = 0
        self._cap = 64
        self._boot_t = np.zeros(self._cap)
        self._ready = np.zeros(self._cap)
        self._term = np.full(self._cap, _INF)       # inf = never terminated
        self._price = np.zeros(self._cap)
        self._bid = np.full(self._cap, np.nan)      # nan = no bid
        self._preempt = np.zeros(self._cap, dtype=bool)
        self._spot = np.zeros(self._cap, dtype=bool)
        self._loc_c = np.zeros(self._cap, dtype=np.int64)
        self._key_c = np.zeros(self._cap, dtype=np.int64)
        self._ids: list[str] = []
        self._types: list[str] = []
        self._locs: list[str] = []
        self._markets: list[str] = []
        self._bkey: list[str] = []                  # "type@loc" per row
        self._row: dict[str, int] = {}
        self._views: dict[str, SimInstance] = {}
        self._loc_uniq: list[str] = []
        self._loc_of: dict[str, int] = {}
        self._key_uniq: list[tuple[str, str, str]] = []
        self._key_of: dict[tuple[str, str, str], int] = {}
        # sealed aggregate of retired instances: lifetime hours per
        # (location, type, market) — billing already accrued them tick by
        # tick; this keeps capacity reporting whole after rows are dropped
        self.retired_hours: dict[tuple[str, str, str], float] = {}
        self.retired_count = 0

    # -- columnar plumbing ---------------------------------------------------

    def _grow(self) -> None:
        self._cap *= 2
        for name in ("_boot_t", "_ready", "_term", "_price", "_bid",
                     "_preempt", "_spot", "_loc_c", "_key_c"):
            old = getattr(self, name)
            new = np.empty(self._cap, dtype=old.dtype)
            new[:self._n] = old[:self._n]
            if name == "_term":
                new[self._n:] = _INF
            setattr(self, name, new)

    def _view(self, row: int) -> SimInstance:
        iid = self._ids[row]
        v = self._views.get(iid)
        if v is None:
            term = self._term[row]
            bid = self._bid[row]
            v = SimInstance(
                instance_id=iid, type_name=self._types[row],
                location=self._locs[row], price=float(self._price[row]),
                market=self._markets[row], boot_t=float(self._boot_t[row]),
                ready_t=float(self._ready[row]),
                terminated_t=(float(term) if math.isfinite(term) else None),
                preempted=bool(self._preempt[row]),
                bid=(float(bid) if not math.isnan(bid) else None))
            self._views[iid] = v
        return v

    # -- queries -------------------------------------------------------------

    @property
    def instances(self) -> _InstanceMap:
        """``{instance_id: SimInstance}`` — lazy views over the columns."""
        return _InstanceMap(self)

    def live(self) -> list[SimInstance]:
        rows = np.flatnonzero(np.isinf(self._term[:self._n]))
        return [self._view(int(r)) for r in rows]

    def live_spot(self) -> list[SimInstance]:
        n = self._n
        rows = np.flatnonzero(np.isinf(self._term[:n]) & self._spot[:n])
        return [self._view(int(r)) for r in rows]

    def live_count(self) -> int:
        """``len(live())`` without materializing views."""
        return int(np.count_nonzero(np.isinf(self._term[:self._n])))

    def get(self, instance_id: str) -> SimInstance:
        return self._view(self._row[instance_id])

    # -- lifecycle -----------------------------------------------------------

    def _boot_row(self, t: float, choice_key: str, type_name: str,
                  location: str, price: float, market: Optional[str] = None,
                  bid: Optional[float] = None) -> int:
        if market is None:
            # legacy mode: the market is drawn per boot (spot_fraction);
            # market-aware plans pass it explicitly and consume no RNG
            market = SPOT if (self.spot_fraction > 0 and
                              self._rng.random() < self.spot_fraction) \
                else ONDEMAND
        self._counter += 1
        iid = f"{choice_key}#{self._counter}"
        if self._n == self._cap:
            self._grow()
        row = self._n
        self._n += 1
        self._boot_t[row] = t
        self._ready[row] = t + self.boot_delay_h
        self._term[row] = _INF
        self._price[row] = price
        self._bid[row] = np.nan if bid is None else bid
        self._preempt[row] = False
        self._spot[row] = market == SPOT
        loc_code = self._loc_of.get(location)
        if loc_code is None:
            loc_code = len(self._loc_uniq)
            self._loc_of[location] = loc_code
            self._loc_uniq.append(location)
        self._loc_c[row] = loc_code
        key = (location, type_name, market)
        key_code = self._key_of.get(key)
        if key_code is None:
            key_code = len(self._key_uniq)
            self._key_of[key] = key_code
            self._key_uniq.append(key)
        self._key_c[row] = key_code
        self._ids.append(iid)
        self._types.append(type_name)
        self._locs.append(location)
        self._markets.append(market)
        self._bkey.append(f"{type_name}@{location}")
        self._row[iid] = row
        if self.telemetry is not None:
            self.telemetry.emit(t, "cluster.instance.boot", 1.0,
                                instance=iid, type=type_name,
                                location=location, market=market)
        return row

    def _boot(self, t: float, choice_key: str, type_name: str, location: str,
              price: float, market: Optional[str] = None,
              bid: Optional[float] = None) -> SimInstance:
        return self._view(self._boot_row(t, choice_key, type_name, location,
                                         price, market, bid))

    def terminate(self, instance_id: str, t: float,
                  preempted: bool = False) -> None:
        """Schedule termination at ``t`` (which may be in the future, for
        drains). An earlier termination — e.g. a preemption landing during a
        drain — wins; a later one never extends a lifetime."""
        row = self._row[instance_id]
        cur = self._term[row]
        if t < cur:
            first = math.isinf(cur)
            self._term[row] = t
            if preempted:
                self._preempt[row] = True
            v = self._views.get(instance_id)
            if v is not None:
                v.terminated_t = t
                v.preempted = preempted or v.preempted
            if self.telemetry is not None and first:
                self.telemetry.emit(t, "cluster.instance.terminate", 1.0,
                                    instance=instance_id,
                                    type=self._types[row],
                                    location=self._locs[row],
                                    market=self._markets[row],
                                    preempted=str(bool(self._preempt[row])))

    def terminate_batch(self, events) -> list:
        """Apply one tick's preemption batch in event order.

        ``events`` is an iterable of ``(when, instance_id, tag)`` sorted the
        way the old per-event heap would have popped them. An event lands
        only if its target is still alive past ``when`` (the same aliveness
        check the event loop used to make per pop); applied events mark the
        instance preempted. Returns the tags of the applied events, in
        order — the event loop's preemption/outbid counters."""
        applied = []
        term = self._term
        for when, iid, tag in events:
            row = self._row.get(iid)
            if row is None:
                continue
            cur = term[row]
            if cur > when:
                fresh = math.isinf(cur)
                term[row] = when
                self._preempt[row] = True
                v = self._views.get(iid)
                if v is not None:
                    v.terminated_t = when
                    v.preempted = True
                if self.telemetry is not None and fresh:
                    self.telemetry.emit(when, "cluster.instance.terminate",
                                        1.0, instance=iid,
                                        type=self._types[row],
                                        location=self._locs[row],
                                        market=self._markets[row],
                                        preempted="True")
                applied.append(tag)
        return applied

    def _cancel_drain(self, row: int, t: float) -> None:
        """Reclaim a draining instance the new plan matched: cancel the
        scheduled termination instead of booting (and billing) a duplicate
        while the identical lame-duck is still running."""
        if math.isinf(self._term[row]):
            return
        self._term[row] = _INF
        iid = self._ids[row]
        v = self._views.get(iid)
        if v is not None:
            v.terminated_t = None
        if self.telemetry is not None:
            self.telemetry.emit(t, "cluster.instance.undrain", 1.0,
                                instance=iid, type=self._types[row],
                                location=self._locs[row],
                                market=self._markets[row])

    def retire(self, before_t: float) -> Optional[np.ndarray]:
        """Drop rows terminated strictly before ``before_t`` from the
        columns, sealing their lifetime hours into :attr:`retired_hours`.

        The caller (the fleet loop, after accounting [t0, t1) with
        ``before_t = t0``) guarantees nothing still references them: any
        instance a future accounting interval or reconcile vote can touch
        was assigned at some decision time >= t0 and therefore has
        ``terminated_t >= t0``. Billing is unaffected — a row with
        ``terminated_t < t0`` accrues exactly zero in every window from t0
        on. Returns the old->new row remap (-1 = dropped) so callers
        holding row arrays can update them (``_prev_cols`` is remapped in
        place here), or None if nothing was dropped."""
        n = self._n
        if n == 0:
            return None
        term = self._term[:n]
        drop = term < before_t
        if not drop.any():
            return None
        for r in np.flatnonzero(drop).tolist():
            key = (self._locs[r], self._types[r], self._markets[r])
            self.retired_hours[key] = (self.retired_hours.get(key, 0.0)
                                       + float(term[r] - self._boot_t[r]))
            iid = self._ids[r]
            del self._row[iid]
            self._views.pop(iid, None)
        keep = np.flatnonzero(~drop)
        m = int(keep.size)
        for name in ("_boot_t", "_ready", "_term", "_price", "_bid",
                     "_preempt", "_spot", "_loc_c", "_key_c"):
            arr = getattr(self, name)
            arr[:m] = arr[keep]
            if name == "_term":
                arr[m:n] = _INF
        kl = keep.tolist()
        self._ids = [self._ids[r] for r in kl]
        self._types = [self._types[r] for r in kl]
        self._locs = [self._locs[r] for r in kl]
        self._markets = [self._markets[r] for r in kl]
        self._bkey = [self._bkey[r] for r in kl]
        self._row = {iid: k for k, iid in enumerate(self._ids)}
        self.retired_count += int(n - m)
        self._n = m
        remap = np.full(n, -1, dtype=np.int64)
        remap[keep] = np.arange(m, dtype=np.int64)
        if self._prev_cols is not None:
            _, prows = self._prev_cols
            prows[:] = np.where(prows >= 0, remap[np.maximum(prows, 0)], -1)
        return remap

    # -- reconciliation ------------------------------------------------------

    def _candidates_by_key(self, t: float,
                           market_aware: bool) -> dict[str, list[int]]:
        """Rows a plan's bins can match at decision time ``t``, grouped by
        matching key and ordered (boot_t, instance_id) like the historical
        live-instance sort. Includes *draining* rows (terminated_t > t):
        the drain-reclaim fix — a scale-up inside the drain window re-uses
        the lame-duck instead of booting a duplicate."""
        n = self._n
        rows = np.flatnonzero(self._term[:n] > t)
        out: dict[str, list[int]] = {}
        bkey = self._bkey
        spot = self._spot
        for r in rows.tolist():
            key = bkey[r]
            if market_aware and spot[r]:
                key += SPOT_KEY_SUFFIX
            out.setdefault(key, []).append(r)
        boot = self._boot_t
        ids = self._ids
        for rws in out.values():
            rws.sort(key=lambda r: (boot[r], ids[r]))
        return out

    def _prev_rows_for_items(self, problem) -> Optional[np.ndarray]:
        """Per-item previous-instance row (-1 = none), aligned with
        ``problem.items`` — the vote-tally input, from whichever previous
        assignment representation is current."""
        ids = getattr(problem, "packed_ids", None)
        if (self._prev_cols is not None and ids is not None
                and self._prev_cols[0] is ids):
            return self._prev_cols[1]
        prev = self._prev_assignment
        if prev is None and self._prev_cols is not None:
            pids, prows = self._prev_cols
            prev = {}
            own = self._ids
            for sid, r in zip(pids, prows.tolist()):
                if r >= 0:
                    prev[sid] = own[r]
            self._prev_assignment = prev
        if not prev:
            return None
        keys = ids if ids is not None else [it.key for it in problem.items]
        pr = np.full(len(keys), -1, dtype=np.int64)
        row_of = self._row
        for k, sid in enumerate(keys):
            iid = prev.get(sid)
            if iid is not None:
                r = row_of.get(iid)
                if r is not None:
                    pr[k] = r
        return pr

    def _reconcile_impl(self, t: float, plan: Plan, drain_h: float,
                        bids: Optional[dict],
                        pr: Optional[np.ndarray]) -> dict[int, int]:
        """Shared matching core: returns {solution bin index: row}.

        Matching is *sticky*: per (type, location[, market]) key, each bin
        goes to the candidate instance already hosting the most of its
        streams (vote tally over ``pr``, the per-item previous rows), ties
        to earlier bins and older instances; leftovers pair oldest-first;
        missing instances boot; surplus ones drain for ``drain_h``. A
        matched candidate that was draining has its drain canceled."""
        market_aware = bids is not None
        problem = plan.problem
        choices = problem.choices
        ondemand_ref: dict[tuple[str, str], float] = {}
        if market_aware:
            for c in choices:
                if c.market == ONDEMAND:
                    ondemand_ref[(c.type_name, c.location)] = c.price

        bins = plan.solution.bins
        by_key: dict[str, list[int]] = {}
        for bi, b in enumerate(bins):
            by_key.setdefault(choices[b.choice].key, []).append(bi)

        cands = self._candidates_by_key(t, market_aware)

        # vote tally, vectorized over (bin, previous row) pairs: how many of
        # each bin's streams already live on each candidate of its key
        votes_by_key: dict[str, list[tuple[int, int, int]]] = {}
        if pr is not None and bins:
            lengths = np.fromiter((len(b.items) for b in bins),
                                  dtype=np.int64, count=len(bins))
            total = int(lengths.sum())
            if total:
                flat = np.fromiter((i for b in bins for i in b.items),
                                   dtype=np.int64, count=total)
                item_bin = np.repeat(
                    np.arange(len(bins), dtype=np.int64), lengths)
                p = pr[flat]
                ok = p >= 0
                if ok.any():
                    span = np.int64(max(self._n, 1))
                    pairs = item_bin[ok] * span + p[ok]
                    uniq, counts = np.unique(pairs, return_counts=True)
                    bin_local: dict[int, tuple[str, int]] = {}
                    for key, bl in by_key.items():
                        for nn, bi in enumerate(bl):
                            bin_local[bi] = (key, nn)
                    cand_local: dict[int, tuple[str, int]] = {}
                    for key, rws in cands.items():
                        for mm, r in enumerate(rws):
                            cand_local[r] = (key, mm)
                    for pair, c in zip(uniq.tolist(), counts.tolist()):
                        bi, r = divmod(pair, int(span))
                        kb, nn = bin_local[bi]
                        kc = cand_local.get(r)
                        if kc is None or kc[0] != kb:
                            continue
                        votes_by_key.setdefault(kb, []).append((-c, nn, kc[1]))

        bin_row: dict[int, int] = {}
        for key in sorted(by_key):
            bl = by_key[key]
            have = cands.get(key, [])
            votes = votes_by_key.get(key, [])
            votes.sort()
            matched: dict[int, int] = {}
            taken: set[int] = set()
            for _negc, nn, mm in votes:
                if nn in matched or mm in taken:
                    continue
                matched[nn] = have[mm]
                taken.add(mm)
            # leftovers pair oldest-first, then boot
            free = [r for mm, r in enumerate(have) if mm not in taken]
            for nn, bi in enumerate(bl):
                row = matched.get(nn)
                if row is None and free:
                    row = free.pop(0)
                if row is None:
                    ch = choices[bins[bi].choice]
                    if market_aware:
                        ref = ondemand_ref.get((ch.type_name, ch.location),
                                               ch.price)
                        row = self._boot_row(
                            t, ch.key, ch.type_name, ch.location, ref,
                            market=ch.market,
                            bid=(bids.get((ch.type_name, ch.location))
                                 if ch.market == SPOT else None))
                    else:
                        row = self._boot_row(t, ch.key, ch.type_name,
                                             ch.location, ch.price)
                else:
                    self._cancel_drain(row, t)
                bin_row[bi] = row
            for extra in free:
                self.terminate(self._ids[extra], t + drain_h)
        for key, rws in cands.items():
            if key not in by_key:
                for r in rws:
                    self.terminate(self._ids[r], t + drain_h)
        return bin_row

    def reconcile(self, t: float, plan: Plan,
                  drain_h: float = 0.0,
                  bids: Optional[dict] = None) -> dict[str, str]:
        """Make the physical fleet match the plan; map streams to instances.

        Matching is *sticky*: a bin goes to the live instance of its (type,
        location) choice that already hosts the most of its streams (by the
        previous reconcile's assignment), so stable plans produce stable
        placements — a single preemption no longer shifts every later bin of
        that key onto a different machine. Bins and instances left unmatched
        pair up oldest-first, so scale-down still retires the newest rentals.
        Missing instances boot now (ready after the boot delay); surplus ones
        drain for ``drain_h`` before terminating (make-before-break: the old
        placement keeps serving while replacements boot — billed, like any
        lame-duck VM). An instance still *draining* at decision time is a
        match candidate like any live one — matching it cancels the drain
        (no duplicate boot inside the drain window). Returns ``{stream_id:
        instance_id}`` for the ledger.

        ``bids`` switches on market-aware reconciliation for mixed plans
        (bins labeled via ``Choice.market``): instances are matched within
        their market (a spot rental never serves an on-demand bin), spot
        bins boot SPOT instances carrying the policy's ``(type_name,
        location)`` bid, and no boot consumes market RNG. The instance's
        ``price`` stays the on-demand list price — spot billing applies the
        market multiplier at accrual time, and the bid only controls
        reclaims.
        """
        pr = self._prev_rows_for_items(plan.problem)
        bin_row = self._reconcile_impl(t, plan, drain_h, bids, pr)
        ids = getattr(plan.problem, "packed_ids", None)
        items = plan.problem.items
        own = self._ids
        assignment: dict[str, str] = {}
        for bi, b in enumerate(plan.solution.bins):
            iid = own[bin_row[bi]]
            if ids is not None:
                for i in b.items:
                    assignment[ids[i]] = iid
            else:
                for i in b.items:
                    assignment[items[i].key] = iid
        self._prev_assignment = assignment
        self._prev_cols = None
        return assignment

    def reconcile_rows(self, t: float, plan: Plan, stream_ids,
                       drain_h: float = 0.0,
                       bids: Optional[dict] = None) -> np.ndarray:
        """Columnar reconcile: same matching as :meth:`reconcile`, returning
        the per-stream instance *row* array aligned with ``stream_ids``
        (-1 = unplaced) instead of a dict. Requires the plan's problem to
        carry ``packed_ids is stream_ids`` (the packed builder stamps it);
        otherwise it delegates to the object path and converts. The result
        array is also stored as the previous assignment for the next tick's
        vote tally (and is remapped in place by :meth:`retire`)."""
        if getattr(plan.problem, "packed_ids", None) is not stream_ids:
            assignment = self.reconcile(t, plan, drain_h, bids)
            rows = np.full(len(stream_ids), -1, dtype=np.int64)
            row_of = self._row
            for k, sid in enumerate(stream_ids):
                iid = assignment.get(sid)
                if iid is not None:
                    rows[k] = row_of[iid]
            self._prev_cols = (stream_ids, rows)
            return rows
        pr = self._prev_rows_for_items(plan.problem)
        bin_row = self._reconcile_impl(t, plan, drain_h, bids, pr)
        rows = np.full(len(stream_ids), -1, dtype=np.int64)
        bins = plan.solution.bins
        if bins:
            lengths = np.fromiter((len(b.items) for b in bins),
                                  dtype=np.int64, count=len(bins))
            flat = np.fromiter((i for b in bins for i in b.items),
                               dtype=np.int64, count=int(lengths.sum()))
            per_bin = np.fromiter((bin_row[bi] for bi in range(len(bins))),
                                  dtype=np.int64, count=len(bins))
            rows[flat] = np.repeat(per_bin, lengths)
        self._prev_cols = (stream_ids, rows)
        self._prev_assignment = None
        return rows

    # -- capacity / billing --------------------------------------------------

    def accrue(self, t0: float, t1: float,
               market: Optional[SpotMarket] = None
               ) -> tuple[float, dict[tuple[str, str, str], float],
                          dict[str, float]]:
        """Cost and instance-hours accrued over [t0, t1), as one numpy pass
        over the columns (retired rows would accrue exactly zero, so the
        scan really is O(live + recently-terminated) once the fleet loop
        retires old rows).

        Spot instances bill at the market's current multiplier (you pay the
        market price, never your bid); on-demand at the catalog price.
        Returns (dollars, {(location, type, market): hours},
        {market: dollars}) — the last is the ledger's spot vs on-demand
        spend split.

        Bit-parity with the historical per-instance loop: per-row hours and
        rates are the same float expressions, and every reduction
        (``cumsum``'s running sum, ``bincount``'s in-order accumulation)
        adds in boot order exactly like the old ``+=`` loop; rows with zero
        billed hours contribute ``+ 0.0``, which is an identity on floats.
        """
        n = self._n
        by_market: dict[str, float] = {ONDEMAND: 0.0, SPOT: 0.0}
        if n == 0:
            return 0.0, {}, by_market
        boot = self._boot_t[:n]
        term = self._term[:n]
        h = np.maximum(0.0, np.minimum(t1, term) - np.maximum(t0, boot))
        rate = self._price[:n].copy()
        spot = self._spot[:n]
        if market is not None and spot.any():
            mult = np.array([market.multiplier(loc)
                             for loc in self._loc_uniq])
            srows = np.flatnonzero(spot)
            rate[srows] *= mult[self._loc_c[srows]]
        contrib = rate * h
        cost = float(np.cumsum(contrib)[-1])
        ond = contrib[~spot]
        if ond.size:
            by_market[ONDEMAND] = float(np.cumsum(ond)[-1])
        sp = contrib[spot]
        if sp.size:
            by_market[SPOT] = float(np.cumsum(sp)[-1])
        hours: dict[tuple[str, str, str], float] = {}
        active = h > 0.0
        if active.any():
            kc = self._key_c[:n]
            totals = np.bincount(kc, weights=h, minlength=len(self._key_uniq))
            # key insertion mirrors the scalar loop: only keys that actually
            # billed hours this window appear
            for k in np.unique(kc[active]).tolist():
                hours[self._key_uniq[k]] = float(totals[k])
        return cost, hours, by_market
