"""Composable demand generators: the fleet's frame-rate needs over time.

A demand model maps simulated UTC hours to the set of demanded
:class:`~repro.core.workload.Stream` objects. The base generator gives every
camera a diurnal rush-hour curve in its *local* (solar) time via
``core.geo.local_hour``, so a worldwide fleet ramps region by region as the
sun moves. Wrappers compose on top: Poisson camera churn (arrivals with
exponential lifetimes), flash-crowd events (a region's rates spike for a
window), and day/night program-mix shifts. Everything is a pure, seeded
function of time — two scans of the same model are identical.

Demand has two equivalent representations. ``streams_at`` returns the
classic list of ``Stream`` objects (the API edge). ``columns_at`` returns a
:class:`StreamColumns` — the same fleet as struct-of-arrays (ids, fps
vector, program/camera codes) — which the columnar fleet simulator and the
packed planner consume without materializing a Python object per stream.
Every wrapper composes on columns: churn appends rows, flash crowds rescale
the fps vector, mix shifts rewrite program codes. The two views are
bit-identical (``float(cols.fps[i]) == streams[i].fps`` etc.; see
tests/test_columnar_parity.py).
"""
from __future__ import annotations

import dataclasses
import math
import zlib
from typing import Optional, Protocol, Sequence

import numpy as np

from repro.core import geo
from repro.core.workload import PIPELINES, PROGRAMS, Stream


class DemandModel(Protocol):
    def streams_at(self, t_h: float) -> list[Stream]: ...


class StreamColumns(Sequence):
    """One tick's demanded fleet as struct-of-arrays.

    ``ids`` is the per-stream id list (stable models reuse the same list
    object every tick — downstream fast paths key on that identity);
    ``fps`` the demanded rates in frames/s (float64, exactly the rounded
    values ``streams_at`` would produce); programs and cameras are stored
    factorized: ``program_codes[i]`` indexes ``programs_unique`` (and
    ``camera_codes[i]`` indexes ``cameras_unique``, ``-1`` = no camera), so
    class grouping in the packed planner is pure array work.

    It is also a ``Sequence[Stream]``: indexing/iterating materializes the
    object view lazily (once per tick, cached), so object-path consumers —
    repair planning, EWMA forecasts — keep working unchanged.
    """

    __slots__ = ("ids", "fps", "program_codes", "programs_unique",
                 "camera_codes", "cameras_unique", "_streams")

    def __init__(self, ids, fps, program_codes, programs_unique,
                 camera_codes, cameras_unique) -> None:
        self.ids = ids
        self.fps = fps
        self.program_codes = program_codes
        self.programs_unique = programs_unique
        self.camera_codes = camera_codes
        self.cameras_unique = cameras_unique
        self._streams: Optional[list[Stream]] = None

    def __len__(self) -> int:
        return len(self.ids)

    def _materialize(self) -> list[Stream]:
        if self._streams is None:
            progs = self.programs_unique
            cams = self.cameras_unique
            fps = self.fps.tolist()
            self._streams = [
                Stream(sid, progs[p], fps=f,
                       camera=(cams[c] if c >= 0 else None))
                for sid, p, f, c in zip(self.ids, self.program_codes.tolist(),
                                        fps, self.camera_codes.tolist())]
        return self._streams

    def __getitem__(self, i):
        return self._materialize()[i]

    def __iter__(self):
        return iter(self._materialize())

    def any_camera(self) -> bool:
        return bool((self.camera_codes >= 0).any())


def _factorize_by_id(objs) -> tuple[np.ndarray, tuple]:
    """Codes for a list of objects, grouped by identity."""
    code_of: dict[int, int] = {}
    unique: list = []
    codes = np.empty(len(objs), dtype=np.int64)
    for n, o in enumerate(objs):
        c = code_of.get(id(o))
        if c is None:
            c = len(unique)
            code_of[id(o)] = c
            unique.append(o)
        codes[n] = c
    return codes, tuple(unique)


def _factorize_cameras(cams) -> tuple[np.ndarray, tuple]:
    """Codes for a list of camera ids (``None`` maps to code ``-1``)."""
    code_of: dict[str, int] = {}
    unique: list[str] = []
    codes = np.empty(len(cams), dtype=np.int64)
    for n, c in enumerate(cams):
        if c is None:
            codes[n] = -1
            continue
        k = code_of.get(c)
        if k is None:
            k = len(unique)
            code_of[c] = k
            unique.append(c)
        codes[n] = k
    return codes, tuple(unique)


@dataclasses.dataclass(frozen=True)
class CameraSpec:
    """One camera's demand profile: a diurnal curve between ``base_fps`` and
    ``peak_fps`` (both in frames/s, reached at local rush hours)."""

    stream_id: str
    camera: str                  # key in geo.CAMERAS
    program: str                 # key in workload.PROGRAMS
    base_fps: float              # frames/s off-peak
    peak_fps: float              # frames/s at the rush-hour crest


def rush_hour_fps(local_h: float, base: float, peak: float,
                  width_h: float = 1.5) -> float:
    """Demanded frame rate (frames/s) at local hour ``local_h``: morning
    (8:30) and evening (17:30) rush hours as Gaussian bumps of width
    ``width_h`` hours over a quiet base rate (paper Fig. 5's shape)."""
    bump = (math.exp(-((local_h - 8.5) / width_h) ** 2)
            + math.exp(-((local_h - 17.5) / width_h) ** 2))
    return base + (peak - base) * min(1.0, bump)


def _rush_hour_fps_array(local_h: np.ndarray, base, peak,
                         width_h: float) -> np.ndarray:
    """Batched :func:`rush_hour_fps` — identical floats, one numpy pass."""
    bump = (np.exp(-((local_h - 8.5) / width_h) ** 2)
            + np.exp(-((local_h - 17.5) / width_h) ** 2))
    return base + (peak - base) * np.minimum(1.0, bump)


@dataclasses.dataclass(frozen=True)
class DiurnalFleet:
    """Each camera follows the rush-hour curve in its own local time.

    Demand is evaluated *batched*: one numpy pass computes every camera's
    local hour and rush-hour frame rate (frames/s) per tick, instead of a
    Python call per camera — the per-stream loop only constructs the
    ``Stream`` objects. ``repro.core.packed.scalar_mode()`` switches back to
    the original per-camera evaluation (the parity baseline); both paths
    produce identical streams bit for bit (see tests/test_packed_parity.py).
    """

    cameras: tuple[CameraSpec, ...]
    width_h: float = 1.5

    def _arrays(self):
        """Cached per-camera columns: (utc offsets h, base fps, peak fps,
        program objects, stream ids, camera ids, program codes/unique,
        camera codes/unique)."""
        cached = getattr(self, "_cols", None)
        if cached is None:
            programs = [PROGRAMS[c.program] for c in self.cameras]
            cams = [c.camera for c in self.cameras]
            pcodes, puniq = _factorize_by_id(programs)
            ccodes, cuniq = _factorize_cameras(cams)
            cached = (
                np.array([geo.utc_offset_hours(c.camera)
                          for c in self.cameras]),
                np.array([c.base_fps for c in self.cameras]),
                np.array([c.peak_fps for c in self.cameras]),
                programs,
                [c.stream_id for c in self.cameras],
                cams,
                pcodes, puniq, ccodes, cuniq,
            )
            object.__setattr__(self, "_cols", cached)
        return cached

    def fps_at(self, t_h: float) -> np.ndarray:
        """All cameras' demanded frame rates (frames/s) at UTC hour ``t_h``
        as one vector — the batched form of :func:`rush_hour_fps`."""
        offs, base, peak = self._arrays()[:3]
        local_h = np.mod(t_h + offs, 24.0)
        return _rush_hour_fps_array(local_h, base, peak, self.width_h)

    def columns_at(self, t_h: float) -> StreamColumns:
        """The fleet at ``t_h`` as :class:`StreamColumns` (the id list and
        code arrays are the cached per-fleet objects, reused every tick)."""
        (_, _, _, _, ids, _, pcodes, puniq, ccodes, cuniq) = self._arrays()
        # np.round is verified bit-identical to the scalar round(., 3) on
        # this curve family (tests/test_packed_parity.py covers it end to
        # end)
        fps = np.round(self.fps_at(t_h), 3)
        return StreamColumns(ids, fps, pcodes, puniq, ccodes, cuniq)

    def streams_at(self, t_h: float) -> list[Stream]:
        from repro.core import packed
        if not packed.enabled() and self.cameras:
            out = []
            for c in self.cameras:
                fps = rush_hour_fps(geo.local_hour(t_h, c.camera),
                                    c.base_fps, c.peak_fps, self.width_h)
                out.append(Stream(c.stream_id, PROGRAMS[c.program],
                                  fps=round(fps, 3), camera=c.camera))
            return out
        (_, _, _, programs, ids, cams) = self._arrays()[:6]
        # tolist() converts to Python floats in one pass
        fps = np.round(self.fps_at(t_h), 3).tolist()
        # reuse the frozen Stream while a camera's rounded rate is unchanged
        # (diurnal curves plateau at base and peak) — identical objects, no
        # per-tick reallocation for the stable part of the fleet
        cache = getattr(self, "_stream_cache", None)
        if cache is None:
            cache = [None] * len(ids)
            object.__setattr__(self, "_stream_cache", cache)
        out = []
        for n, (sid, prog, fr, cam) in enumerate(zip(ids, programs, fps, cams)):
            s = cache[n]
            if s is None or s.fps != fr:
                s = Stream(sid, prog, fps=fr, camera=cam)
                cache[n] = s
            out.append(s)
        return out


def columnar_fleet(ids: list, utc_offset_h: np.ndarray, base_fps: np.ndarray,
                   peak_fps: np.ndarray, program_codes: np.ndarray,
                   programs_unique: tuple, camera_codes: np.ndarray,
                   cameras_unique: tuple, width_h: float = 1.5) -> DiurnalFleet:
    """Build a :class:`DiurnalFleet` directly from columns — no per-camera
    :class:`CameraSpec` objects. At continent scale (10^6 streams) the object
    constructor would allocate a million specs just to factorize them back
    into the arrays below; this hands the fleet its cached columns up front.
    ``programs_unique`` holds :class:`~repro.core.workload.Program` objects,
    ``cameras_unique`` camera ids (keys of ``geo.CAMERAS``); the code arrays
    index them per stream (camera code ``-1`` = no camera). The resulting
    model is bit-identical to the equivalent ``DiurnalFleet(specs)``."""
    pcodes = np.asarray(program_codes, dtype=np.int64)
    ccodes = np.asarray(camera_codes, dtype=np.int64)
    puniq = tuple(programs_unique)
    cuniq = tuple(cameras_unique)
    programs = [puniq[c] for c in pcodes.tolist()]
    cams = [cuniq[c] if c >= 0 else None for c in ccodes.tolist()]
    fleet = DiurnalFleet(cameras=(), width_h=width_h)
    object.__setattr__(fleet, "_cols", (
        np.asarray(utc_offset_h, dtype=np.float64),
        np.asarray(base_fps, dtype=np.float64),
        np.asarray(peak_fps, dtype=np.float64),
        programs, list(ids), cams, pcodes, puniq, ccodes, cuniq))
    return fleet


@dataclasses.dataclass(frozen=True)
class PipelineCameraSpec:
    """One camera running an analysis *pipeline* at a fixed capture rate.

    Unlike :class:`CameraSpec` (whose frame rate swings diurnally), the
    camera grabs ``fps`` frames/s around the clock — what swings is the
    scene's *content density* between ``base_density`` (sparse night) and
    ``peak_density`` (dense rush hour), which modulates how often each
    downstream pipeline stage activates. A busy scene IS the demand spike."""

    stream_id: str
    camera: str                  # key in geo.CAMERAS
    pipeline: str                # key in workload.PIPELINES
    fps: float                   # capture rate, frames/s (constant)
    base_density: float = 0.05   # scene density off-peak, in [0, 1]
    peak_density: float = 1.0    # scene density at the rush-hour crest


class _PipelineArrays:
    """Static per-fleet columns for :class:`PipelineFleet` (built once)."""

    __slots__ = ("offs", "dbase", "dpeak",
                 "pair_spec", "pair_share", "pair_floor", "pair_gain",
                 "pair_fps", "base_idx", "pooled_idx",
                 "base_ids", "base_pcodes", "base_ccodes",
                 "pool_code", "n_pools", "pool_chunks", "pool_prefixes",
                 "all_pcodes", "all_ccodes", "puniq", "cuniq", "ids")


@dataclasses.dataclass(frozen=True)
class PipelineFleet:
    """Content-aware pipeline demand: cameras emit *stages*, not streams.

    Every camera runs its pipeline's stages; each stage becomes one demand
    item ``"{stream_id}::{stage}"`` at the activation-weighted stage rate —
    so the planner packs stages (cheap full-frame detectors separately from
    heavy crop models) and the fleet's effective demand follows the scene
    density curve, not a frame-rate knob.

    ``consolidate=True`` additionally pools each camera-colocated group of
    ``consolidatable`` stage crops (same camera, pipeline, stage) into
    shared workers: the pooled rate is split across the fewest chunks that
    respect the stage's ``cap_fps()`` *at peak density* — the chunk count is
    static, so pooled ids (``"pool::{pipeline}.{stage}@{camera}#{k}"``) are
    stable all day and only the per-chunk rate breathes with the scene; one
    model load serves many cameras' crops, and no chunk ever appears
    mid-run just because the scene got busy. The ``#k`` suffix reuses the
    replica anti-affinity grammar from ``core.markets``: chunks of one pool
    never co-locate on a single spot market.

    Like :class:`DiurnalFleet`, evaluation is batched (one numpy pass per
    tick over the flattened (camera, stage) pairs) with a bit-identical
    scalar fallback under ``repro.core.packed.scalar_mode()``.
    """

    cameras: tuple[PipelineCameraSpec, ...]
    width_h: float = 1.5
    consolidate: bool = False

    # sim.fleet keys its stage/pooled ledger columns off this marker
    emits_stages = True

    def _arrays(self) -> _PipelineArrays:
        cached = getattr(self, "_cols", None)
        if cached is not None:
            return cached
        a = _PipelineArrays()
        a.offs = np.array([geo.utc_offset_hours(c.camera)
                           for c in self.cameras])
        a.dbase = np.array([c.base_density for c in self.cameras])
        a.dpeak = np.array([c.peak_density for c in self.cameras])
        # flatten to (camera, stage) pairs, spec-major in stage order
        pair_spec, share, floor, gain, fps = [], [], [], [], []
        pair_ids, pair_progs, pair_cams, pooled = [], [], [], []
        pair_stage, pair_pipe = [], []
        for n, spec in enumerate(self.cameras):
            pipe = PIPELINES[spec.pipeline]
            for st in pipe.stages:
                pair_spec.append(n)
                share.append(st.rate_share)
                floor.append(st.activation_floor)
                gain.append(st.activation_gain)
                fps.append(spec.fps)
                pair_ids.append(f"{spec.stream_id}::{st.name}")
                pair_progs.append(st.resolved_program())
                pair_cams.append(spec.camera)
                pair_stage.append(st)
                pair_pipe.append(pipe.name)
                pooled.append(self.consolidate and st.consolidatable)
        a.pair_spec = np.array(pair_spec, dtype=np.int64)
        a.pair_share = np.array(share)
        a.pair_floor = np.array(floor)
        a.pair_gain = np.array(gain)
        a.pair_fps = np.array(fps)
        pooled = np.array(pooled, dtype=bool)
        a.base_idx = np.flatnonzero(~pooled)
        a.pooled_idx = np.flatnonzero(pooled)
        a.base_ids = [pair_ids[i] for i in a.base_idx.tolist()]
        # pools factorize by (camera, pipeline, stage) in first appearance
        # order over the pooled pairs — the scalar path's dict order
        pool_of: dict[tuple, int] = {}
        pool_code, caps, prefixes, pool_progs, pool_cams = [], [], [], [], []
        peak_tot: list[float] = []
        for i in a.pooled_idx.tolist():
            st, pname, cam = pair_stage[i], pair_pipe[i], pair_cams[i]
            spec = self.cameras[pair_spec[i]]
            key = (cam, pname, st.name)
            k = pool_of.get(key)
            if k is None:
                k = len(pool_of)
                pool_of[key] = k
                caps.append(st.cap_fps())
                prefixes.append(f"pool::{pname}.{st.name}@{cam}")
                pool_progs.append(st.resolved_program())
                pool_cams.append(cam)
                peak_tot.append(0.0)
            pool_code.append(k)
            # the member's rate at the densest the scene ever gets — the
            # diurnal curve is bounded by [min, max](base, peak) density
            dmax = max(spec.base_density, spec.peak_density)
            act = min(1.0, max(0.0, st.activation_floor
                               + st.activation_gain * dmax))
            peak_tot[k] += round(spec.fps * (st.rate_share * act), 3)
        a.pool_code = np.array(pool_code, dtype=np.int64)
        a.n_pools = len(pool_of)
        # chunk counts are pinned at peak: per-chunk rate stays under
        # cap_fps() all day and the pooled id list never changes mid-run
        a.pool_chunks = np.array(
            [max(1, math.ceil(t / c)) for t, c in zip(peak_tot, caps)],
            dtype=np.int64)
        a.pool_prefixes = prefixes
        # one factorization covers base pairs and pools (emission order:
        # base items first, then pool chunks)
        base_progs = [pair_progs[i] for i in a.base_idx.tolist()]
        base_cams = [pair_cams[i] for i in a.base_idx.tolist()]
        pcodes, a.puniq = _factorize_by_id(base_progs + pool_progs)
        ccodes, a.cuniq = _factorize_cameras(base_cams + pool_cams)
        nb = len(base_progs)
        if a.n_pools:
            mm = a.pool_chunks
            a.all_pcodes = np.concatenate([pcodes[:nb],
                                           np.repeat(pcodes[nb:], mm)])
            a.all_ccodes = np.concatenate([ccodes[:nb],
                                           np.repeat(ccodes[nb:], mm)])
        else:
            a.all_pcodes, a.all_ccodes = pcodes, ccodes
        a.ids = a.base_ids + [f"{pref}#{k}"
                              for pref, m in zip(a.pool_prefixes,
                                                 a.pool_chunks.tolist())
                              for k in range(m)]
        object.__setattr__(self, "_cols", a)
        return a

    def density_at(self, t_h: float) -> np.ndarray:
        """Every camera's scene density at UTC hour ``t_h`` — the rush-hour
        curve of :func:`rush_hour_fps` reinterpreted as content density."""
        a = self._arrays()
        local = np.mod(t_h + a.offs, 24.0)
        return _rush_hour_fps_array(local, a.dbase, a.dpeak, self.width_h)

    def _pair_rates(self, t_h: float) -> np.ndarray:
        """Per-(camera, stage) demanded frames/s at ``t_h`` (milli-fps)."""
        a = self._arrays()
        dens = self.density_at(t_h)
        act = np.minimum(1.0, np.maximum(
            0.0, a.pair_floor + a.pair_gain * dens[a.pair_spec]))
        # same op order as the scalar path: fps * (share * activation)
        return np.round(a.pair_fps * (a.pair_share * act), 3)

    def columns_at(self, t_h: float) -> StreamColumns:
        a = self._arrays()
        rate = self._pair_rates(t_h)
        if a.n_pools == 0:
            return StreamColumns(a.ids, rate, a.all_pcodes, a.puniq,
                                 a.all_ccodes, a.cuniq)
        # np.bincount accumulates weights in input order — the same order
        # (spec-major, stage order) the scalar dict accumulation uses
        totals = np.bincount(a.pool_code, weights=rate[a.pooled_idx],
                             minlength=a.n_pools)
        # truncate (never round up) so cap_fps stays a hard per-chunk ceiling
        chunk = np.floor((totals / a.pool_chunks) * 1000.0) / 1000.0
        fps = np.concatenate([rate[a.base_idx],
                              np.repeat(chunk, a.pool_chunks)])
        return StreamColumns(a.ids, fps, a.all_pcodes, a.puniq,
                             a.all_ccodes, a.cuniq)

    def streams_at(self, t_h: float) -> list[Stream]:
        from repro.core import packed
        if packed.enabled() or not self.cameras:
            return list(self.columns_at(t_h))
        out: list[Stream] = []
        pool_totals: dict[tuple, float] = {}
        pool_meta: dict[tuple, tuple] = {}
        for spec in self.cameras:
            pipe = PIPELINES[spec.pipeline]
            dens = rush_hour_fps(geo.local_hour(t_h, spec.camera),
                                 spec.base_density, spec.peak_density,
                                 self.width_h)
            for st in pipe.stages:
                act = min(1.0, max(0.0, st.activation_floor
                                   + st.activation_gain * dens))
                f = round(spec.fps * (st.rate_share * act), 3)
                if self.consolidate and st.consolidatable:
                    key = (spec.camera, pipe.name, st.name)
                    meta = pool_meta.get(key)
                    if meta is None:
                        meta = pool_meta[key] = [st.cap_fps(),
                                                 st.resolved_program(), 0.0]
                        pool_totals[key] = 0.0
                    pool_totals[key] += f
                    # member's rate at peak density — fixes the chunk count
                    dmax = max(spec.base_density, spec.peak_density)
                    act_pk = min(1.0, max(0.0, st.activation_floor
                                          + st.activation_gain * dmax))
                    meta[2] += round(spec.fps * (st.rate_share * act_pk), 3)
                else:
                    out.append(Stream(f"{spec.stream_id}::{st.name}",
                                      st.resolved_program(), fps=f,
                                      camera=spec.camera))
        for (cam, pname, sname), total in pool_totals.items():
            cap, prog, peak = pool_meta[(cam, pname, sname)]
            m = max(1, math.ceil(peak / cap))
            f = math.floor((total / m) * 1000.0) / 1000.0
            for k in range(m):
                out.append(Stream(f"pool::{pname}.{sname}@{cam}#{k}",
                                  prog, fps=f, camera=cam))
        return out


@dataclasses.dataclass(frozen=True)
class PoissonChurn:
    """Cameras come and go: Poisson arrivals (``rate_per_h`` per simulated
    hour) over the horizon, each living an exponential lifetime of mean
    ``mean_lifetime_h`` hours, cycling through a pool of camera templates.
    The whole arrival schedule is drawn once at construction from the seed.

    Churn streams ride the *same* diurnal curve as the fleet they join:
    ``width_h`` is taken from the wrapped model's rush-hour width (or set
    explicitly), not silently reset to the default."""

    inner: DemandModel
    templates: tuple[CameraSpec, ...]
    rate_per_h: float = 0.5
    mean_lifetime_h: float = 6.0
    horizon_h: float = 24.0
    seed: int = 0
    # None = inherit the innermost wrapped model's width_h (1.5 if none
    # declares one); a float pins it explicitly
    width_h: Optional[float] = None
    _schedule: tuple[tuple[float, float, CameraSpec], ...] = ()

    def __post_init__(self) -> None:
        rng = np.random.default_rng(self.seed)
        n = int(rng.poisson(self.rate_per_h * self.horizon_h))
        arrivals = np.sort(rng.uniform(0.0, self.horizon_h, n))
        lifetimes = rng.exponential(self.mean_lifetime_h, n)
        sched = []
        for k, (a, life) in enumerate(zip(arrivals, lifetimes)):
            tpl = self.templates[k % len(self.templates)]
            spec = dataclasses.replace(tpl, stream_id=f"{tpl.stream_id}-churn{k}")
            sched.append((float(a), float(a + life), spec))
        object.__setattr__(self, "_schedule", tuple(sched))

    def effective_width_h(self) -> float:
        """The rush-hour width churn streams use: ``width_h`` if set, else
        the first ``width_h`` found walking down the wrapped model chain."""
        if self.width_h is not None:
            return self.width_h
        m = self.inner
        while m is not None:
            w = getattr(m, "width_h", None)
            if w is not None:
                return w
            m = getattr(m, "inner", None)
        return 1.5

    def _churn_arrays(self):
        """Cached per-schedule columns for the batched path."""
        cached = getattr(self, "_carr", None)
        if cached is None:
            sched = self._schedule
            programs = [PROGRAMS[c.program] for _, _, c in sched]
            cached = (
                np.array([s for s, _, _ in sched]),
                np.array([e for _, e, _ in sched]),
                np.array([geo.utc_offset_hours(c.camera)
                          for _, _, c in sched]),
                np.array([c.base_fps for _, _, c in sched]),
                np.array([c.peak_fps for _, _, c in sched]),
                programs,
                [c.stream_id for _, _, c in sched],
                [c.camera for _, _, c in sched],
            )
            object.__setattr__(self, "_carr", cached)
        return cached

    def _active_fps(self, t_h: float):
        """(active schedule indices, their rounded fps) at ``t_h``."""
        starts, ends, offs, base, peak = self._churn_arrays()[:5]
        if starts.size == 0:
            return np.empty(0, dtype=np.int64), np.empty(0)
        active = np.flatnonzero((starts <= t_h) & (t_h < ends))
        if active.size == 0:
            return active, np.empty(0)
        local = np.mod(t_h + offs[active], 24.0)
        fps = _rush_hour_fps_array(local, base[active], peak[active],
                                   self.effective_width_h())
        return active, np.round(fps, 3)

    def streams_at(self, t_h: float) -> list[Stream]:
        from repro.core import packed
        out = self.inner.streams_at(t_h)
        if not packed.enabled():
            width = self.effective_width_h()
            for start, end, c in self._schedule:
                if start <= t_h < end:
                    fps = rush_hour_fps(geo.local_hour(t_h, c.camera),
                                        c.base_fps, c.peak_fps, width)
                    out.append(Stream(c.stream_id, PROGRAMS[c.program],
                                      fps=round(fps, 3), camera=c.camera))
            return out
        active, fps = self._active_fps(t_h)
        if active.size:
            _, _, _, _, _, programs, ids, cams = self._churn_arrays()
            for k, f in zip(active.tolist(), fps.tolist()):
                out.append(Stream(ids[k], programs[k], fps=f, camera=cams[k]))
        return out

    def columns_at(self, t_h: float) -> StreamColumns:
        cols = self.inner.columns_at(t_h)
        active, fps = self._active_fps(t_h)
        if not active.size:
            return cols
        _, _, _, _, _, programs, ids, cams = self._churn_arrays()
        puniq = list(cols.programs_unique)
        pcode_of = {id(p): n for n, p in enumerate(puniq)}
        cuniq = list(cols.cameras_unique)
        ccode_of = {c: n for n, c in enumerate(cuniq)}
        pcodes = np.empty(active.size, dtype=np.int64)
        ccodes = np.empty(active.size, dtype=np.int64)
        for n, k in enumerate(active.tolist()):
            p = programs[k]
            pc = pcode_of.get(id(p))
            if pc is None:
                pc = len(puniq)
                pcode_of[id(p)] = pc
                puniq.append(p)
            pcodes[n] = pc
            cam = cams[k]
            cc = ccode_of.get(cam)
            if cc is None:
                cc = len(cuniq)
                ccode_of[cam] = cc
                cuniq.append(cam)
            ccodes[n] = cc
        return StreamColumns(
            cols.ids + [ids[k] for k in active.tolist()],
            np.concatenate([cols.fps, fps]),
            np.concatenate([cols.program_codes, pcodes]), tuple(puniq),
            np.concatenate([cols.camera_codes, ccodes]), tuple(cuniq))


@dataclasses.dataclass(frozen=True)
class FlashCrowd:
    """An event (match, incident) multiplies demand on selected cameras for a
    window. The spike is capped at ``cap_fps`` *and* at each stream's own
    program feasibility ceiling (the rate a 90%-capped GPU sustains —
    ~14 fps for ZF but only ~2.8 for VGG16), so a boosted stream can always
    still be planned somewhere."""

    inner: DemandModel
    start_h: float
    duration_h: float
    multiplier: float
    cameras: Optional[frozenset[str]] = None      # geo camera ids; None = all
    cap_fps: float = 12.0

    def streams_at(self, t_h: float) -> list[Stream]:
        out = self.inner.streams_at(t_h)
        if not (self.start_h <= t_h < self.start_h + self.duration_h):
            return out
        boosted = []
        for s in out:
            if self.cameras is None or s.camera in self.cameras:
                cap = min(self.cap_fps, s.program.max_gpu_fps())
                f = min(s.fps * self.multiplier, cap)
                # truncate (never round up) so the cap stays a hard ceiling
                s = dataclasses.replace(s, fps=math.floor(f * 1000) / 1000)
            boosted.append(s)
        return boosted

    def columns_at(self, t_h: float) -> StreamColumns:
        cols = self.inner.columns_at(t_h)
        if not (self.start_h <= t_h < self.start_h + self.duration_h):
            return cols
        caps = np.array([min(self.cap_fps, p.max_gpu_fps())
                         for p in cols.programs_unique])
        cap = caps[cols.program_codes]
        if self.cameras is None:
            mask = np.ones(len(cols), dtype=bool)
        else:
            sel = np.array([c in self.cameras for c in cols.cameras_unique],
                           dtype=bool)
            mask = (cols.camera_codes >= 0) \
                & sel[np.maximum(cols.camera_codes, 0)]
        f = np.minimum(cols.fps * self.multiplier, cap)
        fps = np.where(mask, np.floor(f * 1000) / 1000, cols.fps)
        return StreamColumns(cols.ids, fps,
                             cols.program_codes, cols.programs_unique,
                             cols.camera_codes, cols.cameras_unique)


@dataclasses.dataclass(frozen=True)
class MixShift:
    """Program-mix shift: a deterministic fraction of cameras switches to a
    different (cheaper, e.g. VGG16 at low rates) analysis program during
    local night hours — monitoring instead of live detection."""

    inner: DemandModel
    night_program: str = "VGG16"
    fraction: float = 0.3
    night_start_h: float = 22.0
    night_end_h: float = 6.0

    def _selected(self, stream_id: str) -> bool:
        # pure function of the id — memoized so a 10k-stream fleet does not
        # re-hash every stream every tick
        memo = getattr(self, "_memo", None)
        if memo is None:
            memo = {}
            object.__setattr__(self, "_memo", memo)
        sel = memo.get(stream_id)
        if sel is None:
            sel = (zlib.crc32(stream_id.encode()) % 1000) < self.fraction * 1000
            memo[stream_id] = sel
        return sel

    def _selected_mask(self, ids) -> np.ndarray:
        """Per-stream selection as a bool vector, cached per id-list object
        (stable fleets reuse their id list every tick)."""
        cached = getattr(self, "_selmask", None)
        if cached is not None and cached[0] is ids:
            return cached[1]
        mask = np.fromiter((self._selected(sid) for sid in ids),
                           dtype=bool, count=len(ids))
        object.__setattr__(self, "_selmask", (ids, mask))
        return mask

    def streams_at(self, t_h: float) -> list[Stream]:
        # the night test depends only on the camera, not the stream — decide
        # once per distinct camera per tick instead of per stream
        night_of: dict[str, bool] = {}
        prog = PROGRAMS[self.night_program]
        out = []
        for s in self.inner.streams_at(t_h):
            if s.camera is not None:
                night = night_of.get(s.camera)
                if night is None:
                    lh = geo.local_hour(t_h, s.camera)
                    night = lh >= self.night_start_h or lh < self.night_end_h
                    night_of[s.camera] = night
                if night and self._selected(s.stream_id):
                    s = dataclasses.replace(s, program=prog)
            out.append(s)
        return out

    def columns_at(self, t_h: float) -> StreamColumns:
        cols = self.inner.columns_at(t_h)
        if not len(cols):
            return cols
        offs = np.array([geo.utc_offset_hours(c)
                         for c in cols.cameras_unique]) \
            if cols.cameras_unique else np.empty(0)
        local = np.mod(t_h + offs, 24.0)
        night_uniq = (local >= self.night_start_h) | (local < self.night_end_h)
        night = (cols.camera_codes >= 0) \
            & night_uniq[np.maximum(cols.camera_codes, 0)] \
            if offs.size else np.zeros(len(cols), dtype=bool)
        shift = night & self._selected_mask(cols.ids)
        if not shift.any():
            return cols
        prog = PROGRAMS[self.night_program]
        puniq = cols.programs_unique
        try:
            code = next(n for n, p in enumerate(puniq) if p is prog)
        except StopIteration:
            code = len(puniq)
            puniq = puniq + (prog,)
        pcodes = np.where(shift, code, cols.program_codes)
        return StreamColumns(cols.ids, cols.fps, pcodes, puniq,
                             cols.camera_codes, cols.cameras_unique)


def peak_streams(demand: DemandModel, horizon_h: float,
                 step_h: float = 0.5) -> list[Stream]:
    """Scan ``horizon_h`` simulated hours (every ``step_h``) and return each
    stream at its maximum demanded rate in frames/s — what a static
    peak-provisioned deployment must plan (and pay $/hour) for."""
    best: dict[str, Stream] = {}
    t = 0.0
    while t < horizon_h:
        for s in demand.streams_at(t):
            cur = best.get(s.stream_id)
            if cur is None or s.fps > cur.fps:
                best[s.stream_id] = s
        t += step_h
    return [best[k] for k in sorted(best)]
