"""Composable demand generators: the fleet's frame-rate needs over time.

A demand model maps simulated UTC hours to the set of demanded
:class:`~repro.core.workload.Stream` objects. The base generator gives every
camera a diurnal rush-hour curve in its *local* (solar) time via
``core.geo.local_hour``, so a worldwide fleet ramps region by region as the
sun moves. Wrappers compose on top: Poisson camera churn (arrivals with
exponential lifetimes), flash-crowd events (a region's rates spike for a
window), and day/night program-mix shifts. Everything is a pure, seeded
function of time — two scans of the same model are identical.
"""
from __future__ import annotations

import dataclasses
import math
import zlib
from typing import Optional, Protocol, Sequence

import numpy as np

from repro.core import geo
from repro.core.workload import PROGRAMS, Stream


class DemandModel(Protocol):
    def streams_at(self, t_h: float) -> list[Stream]: ...


@dataclasses.dataclass(frozen=True)
class CameraSpec:
    """One camera's demand profile: a diurnal curve between ``base_fps`` and
    ``peak_fps`` (both in frames/s, reached at local rush hours)."""

    stream_id: str
    camera: str                  # key in geo.CAMERAS
    program: str                 # key in workload.PROGRAMS
    base_fps: float              # frames/s off-peak
    peak_fps: float              # frames/s at the rush-hour crest


def rush_hour_fps(local_h: float, base: float, peak: float,
                  width_h: float = 1.5) -> float:
    """Demanded frame rate (frames/s) at local hour ``local_h``: morning
    (8:30) and evening (17:30) rush hours as Gaussian bumps of width
    ``width_h`` hours over a quiet base rate (paper Fig. 5's shape)."""
    bump = (math.exp(-((local_h - 8.5) / width_h) ** 2)
            + math.exp(-((local_h - 17.5) / width_h) ** 2))
    return base + (peak - base) * min(1.0, bump)


@dataclasses.dataclass(frozen=True)
class DiurnalFleet:
    """Each camera follows the rush-hour curve in its own local time.

    Demand is evaluated *batched*: one numpy pass computes every camera's
    local hour and rush-hour frame rate (frames/s) per tick, instead of a
    Python call per camera — the per-stream loop only constructs the
    ``Stream`` objects. ``repro.core.packed.scalar_mode()`` switches back to
    the original per-camera evaluation (the parity baseline); both paths
    produce identical streams bit for bit (see tests/test_packed_parity.py).
    """

    cameras: tuple[CameraSpec, ...]
    width_h: float = 1.5

    def _arrays(self):
        """Cached per-camera columns: (utc offsets h, base fps, peak fps,
        program objects, stream ids, camera ids)."""
        cached = getattr(self, "_cols", None)
        if cached is None:
            cached = (
                np.array([geo.utc_offset_hours(c.camera)
                          for c in self.cameras]),
                np.array([c.base_fps for c in self.cameras]),
                np.array([c.peak_fps for c in self.cameras]),
                [PROGRAMS[c.program] for c in self.cameras],
                [c.stream_id for c in self.cameras],
                [c.camera for c in self.cameras],
            )
            object.__setattr__(self, "_cols", cached)
        return cached

    def fps_at(self, t_h: float) -> np.ndarray:
        """All cameras' demanded frame rates (frames/s) at UTC hour ``t_h``
        as one vector — the batched form of :func:`rush_hour_fps`."""
        offs, base, peak, _, _, _ = self._arrays()
        local_h = np.mod(t_h + offs, 24.0)
        bump = (np.exp(-((local_h - 8.5) / self.width_h) ** 2)
                + np.exp(-((local_h - 17.5) / self.width_h) ** 2))
        return base + (peak - base) * np.minimum(1.0, bump)

    def streams_at(self, t_h: float) -> list[Stream]:
        from repro.core import packed
        if not packed.enabled():
            out = []
            for c in self.cameras:
                fps = rush_hour_fps(geo.local_hour(t_h, c.camera),
                                    c.base_fps, c.peak_fps, self.width_h)
                out.append(Stream(c.stream_id, PROGRAMS[c.program],
                                  fps=round(fps, 3), camera=c.camera))
            return out
        _, _, _, programs, ids, cams = self._arrays()
        # np.round is verified bit-identical to the scalar round(., 3) on
        # this curve family (tests/test_packed_parity.py covers it end to
        # end); tolist() converts to Python floats in one pass
        fps = np.round(self.fps_at(t_h), 3).tolist()
        # reuse the frozen Stream while a camera's rounded rate is unchanged
        # (diurnal curves plateau at base and peak) — identical objects, no
        # per-tick reallocation for the stable part of the fleet
        cache = getattr(self, "_stream_cache", None)
        if cache is None:
            cache = [None] * len(self.cameras)
            object.__setattr__(self, "_stream_cache", cache)
        out = []
        for n, (sid, prog, fr, cam) in enumerate(zip(ids, programs, fps, cams)):
            s = cache[n]
            if s is None or s.fps != fr:
                s = Stream(sid, prog, fps=fr, camera=cam)
                cache[n] = s
            out.append(s)
        return out


@dataclasses.dataclass(frozen=True)
class PoissonChurn:
    """Cameras come and go: Poisson arrivals (``rate_per_h`` per simulated
    hour) over the horizon, each living an exponential lifetime of mean
    ``mean_lifetime_h`` hours, cycling through a pool of camera templates.
    The whole arrival schedule is drawn once at construction from the seed."""

    inner: DemandModel
    templates: tuple[CameraSpec, ...]
    rate_per_h: float = 0.5
    mean_lifetime_h: float = 6.0
    horizon_h: float = 24.0
    seed: int = 0
    _schedule: tuple[tuple[float, float, CameraSpec], ...] = ()

    def __post_init__(self) -> None:
        rng = np.random.default_rng(self.seed)
        n = int(rng.poisson(self.rate_per_h * self.horizon_h))
        arrivals = np.sort(rng.uniform(0.0, self.horizon_h, n))
        lifetimes = rng.exponential(self.mean_lifetime_h, n)
        sched = []
        for k, (a, life) in enumerate(zip(arrivals, lifetimes)):
            tpl = self.templates[k % len(self.templates)]
            spec = dataclasses.replace(tpl, stream_id=f"{tpl.stream_id}-churn{k}")
            sched.append((float(a), float(a + life), spec))
        object.__setattr__(self, "_schedule", tuple(sched))

    def streams_at(self, t_h: float) -> list[Stream]:
        out = self.inner.streams_at(t_h)
        for start, end, c in self._schedule:
            if start <= t_h < end:
                fps = rush_hour_fps(geo.local_hour(t_h, c.camera),
                                    c.base_fps, c.peak_fps)
                out.append(Stream(c.stream_id, PROGRAMS[c.program],
                                  fps=round(fps, 3), camera=c.camera))
        return out


@dataclasses.dataclass(frozen=True)
class FlashCrowd:
    """An event (match, incident) multiplies demand on selected cameras for a
    window. The spike is capped at ``cap_fps`` *and* at each stream's own
    program feasibility ceiling (the rate a 90%-capped GPU sustains —
    ~14 fps for ZF but only ~2.8 for VGG16), so a boosted stream can always
    still be planned somewhere."""

    inner: DemandModel
    start_h: float
    duration_h: float
    multiplier: float
    cameras: Optional[frozenset[str]] = None      # geo camera ids; None = all
    cap_fps: float = 12.0

    def streams_at(self, t_h: float) -> list[Stream]:
        out = self.inner.streams_at(t_h)
        if not (self.start_h <= t_h < self.start_h + self.duration_h):
            return out
        boosted = []
        for s in out:
            if self.cameras is None or s.camera in self.cameras:
                cap = min(self.cap_fps, s.program.max_gpu_fps())
                f = min(s.fps * self.multiplier, cap)
                # truncate (never round up) so the cap stays a hard ceiling
                s = dataclasses.replace(s, fps=math.floor(f * 1000) / 1000)
            boosted.append(s)
        return boosted


@dataclasses.dataclass(frozen=True)
class MixShift:
    """Program-mix shift: a deterministic fraction of cameras switches to a
    different (cheaper, e.g. VGG16 at low rates) analysis program during
    local night hours — monitoring instead of live detection."""

    inner: DemandModel
    night_program: str = "VGG16"
    fraction: float = 0.3
    night_start_h: float = 22.0
    night_end_h: float = 6.0

    def _selected(self, stream_id: str) -> bool:
        # pure function of the id — memoized so a 10k-stream fleet does not
        # re-hash every stream every tick
        memo = getattr(self, "_memo", None)
        if memo is None:
            memo = {}
            object.__setattr__(self, "_memo", memo)
        sel = memo.get(stream_id)
        if sel is None:
            sel = (zlib.crc32(stream_id.encode()) % 1000) < self.fraction * 1000
            memo[stream_id] = sel
        return sel

    def streams_at(self, t_h: float) -> list[Stream]:
        # the night test depends only on the camera, not the stream — decide
        # once per distinct camera per tick instead of per stream
        night_of: dict[str, bool] = {}
        prog = PROGRAMS[self.night_program]
        out = []
        for s in self.inner.streams_at(t_h):
            if s.camera is not None:
                night = night_of.get(s.camera)
                if night is None:
                    lh = geo.local_hour(t_h, s.camera)
                    night = lh >= self.night_start_h or lh < self.night_end_h
                    night_of[s.camera] = night
                if night and self._selected(s.stream_id):
                    s = dataclasses.replace(s, program=prog)
            out.append(s)
        return out


def peak_streams(demand: DemandModel, horizon_h: float,
                 step_h: float = 0.5) -> list[Stream]:
    """Scan ``horizon_h`` simulated hours (every ``step_h``) and return each
    stream at its maximum demanded rate in frames/s — what a static
    peak-provisioned deployment must plan (and pay $/hour) for."""
    best: dict[str, Stream] = {}
    t = 0.0
    while t < horizon_h:
        for s in demand.streams_at(t):
            cur = best.get(s.stream_id)
            if cur is None or s.fps > cur.fps:
                best[s.stream_id] = s
        t += step_h
    return [best[k] for k in sorted(best)]
