"""Composable demand generators: the fleet's frame-rate needs over time.

A demand model maps simulated UTC hours to the set of demanded
:class:`~repro.core.workload.Stream` objects. The base generator gives every
camera a diurnal rush-hour curve in its *local* (solar) time via
``core.geo.local_hour``, so a worldwide fleet ramps region by region as the
sun moves. Wrappers compose on top: Poisson camera churn (arrivals with
exponential lifetimes), flash-crowd events (a region's rates spike for a
window), and day/night program-mix shifts. Everything is a pure, seeded
function of time — two scans of the same model are identical.
"""
from __future__ import annotations

import dataclasses
import math
import zlib
from typing import Optional, Protocol, Sequence

import numpy as np

from repro.core import geo
from repro.core.workload import PROGRAMS, Stream


class DemandModel(Protocol):
    def streams_at(self, t_h: float) -> list[Stream]: ...


@dataclasses.dataclass(frozen=True)
class CameraSpec:
    """One camera's demand profile: a diurnal curve between base and peak."""

    stream_id: str
    camera: str                  # key in geo.CAMERAS
    program: str                 # key in workload.PROGRAMS
    base_fps: float
    peak_fps: float


def rush_hour_fps(local_h: float, base: float, peak: float,
                  width_h: float = 1.5) -> float:
    """Double-peaked diurnal curve: morning (8:30) and evening (17:30) rush
    hours as Gaussian bumps over a quiet base rate (paper Fig. 5's shape)."""
    bump = (math.exp(-((local_h - 8.5) / width_h) ** 2)
            + math.exp(-((local_h - 17.5) / width_h) ** 2))
    return base + (peak - base) * min(1.0, bump)


@dataclasses.dataclass(frozen=True)
class DiurnalFleet:
    """Each camera follows the rush-hour curve in its own local time."""

    cameras: tuple[CameraSpec, ...]
    width_h: float = 1.5

    def streams_at(self, t_h: float) -> list[Stream]:
        out = []
        for c in self.cameras:
            fps = rush_hour_fps(geo.local_hour(t_h, c.camera),
                                c.base_fps, c.peak_fps, self.width_h)
            out.append(Stream(c.stream_id, PROGRAMS[c.program],
                              fps=round(fps, 3), camera=c.camera))
        return out


@dataclasses.dataclass(frozen=True)
class PoissonChurn:
    """Cameras come and go: Poisson arrivals over the horizon, each living an
    exponential lifetime, cycling through a pool of camera templates. The
    whole arrival schedule is drawn once at construction from the seed."""

    inner: DemandModel
    templates: tuple[CameraSpec, ...]
    rate_per_h: float = 0.5
    mean_lifetime_h: float = 6.0
    horizon_h: float = 24.0
    seed: int = 0
    _schedule: tuple[tuple[float, float, CameraSpec], ...] = ()

    def __post_init__(self) -> None:
        rng = np.random.default_rng(self.seed)
        n = int(rng.poisson(self.rate_per_h * self.horizon_h))
        arrivals = np.sort(rng.uniform(0.0, self.horizon_h, n))
        lifetimes = rng.exponential(self.mean_lifetime_h, n)
        sched = []
        for k, (a, life) in enumerate(zip(arrivals, lifetimes)):
            tpl = self.templates[k % len(self.templates)]
            spec = dataclasses.replace(tpl, stream_id=f"{tpl.stream_id}-churn{k}")
            sched.append((float(a), float(a + life), spec))
        object.__setattr__(self, "_schedule", tuple(sched))

    def streams_at(self, t_h: float) -> list[Stream]:
        out = self.inner.streams_at(t_h)
        for start, end, c in self._schedule:
            if start <= t_h < end:
                fps = rush_hour_fps(geo.local_hour(t_h, c.camera),
                                    c.base_fps, c.peak_fps)
                out.append(Stream(c.stream_id, PROGRAMS[c.program],
                                  fps=round(fps, 3), camera=c.camera))
        return out


@dataclasses.dataclass(frozen=True)
class FlashCrowd:
    """An event (match, incident) multiplies demand on selected cameras for a
    window. The spike is capped at ``cap_fps`` *and* at each stream's own
    program feasibility ceiling (the rate a 90%-capped GPU sustains —
    ~14 fps for ZF but only ~2.8 for VGG16), so a boosted stream can always
    still be planned somewhere."""

    inner: DemandModel
    start_h: float
    duration_h: float
    multiplier: float
    cameras: Optional[frozenset[str]] = None      # geo camera ids; None = all
    cap_fps: float = 12.0

    def streams_at(self, t_h: float) -> list[Stream]:
        out = self.inner.streams_at(t_h)
        if not (self.start_h <= t_h < self.start_h + self.duration_h):
            return out
        boosted = []
        for s in out:
            if self.cameras is None or s.camera in self.cameras:
                cap = min(self.cap_fps, s.program.max_gpu_fps())
                f = min(s.fps * self.multiplier, cap)
                # truncate (never round up) so the cap stays a hard ceiling
                s = dataclasses.replace(s, fps=math.floor(f * 1000) / 1000)
            boosted.append(s)
        return boosted


@dataclasses.dataclass(frozen=True)
class MixShift:
    """Program-mix shift: a deterministic fraction of cameras switches to a
    different (cheaper, e.g. VGG16 at low rates) analysis program during
    local night hours — monitoring instead of live detection."""

    inner: DemandModel
    night_program: str = "VGG16"
    fraction: float = 0.3
    night_start_h: float = 22.0
    night_end_h: float = 6.0

    def _selected(self, stream_id: str) -> bool:
        return (zlib.crc32(stream_id.encode()) % 1000) < self.fraction * 1000

    def streams_at(self, t_h: float) -> list[Stream]:
        out = []
        for s in self.inner.streams_at(t_h):
            if s.camera is not None and self._selected(s.stream_id):
                lh = geo.local_hour(t_h, s.camera)
                if lh >= self.night_start_h or lh < self.night_end_h:
                    s = dataclasses.replace(
                        s, program=PROGRAMS[self.night_program])
            out.append(s)
        return out


def peak_streams(demand: DemandModel, horizon_h: float,
                 step_h: float = 0.5) -> list[Stream]:
    """Scan the horizon and return every stream at its maximum demanded rate
    — what a static peak-provisioned deployment must plan for."""
    best: dict[str, Stream] = {}
    t = 0.0
    while t < horizon_h:
        for s in demand.streams_at(t):
            cur = best.get(s.stream_id)
            if cur is None or s.fps > cur.fps:
                best[s.stream_id] = s
        t += step_h
    return [best[k] for k in sorted(best)]
