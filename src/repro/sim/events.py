"""Discrete-event core of the fleet simulator.

A single priority queue orders everything that happens in simulated time:
control-loop ticks, spot preemptions (scheduled mid-interval by the market),
and the end of the horizon. Instance boots and price-walk updates are not
queue events — boots are modeled by each instance's ``ready_t`` window and
prices advance once per tick. Events at equal times break ties by insertion
sequence, which — together with seeded RNGs everywhere else — makes whole
simulations bit-for-bit deterministic (the acceptance criterion for the
ledger).
"""
from __future__ import annotations

import dataclasses
import heapq
from typing import Any, Optional

# Event kinds
TICK = "tick"                  # control-loop boundary: demand + plan + account
PREEMPT = "preempt"            # the spot market reclaimed an instance
                               # (hazard draw on a legacy spot rental)
OUTBID = "outbid"              # the spot price rose above an instance's bid
                               # — the deterministic reclaim of bid-carrying
                               # rentals (see SpotMarket.outbid)
END = "end"                    # end of simulation horizon


@dataclasses.dataclass(order=True, frozen=True)
class Event:
    """One simulation event at ``time`` (simulated hours since the start);
    ``seq`` is the insertion tie-breaker, ``kind`` one of TICK / PREEMPT /
    END, ``payload`` the instance id for preemptions."""

    time: float                   # simulated hours
    seq: int
    kind: str = dataclasses.field(compare=False)
    payload: Any = dataclasses.field(compare=False, default=None)


class EventQueue:
    """Min-heap of :class:`Event` ordered by (time, insertion sequence)."""

    def __init__(self) -> None:
        self._heap: list[Event] = []
        self._seq = 0

    def push(self, time: float, kind: str, payload: Any = None) -> Event:
        ev = Event(time=time, seq=self._seq, kind=kind, payload=payload)
        self._seq += 1
        heapq.heappush(self._heap, ev)
        return ev

    def pop(self) -> Event:
        return heapq.heappop(self._heap)

    def peek(self) -> Optional[Event]:
        return self._heap[0] if self._heap else None

    def __len__(self) -> int:
        return len(self._heap)

    def __bool__(self) -> bool:
        return bool(self._heap)
