"""Model-predictive autoscaling over seasonal forecasts (BEYOND-PAPER).

:class:`MPCPolicy` supersedes the reactive/trend policies: every tick it
rolls a :class:`~repro.sim.forecast.SeasonalForecaster` ahead of the boot
window and plans for the *envelope* — the elementwise max of current
demand and the forecast over the next ``lead_h`` hours — so capacity for a
ramp is already serving when the ramp lands, instead of dropping frames
for a boot-delay's worth of demand first.

The knobs the paper's operator would tune by hand are co-optimized from
the forecast itself, on a slow cadence (``reoptimize_every_h``):

* **boot lead** — for each candidate lead the policy simulates the next
  ``horizon_h`` hours of envelope plans (priced by the *existing*
  ``manager.plan``/packed machinery on forecast columns — no new solver),
  scores forecast dollars against a boot-window drop proxy, and keeps the
  cheapest lead meeting the SLO floor;
* **replan cadence** — from the same plan-cost series, holding capacity
  at the running window max and charging a fixed disruption cost per
  voluntary replan;
* **bid level** (spot mode) — the :class:`~repro.sim.bidding.LookaheadBid`
  ``slo_weight`` whose bids minimize true expected effective price.

Pre-booted capacity must survive the dip in front of the peak it was
bought for: while any stream is planned above current demand the policy
sets ``AdaptiveManager.hold_until = t + lead_h``, which suppresses
voluntary cost-saving adoption (forced replans and mixed zero-migration
repricing still pass). When forecast coverage is below ``warm_coverage``
the envelope degenerates to current demand — the reactive path — so a
cold-started MPC behaves exactly like the baseline it supersedes.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional, Sequence

import numpy as np

from repro.core.adaptive import AdaptiveManager
from repro.core.manager import ResourceManager
from repro.core.markets import SPOT, MixedConfig, quotes
from repro.core.strategies import Plan
from repro.core.workload import Stream
from repro.sim.bidding import LookaheadBid, compute_bids
from repro.sim.demand import StreamColumns
from repro.sim.forecast import SeasonalForecaster


@dataclasses.dataclass(frozen=True)
class MPCConfig:
    """Knobs of the model-predictive loop (hours and dollars)."""

    horizon_h: float = 4.0            # lookahead the co-optimizer scores over
    lead_candidates: tuple = (0.0, 1.0, 2.0)      # boot leads considered
    cadence_candidates: tuple = (1.0, 3.0, 6.0)   # voluntary-replan periods
    slo_floor: float = 0.97           # forecast SLO a lead must clear
    reoptimize_every_h: float = 6.0   # how often lead/cadence/bids re-pick
    replan_cost_usd: float = 2.0      # disruption proxy per voluntary replan
    warm_coverage: float = 0.5        # min forecast coverage to leave the
                                      # reactive path
    savings_threshold: float = 0.02   # adoption hysteresis (tight: cadence
                                      # already rate-limits replans)
    cap_fps: float = 12.0             # envelope rate ceiling per stream


class MPCPolicy:
    """Forecast-driven autoscaling that plans for the demand envelope.

    Drop-in fleet-simulator policy (``decide``/``adaptive``/``bids``): in
    on-demand mode it wraps a plain :class:`AdaptiveManager`; with
    ``spot=True`` it plans mixed-market (on-demand floor + spot burst) and
    recomputes per-region bids every decision like ``SpotBidPolicy``,
    using the slow-cadence-selected ``slo_weight``.
    """

    def __init__(self, manager: ResourceManager,
                 forecaster: Optional[SeasonalForecaster] = None,
                 config: Optional[MPCConfig] = None,
                 strategy: str = "FFD", spot: bool = False,
                 floor_frac: float = 0.5,
                 bidding: Optional[LookaheadBid] = None,
                 slo_weight_candidates: Sequence[float] = (0.5, 1.0, 2.0),
                 name: str = "mpc") -> None:
        self.name = name
        self.manager = manager
        self.config = config or MPCConfig()
        self.forecaster = forecaster or SeasonalForecaster()
        self.strategy = strategy
        self.spot = spot
        self.bidding = bidding or LookaheadBid()
        self.slo_weight_candidates = tuple(slo_weight_candidates)
        # None (not {}) outside spot mode: a non-None bids attribute flips
        # the cluster into market-aware reconciliation (bids gate spot
        # booking), which a pure on-demand/spot_fraction policy must not do
        self.bids: Optional[dict[tuple[str, str], float]] = {} if spot \
            else None
        self._market = None
        self._dt_h = 1.0
        self._boot_delay_h = 0.05
        self.adaptive = AdaptiveManager(
            manager, strategy=strategy,
            savings_threshold=self.config.savings_threshold,
            replan_trigger=self._cadence_trigger,
            mixed=MixedConfig(floor_frac=floor_frac) if spot else None,
            multipliers_fn=self._multipliers)
        # co-optimized each reoptimize_every_h from the forecast
        self.lead_h = max(self.config.lead_candidates)
        self.cadence_h = min(self.config.cadence_candidates)
        self._last_reopt: Optional[float] = None
        self._last_voluntary: Optional[float] = None
        self._last_t: Optional[float] = None
        # ledger plumbing (FleetSimulator._policy_interval_stats)
        self.last_preboot = 0
        self.last_forecast_error = 0.0
        self._pending: Optional[tuple[float, float]] = None

    # -- simulator plumbing --------------------------------------------------

    def attach_market(self, market, dt_h: float = 1.0,
                      boot_delay_h: Optional[float] = None) -> None:
        """Called by the fleet simulator: price walk (spot mode), control
        period (forecast sampling step), and the boot window the lead must
        cover and the drop proxy prices."""
        self._market = market
        self._dt_h = dt_h
        if boot_delay_h is not None:
            self._boot_delay_h = boot_delay_h
            if hasattr(self.bidding, "boot_delay_h"):
                self.bidding.boot_delay_h = boot_delay_h

    def attach_telemetry(self, hub) -> None:
        """Feed live fleet telemetry into the forecaster (live-scale
        correction) — typically the same hub the fleet simulator emits to."""
        self.forecaster.attach_hub(hub)

    def _multipliers(self) -> dict:
        return self._market.multipliers() if self._market is not None else {}

    def _cadence_trigger(self, t, streams, plan) -> bool:
        if self._last_voluntary is None \
                or t - self._last_voluntary >= self.cadence_h - 1e-9:
            self._last_voluntary = t
            return True
        return False

    def _reset_run(self) -> None:
        # same contract as ScheduledPolicy: a reused policy's second run is
        # bit-identical to a fresh one's. The *forecaster* persists — its
        # fitted curves are the learned model, not per-run state.
        self.adaptive.current = None
        self.adaptive.events = []
        self.adaptive.hold_until = float("-inf")
        self._last_reopt = None
        self._last_voluntary = None
        self._pending = None
        self.last_preboot = 0
        self.last_forecast_error = 0.0
        self.bids = {} if self.spot else None

    # -- envelope ------------------------------------------------------------

    def _fps_of(self, streams) -> np.ndarray:
        if isinstance(streams, StreamColumns):
            return streams.fps
        return np.array([s.fps for s in streams])

    def _caps(self, streams) -> np.ndarray:
        """Per-stream envelope ceiling: config cap ∧ the program's GPU
        feasibility ceiling (the FlashCrowd clamp — a forecast must never
        ask the packer for an infeasible rate)."""
        cap = self.config.cap_fps
        if isinstance(streams, StreamColumns):
            per_prog = np.array([min(cap, p.max_gpu_fps())
                                 for p in streams.programs_unique])
            return per_prog[streams.program_codes]
        return np.array([min(cap, s.program.max_gpu_fps())
                         for s in streams])

    def _envelope(self, t: float, streams, cur_fps: np.ndarray,
                  lead_h: float) -> tuple[np.ndarray, int]:
        """(envelope rates, #streams planned above current demand).

        Elementwise max of current demand and the forecast sampled over
        ``(t, t + lead_h]`` at the control period, capped at the
        feasibility ceiling and floored at current demand — the envelope
        never plans *below* what is demanded right now.
        """
        env = cur_fps.astype(float).copy()
        if lead_h > 1e-9 and len(env) > 0:
            dt = max(self._dt_h, 1e-6)
            n = max(1, int(math.ceil(lead_h / dt - 1e-9)))
            taus = [t + k * dt for k in range(1, n + 1)]
            if taus[-1] < t + lead_h - 1e-9:
                taus.append(t + lead_h)
            warm = True
            for tau in taus:
                f, known = self.forecaster.forecast_fps(tau, streams)
                if np.count_nonzero(known) \
                        < self.config.warm_coverage * len(known):
                    warm = False        # cold start: stay reactive
                    break
                env = np.maximum(env, np.where(known, f, cur_fps))
            if not warm:
                env = cur_fps.astype(float).copy()
        caps = self._caps(streams)
        env = np.minimum(env, np.maximum(caps, cur_fps))
        # milli-fps grid (the demand models' own granularity) above current
        # demand, exactly current demand elsewhere: forecast float jitter
        # neither perturbs feasibility checks nor fakes pre-boots
        env = np.where(env > cur_fps + 1e-9,
                       np.maximum(np.round(env, 3), cur_fps), cur_fps)
        n_pre = int(np.count_nonzero(env > cur_fps + 1e-9))
        return env, n_pre

    def _with_fps(self, streams, fps: np.ndarray):
        """The same fleet at different rates. Columnar input reuses the
        *same ids/codes objects*, so the packed-problem and feasibility
        fast paths (keyed on ids identity) stay hot."""
        if isinstance(streams, StreamColumns):
            return StreamColumns(streams.ids, fps, streams.program_codes,
                                 streams.programs_unique,
                                 streams.camera_codes, streams.cameras_unique)
        return [dataclasses.replace(s, fps=float(f)) if f != s.fps else s
                for s, f in zip(streams, fps.tolist())]

    # -- slow-cadence co-optimization ----------------------------------------

    def _plan_cost(self, streams, fps: np.ndarray) -> float:
        try:
            return self.manager.plan(self._with_fps(streams, fps),
                                     "FFD").hourly_cost
        except Exception:
            return float("inf")

    def _reoptimize(self, t: float, streams, cur_fps: np.ndarray) -> None:
        """Pick (lead_h, cadence_h[, slo_weight]) from the forecast.

        For each candidate lead, roll the envelope plans over the horizon:
        cost is forecast dollars; SLO is a boot-window proxy (demand that
        exceeds the previous step's envelope waits ``boot_delay_h`` for
        capacity). Cheapest lead meeting ``slo_floor`` wins; if none does,
        the max-SLO lead. Cadence re-scores the winner's cost series with
        window-max capacity holding plus a fixed cost per replan.
        """
        cfg = self.config
        dt = max(self._dt_h, 1e-6)
        k_n = max(1, int(math.ceil(cfg.horizon_h / dt - 1e-9)))
        taus = [t + k * dt for k in range(1, k_n + 1)]
        fc = [self.forecaster.forecast_fps(tau, streams) for tau in taus]
        if not fc or min(np.count_nonzero(kn) for _, kn in fc) \
                < cfg.warm_coverage * max(len(cur_fps), 1):
            return                      # cold forecast: keep current knobs
        caps = self._caps(streams)
        demand = [np.minimum(np.where(kn, f, cur_fps), caps) for f, kn in fc]
        sec = dt * 3600.0
        total_frames = sum(float(d.sum()) * sec for d in demand) or 1.0

        best = None                     # (cost, -slo, lead, cost_series)
        for lead in cfg.lead_candidates:
            prev_env, _ = self._envelope(t, streams, cur_fps, lead)
            dropped = 0.0
            costs = []
            for k, tau in enumerate(taus):
                env_k = prev_env
                for j in range(k, len(taus)):     # max over (tau, tau+lead]
                    if taus[j] > tau + lead + 1e-9:
                        break
                    env_k = np.maximum(env_k, demand[j]) if j > k \
                        else demand[j].copy()
                env_k = np.maximum(np.minimum(env_k, caps), demand[k])
                # demand beyond what the *previous* step planned boots late
                short = np.maximum(demand[k] - prev_env, 0.0)
                dropped += float(short.sum()) * self._boot_delay_h * 3600.0
                costs.append(self._plan_cost(streams, env_k))
                prev_env = env_k
            cost = sum(c * dt for c in costs)
            slo = 1.0 - dropped / total_frames
            key = (cost, -slo)
            if slo >= cfg.slo_floor:
                if best is None or best[3] is None or key < best[:2]:
                    best = (cost, -slo, lead, costs)
            elif best is None or best[3] is None and -slo < best[1]:
                best = (cost, -slo, lead, None)
        if best is None:
            return
        self.lead_h = best[2]

        if best[3] is not None:
            costs = best[3]
            best_c = None
            for cad in cfg.cadence_candidates:
                win = max(1, int(round(cad / dt)))
                held = 0.0
                for k in range(len(costs)):
                    w0 = (k // win) * win
                    held += max(costs[w0:k + 1]) * dt
                held += cfg.replan_cost_usd \
                    * math.ceil(len(costs) * dt / cad)
                if best_c is None or held < best_c[0]:
                    best_c = (held, cad)
            self.cadence_h = best_c[1]

        if self.spot and self._market is not None:
            self._pick_slo_weight()

    def _pick_slo_weight(self) -> None:
        """Choose the bid-aggressiveness whose bids minimize *true*
        expected effective price: candidate ``slo_weight`` shapes the bid,
        but every candidate is judged under the unweighted reclaim cost."""
        mults = self._market.multipliers()
        if not mults:
            return
        vol = getattr(self._market, "volatility", 0.15)
        qs = [q for q in quotes(self.manager.catalog, mults, volatility=vol)
              if q.market == SPOT]
        if not qs:
            return
        history = {r: [h[r] for h in self._market.price_history if r in h]
                   for r in mults}
        true_pen = LookaheadBid(boot_delay_h=self._boot_delay_h,
                                slo_weight=1.0)
        saved = self.bidding.slo_weight
        best = None
        for w in self.slo_weight_candidates:
            self.bidding.slo_weight = w
            score = 0.0
            for q in qs:
                b = self.bidding.bid(q, history.get(q.location, ()),
                                     self._dt_h)
                score += q.effective_price(
                    b, 1.0, preempt_penalty=true_pen.reclaim_cost(q))
            if best is None or score < best[0] - 1e-12:
                best = (score, w)
        self.bidding.slo_weight = best[1] if best else saved

    # -- the policy interface ------------------------------------------------

    def decide(self, t: float, streams, *, preempted: bool = False) -> Plan:
        if self._last_t is not None and t < self._last_t - 1e-9:
            self._reset_run()
        self._last_t = t
        cur_fps = self._fps_of(streams)

        # score the forecast the previous tick's plan rode on
        self.last_forecast_error = 0.0
        if self._pending is not None:
            target_t, predicted = self._pending
            if abs(t - target_t) <= 1e-6:
                realized = float(cur_fps.sum())
                self.last_forecast_error = (abs(predicted - realized)
                                            / max(realized, 1e-9))
            if t >= target_t - 1e-6:
                self._pending = None

        self.forecaster.observe(t, streams)

        if self._last_reopt is None \
                or t - self._last_reopt >= self.config.reoptimize_every_h \
                - 1e-9:
            self._last_reopt = t
            self._reoptimize(t, streams, cur_fps)

        if self.spot:
            self.bids = compute_bids(self.manager.catalog, self._market,
                                     self.bidding, self._dt_h)

        env, n_pre = self._envelope(t, streams, cur_fps, self.lead_h)
        self.last_preboot = n_pre
        self.adaptive.hold_until = (t + self.lead_h) if n_pre \
            else float("-inf")

        f_next, known = self.forecaster.forecast_fps(t + self._dt_h, streams)
        if len(known) and known.any():
            self._pending = (t + self._dt_h,
                             float(np.where(known, f_next, cur_fps).sum()))

        return self.adaptive.step(t, self._with_fps(streams, env),
                                  force=preempted)
