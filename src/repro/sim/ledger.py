"""Cost/SLO ledger and the serving-measurement calibration path.

The ledger is the simulator's single source of truth for outcomes: per-tick
dollars, frames demanded vs analyzed vs dropped (conservation holds exactly:
``demanded == analyzed + dropped`` every tick), migrations, preemptions, and
instance-hours by (location, type, market). ``totals()`` is a deterministic
summary — the acceptance test runs a scenario twice under one seed and
asserts the dicts are equal.

``ServiceCalibration`` closes the loop with the serving layer: a
``ContinuousBatchingEngine``'s ``measured_rates()`` (tokens/sec per stream)
divided by tokens-per-frame bounds how many frames a simulated stream can
actually have analyzed per tick, and the same rates feed
``tpu_catalog.streams_from_measured`` to build packing items — the paper's
profile-then-pack loop, replayed inside the simulator.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Mapping, Optional


@dataclasses.dataclass(frozen=True)
class ServiceCalibration:
    """Measured serving rates mapped onto the simulator's frame accounting."""

    tokens_per_frame: float = 8.0
    rates_tokens_per_s: Mapping[str, float] = dataclasses.field(
        default_factory=dict)
    default_rate: Optional[float] = None     # for streams never measured

    @classmethod
    def from_engine(cls, engine,
                    tokens_per_frame: float = 8.0) -> "ServiceCalibration":
        """Calibrate from a serving engine's ``measured_rates()`` export; the
        mean measured rate covers streams the engine never saw."""
        rates = dict(engine.measured_rates())
        default = (sum(rates.values()) / len(rates)) if rates else None
        return cls(tokens_per_frame=tokens_per_frame,
                   rates_tokens_per_s=rates, default_rate=default)

    def frame_rate_cap(self, stream_id: str) -> float:
        """Frames/sec the serving layer sustains for this stream (inf if
        uncalibrated)."""
        rate = self.rates_tokens_per_s.get(stream_id, self.default_rate)
        if rate is None:
            return math.inf
        return rate / self.tokens_per_frame

    def packing_streams(self, arch: str, *, kv_seq: int = 32_768):
        """The same measurements as TPU packing items (profile-then-pack)."""
        from repro.core.tpu_catalog import streams_from_measured
        return streams_from_measured(arch, dict(self.rates_tokens_per_s),
                                     kv_seq=kv_seq)


@dataclasses.dataclass(frozen=True)
class TickRecord:
    """One accounting interval of the simulation (the benchmark JSON
    artifacts serialize these; docs/simulator.md documents the schema).

    Frames are counts over the interval (frames/s x seconds); ``cost`` is
    dollars accrued over the interval; conservation holds exactly:
    ``frames_demanded == frames_analyzed + frames_dropped``.
    """

    t: float                      # interval start, simulated hours (UTC)
    cost: float                   # $ accrued this tick
    frames_demanded: float
    frames_analyzed: float
    frames_dropped: float
    migrations: int               # streams whose instance changed this tick
    preemptions: int              # spot reclaims that landed this tick
    instances_live: int           # live instances at the decision point
    streams: int                  # demanded streams at the decision point
    defrags: int = 0              # repair-mode full-replan escape hatches
    cost_ondemand: float = 0.0    # $ of `cost` billed at on-demand prices
    cost_spot: float = 0.0        # $ of `cost` billed at spot prices
    outbids: int = 0              # of `preemptions`: bids the price rose over
    calib_rel_error: float = 0.0  # mean |measured-calibrated|/calibrated rate
                                  # observed at this tick's decision (0 when
                                  # no drift detector is attached)
    recalibrations: int = 0       # drift-triggered re-profile + replans
    stage_items: int = 0          # of `streams`: pipeline *stage* items
                                  # (demand models with ``emits_stages``)
    pooled_items: int = 0         # of `stage_items`: consolidated pool chunks
                                  # serving many cameras' crops
    preboots: int = 0             # demand items planned above current demand
                                  # at this tick's decision: capacity booting
                                  # *ahead* of a forecast ramp (sim/mpc.py);
                                  # 0 for every non-predictive policy
    forecast_rel_error: float = 0.0   # |forecast - realized| / realized total
                                      # demand for the forecast this tick's
                                      # plan rode on (0 when no forecaster)


class Ledger:
    """Append-only account of everything the simulation spent and served."""

    def __init__(self) -> None:
        self.records: list[TickRecord] = []
        self.instance_hours: dict[tuple[str, str, str], float] = {}

    def add_tick(self, rec: TickRecord,
                 hours: Mapping[tuple[str, str, str], float]) -> None:
        if abs(rec.frames_demanded
               - (rec.frames_analyzed + rec.frames_dropped)) \
                > 1e-6 * max(1.0, rec.frames_demanded):
            raise ValueError(
                f"frame conservation violated at t={rec.t}: "
                f"{rec.frames_demanded} demanded != {rec.frames_analyzed} "
                f"analyzed + {rec.frames_dropped} dropped")
        self.records.append(rec)
        for k, h in hours.items():
            self.instance_hours[k] = self.instance_hours.get(k, 0.0) + h

    # -- aggregates ----------------------------------------------------------

    @property
    def total_cost(self) -> float:
        return sum(r.cost for r in self.records)

    @property
    def frames_demanded(self) -> float:
        return sum(r.frames_demanded for r in self.records)

    @property
    def frames_analyzed(self) -> float:
        return sum(r.frames_analyzed for r in self.records)

    @property
    def frames_dropped(self) -> float:
        return sum(r.frames_dropped for r in self.records)

    @property
    def migrations(self) -> int:
        return sum(r.migrations for r in self.records)

    @property
    def preemptions(self) -> int:
        return sum(r.preemptions for r in self.records)

    @property
    def defrags(self) -> int:
        return sum(r.defrags for r in self.records)

    @property
    def cost_ondemand(self) -> float:
        return sum(r.cost_ondemand for r in self.records)

    @property
    def cost_spot(self) -> float:
        return sum(r.cost_spot for r in self.records)

    @property
    def outbids(self) -> int:
        return sum(r.outbids for r in self.records)

    @property
    def recalibrations(self) -> int:
        return sum(r.recalibrations for r in self.records)

    @property
    def calib_max_rel_error(self) -> float:
        return max((r.calib_rel_error for r in self.records), default=0.0)

    @property
    def stage_items_peak(self) -> int:
        """Most pipeline stage items demanded at any one decision point."""
        return max((r.stage_items for r in self.records), default=0)

    @property
    def pooled_items_peak(self) -> int:
        """Most consolidated pool chunks live at any one decision point."""
        return max((r.pooled_items for r in self.records), default=0)

    @property
    def preboots(self) -> int:
        """Total demand items planned ahead of current demand (MPC)."""
        return sum(r.preboots for r in self.records)

    @property
    def forecast_max_rel_error(self) -> float:
        return max((r.forecast_rel_error for r in self.records), default=0.0)

    def slo_attainment(self) -> float:
        """Fraction of demanded frames actually analyzed on time.

        Zero-demand convention: with no frames demanded the attainment is
        vacuously ``1.0`` — nothing was asked for, so nothing was missed.
        This deliberately differs from the serving engine's ``report()``,
        whose ``slo_attainment`` is ``None`` on an empty *completion*
        sample: an idle engine has no evidence of health, but a ledger tick
        with zero demand has positive evidence that nothing was dropped.
        """
        d = self.frames_demanded
        return (self.frames_analyzed / d) if d > 0 else 1.0

    def signature(self) -> tuple:
        """Canonical comparable form: every tick record (exact floats) plus
        the rounded totals. Two simulation runs are bit-identical iff their
        signatures are equal — shared by the parity tests and the
        scale_sweep CI gate."""
        return (tuple(self.records), self.totals())

    def totals(self) -> dict:
        """Deterministic summary (rounded to stable precision) — equal across
        two runs of the same seeded scenario."""
        return {
            "ticks": len(self.records),
            "total_cost": round(self.total_cost, 6),
            "cost_ondemand": round(self.cost_ondemand, 6),
            "cost_spot": round(self.cost_spot, 6),
            "frames_demanded": round(self.frames_demanded, 6),
            "frames_analyzed": round(self.frames_analyzed, 6),
            "frames_dropped": round(self.frames_dropped, 6),
            "slo_attainment": round(self.slo_attainment(), 6),
            "migrations": self.migrations,
            "preemptions": self.preemptions,
            "outbids": self.outbids,
            "defrags": self.defrags,
            "recalibrations": self.recalibrations,
            "calib_max_rel_error": round(self.calib_max_rel_error, 6),
            "stage_items_peak": self.stage_items_peak,
            "pooled_items_peak": self.pooled_items_peak,
            "preboots": self.preboots,
            "forecast_max_rel_error": round(self.forecast_max_rel_error, 6),
            "instance_hours": {"/".join(k): round(v, 6)
                               for k, v in sorted(self.instance_hours.items())},
        }
