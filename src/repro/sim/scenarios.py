"""Scenario library: ready-to-run fleet days.

Each scenario bundles a demand model, a simulation config, and the catalog
to plan against. ``SCENARIOS`` maps names to zero-argument factories so
benchmarks and tests can run them by name; every factory takes optional
overrides (stream count, duration, seed) for scaling studies.

* ``steady``            — flat demand; sanity floor (adaptive ≈ static).
* ``rush_hour``         — US cameras, synchronized morning/evening peaks
                          (the paper's Fig. 5 shape at fleet scale).
* ``follow_the_sun``    — worldwide cameras, the same local curve: peaks
                          rotate around the globe; night cameras shift a
                          fraction of the fleet to a cheaper program.
* ``spot_heavy``        — rush hour with most capacity on the spot market:
                          cheap, but preemptions keep replaying streams.
* ``flash_crowd``       — steady fleet with Poisson camera churn and an
                          8x two-hour demand spike on European cameras.
* ``churn_storm``       — rush hour with Poisson camera churn *and* most
                          capacity on spot: every forced-replan source at
                          once (arrivals, departures, preemptions) — the
                          stress test for min-migration repair planning.
* ``drifting_scene``    — rush hour whose *serving capacity* regresses
                          mid-day (``service`` carries the ground truth, an
                          ``obs.DriftingService``): the drift-detection /
                          online-recalibration scenario.
* ``regional_drift``    — three-region fleet, the regression confined to
                          one region (``groups`` maps streams to regions):
                          the per-region drift / per-group recalibration
                          scenario.
* ``roi_day``           — content-aware pipelines: cameras capture at a
                          fixed rate, scene *density* swings sparse-night /
                          dense-rush, and downstream heavy stages activate
                          with it — the endogenous-demand scenario.
* ``consolidated_city`` — the consolidation gate: many co-located cameras
                          whose crop stages pool onto shared GPU workers
                          (``consolidate=True``); run with
                          ``consolidate=False`` for the unpooled arm.
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import Callable, Optional, Sequence

import numpy as np

from repro.core import geo
from repro.core.catalog import Catalog, fig6_catalog
from repro.core.workload import PROGRAMS
from repro.sim.demand import (CameraSpec, DemandModel, DiurnalFleet,
                              FlashCrowd, MixShift, PipelineCameraSpec,
                              PipelineFleet, PoissonChurn, columnar_fleet,
                              peak_streams)
from repro.sim.fleet import SimConfig

US_CAMERAS = ("nyc", "chicago", "la", "seattle")
EU_CAMERAS = ("london", "paris", "berlin")
ALL_CAMERAS = tuple(sorted(geo.CAMERAS))


@dataclasses.dataclass(frozen=True)
class Scenario:
    """A ready-to-run fleet day: demand model + sim config + catalog.

    Factories in :data:`SCENARIOS` build these by name with optional
    overrides (``n_streams``, ``duration_h`` in simulated hours, ``seed``);
    see docs/simulator.md for what each scenario stresses.
    """

    name: str
    demand: DemandModel
    config: SimConfig
    catalog_factory: Callable[[], Catalog] = fig6_catalog
    description: str = ""
    # ground-truth serving capacity (obs.DriftingService) for scenarios
    # whose service rates change over the day; None = unconstrained
    service: Optional[object] = None
    # stream_id -> group (region) for per-group drift detection
    # (obs.regional); None = no grouping defined
    groups: Optional[dict] = None

    def catalog(self) -> Catalog:
        return self.catalog_factory()

    def peak_streams(self, step_h: float = 0.5):
        """Peak demand over the horizon — the static baseline's plan input."""
        return peak_streams(self.demand, self.config.duration_h, step_h)


def _fleet(cameras: Sequence[str], n_streams: int, *, zf_peak: float = 6.0,
           zf_base: float = 0.2, vgg_every: int = 4) -> tuple[CameraSpec, ...]:
    """n_streams specs round-robined over cameras; every ``vgg_every``-th
    stream runs VGG16 at low rates (its CPU/GPU profiles top out ~2 fps),
    the rest run ZF with the full rush-hour swing."""
    specs = []
    cams = itertools.cycle(cameras)
    for i in range(n_streams):
        cam = next(cams)
        if vgg_every and i % vgg_every == vgg_every - 1:
            specs.append(CameraSpec(f"vgg-{cam}-{i}", cam, "VGG16",
                                    base_fps=0.1, peak_fps=1.5))
        else:
            specs.append(CameraSpec(f"zf-{cam}-{i}", cam, "ZF",
                                    base_fps=zf_base, peak_fps=zf_peak))
    return tuple(specs)


def steady(n_streams: int = 36, duration_h: float = 24.0,
           seed: int = 0) -> Scenario:
    specs = tuple(dataclasses.replace(c, peak_fps=c.base_fps)
                  for c in _fleet(ALL_CAMERAS, n_streams,
                                  zf_base=1.0, zf_peak=1.0))
    return Scenario(
        name="steady",
        demand=DiurnalFleet(specs),
        config=SimConfig(duration_h=duration_h, seed=seed),
        description="flat demand worldwide; adaptive should match static")


def rush_hour(n_streams: int = 108, duration_h: float = 24.0,
              seed: int = 0) -> Scenario:
    return Scenario(
        name="rush_hour",
        demand=DiurnalFleet(_fleet(US_CAMERAS, n_streams)),
        config=SimConfig(duration_h=duration_h, seed=seed),
        description="US fleet, synchronized diurnal peaks (paper Fig. 5)")


def follow_the_sun(n_streams: int = 108, duration_h: float = 24.0,
                   seed: int = 0) -> Scenario:
    demand = MixShift(DiurnalFleet(_fleet(ALL_CAMERAS, n_streams)),
                      night_program="VGG16", fraction=0.3)
    return Scenario(
        name="follow_the_sun",
        demand=demand,
        config=SimConfig(duration_h=duration_h, seed=seed),
        description="worldwide fleet; peaks rotate with local rush hours, "
                    "night cameras shift program mix")


def spot_heavy(n_streams: int = 108, duration_h: float = 24.0,
               seed: int = 0) -> Scenario:
    return Scenario(
        name="spot_heavy",
        demand=DiurnalFleet(_fleet(US_CAMERAS, n_streams)),
        config=SimConfig(duration_h=duration_h, seed=seed,
                         spot_fraction=0.85, preempt_hazard_per_h=0.12),
        description="rush hour mostly on spot: cheaper instance-hours, "
                    "preemptions replayed through replanning")


def flash_crowd(n_streams: int = 36, duration_h: float = 24.0,
                seed: int = 0) -> Scenario:
    base = DiurnalFleet(tuple(
        dataclasses.replace(c, peak_fps=max(c.base_fps, c.peak_fps / 3))
        for c in _fleet(ALL_CAMERAS, n_streams, zf_base=0.5)))
    churned = PoissonChurn(base, templates=_fleet(ALL_CAMERAS, 8,
                                                  zf_base=0.3, zf_peak=2.0),
                           rate_per_h=0.5, mean_lifetime_h=6.0,
                           horizon_h=duration_h, seed=seed + 7)
    demand = FlashCrowd(churned, start_h=12.0, duration_h=2.0,
                        multiplier=8.0, cameras=frozenset(EU_CAMERAS))
    return Scenario(
        name="flash_crowd",
        demand=demand,
        config=SimConfig(duration_h=duration_h, dt_h=0.5, seed=seed),
        description="camera churn plus an 8x two-hour European demand spike")


def churn_storm(n_streams: int = 72, duration_h: float = 24.0,
                seed: int = 0) -> Scenario:
    base = DiurnalFleet(_fleet(US_CAMERAS, n_streams, zf_peak=4.0))
    churned = PoissonChurn(base, templates=_fleet(US_CAMERAS, 12,
                                                  zf_base=0.3, zf_peak=2.0),
                           rate_per_h=1.0, mean_lifetime_h=4.0,
                           horizon_h=duration_h, seed=seed + 13)
    return Scenario(
        name="churn_storm",
        demand=churned,
        config=SimConfig(duration_h=duration_h, seed=seed,
                         spot_fraction=0.6, preempt_hazard_per_h=0.10),
        description="camera churn + spot preemptions: every forced-replan "
                    "source at once (min-migration stress test)")


def drifting_scene(n_streams: int = 72, duration_h: float = 24.0,
                   seed: int = 0, shift_at_h: float = 12.0,
                   shift_factor: float = 0.35) -> Scenario:
    """Rush-hour demand whose *serving* capacity regresses mid-day.

    The ground truth is an :class:`~repro.obs.DriftingService`: every stream
    starts comfortably above its demanded rate (ZF sustains 8 frames/s, VGG
    2.8 against demand peaks of 6 and 1.5), then at ``shift_at_h`` a
    fleet-wide regression multiplies the true rates by ``shift_factor`` —
    after it, a ZF stream can only sustain 2.8 frames/s against a 6 frames/s
    peak. A policy packing from the startup profile keeps paying for
    capacity the service can no longer use; online recalibration
    (``obs.RecalibratingPolicy``) detects the drift, re-profiles, and
    re-packs to the measured rates. ``benchmarks/drift_recalibration.py``
    gates detection latency and the resulting cost savings.
    """
    # lazy import: obs depends on sim.ledger, so importing it at module
    # scope would cycle through sim/__init__ -> scenarios -> obs -> sim
    from repro.obs import DriftingService, RateShift
    specs = _fleet(US_CAMERAS, n_streams)
    tokens_per_frame = 8.0
    base_rates = {c.stream_id: (22.4 if c.program == "VGG16" else 64.0)
                  for c in specs}
    service = DriftingService(base_rates,
                              tokens_per_frame=tokens_per_frame,
                              shifts=(RateShift(at_h=shift_at_h,
                                                factor=shift_factor),))
    return Scenario(
        name="drifting_scene",
        demand=DiurnalFleet(specs),
        config=SimConfig(duration_h=duration_h, seed=seed,
                         spot_fraction=0.0),
        description="rush-hour fleet whose true serving rates regress 65% "
                    "at mid-day: the drift-detection / online-recalibration "
                    "scenario",
        service=service)


def regional_drift(n_streams: int = 96, duration_h: float = 24.0,
                   seed: int = 0, shift_at_h: float = 12.0,
                   shift_factor: float = 0.2,
                   drifted_camera: str = "tokyo") -> Scenario:
    """Three-region fleet; the serving regression hits *one* region.

    Cameras round-robin over nyc / london / tokyo, which map to three
    distinct datacenter regions (us-east-1, eu-west-1, ap-northeast-1) —
    the scenario's ``groups`` field carries that stream → region map. At
    ``shift_at_h`` the true rates of the ``drifted_camera`` region's
    streams are multiplied by ``shift_factor``; the other two regions stay
    healthy. A per-region detector (``obs.RegionalDriftDetector``) should
    fire in exactly one region and a per-group recalibration re-profile
    only that third of the fleet; a fleet-wide detector sees the same
    regression diluted across all streams (mean error ≈ 0.27 with the
    defaults — still above the 0.25 threshold, so both designs fire and
    ``benchmarks/obs_export.py`` can compare their repairs head-to-head).

    Demand is deliberately *flat* (unlike ``drifting_scene``): with no
    diurnal churn, every migration in the ledger traces to the
    recalibration replan itself, so the benchmark's migration comparison
    measures the repair scope and nothing else.
    """
    from repro.obs import DriftingService, RateShift
    cameras = ("nyc", "london", drifted_camera)
    specs = tuple(dataclasses.replace(c, base_fps=c.peak_fps)
                  for c in _fleet(cameras, n_streams))
    tokens_per_frame = 8.0
    base_rates = {c.stream_id: (22.4 if c.program == "VGG16" else 64.0)
                  for c in specs}
    groups = {c.stream_id: geo.nearest_region(c.camera, sorted(geo.DATACENTERS))
              for c in specs}
    drifted_region = geo.nearest_region(drifted_camera,
                                        sorted(geo.DATACENTERS))
    drifted = frozenset(sid for sid, g in groups.items()
                        if g == drifted_region)
    service = DriftingService(base_rates,
                              tokens_per_frame=tokens_per_frame,
                              shifts=(RateShift(at_h=shift_at_h,
                                                factor=shift_factor,
                                                streams=drifted),))
    return Scenario(
        name="regional_drift",
        demand=DiurnalFleet(specs),
        config=SimConfig(duration_h=duration_h, seed=seed,
                         spot_fraction=0.0),
        description="three-region fleet; one region's true serving rates "
                    "regress 80% at mid-day — the per-region drift / "
                    "per-group recalibration scenario",
        service=service,
        groups=groups)


def _pipeline_fleet(cameras: Sequence[str], n_streams: int, *,
                    fps: float = 2.0, plate_every: int = 3,
                    base_density: float = 0.05,
                    peak_density: float = 1.0
                    ) -> tuple[PipelineCameraSpec, ...]:
    """n_streams pipeline cameras round-robined over ``cameras``, capturing
    ``fps`` frames/s around the clock; every ``plate_every``-th runs the
    three-stage ``roi_plate`` pipeline, the rest two-stage ``roi_vehicle``.
    Scene density swings ``base_density`` -> ``peak_density`` diurnally."""
    specs = []
    cams = itertools.cycle(cameras)
    for i in range(n_streams):
        cam = next(cams)
        if plate_every and i % plate_every == plate_every - 1:
            specs.append(PipelineCameraSpec(
                f"plate-{cam}-{i}", cam, "roi_plate", fps=fps,
                base_density=base_density, peak_density=peak_density))
        else:
            specs.append(PipelineCameraSpec(
                f"veh-{cam}-{i}", cam, "roi_vehicle", fps=fps,
                base_density=base_density, peak_density=peak_density))
    return tuple(specs)


def roi_day(n_streams: int = 96, duration_h: float = 24.0,
            seed: int = 0) -> Scenario:
    """Content-aware pipelines over a US day: endogenous demand.

    Cameras capture at a constant 2 frames/s; what swings diurnally is the
    *scene density* (0.05 at night, 1.0 at rush hour), which drives the
    activation of the downstream crop stages — the detector watches every
    frame around the clock, the heavy classify/track/ocr stages fire almost
    never at 3am and on every candidate at 8:30. The planner sees one item
    per stage (``sid::stage``), so a scene getting busy IS a demand spike
    without any frame-rate knob turning."""
    return Scenario(
        name="roi_day",
        demand=PipelineFleet(_pipeline_fleet(US_CAMERAS, n_streams)),
        config=SimConfig(duration_h=duration_h, seed=seed),
        description="US pipeline fleet at fixed capture rate; scene density "
                    "swings sparse-night/dense-rush and heavy stages "
                    "activate with it (endogenous demand)")


def consolidated_city(n_streams: int = 120, duration_h: float = 24.0,
                      seed: int = 0, consolidate: bool = True) -> Scenario:
    """The crop-consolidation gate: one metro area, many co-located cameras.

    All cameras sit in four US cities (~30 per city) running ``roi_vehicle``;
    with ``consolidate=True`` each city's VGG16 crop-classify stages pool
    onto shared GPU workers (``pool::roi_vehicle.classify@nyc#k``) — one
    model load serves every camera's crops, capped at the stage's pooled
    frame-rate ceiling. The ``consolidate=False`` arm packs the same demand
    as per-camera stage items; ``benchmarks/pipeline_consolidation.py``
    gates the saving between the two arms."""
    return Scenario(
        name="consolidated_city",
        demand=PipelineFleet(
            _pipeline_fleet(US_CAMERAS, n_streams, plate_every=0),
            consolidate=consolidate),
        config=SimConfig(duration_h=duration_h, seed=seed),
        description="co-located pipeline cameras; crop-classify stages "
                    "consolidated onto shared GPU workers (on/off arms)")


def _replicated(specs: Sequence[CameraSpec], replicas: int = 2
                ) -> tuple[CameraSpec, ...]:
    """Each camera spec split into ``replicas`` load-sharing replicas
    (``sid#0``, ``sid#1``, ... at 1/replicas of the rate). Replica groups
    are what the mixed planner's anti-affinity rule keeps off any single
    spot market — one region's reclaim can only take one replica down."""
    out = []
    for c in specs:
        for k in range(replicas):
            out.append(dataclasses.replace(
                c, stream_id=f"{c.stream_id}#{k}",
                base_fps=round(c.base_fps / replicas, 6),
                peak_fps=round(c.peak_fps / replicas, 6)))
    return tuple(out)


def spot_bidder(n_streams: int = 108, duration_h: float = 24.0,
                seed: int = 0) -> Scenario:
    """Rush-hour demand served by 2x replicated streams with *no* random
    spot boots (``spot_fraction=0``): all spot capacity comes from a
    bidding policy's mixed plans, reclaimed exactly when the price walk
    rises above a bid. The scenario for ``SpotBidPolicy`` +
    ``benchmarks/spot_bidding.py`` — with a plain policy it runs fully
    on-demand (the cost baseline)."""
    base = _fleet(US_CAMERAS, max(1, n_streams // 2))
    return Scenario(
        name="spot_bidder",
        demand=DiurnalFleet(_replicated(base, replicas=2)),
        config=SimConfig(duration_h=duration_h, seed=seed,
                         spot_fraction=0.0),
        description="replicated rush-hour fleet; spot capacity only via "
                    "bids against the price walk (anti-affinity keeps a "
                    "stream's replicas off any one spot market)")


def mega_city(n_streams: int = 10_000, duration_h: float = 24.0,
              seed: int = 0) -> Scenario:
    """Fleet-scale stress test: 10k cameras worldwide (the 12 cities map to
    all 9 catalog regions), diurnal curves in local time, a night-time
    program-mix shift, and a 4x evening flash crowd on the European cameras
    landing on top of their rush-hour peak. Runs entirely on the vectorized
    demand + packed-planner path; ``benchmarks/scale_sweep.py`` gates its
    24 h wall-clock and its parity against the scalar planner."""
    base = DiurnalFleet(_fleet(ALL_CAMERAS, n_streams,
                               zf_base=0.2, zf_peak=2.5, vgg_every=3))
    shifted = MixShift(base, night_program="VGG16", fraction=0.25)
    demand = FlashCrowd(shifted, start_h=17.0, duration_h=2.0,
                        multiplier=4.0, cameras=frozenset(EU_CAMERAS),
                        cap_fps=8.0)
    return Scenario(
        name="mega_city",
        demand=demand,
        config=SimConfig(duration_h=duration_h, seed=seed),
        description="10k streams, 9 regions: diurnal + night mix shift + "
                    "4x EU evening flash crowd (vectorized-path stress test)")


def continent_scale(n_streams: int = 1_000_000, duration_h: float = 24.0,
                    seed: int = 0) -> Scenario:
    """Million-stream day: the columnar-path scale gate.

    The same fleet shape as ``_fleet(ALL_CAMERAS, n)`` — cameras round-robin
    over the 12 cities, every 4th stream runs VGG16 at low rates, the rest
    ZF with a modest swing — but built straight from numpy columns via
    :func:`~repro.sim.demand.columnar_fleet`, so constructing the scenario
    never allocates a ``CameraSpec`` (or ``Stream``) per camera. Demand is
    pure diurnal (no churn/flash wrappers) and fully on-demand
    (``spot_fraction=0``), so the stable-id fast paths carry every tick:
    ``benchmarks/columnar_sweep.py`` gates the 24 h x 1M wall-clock and the
    columnar-vs-object ledger parity at smaller sizes of the same shape."""
    cams = ALL_CAMERAS
    nc = len(cams)
    idx = np.arange(n_streams, dtype=np.int64)
    cam_codes = idx % nc
    vgg = (idx % 4) == 3
    ids = [(f"vgg-{cams[i % nc]}-{i}" if i % 4 == 3
            else f"zf-{cams[i % nc]}-{i}") for i in range(n_streams)]
    demand = columnar_fleet(
        ids,
        utc_offset_h=np.array([geo.utc_offset_hours(c)
                               for c in cams])[cam_codes],
        base_fps=np.where(vgg, 0.1, 0.2),
        peak_fps=np.where(vgg, 1.5, 2.5),
        program_codes=vgg.astype(np.int64),
        programs_unique=(PROGRAMS["ZF"], PROGRAMS["VGG16"]),
        camera_codes=cam_codes,
        cameras_unique=cams)
    return Scenario(
        name="continent_scale",
        demand=demand,
        config=SimConfig(duration_h=duration_h, dt_h=1.0, seed=seed,
                         spot_fraction=0.0),
        description="1M streams, 12 cities, pure diurnal on-demand day: "
                    "the columnar fleet-state scale gate")


SCENARIOS: dict[str, Callable[..., Scenario]] = {
    "steady": steady,
    "rush_hour": rush_hour,
    "follow_the_sun": follow_the_sun,
    "spot_heavy": spot_heavy,
    "flash_crowd": flash_crowd,
    "churn_storm": churn_storm,
    "drifting_scene": drifting_scene,
    "regional_drift": regional_drift,
    "roi_day": roi_day,
    "consolidated_city": consolidated_city,
    "mega_city": mega_city,
    "spot_bidder": spot_bidder,
    "continent_scale": continent_scale,
}
