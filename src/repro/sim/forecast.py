"""Seasonal hour-of-week demand forecasting (BEYOND-PAPER).

The paper's workloads are strongly diurnal (§V's demand curves repeat by
hour of day), yet every policy up to PR 9 was reactive or, at best,
trend-extrapolating. :class:`SeasonalForecaster` learns the *shape*:
per-stream-class mean demand curves keyed by hour-of-week bucket, with an
EWMA residual correction for systematic bias and an explicit cold-start
answer (an unseen bucket forecasts the current rate — the reactive path).

A *stream class* is ``(program name, camera)``: streams of one class share
a local-time demand curve (the scenario library builds fleets exactly this
way), so a handful of class curves generalizes over thousands of streams
and a camera that joins mid-week inherits its class's history immediately.

Three feature sources feed the same model:

* :meth:`observe` — the per-decision demand the attached policy sees
  (class-resolved; the columnar path is a ``bincount`` over
  :class:`~repro.sim.demand.StreamColumns` codes);
* :meth:`fit_ledger` — a past run's :class:`~repro.sim.ledger.Ledger`
  (fleet-level ``frames_demanded`` per tick → the fleet curve);
* :meth:`attach_hub` — live ``fleet.frames.demanded`` telemetry points
  from an :class:`~repro.obs.TelemetryHub`, which both extend the fleet
  curve *during* a run and drive a clipped multiplicative live-scale
  correction (today is running X% hotter than the fitted curve).

:class:`~repro.sim.mpc.MPCPolicy` rolls these forecasts ahead of the boot
delay; ``benchmarks/forecast_mpc.py`` gates the pair against the reactive
baseline.
"""
from __future__ import annotations

import collections
import math
from typing import Optional, Sequence

import numpy as np

from repro.sim.demand import StreamColumns


class SeasonalForecaster:
    """Hour-of-week demand curves per stream class, with residual EWMA.

    Per class and per bucket the fit is the running mean of the observed
    *per-member* rate (frames/s); :meth:`forecast_fps` adds the class's
    EWMA residual (systematic error of recent observations against the
    fitted curve) and the fleet-level live scale. A target bucket with
    fewer than ``min_obs`` observations is *cold*: the forecast falls back
    to the stream's current rate, i.e. exactly what a reactive policy
    plans for.
    """

    #: the telemetry metric the hub subscriber consumes
    HUB_METRIC = "fleet.frames.demanded"

    def __init__(self, period_h: float = 168.0, bucket_h: float = 1.0,
                 alpha: float = 0.2, min_obs: int = 1,
                 live_window: int = 6,
                 live_clip: tuple[float, float] = (0.5, 2.0)) -> None:
        self.period_h = period_h
        self.bucket_h = bucket_h
        self.n_buckets = max(1, int(round(period_h / bucket_h)))
        self.alpha = alpha
        self.min_obs = min_obs
        self.live_clip = live_clip
        # class key -> [bucket sums (mean fps per member), bucket counts]
        self._classes: dict[tuple[str, str], list[np.ndarray]] = {}
        self._resid: dict[tuple[str, str], float] = {}
        # fleet-level curve (ledger fits + telemetry points land here)
        self._fleet_sum = np.zeros(self.n_buckets)
        self._fleet_cnt = np.zeros(self.n_buckets, dtype=np.int64)
        # recent observed/fitted fleet ratios from the hub subscriber
        self._live: collections.deque = collections.deque(maxlen=live_window)
        self._last_point: Optional[tuple[float, float]] = None
        self._idx_cache: Optional[tuple] = None

    # -- time --------------------------------------------------------------

    def bucket(self, t_h: float) -> int:
        """Hour-of-week bucket of simulated UTC hour ``t_h``."""
        return int(math.floor((t_h % self.period_h) / self.bucket_h)) \
            % self.n_buckets

    # -- stream classes ----------------------------------------------------

    def _class_index(self, streams) -> tuple[list, np.ndarray]:
        """(class keys, per-stream class index) for one tick's fleet.

        Columnar input resolves classes with one ``np.unique`` over the
        combined program/camera codes; the result is cached on the identity
        of the three arrays, so stable fleets (same ids, same codes object)
        pay once. Object input takes the per-stream dict walk.
        """
        if isinstance(streams, StreamColumns):
            cols = streams
            key = (id(cols.ids), id(cols.program_codes),
                   id(cols.camera_codes))
            cached = self._idx_cache
            if cached is not None and cached[0] == key:
                return cached[1], cached[2]
            pc = cols.program_codes
            cc = cols.camera_codes
            combo = pc.astype(np.int64) * (len(cols.cameras_unique) + 1) \
                + (cc + 1)
            _, first, inv = np.unique(combo, return_index=True,
                                      return_inverse=True)
            keys = []
            for i0 in first.tolist():
                p = cols.programs_unique[int(pc[i0])]
                c = int(cc[i0])
                keys.append((getattr(p, "name", str(p)),
                             cols.cameras_unique[c] if c >= 0 else ""))
            self._idx_cache = (key, keys, inv)
            return keys, inv
        keys: list[tuple[str, str]] = []
        of: dict[tuple[str, str], int] = {}
        inv = np.empty(len(streams), dtype=np.int64)
        for n, s in enumerate(streams):
            k = (getattr(s.program, "name", str(s.program)), s.camera or "")
            c = of.get(k)
            if c is None:
                c = len(keys)
                of[k] = c
                keys.append(k)
            inv[n] = c
        return keys, inv

    def _fps_of(self, streams) -> np.ndarray:
        if isinstance(streams, StreamColumns):
            return streams.fps
        return np.array([s.fps for s in streams])

    # -- fitting -----------------------------------------------------------

    def observe(self, t_h: float, streams) -> None:
        """Fold one decision's demanded rates into the seasonal fit.

        Residuals update *before* the new observation merges: the EWMA
        tracks how today's demand deviates from the curve as fitted so
        far, which is exactly the correction the next forecast needs.
        """
        if len(streams) == 0:
            return
        keys, inv = self._class_index(streams)
        fps = self._fps_of(streams)
        sums = np.bincount(inv, weights=fps, minlength=len(keys))
        cnts = np.bincount(inv, minlength=len(keys))
        means = sums / np.maximum(cnts, 1)
        b = self.bucket(t_h)
        for k, key in enumerate(keys):
            m = float(means[k])
            rec = self._classes.get(key)
            if rec is None:
                rec = self._classes[key] = [
                    np.zeros(self.n_buckets),
                    np.zeros(self.n_buckets, dtype=np.int64)]
            csum, ccnt = rec
            if ccnt[b] > 0:
                pred = csum[b] / ccnt[b]
                self._resid[key] = ((1.0 - self.alpha)
                                    * self._resid.get(key, 0.0)
                                    + self.alpha * (m - pred))
            csum[b] += m
            ccnt[b] += 1

    def warmup(self, demand, horizon_h: float, dt_h: float = 1.0,
               start_h: float = 0.0) -> None:
        """Prime the class curves by replaying a demand model over
        ``[start_h, start_h + horizon_h)`` — "yesterday's telemetry" (every
        demand model in the scenario library is a pure seeded function of
        time, so a replay is legitimate history, not leakage)."""
        t = start_h
        end = start_h + horizon_h
        cols = getattr(demand, "columns_at", None)
        while t < end - 1e-9:
            self.observe(t, cols(t) if cols is not None
                         else demand.streams_at(t))
            t += dt_h

    def fit_ledger(self, ledger) -> None:
        """Fold a past run's per-tick ``frames_demanded`` into the
        fleet-level hour-of-week curve (intervals come from consecutive
        record times; the final record reuses the last interval)."""
        recs = list(ledger.records)
        for i, r in enumerate(recs):
            if i + 1 < len(recs):
                dt = recs[i + 1].t - r.t
            elif i > 0:
                dt = r.t - recs[i - 1].t
            else:
                continue               # one record: interval unknowable
            if dt <= 0:
                continue
            b = self.bucket(r.t)
            self._fleet_sum[b] += r.frames_demanded / (dt * 3600.0)
            self._fleet_cnt[b] += 1

    # -- live telemetry ----------------------------------------------------

    def attach_hub(self, hub) -> None:
        """Subscribe to an :class:`~repro.obs.TelemetryHub`: every
        ``fleet.frames.demanded`` point extends the fleet curve and the
        live-scale window as the run happens."""
        hub.subscribe(self._on_point)

    def _on_point(self, point) -> None:
        if point.name != self.HUB_METRIC:
            return
        prev = self._last_point
        self._last_point = (point.t, point.value)
        if prev is None:
            return
        t0, frames = prev
        dt = point.t - t0
        if dt <= 0:
            # time went backwards: a new run is streaming through the hub
            self._live.clear()
            return
        fps = frames / (dt * 3600.0)
        b = self.bucket(t0)
        if self._fleet_cnt[b] > 0:
            pred = self._fleet_sum[b] / self._fleet_cnt[b]
            if pred > 0:
                self._live.append(fps / pred)
        self._fleet_sum[b] += fps
        self._fleet_cnt[b] += 1

    def live_scale(self) -> float:
        """Clipped mean of recent observed/fitted fleet demand ratios —
        the "today is hotter/cooler than the curve" correction. 1.0 when
        no telemetry has arrived (and, by construction, on a day that
        matches the fit)."""
        if not self._live:
            return 1.0
        s = sum(self._live) / len(self._live)
        lo, hi = self.live_clip
        return min(hi, max(lo, s))

    def fleet_fps(self, at_t: float) -> Optional[float]:
        """Fitted fleet-level rate at ``at_t`` (None when the bucket is
        cold) — the coarse curve ledger fits and telemetry feed."""
        b = self.bucket(at_t)
        if self._fleet_cnt[b] < self.min_obs:
            return None
        return float(self._fleet_sum[b] / self._fleet_cnt[b])

    # -- forecasting -------------------------------------------------------

    def forecast_fps(self, at_t: float, streams
                     ) -> tuple[np.ndarray, np.ndarray]:
        """(forecast frames/s, known mask) aligned with ``streams``.

        Where the mask is False the class's target bucket is cold and the
        returned rate is the stream's *current* rate — the reactive
        fallback. Warm entries are ``(bucket mean + residual) * live_scale``,
        floored at zero.
        """
        fps = self._fps_of(streams)
        if len(fps) == 0:
            return fps, np.zeros(0, dtype=bool)
        keys, inv = self._class_index(streams)
        b = self.bucket(at_t)
        scale = self.live_scale()
        pred = np.empty(len(keys))
        known = np.zeros(len(keys), dtype=bool)
        for k, key in enumerate(keys):
            rec = self._classes.get(key)
            if rec is not None and rec[1][b] >= self.min_obs:
                p = (rec[0][b] / rec[1][b] + self._resid.get(key, 0.0)) \
                    * scale
                pred[k] = max(0.0, p)
                known[k] = True
            else:
                pred[k] = 0.0
        known_s = known[inv]
        return np.where(known_s, pred[inv], fps), known_s

    def coverage(self, at_t: float, streams) -> float:
        """Fraction of the fleet whose class bucket at ``at_t`` is warm —
        the cold-start gate :class:`~repro.sim.mpc.MPCPolicy` checks
        before trusting the forecast over the reactive path."""
        if len(streams) == 0:
            return 0.0
        _, known = self.forecast_fps(at_t, streams)
        return float(np.count_nonzero(known)) / len(known)
