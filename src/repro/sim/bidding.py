"""Spot bidding policies and the mixed-market autoscaling policy.

A *bidding strategy* turns a :class:`~repro.core.markets.MarketQuote` into
a bid in $/hour — the price above which the market may reclaim the
instance. On this simulator's market (as on EC2's classic spot market) you
always *pay* the going spot price, never your bid, so the bid only sets
preemption risk: the classic result is that high bids are cheap insurance.
The strategies differ in how they pick the head-room:

* :class:`FixedMarginBid` — bid the current price times ``1 + margin``.
* :class:`PercentileBid` — bid the given percentile of the region's
  observed multiplier history (needs a few ticks of warm-up, then adapts
  to each region's realized volatility).
* :class:`LookaheadBid` — pick the margin minimizing the *expected
  effective price* of the next interval: expected payment while alive,
  plus — on reclaim — the on-demand fallback and the boot-window SLO loss
  (``MarketQuote.effective_price``). This is the policy that trades
  preemption SLO loss against spot savings explicitly.

:class:`SpotBidPolicy` is the fleet-simulator policy: an
:class:`~repro.core.adaptive.AdaptiveManager` in mixed-market mode (plans
carry an on-demand floor per stream class plus spot burst bins under the
replica anti-affinity rule; replans are min-migration mixed repairs), with
per-(type, region) bids recomputed from the attached
:class:`~repro.sim.cluster.SpotMarket` every decision.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional, Sequence

from repro.core.adaptive import AdaptiveManager
from repro.core.manager import ResourceManager
from repro.core.markets import SPOT, MarketQuote, MixedConfig, quotes
from repro.core.strategies import Plan
from repro.core.workload import Stream


class FixedMarginBid:
    """Bid a constant multiplicative head-room over the current price."""

    def __init__(self, margin: float = 0.35) -> None:
        self.name = f"fixed-margin-{margin:g}"
        self.margin = margin

    def bid(self, quote: MarketQuote, history: Sequence[float],
            dt_h: float) -> float:
        # never bid above the on-demand list price: past it you would pay
        # more to keep a reclaimable instance than a guaranteed one costs
        return min(quote.price * (1.0 + self.margin), quote.ondemand_price)


class PercentileBid:
    """Bid the q-th percentile of the region's observed price history.

    ``history`` is the multiplier series the attached market exposes; the
    bid is that percentile of the last ``window`` observations times the
    on-demand price. Until enough history accumulates it falls back to a
    fixed margin."""

    def __init__(self, pct: float = 98.0, window: int = 12,
                 warmup_margin: float = 0.35) -> None:
        self.name = f"percentile-{pct:g}"
        self.pct = pct
        self.window = window
        self._warmup = FixedMarginBid(warmup_margin)

    def bid(self, quote: MarketQuote, history: Sequence[float],
            dt_h: float) -> float:
        if len(history) < 3:
            return self._warmup.bid(quote, history, dt_h)
        tail = sorted(history[-self.window:])
        # nearest-rank percentile, deterministic
        k = min(len(tail) - 1, int(math.ceil(self.pct / 100.0 * len(tail))) - 1)
        mult = tail[max(k, 0)]
        bid = quote.ondemand_price * mult
        # at least the current price (a bid below it would be reclaimed
        # immediately), at most the on-demand list price
        return min(max(bid, quote.price), quote.ondemand_price)


class LookaheadBid:
    """Pick the margin minimizing next-interval expected effective price.

    For each candidate margin the expected cost is
    ``MarketQuote.effective_price``: survive and pay the (slightly higher)
    expected market price, or get reclaimed and pay on-demand plus the
    dt-independent **dollar cost of one reclaim** —
    ``slo_weight * ondemand_price * boot_delay_h``, the on-demand dollars'
    worth of the boot window the replacement instance spends not serving.
    The expected-price model is evaluated over a fixed ``horizon_h``
    decision horizon (not the control-loop tick), so the same policy picks
    the same margins whether the simulator ticks hourly or every five
    minutes. Low margins save nothing (you pay the market either way) and
    risk the penalty, so the optimum sits high — but below the cap when
    the walk is calm."""

    def __init__(self, margins: Sequence[float] = (0.1, 0.2, 0.3, 0.4,
                                                   0.5, 0.75, 1.0),
                 boot_delay_h: float = 0.05, slo_weight: float = 1.0,
                 horizon_h: float = 1.0) -> None:
        self.name = "lookahead"
        self.margins = tuple(margins)
        # default matches SimConfig.boot_delay_h; SpotBidPolicy overwrites
        # it with the simulator's actual boot window on attach_market, so
        # the penalty model prices the outage the ledger will really charge
        self.boot_delay_h = boot_delay_h
        self.slo_weight = slo_weight
        self.horizon_h = horizon_h

    def reclaim_cost(self, quote: MarketQuote) -> float:
        """The dt-independent dollars one reclaim of this quote costs."""
        return self.slo_weight * quote.ondemand_price * self.boot_delay_h

    def bid(self, quote: MarketQuote, history: Sequence[float],
            dt_h: float) -> float:
        penalty = self.reclaim_cost(quote)
        best = min(
            self.margins,
            key=lambda m: (quote.effective_price(
                min(quote.price * (1.0 + m), quote.ondemand_price),
                self.horizon_h, preempt_penalty=penalty), m))
        return min(quote.price * (1.0 + best), quote.ondemand_price)


def compute_bids(catalog, market, bidding, dt_h: float
                 ) -> dict[tuple[str, str], float]:
    """One bid per (instance type, region) spot quote at the attached
    market's current multipliers — the shared bid-refresh step of
    :class:`SpotBidPolicy` and :class:`~repro.sim.mpc.MPCPolicy`. Returns
    ``{}`` when no market is attached (pure on-demand operation)."""
    if market is None:
        return {}
    mults = market.multipliers()
    if not mults:
        return {}
    history = {r: [h[r] for h in market.price_history if r in h]
               for r in mults}
    vol = getattr(market, "volatility", 0.15)
    out: dict[tuple[str, str], float] = {}
    for q in quotes(catalog, mults, volatility=vol):
        if q.market != SPOT:
            continue
        out[(q.type_name, q.location)] = bidding.bid(
            q, history.get(q.location, ()), dt_h)
    return out


@dataclasses.dataclass
class SpotBidPolicy:
    """Mixed on-demand/spot autoscaling with per-region bids.

    Every decision: read the attached market's multipliers, recompute one
    bid per (instance type, region) spot quote with the bidding strategy,
    and plan through the mixed-market ``AdaptiveManager`` (on-demand floor
    per stream class, spot burst under replica anti-affinity,
    min-migration repairs). The fleet simulator reads ``bids`` when
    reconciling, so spot instances boot carrying exactly the bids the plan
    was made under; the market later reclaims exactly the bids it rises
    above.
    """

    manager: ResourceManager
    bidding: object = None                    # a *Bid strategy
    floor_frac: float = 0.5
    savings_threshold: float = 0.10
    defrag_ratio: Optional[float] = 1.25
    name: str = "spot-bidder"

    def __post_init__(self) -> None:
        if self.bidding is None:
            self.bidding = LookaheadBid()
        self.bids: dict[tuple[str, str], float] = {}
        self._market = None
        self._dt_h = 1.0
        self.adaptive = AdaptiveManager(
            self.manager, strategy="FFD",
            savings_threshold=self.savings_threshold,
            mixed=MixedConfig(floor_frac=self.floor_frac,
                              defrag_ratio=self.defrag_ratio),
            multipliers_fn=self._multipliers)

    # -- market plumbing -----------------------------------------------------

    def attach_market(self, market, dt_h: float = 1.0,
                      boot_delay_h: Optional[float] = None) -> None:
        """Called by the fleet simulator: the exogenous price walk this
        policy observes (and bids against), the control-loop period, and
        the boot window its preemption-penalty model should price."""
        self._market = market
        self._dt_h = dt_h
        if boot_delay_h is not None and hasattr(self.bidding, "boot_delay_h"):
            self.bidding.boot_delay_h = boot_delay_h

    def _multipliers(self) -> dict:
        return self._market.multipliers() if self._market is not None else {}

    def _refresh_bids(self) -> None:
        self.bids = compute_bids(self.manager.catalog, self._market,
                                 self.bidding, self._dt_h)

    # -- the policy interface ------------------------------------------------

    def decide(self, t: float, streams: Sequence[Stream], *,
               preempted: bool = False) -> Plan:
        self._refresh_bids()
        return self.adaptive.step(t, streams, force=preempted)
