"""Autoscaling policies over the paper's planning machinery.

Each policy answers one question per tick: *what should the fleet plan be
for the demand we see right now?* All of them delegate the actual packing to
:class:`~repro.core.manager.ResourceManager` (via
:class:`~repro.core.adaptive.AdaptiveManager` for the adaptive ones, whose
``replan_trigger`` hook and ``force`` flag this module exercises):

* ``StaticPeakPolicy`` — the baseline: plan once for the scanned peak
  demand, never touch it again. Maximum SLO, maximum cost.
* ``ReactivePolicy`` — replan when the current plan can't serve demand, or
  when a replan saves more than the hysteresis threshold.
* ``ScheduledPolicy`` — reactive, but voluntary (cost-saving) replans are
  only *considered* every ``every_h`` hours; infeasibility still forces.
* ``PredictiveEWMAPolicy`` — plans for an EWMA-extrapolated forecast of
  each stream's rate, so capacity boots *before* the ramp arrives instead
  of after it (trading a little cost for boot-window SLO).
* ``RepairPolicy`` — reactive, but replans run through the min-migration
  repair planner (``core/repair.py``): feasible placements stay put, only
  the delta re-packs, and a defrag escape hatch bounds the cost drift.

``SpotBidPolicy`` (in :mod:`repro.sim.bidding`) extends the family with
mixed on-demand/spot planning: per-region bids against the price walk, an
on-demand floor per stream class, and replica anti-affinity across spot
markets.

A spot preemption reaches a policy as ``decide(..., preempted=True)``; the
adaptive policies force a replan, which replays the orphaned streams onto
live capacity.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

from repro.core.adaptive import AdaptiveManager
from repro.core.manager import ResourceManager
from repro.core.repair import RepairConfig
from repro.core.strategies import Plan
from repro.core.workload import Stream


class StaticPeakPolicy:
    """Provision the scanned peak (each stream's maximum frames/s over the
    horizon) once; ignore demand thereafter. Maximum SLO, maximum $/hour."""

    def __init__(self, manager: ResourceManager, peak: Sequence[Stream],
                 strategy: str = "FFD") -> None:
        self.name = "static-peak"
        self._manager = manager
        self._peak = list(peak)
        self._strategy = strategy
        self._plan: Optional[Plan] = None

    def decide(self, t: float, streams: Sequence[Stream], *,
               preempted: bool = False) -> Plan:
        if self._plan is None:
            self._plan = self._manager.plan(self._peak, self._strategy)
        return self._plan


class ReactivePolicy:
    """Adaptive replanning with hysteresis (the paper's runtime manager):
    replan when the plan cannot serve the demanded frames/s, or when a
    replan saves more than ``savings_threshold`` (a fraction of the current
    plan's $/hour cost)."""

    def __init__(self, manager: ResourceManager, strategy: str = "FFD",
                 savings_threshold: float = 0.10, replan_trigger=None,
                 name: str = "reactive") -> None:
        self.name = name
        self.adaptive = AdaptiveManager(manager, strategy=strategy,
                                        savings_threshold=savings_threshold,
                                        replan_trigger=replan_trigger)

    def decide(self, t: float, streams: Sequence[Stream], *,
               preempted: bool = False) -> Plan:
        return self.adaptive.step(t, streams, force=preempted)


class RepairPolicy(ReactivePolicy):
    """Reactive control loop whose replans are min-migration repairs
    (demanded rates in frames/s, plan costs in $/hour).

    Preemption replays and demand-growth replans keep every still-feasible
    placement and re-pack only the orphaned/overflowing delta; cost drift is
    bounded by the defrag escape hatch (adopt a fresh FFD plan when repaired
    cost reaches ``defrag_ratio`` x the fresh cost). ``migration_budget``
    additionally lets each repair spend leftover moves on consolidation.
    """

    def __init__(self, manager: ResourceManager,
                 savings_threshold: float = 0.10,
                 migration_budget: Optional[int] = None,
                 defrag_ratio: Optional[float] = 1.25,
                 name: str = "repair") -> None:
        super().__init__(manager, strategy="REPAIR",
                         savings_threshold=savings_threshold, name=name)
        self.adaptive.repair = RepairConfig(migration_budget=migration_budget,
                                            defrag_ratio=defrag_ratio)


class ScheduledPolicy(ReactivePolicy):
    """Voluntary replans only on a fixed cadence (e.g. every 6 simulated
    hours); demand infeasibility and preemptions still replan immediately."""

    def __init__(self, manager: ResourceManager, every_h: float = 6.0,
                 strategy: str = "FFD",
                 savings_threshold: float = 0.10) -> None:
        last = [None]

        def on_schedule(t, streams, plan) -> bool:
            # elapsed-time cadence, robust to tick sizes that do not divide
            # every_h (a modulo test would fire rarely or never for those)
            if last[0] is None or t - last[0] >= every_h - 1e-9:
                last[0] = t
                return True
            return False

        super().__init__(manager, strategy=strategy,
                         savings_threshold=savings_threshold,
                         replan_trigger=on_schedule, name="scheduled")
        self.every_h = every_h


class PredictiveEWMAPolicy(ReactivePolicy):
    """Plan for a one-tick-ahead forecast: EWMA-smoothed per-stream trend in
    frames/s, floored at current demand so falling forecasts never
    under-provision, capped at ``cap_fps`` frames/s."""

    def __init__(self, manager: ResourceManager, strategy: str = "FFD",
                 savings_threshold: float = 0.10, alpha: float = 0.3,
                 lead_ticks: float = 2.0, cap_fps: float = 12.0) -> None:
        super().__init__(manager, strategy=strategy,
                         savings_threshold=savings_threshold,
                         name="predictive-ewma")
        self.alpha = alpha
        self.lead_ticks = lead_ticks
        self.cap_fps = cap_fps
        self._prev_fps: dict[str, float] = {}
        self._trend: dict[str, float] = {}

    def forecast(self, streams: Sequence[Stream]) -> list[Stream]:
        out = []
        present = set()
        for s in streams:
            present.add(s.stream_id)
            prev = self._prev_fps.get(s.stream_id, s.fps)
            trend = s.fps - prev
            ewma = ((1 - self.alpha) * self._trend.get(s.stream_id, 0.0)
                    + self.alpha * trend)
            self._trend[s.stream_id] = ewma
            self._prev_fps[s.stream_id] = s.fps
            f = max(s.fps, s.fps + ewma * self.lead_ticks)
            out.append(dataclasses.replace(
                s, fps=round(min(f, self.cap_fps), 3)))
        # evict state for departed streams: a churned-out camera that later
        # rejoins must start a fresh trend (not inherit a stale one), and
        # state must stay bounded by the live fleet under heavy churn
        for sid in list(self._prev_fps):
            if sid not in present:
                del self._prev_fps[sid]
                self._trend.pop(sid, None)
        return out

    def decide(self, t: float, streams: Sequence[Stream], *,
               preempted: bool = False) -> Plan:
        return self.adaptive.step(t, self.forecast(streams), force=preempted)
