"""Autoscaling policies over the paper's planning machinery.

Each policy answers one question per tick: *what should the fleet plan be
for the demand we see right now?* All of them delegate the actual packing to
:class:`~repro.core.manager.ResourceManager` (via
:class:`~repro.core.adaptive.AdaptiveManager` for the adaptive ones, whose
``replan_trigger`` hook and ``force`` flag this module exercises):

* ``StaticPeakPolicy`` — the baseline: plan once for the scanned peak
  demand, never touch it again. Maximum SLO, maximum cost.
* ``ReactivePolicy`` — replan when the current plan can't serve demand, or
  when a replan saves more than the hysteresis threshold.
* ``ScheduledPolicy`` — reactive, but voluntary (cost-saving) replans are
  only *considered* every ``every_h`` hours; infeasibility still forces.
* ``PredictiveEWMAPolicy`` — plans for an EWMA-extrapolated forecast of
  each stream's rate, so capacity boots *before* the ramp arrives instead
  of after it (trading a little cost for boot-window SLO).
* ``RepairPolicy`` — reactive, but replans run through the min-migration
  repair planner (``core/repair.py``): feasible placements stay put, only
  the delta re-packs, and a defrag escape hatch bounds the cost drift.

``SpotBidPolicy`` (in :mod:`repro.sim.bidding`) extends the family with
mixed on-demand/spot planning: per-region bids against the price walk, an
on-demand floor per stream class, and replica anti-affinity across spot
markets.

A spot preemption reaches a policy as ``decide(..., preempted=True)``; the
adaptive policies force a replan, which replays the orphaned streams onto
live capacity.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

from repro.core.adaptive import AdaptiveManager
from repro.core.manager import ResourceManager
from repro.core.repair import RepairConfig
from repro.core.strategies import Plan
from repro.core.workload import Stream


class StaticPeakPolicy:
    """Provision the scanned peak (each stream's maximum frames/s over the
    horizon) once; ignore demand thereafter. Maximum SLO, maximum $/hour."""

    def __init__(self, manager: ResourceManager, peak: Sequence[Stream],
                 strategy: str = "FFD") -> None:
        self.name = "static-peak"
        self._manager = manager
        self._peak = list(peak)
        self._strategy = strategy
        self._plan: Optional[Plan] = None

    def decide(self, t: float, streams: Sequence[Stream], *,
               preempted: bool = False) -> Plan:
        if self._plan is None:
            self._plan = self._manager.plan(self._peak, self._strategy)
        return self._plan


class ReactivePolicy:
    """Adaptive replanning with hysteresis (the paper's runtime manager):
    replan when the plan cannot serve the demanded frames/s, or when a
    replan saves more than ``savings_threshold`` (a fraction of the current
    plan's $/hour cost)."""

    def __init__(self, manager: ResourceManager, strategy: str = "FFD",
                 savings_threshold: float = 0.10, replan_trigger=None,
                 name: str = "reactive") -> None:
        self.name = name
        self.adaptive = AdaptiveManager(manager, strategy=strategy,
                                        savings_threshold=savings_threshold,
                                        replan_trigger=replan_trigger)

    def decide(self, t: float, streams: Sequence[Stream], *,
               preempted: bool = False) -> Plan:
        return self.adaptive.step(t, streams, force=preempted)


class RepairPolicy(ReactivePolicy):
    """Reactive control loop whose replans are min-migration repairs
    (demanded rates in frames/s, plan costs in $/hour).

    Preemption replays and demand-growth replans keep every still-feasible
    placement and re-pack only the orphaned/overflowing delta; cost drift is
    bounded by the defrag escape hatch (adopt a fresh FFD plan when repaired
    cost reaches ``defrag_ratio`` x the fresh cost). ``migration_budget``
    additionally lets each repair spend leftover moves on consolidation.
    """

    def __init__(self, manager: ResourceManager,
                 savings_threshold: float = 0.10,
                 migration_budget: Optional[int] = None,
                 defrag_ratio: Optional[float] = 1.25,
                 name: str = "repair") -> None:
        super().__init__(manager, strategy="REPAIR",
                         savings_threshold=savings_threshold, name=name)
        self.adaptive.repair = RepairConfig(migration_budget=migration_budget,
                                            defrag_ratio=defrag_ratio)


class ScheduledPolicy(ReactivePolicy):
    """Voluntary replans only on a fixed cadence (e.g. every 6 simulated
    hours); demand infeasibility and preemptions still replan immediately.

    The cadence phase — and the adaptive plan state — reset whenever
    simulated time moves backwards, i.e. when one policy object is reused
    across :class:`~repro.sim.fleet.FleetSimulator` runs: the second run's
    first decision must behave exactly like a fresh policy's, not inherit
    the prior run's phase (or its final plan)."""

    def __init__(self, manager: ResourceManager, every_h: float = 6.0,
                 strategy: str = "FFD",
                 savings_threshold: float = 0.10) -> None:
        last = [None]

        def on_schedule(t, streams, plan) -> bool:
            # elapsed-time cadence, robust to tick sizes that do not divide
            # every_h (a modulo test would fire rarely or never for those)
            if last[0] is None or t - last[0] >= every_h - 1e-9:
                last[0] = t
                return True
            return False

        super().__init__(manager, strategy=strategy,
                         savings_threshold=savings_threshold,
                         replan_trigger=on_schedule, name="scheduled")
        self.every_h = every_h
        self._last_voluntary = last
        self._last_decide_t: Optional[float] = None

    def decide(self, t: float, streams: Sequence[Stream], *,
               preempted: bool = False) -> Plan:
        if self._last_decide_t is not None and t < self._last_decide_t - 1e-9:
            # a new run started: reset the cadence phase and the plan state
            # (the events list is replaced, not cleared, so a finished
            # simulator's view of the old trace stays intact)
            self._last_voluntary[0] = None
            self.adaptive.current = None
            self.adaptive.events = []
        self._last_decide_t = t
        return super().decide(t, streams, preempted=preempted)


class PredictiveEWMAPolicy(ReactivePolicy):
    """Plan for a ``lead_h``-hours-ahead forecast: EWMA-smoothed per-stream
    trend in frames/s **per hour**, floored at current demand so falling
    forecasts never under-provision, capped at ``cap_fps`` frames/s.

    Time units matter here. The observed trend is ``Δfps / Δt`` between
    decisions and the extrapolation horizon ``lead_h`` is in simulated
    hours, so the forecast is a function of the demand *path*, not of the
    control-loop period: halving ``dt_h`` (or running PR 8's fractional
    final tick) yields the same forecasts at the same times. The EWMA decay
    is time-based too — ``(1 - alpha)`` per hour of elapsed time — so the
    smoothing window is a wall-clock quantity. At the legacy 1-hour tick
    every expression reduces bit-for-bit to the historical per-observation
    form (``lead_ticks`` remains as a deprecated alias for that era's
    callers: one tick meant one hour).
    """

    def __init__(self, manager: ResourceManager, strategy: str = "FFD",
                 savings_threshold: float = 0.10, alpha: float = 0.3,
                 lead_h: Optional[float] = None, cap_fps: float = 12.0,
                 lead_ticks: Optional[float] = None) -> None:
        super().__init__(manager, strategy=strategy,
                         savings_threshold=savings_threshold,
                         name="predictive-ewma")
        self.alpha = alpha
        if lead_h is None:
            # deprecated alias: a "tick" of lead is interpreted at the
            # legacy 1-hour control period
            lead_h = float(lead_ticks) if lead_ticks is not None else 2.0
        self.lead_h = lead_h
        self.cap_fps = cap_fps
        self._prev_fps: dict[str, float] = {}
        self._trend: dict[str, float] = {}        # frames/s per hour
        self._last_t: Optional[float] = None

    @property
    def lead_ticks(self) -> float:
        """Deprecated alias for :attr:`lead_h` (ticks were hours)."""
        return self.lead_h

    @lead_ticks.setter
    def lead_ticks(self, value: float) -> None:
        self.lead_h = float(value)

    def forecast(self, streams: Sequence[Stream],
                 dt_h: float = 1.0) -> list[Stream]:
        """One observation + extrapolation pass. ``dt_h`` is the simulated
        time since the previous observation (the legacy default of 1.0
        reproduces the historical per-tick behavior exactly)."""
        if dt_h == 1.0:
            # bit-identical to the historical per-observation update
            decay, gain = 1.0 - self.alpha, self.alpha
        else:
            decay = (1.0 - self.alpha) ** dt_h
            gain = 1.0 - decay
        out = []
        present = set()
        for s in streams:
            present.add(s.stream_id)
            prev = self._prev_fps.get(s.stream_id, s.fps)
            trend = (s.fps - prev) / dt_h         # frames/s per hour
            ewma = decay * self._trend.get(s.stream_id, 0.0) + gain * trend
            self._trend[s.stream_id] = ewma
            self._prev_fps[s.stream_id] = s.fps
            f = max(s.fps, s.fps + ewma * self.lead_h)
            out.append(dataclasses.replace(
                s, fps=round(min(f, self.cap_fps), 3)))
        # evict state for departed streams: a churned-out camera that later
        # rejoins must start a fresh trend (not inherit a stale one), and
        # state must stay bounded by the live fleet under heavy churn
        for sid in list(self._prev_fps):
            if sid not in present:
                del self._prev_fps[sid]
                self._trend.pop(sid, None)
        return out

    def decide(self, t: float, streams: Sequence[Stream], *,
               preempted: bool = False) -> Plan:
        if self._last_t is not None and t < self._last_t - 1e-9:
            # the policy object was reused for a new run: trends observed
            # across the time jump would be garbage
            self._prev_fps.clear()
            self._trend.clear()
            self._last_t = None
        # the realized interval since the last decision (PR 8's accumulation
        # schedule keeps decisions at k*dt, but this stays correct even for
        # irregular calls); the first observation has no interval — its
        # trend is zero regardless, so any positive dt is equivalent
        dt_h = (t - self._last_t) if self._last_t is not None else 1.0
        if dt_h <= 0:
            dt_h = 1.0
        self._last_t = t
        return self.adaptive.step(t, self.forecast(streams, dt_h),
                                  force=preempted)
