"""Trace-driven fleet simulator (BEYOND-PAPER).

Drives the paper's planning/adaptive machinery end-to-end over simulated
days: diurnal demand per camera region (``demand``), a discrete-event loop
with instance boot delays, spot-price walks and preemptions (``events`` +
``cluster``), autoscaling policies over ``AdaptiveManager`` (``autoscaler``),
per-tick cost/SLO accounting calibrated from serving measurements
(``ledger``), and a scenario library (``scenarios``). See DESIGN.md.
"""
from repro.sim.autoscaler import (PredictiveEWMAPolicy, ReactivePolicy,
                                  RepairPolicy, ScheduledPolicy,
                                  StaticPeakPolicy)
from repro.sim.bidding import (FixedMarginBid, LookaheadBid, PercentileBid,
                               SpotBidPolicy, compute_bids)
from repro.sim.cluster import Cluster, SimInstance, SpotMarket
from repro.sim.demand import (CameraSpec, DiurnalFleet, FlashCrowd, MixShift,
                              PipelineCameraSpec, PipelineFleet, PoissonChurn,
                              peak_streams, rush_hour_fps)
from repro.sim.events import Event, EventQueue
from repro.sim.fleet import FleetSimulator, SimConfig
from repro.sim.forecast import SeasonalForecaster
from repro.sim.ledger import Ledger, ServiceCalibration, TickRecord
from repro.sim.mpc import MPCConfig, MPCPolicy
from repro.sim.scenarios import SCENARIOS, Scenario

__all__ = [
    "CameraSpec", "Cluster", "DiurnalFleet", "Event", "EventQueue",
    "FixedMarginBid", "FlashCrowd", "FleetSimulator", "Ledger",
    "LookaheadBid", "MPCConfig", "MPCPolicy", "MixShift", "PercentileBid",
    "PipelineCameraSpec", "PipelineFleet", "PoissonChurn",
    "PredictiveEWMAPolicy", "ReactivePolicy", "RepairPolicy", "SCENARIOS",
    "Scenario", "ScheduledPolicy", "SeasonalForecaster",
    "ServiceCalibration", "SimConfig",
    "SimInstance", "SpotBidPolicy", "SpotMarket", "StaticPeakPolicy",
    "TickRecord", "compute_bids", "peak_streams", "rush_hour_fps",
]
