"""The fleet simulator: demand → policy → cluster → ledger, in event order.

One :class:`FleetSimulator` run replays a demand model against an
autoscaling policy over simulated days. The control loop interleaves
ticks with spot preemptions; every demanded frame ends the run either
analyzed or dropped (never silently lost), and every instance-hour is
billed — so policies are comparable on exactly the two axes the paper
cares about: dollars and service.

Per tick ``t`` (all times in simulated hours):

1. apply the preemptions that fired inside the interval that just ended
   (one vectorized batch in event order — equivalent to the historical
   one-heap-pop-per-event loop, and bit-identical in its ledgers);
2. account the interval, using the demand and stream→instance assignment
   that were in force, then retire long-terminated instances from the
   cluster's columns (their hours seal into an aggregate; billing is
   unchanged);
3. read the demand model, tell the policy whether a preemption hit since
   its last decision (``decide(..., preempted=True)`` forces adaptive
   replans, replaying orphaned streams), and reconcile the cluster to the
   new plan — missing instances boot with a delay, surplus ones drain;
4. advance the spot market's price walk and schedule the preemptions it
   draws for the coming interval.

The loop runs in one of two modes with bit-identical ledgers:

* **object** — per-tick ``Stream`` lists and ``{stream_id: instance_id}``
  dicts, the historical path; always used when a ground-truth service or
  calibration caps frames (those are keyed per stream id).
* **columnar** — demand stays a :class:`~repro.sim.demand.StreamColumns`
  struct-of-arrays, placement is a per-stream instance-row array, and
  accounting is a handful of numpy passes. Chosen automatically when the
  demand model exposes ``columns_at`` and packed mode is on; this is the
  path that takes a 24 h × 1M-stream day from hours to minutes
  (benchmarks/columnar_sweep.py).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

from repro.core import packed as packed_mod
from repro.core.catalog import Catalog
from repro.sim import events as ev
from repro.sim.cluster import ONDEMAND, SPOT, Cluster, SpotMarket
from repro.sim.demand import DemandModel
from repro.sim.ledger import Ledger, ServiceCalibration, TickRecord


@dataclasses.dataclass(frozen=True)
class SimConfig:
    """Simulation knobs; every duration/rate is in simulated hours.

    ``spot_discount`` is the spot base price as a fraction of the on-demand
    $/hour price; ``preempt_hazard_per_h`` the per-instance reclaim hazard
    per simulated hour.
    """

    duration_h: float = 24.0
    dt_h: float = 1.0
    boot_delay_h: float = 0.05           # 3 minutes
    spot_fraction: float = 0.0           # fraction of boots on the spot market
    spot_discount: float = 0.35          # spot base price / on-demand price
    spot_volatility: float = 0.15
    preempt_hazard_per_h: float = 0.08
    seed: int = 0


class FleetSimulator:
    """Replay a demand model against an autoscaling policy (module doc above).

    ``run()`` returns the :class:`~repro.sim.ledger.Ledger`: per-tick $
    spent, frames demanded/analyzed/dropped (frames = frames/s x seconds),
    migrations and preemptions — the two axes (dollars, service) every
    policy is compared on.

    ``columnar`` pins the loop mode: True/False force it, None (default)
    picks columnar when the demand model supports it (see module doc).
    """

    def __init__(self, demand: DemandModel, policy, catalog: Catalog,
                 config: SimConfig = SimConfig(),
                 calibration: Optional[ServiceCalibration] = None,
                 service=None, telemetry=None,
                 columnar: Optional[bool] = None) -> None:
        self.demand = demand
        self.policy = policy
        self.config = config
        self.calibration = calibration
        self.columnar = columnar
        # ``service`` is the *ground truth* serving capacity
        # (obs.DriftingService): when set, it caps analyzed frames instead of
        # the policy's believed calibration — the truth-vs-belief split that
        # lets a stale calibration overpay without over-serving.
        self.service = service
        # ``telemetry`` (obs.TelemetryHub) receives streaming per-tick metric
        # points from the event loop; None = zero overhead.
        self.telemetry = telemetry
        self.cluster = Cluster(boot_delay_h=config.boot_delay_h,
                               spot_fraction=config.spot_fraction,
                               seed=config.seed + 1,
                               telemetry=telemetry)
        self.market = SpotMarket(catalog.locations,
                                 discount=config.spot_discount,
                                 volatility=config.spot_volatility,
                                 hazard_per_h=config.preempt_hazard_per_h,
                                 seed=config.seed + 2)
        self.ledger = Ledger()
        # pipeline demand models (sim.demand.PipelineFleet) emit per-stage
        # items; the ledger then carries stage/pooled-chunk columns
        self._emits_stages = bool(getattr(demand, "emits_stages", False))
        self._pipe_counts: Optional[tuple] = None   # id-list-keyed cache
        # bidding policies observe the market (prices are exogenous: the
        # walk never depends on what any policy rents or bids) and the
        # control-loop timing their preemption-penalty models price against
        attach = getattr(policy, "attach_market", None)
        if attach is not None:
            attach(self.market, config.dt_h, config.boot_delay_h)

    def _tick_times(self) -> list[float]:
        """Decision boundaries ``k * dt`` strictly inside the horizon.

        Generated by accumulation, not ``round(duration / dt)``: a
        non-divisible horizon (2.5 h at dt=1.0) keeps its genuine final
        interval — demand is re-read at the last whole tick and the tail
        [2.0, 2.5) is accounted at END — instead of banker's-rounding the
        tail away."""
        cfg = self.config
        out: list[float] = []
        k = 0
        while True:
            t = k * cfg.dt_h
            if t >= cfg.duration_h - 1e-9:
                break
            out.append(t)
            k += 1
        return out

    def run(self) -> Ledger:
        use_columnar = self.columnar
        if use_columnar is None:
            use_columnar = (packed_mod.enabled()
                            and hasattr(self.demand, "columns_at")
                            and self.service is None
                            and self.calibration is None)
        if use_columnar:
            return self._run_columnar()
        return self._run_object()

    # -- shared event-batch plumbing ----------------------------------------
    #
    # Preemption/outbid events land mid-interval. The historical loop kept
    # them in a heap and popped one at a time; here each boundary drains its
    # batch in (time, push-order) — the exact heap pop order — through
    # Cluster.terminate_batch. An event timed exactly *at* a boundary is
    # applied at the next one, which is precisely when the old heap popped
    # it (ticks were pushed first, so at equal times the tick went first).

    @staticmethod
    def _due(pending: list, t: float) -> tuple[list, list]:
        due = sorted(e for e in pending if e[0] < t)
        if due:
            pending = [e for e in pending if not (e[0] < t)]
        return due, pending

    def _apply_batch(self, due: list) -> tuple[int, int]:
        """Apply one boundary's event batch; return (#applied, #outbids)."""
        applied = self.cluster.terminate_batch(
            (when, iid, kind) for (when, _seq, kind, iid) in due)
        outbids = sum(1 for kind in applied if kind == ev.OUTBID)
        return len(applied), outbids

    def _schedule_market(self, t: float, pending: list, seq: int) -> int:
        """Advance the price walk; push the coming interval's reclaims."""
        cfg = self.config
        self.market.step(cfg.dt_h)
        if cfg.spot_fraction > 0:
            for when, iid in self.market.draw_preemptions(
                    t, cfg.dt_h, self.cluster.live_spot()):
                pending.append((when, seq, ev.PREEMPT, iid))
                seq += 1
        # deterministic bid-based reclaims: the walk just set the price
        # for [t, t + dt); every bid now underwater is reclaimed when
        # the price path crosses it mid-interval. Consumes no RNG, so
        # legacy hazard draws and the walk stay policy-independent.
        for iid in self.market.outbid(self.cluster.live_spot()):
            pending.append((t + 0.5 * cfg.dt_h, seq, ev.OUTBID, iid))
            seq += 1
        return seq

    def _policy_interval_stats(self, adaptive, events_seen: int
                               ) -> tuple[int, int, int, float, int, float]:
        """(events_seen', defrags, recals, calib_err, preboots, fcast_err)
        after a decide()."""
        defrags = recals = 0
        if adaptive is not None:
            new_events = adaptive.events[events_seen:]
            events_seen = len(adaptive.events)
            defrags = sum(1 for e in new_events
                          if getattr(e, "defrag", False))
            recals = sum(1 for e in new_events
                         if getattr(e, "recalibration", False))
        # drift-aware policies publish the verdict of the probe they
        # just took; the ledger gets the calibration error column
        verdict = getattr(self.policy, "last_drift", None)
        calib_err = verdict.rel_error if verdict is not None else 0.0
        # forecast-driven policies (sim/mpc.py) publish how many items they
        # planned above current demand and the realized error of the
        # forecast the outgoing plan rode on; plain policies leave both 0
        preboots = int(getattr(self.policy, "last_preboot", 0) or 0)
        fcast_err = float(getattr(self.policy, "last_forecast_error", 0.0)
                          or 0.0)
        return events_seen, defrags, recals, calib_err, preboots, fcast_err

    # -- object-path loop ---------------------------------------------------

    def _run_object(self) -> Ledger:
        cfg = self.config
        ticks = self._tick_times()

        current_streams = []                 # demand in force this interval
        assignment: dict[str, str] = {}      # stream_id -> instance_id
        prev_assignment: dict[str, str] = {}
        prev_fps: dict[str, float] = {}
        prev_t = 0.0
        preempted_since_decide = 0
        preemptions_this_interval = 0
        migrations_this_interval = 0
        defrags_this_interval = 0
        calib_err_this_interval = 0.0
        recals_this_interval = 0
        outbids_this_interval = 0
        preboots_this_interval = 0
        fcast_err_this_interval = 0.0
        # adaptive policies expose their decision trace; the ledger records
        # when the repair planner's defrag escape hatch fired
        adaptive = getattr(self.policy, "adaptive", None)
        events_seen = 0
        pending: list = []                   # (when, seq, kind, instance_id)
        seq = 0

        for t in ticks + [cfg.duration_h]:
            due, pending = self._due(pending, t)
            if due:
                n_applied, n_outbids = self._apply_batch(due)
                preempted_since_decide += n_applied
                preemptions_this_interval += n_applied
                outbids_this_interval += n_outbids
            if t > prev_t:
                self._account(prev_t, t, current_streams, assignment,
                              prev_assignment, prev_fps,
                              preemptions_this_interval,
                              migrations_this_interval,
                              defrags_this_interval,
                              outbids_this_interval,
                              calib_err_this_interval,
                              recals_this_interval,
                              preboots_this_interval,
                              fcast_err_this_interval)
                preemptions_this_interval = 0
                outbids_this_interval = 0
                # rows terminated before the interval just billed can never
                # be billed, matched, or credited again — seal them off so
                # per-tick work tracks the live fleet, not every boot ever
                self.cluster.retire(prev_t)
                prev_t = t
            if t >= cfg.duration_h - 1e-9:
                break

            prev_assignment = assignment
            prev_fps = {s.stream_id: s.fps for s in current_streams}
            current_streams = self.demand.streams_at(t)
            plan = self.policy.decide(t, current_streams,
                                      preempted=preempted_since_decide > 0)
            preempted_since_decide = 0
            (events_seen, defrags_this_interval, recals_this_interval,
             calib_err_this_interval, preboots_this_interval,
             fcast_err_this_interval) = self._policy_interval_stats(
                adaptive, events_seen)
            assignment = self.cluster.reconcile(
                t, plan, drain_h=cfg.boot_delay_h,
                bids=getattr(self.policy, "bids", None))
            # physical migrations: streams whose instance changed, including
            # preemption replays that a plan-level diff cannot see (the new
            # plan may be structurally identical while the orphaned streams
            # land on freshly booted replacements). A stream with no previous
            # instance is an arrival — its first placement is a boot, not a
            # migration.
            migrations_this_interval = sum(
                1 for sid, iid in assignment.items()
                if sid in prev_assignment and prev_assignment[sid] != iid)

            seq = self._schedule_market(t, pending, seq)
        return self.ledger

    # -- columnar loop ------------------------------------------------------

    def _run_columnar(self) -> Ledger:
        cfg = self.config
        ticks = self._tick_times()
        cluster = self.cluster

        cur = None                            # StreamColumns in force
        cur_rows: Optional[np.ndarray] = None  # per-stream instance row
        pprev_ids = None                      # the decision before that
        pprev_rows: Optional[np.ndarray] = None
        pprev_fps: Optional[np.ndarray] = None
        prev_t = 0.0
        preempted_since_decide = 0
        preemptions_this_interval = 0
        migrations_this_interval = 0
        defrags_this_interval = 0
        calib_err_this_interval = 0.0
        recals_this_interval = 0
        outbids_this_interval = 0
        preboots_this_interval = 0
        fcast_err_this_interval = 0.0
        adaptive = getattr(self.policy, "adaptive", None)
        events_seen = 0
        pending: list = []
        seq = 0

        for t in ticks + [cfg.duration_h]:
            due, pending = self._due(pending, t)
            if due:
                n_applied, n_outbids = self._apply_batch(due)
                preempted_since_decide += n_applied
                preemptions_this_interval += n_applied
                outbids_this_interval += n_outbids
            if t > prev_t:
                self._account_cols(prev_t, t, cur, cur_rows,
                                   pprev_ids, pprev_rows, pprev_fps,
                                   preemptions_this_interval,
                                   migrations_this_interval,
                                   defrags_this_interval,
                                   outbids_this_interval,
                                   calib_err_this_interval,
                                   recals_this_interval,
                                   preboots_this_interval,
                                   fcast_err_this_interval)
                preemptions_this_interval = 0
                outbids_this_interval = 0
                # retire remaps cluster._prev_cols (our cur_rows array) in
                # place; pprev_rows is a different array, remapped here —
                # though rows it can reference are never old enough to drop
                remap = cluster.retire(prev_t)
                if remap is not None and pprev_rows is not None \
                        and pprev_rows is not cur_rows:
                    pprev_rows[:] = np.where(
                        pprev_rows >= 0,
                        remap[np.maximum(pprev_rows, 0)], -1)
                prev_t = t
            if t >= cfg.duration_h - 1e-9:
                break

            pprev_ids = cur.ids if cur is not None else None
            pprev_rows = cur_rows
            pprev_fps = cur.fps if cur is not None else None
            cur = self.demand.columns_at(t)
            plan = self.policy.decide(t, cur,
                                      preempted=preempted_since_decide > 0)
            preempted_since_decide = 0
            (events_seen, defrags_this_interval, recals_this_interval,
             calib_err_this_interval, preboots_this_interval,
             fcast_err_this_interval) = self._policy_interval_stats(
                adaptive, events_seen)
            cur_rows = cluster.reconcile_rows(
                t, plan, cur.ids, drain_h=cfg.boot_delay_h,
                bids=getattr(self.policy, "bids", None))
            prow = self._aligned_prev_rows(cur.ids, pprev_ids, pprev_rows)
            if prow is None:
                migrations_this_interval = 0
            else:
                migrations_this_interval = int(np.count_nonzero(
                    (cur_rows >= 0) & (prow >= 0) & (cur_rows != prow)))

            seq = self._schedule_market(t, pending, seq)
        return self.ledger

    def _aligned_prev_rows(self, ids, pids, prows) -> Optional[np.ndarray]:
        """Previous-decision instance rows re-aligned to stream id list
        ``ids`` (-1 = stream had no previous placement). Identity of the
        id list is the fast path — stable fleets reuse one list forever."""
        if prows is None or pids is None:
            return None
        if pids is ids:
            return prows
        index = {sid: k for k, sid in enumerate(pids)}
        out = np.full(len(ids), -1, dtype=np.int64)
        pl = prows.tolist()
        for k, sid in enumerate(ids):
            j = index.get(sid)
            if j is not None:
                out[k] = pl[j]
        return out

    def _aligned_prev_fps(self, ids, pids, pfps) -> Optional[np.ndarray]:
        if pfps is None or pids is None:
            return None
        if pids is ids:
            return pfps
        index = {sid: k for k, sid in enumerate(pids)}
        out = np.zeros(len(ids))
        pl = pfps.tolist()
        for k, sid in enumerate(ids):
            j = index.get(sid)
            if j is not None:
                out[k] = pl[j]
        return out

    # -- accounting ---------------------------------------------------------

    def _pipeline_counts(self, ids) -> tuple[int, int]:
        """(stage items, pooled chunks) among the demanded ids, following
        the id grammar of ``sim.demand.PipelineFleet`` (``sid::stage`` /
        ``pool::...#k``). Cached per id-list object — the columnar path
        reuses one list while the pool split is stable."""
        cached = self._pipe_counts
        if cached is not None and cached[0] is ids:
            return cached[1]
        stage = pooled = 0
        for sid in ids:
            if "::" in sid:
                stage += 1
                if sid.startswith("pool::"):
                    pooled += 1
        val = (stage, pooled)
        self._pipe_counts = (ids, val)
        return val

    def _account(self, t0: float, t1: float, streams, assignment,
                 prev_assignment, prev_fps, preemptions: int,
                 migrations: int, defrags: int = 0,
                 outbids: int = 0, calib_err: float = 0.0,
                 recals: int = 0, preboots: int = 0,
                 fcast_err: float = 0.0) -> None:
        """Frames and dollars for [t0, t1).

        While a stream's planned instance is still booting, its *previous*
        placement — kept alive by the reconcile drain window — continues to
        serve, but only up to the rate it was planned for (make-before-break
        migration: a scale-up drops only the incremental demand during the
        boot, unless the old instance was preempted away). The credit only
        applies when the old instance is *actually* draining — an instance
        the new plan reuses for other streams has no spare capacity to lend.
        """
        dt_s = (t1 - t0) * 3600.0           # frame counts are fps x seconds
        busy = set(assignment.values())     # instances serving the new plan
        demanded = analyzed = 0.0
        for s in streams:
            d = s.fps * dt_s
            demanded += d
            iid = assignment.get(s.stream_id)
            frac = (self.cluster.instances[iid].running_fraction(t0, t1)
                    if iid is not None else 0.0)
            a = d * frac
            old = prev_assignment.get(s.stream_id)
            if old is not None and old != iid and old not in busy:
                old_rate = min(s.fps, prev_fps.get(s.stream_id, 0.0))
                a = max(a, old_rate * dt_s
                        * self.cluster.instances[old].running_fraction(t0, t1))
            a = min(a, d)
            if self.service is not None:
                # ground truth caps what gets served, independent of what any
                # calibration *believes* — a stale belief overpays for
                # capacity the service cannot use, it never over-serves
                a = min(a, self.service.frame_rate_cap(s.stream_id, t0) * dt_s)
            elif self.calibration is not None:
                a = min(a, self.calibration.frame_rate_cap(s.stream_id) * dt_s)
            analyzed += a
        stage_n = pooled_n = 0
        if self._emits_stages:
            stage_n, pooled_n = self._pipeline_counts(
                [s.stream_id for s in streams])
        self._close_tick(t0, t1, len(streams), demanded, analyzed,
                         preemptions, migrations, defrags, outbids,
                         calib_err, recals, stage_n, pooled_n,
                         preboots, fcast_err)

    def _account_cols(self, t0: float, t1: float, cols, rows,
                      pids, prows, pfps, preemptions: int, migrations: int,
                      defrags: int, outbids: int, calib_err: float,
                      recals: int, preboots: int = 0,
                      fcast_err: float = 0.0) -> None:
        """Columnar twin of :meth:`_account`: the same per-stream float
        expressions as array ops, summed in stream order (cumsum) so the
        totals are bit-identical to the scalar loop."""
        if cols is None or len(cols) == 0:
            self._close_tick(t0, t1, 0, 0.0, 0.0, preemptions, migrations,
                             defrags, outbids, calib_err, recals,
                             preboots=preboots, fcast_err=fcast_err)
            return
        dt_s = (t1 - t0) * 3600.0
        c = self.cluster
        fps = cols.fps
        d = fps * dt_s
        has = rows >= 0
        r = np.maximum(rows, 0)
        ready = c._ready[r]
        term = c._term[r]
        span = t1 - t0
        frac = np.maximum(0.0, np.minimum(t1, term)
                          - np.maximum(t0, ready)) / span
        a = d * np.where(has, frac, 0.0)

        prow = self._aligned_prev_rows(cols.ids, pids, prows)
        if prow is not None:
            busy = np.zeros(c._n, dtype=bool)
            busy[rows[has]] = True
            pr = np.maximum(prow, 0)
            credit_mask = (prow >= 0) & (prow != rows) & ~busy[pr]
            if credit_mask.any():
                pready = c._ready[pr]
                pterm = c._term[pr]
                pfrac = np.maximum(0.0, np.minimum(t1, pterm)
                                   - np.maximum(t0, pready)) / span
                old_rate = np.minimum(
                    fps, self._aligned_prev_fps(cols.ids, pids, pfps))
                a = np.where(credit_mask,
                             np.maximum(a, old_rate * dt_s * pfrac), a)
        a = np.minimum(a, d)
        demanded = float(np.cumsum(d)[-1])
        analyzed = float(np.cumsum(a)[-1])
        stage_n = pooled_n = 0
        if self._emits_stages:
            stage_n, pooled_n = self._pipeline_counts(cols.ids)
        self._close_tick(t0, t1, len(cols), demanded, analyzed, preemptions,
                         migrations, defrags, outbids, calib_err, recals,
                         stage_n, pooled_n, preboots, fcast_err)

    def _close_tick(self, t0: float, t1: float, n_streams: int,
                    demanded: float, analyzed: float, preemptions: int,
                    migrations: int, defrags: int, outbids: int,
                    calib_err: float, recals: int,
                    stage_items: int = 0, pooled_items: int = 0,
                    preboots: int = 0, fcast_err: float = 0.0) -> None:
        cost, hours, by_market = self.cluster.accrue(t0, t1, self.market)
        live = self.cluster.live_count()
        self.ledger.add_tick(TickRecord(
            t=t0, cost=cost, frames_demanded=demanded,
            frames_analyzed=analyzed, frames_dropped=demanded - analyzed,
            migrations=migrations, preemptions=preemptions,
            instances_live=live, streams=n_streams,
            defrags=defrags,
            cost_ondemand=by_market.get(ONDEMAND, 0.0),
            cost_spot=by_market.get(SPOT, 0.0),
            outbids=outbids,
            calib_rel_error=calib_err,
            recalibrations=recals,
            stage_items=stage_items,
            pooled_items=pooled_items,
            preboots=preboots,
            forecast_rel_error=fcast_err,
        ), hours)
        if self.telemetry is not None:
            emit = self.telemetry.emit
            emit(t0, "fleet.cost.usd", cost)
            emit(t0, "fleet.frames.demanded", demanded)
            emit(t0, "fleet.frames.analyzed", analyzed)
            emit(t0, "fleet.frames.dropped", demanded - analyzed)
            emit(t0, "fleet.slo",
                 (analyzed / demanded) if demanded > 0 else 1.0)
            emit(t0, "fleet.instances.live", float(live))
            emit(t0, "fleet.migrations", float(migrations))
            emit(t0, "fleet.preemptions", float(preemptions))
            emit(t0, "fleet.calib.rel_error", calib_err)
            if recals:
                emit(t0, "fleet.recalibrations", float(recals))
            if stage_items:
                emit(t0, "fleet.stage_items", float(stage_items))
                emit(t0, "fleet.pooled_items", float(pooled_items))
            if preboots:
                emit(t0, "fleet.preboots", float(preboots))
            if fcast_err:
                emit(t0, "fleet.forecast.rel_error", fcast_err)
