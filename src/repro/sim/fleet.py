"""The fleet simulator: demand → policy → cluster → ledger, in event order.

One :class:`FleetSimulator` run replays a demand model against an
autoscaling policy over simulated days. The event queue interleaves
control-loop ticks with spot preemptions; every demanded frame ends the run
either analyzed or dropped (never silently lost), and every instance-hour is
billed — so policies are comparable on exactly the two axes the paper cares
about: dollars and service.

Per tick ``t`` (all times in simulated hours):

1. account the interval that just ended, using the demand and stream→instance
   assignment that were in force (preemptions that fired mid-interval have
   already truncated their instances' service windows);
2. read the demand model, tell the policy whether a preemption hit since its
   last decision (``decide(..., preempted=True)`` forces adaptive replans,
   replaying orphaned streams), and reconcile the cluster to the new plan —
   missing instances boot with a delay, surplus ones terminate;
3. advance the spot market's price walk and schedule the preemptions it
   draws for the coming interval.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

from repro.core.catalog import Catalog
from repro.sim import events as ev
from repro.sim.cluster import ONDEMAND, SPOT, Cluster, SpotMarket
from repro.sim.demand import DemandModel
from repro.sim.ledger import Ledger, ServiceCalibration, TickRecord


@dataclasses.dataclass(frozen=True)
class SimConfig:
    """Simulation knobs; every duration/rate is in simulated hours.

    ``spot_discount`` is the spot base price as a fraction of the on-demand
    $/hour price; ``preempt_hazard_per_h`` the per-instance reclaim hazard
    per simulated hour.
    """

    duration_h: float = 24.0
    dt_h: float = 1.0
    boot_delay_h: float = 0.05           # 3 minutes
    spot_fraction: float = 0.0           # fraction of boots on the spot market
    spot_discount: float = 0.35          # spot base price / on-demand price
    spot_volatility: float = 0.15
    preempt_hazard_per_h: float = 0.08
    seed: int = 0


class FleetSimulator:
    """Replay a demand model against an autoscaling policy (module doc above).

    ``run()`` returns the :class:`~repro.sim.ledger.Ledger`: per-tick $
    spent, frames demanded/analyzed/dropped (frames = frames/s x seconds),
    migrations and preemptions — the two axes (dollars, service) every
    policy is compared on.
    """

    def __init__(self, demand: DemandModel, policy, catalog: Catalog,
                 config: SimConfig = SimConfig(),
                 calibration: Optional[ServiceCalibration] = None,
                 service=None, telemetry=None) -> None:
        self.demand = demand
        self.policy = policy
        self.config = config
        self.calibration = calibration
        # ``service`` is the *ground truth* serving capacity
        # (obs.DriftingService): when set, it caps analyzed frames instead of
        # the policy's believed calibration — the truth-vs-belief split that
        # lets a stale calibration overpay without over-serving.
        self.service = service
        # ``telemetry`` (obs.TelemetryHub) receives streaming per-tick metric
        # points from the event loop; None = zero overhead.
        self.telemetry = telemetry
        self.cluster = Cluster(boot_delay_h=config.boot_delay_h,
                               spot_fraction=config.spot_fraction,
                               seed=config.seed + 1,
                               telemetry=telemetry)
        self.market = SpotMarket(catalog.locations,
                                 discount=config.spot_discount,
                                 volatility=config.spot_volatility,
                                 hazard_per_h=config.preempt_hazard_per_h,
                                 seed=config.seed + 2)
        self.ledger = Ledger()
        # bidding policies observe the market (prices are exogenous: the
        # walk never depends on what any policy rents or bids) and the
        # control-loop timing their preemption-penalty models price against
        attach = getattr(policy, "attach_market", None)
        if attach is not None:
            attach(self.market, config.dt_h, config.boot_delay_h)

    def run(self) -> Ledger:
        cfg = self.config
        q = ev.EventQueue()
        n_ticks = int(round(cfg.duration_h / cfg.dt_h))
        for k in range(n_ticks):
            q.push(k * cfg.dt_h, ev.TICK)
        q.push(cfg.duration_h, ev.END)

        current_streams = []                 # demand in force this interval
        assignment: dict[str, str] = {}      # stream_id -> instance_id
        prev_assignment: dict[str, str] = {}
        prev_fps: dict[str, float] = {}
        prev_t = 0.0
        preempted_since_decide = 0
        preemptions_this_interval = 0
        migrations_this_interval = 0
        defrags_this_interval = 0
        calib_err_this_interval = 0.0
        recals_this_interval = 0
        # adaptive policies expose their decision trace; the ledger records
        # when the repair planner's defrag escape hatch fired
        adaptive = getattr(self.policy, "adaptive", None)
        events_seen = 0

        outbids_this_interval = 0

        while q:
            e = q.pop()
            if e.kind in (ev.PREEMPT, ev.OUTBID):
                inst = self.cluster.instances.get(e.payload)
                if inst is not None and (inst.terminated_t is None
                                         or inst.terminated_t > e.time):
                    self.cluster.terminate(inst.instance_id, e.time,
                                           preempted=True)
                    preempted_since_decide += 1
                    preemptions_this_interval += 1
                    if e.kind == ev.OUTBID:
                        outbids_this_interval += 1
                continue
            if e.kind not in (ev.TICK, ev.END):
                continue

            t = e.time
            if t > prev_t:
                self._account(prev_t, t, current_streams, assignment,
                              prev_assignment, prev_fps,
                              preemptions_this_interval,
                              migrations_this_interval,
                              defrags_this_interval,
                              outbids_this_interval,
                              calib_err_this_interval,
                              recals_this_interval)
                preemptions_this_interval = 0
                outbids_this_interval = 0
                prev_t = t
            if e.kind == ev.END:
                break

            prev_assignment = assignment
            prev_fps = {s.stream_id: s.fps for s in current_streams}
            current_streams = self.demand.streams_at(t)
            plan = self.policy.decide(t, current_streams,
                                      preempted=preempted_since_decide > 0)
            preempted_since_decide = 0
            if adaptive is not None:
                new_events = adaptive.events[events_seen:]
                events_seen = len(adaptive.events)
                defrags_this_interval = sum(
                    1 for e in new_events if getattr(e, "defrag", False))
                recals_this_interval = sum(
                    1 for e in new_events
                    if getattr(e, "recalibration", False))
            else:
                defrags_this_interval = 0
                recals_this_interval = 0
            # drift-aware policies publish the verdict of the probe they
            # just took; the ledger gets the calibration error column
            verdict = getattr(self.policy, "last_drift", None)
            calib_err_this_interval = (verdict.rel_error
                                       if verdict is not None else 0.0)
            assignment = self.cluster.reconcile(
                t, plan, drain_h=cfg.boot_delay_h,
                bids=getattr(self.policy, "bids", None))
            # physical migrations: streams whose instance changed, including
            # preemption replays that a plan-level diff cannot see (the new
            # plan may be structurally identical while the orphaned streams
            # land on freshly booted replacements). A stream with no previous
            # instance is an arrival — its first placement is a boot, not a
            # migration.
            migrations_this_interval = sum(
                1 for sid, iid in assignment.items()
                if sid in prev_assignment and prev_assignment[sid] != iid)

            self.market.step(cfg.dt_h)
            if cfg.spot_fraction > 0:
                for when, iid in self.market.draw_preemptions(
                        t, cfg.dt_h, self.cluster.live_spot()):
                    q.push(when, ev.PREEMPT, iid)
            # deterministic bid-based reclaims: the walk just set the price
            # for [t, t + dt); every bid now underwater is reclaimed when
            # the price path crosses it mid-interval. Consumes no RNG, so
            # legacy hazard draws and the walk stay policy-independent.
            for iid in self.market.outbid(self.cluster.live_spot()):
                q.push(t + 0.5 * cfg.dt_h, ev.OUTBID, iid)
        return self.ledger

    def _account(self, t0: float, t1: float, streams, assignment,
                 prev_assignment, prev_fps, preemptions: int,
                 migrations: int, defrags: int = 0,
                 outbids: int = 0, calib_err: float = 0.0,
                 recals: int = 0) -> None:
        """Frames and dollars for [t0, t1).

        While a stream's planned instance is still booting, its *previous*
        placement — kept alive by the reconcile drain window — continues to
        serve, but only up to the rate it was planned for (make-before-break
        migration: a scale-up drops only the incremental demand during the
        boot, unless the old instance was preempted away). The credit only
        applies when the old instance is *actually* draining — an instance
        the new plan reuses for other streams has no spare capacity to lend.
        """
        dt_s = (t1 - t0) * 3600.0           # frame counts are fps x seconds
        busy = set(assignment.values())     # instances serving the new plan
        demanded = analyzed = 0.0
        for s in streams:
            d = s.fps * dt_s
            demanded += d
            iid = assignment.get(s.stream_id)
            frac = (self.cluster.instances[iid].running_fraction(t0, t1)
                    if iid is not None else 0.0)
            a = d * frac
            old = prev_assignment.get(s.stream_id)
            if old is not None and old != iid and old not in busy:
                old_rate = min(s.fps, prev_fps.get(s.stream_id, 0.0))
                a = max(a, old_rate * dt_s
                        * self.cluster.instances[old].running_fraction(t0, t1))
            a = min(a, d)
            if self.service is not None:
                # ground truth caps what gets served, independent of what any
                # calibration *believes* — a stale belief overpays for
                # capacity the service cannot use, it never over-serves
                a = min(a, self.service.frame_rate_cap(s.stream_id, t0) * dt_s)
            elif self.calibration is not None:
                a = min(a, self.calibration.frame_rate_cap(s.stream_id) * dt_s)
            analyzed += a
        cost, hours, by_market = self.cluster.accrue(t0, t1, self.market)
        live = len(self.cluster.live())
        self.ledger.add_tick(TickRecord(
            t=t0, cost=cost, frames_demanded=demanded,
            frames_analyzed=analyzed, frames_dropped=demanded - analyzed,
            migrations=migrations, preemptions=preemptions,
            instances_live=live, streams=len(streams),
            defrags=defrags,
            cost_ondemand=by_market.get(ONDEMAND, 0.0),
            cost_spot=by_market.get(SPOT, 0.0),
            outbids=outbids,
            calib_rel_error=calib_err,
            recalibrations=recals,
        ), hours)
        if self.telemetry is not None:
            emit = self.telemetry.emit
            emit(t0, "fleet.cost.usd", cost)
            emit(t0, "fleet.frames.demanded", demanded)
            emit(t0, "fleet.frames.analyzed", analyzed)
            emit(t0, "fleet.frames.dropped", demanded - analyzed)
            emit(t0, "fleet.slo",
                 (analyzed / demanded) if demanded > 0 else 1.0)
            emit(t0, "fleet.instances.live", float(live))
            emit(t0, "fleet.migrations", float(migrations))
            emit(t0, "fleet.preemptions", float(preemptions))
            emit(t0, "fleet.calib.rel_error", calib_err)
            if recals:
                emit(t0, "fleet.recalibrations", float(recals))
