from repro.serving.engine import Request, ServingEngine, StreamSimulator

__all__ = ["Request", "ServingEngine", "StreamSimulator"]
