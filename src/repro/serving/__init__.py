from repro.serving.engine import (ContinuousBatchingEngine, Request,
                                  ServingEngine, StreamSimulator)

__all__ = ["ContinuousBatchingEngine", "Request", "ServingEngine",
           "StreamSimulator"]
