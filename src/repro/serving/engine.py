"""Batched serving engine + camera-stream simulator.

The paper's workload is "analysis program x camera stream at a frame rate".
The modern analogue served here: each camera frame becomes one fixed-size
inference request (frame caption / detection readout from a VLM-style
decoder); a stream at f fps enqueues f requests per second. The engine runs
static batching: prefill a batch of equal-length prompts, then decode all of
them in lock-step (fixed-size requests make frame workloads perfectly
batchable — see DESIGN.md).

The measured tokens/sec feeds core/tpu_catalog.py, which runs the paper's
packing machinery over TPU slice types instead of EC2 instances.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import model as M
from repro.models.config import ArchConfig
from repro.models.steps import make_jitted_decode, make_jitted_prefill


@dataclasses.dataclass
class Request:
    request_id: str
    tokens: np.ndarray                 # (prompt_len,) int32
    max_new_tokens: int = 16
    stream_id: Optional[str] = None
    enqueue_t: float = 0.0
    output: Optional[np.ndarray] = None
    finish_t: float = 0.0


class ServingEngine:
    """Static-batching engine for equal-length frame requests."""

    def __init__(self, cfg: ArchConfig, params, *, max_batch: int = 8,
                 cache_len: int = 512, opts: Optional[M.ModelOptions] = None):
        self.cfg = cfg
        self.params = params
        self.max_batch = max_batch
        self.cache_len = cache_len
        self.opts = opts or M.ModelOptions(remat=False)
        self.queue: list[Request] = []
        self._prefill = make_jitted_prefill(cfg, self.opts, cache_len)
        self._decode = make_jitted_decode(cfg, self.opts)
        self.stats = {"requests": 0, "tokens_generated": 0, "batches": 0,
                      "decode_steps": 0, "wall_s": 0.0}

    def submit(self, req: Request) -> None:
        req.enqueue_t = time.monotonic()
        self.queue.append(req)

    def _pad_batch(self, reqs: Sequence[Request]) -> jnp.ndarray:
        L = max(len(r.tokens) for r in reqs)
        assert all(len(r.tokens) == L for r in reqs), \
            "static batching requires equal-length frame requests"
        toks = np.stack([r.tokens for r in reqs])
        return jnp.asarray(toks, jnp.int32)

    def step(self) -> list[Request]:
        """Serve one batch from the queue; returns completed requests."""
        if not self.queue:
            return []
        batch_reqs = self.queue[: self.max_batch]
        self.queue = self.queue[len(batch_reqs):]
        t0 = time.monotonic()

        tokens = self._pad_batch(batch_reqs)
        B, L = tokens.shape
        logits, cache = self._prefill(self.params, {"tokens": tokens})
        max_new = max(r.max_new_tokens for r in batch_reqs)
        outs = np.zeros((B, max_new), np.int32)
        tok = jnp.argmax(logits, -1).astype(jnp.int32)
        for i in range(max_new):
            outs[:, i] = np.asarray(tok)
            logits, cache = self._decode(self.params, cache,
                                         {"token": tok,
                                          "pos": jnp.asarray(L + i, jnp.int32)})
            tok = jnp.argmax(logits, -1).astype(jnp.int32)
            self.stats["decode_steps"] += 1

        wall = time.monotonic() - t0
        self.stats["wall_s"] += wall
        self.stats["batches"] += 1
        for b, r in enumerate(batch_reqs):
            r.output = outs[b, : r.max_new_tokens]
            r.finish_t = time.monotonic()
            self.stats["requests"] += 1
            self.stats["tokens_generated"] += r.max_new_tokens
        return list(batch_reqs)

    def drain(self) -> list[Request]:
        done: list[Request] = []
        while self.queue:
            done.extend(self.step())
        return done

    def throughput_tokens_per_s(self) -> float:
        if self.stats["wall_s"] == 0:
            return 0.0
        return self.stats["tokens_generated"] / self.stats["wall_s"]


class StreamSimulator:
    """Camera streams enqueueing fixed-size frame requests at a frame rate."""

    def __init__(self, engine: ServingEngine, prompt_len: int = 32,
                 new_tokens: int = 8, vocab: Optional[int] = None,
                 seed: int = 0):
        self.engine = engine
        self.prompt_len = prompt_len
        self.new_tokens = new_tokens
        self.vocab = vocab or engine.cfg.vocab_size
        self.rng = np.random.default_rng(seed)
        self.frame_count = 0
        self._accum: dict[str, float] = {}

    def tick(self, streams_fps: dict[str, float], dt_s: float = 1.0) -> int:
        """Enqueue dt_s worth of frames for each stream at its fps.
        Fractional frames accumulate across ticks (a 0.25 fps camera emits
        one frame every 4 seconds)."""
        n = 0
        for sid, fps in streams_fps.items():
            acc = self._accum.get(sid, 0.0) + fps * dt_s
            frames = int(acc)
            self._accum[sid] = acc - frames
            for _ in range(frames):
                toks = self.rng.integers(
                    0, self.vocab, self.prompt_len).astype(np.int32)
                self.engine.submit(Request(
                    request_id=f"{sid}-f{self.frame_count}",
                    tokens=toks, max_new_tokens=self.new_tokens,
                    stream_id=sid))
                self.frame_count += 1
                n += 1
        return n
