"""Serving engines + camera-stream simulator.

The paper's workload is "analysis program x camera stream at a frame rate".
The modern analogue served here: each camera frame becomes one fixed-size
inference request (frame caption / detection readout from a VLM-style
decoder); a stream at f fps enqueues f requests per second.

Two engines (see DESIGN.md for the design rationale):

* ``ServingEngine`` — static lock-step batching: prefill a batch of
  equal-length prompts, then decode all of them together; the batch stalls
  until its slowest request finishes.
* ``ContinuousBatchingEngine`` — a fixed pool of preallocated KV-cache
  slots; new requests are admitted into free slots mid-decode (single-slot
  prefill-into-cache, no re-prefill of the pool), finished requests free
  their slot immediately, and the queue is drained earliest-deadline-first
  using each stream's per-frame latency budget (1/fps).

The measured tokens/sec feeds core/tpu_catalog.py, which runs the paper's
packing machinery over TPU slice types instead of EC2 instances.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import model as M
from repro.models.config import ArchConfig
from repro.models.steps import (make_jitted_decode, make_jitted_prefill,
                                make_jitted_prefill_into_slot)


@dataclasses.dataclass
class Request:
    request_id: str
    tokens: np.ndarray                 # (prompt_len,) int32
    max_new_tokens: int = 16
    stream_id: Optional[str] = None
    enqueue_t: float = 0.0
    deadline_s: float = float("inf")   # per-frame latency budget (1/fps)
    output: Optional[np.ndarray] = None
    finish_t: float = 0.0

    @property
    def deadline_t(self) -> float:
        return self.enqueue_t + self.deadline_s

    @property
    def latency_s(self) -> float:
        return self.finish_t - self.enqueue_t


class _EngineStatsMixin:
    """Shared stats accounting (both engines keep a ``stats`` dict with a
    float ``wall_s`` and integer counters including ``tokens_generated``,
    plus per-stream token tallies and active windows behind
    ``measured_rates``/``windowed_rates``)."""

    def _init_stream_stats(self) -> None:
        self._stream_tokens: dict[str, int] = {}
        # per-stream active window [first_seen, last_seen] on the engine
        # clock (cumulative wall_s): a late joiner's window starts at the
        # step that first served it, an early leaver's ends at its last
        self._stream_window: dict[str, list[float]] = {}
        self._touched: set[str] = set()
        self._rate_snapshot: tuple[float, dict[str, int]] = (0.0, {})

    def reset_stats(self) -> None:
        """Zero the counters (e.g. after a jit warmup run)."""
        self.stats = {k: 0.0 if isinstance(v, float) else 0
                      for k, v in self.stats.items()}
        self._init_stream_stats()

    def throughput_tokens_per_s(self) -> float:
        if self.stats["wall_s"] == 0:
            return 0.0
        return self.stats["tokens_generated"] / self.stats["wall_s"]

    def _count_stream_token(self, req: Request, n: int = 1) -> None:
        key = req.stream_id or req.request_id
        self._stream_tokens[key] = self._stream_tokens.get(key, 0) + n
        self._touched.add(key)

    def _mark_windows(self, clock0: float, clock1: float) -> None:
        """Extend the active window of every stream served this step to
        cover [clock0, clock1] (engine-clock seconds)."""
        for key in self._touched:
            w = self._stream_window.get(key)
            if w is None:
                self._stream_window[key] = [clock0, clock1]
            elif clock1 > w[1]:
                w[1] = clock1
        self._touched.clear()

    def measured_rates(self) -> dict[str, float]:
        """Measured tokens/sec per stream over *that stream's* active window
        (first-seen to last-seen on the engine clock).

        This is the profiling export the paper's manager consumes: feed it to
        ``core.tpu_catalog.streams_from_measured`` (or ``streams_from_engine``)
        to build packing items from observed — not nominal — throughput, and
        to the fleet simulator's ``ServiceCalibration`` to bound how many
        frames a simulated instance can actually analyze.

        Per-stream windows matter: dividing by the engine's *total* wall time
        systematically under-measures streams that join late or leave early
        — a drift detector fed such rates chases phantom throughput drops.
        A stream whose window is empty (all tokens in one step on a clock
        that did not advance) falls back to the total wall time.
        """
        wall = self.stats["wall_s"]
        out: dict[str, float] = {}
        for sid, n in sorted(self._stream_tokens.items()):
            w = self._stream_window.get(sid)
            span = (w[1] - w[0]) if w is not None else 0.0
            if span <= 0.0:
                span = wall
            if span <= 0.0:
                continue
            out[sid] = n / span
        return out

    def windowed_rates(self) -> dict[str, float]:
        """Tokens/sec per stream since the *previous* call (poll-style
        window over the cumulative counters).

        This is the live telemetry export a drift detector should consume:
        lifetime averages (``measured_rates``) dilute a throughput
        regression across the whole history, while successive windows show
        it at full magnitude immediately. Streams with no tokens in the
        window are omitted (no data, not zero throughput)."""
        wall = self.stats["wall_s"]
        prev_wall, prev_tokens = self._rate_snapshot
        span = wall - prev_wall
        out: dict[str, float] = {}
        if span > 0:
            for sid, n in sorted(self._stream_tokens.items()):
                delta = n - prev_tokens.get(sid, 0)
                if delta > 0:
                    out[sid] = delta / span
        self._rate_snapshot = (wall, dict(self._stream_tokens))
        return out


class ServingEngine(_EngineStatsMixin):
    """Static-batching engine for equal-length frame requests."""

    def __init__(self, cfg: ArchConfig, params, *, max_batch: int = 8,
                 cache_len: int = 512, opts: Optional[M.ModelOptions] = None):
        self.cfg = cfg
        self.params = params
        self.max_batch = max_batch
        self.cache_len = cache_len
        self.opts = opts or M.ModelOptions(remat=False)
        self.queue: list[Request] = []
        self._prefill = make_jitted_prefill(cfg, self.opts, cache_len)
        self._decode = make_jitted_decode(cfg, self.opts)
        self._init_stream_stats()
        self.stats = {"requests": 0, "tokens_generated": 0, "batches": 0,
                      "decode_steps": 0, "wall_s": 0.0}

    def submit(self, req: Request) -> None:
        req.enqueue_t = time.monotonic()
        self.queue.append(req)

    def _pad_batch(self, reqs: Sequence[Request]) -> jnp.ndarray:
        L = max(len(r.tokens) for r in reqs)
        assert all(len(r.tokens) == L for r in reqs), \
            "static batching requires equal-length frame requests"
        toks = np.stack([r.tokens for r in reqs])
        return jnp.asarray(toks, jnp.int32)

    def step(self) -> list[Request]:
        """Serve one batch from the queue; returns completed requests."""
        if not self.queue:
            return []
        batch_reqs = self.queue[: self.max_batch]
        self.queue = self.queue[len(batch_reqs):]
        t0 = time.monotonic()
        clock0 = self.stats["wall_s"]

        tokens = self._pad_batch(batch_reqs)
        B, L = tokens.shape
        logits, cache = self._prefill(self.params, {"tokens": tokens})
        max_new = max(r.max_new_tokens for r in batch_reqs)
        outs = np.zeros((B, max_new), np.int32)
        tok = jnp.argmax(logits, -1).astype(jnp.int32)
        for i in range(max_new):
            outs[:, i] = np.asarray(tok)
            logits, cache = self._decode(self.params, cache,
                                         {"token": tok,
                                          "pos": jnp.asarray(L + i, jnp.int32)})
            tok = jnp.argmax(logits, -1).astype(jnp.int32)
            self.stats["decode_steps"] += 1

        wall = time.monotonic() - t0
        self.stats["wall_s"] += wall
        self.stats["batches"] += 1
        for b, r in enumerate(batch_reqs):
            r.output = outs[b, : r.max_new_tokens]
            r.finish_t = time.monotonic()
            self.stats["requests"] += 1
            self.stats["tokens_generated"] += r.max_new_tokens
            self._count_stream_token(r, r.max_new_tokens)
        self._mark_windows(clock0, self.stats["wall_s"])
        return list(batch_reqs)

    def drain(self) -> list[Request]:
        done: list[Request] = []
        while self.queue:
            done.extend(self.step())
        return done

class ContinuousBatchingEngine(_EngineStatsMixin):
    """Continuous batching over a fixed pool of preallocated KV-cache slots.

    Each of the ``max_slots`` rows of one batched cache (length ``cache_len``)
    is a slot. Per step: (1) admit queued requests into free slots in
    earliest-deadline-first order — each admission prefills that one request
    and inserts its KV/state into the slot (steps.prefill_into_slot_step),
    leaving the other slots' caches untouched; (2) run a single batched
    decode step with per-slot positions; (3) retire any request that reached
    its ``max_new_tokens``, freeing its slot for the next admission instead
    of stalling until the whole batch drains.

    Greedy decoding is identical to the static engine's: the prefill's
    last-position argmax is the first generated token, and each decode step
    at position prompt_len + i yields token i + 1. (Exception: capacity-
    limited MoE routing is batch-global — tokens compete for expert capacity
    with whatever shares the batch — so MoE outputs depend on batch
    composition under either engine; per-request token equality holds for
    the batch-independent mixers: dense/windowed attention, SSD, RG-LRU.)
    """

    def __init__(self, cfg: ArchConfig, params, *, max_slots: int = 8,
                 cache_len: int = 512, opts: Optional[M.ModelOptions] = None):
        self.cfg = cfg
        self.params = params
        self.max_slots = max_slots
        self.cache_len = cache_len
        self.opts = opts or M.ModelOptions(remat=False)
        self.queue: list[Request] = []
        self._prefill_slot = make_jitted_prefill_into_slot(
            cfg, self.opts, cache_len)
        self._decode = make_jitted_decode(cfg, self.opts)
        dtype = jax.tree.leaves(params)[0].dtype
        self.cache = M.init_cache(cfg, max_slots, cache_len, dtype, self.opts)
        self._slot_req: list[Optional[Request]] = [None] * max_slots
        self._slot_pos = np.zeros(max_slots, np.int32)   # next write position
        self._slot_out: list[list[int]] = [[] for _ in range(max_slots)]
        self._pending = np.zeros(max_slots, np.int32)    # next token to feed
        self._latencies: list[float] = []
        self._slo_hits = 0
        self._occupancy_sum = 0.0
        self._init_stream_stats()
        self.stats = {"requests": 0, "tokens_generated": 0, "prefills": 0,
                      "decode_steps": 0, "wall_s": 0.0}

    # -- queue ---------------------------------------------------------------

    def submit(self, req: Request) -> None:
        if len(req.tokens) + req.max_new_tokens > self.cache_len:
            raise ValueError(
                f"request {req.request_id}: prompt {len(req.tokens)} + "
                f"{req.max_new_tokens} new tokens exceeds cache_len "
                f"{self.cache_len}")
        req.enqueue_t = time.monotonic()
        self.queue.append(req)

    def active_slots(self) -> list[int]:
        return [s for s in range(self.max_slots)
                if self._slot_req[s] is not None]

    # -- engine loop ---------------------------------------------------------

    def _admit(self, req: Request, slot: int) -> None:
        tokens = jnp.asarray(req.tokens[None, :], jnp.int32)
        logits, self.cache = self._prefill_slot(
            self.params, self.cache, {"tokens": tokens},
            jnp.asarray(slot, jnp.int32))
        first = int(jnp.argmax(logits, -1))
        self._slot_req[slot] = req
        self._slot_out[slot] = [first]
        self._slot_pos[slot] = len(req.tokens)
        self._pending[slot] = first
        self.stats["prefills"] += 1
        self.stats["tokens_generated"] += 1
        self._count_stream_token(req)

    def _retire(self, slot: int) -> Request:
        req = self._slot_req[slot]
        req.output = np.asarray(self._slot_out[slot], np.int32)
        req.finish_t = time.monotonic()
        self._latencies.append(req.latency_s)
        if req.latency_s <= req.deadline_s:
            self._slo_hits += 1
        self._slot_req[slot] = None
        self._slot_out[slot] = []
        self.stats["requests"] += 1
        return req

    def step(self) -> list[Request]:
        """One engine iteration: EDF admission into free slots, then one
        batched decode step for every occupied slot. Returns the requests
        completed this iteration."""
        t0 = time.monotonic()
        clock0 = self.stats["wall_s"]
        done: list[Request] = []

        # 1) admission, earliest deadline first
        if self.queue:
            self.queue.sort(key=lambda r: r.deadline_t)
            for slot in range(self.max_slots):
                if not self.queue:
                    break
                if self._slot_req[slot] is not None:
                    continue
                self._admit(self.queue.pop(0), slot)
                if len(self._slot_out[slot]) >= \
                        self._slot_req[slot].max_new_tokens:
                    done.append(self._retire(slot))   # max_new_tokens == 1

        # 2) one decode step for all active slots (free slots ride along and
        # are overwritten by the next admission's prefill)
        active = self.active_slots()
        if active:
            tok = jnp.asarray(self._pending, jnp.int32)
            pos = jnp.asarray(self._slot_pos, jnp.int32)
            logits, self.cache = self._decode(
                self.params, self.cache, {"token": tok, "pos": pos})
            nxt = np.asarray(jnp.argmax(logits, -1), np.int32)
            self.stats["decode_steps"] += 1
            self._occupancy_sum += len(active) / self.max_slots
            for s in active:
                self._slot_pos[s] += 1
                self._slot_out[s].append(int(nxt[s]))
                self._pending[s] = nxt[s]
                self.stats["tokens_generated"] += 1
                self._count_stream_token(self._slot_req[s])
                if len(self._slot_out[s]) >= self._slot_req[s].max_new_tokens:
                    done.append(self._retire(s))

        self.stats["wall_s"] += time.monotonic() - t0
        self._mark_windows(clock0, self.stats["wall_s"])
        return done

    def drain(self) -> list[Request]:
        done: list[Request] = []
        while self.queue or self.active_slots():
            done.extend(self.step())
        return done

    # -- reporting -----------------------------------------------------------

    def reset_stats(self) -> None:
        """Zero the counters and latency records (e.g. after a jit warmup)."""
        super().reset_stats()
        self._latencies = []
        self._slo_hits = 0
        self._occupancy_sum = 0.0

    def report(self) -> dict:
        """SLO attainment, latency percentiles, and slot occupancy — the
        scheduler-facing metrics (tokens/s feeds the packing catalog).

        With no completed requests yet the latency fields *and*
        ``slo_attainment`` are ``None`` (there is no percentile — nor an
        attainment fraction — of an empty sample; reporting 1.0 would feed
        a drift detector "perfect SLO" from an idle engine) and the
        counters are zero — the report never raises. Contrast with
        ``Ledger.slo_attainment()``, which is vacuously 1.0 only under
        zero *demand* (nothing was asked for, so nothing was missed).
        """
        lat = sorted(self._latencies)
        n = len(lat)

        def pct(p: float) -> Optional[float]:
            if not lat:
                return None
            return lat[min(n - 1, max(0, int(np.ceil(p * n)) - 1))]

        steps = self.stats["decode_steps"]
        return {
            "requests": self.stats["requests"],
            "tokens_per_s": self.throughput_tokens_per_s(),
            "slo_attainment": (self._slo_hits / n) if n else None,
            "p50_latency_s": pct(0.50),
            "p99_latency_s": pct(0.99),
            "slot_occupancy": (self._occupancy_sum / steps) if steps else 0.0,
        }


class StreamSimulator:
    """Camera streams enqueueing fixed-size frame requests at a frame rate.

    Works with either engine (both expose submit/drain/cfg)."""

    def __init__(self, engine, prompt_len: int = 32,
                 new_tokens: int = 8, vocab: Optional[int] = None,
                 seed: int = 0):
        self.engine = engine
        self.prompt_len = prompt_len
        self.new_tokens = new_tokens
        self.vocab = vocab or engine.cfg.vocab_size
        self.rng = np.random.default_rng(seed)
        self.frame_count = 0
        self._accum: dict[str, float] = {}

    def tick(self, streams_fps: dict[str, float], dt_s: float = 1.0) -> int:
        """Enqueue dt_s worth of frames for each stream at its fps.
        Fractional frames accumulate across ticks (a 0.25 fps camera emits
        one frame every 4 seconds). Each frame carries a 1/fps latency
        budget — the stream's frame period — which the deadline-aware
        engine uses for EDF ordering and SLO accounting."""
        n = 0
        for sid, fps in streams_fps.items():
            acc = self._accum.get(sid, 0.0) + fps * dt_s
            frames = int(acc)
            self._accum[sid] = acc - frames
            budget = (1.0 / fps) if fps > 0 else float("inf")
            for _ in range(frames):
                toks = self.rng.integers(
                    0, self.vocab, self.prompt_len).astype(np.int32)
                self.engine.submit(Request(
                    request_id=f"{sid}-f{self.frame_count}",
                    tokens=toks, max_new_tokens=self.new_tokens,
                    stream_id=sid, deadline_s=budget))
                self.frame_count += 1
                n += 1
        return n
