"""Min-migration incremental replanning: repair the plan, don't rebuild it.

Full replanning treats every control-loop tick as a fresh bin-packing
instance: ``ffd_greedy`` re-sorts and re-packs *all* streams, so one spot
preemption or one camera's ramp can reshuffle placements fleet-wide. The
fleet simulator bills every move as a boot-window SLO loss, which is the
hidden cost the paper's adaptive manager never accounts for. Jain et al. and
Rivas et al. both observe that placement *stability* is what makes
cross-camera consolidation real at fleet scale.

The repair planner treats the previous :class:`Plan` as state:

1. **Keep** every still-feasible (stream -> bin) placement exactly where it
   is, in the old bin order (bin order is what the cluster's reconcile maps
   onto physical instances, oldest-first).
2. **Evict** only what must move: streams on bins whose (type, location)
   choice disappeared from the new problem, streams whose new requirement is
   incompatible with their bin's choice, and — on overfull bins — the
   largest streams first, so the fewest streams move.
3. **Pack the delta** (evictions + new arrivals) first-fit-decreasing over
   the residual capacity of the kept bins, opening new instances only when
   nothing fits (same cost-efficiency opening rule as the full FFD).
4. **Migration budget** (optional): leftover budget after forced moves is
   spent on consolidation — close the emptiest bins by re-packing their
   streams into residual capacity elsewhere, clawing back cost without a
   fleet-wide reshuffle.
5. **Defrag escape hatch** (optional): when the repaired cost drifts to
   ``defrag_ratio`` x a fresh FFD plan's cost, adopt the fresh plan
   wholesale — one big migration buys back the accumulated fragmentation.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

import numpy as np

from repro.core import packed as packed_mod
from repro.core.catalog import Catalog
from repro.core.heuristics import ffd_pack_into, first_fit_decreasing
from repro.core.packing import Bin, Problem, Solution, fits, validate
from repro.core.strategies import Plan, build_problem
from repro.core.workload import Stream


@dataclasses.dataclass(frozen=True)
class RepairConfig:
    """Knobs for the repair planner.

    ``migration_budget``: total *real* moves the repair may spend per call
    (a stream whose final bin equals its old bin costs nothing, and
    arrivals are free). Forced moves (evictions) always happen —
    feasibility beats the budget — and consolidation only spends what they
    left over. ``None`` disables consolidation entirely: pure min-migration
    repair.

    ``defrag_ratio``: adopt a fresh FFD plan when the repaired plan costs at
    least this multiple of it. ``None`` never defrags.
    """

    migration_budget: Optional[int] = None
    defrag_ratio: Optional[float] = None


@dataclasses.dataclass(frozen=True)
class RepairResult:
    """A repaired plan plus the migration ledger the event trace records."""

    plan: Plan
    migrations: int          # streams whose final bin differs from their old
                             # bin (arrivals and put-back evictions excluded)
    evicted: int             # forced evictions (lost/overfull/incompatible)
    consolidated: int        # budget spent on voluntary consolidation moves
    arrivals: int            # streams with no prior placement (not migrations)
    departures: int          # streams that left the fleet
    kept: int                # streams kept in place by the eviction pass
    defrag: bool = False
    fresh_cost: Optional[float] = None   # fresh-FFD reference, when computed


def plan_assignment(plan: Plan) -> dict[str, tuple[str, int]]:
    """stream key -> (choice key, ordinal among that key's bins).

    The ordinal mirrors how the simulated cluster maps bins onto live
    instances (per choice key, in bin order), so diffing two assignments
    counts the moves the fleet would physically perform — unlike a bare
    choice-key diff, which misses moves between two instances of one type.
    """
    out: dict[str, tuple[str, int]] = {}
    ordinal: dict[str, int] = {}
    # Packed problems carry item keys as a plain sequence; indexing it
    # directly skips materializing an Item object per stream.
    ids = getattr(plan.problem, "packed_ids", None)
    for b in plan.solution.bins:
        key = plan.problem.choices[b.choice].key
        n = ordinal.get(key, 0)
        ordinal[key] = n + 1
        if ids is not None:
            placed = (key, n)
            for i in b.items:
                out[ids[i]] = placed
        else:
            for i in b.items:
                out[plan.problem.items[i].key] = (key, n)
    return out


def count_plan_migrations(old: Plan, new: Plan) -> int:
    """Streams present in both plans whose (choice, ordinal) placement moved.
    Arrivals and departures are not migrations — nothing physically moves."""
    a, b = plan_assignment(old), plan_assignment(new)
    return sum(1 for k, v in b.items() if k in a and a[k] != v)


def _keep_and_evict(previous: Plan, problem: Problem):
    """Map the old plan's bins into the new problem.

    Returns (kept bins, their used vectors, their origin old-bin indices,
    {new item idx -> origin old-bin idx}, evicted item indices, departures).
    Kept bins preserve the old bin order; a bin whose members all departed is
    dropped (scale-down). Overfull bins evict their largest members first —
    each eviction frees the most room, so the fewest streams move.
    """
    key2choice = {c.key: i for i, c in enumerate(problem.choices)}
    key2item = {it.key: i for i, it in enumerate(problem.items)}
    kept: list[Bin] = []
    kept_used: list[list[float]] = []
    kept_origin: list[Optional[int]] = []
    old_bin_of: dict[int, int] = {}
    evicted: list[int] = []
    departures = 0

    # First pass: surviving members per old bin (choice mapped into the new
    # problem, departures counted, incompatible members marked for eviction;
    # the global eviction order — per bin, incompatible first, then overfull
    # — is assembled in the second pass, identical to the scalar loop).
    per_bin: list[tuple[int, Optional[int],
                        list[tuple[int, tuple[float, ...]]], list[int]]] = []
    for obi, b in enumerate(previous.solution.bins):
        c = key2choice.get(previous.problem.choices[b.choice].key)
        members: list[tuple[int, tuple[float, ...]]] = []
        pre_ev: list[int] = []
        for i in b.items:
            j = key2item.get(previous.problem.items[i].key)
            if j is None:
                departures += 1
                continue
            old_bin_of[j] = obi
            req = problem.items[j].requirements[c] if c is not None else None
            if req is None:
                pre_ev.append(j)
            else:
                members.append((j, req))
        per_bin.append((obi, c, members, pre_ev))

    # Residual-capacity screen on packed arrays: one vectorized pass totals
    # every kept bin's new requirements and flags bins that could be
    # overfull. numpy's pairwise summation can differ from the scalar
    # member-order sums by ~1 ulp, so the margin is generous (1e-6 vs the
    # 1e-9 decision threshold) and flagged bins re-check exactly below —
    # decisions are bit-identical to the scalar path.
    pp = packed_mod.get_packed(problem)
    survivors = [(n, c, members) for n, (_, c, members, _) in enumerate(per_bin)
                 if c is not None and members]
    maybe_over = {n: True for n, _, _ in survivors}
    if pp is not None and survivors:
        bin_id = np.concatenate([
            np.full(len(members), k, dtype=np.int64)
            for k, (_, _, members) in enumerate(survivors)])
        item_idx = np.fromiter(
            (j for _, _, members in survivors for j, _ in members),
            dtype=np.int64)
        choice_idx = np.concatenate([
            np.full(len(members), c, dtype=np.int64)
            for _, c, members in survivors])
        reqs = pp.class_req[pp.item_class[item_idx], choice_idx]
        totals = np.zeros((len(survivors), problem.ndim))
        np.add.at(totals, bin_id, reqs)
        caps = pp.capacity[[c for _, c, _ in survivors]]
        flags = np.any(totals > caps - 1e-6, axis=1)
        maybe_over = {n: bool(f) for (n, _, _), f in zip(survivors, flags)}

    for n, (obi, c, members, pre_ev) in enumerate(per_bin):
        evicted.extend(pre_ev)
        if c is None or not members:
            continue
        cap = problem.choices[c].capacity
        while members and maybe_over[n]:
            used = [sum(r[k] for _, r in members)
                    for k in range(problem.ndim)]
            over = [k for k in range(problem.ndim)
                    if used[k] > cap[k] + 1e-9]
            if not over:
                break
            # evict the member largest in the overflowing dimensions: each
            # eviction then frees the most of what is actually scarce, so
            # the fewest streams move
            worst = max(range(len(members)),
                        key=lambda m: max(
                            (members[m][1][k] / cap[k] if cap[k] > 0
                             else float("inf")) for k in over))
            evicted.append(members.pop(worst)[0])
        if members:
            kept.append(Bin(choice=c, items=[j for j, _ in members]))
            kept_used.append([sum(r[k] for _, r in members)
                              for k in range(problem.ndim)])
            kept_origin.append(obi)
    return kept, kept_used, kept_origin, old_bin_of, evicted, departures


# Public aliases: the mixed-market planner (core/markets.py) repairs mixed
# plans with exactly this keep/evict pass and migration accounting — the
# eviction order, origin tracking, and packed pre-screen are shared, only
# the delta packing differs (market floor + anti-affinity rules).
def keep_and_evict(previous: Plan, problem: Problem):
    """See :func:`_keep_and_evict` — the repair planner's keep/evict pass."""
    return _keep_and_evict(previous, problem)


def final_moves(bins: Sequence[Bin], origins: Sequence[Optional[int]],
                old_bin_of: dict[int, int]) -> int:
    """See :func:`_final_moves` — the true migration count of a repair."""
    return _final_moves(bins, origins, old_bin_of)


def _final_moves(bins: Sequence[Bin], origins: Sequence[Optional[int]],
                 old_bin_of: dict[int, int]) -> int:
    """Streams whose final bin differs from the old bin that held them —
    the true migration count. Arrivals (no old bin) never count, and an
    evicted stream that the delta pass put back where it came from does
    not count either."""
    moved = 0
    for b, org in zip(bins, origins):
        for i in b.items:
            obi = old_bin_of.get(i)
            if obi is not None and obi != org:
                moved += 1
    return moved


def _consolidate(problem: Problem, bins: list[Bin],
                 bin_used: list[list[float]],
                 origins: list[Optional[int]], budget: int,
                 free_movers: set[int],
                 scope: Optional[frozenset] = None) -> int:
    """Close the emptiest bins by re-packing their members into residual
    capacity elsewhere, spending at most ``budget`` moves. A member in
    ``free_movers`` (an arrival or an already-evicted stream — it is moving
    this tick anyway) costs no budget. ``scope`` (per-group recalibration)
    restricts which bins may *close*: only bins hosting a scoped stream, or
    bins opened this repair (origin ``None``) — a healthy region's
    placements are never consolidation fodder, though any bin may still
    *receive* movers. Returns the budget spent."""
    moved = 0
    while budget - moved >= 0:
        # emptiest first: fewest members, then highest price per member
        candidates = sorted(
            (n for n in range(len(bins))
             if scope is None or origins[n] is None
             or any(problem.items[i].key in scope for i in bins[n].items)),
            key=lambda n: (len(bins[n].items),
                           -problem.choices[bins[n].choice].price))
        closed = False
        for n in candidates:
            src = bins[n]
            charge = sum(1 for i in src.items if i not in free_movers)
            if not src.items or charge > budget - moved:
                continue
            trial_used = [list(u) for u in bin_used]
            landing: list[tuple[int, int, tuple[float, ...]]] = []
            for i in src.items:
                ok = False
                for m, (b, used) in enumerate(zip(bins, trial_used)):
                    if m == n:
                        continue
                    req = problem.items[i].requirements[b.choice]
                    if req is not None and fits(
                            req, used, problem.choices[b.choice].capacity):
                        landing.append((i, m, req))
                        for k in range(problem.ndim):
                            used[k] += req[k]
                        ok = True
                        break
                if not ok:
                    break
            if len(landing) == len(src.items):
                for i, m, req in landing:
                    bins[m].items.append(i)
                    for k in range(problem.ndim):
                        bin_used[m][k] += req[k]
                moved += charge
                del bins[n], bin_used[n], origins[n]
                closed = True
                break
        if not closed:
            break
    return moved


def repair_plan(streams: Sequence[Stream], catalog: Catalog,
                previous: Optional[Plan] = None,
                config: RepairConfig = RepairConfig(),
                scope: Optional[frozenset] = None) -> RepairResult:
    """Incrementally repair ``previous`` for the new stream set.

    With no previous plan this degrades to a fresh FFD plan (everything is
    an arrival; migrations are zero by definition).

    ``scope`` (per-group recalibration, ``obs.regional``): a set of stream
    ids whose calibration just changed. The keep/evict pass and delta
    packing run as usual — feasibility is global — but voluntary work is
    confined to the scope: consolidation may only close bins hosting a
    scoped stream (or bins opened this call), and the defrag escape hatch
    stays shut — a fleet-wide reshuffle is never the right response to a
    one-region re-profile.
    """
    rtt = any(s.camera is not None for s in streams)
    problem = build_problem(streams, catalog, rtt_filter=rtt)

    if previous is None:
        sol = first_fit_decreasing(problem)
        validate(problem, sol)
        return RepairResult(plan=Plan(sol, problem, "REPAIR"), migrations=0,
                            evicted=0, consolidated=0, arrivals=len(streams),
                            departures=0, kept=0)

    kept, kept_used, origins, old_bin_of, evicted, departures = \
        _keep_and_evict(previous, problem)
    placed = {i for b in kept for i in b.items} | set(evicted)
    arrivals = [i for i in range(len(problem.items)) if i not in placed]
    n_kept = sum(len(b.items) for b in kept)

    # FFD the delta over the kept bins' residual capacity first; new bins
    # append after them, preserving the order the cluster maps onto
    # instances
    ffd_pack_into(problem, kept, kept_used, evicted + arrivals)
    origins.extend([None] * (len(kept) - len(origins)))

    consolidated = 0
    if config.migration_budget is not None:
        left = config.migration_budget - _final_moves(kept, origins,
                                                      old_bin_of)
        if left >= 0:
            free = set(evicted) | set(arrivals)   # moving this tick anyway
            consolidated = _consolidate(problem, kept, kept_used, origins,
                                        left, free, scope)

    cost = sum(problem.choices[b.choice].price for b in kept)
    sol = Solution(bins=kept, cost=cost, optimal=False, note="repair")
    validate(problem, sol)
    plan = Plan(sol, problem, "REPAIR")

    fresh_cost: Optional[float] = None
    if config.defrag_ratio is not None and scope is None:
        fresh = first_fit_decreasing(problem)
        fresh_cost = fresh.cost
        if cost >= config.defrag_ratio * fresh.cost - 1e-9:
            validate(problem, fresh)
            fresh_plan = Plan(fresh, problem, "REPAIR")
            return RepairResult(
                plan=fresh_plan,
                migrations=count_plan_migrations(previous, fresh_plan),
                evicted=len(evicted), consolidated=0,
                arrivals=len(arrivals), departures=departures,
                kept=n_kept, defrag=True, fresh_cost=fresh_cost)

    # true moves: the final old-bin vs new-bin diff per stream. Arrivals
    # never count (no prior placement), an evicted stream packed back into
    # its own bin does not count, and streams whose bin merely shifted
    # position after an earlier same-key bin emptied do not count either —
    # the cluster's sticky reconcile keeps them on their instances.
    return RepairResult(
        plan=plan, migrations=_final_moves(kept, origins, old_bin_of),
        evicted=len(evicted), consolidated=consolidated,
        arrivals=len(arrivals), departures=departures,
        kept=n_kept, defrag=False, fresh_cost=fresh_cost)
