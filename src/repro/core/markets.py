"""Spot markets and mixed on-demand/spot planning (BEYOND-PAPER).

The paper buys every instance at the posted on-demand price. Real clouds
also run a *spot* market per region: the same instance at a fluctuating
discount, reclaimable whenever the market price rises above the renter's
bid. This module models the market side in core terms — no simulator
imports — so the planner can price risk:

* :class:`MarketQuote` — one (instance type, location, market) offer:
  the price you pay now, the on-demand reference price, and the walk
  volatility, from which bid-vs-price preemption risk is derived
  (``preempt_probability``: the chance the next lognormal price step ends
  above the bid).
* :func:`quotes` — the quote sheet for a catalog given current per-region
  spot multipliers (the simulator's price walk, or any observed prices).
* :func:`mixed_plan` — preemption-aware packing producing *mixed* plans:
  every stream class keeps an **on-demand floor** (``floor_frac`` of its
  members on reclaim-proof capacity) while the rest may ride spot, under an
  **anti-affinity rule**: no two replicas of one stream may sit on the same
  spot market, so a single market reclaim never takes a whole replica group
  down. Replans are min-migration repairs of the previous mixed plan (kept
  placements stay put, only the delta re-packs) with the same defrag escape
  hatch as :mod:`repro.core.repair`.

A mixed plan is an ordinary :class:`~repro.core.strategies.Plan` whose
problem carries twin choices per (type, location): the on-demand choice at
the catalog price and a ``...!spot`` choice at the current spot price, with
``Choice.market`` telling the cluster which market to rent each bin on.
Because the mixed packer never costs spot above on-demand and falls back to
the pure on-demand packing whenever that is cheaper, a mixed plan's $/hour
cost never exceeds the on-demand-only plan's (property-tested in
``tests/test_markets_properties.py``).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Callable, Mapping, Optional, Sequence

import numpy as np

from repro.core.catalog import Catalog
from repro.core.heuristics import _norm_size
from repro.core.packing import (EPS, Bin, Infeasible, Problem, Solution,
                                fits, validate)
from repro.core.strategies import Plan, build_problem
from repro.core.workload import Stream

# Canonical market names; the simulator's cluster re-exports these.
ONDEMAND = "ondemand"
SPOT = "spot"

# Spot twin of choice "type@loc" is keyed "type@loc!spot" — "!" cannot occur
# in a type name or region id, so keys stay unambiguous across ticks.
SPOT_KEY_SUFFIX = "!spot"


def _phi(z: float) -> float:
    """Standard normal CDF."""
    return 0.5 * (1.0 + math.erf(z / math.sqrt(2.0)))


# ---------------------------------------------------------------------------
# Quotes
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class MarketQuote:
    """One (instance type, location) offer on one market.

    ``price`` is the $/hour you pay *now* (the on-demand list price, or the
    current spot price); ``ondemand_price`` is always the list-price
    reference. ``volatility`` is the per-sqrt-hour sigma of the lognormal
    price step, from which the bid-vs-price preemption hazard derives: a
    spot instance is reclaimed exactly when the market price ends a step
    above its bid.
    """

    type_name: str
    location: str
    market: str                   # ONDEMAND or SPOT
    price: float                  # $/hour paid now
    ondemand_price: float         # $/hour list-price reference
    volatility: float = 0.15      # lognormal step sigma per sqrt(hour)

    @property
    def key(self) -> str:
        base = f"{self.type_name}@{self.location}"
        return base + (SPOT_KEY_SUFFIX if self.market == SPOT else "")

    def margin(self, bid: float) -> float:
        """Bid head-room over the current price (bid/price - 1)."""
        return bid / self.price - 1.0 if self.price > 0 else math.inf

    def _sigma(self, dt_h: float) -> float:
        return self.volatility * math.sqrt(max(dt_h, 1e-9))

    def preempt_probability(self, bid: float, dt_h: float = 1.0) -> float:
        """P(next price step ends above ``bid``) — the per-interval hazard
        as a function of the bid-vs-price margin. Zero margin means ~50%
        (the walk is symmetric in log space); large margins decay like the
        normal tail."""
        if self.market != SPOT:
            return 0.0
        if bid <= 0:
            return 1.0
        s = self._sigma(dt_h)
        return 1.0 - _phi(math.log(bid / self.price) / s)

    def expected_payment(self, bid: float, dt_h: float = 1.0) -> float:
        """E[next price | not reclaimed]: what surviving the interval is
        expected to cost per hour. Grows slowly with the bid — the classic
        reason high bids are cheap insurance on spot markets."""
        if self.market != SPOT:
            return self.price
        if bid <= 0:
            return self.price
        s = self._sigma(dt_h)
        z = math.log(bid / self.price) / s
        p_survive = _phi(z)
        if p_survive <= 1e-12:
            return self.price
        # E[P * 1{P <= bid}] for lognormal P = price * exp(N(0, s^2))
        truncated_mean = (self.price * math.exp(0.5 * s * s)
                          * _phi(z - s))
        return truncated_mean / p_survive

    def effective_price(self, bid: float, dt_h: float = 1.0,
                        preempt_penalty: float = 0.0) -> float:
        """Risk-adjusted $/hour of renting on this quote at ``bid``:
        expected payment while alive, plus — on reclaim — falling back to
        on-demand for the interval and eating ``preempt_penalty`` dollars
        of boot-window SLO loss."""
        if self.market != SPOT:
            return self.price
        p = self.preempt_probability(bid, dt_h)
        return ((1.0 - p) * self.expected_payment(bid, dt_h)
                + p * (self.ondemand_price + preempt_penalty))


def quotes(catalog: Catalog, multipliers: Mapping[str, float],
           *, volatility: float = 0.15) -> list[MarketQuote]:
    """The quote sheet: one on-demand quote per catalog (type, location),
    plus a spot quote wherever ``multipliers`` prices that region (spot
    price = list price x the region's current spot/on-demand multiplier)."""
    out: list[MarketQuote] = []
    for t, loc, price in catalog.choices():
        out.append(MarketQuote(t.name, loc, ONDEMAND, price, price,
                               volatility))
        m = multipliers.get(loc)
        if m is not None:
            out.append(MarketQuote(t.name, loc, SPOT, price * m, price,
                                   volatility))
    return out


# ---------------------------------------------------------------------------
# Replica groups and the anti-affinity invariant
# ---------------------------------------------------------------------------


def replica_group(stream_key: str, sep: str = "#") -> str:
    """The replica group of a stream key: ``cam-3#1`` -> ``cam-3``. Streams
    without the separator are singleton groups (trivially anti-affine)."""
    return stream_key.split(sep, 1)[0]


def spot_affinity_violations(plan: Plan, sep: str = "#") -> list[tuple]:
    """(group, location) pairs hosting two or more of a group's replicas on
    one spot market — empty iff the anti-affinity invariant holds."""
    count: dict[tuple[str, str], int] = {}
    for b in plan.solution.bins:
        ch = plan.problem.choices[b.choice]
        if getattr(ch, "market", ONDEMAND) != SPOT:
            continue
        for i in b.items:
            g = replica_group(plan.problem.items[i].key, sep)
            k = (g, ch.location)
            count[k] = count.get(k, 0) + 1
    return [k for k, n in sorted(count.items()) if n > 1]


# ---------------------------------------------------------------------------
# Mixed on-demand/spot packing
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class MixedConfig:
    """Knobs for mixed planning.

    ``floor_frac``: fraction of every stream class kept on on-demand
    capacity (the reclaim-proof floor); the remainder is spot-eligible
    burst. ``class_fn`` buckets streams into classes (default: program x
    camera). ``replica_sep`` splits replica groups out of stream ids for
    the anti-affinity rule. ``defrag_ratio`` is the repair escape hatch:
    adopt a fresh mixed plan when the repaired one costs at least this
    multiple of it (``None`` never defrags).
    """

    floor_frac: float = 0.5
    class_fn: Optional[Callable[[Stream], tuple]] = None
    replica_sep: str = "#"
    defrag_ratio: Optional[float] = 1.25

    def stream_class(self, s: Stream) -> tuple:
        if self.class_fn is not None:
            return self.class_fn(s)
        return (s.program.name, s.camera)


@dataclasses.dataclass(frozen=True)
class MixedResult:
    """A mixed plan plus the repair ledger and the on-demand reference."""

    plan: Plan
    migrations: int              # streams whose bin differs from their old one
    evicted: int
    arrivals: int
    departures: int
    kept: int
    defrag: bool = False
    ondemand_cost: Optional[float] = None   # fresh on-demand-only $/hour


def spot_problem(streams: Sequence[Stream], catalog: Catalog,
                 multipliers: Mapping[str, float]) -> Problem:
    """The augmented packing problem: the ordinary (RTT-filtered) on-demand
    problem plus a spot twin of every choice whose region has a spot
    multiplier, priced at the current spot price. Item requirement tuples
    are extended preserving the packed builder's class sharing (see
    :func:`repro.core.packed.augment_problem_with_spot`)."""
    from repro.core import packed as packed_mod
    rtt = any(s.camera is not None for s in streams)
    base = build_problem(streams, catalog, rtt_filter=rtt)
    return packed_mod.augment_problem_with_spot(base, multipliers)


def _floor_spot_eligible(streams: Sequence[Stream],
                         config: MixedConfig) -> set[int]:
    """Item indices allowed on spot: everything past each class's on-demand
    floor. Within a class the floor takes the lexicographically first
    stream ids, so the floor/burst split is deterministic and stable across
    ticks for a stable fleet."""
    by_class: dict[tuple, list[int]] = {}
    for i, s in enumerate(streams):
        by_class.setdefault(config.stream_class(s), []).append(i)
    spot_ok: set[int] = set()
    for members in by_class.values():
        members.sort(key=lambda i: streams[i].stream_id)
        floor = math.ceil(config.floor_frac * len(members))
        spot_ok.update(members[floor:])
    return spot_ok


def _spot_locations(problem: Problem, bins: Sequence[Bin],
                    sep: str) -> dict[str, set[str]]:
    """group -> spot locations already holding one of its replicas."""
    taken: dict[str, set[str]] = {}
    for b in bins:
        ch = problem.choices[b.choice]
        if ch.market != SPOT:
            continue
        for i in b.items:
            g = replica_group(problem.items[i].key, sep)
            taken.setdefault(g, set()).add(ch.location)
    return taken


class _OpeningScorer:
    """Vectorized bin-opening scores for the mixed packer.

    The score of opening one bin of choice ``c`` is price / (how many of
    the remaining items a greedy fill of that bin would hold) — the same
    cost-efficiency rule as ``heuristics._cost_efficiency``, evaluated
    market-aware (a spot choice only counts spot-eligible items; the
    anti-affinity state is deliberately ignored — it is a per-item
    placement constraint, not a capacity one, and the score only ranks
    candidates deterministically).

    The fill is run-compressed: remaining items collapse to requirement
    *classes* (items sharing a requirements tuple **by value**, so the
    packed and scalar problem builders produce identical classes) taken in
    first-appearance order, and per class the copies that still fit come
    closed-form from the residual capacity — one (C, D) numpy pass per
    class instead of a Python fits() per (item, choice). This is what
    makes 1k-stream mixed replanning affordable (see
    ``benchmarks/spot_bidding.py``'s parity + wall-clock gates).
    """

    def __init__(self, problem: Problem, spot_ok: set[int]) -> None:
        self.problem = problem
        class_of_key: dict[tuple, int] = {}
        self.class_of = np.empty(len(problem.items), dtype=np.int64)
        reps: list[int] = []
        for i, it in enumerate(problem.items):
            g = class_of_key.setdefault(it.requirements, len(class_of_key))
            if g == len(reps):
                reps.append(i)
            self.class_of[i] = g
        C, D = len(problem.choices), problem.ndim
        self.req = np.full((len(reps), C, D), np.inf)
        for g, i in enumerate(reps):
            for c, r in enumerate(problem.items[i].requirements):
                if r is not None:
                    self.req[g, c] = r
        self.compat = np.isfinite(self.req).all(axis=2)
        self.capacity = np.array([c.capacity for c in problem.choices])
        self.prices = np.array([c.price for c in problem.choices])
        self.is_spot = np.array([c.market == SPOT for c in problem.choices])
        self.spot_ok = spot_ok

    def scores(self, rest: Sequence[int]) -> np.ndarray:
        """Cost-efficiency of opening one bin of every choice for the
        remaining items (``inf`` where nothing fits)."""
        counts: dict[int, list[float]] = {}     # class -> [total, spot_ok]
        blocks: list[int] = []                  # first-appearance order
        for i in rest:
            g = int(self.class_of[i])
            ent = counts.get(g)
            if ent is None:
                counts[g] = ent = [0.0, 0.0]
                blocks.append(g)
            ent[0] += 1.0
            if i in self.spot_ok:
                ent[1] += 1.0
        C, D = self.capacity.shape
        used = np.zeros((C, D))
        held = np.zeros(C)
        for g in blocks:
            total, n_spot = counts[g]
            n = np.where(self.is_spot, n_spot, total) * self.compat[g]
            if not n.any():
                continue
            req = self.req[g]
            resid = self.capacity + EPS - used
            with np.errstate(divide="ignore", invalid="ignore"):
                kd = np.floor(resid / req)
            kd = np.where(req > 0, kd, np.inf)
            k = np.maximum(np.minimum(kd.min(axis=1), n), 0.0)
            if k.any():
                used += k[:, None] * np.where(np.isfinite(req), req, 0.0)
                held += k
        with np.errstate(divide="ignore"):
            return np.where(held > 0, self.prices / np.maximum(held, 1.0),
                            np.inf)


def _mixed_pack_into(problem: Problem, bins: list[Bin],
                     bin_used: list[list[float]], items: Sequence[int],
                     spot_ok: set[int], sep: str) -> None:
    """First-fit-decreasing with the market rules. Floor items never enter
    spot bins. Spot-eligible items *prefer* the spot market — they first-fit
    over spot bins (and open spot bins) before touching on-demand capacity,
    so the burst actually rides the discount instead of back-filling the
    floor's residuals — under the anti-affinity rule: no spot bin at
    location L takes a second replica of a group already on the L spot
    market. Anything un-spottable (anti-affinity exhausted, no spot quote)
    falls back to on-demand. Mutates ``bins``/``bin_used`` in place (new
    bins append), mirroring ``heuristics.ffd_pack_into``; the fresh-plan
    caller keeps the cheaper of this and the pure on-demand packing, so
    the spot preference can never cost money overall."""
    taken = _spot_locations(problem, bins, sep)
    scorer = _OpeningScorer(problem, spot_ok)
    order = sorted(items, key=lambda i: _norm_size(problem, problem.items[i]),
                   reverse=True)

    def try_bins(i, item, g, market) -> bool:
        g_taken = taken.get(g, set())
        for b, used in zip(bins, bin_used):
            ch = problem.choices[b.choice]
            if ch.market != market:
                continue
            if ch.market == SPOT and ch.location in g_taken:
                continue
            req = item.requirements[b.choice]
            if req is None or not fits(req, used, ch.capacity):
                continue
            b.items.append(i)
            for k in range(problem.ndim):
                used[k] += req[k]
            if ch.market == SPOT:
                taken.setdefault(g, set()).add(ch.location)
            return True
        return False

    def try_open(i, item, g, market, eff) -> bool:
        g_taken = taken.get(g, set())
        cands = [c for c in item.compatible()
                 if problem.choices[c].market == market
                 and (market == ONDEMAND
                      or problem.choices[c].location not in g_taken)]
        if not cands:
            return False
        c = min(cands, key=lambda c: (
            float(eff[c]), problem.choices[c].price, problem.choices[c].key))
        if not math.isfinite(eff[c]):
            return False
        bins.append(Bin(choice=c, items=[i]))
        bin_used.append(list(item.requirements[c]))
        if problem.choices[c].market == SPOT:
            taken.setdefault(g, set()).add(problem.choices[c].location)
        return True

    for pos, i in enumerate(order):
        item = problem.items[i]
        g = replica_group(item.key, sep)
        markets = (SPOT, ONDEMAND) if i in spot_ok else (ONDEMAND,)
        eff = None
        placed = False
        for m in markets:
            if try_bins(i, item, g, m):
                placed = True
                break
            if eff is None:
                eff = scorer.scores(order[pos:])   # one pass per opening
            if try_open(i, item, g, m, eff):
                placed = True
                break
        if not placed:
            if not item.compatible():
                raise Infeasible(f"item {item.key} has no compatible choice")
            raise Infeasible(f"item {item.key} fits no empty instance")


def _pack_fresh(problem: Problem, spot_ok: set[int], sep: str) -> Solution:
    bins: list[Bin] = []
    bin_used: list[list[float]] = []
    _mixed_pack_into(problem, bins, bin_used, range(len(problem.items)),
                     spot_ok, sep)
    cost = sum(problem.choices[b.choice].price for b in bins)
    return Solution(bins=bins, cost=cost, optimal=False, note="mixed-ffd")


def _fresh_mixed(problem: Problem, spot_ok: set[int],
                 sep: str) -> tuple[Solution, float]:
    """Fresh mixed solution and the on-demand-only reference cost. The
    mixed packer falls back to the pure on-demand packing whenever that is
    cheaper, so mixed cost <= on-demand-only cost *by construction* (FFD is
    not monotone in the choice set, so this cannot be assumed)."""
    ondemand = _pack_fresh(problem, set(), sep)
    if not spot_ok:
        return ondemand, ondemand.cost
    mixed = _pack_fresh(problem, spot_ok, sep)
    best = mixed if mixed.cost <= ondemand.cost else ondemand
    return best, ondemand.cost


def mixed_plan(streams: Sequence[Stream], catalog: Catalog,
               multipliers: Mapping[str, float],
               previous: Optional[Plan] = None,
               config: MixedConfig = MixedConfig()) -> MixedResult:
    """Plan (or incrementally repair) a mixed on-demand/spot allocation.

    Fresh plans pack under the floor + anti-affinity rules and keep the
    cheaper of the mixed and pure on-demand packings. With ``previous``,
    replans are min-migration repairs: still-feasible placements stay on
    their bins (and markets), only evicted/arriving streams re-pack over
    residual capacity — at current spot prices — and the defrag escape
    hatch adopts a fresh mixed plan when the repaired cost drifts past
    ``config.defrag_ratio`` times it.
    """
    from repro.core.repair import final_moves, keep_and_evict

    problem = spot_problem(streams, catalog, multipliers)
    spot_ok = _floor_spot_eligible(streams, config)
    sep = config.replica_sep

    if previous is None:
        sol, od_cost = _fresh_mixed(problem, spot_ok, sep)
        validate(problem, sol)
        return MixedResult(plan=Plan(sol, problem, "MIXED"), migrations=0,
                           evicted=0, arrivals=len(streams), departures=0,
                           kept=0, ondemand_cost=od_cost)

    kept, kept_used, origins, old_bin_of, evicted, departures = \
        keep_and_evict(previous, problem)

    # Re-establish the on-demand floor: churn can leave a *floored* stream
    # (not spot-eligible under the current class split) sitting on a kept
    # spot bin — e.g. its class shrank until the floor covers it. Such
    # placements are evicted like any other infeasibility, so the delta
    # pass puts them back on reclaim-proof capacity; spot-eligible members
    # on spot stay put, and the deterministic (lex-first) floor split keeps
    # this a no-op for a stable fleet.
    for n, b in enumerate(kept):
        if problem.choices[b.choice].market != SPOT:
            continue
        floored = [i for i in b.items if i not in spot_ok]
        if not floored:
            continue
        for i in floored:
            b.items.remove(i)
            req = problem.items[i].requirements[b.choice]
            for k in range(problem.ndim):
                kept_used[n][k] -= req[k]
        evicted.extend(floored)
    empties = [n for n, b in enumerate(kept) if not b.items]
    for n in reversed(empties):
        del kept[n], kept_used[n], origins[n]

    placed = {i for b in kept for i in b.items} | set(evicted)
    arrivals = [i for i in range(len(problem.items)) if i not in placed]
    n_kept = sum(len(b.items) for b in kept)

    _mixed_pack_into(problem, kept, kept_used, evicted + arrivals,
                     spot_ok, sep)
    origins.extend([None] * (len(kept) - len(origins)))
    cost = sum(problem.choices[b.choice].price for b in kept)
    sol = Solution(bins=kept, cost=cost, optimal=False, note="mixed-repair")
    validate(problem, sol)

    if config.defrag_ratio is not None:
        fresh, od_cost = _fresh_mixed(problem, spot_ok, sep)
        if cost >= config.defrag_ratio * fresh.cost - 1e-9:
            from repro.core.repair import count_plan_migrations
            validate(problem, fresh)
            fresh_plan = Plan(fresh, problem, "MIXED")
            return MixedResult(
                plan=fresh_plan,
                migrations=count_plan_migrations(previous, fresh_plan),
                evicted=len(evicted), arrivals=len(arrivals),
                departures=departures, kept=n_kept, defrag=True,
                ondemand_cost=od_cost)

    return MixedResult(
        plan=Plan(sol, problem, "MIXED"),
        migrations=final_moves(kept, origins, old_bin_of),
        evicted=len(evicted), arrivals=len(arrivals),
        departures=departures, kept=n_kept)
