"""Packed (columnwise) representation of the packing problem — the 10k-stream
fast path.

The object API (:class:`~repro.core.packing.Problem` / ``Item`` / ``Bin``)
is pleasant to reason about but scales as O(streams x choices) Python objects
per control-loop tick: at 10,000 streams over a 35-choice catalog that is
350k requirement tuples *per replan*, and the FFD heuristic's
cost-efficiency opening rule rescans every remaining item per opened bin.

The packed path exploits the fleet's *class structure*: streams are
(program, frame-rate, camera) instances drawn from a small set of
requirement classes G (tens, not thousands), because requirement vectors are
linear in fps and fps comes from a handful of diurnal curves. We therefore:

* build requirement matrices **columnwise** — one ``(G, C, D)`` array of
  per-class requirement vectors (``inf`` where incompatible) instead of N x C
  Python tuples; items of one class *share* a single requirements tuple, so
  the object view stays intact at O(G x C) construction cost;
* run FFD over **runs** of identical items (maximal same-class blocks of the
  size-sorted order) with numpy first-fit masks over all open bins at once,
  falling back to exact per-copy arithmetic inside the chosen bin so
  ``bin_used`` accumulates bit-identically to the scalar path;
* evaluate the bin-opening cost-efficiency rule run-compressed (closed-form
  "how many copies of this class still fit"), and reuse the previous opening
  decision while the only change to the remaining items is the head run's
  count and every choice's head fill is already saturated — which is exactly
  when the decision provably cannot change.

Everything here is semantics-preserving: ``tests/test_packed_parity.py``
asserts bit-identical plans and ledgers against the scalar path, and
``scalar_mode()`` switches the whole pipeline back to the original
per-object code for baselines and property tests.
"""
from __future__ import annotations

import contextlib
import dataclasses
from collections.abc import Sequence
from typing import Optional

import numpy as np

from repro.core import geo
from repro.core.packing import EPS, Bin, Infeasible, Item, Problem
from repro.core.workload import (Stream, class_requirement_columns,
                                 requirement_columns)

# ---------------------------------------------------------------------------
# Global switch: the scalar (pre-refactor) path stays available for parity
# tests and the scale_sweep speedup baseline.
# ---------------------------------------------------------------------------

_ENABLED = True


def enabled() -> bool:
    """Whether the vectorized planning/demand path is active."""
    return _ENABLED


@contextlib.contextmanager
def scalar_mode():
    """Run the original per-object / per-stream code paths (parity baseline).

    Inside this context ``build_problem`` builds Items the scalar way (no
    packed arrays attached, so FFD takes its scalar path too) and
    ``DiurnalFleet`` evaluates demand per camera instead of as arrays.
    """
    global _ENABLED
    prev = _ENABLED
    _ENABLED = False
    try:
        yield
    finally:
        _ENABLED = prev


# Cached RTT feasibility: geo.max_fps is a pure function of (camera, region)
# but costs a haversine per call; the scalar path recomputes it per
# (stream, choice) pair.
_MAX_FPS_CACHE: dict[tuple[str, str], float] = {}


def max_fps_cached(camera: str, region: str) -> float:
    key = (camera, region)
    v = _MAX_FPS_CACHE.get(key)
    if v is None:
        v = geo.max_fps(camera, region)
        _MAX_FPS_CACHE[key] = v
    return v


# ---------------------------------------------------------------------------
# Packed problem
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class PackedProblem:
    """Columnwise arrays mirroring a :class:`Problem`.

    ``class_req[g, c]`` is class ``g``'s requirement vector under choice
    ``c`` (``+inf`` where incompatible, so a fits-test fails naturally);
    ``item_class[i]`` maps every item to its class. Capacities are the
    usable (90%-capped) vectors, prices are $/hour — identical floats to the
    object view, just laid out for whole-fleet operations.
    """

    item_class: np.ndarray        # (N,) int64
    class_req: np.ndarray         # (G, C, D) float64, +inf = incompatible
    class_compat: np.ndarray      # (G, C) bool
    class_has_compat: np.ndarray  # (G,) bool
    class_size: np.ndarray        # (G,) float64 — FFD norm size (l_inf frac)
    class_kmax: np.ndarray        # (G, C) float64 — copies fitting an empty bin
    capacity: np.ndarray          # (C, D) float64 — usable capacity
    prices: np.ndarray            # (C,) float64 — $/hour
    # requirement *groups*: classes that share (program, fps) — and therefore
    # the same requirement vector on every choice — but may differ in RTT
    # compatibility (different cameras). The opening rule compresses over
    # groups: a greedy fill's accept count for a choice depends only on how
    # many of a group's items are compatible, not on their interleaving.
    class_group: np.ndarray       # (G,) int64 — group id per class
    group_req: np.ndarray         # (G2, C, D) float64, inf = type-incompatible

    @property
    def ndim(self) -> int:
        return self.capacity.shape[1]


def get_packed(problem: Problem) -> Optional[PackedProblem]:
    """The packed arrays attached to a problem, if it was built packed."""
    return getattr(problem, "packed", None)


def _class_arrays(class_reqs: list[tuple], capacity: np.ndarray,
                  prices: np.ndarray) -> tuple:
    """(class_req, compat, has_compat, size, kmax) from per-class req tuples."""
    G, C = len(class_reqs), capacity.shape[0]
    D = capacity.shape[1]
    req = np.full((G, C, D), np.inf)
    for g, per_choice in enumerate(class_reqs):
        for c, r in enumerate(per_choice):
            if r is not None:
                req[g, c] = r
    compat = np.isfinite(req).all(axis=2)
    has_compat = compat.any(axis=1)

    # norm size: max over compatible choices of the max per-dim fraction
    # (same arithmetic as heuristics._norm_size: req/cap, 0-capacity dims
    # contribute 0 when the requirement is 0 too).
    with np.errstate(divide="ignore", invalid="ignore"):
        frac = np.where(capacity[None, :, :] > 0,
                        req / capacity[None, :, :],
                        np.where(req <= 0, 0.0, np.inf))
    frac_max = frac.max(axis=2)                         # (G, C)
    size = np.where(compat, frac_max, -np.inf).max(axis=1)

    # copies of a class fitting an *empty* bin of each choice (0 if
    # incompatible): min over dims of floor((cap + EPS) / req).
    with np.errstate(divide="ignore", invalid="ignore"):
        kd = np.floor((capacity[None, :, :] + EPS) / req)
    kd = np.where(req > 0, kd, np.inf)
    kmax = np.where(compat, kd.min(axis=2), 0.0)
    return req, compat, has_compat, size, kmax


class _PackedItemSeq(Sequence):
    """Lazy ``problem.items``: Item views over (stream id, class) columns.

    At a million streams, materializing N ``Item`` objects per replan is
    the dominant cost of building a problem — and the packed pipeline never
    looks at them (FFD runs on the arrays; reconcile uses ``packed_ids``).
    This sequence constructs an ``Item`` only when some object-path consumer
    actually indexes it; all items of a class share one requirements tuple,
    exactly like the eager builder. ``distinct_requirements()`` hands
    ``Problem.__post_init__`` the per-class tuples so validation stays
    O(classes x choices) without touching any item."""

    __slots__ = ("_ids", "_cls", "_reqs")

    def __init__(self, ids, item_class, class_reqs) -> None:
        self._ids = ids
        self._cls = item_class
        self._reqs = class_reqs

    def __len__(self) -> int:
        return len(self._ids)

    def __getitem__(self, i):
        if isinstance(i, slice):
            return [self[k] for k in range(*i.indices(len(self._ids)))]
        return Item(key=self._ids[i], requirements=self._reqs[self._cls[i]])

    def distinct_requirements(self):
        return self._reqs


def _build_items_from_columns(streams, choices, metas, target_fps,
                              rtt_filter, types, type_ids) -> Problem:
    """Column-native twin of the per-stream class grouping below: factorize
    (program, fps, camera) by integer codes instead of hashing N Python
    tuples. Class/group *numbering* differs from the eager builder (sorted
    by code, not first appearance) — provably irrelevant: the FFD order is a
    stable sort on per-item sizes, runs/blocks/opening decisions depend only
    on class identity patterns and contents, and requirement floats come
    from the same ``requirement_columns`` / ``max_fps_cached`` calls."""
    n = len(streams)
    puniq = streams.programs_unique
    cuniq = streams.cameras_unique
    pcodes = streams.program_codes
    if target_fps is not None:
        fps = np.full(n, float(target_fps))
    else:
        fps = streams.fps
    camk = streams.camera_codes if rtt_filter \
        else np.full(n, -1, dtype=np.int64)

    uf = np.unique(fps)
    fcode = np.searchsorted(uf, fps)
    combo = ((pcodes.astype(np.int64) * (len(cuniq) + 1) + (camk + 1))
             * len(uf) + fcode)
    _, first, item_class = np.unique(combo, return_index=True,
                                     return_inverse=True)
    item_class = item_class.astype(np.int64, copy=False)
    G = len(first)
    cls_p = pcodes[first]
    cls_f = fps[first]
    cls_cam = camk[first]

    gcombo = cls_p.astype(np.int64) * len(uf) + fcode[first]
    _, gfirst, class_group = np.unique(gcombo, return_index=True,
                                       return_inverse=True)
    class_group = class_group.astype(np.int64, copy=False)

    group_per_choice: list[list] = []
    for g2 in gfirst.tolist():
        by_type = class_requirement_columns(puniq[int(cls_p[g2])],
                                            float(cls_f[g2]),
                                            types, target_fps)
        group_per_choice.append(
            [by_type[type_ids[id(t)]] for (t, _loc) in metas])

    class_reqs: list[tuple] = []
    for g in range(G):
        base = group_per_choice[int(class_group[g])]
        ck = int(cls_cam[g])
        if rtt_filter and ck >= 0:
            cam = cuniq[ck]
            f = float(cls_f[g]) if target_fps is None else target_fps
            per_choice = [None if (req is not None
                                   and max_fps_cached(cam, loc) < f)
                          else req
                          for req, (_t, loc) in zip(base, metas)]
            class_reqs.append(tuple(per_choice))
        else:
            class_reqs.append(tuple(base))

    items = _PackedItemSeq(streams.ids, item_class, class_reqs)
    problem = Problem(choices=tuple(choices), items=items)
    _attach_packed(problem, item_class, class_reqs, choices,
                   class_group, group_per_choice)
    object.__setattr__(problem, "packed_ids", streams.ids)
    return problem


def _attach_packed(problem: Problem, item_class, class_reqs, choices,
                   class_group, group_per_choice) -> None:
    capacity = np.array([c.capacity for c in choices], dtype=np.float64)
    prices = np.array([c.price for c in choices], dtype=np.float64)
    req, compat, has_compat, size, kmax = _class_arrays(
        class_reqs, capacity, prices)
    C, D = capacity.shape
    group_req = np.full((len(group_per_choice), C, D), np.inf)
    for g2, per_choice in enumerate(group_per_choice):
        for c, r in enumerate(per_choice):
            if r is not None:
                group_req[g2, c] = r
    packed = PackedProblem(item_class=item_class, class_req=req,
                           class_compat=compat, class_has_compat=has_compat,
                           class_size=size, class_kmax=kmax,
                           capacity=capacity, prices=prices,
                           class_group=np.asarray(class_group,
                                                  dtype=np.int64),
                           group_req=group_req)
    object.__setattr__(problem, "packed", packed)


def build_packed_items(streams, choices, metas, target_fps,
                       rtt_filter) -> Problem:
    """Columnwise item construction: group streams into requirement classes,
    compute each class's vector once per instance *type* (it does not vary by
    location), apply the RTT feasibility column from the cached camera x
    region matrix, and share one requirements tuple across all items of a
    class. Bit-identical to the scalar loop (same ``requirement_for`` and
    ``geo.max_fps`` floats), at O(G x C) instead of O(N x C) cost."""
    # distinct instance types among the (type, location) metas
    type_ids: dict[int, int] = {}
    types = []
    for (t, _loc) in metas:
        if id(t) not in type_ids:
            type_ids[id(t)] = len(types)
            types.append(t)

    if getattr(streams, "program_codes", None) is not None:
        # columnar demand (StreamColumns): factorize by codes, skip the
        # N-item materialization entirely
        return _build_items_from_columns(streams, choices, metas,
                                         target_fps, rtt_filter,
                                         types, type_ids)

    class_of: dict[tuple, int] = {}
    class_rep: list = []                 # representative stream per class
    item_class = np.empty(len(streams), dtype=np.int64)
    for n, s in enumerate(streams):
        fps = target_fps if target_fps is not None else s.fps
        cam = s.camera if (rtt_filter and s.camera is not None) else None
        key = (id(s.program), fps, cam)
        g = class_of.get(key)
        if g is None:
            g = len(class_rep)
            class_of[key] = g
            class_rep.append(s)
        item_class[n] = g

    group_of: dict[tuple, int] = {}
    class_group = np.empty(len(class_rep), dtype=np.int64)
    group_per_choice: list[list] = []
    class_reqs: list[tuple] = []
    for g, s in enumerate(class_rep):
        fps = target_fps if target_fps is not None else s.fps
        gkey = (id(s.program), fps)
        g2 = group_of.get(gkey)
        if g2 is None:
            g2 = len(group_per_choice)
            group_of[gkey] = g2
            by_type = requirement_columns(s, types, target_fps)
            group_per_choice.append(
                [by_type[type_ids[id(t)]] for (t, _loc) in metas])
        class_group[g] = g2
        per_choice = []
        for req, (t, loc) in zip(group_per_choice[g2], metas):
            if req is not None and rtt_filter and s.camera is not None:
                if max_fps_cached(s.camera, loc) < fps:
                    req = None
            per_choice.append(req)
        class_reqs.append(tuple(per_choice))

    items = tuple(Item(key=s.stream_id, requirements=class_reqs[g])
                  for s, g in zip(streams, item_class))
    problem = Problem(choices=tuple(choices), items=items)
    _attach_packed(problem, item_class, class_reqs, choices,
                   class_group, group_per_choice)
    ids = getattr(streams, "ids", None)
    if ids is not None:
        object.__setattr__(problem, "packed_ids", ids)
    return problem


def augment_problem_with_spot(base: Problem,
                              multipliers) -> Problem:
    """The mixed-market problem: ``base`` plus a spot twin of every choice
    whose region has a spot multiplier (same capacity and requirements,
    price = list price x multiplier, ``market="spot"``).

    Item requirement tuples are extended *preserving class sharing*: all
    items that shared one requirements tuple in ``base`` (the packed
    builder's class structure) share one extended tuple here, so
    ``Problem.__post_init__`` still validates O(classes x choices) and the
    repair planner's vectorized overfull pre-screen stays usable. When the
    base problem carries packed arrays, the augmented one gets them too —
    requirement/compat columns tiled onto the spot choices, prices from the
    spot quotes — so ``keep_and_evict`` runs its fast path on mixed plans.
    """
    from repro.core.packing import Choice

    spot_choices: list[Choice] = []
    spot_src: list[int] = []                 # base choice index of each twin
    for c, ch in enumerate(base.choices):
        m = multipliers.get(ch.location)
        if m is None:
            continue
        spot_choices.append(Choice(
            key=ch.key + "!spot", type_name=ch.type_name,
            location=ch.location, capacity=ch.capacity,
            price=ch.price * m, has_gpu=ch.has_gpu, market="spot"))
        spot_src.append(c)
    if not spot_choices:
        return base

    if isinstance(base.items, _PackedItemSeq):
        # lazy items: extend the per-class tuples, never touch the N items
        ext = [r + tuple(r[c] for c in spot_src)
               for r in base.items.distinct_requirements()]
        items = _PackedItemSeq(base.items._ids, base.items._cls, ext)
    else:
        extended: dict[int, tuple] = {}      # id(base tuple) -> shared tuple
        items = []
        for it in base.items:
            reqs = extended.get(id(it.requirements))
            if reqs is None:
                reqs = it.requirements + tuple(
                    it.requirements[c] for c in spot_src)
                extended[id(it.requirements)] = reqs
            items.append(Item(key=it.key, requirements=reqs))
        items = tuple(items)
    problem = Problem(choices=base.choices + tuple(spot_choices),
                      items=items)
    ids = getattr(base, "packed_ids", None)
    if ids is not None:
        object.__setattr__(problem, "packed_ids", ids)

    pp = get_packed(base)
    if pp is not None:
        src = np.asarray(spot_src, dtype=np.int64)
        capacity = np.concatenate([pp.capacity, pp.capacity[src]])
        prices = np.concatenate(
            [pp.prices, np.array([c.price for c in spot_choices])])
        class_req = np.concatenate([pp.class_req, pp.class_req[:, src]],
                                   axis=1)
        compat = np.concatenate([pp.class_compat, pp.class_compat[:, src]],
                                axis=1)
        kmax = np.concatenate([pp.class_kmax, pp.class_kmax[:, src]], axis=1)
        group_req = np.concatenate([pp.group_req, pp.group_req[:, src]],
                                   axis=1)
        aug = PackedProblem(
            item_class=pp.item_class, class_req=class_req,
            class_compat=compat, class_has_compat=compat.any(axis=1),
            class_size=pp.class_size, class_kmax=kmax,
            capacity=capacity, prices=prices,
            class_group=pp.class_group, group_req=group_req)
        object.__setattr__(problem, "packed", aug)
    return problem


# ---------------------------------------------------------------------------
# Packed FFD
# ---------------------------------------------------------------------------


def _open_efficiency(pp: PackedProblem, blocks) -> np.ndarray:
    """Cost-efficiency of opening one bin of every choice, vectorized.

    Exactly the scalar ``_cost_efficiency`` semantics, compressed over
    requirement-group *blocks* of the remaining item order. Within a block
    every item carries the same requirement vector per choice and differs at
    most in RTT compatibility, and a greedy fill skips incompatible items
    without touching state — so the accept count for choice ``c`` is
    ``min(compatible-items-in-block, copies-that-still-fit)`` no matter how
    the block's cameras interleave; once one copy is rejected every later
    identical copy is too, so the closed-form count equals the per-item
    scan. ``blocks`` is a sequence of ``(group_id, n_compat)`` with
    ``n_compat`` a per-choice count vector. Returns price / items-held per
    choice (``inf`` where nothing fits).

    Group-aliveness screen: a block of group ``g2`` changes the fill state
    only if some choice still fits one whole copy of ``g2``
    (``floor(resid/req) >= 1`` on every binding dim). Base-dominated items
    (e.g. pipeline crop stages whose binding dim is an fps-independent
    model-load base) tie in norm size across many (program, fps) groups, so
    the sorted order interleaves them into hundreds of tiny blocks — but
    every choice saturates within the first few, after which each later
    block of a dead group provably contributes ``k = 0``. Those blocks are
    skipped without touching state (aliveness is recomputed with the same
    floor-division arithmetic whenever the state changes, so the skip is
    exact), and the scan stops once no group is alive. Counts — and hence
    efficiencies and the opening argmin — are bit-identical to the full
    scan."""
    C, D = pp.capacity.shape
    used = np.zeros((C, D))
    count = np.zeros(C)
    cap_eps = pp.capacity + EPS
    guniq = sorted({g2 for g2, _ in blocks})
    gpos = {g2: i for i, g2 in enumerate(guniq)}
    greq = pp.group_req[guniq]                      # (Gu, C, D)
    gfin = np.where(np.isfinite(greq), greq, 0.0)
    with np.errstate(divide="ignore", invalid="ignore"):
        def _alive() -> np.ndarray:
            kd = np.floor((cap_eps - used)[None, :, :] / greq)
            kd = np.where(greq > 0, kd, np.inf)
            return (kd.min(axis=2) >= 1.0).any(axis=1)     # (Gu,)

        alive = _alive()
        any_alive = bool(alive.any())
        for g2, n_compat in blocks:
            if not any_alive:
                break
            gi = gpos[g2]
            if not alive[gi]:
                continue
            req = greq[gi]                          # (C, D)
            kd = np.floor((cap_eps - used) / req)
            kd = np.where(req > 0, kd, np.inf)      # only positive dims bind
            k = np.minimum(kd.min(axis=1), n_compat)
            k = np.maximum(k, 0.0)
            if k.any():
                used += k[:, None] * gfin[gi]
                count += k
                alive = _alive()
                any_alive = bool(alive.any())
    with np.errstate(divide="ignore"):
        eff = np.where(count > 0, pp.prices / np.maximum(count, 1.0), np.inf)
    return eff


def _choose_open(problem: Problem, pp: PackedProblem, g: int,
                 blocks, item_idx: int) -> int:
    """The scalar opening rule on packed arrays: among the class's compatible
    choices, minimize (cost-efficiency over remaining items, price); raise
    the same Infeasible errors the scalar path would."""
    eff = _open_efficiency(pp, blocks)
    cands = np.flatnonzero(pp.class_compat[g])
    if cands.size == 0:
        raise Infeasible(
            f"item {problem.items[item_idx].key} has no compatible choice")
    best = min((int(c) for c in cands),
               key=lambda c: (eff[c], problem.choices[c].price))
    if eff[best] == np.inf:
        raise Infeasible(
            f"item {problem.items[item_idx].key} fits no empty instance")
    return best


def ffd_pack_packed(problem: Problem, pp: PackedProblem, bins: list[Bin],
                    bin_used: list[list[float]], items) -> None:
    """Packed first-fit-decreasing over ``items`` into ``bins`` (mutated in
    place, exactly like the scalar ``ffd_pack_into``).

    Items are sorted by class norm-size (stable, so ties keep input order —
    identical to the scalar stable sort) and processed as runs of equal
    class. Per run, one numpy mask finds every currently-fitting open bin;
    bins are then filled left-to-right with exact per-copy arithmetic (the
    same ``u + r <= cap + EPS`` float comparisons and ``+=`` accumulation
    order as the scalar path, so ``bin_used`` ends bit-identical). When no
    bin fits, the opening rule runs run-compressed, with the previous
    decision reused while it provably cannot change (every choice's head
    fill saturated below the remaining count)."""
    idx = np.fromiter(items, dtype=np.int64)
    if idx.size == 0:
        return
    cls = pp.item_class[idx]
    ok = pp.class_has_compat[cls]
    if not ok.all():
        bad = int(idx[int(np.argmin(ok))])      # first infeasible, input order
        raise Infeasible(
            f"item {problem.items[bad].key} has no compatible choice")

    order = idx[np.argsort(-pp.class_size[cls], kind="stable")]
    ocls = pp.item_class[order]
    cuts = np.flatnonzero(ocls[1:] != ocls[:-1]) + 1
    starts = np.concatenate(([0], cuts))
    ends = np.concatenate((cuts, [order.size]))
    run_class = [int(g) for g in ocls[starts]]
    run_len = [int(v) for v in (ends - starts)]
    n_runs = len(run_class)

    # Block structure for the opening rule: maximal same-group segments of
    # the run sequence (at night, thousands of equal-size single-item runs
    # from different cameras collapse into a handful of blocks).
    run_group = pp.class_group[np.asarray(run_class, dtype=np.int64)]
    compat_f = pp.class_compat.astype(np.float64)
    block_of_run = np.empty(n_runs, dtype=np.int64)
    full_blocks: list[tuple[int, np.ndarray]] = []   # (group, n_compat)
    # per-run suffix compat counts within the run's own block
    suffix_compat = [None] * n_runs
    ri = n_runs - 1
    while ri >= 0:
        g2 = int(run_group[ri])
        acc = np.zeros(pp.capacity.shape[0])
        lo = ri
        while lo >= 0 and int(run_group[lo]) == g2:
            lo -= 1
        for rj in range(ri, lo, -1):
            acc = acc + run_len[rj] * compat_f[run_class[rj]]
            suffix_compat[rj] = acc
            acc = acc.copy()
        full_blocks.append((g2, suffix_compat[lo + 1]))
        for rj in range(lo + 1, ri + 1):
            block_of_run[rj] = len(full_blocks) - 1
        ri = lo
    full_blocks.reverse()
    n_blocks = len(full_blocks)
    block_of_run = (n_blocks - 1) - block_of_run

    def rest_blocks(ri: int, consumed: int) -> list:
        """Blocks of ``order[pos:]``: the current run's block minus what has
        been consumed, then every later block whole."""
        g = run_class[ri]
        head = suffix_compat[ri] - consumed * compat_f[g]
        return [(int(run_group[ri]), head)] + full_blocks[block_of_run[ri] + 1:]

    # growable bin-state arrays (parallel to the `bins` object list)
    nb = len(bins)
    cap_rows = max(64, 1 << int(nb + 16).bit_length())
    D = pp.ndim
    bused = np.zeros((cap_rows, D))
    bcap = np.zeros((cap_rows, D))
    bchoice = np.zeros(cap_rows, dtype=np.int64)
    if nb:
        bused[:nb] = np.asarray(bin_used, dtype=np.float64)
        bchoice[:nb] = [b.choice for b in bins]
        bcap[:nb] = pp.capacity[bchoice[:nb]]

    def grow() -> None:
        nonlocal bused, bcap, bchoice, cap_rows
        cap_rows *= 2
        bused = np.concatenate([bused, np.zeros_like(bused)])
        bcap = np.concatenate([bcap, np.zeros_like(bcap)])
        bchoice = np.concatenate([bchoice, np.zeros_like(bchoice)])

    n_preexisting = len(bins)
    # Per-class first-fit cursors. First-fit scans bins in index order, and
    # a bin only ever *gains* load during a pack — once it fails to fit a
    # class it never fits that class again. Each class therefore keeps an
    # ordered queue of not-yet-rejected candidate bins plus a high-water
    # mark of how far it has scanned; every (class, bin) pair is examined
    # O(1) times. Without this, interleaved equal-size classes fragment the
    # order into near-single-item runs and a fresh every-run scan over all
    # open bins turns the pack quadratic (hours at 10^6 streams). Inner
    # fills run on Python floats — IEEE-identical to the numpy elementwise
    # ops, an order of magnitude faster per 4-vector.
    state: dict[int, list] = {}      # g -> [candidate bins, ptr, scanned]
    kmax_of = pp.class_kmax.max(axis=1)       # head saturation thresholds
    pos = 0                                   # global index into `order`
    for ri in range(n_runs):
        g = run_class[ri]
        n = run_len[ri]
        run_items = order[pos:pos + n].tolist()
        reqs_c = pp.class_req[g]              # (C, D)
        k = 0

        st = state.get(g)
        if st is None:
            st = state[g] = [[], 0, 0]
        cands, ptr, scanned = st
        while k < n:
            if ptr >= len(cands):
                if scanned >= nb:
                    break
                # scan only bins appended since this class last looked
                m = (bused[scanned:nb] + reqs_c[bchoice[scanned:nb]]
                     <= bcap[scanned:nb] + EPS).all(axis=1)
                fresh = (scanned + np.flatnonzero(m)).tolist()
                scanned = nb
                if not fresh:
                    continue                   # next pass breaks
                cands = fresh
                ptr = 0
            b = cands[ptr]
            rt = reqs_c[bchoice[b]].tolist()
            ubt = bused[b].tolist()
            cbt = (bcap[b] + EPS).tolist()
            blist = bins[b].items
            filled = False
            while k < n:
                nt = [u + x for u, x in zip(ubt, rt)]
                if not all(v <= c for v, c in zip(nt, cbt)):
                    break
                blist.append(run_items[k])
                ubt = nt
                filled = True
                k += 1
            if filled:
                bused[b] = ubt
            if k < n:
                ptr += 1                       # saturated/unfitting for g
        st[0], st[1], st[2] = cands, ptr, scanned

        # nothing open fits the rest of the run: open bins by the
        # cost-efficiency rule, reusing the decision while it cannot change
        cached_choice: Optional[int] = None
        thr = float(kmax_of[g])               # head saturation threshold
        while k < n:
            head = n - k
            if cached_choice is not None and head >= thr:
                # the only change since the cached decision is the head
                # run's count, and every choice's head fill still saturates
                # below it — the cost-efficiency argmin cannot have moved
                best = cached_choice
            else:
                best = _choose_open(problem, pp, g, rest_blocks(ri, k),
                                    run_items[k])
                cached_choice = best if head >= thr else None
            if nb == cap_rows:
                grow()
            b = nb
            nb += 1
            bchoice[b] = best
            bcap[b] = pp.capacity[best]
            r = reqs_c[best]
            # the scalar path seeds the new bin with the item's own vector
            bused[b] = r
            bins.append(Bin(choice=best, items=[run_items[k]]))
            bin_used.append([0.0] * D)        # synced below
            k += 1
            rt = r.tolist()
            ubt = bused[b].tolist()
            cbt = (bcap[b] + EPS).tolist()
            blist = bins[b].items
            while k < n:
                nt = [u + x for u, x in zip(ubt, rt)]
                if not all(v <= c for v, c in zip(nt, cbt)):
                    break
                blist.append(run_items[k])
                ubt = nt
                k += 1
            bused[b] = ubt
        pos += n

    # sync the object view: pre-existing lists updated in place (the repair
    # planner keeps references), new bins get their final vectors
    for i in range(nb):
        bin_used[i][:] = [float(v) for v in bused[i]]
