"""Problem definition for multi-dimensional multiple-choice vector bin packing.

Items (streams) must each be assigned to exactly one bin. A bin is an instance
of a *choice* = (instance type, location); each choice has a usable capacity
vector (after the 90% head-room rule) and an hourly price. The requirement
vector of an item may differ per choice (CPU vs GPU execution profile) and may
be None (incompatible: program needs a GPU, or the camera's RTT circle
excludes the location). Objective: minimize total hourly price.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

EPS = 1e-9


@dataclasses.dataclass(frozen=True)
class Choice:
    """One (instance type, location) option — a truck model in the analogy.

    ``capacity`` is the usable (90%-capped) vector in the catalog's
    dimension units (cores, GiB, GPU fraction, GPU GiB for the paper
    catalogs; TFLOP/s and HBM GiB for the TPU one); ``price`` is $/hour.
    """

    key: str                      # e.g. "g2.2xlarge@us-east-1"
    type_name: str
    location: str
    capacity: tuple[float, ...]   # usable capacity (90%-capped)
    price: float                  # $/hour at this location
    has_gpu: bool = False         # carried from the catalog's InstanceType
    market: str = "ondemand"      # "ondemand", or "spot" for the market
                                  # twins built by core.markets (same
                                  # capacity, spot-walk price, reclaimable)


@dataclasses.dataclass(frozen=True)
class Item:
    """One stream; requirements[c] is its vector under choice c (None = incompatible)."""

    key: str
    requirements: tuple[Optional[tuple[float, ...]], ...]

    def compatible(self) -> list[int]:
        return [c for c, r in enumerate(self.requirements) if r is not None]


@dataclasses.dataclass(frozen=True)
class Problem:
    """One multiple-choice vector bin-packing instance: every item (stream)
    must land on exactly one bin (instance) of some choice, minimizing the
    summed $/hour price. Problems built by the packed ``build_problem``
    carry columnwise arrays (see :mod:`repro.core.packed`) as a non-field
    attribute; the object API is unaffected."""

    choices: tuple[Choice, ...]
    items: tuple[Item, ...]

    def __post_init__(self) -> None:
        dims = {len(c.capacity) for c in self.choices}
        if len(dims) > 1:
            raise ValueError("inconsistent capacity dimensionality")
        (d,) = dims or {0}
        # the packed builder shares one requirements tuple across all items
        # of a class — validating each distinct tuple once keeps construction
        # O(classes x choices), not O(items x choices). A lazy item sequence
        # (packed._PackedItemSeq) hands us the per-class tuples directly so
        # no item object needs to exist at all.
        distinct = getattr(self.items, "distinct_requirements", None)
        if distinct is not None:
            for g, reqs in enumerate(distinct()):
                if len(reqs) != len(self.choices):
                    raise ValueError(
                        f"class {g}: requirements must align with choices")
                for r in reqs:
                    if r is not None and len(r) != d:
                        raise ValueError(f"class {g}: bad vector length")
            return
        seen: set[int] = set()
        for it in self.items:
            if id(it.requirements) in seen:
                continue
            seen.add(id(it.requirements))
            if len(it.requirements) != len(self.choices):
                raise ValueError(f"item {it.key}: requirements must align with choices")
            for r in it.requirements:
                if r is not None and len(r) != d:
                    raise ValueError(f"item {it.key}: bad vector length")

    @property
    def ndim(self) -> int:
        return len(self.choices[0].capacity)


@dataclasses.dataclass
class Bin:
    """An opened instance: which choice it is and what is packed inside."""

    choice: int
    items: list[int] = dataclasses.field(default_factory=list)

    def used(self, problem: Problem) -> tuple[float, ...]:
        d = problem.ndim
        tot = [0.0] * d
        for i in self.items:
            r = problem.items[i].requirements[self.choice]
            assert r is not None
            for k in range(d):
                tot[k] += r[k]
        return tuple(tot)

    def residual(self, problem: Problem) -> tuple[float, ...]:
        """Capacity left in this bin (per dimension): what the repair
        planner's delta pass fills before opening new instances. Never
        negative (beyond float noise) in a valid solution."""
        cap = problem.choices[self.choice].capacity
        return tuple(c - u for c, u in zip(cap, self.used(problem)))


@dataclasses.dataclass
class Solution:
    """An assignment of every item to a bin; ``cost`` is the total rental
    price in $/hour. ``optimal`` marks exact-solver proofs (heuristics and
    repaired plans leave it False)."""

    bins: list[Bin]
    cost: float                   # $/hour
    optimal: bool = False
    note: str = ""

    def instance_counts(self, problem: Problem) -> dict[str, int]:
        out: dict[str, int] = {}
        for b in self.bins:
            k = problem.choices[b.choice].key
            out[k] = out.get(k, 0) + 1
        return out


class Infeasible(Exception):
    """No assignment exists (e.g. Fig. 3 scenario 3 under CPU-only strategy)."""


def validate(problem: Problem, sol: Solution) -> None:
    """Assert solution invariants: coverage, capacity, cost accounting.

    Problems carrying packed arrays are checked with a handful of numpy
    passes (identical invariants, same 1e-6 tolerances) — the per-item loop
    below is O(N x D) Python work per replan, which at a million streams
    would dwarf the packing itself."""
    if getattr(problem, "packed", None) is not None:
        _validate_packed(problem, sol)
        return
    seen: set[int] = set()
    cost = 0.0
    for b in sol.bins:
        ch = problem.choices[b.choice]
        cost += ch.price
        used = b.used(problem)
        for k in range(problem.ndim):
            if used[k] > ch.capacity[k] + 1e-6:
                raise AssertionError(
                    f"bin {ch.key} overfull in dim {k}: {used[k]} > {ch.capacity[k]}")
        for i in b.items:
            if i in seen:
                raise AssertionError(f"item {i} assigned twice")
            seen.add(i)
            if problem.items[i].requirements[b.choice] is None:
                raise AssertionError(f"item {i} incompatible with {ch.key}")
    if seen != set(range(len(problem.items))):
        raise AssertionError(f"items not covered: {set(range(len(problem.items))) - seen}")
    if abs(cost - sol.cost) > 1e-6:
        raise AssertionError(f"cost mismatch: {cost} vs {sol.cost}")


def _validate_packed(problem: Problem, sol: Solution) -> None:
    """Vectorized :func:`validate` over the problem's packed arrays."""
    import numpy as np

    pp = problem.packed                       # attached by the packed builder
    n_items = len(pp.item_class)
    bins = sol.bins
    nb = len(bins)
    lengths = np.fromiter((len(b.items) for b in bins),
                          dtype=np.int64, count=nb)
    total = int(lengths.sum()) if nb else 0
    flat = np.fromiter((i for b in bins for i in b.items),
                       dtype=np.int64, count=total)
    binc = np.fromiter((b.choice for b in bins), dtype=np.int64, count=nb)
    item_bin = np.repeat(np.arange(nb, dtype=np.int64), lengths)

    counts = np.bincount(flat, minlength=n_items) if total \
        else np.zeros(n_items, dtype=np.int64)
    if (counts > 1).any():
        raise AssertionError(
            f"item {int(np.argmax(counts > 1))} assigned twice")
    if (counts == 0).any():
        missing = set(np.flatnonzero(counts == 0).tolist())
        raise AssertionError(f"items not covered: {missing}")

    if total:
        cls = pp.item_class[flat]
        ch = binc[item_bin]
        compat = pp.class_compat[cls, ch]
        if not compat.all():
            k = int(np.argmin(compat))
            key = problem.choices[int(ch[k])].key
            raise AssertionError(
                f"item {int(flat[k])} incompatible with {key}")
        reqv = pp.class_req[cls, ch]          # (total, D)
        D = pp.ndim
        used = np.empty((nb, D))
        for d in range(D):
            used[:, d] = np.bincount(item_bin, weights=reqv[:, d],
                                     minlength=nb)
        cap = pp.capacity[binc]
        over = used > cap + 1e-6
        if over.any():
            b, d = np.unravel_index(int(np.argmax(over)), over.shape)
            raise AssertionError(
                f"bin {problem.choices[int(binc[b])].key} overfull in dim "
                f"{int(d)}: {used[b, d]} > {cap[b, d]}")
    cost = float(np.sum(pp.prices[binc])) if nb else 0.0
    if abs(cost - sol.cost) > 1e-6:
        raise AssertionError(f"cost mismatch: {cost} vs {sol.cost}")


def fits(req: Sequence[float], used: Sequence[float], cap: Sequence[float]) -> bool:
    return all(u + r <= c + EPS for r, u, c in zip(req, used, cap))


def residuals(problem: Problem, bins: Sequence[Bin]) -> list[tuple[float, ...]]:
    """Residual capacity vector of every bin, in bin order."""
    return [b.residual(problem) for b in bins]
