"""Adaptive runtime resource management [6,14].

Demands vary (rush hour, content complexity). The adaptive manager monitors
the demanded frame rates, re-solves when the current plan is infeasible or
when re-solving would save enough to justify migration, and applies
hysteresis so it does not thrash.

Replans come in two flavors. A **full** re-solve hands the whole fleet back
to the strategy (the default). **Repair** mode (``repair`` config, or
``strategy="REPAIR"``) routes replans through the incremental repair planner
instead: still-feasible placements stay put, only the delta — streams on
preempted/overloaded bins, plus arrivals — is re-packed, and a defrag escape
hatch falls back to a full plan when repaired cost drifts too far above a
fresh one (see core/repair.py). The event trace records per-event migration
counts and whether the defrag hatch fired.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional, Sequence

import numpy as np

from repro.core.catalog import Catalog
from repro.core.manager import ResourceManager
from repro.core.packed import get_packed
from repro.core.packing import EPS, Infeasible, fits
from repro.core.workload import requirement_columns
from repro.core.repair import (RepairConfig, RepairResult,
                               count_plan_migrations, repair_plan)
from repro.core.strategies import Plan
from repro.core.workload import Stream


@dataclasses.dataclass
class AdaptiveEvent:
    t: int
    action: str            # "keep" | "replan" | "forced-replan"
    hourly_cost: float
    migrations: int
    defrag: bool = False   # repair mode: the full-replan escape hatch fired
    recalibration: bool = False   # replan forced by a drift-triggered
                                  # re-profile (obs.RecalibratingPolicy)


# A replan trigger decides whether a *still-feasible* plan should even be
# re-evaluated this tick (computing a candidate plan costs a solver call).
# Signature: (t, streams, current_plan) -> bool. None = always evaluate.
ReplanTrigger = Callable[[int, Sequence[Stream], Plan], bool]


@dataclasses.dataclass
class AdaptiveManager:
    """Replans when demand drifts (rates in frames/s, costs in $/hour).

    ``savings_threshold``: fraction of current cost a replan must save to be
    worth the migration disruption (hysteresis). A plan that can no longer
    serve the demanded rates forces a replan regardless.

    ``replan_trigger`` makes the control loop pluggable: when the current
    plan is still feasible, the trigger decides whether to spend a solver
    call evaluating a cheaper candidate this tick (scheduled policies replan
    only at chosen hours; the default always evaluates). Infeasibility — or
    ``step(force=True)``, used by the fleet simulator to replay streams off
    preempted instances — bypasses the trigger.

    ``repair`` (or ``strategy="REPAIR"``) switches *replanning* to the
    min-migration repair planner; the config carries the migration budget
    and the defrag ratio. The first placement still uses the configured
    strategy (with no previous plan there is nothing to repair; the REPAIR
    strategy itself degrades to fresh FFD). Like FFD, the repair planner
    packs at each stream's own rate — ``target_fps`` does not apply to
    repaired replans.
    """

    manager: ResourceManager
    strategy: str = "ST3"
    savings_threshold: float = 0.10
    target_fps: Optional[float] = None
    replan_trigger: Optional[ReplanTrigger] = None
    repair: Optional[RepairConfig] = None
    # Mixed-market mode (core/markets.py): when ``mixed`` is set, planning
    # goes through ``manager.plan_mixed`` with the spot multipliers read
    # from ``multipliers_fn`` at every decision — plans carry on-demand and
    # spot bins, replans are min-migration mixed repairs.
    mixed: Optional[object] = None               # markets.MixedConfig
    multipliers_fn: Optional[Callable[[], dict]] = None

    # Capacity hold (model-predictive pre-booting, sim/mpc.py): while
    # ``t < hold_until`` voluntary cost-saving replans are *not adopted* —
    # capacity planned ahead of a forecast peak must survive the dip before
    # it instead of being drained as savings. Forced replans (infeasible
    # demand, preemption replays) and mixed-mode zero-migration repricing
    # are unaffected. The default never holds.
    hold_until: float = float("-inf")

    current: Optional[Plan] = None
    events: list = dataclasses.field(default_factory=list)
    # consumed by the next step(): marks its event as recalibration-forced
    recalibration_pending: bool = dataclasses.field(default=False,
                                                    repr=False)
    # consumed alongside the flag: restricts that replan's repair to bins
    # hosting these streams (per-group recalibration; None = unrestricted)
    recalibration_scope: Optional[frozenset] = dataclasses.field(
        default=None, repr=False)

    def __post_init__(self) -> None:
        if self.strategy == "REPAIR" and self.repair is None:
            self.repair = RepairConfig()

    def flag_recalibration(self,
                           scope: Optional[frozenset] = None) -> None:
        """Mark the *next* decision as recalibration-triggered (called by
        ``repro.obs.RecalibratingPolicy`` just before it forces a replan
        with the re-profiled calibration); the flag is consumed by the
        event that decision appends, so the trace records which replans
        the drift detector caused.

        ``scope`` (per-group recalibration, ``obs.regional``): restrict
        that replan's repair to bins hosting the given stream ids — healthy
        regions' placements are not consolidation fodder and the defrag
        escape hatch stays shut. Repair mode only; full re-solves and mixed
        plans have no bin identity to scope by, so it is ignored there."""
        self.recalibration_pending = True
        self.recalibration_scope = (frozenset(scope)
                                    if scope is not None else None)

    def _multipliers(self) -> dict:
        return self.multipliers_fn() if self.multipliers_fn is not None else {}

    @property
    def repair_mode(self) -> bool:
        return self.repair is not None or self.strategy == "REPAIR"

    def history(self) -> tuple[AdaptiveEvent, ...]:
        """The decision trace so far (immutable view for ledgers/reports)."""
        return tuple(self.events)

    def _plan_feasible_for(self, plan: Plan, streams: Sequence[Stream]) -> bool:
        """Can the already-rented instances serve the new demands in place?

        Each stream stays on its assigned instance; we recompute its
        requirement at the new fps and check capacities. A stream the plan
        has never placed (fleet churn: a camera that just came online) makes
        the plan infeasible — something must host it.
        """
        fast = self._plan_feasible_cols(plan, streams)
        if fast is not None:
            return fast
        by_key = {s.stream_id: s for s in streams}
        placed = {plan.problem.items[i].key
                  for b in plan.solution.bins for i in b.items}
        if any(s.stream_id not in placed for s in streams):
            return False
        for b in plan.solution.bins:
            ch = plan.problem.choices[b.choice]
            used = [0.0] * plan.problem.ndim
            for i in b.items:
                key = plan.problem.items[i].key
                s = by_key.get(key)
                if s is None:
                    continue
                itype = self.manager.catalog.get(ch.type_name)
                req = s.requirement_for(itype)
                if req is None:
                    return False
                if not fits(req, used, ch.capacity):
                    return False
                used = [u + r for u, r in zip(used, req)]
        return True

    def _plan_feasible_cols(self, plan: Plan, streams) -> Optional[bool]:
        """Columnar twin of the scalar walk above; None = preconditions not
        met, fall back to the per-item loop.

        Preconditions: the plan's problem carries packed arrays plus the
        ``packed_ids`` list, and ``streams`` is a StreamColumns built over
        *that same list object* — identity means the stream set is unchanged
        (only the fps column moved), so the "every stream placed" check
        reduces to the coverage the plan was validated with. Equivalence of
        the capacity check is exact, not approximate: the scalar ``fits``
        prefix sums are monotone nondecreasing (non-negative requirement
        vectors), so every per-item check passes iff the *final* per-bin
        per-dim total — accumulated in the same item order by ``bincount``,
        hence the same float — is within ``cap + EPS``."""
        pp = get_packed(plan.problem)
        ids = getattr(plan.problem, "packed_ids", None)
        if (pp is None or ids is None
                or getattr(streams, "ids", None) is not ids):
            return None
        bins = plan.solution.bins
        nb = len(bins)
        lengths = np.fromiter((len(b.items) for b in bins),
                              dtype=np.int64, count=nb)
        total = int(lengths.sum()) if nb else 0
        if total != len(ids):
            return None
        if total == 0:
            return True
        fps = streams.fps
        pcodes = streams.program_codes
        puniq = streams.programs_unique
        uf = np.unique(fps)
        combo = (pcodes.astype(np.int64) * len(uf)
                 + np.searchsorted(uf, fps))
        _, first, cls = np.unique(combo, return_index=True,
                                  return_inverse=True)

        choices = plan.problem.choices
        catalog = self.manager.catalog
        types: list = []
        tidx: dict[str, int] = {}
        tcode = np.empty(len(choices), dtype=np.int64)
        for c, ch in enumerate(choices):
            ti = tidx.get(ch.type_name)
            if ti is None:
                ti = len(types)
                tidx[ch.type_name] = ti
                types.append(catalog.get(ch.type_name))
            tcode[c] = ti
        D = pp.ndim
        reqmat = np.full((len(first), len(types), D), np.inf)
        for g, i0 in enumerate(first.tolist()):
            rep = Stream(stream_id="_feas",
                         program=puniq[int(pcodes[i0])],
                         fps=float(fps[i0]))
            for ti, r in enumerate(requirement_columns(rep, types, None)):
                if r is not None:
                    reqmat[g, ti] = r

        flat = np.fromiter((i for b in bins for i in b.items),
                           dtype=np.int64, count=total)
        item_bin = np.repeat(np.arange(nb, dtype=np.int64), lengths)
        bchoice = np.fromiter((b.choice for b in bins),
                              dtype=np.int64, count=nb)
        reqv = reqmat[cls[flat], tcode[bchoice[item_bin]]]   # (total, D)
        if not np.isfinite(reqv).all():
            return False                      # some stream lost compatibility
        used = np.empty((nb, D))
        for d in range(D):
            used[:, d] = np.bincount(item_bin, weights=reqv[:, d],
                                     minlength=nb)
        cap = pp.capacity[bchoice]
        return bool((used <= cap + EPS).all())

    def _candidate(self, streams: Sequence[Stream],
                   scope: Optional[frozenset] = None
                   ) -> tuple[Plan, int, bool]:
        """(candidate plan, migrations it would perform, defrag?)."""
        if self.mixed is not None:
            res = self.manager.plan_mixed(streams, self._multipliers(),
                                          previous=self.current,
                                          config=self.mixed)
            return res.plan, res.migrations, res.defrag
        if self.repair_mode:
            res: RepairResult = repair_plan(
                streams, self.manager.catalog, previous=self.current,
                config=self.repair or RepairConfig(), scope=scope)
            return res.plan, res.migrations, res.defrag
        candidate = self.manager.plan(streams, self.strategy, self.target_fps)
        migrations = (0 if self.current is None
                      else _count_migrations(self.current, candidate))
        return candidate, migrations, False

    def step(self, t: int, streams: Sequence[Stream], *,
             force: bool = False) -> Plan:
        """One control-loop tick with the current demanded streams.

        ``force=True`` treats the current plan as infeasible regardless of
        capacity (e.g. an instance it relies on was spot-preempted).
        """
        recal = self.recalibration_pending
        scope = self.recalibration_scope if recal else None
        self.recalibration_pending = False
        self.recalibration_scope = None
        if self.current is None:
            # first placement goes through the configured strategy — repair
            # mode only changes how *replans* are computed (with no previous
            # plan there is nothing to repair anyway); mixed mode plans the
            # initial floor/burst split fresh
            if self.mixed is not None:
                self.current = self.manager.plan_mixed(
                    streams, self._multipliers(), config=self.mixed).plan
            else:
                self.current = self.manager.plan(streams, self.strategy,
                                                 self.target_fps)
            # every stream is an arrival, nothing migrates
            self.events.append(AdaptiveEvent(t, "replan",
                                             self.current.hourly_cost,
                                             migrations=0,
                                             recalibration=recal))
            return self.current

        feasible = (not force) and self._plan_feasible_for(self.current, streams)
        if feasible and self.replan_trigger is not None \
                and not self.replan_trigger(t, streams, self.current):
            self.events.append(AdaptiveEvent(t, "keep",
                                             self.current.hourly_cost, 0,
                                             recalibration=recal))
            return self.current
        candidate, migrations, defrag = self._candidate(streams, scope)
        if not feasible:
            self.current = candidate
            self.events.append(AdaptiveEvent(t, "forced-replan",
                                             candidate.hourly_cost, migrations,
                                             defrag=defrag,
                                             recalibration=recal))
        elif (t >= self.hold_until
              and candidate.hourly_cost
              < self.current.hourly_cost * (1 - self.savings_threshold)) \
                or (self.mixed is not None and migrations == 0
                    and candidate.hourly_cost != self.current.hourly_cost):
            # mixed mode: a zero-migration candidate is the same placement
            # repriced at the current spot quotes — adopting it is free and
            # keeps the plan's $/hour honest as the price walk moves
            self.current = candidate
            self.events.append(AdaptiveEvent(t, "replan", candidate.hourly_cost,
                                             migrations, defrag=defrag,
                                             recalibration=recal))
        else:
            self.events.append(AdaptiveEvent(t, "keep",
                                             self.current.hourly_cost, 0,
                                             recalibration=recal))
        return self.current

    def total_cost(self) -> float:
        """Integrated cost over all ticks (1 tick = 1 hour)."""
        return sum(e.hourly_cost for e in self.events)

    def total_migrations(self) -> int:
        return sum(e.migrations for e in self.events)

    def defrags(self) -> int:
        return sum(1 for e in self.events if e.defrag)


def _count_migrations(old: Plan, new: Plan) -> int:
    """Streams that *moved* between plans. A newly arrived stream has no
    prior placement — placing it is a boot, not a migration — and a departed
    stream migrates nowhere either. Delegates to the ordinal-aware plan
    diff, which sees moves between two instances of one (type, location)
    but can over-count when a bin's position shifts within its key: a full
    re-solve has no bin identity to track, so this is an upper bound on the
    moves the cluster's sticky reconcile will actually perform. Repair-mode
    events carry exact counts (origin-tracked); the simulation ledger's
    per-tick physical count is the unbiased metric for comparing the two."""
    return count_plan_migrations(old, new)
