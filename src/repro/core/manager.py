"""Resource manager facade (Fig. 1 of the paper).

Inputs: the analysis programs and their per-stream requirements, desired frame
rates, camera locations, and the instance catalog. Output: a Plan — which
instances to rent where, and which streams run on each.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

from repro.core import strategies
from repro.core.catalog import Catalog
from repro.core.packing import Infeasible
from repro.core.strategies import Plan
from repro.core.workload import Stream


@dataclasses.dataclass
class ResourceManager:
    """The paper's cloud resource manager (Fig. 1): plan instance rentals.

    Given streams (each demanding a frame rate in frames/s) and a
    :class:`~repro.core.catalog.Catalog` of instance types priced in $/hour
    per location, ``plan`` runs the named strategy from
    :data:`~repro.core.strategies.STRATEGIES` (exact packing, greedy
    baselines, FFD, or incremental REPAIR) and returns a
    :class:`~repro.core.strategies.Plan` whose ``hourly_cost`` is the total
    rental price in $/hour.
    """

    catalog: Catalog
    default_strategy: str = "ST3"

    def plan(self, streams: Sequence[Stream], strategy: Optional[str] = None,
             target_fps: Optional[float] = None,
             previous: Optional[Plan] = None) -> Plan:
        name = strategy or self.default_strategy
        fn = strategies.STRATEGIES[name]
        if name in ("NL", "ARMVAC", "ARMVAC+", "GCL"):
            if target_fps is None:
                raise ValueError(f"{name} requires target_fps")
            return fn(streams, self.catalog, target_fps)
        if name == "REPAIR":
            # incremental: the previous plan is planner state, not a hint
            return fn(streams, self.catalog, previous=previous)
        return fn(streams, self.catalog)

    def plan_mixed(self, streams: Sequence[Stream], multipliers,
                   previous: Optional[Plan] = None, config=None):
        """Mixed on-demand/spot planning (see :mod:`repro.core.markets`):
        pack under the per-class on-demand floor and the spot anti-affinity
        rule, at current spot prices (``multipliers`` maps region ->
        spot/on-demand price ratio). With ``previous``, replans are
        min-migration repairs of the mixed plan. Returns a
        :class:`~repro.core.markets.MixedResult`."""
        from repro.core.markets import MixedConfig, mixed_plan
        return mixed_plan(streams, self.catalog, multipliers,
                          previous=previous, config=config or MixedConfig())

    def plan_or_fail(self, streams: Sequence[Stream], strategy: str,
                     target_fps: Optional[float] = None):
        """Like plan() but returns None on infeasibility (Fig. 3 'Fail' cells)."""
        try:
            return self.plan(streams, strategy, target_fps)
        except Infeasible:
            return None

    def utilization(self, plan: Plan) -> list[dict]:
        """Per-instance utilization report; the 90% cap is already inside the
        usable capacities, so fractions here are of the *usable* envelope."""
        out = []
        for b in plan.solution.bins:
            ch = plan.problem.choices[b.choice]
            used = b.used(plan.problem)
            frac = tuple((u / c if c > 0 else 0.0) for u, c in zip(used, ch.capacity))
            out.append({
                "instance": ch.key,
                "streams": [plan.problem.items[i].key for i in b.items],
                "utilization_of_usable": tuple(round(f, 3) for f in frac),
            })
        return out
