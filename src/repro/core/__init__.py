"""Core: the paper's cloud resource-allocation manager.

Public API:
    Catalog / InstanceType / fig3_catalog / fig6_catalog / table1_catalog
    Stream / AnalysisProgram / VGG16 / ZF / FIG3_SCENARIOS / make_streams
    AnalysisPipeline / PipelineStage / PIPELINES / scaled_program
    ResourceManager / AdaptiveManager / Plan
    strategies: ST1/ST2/ST3 (CPU-GPU), NL/ARMVAC/GCL (location-aware)
    solver: exact branch-and-bound MDMC vector-bin-packing
    arcflow: Brandão–Pedroso arc-flow graphs with compression
"""
from repro.core.adaptive import AdaptiveManager
from repro.core.catalog import (Catalog, InstanceType, UTILIZATION_CAP,
                                fig3_catalog, fig6_catalog, table1_catalog)
from repro.core.manager import ResourceManager
from repro.core.markets import (MarketQuote, MixedConfig, MixedResult,
                                mixed_plan, quotes, replica_group,
                                spot_affinity_violations, spot_problem)
from repro.core.packing import (Bin, Choice, Infeasible, Item, Problem,
                                Solution, validate)
from repro.core.repair import (RepairConfig, RepairResult,
                               count_plan_migrations, plan_assignment,
                               repair_plan)
from repro.core.strategies import Plan, STRATEGIES, build_problem
from repro.core.workload import (FIG3_SCENARIOS, PIPELINES, PROGRAMS, VGG16,
                                 ZF, AnalysisPipeline, AnalysisProgram,
                                 PipelineStage, Stream, make_streams,
                                 scaled_program)

__all__ = [
    "AdaptiveManager", "AnalysisPipeline", "AnalysisProgram", "Bin",
    "Catalog", "Choice",
    "FIG3_SCENARIOS", "Infeasible", "InstanceType", "Item", "MarketQuote",
    "MixedConfig", "MixedResult", "PIPELINES", "PROGRAMS",
    "Plan", "PipelineStage", "Problem", "RepairConfig", "RepairResult",
    "ResourceManager",
    "STRATEGIES", "Solution", "Stream", "UTILIZATION_CAP", "VGG16", "ZF",
    "build_problem", "count_plan_migrations", "fig3_catalog", "fig6_catalog",
    "make_streams", "mixed_plan", "plan_assignment", "quotes", "repair_plan",
    "replica_group", "scaled_program", "spot_affinity_violations",
    "spot_problem", "table1_catalog", "validate",
]
