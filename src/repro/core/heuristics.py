"""Greedy heuristics: first-fit-decreasing and cheapest-instance-first (ARMVAC core).

These provide (a) the incumbent for the exact branch-and-bound solver and
(b) the paper's greedy baselines.
"""
from __future__ import annotations

from typing import Optional

from repro.core.packing import (
    Bin, Choice, Infeasible, Item, Problem, Solution, fits,
)


def _norm_size(problem: Problem, item: Item) -> float:
    """Item size for the decreasing order: max normalized dim over the item's
    *cheapest-per-unit* compatible choice (standard l_inf FFD for VBP)."""
    best = 0.0
    any_ok = False
    for c in item.compatible():
        any_ok = True
        req = item.requirements[c]
        cap = problem.choices[c].capacity
        frac = max((r / k if k > 0 else (0.0 if r <= 0 else float("inf")))
                   for r, k in zip(req, cap))
        best = max(best, frac)
    if not any_ok:
        raise Infeasible(f"item {item.key} has no compatible choice")
    return best


def _cost_efficiency(problem: Problem, choice_idx: int, remaining_items: list[int]) -> float:
    """Price per unit of 'how many of the remaining items this choice could hold'
    — a greedy desirability score (lower is better)."""
    ch = problem.choices[choice_idx]
    count = 0
    used = [0.0] * problem.ndim
    for i in remaining_items:
        req = problem.items[i].requirements[choice_idx]
        if req is None:
            continue
        if fits(req, used, ch.capacity):
            used = [u + r for u, r in zip(used, req)]
            count += 1
    if count == 0:
        return float("inf")
    return ch.price / count


def ffd_pack_into(problem: Problem, bins: list[Bin],
                  bin_used: list[list[float]], items) -> None:
    """First-fit the given item indices (decreasing norm-size order) into
    ``bins``/``bin_used`` (mutated in place; new bins append), opening a new
    bin by the lowest price-per-held-items rule when nothing fits. Shared by
    :func:`first_fit_decreasing` (empty seed) and the repair planner's delta
    pass (seeded with the kept bins, so residual capacity fills first).

    Problems built by the packed (columnwise) ``build_problem`` path carry
    class-structured arrays and dispatch to the vectorized packer in
    :mod:`repro.core.packed`, which produces bit-identical bins (see
    tests/test_packed_parity.py); hand-built problems take the scalar loop
    below.
    """
    from repro.core import packed as _packed
    pp = _packed.get_packed(problem)
    if pp is not None:
        _packed.ffd_pack_packed(problem, pp, bins, bin_used, items)
        return
    _ffd_pack_into_scalar(problem, bins, bin_used, items)


def _ffd_pack_into_scalar(problem: Problem, bins: list[Bin],
                          bin_used: list[list[float]], items) -> None:
    """The original per-item FFD loop — the parity/speedup baseline."""
    order = sorted(items, key=lambda i: _norm_size(problem, problem.items[i]),
                   reverse=True)
    for pos, i in enumerate(order):
        item = problem.items[i]
        placed = False
        for b, used in zip(bins, bin_used):
            req = item.requirements[b.choice]
            if req is None:
                continue
            if fits(req, used, problem.choices[b.choice].capacity):
                b.items.append(i)
                for k in range(problem.ndim):
                    used[k] += req[k]
                placed = True
                break
        if not placed:
            rest = order[pos:]
            cands = item.compatible()
            if not cands:
                raise Infeasible(f"item {item.key} has no compatible choice")
            c = min(cands, key=lambda c: (_cost_efficiency(problem, c, rest),
                                          problem.choices[c].price))
            if _cost_efficiency(problem, c, rest) == float("inf"):
                raise Infeasible(f"item {item.key} fits no empty instance")
            bins.append(Bin(choice=c, items=[i]))
            bin_used.append(list(item.requirements[c]))


def first_fit_decreasing(problem: Problem) -> Solution:
    """FFD over items; for each item try open bins, else open the bin whose
    price-per-held-items is lowest among compatible choices."""
    bins: list[Bin] = []
    bin_used: list[list[float]] = []
    ffd_pack_into(problem, bins, bin_used, range(len(problem.items)))
    cost = sum(problem.choices[b.choice].price for b in bins)
    return Solution(bins=bins, cost=cost, optimal=False, note="ffd")


def lowest_price_first(problem: Problem) -> Solution:
    """The paper's literal ARMVAC packing rule [6,8]: "selects the lowest-cost
    instances from the remaining pool, and sends as many data streams to this
    instance" — i.e. pick the instance with the lowest *hourly price* that can
    still hold at least one remaining stream, fill it, repeat. This is exactly
    why ARMVAC underperforms in the 1–20 fps mid-band: it keeps renting cheap
    small instances where one bigger/GPU instance is cheaper per stream.
    """
    remaining = sorted(range(len(problem.items)),
                       key=lambda i: _norm_size(problem, problem.items[i]),
                       reverse=True)
    bins: list[Bin] = []
    cost = 0.0
    by_price = sorted(range(len(problem.choices)),
                      key=lambda c: (problem.choices[c].price, problem.choices[c].key))
    while remaining:
        chosen = None
        for c in by_price:
            ch = problem.choices[c]
            if any(problem.items[i].requirements[c] is not None and
                   fits(problem.items[i].requirements[c], [0.0] * problem.ndim,
                        ch.capacity)
                   for i in remaining):
                chosen = c
                break
        if chosen is None:
            raise Infeasible(f"no choice can hold any of {len(remaining)} remaining streams")
        ch = problem.choices[chosen]
        b = Bin(choice=chosen)
        used = [0.0] * problem.ndim
        still: list[int] = []
        for i in remaining:
            req = problem.items[i].requirements[chosen]
            if req is not None and fits(req, used, ch.capacity):
                b.items.append(i)
                for k in range(problem.ndim):
                    used[k] += req[k]
            else:
                still.append(i)
        bins.append(b)
        cost += ch.price
        remaining = still
    return Solution(bins=bins, cost=cost, optimal=False, note="lowest-price-first")


def cheapest_instance_first(problem: Problem) -> Solution:
    """ARMVAC's packing core [6,8]: repeatedly pick the most cost-efficient
    choice for the remaining streams, open one instance of it, and push as many
    remaining streams into it as fit (in decreasing size order)."""
    remaining = sorted(range(len(problem.items)),
                       key=lambda i: _norm_size(problem, problem.items[i]),
                       reverse=True)
    bins: list[Bin] = []
    cost = 0.0
    while remaining:
        best_c = min(range(len(problem.choices)),
                     key=lambda c: (_cost_efficiency(problem, c, remaining),
                                    problem.choices[c].price))
        if _cost_efficiency(problem, best_c, remaining) == float("inf"):
            raise Infeasible(f"no choice can hold any of {len(remaining)} remaining streams")
        ch = problem.choices[best_c]
        b = Bin(choice=best_c)
        used = [0.0] * problem.ndim
        still: list[int] = []
        for i in remaining:
            req = problem.items[i].requirements[best_c]
            if req is not None and fits(req, used, ch.capacity):
                b.items.append(i)
                for k in range(problem.ndim):
                    used[k] += req[k]
            else:
                still.append(i)
        bins.append(b)
        cost += ch.price
        remaining = still
    return Solution(bins=bins, cost=cost, optimal=False, note="cheapest-first")
