"""Arc-flow formulation with graph compression (Brandão & Pedroso [9,10]).

The paper's sidebar builds, per truck (instance) type, a DAG whose nodes are
capacity-usage states and whose arcs place one box (stream). Any source→sink
path is a feasible packing *pattern* for one bin. The multiple-choice variant
keeps one graph per bin type coupled by demand constraints.

We reproduce that construction faithfully for integer-quantized requirement
vectors: items are added type by type (bounded by demand), then the graph is
*compressed* by hash-consing suffix-equivalent nodes (two states whose
remaining-capacity future is identical are merged), which is what makes
hundreds-of-boxes instances tractable in [9].

Downstream use: the exact solver (solver.py) is the branch-and-cut
replacement; this module provides (a) a validated pattern enumerator used in
tests to cross-check the solver on single-choice instances, and (b) per-choice
``max_items_per_bin`` bounds used by heuristics.
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import Iterator, Sequence


@dataclasses.dataclass(frozen=True)
class IntItem:
    """Quantized item type: integer vector + demand (how many such boxes)."""

    vector: tuple[int, ...]
    demand: int
    label: str = ""


@dataclasses.dataclass
class ArcFlowGraph:
    capacity: tuple[int, ...]
    # arcs: (src_state, dst_state, item_index or -1 for loss arc)
    arcs: list[tuple[tuple[int, ...], tuple[int, ...], int]]
    nodes: set[tuple[int, ...]]
    items: tuple[IntItem, ...]

    @property
    def source(self) -> tuple[int, ...]:
        return tuple(0 for _ in self.capacity)

    @property
    def sink(self) -> tuple[int, ...]:
        return self.capacity


def quantize(vectors: Sequence[Sequence[float]], capacity: Sequence[float],
             levels: int = 200) -> tuple[list[tuple[int, ...]], tuple[int, ...]]:
    """Round item vectors up (conservative) onto an integer grid per dimension."""
    nd = len(capacity)
    cap_int = tuple(levels for _ in range(nd))
    out = []
    for v in vectors:
        q = []
        for d in range(nd):
            if capacity[d] <= 0:
                q.append(0 if v[d] <= 0 else levels + 1)  # cannot fit
            else:
                q.append(int(-(-v[d] * levels // capacity[d])))  # ceil
        out.append(tuple(q))
    return out, cap_int


def build_graph(capacity: tuple[int, ...], items: Sequence[IntItem]) -> ArcFlowGraph:
    """Level-by-level construction: item types in the given order; each type
    expands every current node by up to ``demand`` placements."""
    nd = len(capacity)
    nodes: set[tuple[int, ...]] = {tuple(0 for _ in range(nd))}
    arcs: list[tuple[tuple[int, ...], tuple[int, ...], int]] = []
    seen_arcs: set[tuple[tuple[int, ...], tuple[int, ...], int]] = set()

    for idx, item in enumerate(items):
        frontier = sorted(nodes)
        for node in frontier:
            cur = node
            for _rep in range(item.demand):
                nxt = tuple(c + v for c, v in zip(cur, item.vector))
                if any(x > cap for x, cap in zip(nxt, capacity)):
                    break
                arc = (cur, nxt, idx)
                if arc not in seen_arcs:
                    seen_arcs.add(arc)
                    arcs.append(arc)
                nodes.add(nxt)
                cur = nxt

    # loss arcs: every node can terminate (connect to the sink)
    sink = capacity
    for node in sorted(nodes):
        if node != sink:
            arcs.append((node, sink, -1))
    nodes.add(sink)
    return ArcFlowGraph(capacity=capacity, arcs=arcs, nodes=nodes, items=tuple(items))


def compress(graph: ArcFlowGraph) -> ArcFlowGraph:
    """Merge suffix-equivalent nodes (hash-consing of outgoing structure).

    Two nodes with identical sets of (item, merged-destination) outgoing arcs
    accept exactly the same future packings, so they are interchangeable —
    this is the practical effect of the compression step in [9].
    """
    out_arcs: dict[tuple[int, ...], list[tuple[tuple[int, ...], int]]] = {}
    for src, dst, it in graph.arcs:
        out_arcs.setdefault(src, []).append((dst, it))

    # process nodes in reverse topological order (sum of coords descending)
    order = sorted(graph.nodes, key=lambda n: sum(n), reverse=True)
    canon: dict[tuple[int, ...], tuple[int, ...]] = {}
    sig_to_node: dict[tuple, tuple[int, ...]] = {}
    for node in order:
        outs = frozenset((canon.get(d, d), it) for d, it in out_arcs.get(node, []))
        sig = (outs,)
        if sig in sig_to_node:
            canon[node] = sig_to_node[sig]
        else:
            canon[node] = node
            sig_to_node[sig] = node

    new_arcs: list[tuple[tuple[int, ...], tuple[int, ...], int]] = []
    seen: set = set()
    for src, dst, it in graph.arcs:
        a = (canon.get(src, src), canon.get(dst, dst), it)
        if a[0] == a[1] and it == -1:
            continue
        if a not in seen:
            seen.add(a)
            new_arcs.append(a)
    new_nodes = {canon.get(n, n) for n in graph.nodes}
    return ArcFlowGraph(capacity=graph.capacity, arcs=new_arcs, nodes=new_nodes,
                        items=graph.items)


def patterns(graph: ArcFlowGraph, limit: int = 100_000) -> Iterator[tuple[int, ...]]:
    """Enumerate packing patterns (item-count multisets) as source→sink paths.

    Demand bounds are enforced per path. Patterns are deduplicated.
    """
    out_arcs: dict[tuple[int, ...], list[tuple[tuple[int, ...], int]]] = {}
    for src, dst, it in graph.arcs:
        out_arcs.setdefault(src, []).append((dst, it))
    nitems = len(graph.items)
    emitted: set[tuple[int, ...]] = set()
    budget = [limit]

    def rec(node: tuple[int, ...], counts: list[int]) -> Iterator[tuple[int, ...]]:
        if budget[0] <= 0:
            return
        if node == graph.sink:
            pat = tuple(counts)
            if pat not in emitted:
                emitted.add(pat)
                budget[0] -= 1
                yield pat
            return
        for dst, it in out_arcs.get(node, []):
            if it >= 0:
                if counts[it] >= graph.items[it].demand:
                    continue
                counts[it] += 1
                yield from rec(dst, counts)
                counts[it] -= 1
            else:
                yield from rec(dst, counts)

    yield from rec(graph.source, [0] * nitems)


def max_items_per_bin(graph: ArcFlowGraph) -> int:
    """Longest source→sink path in item-arcs — how many boxes one bin can hold."""
    best = 0
    for pat in patterns(graph):
        best = max(best, sum(pat))
    return best


def min_bins_from_patterns(graph: ArcFlowGraph) -> int:
    """Exact minimum number of identical bins covering all demands, by
    branch-and-bound over the enumerated pattern set (small instances)."""
    pats = [p for p in patterns(graph) if sum(p) > 0]
    if not pats:
        if all(it.demand == 0 for it in graph.items):
            return 0
        raise ValueError("no feasible pattern but demand > 0")
    # prefer patterns that pack more
    pats.sort(key=sum, reverse=True)
    demand = tuple(it.demand for it in graph.items)
    best = [sum(demand)]  # one bin per box is an upper bound IF each fits alone

    def rec(remaining: tuple[int, ...], used: int) -> None:
        if used >= best[0]:
            return
        if all(r <= 0 for r in remaining):
            best[0] = used
            return
        # lower bound: total remaining items / max pattern size
        maxp = sum(pats[0])
        lb = -(-sum(max(r, 0) for r in remaining) // maxp)
        if used + lb >= best[0]:
            return
        tried = set()
        for p in pats:
            # clip pattern to remaining demand to avoid waste-equivalent branches
            eff = tuple(min(c, max(r, 0)) for c, r in zip(p, remaining))
            if sum(eff) == 0 or eff in tried:
                continue
            tried.add(eff)
            rec(tuple(r - c for r, c in zip(remaining, eff)), used + 1)

    rec(demand, 0)
    return best[0]
