"""Geography: cameras, datacenters, RTT model, and RTT-feasibility (Fig. 4).

Chen et al. [5] observed that the achievable frame rate of a pull-based
network-camera stream drops as the camera<->instance round-trip time grows.
We model the achievable frame rate as ``fps_max(rtt_ms) = RTT_BUDGET / rtt_ms``:
a stream with target frame rate f is feasible at a location iff
``rtt(camera, location) <= RTT_BUDGET / f``. With RTT_BUDGET = 1000 this gives
the paper's regimes: below 1 fps almost every location is feasible (circles
cover the globe, Fig. 4b); above 20 fps only nearby datacenters qualify
(Fig. 4a); 1-20 fps is the interesting mid-band.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Mapping

RTT_BUDGET_MS = 1000.0          # fps * rtt_ms <= RTT_BUDGET_MS
FIBER_MS_PER_KM = 0.01          # ~200 km/ms one way -> 0.01 ms/km round trip x2 below
RTT_OVERHEAD_MS = 10.0          # handshake / last-mile constant


@dataclasses.dataclass(frozen=True)
class Place:
    name: str
    lat: float
    lon: float


# Cloud datacenters (region name -> coordinates), EC2-style regions.
DATACENTERS: Mapping[str, Place] = {
    "us-east-1": Place("N. Virginia", 38.95, -77.45),
    "us-west-2": Place("Oregon", 45.60, -122.60),
    "sa-east-1": Place("Sao Paulo", -23.55, -46.63),
    "eu-west-1": Place("Ireland", 53.35, -6.26),
    "eu-central-1": Place("Frankfurt", 50.11, 8.68),
    "ap-southeast-1": Place("Singapore", 1.35, 103.82),
    "ap-northeast-1": Place("Tokyo", 35.68, 139.69),
    "ap-southeast-2": Place("Sydney", -33.87, 151.21),
    "ap-south-1": Place("Mumbai", 19.08, 72.88),
}

# Worldwide network cameras, mirroring the paper's Fig. 4 world map.
CAMERAS: Mapping[str, Place] = {
    "nyc": Place("New York", 40.71, -74.01),
    "chicago": Place("Chicago", 41.88, -87.63),
    "la": Place("Los Angeles", 34.05, -118.24),
    "saopaulo": Place("Sao Paulo", -23.55, -46.63),
    "london": Place("London", 51.51, -0.13),
    "paris": Place("Paris", 48.86, 2.35),
    "berlin": Place("Berlin", 52.52, 13.40),
    "singapore": Place("Singapore", 1.29, 103.85),
    "tokyo": Place("Tokyo", 35.68, 139.69),
    "sydney": Place("Sydney", -33.87, 151.21),
    "mumbai": Place("Mumbai", 19.08, 72.88),
    "seattle": Place("Seattle", 47.61, -122.33),
}


def haversine_km(a: Place, b: Place) -> float:
    r = 6371.0
    p1, p2 = math.radians(a.lat), math.radians(b.lat)
    dp = p2 - p1
    dl = math.radians(b.lon - a.lon)
    h = math.sin(dp / 2) ** 2 + math.cos(p1) * math.cos(p2) * math.sin(dl / 2) ** 2
    return 2 * r * math.asin(math.sqrt(h))


def rtt_ms(camera: str, region: str) -> float:
    """Round-trip time estimate between a camera and a datacenter region."""
    cam, dc = CAMERAS[camera], DATACENTERS[region]
    km = haversine_km(cam, dc)
    return RTT_OVERHEAD_MS + 2.0 * km * FIBER_MS_PER_KM


def max_fps(camera: str, region: str) -> float:
    """Highest frame rate sustainable from this camera at this region [5]."""
    return RTT_BUDGET_MS / rtt_ms(camera, region)


def feasible_regions(camera: str, fps: float, regions) -> list[str]:
    """Regions inside the camera's Fig.-4 circle for this target frame rate."""
    return [r for r in regions if max_fps(camera, r) >= fps]


def nearest_region(camera: str, regions) -> str:
    return min(regions, key=lambda r: rtt_ms(camera, r))


# ---------------------------------------------------------------------------
# Local (solar) time — the fleet simulator's diurnal demand curves peak at a
# camera's *local* rush hours, so a worldwide fleet ramps region by region as
# the sun moves ("follow the sun").
# ---------------------------------------------------------------------------

def utc_offset_hours(place: Place | str) -> float:
    """Solar-time UTC offset from longitude (15 degrees of longitude = 1 h).

    A mean-solar-time approximation of the timezone: it ignores political
    timezone boundaries and DST, which is exactly what a demand model keyed
    to daylight/rush-hour behaviour wants.
    """
    if isinstance(place, str):
        place = CAMERAS.get(place) or DATACENTERS[place]
    return place.lon / 15.0


def local_hour(utc_hour: float, place: Place | str) -> float:
    """Local solar hour-of-day in [0, 24) for a UTC simulation time in hours.

    ``place`` is a camera id, a datacenter region id, or a ``Place``.
    """
    return (utc_hour + utc_offset_hours(place)) % 24.0
