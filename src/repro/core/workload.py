"""Stream workloads and the calibrated per-program resource model.

A *stream* = one analysis program running on one camera's data at a desired
frame rate (a "box" in the paper's truck analogy). Its resource requirement
vector depends on which kind of instance executes it (CPU-only vs GPU) — this
is the *multiple-choice* part of the packing problem.

Calibration. The paper does not publish the raw per-program utilization
coefficients, only the outcomes (Fig. 3) and qualitative facts (GPU speedup up
to 16x at high frame rates, <5% benefit at low rates; performance degrades
past 90% utilization). The linear coefficients below are fitted so that the
solver reproduces *all nine cells* of Fig. 3 exactly — instance counts and
dollar figures — under the Fig. 3 catalog. See tests/test_fig3.py.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

from repro.core.catalog import InstanceType


@dataclasses.dataclass(frozen=True)
class AnalysisProgram:
    """Resource model of one computer-vision program (VGG16, ZF, ...).

    Requirements are linear in frame rate: ``base + per_fps * fps`` per
    dimension, with separate CPU-execution and GPU-execution profiles.
    ``cpu_cores_per_fps=None`` in the GPU profile's host part means the GPU
    profile still consumes some host cores to decode/feed frames.
    """

    name: str
    # CPU execution profile
    cpu_cores_per_fps: float              # cores needed per frame/second on CPU
    cpu_mem_gib: float                    # host memory (model + buffers)
    # GPU execution profile
    gpu_frac_per_fps: float               # fraction of one GPU per frame/second
    gpu_mem_base_gib: float               # GPU memory: model weights
    gpu_mem_per_fps_gib: float            # GPU memory: frame buffers
    gpu_feed_cores: float = 0.5           # host cores to fetch/decode the stream
    supports_cpu: bool = True
    supports_gpu: bool = True

    def cpu_requirement(self, fps: float) -> tuple[float, ...]:
        """(cpu_cores, memory_gib, gpu_compute, gpu_memory_gib) on a CPU instance."""
        return (self.cpu_cores_per_fps * fps, self.cpu_mem_gib, 0.0, 0.0)

    def gpu_requirement(self, fps: float) -> tuple[float, ...]:
        return (
            self.gpu_feed_cores,
            self.cpu_mem_gib,
            self.gpu_frac_per_fps * fps,
            self.gpu_mem_base_gib + self.gpu_mem_per_fps_gib * fps,
        )

    def max_cpu_fps(self, cores_usable: float) -> float:
        return cores_usable / self.cpu_cores_per_fps

    def max_gpu_fps(self, gpu_usable: float = 0.9) -> float:
        return gpu_usable / self.gpu_frac_per_fps

    def gpu_speedup(self, fps: float, cores_usable: float = 7.2) -> float:
        """Effective GPU speedup at a target frame rate (paper: up to 16x at
        high rates, <5% at the lowest rates — batching amortization)."""
        peak = self.max_gpu_fps() / self.max_cpu_fps(cores_usable)
        return max(1.0, min(peak, peak * fps / self.max_gpu_fps()))


# Fitted to reproduce Fig. 3 exactly (see module docstring).
VGG16 = AnalysisProgram(
    name="VGG16",
    cpu_cores_per_fps=16.0,      # 0.45 fps max on a c4.2xlarge (7.2 usable cores)
    cpu_mem_gib=2.0,
    gpu_frac_per_fps=0.32,       # 2.81 fps max on one GPU -> ~6.3x speedup
    gpu_mem_base_gib=0.5,        # ~528 MB of weights
    gpu_mem_per_fps_gib=0.3,
)

ZF = AnalysisProgram(
    name="ZF",
    cpu_cores_per_fps=7.2,       # 1.0 fps max on a c4.2xlarge
    cpu_mem_gib=1.5,
    gpu_frac_per_fps=0.056,      # 16.07 fps max on one GPU -> ~16x speedup
    gpu_mem_base_gib=0.25,
    gpu_mem_per_fps_gib=0.35,
)

PROGRAMS = {"VGG16": VGG16, "ZF": ZF}


@dataclasses.dataclass(frozen=True)
class Stream:
    """One analysis program bound to one camera at a desired frame rate
    (``fps`` in frames/s); the box being packed onto $/hour instances."""

    stream_id: str
    program: AnalysisProgram
    fps: float
    camera: Optional[str] = None          # camera id for the geo experiments
    frame_pixels: int = 640 * 480         # kept for completeness; folded into fps cost

    def requirement_for(self, itype: InstanceType,
                        fps: Optional[float] = None) -> Optional[tuple[float, ...]]:
        """Requirement vector on this instance type, or None if incompatible.

        ``fps`` overrides the stream's own frame rate (used by the Fig. 6
        target-frame-rate sweeps). Compatibility also checks that the vector
        fits inside the usable (90%-capped) capacity of a single empty
        instance: a ZF stream at 8 fps needs 57.6 cores — no CPU instance in
        the catalog can run it at all.
        """
        f = self.fps if fps is None else fps
        return requirement_for(self.program, f, itype)


def requirement_for(program: AnalysisProgram, fps: float,
                    itype: InstanceType) -> Optional[tuple[float, ...]]:
    """Requirement vector of ``program`` at ``fps`` on ``itype``, or None if
    incompatible (unsupported execution mode, or the vector does not fit the
    usable capacity of a single empty instance)."""
    if itype.has_gpu:
        if not program.supports_gpu:
            return None
        req = program.gpu_requirement(fps)
    else:
        if not program.supports_cpu:
            return None
        req = program.cpu_requirement(fps)
    usable = itype.usable()
    if any(r > u + 1e-9 for r, u in zip(req, usable)):
        return None
    return req


def class_requirement_columns(program: AnalysisProgram, fps: float,
                              types: Sequence[InstanceType],
                              target_fps: Optional[float] = None
                              ) -> list[Optional[tuple[float, ...]]]:
    """Requirement column of one (program, frame-rate) *class*: its vector on
    every instance type (None = incompatible), at ``target_fps`` frames/s or
    the class's own rate. Pipeline stages become classes through their
    (possibly pixel-scaled) stage program, so the packed builder prices
    stages with exactly the same code path as whole streams."""
    f = fps if target_fps is None else target_fps
    return [requirement_for(program, f, t) for t in types]


def requirement_columns(stream: Stream, types: Sequence[InstanceType],
                        target_fps: Optional[float] = None
                        ) -> list[Optional[tuple[float, ...]]]:
    """One *column* of the requirement matrix: this stream's vector on every
    instance type (None = incompatible), at ``target_fps`` frames/s or the
    stream's own rate. The packed ``build_problem`` evaluates one column per
    (program, frame-rate) class and broadcasts it across locations — the
    requirement vector never varies by location, only RTT feasibility does
    — so construction is O(classes x types), not O(streams x choices)."""
    return class_requirement_columns(stream.program, stream.fps, types,
                                     target_fps)


def make_streams(spec: Sequence[tuple[str, float, int]], camera_ids: Sequence[str] | None = None) -> list[Stream]:
    """Build streams from (program_name, fps, count) tuples."""
    out: list[Stream] = []
    k = 0
    for prog_name, fps, count in spec:
        for _ in range(count):
            cam = camera_ids[k] if camera_ids is not None else None
            out.append(Stream(f"{prog_name.lower()}-{fps}-{k}", PROGRAMS[prog_name], fps, camera=cam))
            k += 1
    return out


# The three scenarios of Fig. 3 — (program, fps, number of cameras).
FIG3_SCENARIOS: dict[int, list[tuple[str, float, int]]] = {
    1: [("VGG16", 0.25, 1), ("ZF", 0.55, 3)],
    2: [("VGG16", 0.20, 1), ("ZF", 0.50, 1)],
    3: [("VGG16", 0.20, 2), ("ZF", 8.00, 10)],
}


# ---------------------------------------------------------------------------
# Content-aware analysis pipelines (beyond-paper).
#
# Real deployments run multi-stage filter pipelines: a cheap detector watches
# every frame and an expensive model fires only on the ROI crops the detector
# surfaces (smart tolling's hierarchical ROI execution; Rivas et al.'s
# object-level consolidation; CrossRoI's cross-camera overlap — PAPERS.md).
# Two consequences for the planner:
#
#   * demand is *endogenous*: how busy the scene is (traffic density) decides
#     how often downstream stages activate, so a scene getting busy IS a
#     demand spike — not just a frame-rate knob someone turned;
#   * the unit being packed is the *stage*, not the stream: a crop stage
#     processes a fraction of the source pixels (``pixel_share``) at a
#     density-dependent fraction of the source rate, and crop stages from
#     co-located cameras can be consolidated onto shared GPU bins because
#     the model weights are loaded once per bin, not once per camera.
# ---------------------------------------------------------------------------

_SCALED_PROGRAMS: dict[tuple[int, float], AnalysisProgram] = {}
_SCALED_BASES: list[AnalysisProgram] = []   # strong refs: keep id() keys unique


def scaled_program(base: AnalysisProgram, pixel_share: float) -> AnalysisProgram:
    """The ``base`` program run on crops covering ``pixel_share`` of a frame.

    Per-frame compute and frame-buffer memory scale with the pixels actually
    processed, so the per-fps coefficients shrink by ``pixel_share``; the
    model-weight and host-buffer bases do not (the network is the same size
    no matter how small the crop) — which is exactly why consolidating many
    small crop stages onto one bin pays: one copy of the weights serves all.

    Cached per (base, pixel_share) so repeated calls return the *same*
    object — requirement classes factorize by ``id(program)``.
    """
    if pixel_share == 1.0:
        return base
    if not (0.0 < pixel_share <= 1.0):
        raise ValueError(f"pixel_share must be in (0, 1], got {pixel_share}")
    key = (id(base), float(pixel_share))
    prog = _SCALED_PROGRAMS.get(key)
    if prog is None:
        prog = dataclasses.replace(
            base,
            name=f"{base.name}@{pixel_share:g}px",
            cpu_cores_per_fps=base.cpu_cores_per_fps * pixel_share,
            gpu_frac_per_fps=base.gpu_frac_per_fps * pixel_share,
            gpu_mem_per_fps_gib=base.gpu_mem_per_fps_gib * pixel_share,
        )
        _SCALED_PROGRAMS[key] = prog
        _SCALED_BASES.append(base)
    return prog


@dataclasses.dataclass(frozen=True)
class PipelineStage:
    """One stage of an analysis pipeline.

    ``rate_share`` is the fraction of source frames this stage sees when the
    scene is fully dense; ``activation(density)`` modulates it by content:
    ``clip(activation_floor + activation_gain * density, 0, 1)``. A stage
    with ``activation_floor=1.0, activation_gain=0.0`` is always-on (the
    upstream detector watching every frame); a downstream crop stage uses a
    small floor (idle scenes still trigger occasionally) and gain ~1.

    ``pixel_share`` shrinks the per-fps coefficients of ``program`` (crops
    cover a fraction of the frame); ``consolidatable`` marks stages whose
    crops from co-located cameras may be pooled onto shared bins, up to
    ``pool_cap_fps`` frames/s per pooled worker (default: the scaled
    program's single-GPU ceiling).
    """

    name: str
    program: AnalysisProgram
    rate_share: float = 1.0
    pixel_share: float = 1.0
    activation_floor: float = 1.0
    activation_gain: float = 0.0
    consolidatable: bool = False
    pool_cap_fps: Optional[float] = None

    def resolved_program(self) -> AnalysisProgram:
        """The (pixel-share-scaled) program this stage actually runs."""
        return scaled_program(self.program, self.pixel_share)

    def activation(self, density: float) -> float:
        """Fraction of this stage's full-density rate active at ``density``."""
        return min(1.0, max(0.0, self.activation_floor
                            + self.activation_gain * density))

    def stage_fps(self, source_fps: float, density: float) -> float:
        """Frames/s this stage processes from a ``source_fps`` camera."""
        return source_fps * (self.rate_share * self.activation(density))

    def cap_fps(self, gpu_usable: float = 0.9) -> float:
        """Max frames/s one pooled worker of this stage can absorb."""
        if self.pool_cap_fps is not None:
            return self.pool_cap_fps
        return self.resolved_program().max_gpu_fps(gpu_usable)


@dataclasses.dataclass(frozen=True)
class AnalysisPipeline:
    """A per-camera DAG of stages, linearized to per-stage rate shares.

    A camera running a pipeline does not emit one demand item — it emits one
    item per stage, each a (scaled-program, stage-fps) requirement class the
    planner packs like any other stream. The *effective* demand of the
    camera is the activation-weighted sum of its stage demands.
    """

    name: str
    stages: tuple[PipelineStage, ...]

    def __post_init__(self) -> None:
        if not self.stages:
            raise ValueError("pipeline needs at least one stage")
        seen = set()
        for st in self.stages:
            if st.name in seen:
                raise ValueError(f"duplicate stage name {st.name!r}")
            seen.add(st.name)

    def effective_fps(self, source_fps: float, density: float) -> float:
        """Total frames/s across stages at this content density."""
        return sum(st.stage_fps(source_fps, density) for st in self.stages)

    def stage_rates(self, source_fps: float, density: float
                    ) -> list[tuple[PipelineStage, float]]:
        """(stage, frames/s) per stage — the demand items a camera emits."""
        return [(st, st.stage_fps(source_fps, density)) for st in self.stages]


def stage_requirement_columns(pipeline: AnalysisPipeline, source_fps: float,
                              density: float,
                              types: Sequence[InstanceType]
                              ) -> list[list[Optional[tuple[float, ...]]]]:
    """Per-stage requirement columns at a content density — one
    ``class_requirement_columns`` row per stage, at the demand layer's
    rounding (rates quantized to milli-fps like ``sim.demand`` emits)."""
    return [class_requirement_columns(st.resolved_program(),
                                      round(f, 3), types)
            for st, f in pipeline.stage_rates(source_fps, density)]


# Reference pipelines. ``roi_vehicle``: a full-frame ZF detector watches every
# frame; a VGG16 classifier fires on vehicle crops (~quarter frame) for half
# the frames when the scene is saturated, almost never at night.
# ``roi_plate``: detector -> plate tracker on half-frame crops -> OCR-style
# VGG16 on tiny plate crops; only the OCR stage is consolidatable (trackers
# keep per-camera state).
PIPELINES: dict[str, AnalysisPipeline] = {
    "roi_vehicle": AnalysisPipeline("roi_vehicle", (
        PipelineStage("detect", ZF),
        PipelineStage("classify", VGG16, rate_share=0.5, pixel_share=0.25,
                      activation_floor=0.04, activation_gain=0.96,
                      consolidatable=True),
    )),
    "roi_plate": AnalysisPipeline("roi_plate", (
        PipelineStage("detect", ZF),
        PipelineStage("track", ZF, rate_share=0.4, pixel_share=0.5,
                      activation_floor=0.1, activation_gain=0.9),
        PipelineStage("ocr", VGG16, rate_share=0.2, pixel_share=0.125,
                      activation_floor=0.02, activation_gain=0.98,
                      consolidatable=True),
    )),
}
