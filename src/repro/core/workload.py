"""Stream workloads and the calibrated per-program resource model.

A *stream* = one analysis program running on one camera's data at a desired
frame rate (a "box" in the paper's truck analogy). Its resource requirement
vector depends on which kind of instance executes it (CPU-only vs GPU) — this
is the *multiple-choice* part of the packing problem.

Calibration. The paper does not publish the raw per-program utilization
coefficients, only the outcomes (Fig. 3) and qualitative facts (GPU speedup up
to 16x at high frame rates, <5% benefit at low rates; performance degrades
past 90% utilization). The linear coefficients below are fitted so that the
solver reproduces *all nine cells* of Fig. 3 exactly — instance counts and
dollar figures — under the Fig. 3 catalog. See tests/test_fig3.py.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

from repro.core.catalog import InstanceType


@dataclasses.dataclass(frozen=True)
class AnalysisProgram:
    """Resource model of one computer-vision program (VGG16, ZF, ...).

    Requirements are linear in frame rate: ``base + per_fps * fps`` per
    dimension, with separate CPU-execution and GPU-execution profiles.
    ``cpu_cores_per_fps=None`` in the GPU profile's host part means the GPU
    profile still consumes some host cores to decode/feed frames.
    """

    name: str
    # CPU execution profile
    cpu_cores_per_fps: float              # cores needed per frame/second on CPU
    cpu_mem_gib: float                    # host memory (model + buffers)
    # GPU execution profile
    gpu_frac_per_fps: float               # fraction of one GPU per frame/second
    gpu_mem_base_gib: float               # GPU memory: model weights
    gpu_mem_per_fps_gib: float            # GPU memory: frame buffers
    gpu_feed_cores: float = 0.5           # host cores to fetch/decode the stream
    supports_cpu: bool = True
    supports_gpu: bool = True

    def cpu_requirement(self, fps: float) -> tuple[float, ...]:
        """(cpu_cores, memory_gib, gpu_compute, gpu_memory_gib) on a CPU instance."""
        return (self.cpu_cores_per_fps * fps, self.cpu_mem_gib, 0.0, 0.0)

    def gpu_requirement(self, fps: float) -> tuple[float, ...]:
        return (
            self.gpu_feed_cores,
            self.cpu_mem_gib,
            self.gpu_frac_per_fps * fps,
            self.gpu_mem_base_gib + self.gpu_mem_per_fps_gib * fps,
        )

    def max_cpu_fps(self, cores_usable: float) -> float:
        return cores_usable / self.cpu_cores_per_fps

    def max_gpu_fps(self, gpu_usable: float = 0.9) -> float:
        return gpu_usable / self.gpu_frac_per_fps

    def gpu_speedup(self, fps: float, cores_usable: float = 7.2) -> float:
        """Effective GPU speedup at a target frame rate (paper: up to 16x at
        high rates, <5% at the lowest rates — batching amortization)."""
        peak = self.max_gpu_fps() / self.max_cpu_fps(cores_usable)
        return max(1.0, min(peak, peak * fps / self.max_gpu_fps()))


# Fitted to reproduce Fig. 3 exactly (see module docstring).
VGG16 = AnalysisProgram(
    name="VGG16",
    cpu_cores_per_fps=16.0,      # 0.45 fps max on a c4.2xlarge (7.2 usable cores)
    cpu_mem_gib=2.0,
    gpu_frac_per_fps=0.32,       # 2.81 fps max on one GPU -> ~6.3x speedup
    gpu_mem_base_gib=0.5,        # ~528 MB of weights
    gpu_mem_per_fps_gib=0.3,
)

ZF = AnalysisProgram(
    name="ZF",
    cpu_cores_per_fps=7.2,       # 1.0 fps max on a c4.2xlarge
    cpu_mem_gib=1.5,
    gpu_frac_per_fps=0.056,      # 16.07 fps max on one GPU -> ~16x speedup
    gpu_mem_base_gib=0.25,
    gpu_mem_per_fps_gib=0.35,
)

PROGRAMS = {"VGG16": VGG16, "ZF": ZF}


@dataclasses.dataclass(frozen=True)
class Stream:
    """One analysis program bound to one camera at a desired frame rate
    (``fps`` in frames/s); the box being packed onto $/hour instances."""

    stream_id: str
    program: AnalysisProgram
    fps: float
    camera: Optional[str] = None          # camera id for the geo experiments
    frame_pixels: int = 640 * 480         # kept for completeness; folded into fps cost

    def requirement_for(self, itype: InstanceType,
                        fps: Optional[float] = None) -> Optional[tuple[float, ...]]:
        """Requirement vector on this instance type, or None if incompatible.

        ``fps`` overrides the stream's own frame rate (used by the Fig. 6
        target-frame-rate sweeps). Compatibility also checks that the vector
        fits inside the usable (90%-capped) capacity of a single empty
        instance: a ZF stream at 8 fps needs 57.6 cores — no CPU instance in
        the catalog can run it at all.
        """
        f = self.fps if fps is None else fps
        if itype.has_gpu:
            if not self.program.supports_gpu:
                return None
            req = self.program.gpu_requirement(f)
        else:
            if not self.program.supports_cpu:
                return None
            req = self.program.cpu_requirement(f)
        usable = itype.usable()
        if any(r > u + 1e-9 for r, u in zip(req, usable)):
            return None
        return req


def requirement_columns(stream: Stream, types: Sequence[InstanceType],
                        target_fps: Optional[float] = None
                        ) -> list[Optional[tuple[float, ...]]]:
    """One *column* of the requirement matrix: this stream's vector on every
    instance type (None = incompatible), at ``target_fps`` frames/s or the
    stream's own rate. The packed ``build_problem`` evaluates one column per
    (program, frame-rate) class and broadcasts it across locations — the
    requirement vector never varies by location, only RTT feasibility does
    — so construction is O(classes x types), not O(streams x choices)."""
    return [stream.requirement_for(t, fps=target_fps) for t in types]


def make_streams(spec: Sequence[tuple[str, float, int]], camera_ids: Sequence[str] | None = None) -> list[Stream]:
    """Build streams from (program_name, fps, count) tuples."""
    out: list[Stream] = []
    k = 0
    for prog_name, fps, count in spec:
        for _ in range(count):
            cam = camera_ids[k] if camera_ids is not None else None
            out.append(Stream(f"{prog_name.lower()}-{fps}-{k}", PROGRAMS[prog_name], fps, camera=cam))
            k += 1
    return out


# The three scenarios of Fig. 3 — (program, fps, number of cameras).
FIG3_SCENARIOS: dict[int, list[tuple[str, float, int]]] = {
    1: [("VGG16", 0.25, 1), ("ZF", 0.55, 3)],
    2: [("VGG16", 0.20, 1), ("ZF", 0.50, 1)],
    3: [("VGG16", 0.20, 2), ("ZF", 8.00, 10)],
}
