"""The paper's resource-management strategies.

Fig. 3 (instance-type selection, single location):
  ST1 — CPU-only instances; ST2 — GPU-only instances; ST3 — Kaseb's
  multiple-choice CPU/GPU packing (our exact solver).

Fig. 6 (type × location):
  NL     — Nearest Location: each stream goes to its nearest RTT-feasible
           region; per-region packing.
  ARMVAC — Mohan's adaptive greedy [6,8]: RTT-filter locations, then
           cheapest-cost-efficient instance first, fill it up, repeat.
  GCL    — Globally Cheapest Location [8]: full multi-dimensional
           multiple-choice packing over (type × location) choices with the
           RTT feasibility constraints (our exact solver).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional, Sequence

from repro.core import geo
from repro.core.catalog import Catalog, InstanceType, UTILIZATION_CAP
from repro.core.heuristics import (cheapest_instance_first,
                                   first_fit_decreasing, lowest_price_first)
from repro.core.packing import Choice, Infeasible, Item, Problem, Solution, validate
from repro.core.solver import solve
from repro.core.workload import Stream


@dataclasses.dataclass
class Plan:
    """A resource allocation: which instances to rent, what runs where.

    ``hourly_cost`` is in $/hour; each bin of ``solution`` is one rented
    instance holding the streams (frames/s demands) packed into it.
    """

    solution: Solution
    problem: Problem
    strategy: str

    @property
    def hourly_cost(self) -> float:
        """Total rental price of the planned instances, $/hour."""
        return self.solution.cost

    def signature(self) -> tuple:
        """Canonical comparable form: ordered (choice key, member stream
        keys) per bin plus the exact $/hour cost. Two plans are
        bit-identical iff their signatures are equal — the parity notion
        the packed-vs-scalar tests and the scale_sweep CI gate share."""
        return ([(self.problem.choices[b.choice].key,
                  [self.problem.items[i].key for i in b.items])
                 for b in self.solution.bins], self.solution.cost)

    def instance_counts(self) -> dict[str, int]:
        return self.solution.instance_counts(self.problem)

    def summary(self) -> dict:
        counts = self.instance_counts()
        n_gpu = sum(v for k, v in counts.items() if _key_is_gpu(self.problem, k))
        n_cpu = sum(counts.values()) - n_gpu
        return {
            "strategy": self.strategy,
            "hourly_cost": round(self.hourly_cost, 3),
            "non_gpu_instances": n_cpu,
            "gpu_instances": n_gpu,
            "instances": counts,
            "optimal": self.solution.optimal,
        }


def _key_is_gpu(problem: Problem, key: str) -> bool:
    """GPU-ness comes from the catalog's ``InstanceType.has_gpu``, carried on
    each Choice by build_problem — a name-prefix heuristic misclassifies any
    CPU type that happens to start with "g"/"p"/"NC" (and vice versa)."""
    for c in problem.choices:
        if c.key == key:
            return c.has_gpu
    return False


def build_problem(streams: Sequence[Stream], catalog: Catalog,
                  locations: Optional[Sequence[str]] = None,
                  target_fps: Optional[float] = None,
                  rtt_filter: bool = False,
                  gpu_only: bool = False, cpu_only: bool = False,
                  packed: Optional[bool] = None) -> Problem:
    """Assemble the packing problem from streams + catalog (+ geo constraints).

    With ``rtt_filter``, an item is compatible with a (type, location) choice
    only if the camera's RTT to that location sustains the stream's frame rate.

    ``packed`` selects between the columnwise (vectorized) item builder —
    the default, which groups streams into requirement classes and attaches
    the arrays the fast FFD path consumes — and the original per-stream
    scalar loop (``packed=False``, or anything inside
    ``repro.core.packed.scalar_mode()``). Both produce the same Problem,
    bit for bit; the packed one does it in O(classes x choices) instead of
    O(streams x choices).
    """
    from repro.core import packed as packed_mod

    choices: list[Choice] = []
    metas: list[tuple[InstanceType, str]] = []
    for t in catalog.types:
        if gpu_only and not t.has_gpu:
            continue
        if cpu_only and t.has_gpu:
            continue
        for loc, price in sorted(t.prices.items()):
            if locations is not None and loc not in locations:
                continue
            choices.append(Choice(
                key=f"{t.name}@{loc}", type_name=t.name, location=loc,
                capacity=t.usable(UTILIZATION_CAP), price=price,
                has_gpu=t.has_gpu))
            metas.append((t, loc))
    if not choices:
        raise Infeasible("catalog empty after strategy filters")

    if packed is None:
        packed = packed_mod.enabled()
    if packed:
        return packed_mod.build_packed_items(streams, choices, metas,
                                             target_fps, rtt_filter)

    items: list[Item] = []
    for s in streams:
        fps = target_fps if target_fps is not None else s.fps
        reqs: list[Optional[tuple[float, ...]]] = []
        for (t, loc) in metas:
            req = s.requirement_for(t, fps=target_fps)
            if req is not None and rtt_filter and s.camera is not None:
                if geo.max_fps(s.camera, loc) < fps:
                    req = None
            reqs.append(req)
        items.append(Item(key=s.stream_id, requirements=tuple(reqs)))
    return Problem(choices=tuple(choices), items=tuple(items))


# ----------------------------------------------------------------------
# Fig. 3 strategies (single-location, CPU vs GPU)
# ----------------------------------------------------------------------

def st1_cpu_only(streams: Sequence[Stream], catalog: Catalog) -> Plan:
    problem = build_problem(streams, catalog, cpu_only=True)
    sol, _ = solve(problem)
    validate(problem, sol)
    return Plan(sol, problem, "ST1")


def st2_gpu_only(streams: Sequence[Stream], catalog: Catalog) -> Plan:
    problem = build_problem(streams, catalog, gpu_only=True)
    sol, _ = solve(problem)
    validate(problem, sol)
    return Plan(sol, problem, "ST2")


def st3_multiple_choice(streams: Sequence[Stream], catalog: Catalog) -> Plan:
    """Kaseb et al. [7]: the paper's contribution for Fig. 3."""
    problem = build_problem(streams, catalog)
    sol, _ = solve(problem)
    validate(problem, sol)
    return Plan(sol, problem, "ST3")


# ----------------------------------------------------------------------
# Fig. 6 strategies (type × location)
# ----------------------------------------------------------------------

def nearest_location(streams: Sequence[Stream], catalog: Catalog,
                     target_fps: float) -> Plan:
    """NL: every camera ships to its nearest feasible region; pack per region."""
    groups: dict[str, list[Stream]] = {}
    for s in streams:
        assert s.camera is not None, "NL requires camera locations"
        feas = geo.feasible_regions(s.camera, target_fps, catalog.locations)
        if not feas:
            raise Infeasible(f"stream {s.stream_id}: no region within RTT budget")
        region = min(feas, key=lambda r: geo.rtt_ms(s.camera, r))
        groups.setdefault(region, []).append(s)

    bins_total = []
    cost = 0.0
    problems = []
    for region, group in sorted(groups.items()):
        problem = build_problem(group, catalog, locations=[region],
                                target_fps=target_fps)
        sol, _ = solve(problem)
        validate(problem, sol)
        problems.append((problem, sol))
        cost += sol.cost
    # merge into one plan over the union problem for uniform reporting
    union_problem = build_problem(streams, catalog, target_fps=target_fps,
                                  rtt_filter=True)
    merged = _merge_regional(union_problem, problems)
    return Plan(merged, union_problem, "NL")


def _merge_regional(union_problem: Problem, parts) -> Solution:
    from repro.core.packing import Bin
    key_to_idx = {c.key: i for i, c in enumerate(union_problem.choices)}
    item_to_idx = {it.key: i for i, it in enumerate(union_problem.items)}
    bins = []
    cost = 0.0
    for problem, sol in parts:
        for b in sol.bins:
            ch = problem.choices[b.choice]
            nb = Bin(choice=key_to_idx[ch.key],
                     items=[item_to_idx[problem.items[i].key] for i in b.items])
            bins.append(nb)
            cost += ch.price
    return Solution(bins=bins, cost=cost, optimal=False, note="regional-merge")


def armvac(streams: Sequence[Stream], catalog: Catalog, target_fps: float) -> Plan:
    """ARMVAC [6,8]: RTT-filter, then lowest-price-instance-first greedy fill."""
    problem = build_problem(streams, catalog, target_fps=target_fps, rtt_filter=True)
    sol = lowest_price_first(problem)
    validate(problem, sol)
    return Plan(sol, problem, "ARMVAC")


def armvac_plus(streams: Sequence[Stream], catalog: Catalog, target_fps: float) -> Plan:
    """BEYOND-PAPER: ARMVAC with a price-per-held-stream greedy instead of the
    raw lowest-price rule — closes most of the mid-band gap at greedy cost."""
    problem = build_problem(streams, catalog, target_fps=target_fps, rtt_filter=True)
    sol = cheapest_instance_first(problem)
    validate(problem, sol)
    return Plan(sol, problem, "ARMVAC+")


def gcl(streams: Sequence[Stream], catalog: Catalog, target_fps: float) -> Plan:
    """GCL [8]: global multiple-choice packing over types × locations."""
    problem = build_problem(streams, catalog, target_fps=target_fps, rtt_filter=True)
    sol, _ = solve(problem, time_budget_s=30.0)
    validate(problem, sol)
    return Plan(sol, problem, "GCL")


# ----------------------------------------------------------------------
# Fleet-scale greedy (BEYOND-PAPER)
# ----------------------------------------------------------------------

def ffd_greedy(streams: Sequence[Stream], catalog: Catalog) -> Plan:
    """FFD: first-fit-decreasing over the full (type × location) choice set,
    at each stream's own frame rate. Linear-time planning for the fleet
    simulator, where the control loop replans hundreds of streams every
    simulated hour and an exact solve per tick is unaffordable. Streams with
    cameras are RTT-filtered to their Fig.-4 feasible regions.
    """
    has_cam = getattr(streams, "any_camera", None)
    rtt = has_cam() if has_cam is not None \
        else any(s.camera is not None for s in streams)
    problem = build_problem(streams, catalog, rtt_filter=rtt)
    sol = first_fit_decreasing(problem)
    validate(problem, sol)
    return Plan(sol, problem, "FFD")


def consolidated_ffd(streams: Sequence[Stream], catalog: Catalog,
                     pooled: Optional[Sequence[Stream]] = None) -> Plan:
    """Keep-the-cheaper stage consolidation (the mixed-market pattern from
    ``core.markets``): FFD-pack the per-camera stage items and, when a
    ``pooled`` view of the same demand is given (crop stages merged onto
    shared workers — e.g. the ``consolidate=True`` arm of
    ``sim.demand.PipelineFleet``), also pack that; return whichever plan is
    cheaper. Consolidating is therefore never worse than not consolidating,
    by construction — the property tests rely on this, the simulator gates
    the actual saving empirically."""
    base = ffd_greedy(streams, catalog)
    if pooled is None:
        return base
    alt = ffd_greedy(pooled, catalog)
    return alt if alt.hourly_cost <= base.hourly_cost else base


def repair_incremental(streams: Sequence[Stream], catalog: Catalog,
                       previous=None, config=None) -> Plan:
    """REPAIR (BEYOND-PAPER): min-migration incremental replanning. Keeps
    every still-feasible placement of ``previous`` in place, evicts only
    streams on lost/overloaded bins, and FFD-packs just that delta over
    residual capacity (see core/repair.py). With no previous plan it is a
    fresh FFD."""
    from repro.core.repair import RepairConfig, repair_plan
    return repair_plan(streams, catalog, previous=previous,
                       config=config or RepairConfig()).plan


# The planner registry ResourceManager.plan dispatches on. Paper strategies:
# ST1/ST2/ST3 (Fig. 3 CPU/GPU selection, exact solver) and NL/ARMVAC/GCL
# (Fig. 6 type x location; ARMVAC+ is our improved greedy) — these take a
# target_fps in frames/s. Beyond-paper fleet strategies: FFD (linear-time
# first-fit-decreasing at each stream's own rate) and REPAIR (min-migration
# incremental replanning). Every strategy returns a Plan costed in $/hour.
STRATEGIES: dict[str, Callable] = {
    "ST1": st1_cpu_only, "ST2": st2_gpu_only, "ST3": st3_multiple_choice,
    "NL": nearest_location, "ARMVAC": armvac, "ARMVAC+": armvac_plus, "GCL": gcl,
    "FFD": ffd_greedy, "REPAIR": repair_incremental,
}
