"""Cloud instance catalog — Table I of the paper plus the instances used in Fig. 3/6.

An *instance type* is a bin with a capacity vector over resource dimensions and an
hourly price that depends on the datacenter location. The paper's dimensions are
(cpu_cores, memory_gib, gpu_compute, gpu_memory_gib); the beyond-paper TPU catalog
(tpu_catalog.py) reuses the same InstanceType with different dimension names.
"""
from __future__ import annotations

import dataclasses
from typing import Mapping, Sequence

# Canonical resource dimension order used by the packing solver for the cloud
# (paper) catalog. Kaseb et al. [7] use exactly these four dimensions.
DIMENSIONS = ("cpu_cores", "memory_gib", "gpu_compute", "gpu_memory_gib")

# The paper's measured safe-utilization threshold: above 90% on any dimension,
# analysis performance degrades, so the manager never packs past it.
UTILIZATION_CAP = 0.90


@dataclasses.dataclass(frozen=True)
class InstanceType:
    """One cloud instance configuration (a "truck" in the sidebar analogy):
    a raw capacity vector over ``dimensions`` (cores, GiB, GPU fraction,
    GPU GiB by default) priced in $/hour per location."""

    name: str
    capacity: tuple[float, ...]          # raw capacity per dimension
    prices: Mapping[str, float]          # location -> $/hour
    has_gpu: bool = False
    dimensions: tuple[str, ...] = DIMENSIONS

    def price_at(self, location: str) -> float:
        try:
            return self.prices[location]
        except KeyError:
            raise KeyError(
                f"instance {self.name} is not offered in {location}; "
                f"available: {sorted(self.prices)}"
            ) from None

    @property
    def locations(self) -> tuple[str, ...]:
        return tuple(sorted(self.prices))

    def usable(self, cap: float = UTILIZATION_CAP) -> tuple[float, ...]:
        """Capacity after the 90% utilization head-room rule."""
        return tuple(c * cap for c in self.capacity)

    def cheapest_location(self) -> tuple[str, float]:
        loc = min(self.prices, key=self.prices.__getitem__)
        return loc, self.prices[loc]


@dataclasses.dataclass(frozen=True)
class Catalog:
    """A set of instance types offered by one or more vendors, each priced
    in $/hour per datacenter location."""

    types: tuple[InstanceType, ...]

    def __post_init__(self) -> None:
        names = [t.name for t in self.types]
        if len(set(names)) != len(names):
            raise ValueError(f"duplicate instance type names: {names}")

    def get(self, name: str) -> InstanceType:
        for t in self.types:
            if t.name == name:
                return t
        raise KeyError(name)

    def offered_at(self, location: str) -> tuple[InstanceType, ...]:
        return tuple(t for t in self.types if location in t.prices)

    @property
    def locations(self) -> tuple[str, ...]:
        locs: set[str] = set()
        for t in self.types:
            locs.update(t.prices)
        return tuple(sorted(locs))

    def choices(self) -> tuple[tuple[InstanceType, str, float], ...]:
        """All (type, location, price) choices — the multiple-choice dimension."""
        out = []
        for t in self.types:
            for loc, p in sorted(t.prices.items()):
                out.append((t, loc, p))
        return tuple(out)


# --------------------------------------------------------------------------
# Paper catalogs
# --------------------------------------------------------------------------

def fig3_catalog() -> Catalog:
    """The two instance types behind Fig. 3 of the paper.

    Kaseb et al. [7] ran on EC2 with a CPU instance at $0.419/h (c4.2xlarge,
    2016 pricing) and a GPU instance at $0.650/h (g2.2xlarge: 8 vCPU, 15 GiB,
    1×GRID K520 with 4 GiB GPU memory). These prices reproduce every dollar
    figure in Fig. 3 (4×0.419=1.676, 11×0.650=7.150, 0.419+10×0.650=6.919).
    """
    cpu = InstanceType(
        name="c4.2xlarge",
        capacity=(8.0, 15.0, 0.0, 0.0),
        prices={"us-east-1": 0.419},
        has_gpu=False,
    )
    gpu = InstanceType(
        name="g2.2xlarge",
        capacity=(8.0, 15.0, 1.0, 4.0),
        prices={"us-east-1": 0.650},
        has_gpu=True,
    )
    return Catalog(types=(cpu, gpu))


def table1_catalog() -> Catalog:
    """Table I of the paper: EC2 + Azure types at three locations each."""
    return Catalog(types=(
        InstanceType("c4.2xlarge", (8.0, 15.0, 0.0, 0.0),
                     {"virginia": 0.398, "london": 0.476, "singapore": 0.462}),
        InstanceType("c4.8xlarge", (36.0, 60.0, 0.0, 0.0),
                     {"virginia": 1.591, "london": 1.902, "singapore": 1.848}),
        InstanceType("g3.8xlarge", (32.0, 244.0, 2.0, 16.0),
                     {"virginia": 2.280, "singapore": 3.340}, has_gpu=True),
        InstanceType("D8v3", (8.0, 32.0, 0.0, 0.0),
                     {"us-east": 0.384, "west-europe": 0.480, "east-asia": 0.625}),
        InstanceType("NC24r", (24.0, 224.0, 4.0, 48.0),
                     {"us-east": 3.960, "west-europe": 5.132}, has_gpu=True),
    ))


def fig6_catalog() -> Catalog:
    """Multi-region catalog for the location experiments (Fig. 6).

    Modeled on 2018 EC2 pricing across the regions the paper's Fig. 4 world
    map shows (N. Virginia, Oregon, São Paulo, Ireland, Frankfurt, Singapore,
    Tokyo, Sydney). Price disparity across regions reaches ~63%, matching the
    paper's observation on the Azure D8v3 (0.625/0.384 = 1.63).
    """
    cpu_small_prices = {
        "us-east-1": 0.398, "us-west-2": 0.398, "sa-east-1": 0.618,
        "eu-west-1": 0.453, "eu-central-1": 0.486, "ap-southeast-1": 0.462,
        "ap-northeast-1": 0.504, "ap-southeast-2": 0.522, "ap-south-1": 0.420,
    }
    cpu_large_prices = {k: round(v * 4.0 - 0.001, 3) for k, v in cpu_small_prices.items()}
    gpu_prices = {
        "us-east-1": 0.650, "us-west-2": 0.650, "eu-west-1": 0.702,
        "ap-southeast-1": 1.000, "ap-northeast-1": 0.898, "sa-east-1": 1.134,
        "ap-southeast-2": 0.898, "ap-south-1": 0.813,
    }
    gpu_big_prices = {
        "us-east-1": 2.280, "us-west-2": 2.280, "eu-west-1": 2.420,
        "ap-northeast-1": 3.160, "ap-southeast-2": 3.366, "ap-south-1": 2.926,
        "sa-east-1": 3.580, "eu-central-1": 2.726, "ap-southeast-1": 3.340,
    }
    return Catalog(types=(
        InstanceType("c4.2xlarge", (8.0, 15.0, 0.0, 0.0), cpu_small_prices),
        InstanceType("c4.8xlarge", (36.0, 60.0, 0.0, 0.0), cpu_large_prices),
        InstanceType("g2.2xlarge", (8.0, 15.0, 1.0, 4.0), gpu_prices, has_gpu=True),
        InstanceType("g3.8xlarge", (32.0, 244.0, 2.0, 16.0), gpu_big_prices, has_gpu=True),
    ))
