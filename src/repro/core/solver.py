"""Exact branch-and-bound solver for multi-dimensional multiple-choice VBP.

Replaces the Gurobi 5.0 branch-and-cut of the paper (offline environment).
Exact for the paper-scale inputs (tens of streams, dozens of choices); falls
back to the FFD incumbent with ``optimal=False`` when the node budget is hit.

Search: items in decreasing l_inf-size order; each node assigns the next item
either into one of the open bins (deduplicated by identical (choice, load))
or into a new bin of each compatible choice (deduplicated by choice, and
symmetry-broken: at most one *empty-equivalent* new bin per choice per node).

Bounds: dual per-dimension lower bound — for dimension d,
    LB_d = sum_i min_{c in compat(i)} price_c * req_{i,d}(c) / cap_{c,d}
is a valid lower bound on the remaining cost since each opened instance of
choice c contributes at most cap_{c,d} of dimension d at price price_c.
We take max_d LB_d minus a credit for free capacity already paid for in the
open bins (an item landing in open bin b of choice c consumes at most
price_c * free_{b,d} / cap_{c,d} of its unit bound in dimension d, so
subtracting the open bins' free-capacity value keeps the bound valid —
without the credit the bound over-estimates and prunes optimal branches).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Optional

from repro.core.heuristics import first_fit_decreasing
from repro.core.packing import Bin, Infeasible, Problem, Solution, fits


@dataclasses.dataclass
class SolveStats:
    nodes: int = 0
    pruned_bound: int = 0
    pruned_memo: int = 0
    wall_s: float = 0.0
    optimal: bool = False


def _item_order(problem: Problem) -> list[int]:
    def size(i: int) -> float:
        item = problem.items[i]
        best = 0.0
        for c in item.compatible():
            req = item.requirements[c]
            cap = problem.choices[c].capacity
            best = max(best, max((r / k if k > 0 else 0.0) for r, k in zip(req, cap)))
        return best
    return sorted(range(len(problem.items)), key=size, reverse=True)


def _unit_costs(problem: Problem) -> list[list[float]]:
    """unit[i][d] = min over compatible c of price_c * req/cap (inf if no compat)."""
    nd = problem.ndim
    out: list[list[float]] = []
    for item in problem.items:
        best = [float("inf")] * nd
        compat = item.compatible()
        if not compat:
            raise Infeasible(f"item {item.key} has no compatible choice")
        for c in compat:
            req = item.requirements[c]
            ch = problem.choices[c]
            for d in range(nd):
                cap = ch.capacity[d]
                v = 0.0 if req[d] <= 0 else (ch.price * req[d] / cap if cap > 0 else float("inf"))
                best[d] = min(best[d], v)
        out.append([0.0 if v == float("inf") else v for v in best])
    return out


def solve(problem: Problem,
          node_budget: int = 2_000_000,
          time_budget_s: float = 60.0) -> tuple[Solution, SolveStats]:
    """Exact BnB; returns best solution found and whether it is proven optimal."""
    stats = SolveStats()
    t0 = time.monotonic()
    order = _item_order(problem)
    unit = _unit_costs(problem)
    nd = problem.ndim

    # per-dim suffix sums of the unit lower bounds over the ordered items
    n = len(order)
    suff = [[0.0] * nd for _ in range(n + 1)]
    for pos in range(n - 1, -1, -1):
        i = order[pos]
        for d in range(nd):
            suff[pos][d] = suff[pos + 1][d] + unit[i][d]

    try:
        incumbent = first_fit_decreasing(problem)
    except Infeasible:
        incumbent = None

    best_cost = incumbent.cost if incumbent is not None else float("inf")
    best_bins: Optional[list[Bin]] = (
        [Bin(b.choice, list(b.items)) for b in incumbent.bins] if incumbent else None)

    # open bins as parallel arrays
    bin_choice: list[int] = []
    bin_used: list[list[float]] = []
    bin_items: list[list[int]] = []
    memo: dict[tuple, float] = {}

    def state_key(pos: int) -> tuple:
        sig = tuple(sorted(
            (bin_choice[b], tuple(round(v, 6) for v in bin_used[b]))
            for b in range(len(bin_choice))))
        return (pos, sig)

    aborted = [False]

    def dfs(pos: int, cost: float) -> None:
        nonlocal best_cost, best_bins
        if aborted[0]:
            return
        stats.nodes += 1
        if stats.nodes > node_budget or (stats.nodes % 4096 == 0 and
                                         time.monotonic() - t0 > time_budget_s):
            aborted[0] = True
            return
        if pos == n:
            if cost < best_cost - 1e-9:
                best_cost = cost
                best_bins = [Bin(bin_choice[b], list(bin_items[b]))
                             for b in range(len(bin_choice))]
            return
        # credit[d]: value of free, already-paid capacity in the open bins
        credit = [0.0] * nd
        for b in range(len(bin_choice)):
            ch_b = problem.choices[bin_choice[b]]
            for d in range(nd):
                cap = ch_b.capacity[d]
                if cap > 0:
                    credit[d] += ch_b.price * (cap - bin_used[b][d]) / cap
        node_lb = max((suff[pos][d] - credit[d] for d in range(nd)),
                      default=0.0)
        if cost + max(node_lb, 0.0) >= best_cost - 1e-9:
            stats.pruned_bound += 1
            return
        key = state_key(pos)
        prev = memo.get(key)
        if prev is not None and prev <= cost + 1e-9:
            stats.pruned_memo += 1
            return
        memo[key] = cost

        i = order[pos]
        item = problem.items[i]

        # 1) place into an open bin (dedupe identical (choice, load) states)
        tried: set[tuple] = set()
        for b in range(len(bin_choice)):
            c = bin_choice[b]
            req = item.requirements[c]
            if req is None:
                continue
            sig = (c, tuple(round(v, 6) for v in bin_used[b]))
            if sig in tried:
                continue
            tried.add(sig)
            cap = problem.choices[c].capacity
            if fits(req, bin_used[b], cap):
                for d in range(nd):
                    bin_used[b][d] += req[d]
                bin_items[b].append(i)
                dfs(pos + 1, cost)
                bin_items[b].pop()
                for d in range(nd):
                    bin_used[b][d] -= req[d]

        # 2) open a new bin of each compatible choice (cheapest first)
        compat = sorted(item.compatible(), key=lambda c: problem.choices[c].price)
        for c in compat:
            req = item.requirements[c]
            ch = problem.choices[c]
            if not fits(req, [0.0] * nd, ch.capacity):
                continue
            child_lb = 0.0
            for d in range(nd):
                cap = ch.capacity[d]
                extra = ch.price * (cap - req[d]) / cap if cap > 0 else 0.0
                child_lb = max(child_lb, suff[pos + 1][d] - credit[d] - extra)
            if cost + ch.price + max(child_lb, 0.0) >= best_cost - 1e-9:
                continue
            bin_choice.append(c)
            bin_used.append(list(req))
            bin_items.append([i])
            dfs(pos + 1, cost + ch.price)
            bin_choice.pop()
            bin_used.pop()
            bin_items.pop()

    dfs(0, 0.0)
    stats.wall_s = time.monotonic() - t0
    stats.optimal = not aborted[0]

    if best_bins is None:
        raise Infeasible("no feasible assignment exists")
    sol = Solution(bins=[b for b in best_bins if b.items], cost=best_cost,
                   optimal=stats.optimal,
                   note="bnb" if stats.optimal else "bnb(budget hit; incumbent)")
    return sol, stats


def brute_force(problem: Problem, max_items: int = 7) -> Solution:
    """Exhaustive reference for property tests (tiny inputs only)."""
    n = len(problem.items)
    if n > max_items:
        raise ValueError("brute_force is for tiny instances")
    best: Optional[Solution] = None

    bin_choice: list[int] = []
    bin_used: list[list[float]] = []
    bin_items: list[list[int]] = []

    def rec(i: int, cost: float) -> None:
        nonlocal best
        if best is not None and cost >= best.cost - 1e-9:
            return
        if i == n:
            bins = [Bin(bin_choice[b], list(bin_items[b])) for b in range(len(bin_choice))]
            best = Solution(bins=bins, cost=cost, optimal=True, note="brute")
            return
        item = problem.items[i]
        for b in range(len(bin_choice)):
            req = item.requirements[bin_choice[b]]
            if req is None:
                continue
            if fits(req, bin_used[b], problem.choices[bin_choice[b]].capacity):
                for d in range(problem.ndim):
                    bin_used[b][d] += req[d]
                bin_items[b].append(i)
                rec(i + 1, cost)
                bin_items[b].pop()
                for d in range(problem.ndim):
                    bin_used[b][d] -= req[d]
        for c in item.compatible():
            req = item.requirements[c]
            ch = problem.choices[c]
            if not fits(req, [0.0] * problem.ndim, ch.capacity):
                continue
            bin_choice.append(c)
            bin_used.append(list(req))
            bin_items.append([i])
            rec(i + 1, cost + ch.price)
            bin_choice.pop()
            bin_used.pop()
            bin_items.pop()

    rec(0, 0.0)
    if best is None:
        raise Infeasible("no feasible assignment exists")
    return best
