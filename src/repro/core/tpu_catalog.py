"""BEYOND-PAPER: the paper's allocation machinery over TPU slice types.

The paper packs (analysis program x camera stream) boxes into EC2 CPU/GPU
trucks. Here the boxes are LLM serving workloads — (architecture x shape)
streams with a tokens/sec target — and the trucks are TPU v5e slices of
different sizes/regions. Requirement vectors are derived *analytically from
the compiled dry-run* (per-token FLOPs and HBM-resident bytes from
experiments/dryrun/*.json when present, else closed-form estimates), which
replaces the paper's empirical profiling step with static analysis.

Dimensions: (bf16 TFLOP/s sustained, HBM GiB). The same 90% head-room rule
and the same exact solver apply unchanged — demonstrating that the
contribution is catalog-agnostic.
"""
from __future__ import annotations

import dataclasses
import json
import os
from typing import Optional, Sequence

from repro.core.catalog import Catalog, InstanceType
from repro.core.packing import Infeasible
from repro.core.manager import ResourceManager
from repro.models.config import ArchConfig, get_config

PEAK_TFLOPS_BF16 = 197.0         # per v5e chip
HBM_GIB = 16.0                   # per v5e chip
MFU = 0.4                        # sustained fraction assumed for serving


def tpu_catalog() -> Catalog:
    """v5e slices at on-demand-style prices (per-chip $1.20/h base, with
    regional multipliers mirroring Table I's price disparity)."""
    def prices(base: float) -> dict[str, float]:
        return {"us-west4": round(base, 3),
                "europe-west4": round(base * 1.12, 3),
                "asia-east1": round(base * 1.23, 3)}

    def slice_type(chips: int) -> InstanceType:
        return InstanceType(
            name=f"v5e-{chips}",
            capacity=(chips * PEAK_TFLOPS_BF16 * MFU, chips * HBM_GIB),
            prices=prices(1.20 * chips),
            has_gpu=False,
            dimensions=("tflops", "hbm_gib"),
        )

    return Catalog(types=(slice_type(1), slice_type(4), slice_type(8),
                          slice_type(16)))


@dataclasses.dataclass(frozen=True)
class LLMStream:
    """One serving workload: an architecture decoding at a tokens/s target."""

    stream_id: str
    arch: str
    tokens_per_s: float
    kv_seq: int = 32_768          # resident context per stream
    batch_of_streams: int = 1

    def requirement(self, dryrun_dir: Optional[str] = None) -> tuple[float, float]:
        """(sustained TFLOP/s needed, HBM GiB resident)."""
        cfg = get_config(self.arch)
        flops_tok = 2.0 * cfg.active_param_count()      # decode fwd
        rec = _load_dryrun(dryrun_dir, self.arch, "decode_32k") if dryrun_dir else None
        if rec and rec.get("flops_per_device", 0) > 0:
            # per-device HLO flops x devices / batch = per-token compiled flops
            flops_tok = rec["flops_per_device"] * 256 / 128
        tflops = self.tokens_per_s * flops_tok / 1e12
        hbm = (_param_bytes(cfg) + _kv_bytes(cfg, self.kv_seq)) / 2**30
        return (tflops, hbm)


def _param_bytes(cfg: ArchConfig) -> float:
    return 2.0 * cfg.param_count()                      # bf16


def _kv_bytes(cfg: ArchConfig, seq: int) -> float:
    total = 0.0
    for mixer, _ in cfg.layer_kinds:
        if mixer == "attn":
            total += 2 * seq * cfg.num_kv_heads * cfg.head_dim * 2
        elif mixer == "attn_window":
            total += 2 * min(seq, cfg.window) * cfg.num_kv_heads * cfg.head_dim * 2
        elif mixer == "ssd":
            total += cfg.ssm_heads * cfg.ssm_head_dim * cfg.ssm_state * 4
        elif mixer == "rglru":
            total += cfg.rnn_width * 4
    return total


def _load_dryrun(dryrun_dir: str, arch: str, shape: str) -> Optional[dict]:
    path = os.path.join(dryrun_dir, f"{arch}_{shape}_pod1.json")
    if not os.path.exists(path):
        return None
    with open(path) as f:
        rec = json.load(f)
    return rec if "error" not in rec and "skipped" not in rec else None


def streams_from_measured(arch: str,
                          per_stream_tokens_per_s: dict[str, float],
                          *, kv_seq: int = 32_768) -> list[LLMStream]:
    """Packing items from an engine's *measured* per-stream decode rates.

    The paper profiles each (program x stream) empirically before packing;
    our analogue is the serving engine's measured tokens/sec rather than an
    assumed fps x tokens-per-frame target. Static lock-step batching
    understates sustainable throughput (a batch stalls on its slowest
    request), so fleet plans built from it over-provision; the continuous-
    batching engine's rates reflect what the hardware actually serves.
    """
    return [LLMStream(sid, arch, tokens_per_s=rate, kv_seq=kv_seq)
            for sid, rate in sorted(per_stream_tokens_per_s.items())]


def streams_from_engine(arch: str, engine, *,
                        kv_seq: int = 32_768) -> list[LLMStream]:
    """Packing items straight from a serving engine's ``measured_rates()``
    export (decode throughput per stream, tokens/s) — the one-call version
    of the profile-then-pack loop. Each item's requirement vector is
    (sustained TFLOP/s, HBM GiB); the resulting plan is costed in $/hour
    like every other catalog. The engine must have served (and been timed
    on) some requests first; an engine with no wall time yields no items.
    """
    return streams_from_measured(arch, engine.measured_rates(), kv_seq=kv_seq)


def build_tpu_problem(streams: Sequence[LLMStream], catalog: Catalog,
                      dryrun_dir: Optional[str] = None):
    """Packing problem over TPU slices; reuses repro.core.packing directly.

    Requirement construction is columnwise, like the camera-fleet
    ``build_problem``: the usable-capacity matrix is built once per choice,
    each distinct (TFLOP/s, HBM GiB) requirement vector is compared against
    the whole column in one numpy pass, and items with equal requirements
    share a single requirements tuple — O(distinct reqs x choices) instead
    of O(streams x choices).
    """
    import numpy as np

    from repro.core.catalog import UTILIZATION_CAP
    from repro.core.packing import Choice, Item, Problem

    choices = []
    for t in catalog.types:
        for loc, price in sorted(t.prices.items()):
            choices.append(Choice(key=f"{t.name}@{loc}", type_name=t.name,
                                  location=loc,
                                  capacity=t.usable(UTILIZATION_CAP),
                                  price=price, has_gpu=t.has_gpu))
    usable = np.array([c.capacity for c in choices])          # (C, D)

    req_tuples: dict[tuple[float, float], tuple] = {}
    items = []
    for s in streams:
        req = s.requirement(dryrun_dir)
        shared = req_tuples.get(req)
        if shared is None:
            ok = (np.asarray(req) <= usable).all(axis=1)      # (C,)
            shared = tuple(req if fit else None for fit in ok)
            req_tuples[req] = shared
        items.append(Item(key=s.stream_id, requirements=shared))
    return Problem(choices=tuple(choices), items=tuple(items))


def plan_tpu_fleet(streams: Sequence[LLMStream],
                   dryrun_dir: Optional[str] = None,
                   strategy: str = "packed") -> dict:
    """strategy: 'packed' (paper's ST3 analog: exact multi-choice packing),
    'uniform-big' (one slice size fits all), 'per-stream' (one slice each)."""
    from repro.core.solver import solve
    from repro.core.heuristics import first_fit_decreasing
    from repro.core.packing import Bin, Solution, validate

    catalog = tpu_catalog()
    problem = build_tpu_problem(streams, catalog, dryrun_dir)
    if strategy == "packed":
        sol, _ = solve(problem, time_budget_s=30.0)
    elif strategy == "per-stream":
        bins = []
        cost = 0.0
        for i, item in enumerate(problem.items):
            compat = item.compatible()
            if not compat:
                raise Infeasible(item.key)
            c = min(compat, key=lambda c: problem.choices[c].price)
            bins.append(Bin(choice=c, items=[i]))
            cost += problem.choices[c].price
        sol = Solution(bins=bins, cost=cost, note="per-stream")
    elif strategy == "uniform-big":
        big = [c for c, ch in enumerate(problem.choices)
               if ch.type_name == "v5e-16" and ch.location == "us-west4"]
        from repro.core.packing import fits
        bins = []
        cost = 0.0
        for i, item in enumerate(problem.items):
            req = item.requirements[big[0]]
            if req is None:
                raise Infeasible(item.key)
            placed = False
            for b in bins:
                used = b.used(problem)
                if fits(req, used, problem.choices[big[0]].capacity):
                    b.items.append(i)
                    placed = True
                    break
            if not placed:
                bins.append(Bin(choice=big[0], items=[i]))
                cost += problem.choices[big[0]].price
        sol = Solution(bins=bins, cost=cost, note="uniform-big")
    else:
        raise ValueError(strategy)
    validate(problem, sol)
    return {"strategy": strategy, "hourly_cost": round(sol.cost, 2),
            "instances": sol.instance_counts(problem),
            "optimal": sol.optimal}
