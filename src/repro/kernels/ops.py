"""Jitted wrappers around the Pallas kernels with platform dispatch.

On TPU the kernels run compiled; everywhere else they run in interpret mode
(Python execution of the kernel body) so CPU tests validate the exact kernel
code that would run on hardware.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import flash_attention as _fa
from repro.kernels import rglru_scan as _rg
from repro.kernels import ssd_scan as _ssd


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(jax.jit, static_argnames=("causal", "window", "bq", "bk"))
def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    bq: int = 128, bk: int = 128):
    return _fa.flash_attention(q, k, v, causal=causal, window=window,
                               bq=bq, bk=bk, interpret=not _on_tpu())


@functools.partial(jax.jit, static_argnames=("chunk",))
def ssd_scan(x, dt, A, Bm, Cm, chunk: int):
    return _ssd.ssd_scan(x, dt, A, Bm, Cm, chunk, interpret=not _on_tpu())


@jax.jit
def rglru_scan(a, b):
    return _rg.rglru_scan(a, b, interpret=not _on_tpu())
