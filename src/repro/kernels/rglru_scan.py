"""RG-LRU linear-recurrence Pallas TPU kernel.

h_t = a_t * h_{t-1} + b_t, elementwise over the channel (lane) dimension.
Grid: (batch, channel-blocks, seq-blocks) with the seq axis sequential; the
hidden state is a (1, bw) VMEM scratch carried across seq blocks. Within a
block the recurrence runs as an in-VMEM time loop (VPU work). A production
kernel would use a log-depth blocked scan; the sequential-in-block form keeps
the same HBM traffic (each element read once) and is the validation target.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
import jax.experimental.pallas.tpu as pltpu

from repro.kernels.pltpu_compat import CompilerParams as _CompilerParams


def _kernel(a_ref, b_ref, y_ref, h_ref, *, bs: int):
    sj = pl.program_id(2)

    @pl.when(sj == 0)
    def _init():
        h_ref[...] = jnp.zeros_like(h_ref)

    def body(t, h):
        a_t = a_ref[0, t, :]
        b_t = b_ref[0, t, :]
        h = a_t * h + b_t
        y_ref[0, t, :] = h.astype(y_ref.dtype)
        return h

    h = jax.lax.fori_loop(0, bs, body, h_ref[0, :])
    h_ref[0, :] = h


def rglru_scan(a, b, *, block_seq: int = 128, block_w: int = 512,
               interpret: bool = True):
    """a, b: (B, S, W) float32. Returns h: (B, S, W)."""
    B, S, W = a.shape
    bs = min(block_seq, S)
    bw = min(block_w, W)
    assert S % bs == 0 and W % bw == 0, (S, bs, W, bw)

    out = pl.pallas_call(
        functools.partial(_kernel, bs=bs),
        grid=(B, W // bw, S // bs),
        in_specs=[
            pl.BlockSpec((1, bs, bw), lambda bi, wj, sj: (bi, sj, wj)),
            pl.BlockSpec((1, bs, bw), lambda bi, wj, sj: (bi, sj, wj)),
        ],
        out_specs=pl.BlockSpec((1, bs, bw), lambda bi, wj, sj: (bi, sj, wj)),
        out_shape=jax.ShapeDtypeStruct((B, S, W), a.dtype),
        scratch_shapes=[pltpu.VMEM((1, bw), jnp.float32)],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
        name="rglru_scan",
    )(a, b)
    return out
