"""Flash attention Pallas TPU kernel (GQA + causal + sliding window).

TPU adaptation: online-softmax with the KV dimension as the innermost
("arbitrary"/sequential) grid axis; m/l/acc VMEM scratch persists across KV
blocks of one query block and the output block is written on the last KV
step. Block shapes default to 128x128 (MXU-aligned); head_dim is the lane
dimension. GQA is expressed in the K/V index_map (query row -> kv row), so
no KV replication is materialized.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
import jax.experimental.pallas.tpu as pltpu

from repro.kernels.pltpu_compat import CompilerParams as _CompilerParams

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
            scale: float, causal: bool, window: int, bq: int, bk: int,
            nk: int, q_off: int):
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0].astype(jnp.float32)                      # (bq, hd)
    k = k_ref[0].astype(jnp.float32)                      # (bk, hd)
    v = v_ref[0].astype(jnp.float32)                      # (bk, hd)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale

    i = pl.program_id(1)
    q_idx = i * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0) + q_off
    k_idx = j * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    mask = jnp.ones((bq, bk), jnp.bool_)
    if causal:
        mask &= k_idx <= q_idx
    if window > 0:
        mask &= k_idx > q_idx - window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[...]                                   # (bq, 1)
    m_cur = jnp.max(s, axis=1, keepdims=True)
    m_new = jnp.maximum(m_prev, m_cur)
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new)
    l_new = l_ref[...] * alpha + jnp.sum(p, axis=1, keepdims=True)
    acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot(
        p.astype(v.dtype), v, preferred_element_type=jnp.float32)
    m_ref[...] = m_new
    l_ref[...] = l_new

    @pl.when(j == nk - 1)
    def _finish():
        l = l_ref[...]
        l = jnp.where(l == 0.0, 1.0, l)
        o_ref[0] = (acc_ref[...] / l).astype(o_ref.dtype)


def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    bq: int = 128, bk: int = 128,
                    interpret: bool = True) -> jnp.ndarray:
    """q: (B,S,H,hd); k,v: (B,T,K,hd). Returns (B,S,H,hd)."""
    B, S, H, hd = q.shape
    T, K = k.shape[1], k.shape[2]
    G = H // K
    bq = min(bq, S)
    bk = min(bk, T)
    assert S % bq == 0 and T % bk == 0, (S, bq, T, bk)
    nq, nk = S // bq, T // bk
    q_off = T - S                       # queries are the last S of T positions

    qf = jnp.moveaxis(q, 2, 1).reshape(B * H, S, hd)
    kf = jnp.moveaxis(k, 2, 1).reshape(B * K, T, hd)
    vf = jnp.moveaxis(v, 2, 1).reshape(B * K, T, hd)

    def kv_row(bh, i, j):
        return (bh // H) * K + (bh % H) // G

    out = pl.pallas_call(
        functools.partial(_kernel, scale=1.0 / math.sqrt(hd), causal=causal,
                          window=window, bq=bq, bk=bk, nk=nk, q_off=q_off),
        grid=(B * H, nq, nk),
        in_specs=[
            pl.BlockSpec((1, bq, hd), lambda bh, i, j: (bh, i, 0)),
            pl.BlockSpec((1, bk, hd), lambda bh, i, j: (kv_row(bh, i, j), j, 0)),
            pl.BlockSpec((1, bk, hd), lambda bh, i, j: (kv_row(bh, i, j), j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, hd), lambda bh, i, j: (bh, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B * H, S, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, hd), jnp.float32),
        ],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
        name="flash_attention",
    )(qf, kf, vf)
    return jnp.moveaxis(out.reshape(B, H, S, hd), 1, 2)
