"""Pure-jnp oracles for every Pallas kernel. The pytest sweeps assert
allclose(kernel(interpret=True), ref) across shapes/dtypes."""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def flash_attention_ref(q, k, v, *, causal: bool = True, window: int = 0):
    """q: (B,S,H,hd); k,v: (B,T,K,hd) with H % K == 0. Returns (B,S,H,hd)."""
    B, S, H, hd = q.shape
    T, K = k.shape[1], k.shape[2]
    G = H // K
    qg = q.reshape(B, S, K, G, hd)
    scores = jnp.einsum("bskgh,btkh->bkgst", qg.astype(jnp.float32),
                        k.astype(jnp.float32)) / math.sqrt(hd)
    srange = jnp.arange(S)
    trange = jnp.arange(T)
    mask = jnp.ones((S, T), bool)
    off = T - S  # queries are the last S positions when T > S
    if causal:
        mask &= trange[None, :] <= srange[:, None] + off
    if window > 0:
        mask &= trange[None, :] > srange[:, None] + off - window
    scores = jnp.where(mask[None, None, None], scores, NEG_INF)
    w = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgst,btkh->bskgh", w, v.astype(jnp.float32))
    return out.reshape(B, S, H, hd).astype(q.dtype)


def ssd_scan_ref(x, dt, A, Bm, Cm, chunk: int):
    """Chunked SSD oracle (same math as models.ssm.ssd_scan_ref; duplicated so
    kernels/ has a self-contained oracle). x: (b,s,h,p); dt: (b,s,h); A: (h,);
    Bm, Cm: (b,s,g,n)."""
    from repro.models.ssm import ssd_scan_ref as _impl
    return _impl(x, dt, A, Bm, Cm, chunk)


def ssd_scan_naive(x, dt, A, Bm, Cm):
    """O(S) sequential state recurrence — the ground-truth definition."""
    b, s, h, p = x.shape
    g, n = Bm.shape[2], Bm.shape[3]
    rep = h // g
    Bh = jnp.repeat(Bm, rep, axis=2).astype(jnp.float32)
    Ch = jnp.repeat(Cm, rep, axis=2).astype(jnp.float32)
    dA = jnp.exp(dt * A)                                    # (b,s,h)

    def step(state, inp):
        dA_t, dt_t, B_t, C_t, x_t = inp
        state = state * dA_t[:, :, None, None] + jnp.einsum(
            "bh,bhn,bhp->bhpn", dt_t, B_t, x_t)
        y = jnp.einsum("bhn,bhpn->bhp", C_t, state)
        return state, y

    xs = (jnp.moveaxis(dA, 1, 0), jnp.moveaxis(dt, 1, 0),
          jnp.moveaxis(Bh, 1, 0), jnp.moveaxis(Ch, 1, 0),
          jnp.moveaxis(x.astype(jnp.float32), 1, 0))
    _, ys = jax.lax.scan(step, jnp.zeros((b, h, p, n), jnp.float32), xs)
    return jnp.moveaxis(ys, 0, 1).astype(x.dtype)


def rglru_scan_ref(a, b):
    """h_t = a_t * h_{t-1} + b_t over axis 1. a, b: (B, S, W)."""
    def combine(c1, c2):
        a1, b1 = c1
        a2, b2 = c2
        return a1 * a2, b1 * a2 + b2
    _, h = jax.lax.associative_scan(combine, (a, b), axis=1)
    return h
