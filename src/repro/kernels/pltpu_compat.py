"""Version compat for Pallas TPU names shared by the kernel modules.

jax 0.4.x names the compiler-options struct ``TPUCompilerParams``; newer
releases renamed it to ``CompilerParams``. Accept either so the kernels
track the installed jax.
"""
from __future__ import annotations

import jax.experimental.pallas.tpu as pltpu

CompilerParams = getattr(pltpu, "TPUCompilerParams", None) or \
    getattr(pltpu, "CompilerParams")
