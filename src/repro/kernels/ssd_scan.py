"""Mamba-2 SSD chunked-scan Pallas TPU kernel.

Layout: one grid row per (batch*head); the chunk axis is the sequential grid
dimension; the (state_dim x head_dim) SSM state lives in VMEM scratch and is
carried across chunks. Within a chunk everything is dense 2-D matmul work
(MXU): C@B^T intra-chunk scores, score@x, and the rank-L state update — this
is the TPU-native form of SSD (the GPU version's warp-level segsum becomes
plain VMEM-resident cumsum + broadcast here).

Wrapper expectations: B/C already broadcast per head (groups expanded by the
caller); chunk divides S.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
import jax.experimental.pallas.tpu as pltpu

from repro.kernels.pltpu_compat import CompilerParams as _CompilerParams


def _kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, y_ref, state_ref, *, L: int):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        state_ref[...] = jnp.zeros_like(state_ref)

    x = x_ref[0].astype(jnp.float32)          # (L, P)
    dt = dt_ref[0].astype(jnp.float32)        # (L, 1)
    A = a_ref[0, 0]                           # scalar
    Bm = b_ref[0].astype(jnp.float32)         # (L, N)
    Cm = c_ref[0].astype(jnp.float32)         # (L, N)

    dA = dt[:, 0] * A                         # (L,)
    cs = jnp.cumsum(dA)                       # (L,)

    # intra-chunk (attention-like, causal)
    cb = jax.lax.dot_general(Cm, Bm, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)  # (L, L)
    diff = cs[:, None] - cs[None, :]
    li = jax.lax.broadcasted_iota(jnp.int32, (L, L), 0)
    sj = jax.lax.broadcasted_iota(jnp.int32, (L, L), 1)
    scores = jnp.where(li >= sj, cb * jnp.exp(diff) * dt[:, 0][None, :], 0.0)
    y = jax.lax.dot(scores, x, preferred_element_type=jnp.float32)  # (L, P)

    # inter-chunk contribution from the carried state (N, P)
    state = state_ref[...]
    y = y + jax.lax.dot(Cm * jnp.exp(cs)[:, None], state,
                        preferred_element_type=jnp.float32)

    # state update: decay to end of chunk + new outer products
    decay_all = jnp.exp(cs[L - 1])
    w = dt[:, 0] * jnp.exp(cs[L - 1] - cs)                          # (L,)
    state_ref[...] = state * decay_all + jax.lax.dot_general(
        Bm * w[:, None], x, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)                         # (N, P)

    y_ref[0] = y.astype(y_ref.dtype)


def ssd_scan(x, dt, A, Bm, Cm, chunk: int, *, interpret: bool = True):
    """x: (b,s,h,p); dt: (b,s,h); A: (h,); Bm,Cm: (b,s,g,n). -> (b,s,h,p)."""
    b, s, h, p = x.shape
    g, n = Bm.shape[2], Bm.shape[3]
    rep = h // g
    L = chunk
    assert s % L == 0, (s, L)
    nc = s // L

    xf = jnp.moveaxis(x, 2, 1).reshape(b * h, s, p)
    dtf = jnp.moveaxis(dt, 2, 1).reshape(b * h, s, 1)
    Bh = jnp.repeat(Bm, rep, axis=2)
    Ch = jnp.repeat(Cm, rep, axis=2)
    Bf = jnp.moveaxis(Bh, 2, 1).reshape(b * h, s, n)
    Cf = jnp.moveaxis(Ch, 2, 1).reshape(b * h, s, n)
    Af = jnp.tile(A.reshape(1, h), (b, 1)).reshape(b * h, 1)

    out = pl.pallas_call(
        functools.partial(_kernel, L=L),
        grid=(b * h, nc),
        in_specs=[
            pl.BlockSpec((1, L, p), lambda r, j: (r, j, 0)),
            pl.BlockSpec((1, L, 1), lambda r, j: (r, j, 0)),
            pl.BlockSpec((1, 1), lambda r, j: (r, 0)),
            pl.BlockSpec((1, L, n), lambda r, j: (r, j, 0)),
            pl.BlockSpec((1, L, n), lambda r, j: (r, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, L, p), lambda r, j: (r, j, 0)),
        out_shape=jax.ShapeDtypeStruct((b * h, s, p), x.dtype),
        scratch_shapes=[pltpu.VMEM((n, p), jnp.float32)],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
        name="ssd_scan",
    )(xf, dtf, Af, Bf, Cf)
    return jnp.moveaxis(out.reshape(b, h, s, p), 1, 2)
