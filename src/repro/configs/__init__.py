"""Assigned architecture configs. Each module registers a full config (exact
sizes from the source paper/model card) and a REDUCED variant (<=2 layers,
d_model<=512, <=4 experts) used by the CPU smoke tests."""
from repro.models.config import get_config, list_archs  # re-export

__all__ = ["get_config", "list_archs"]
