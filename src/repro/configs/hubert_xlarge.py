"""hubert-xlarge — encoder-only audio transformer [arXiv:2106.07447].

The conv waveform feature extractor is STUBBED — input_specs() provides
frame embeddings (batch, frames, d_model). We implement the transformer
encoder: 48 layers, d_model 1280, 16 heads (MHA, kv=16), d_ff 5120 (GELU,
non-gated), bidirectional attention. "vocab" 504 = masked-prediction
codebook targets. Encoder-only => no decode shapes (noted in DESIGN.md).
"""
from repro.models.config import ArchConfig, register

CONFIG = register(
    ArchConfig(
        name="hubert-xlarge",
        arch_type="audio",
        num_layers=48,
        d_model=1280,
        vocab_size=504,
        block_pattern=(("attn", "mlp"),),
        num_heads=16,
        num_kv_heads=16,
        head_dim=80,
        d_ff=5120,
        activation="gelu",
        gated=False,
        causal=False,
        norm="layernorm",
        frontend="audio",
        source="arXiv:2106.07447 (HuBERT X-Large)",
    ),
    ArchConfig(
        name="hubert-xlarge",
        arch_type="audio",
        num_layers=2,
        d_model=256,
        vocab_size=64,
        block_pattern=(("attn", "mlp"),),
        num_heads=4,
        num_kv_heads=4,
        head_dim=64,
        d_ff=512,
        activation="gelu",
        gated=False,
        causal=False,
        norm="layernorm",
        frontend="audio",
        source="reduced",
    ),
)
