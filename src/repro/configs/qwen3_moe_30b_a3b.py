"""qwen3-moe-30b-a3b — 128 experts top-8 [hf:Qwen/Qwen3-30B-A3B].

MoE decoder: 48 layers, d_model 2048, 32 heads GQA kv=4 (head_dim 128),
per-expert FFN 768, 128 experts, 8 active per token, vocab 151936.
"""
from repro.models.config import ArchConfig, register

CONFIG = register(
    ArchConfig(
        name="qwen3-moe-30b-a3b",
        arch_type="moe",
        num_layers=48,
        d_model=2048,
        vocab_size=151_936,
        block_pattern=(("attn", "moe"),),
        num_heads=32,
        num_kv_heads=4,
        head_dim=128,
        d_ff=0,
        activation="silu",
        gated=True,
        num_experts=128,
        experts_per_token=8,
        moe_d_ff=768,
        norm="rmsnorm",
        source="hf:Qwen/Qwen3-30B-A3B",
    ),
    ArchConfig(
        name="qwen3-moe-30b-a3b",
        arch_type="moe",
        num_layers=2,
        d_model=128,
        vocab_size=512,
        block_pattern=(("attn", "moe"),),
        num_heads=4,
        num_kv_heads=2,
        head_dim=32,
        d_ff=0,
        activation="silu",
        gated=True,
        num_experts=4,
        experts_per_token=2,
        moe_d_ff=64,
        norm="rmsnorm",
        source="reduced",
    ),
)
