"""moonshot-v1-16b-a3b — Moonlight-16B-A3B [hf:moonshotai/Moonlight-16B-A3B].

DeepSeek-V3-style fine-grained MoE: 48 layers (as assigned), d_model 2048,
16 heads GQA kv=16 (MHA-width KV), per-expert FFN 1408, 64 experts top-6,
vocab 163840. The assignment tags it "[dense] ... MoE?" — the model card is
a MoE; we implement it as MoE (64e/top-6) and note the ambiguity here.
"""
from repro.models.config import ArchConfig, register

CONFIG = register(
    ArchConfig(
        name="moonshot-v1-16b-a3b",
        arch_type="moe",
        num_layers=48,
        d_model=2048,
        vocab_size=163_840,
        block_pattern=(("attn", "moe"),),
        num_heads=16,
        num_kv_heads=16,
        head_dim=128,
        d_ff=0,
        activation="silu",
        gated=True,
        num_experts=64,
        experts_per_token=6,
        moe_d_ff=1408,
        norm="rmsnorm",
        source="hf:moonshotai/Moonlight-16B-A3B",
    ),
    ArchConfig(
        name="moonshot-v1-16b-a3b",
        arch_type="moe",
        num_layers=2,
        d_model=128,
        vocab_size=512,
        block_pattern=(("attn", "moe"),),
        num_heads=4,
        num_kv_heads=4,
        head_dim=32,
        d_ff=0,
        activation="silu",
        gated=True,
        num_experts=4,
        experts_per_token=2,
        moe_d_ff=64,
        norm="rmsnorm",
        source="reduced",
    ),
)
