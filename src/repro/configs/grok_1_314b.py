"""grok-1-314b — 8-expert top-2 MoE [hf:xai-org/grok-1].

64 layers, d_model 6144, 48 heads GQA kv=8 (head_dim 128), per-expert
FFN 32768, 8 experts top-2, vocab 131072.
"""
from repro.models.config import ArchConfig, register

CONFIG = register(
    ArchConfig(
        name="grok-1-314b",
        arch_type="moe",
        num_layers=64,
        d_model=6144,
        vocab_size=131_072,
        block_pattern=(("attn", "moe"),),
        num_heads=48,
        num_kv_heads=8,
        head_dim=128,
        d_ff=0,
        activation="gelu",
        gated=True,
        num_experts=8,
        experts_per_token=2,
        moe_d_ff=32768,
        norm="rmsnorm",
        source="hf:xai-org/grok-1",
    ),
    ArchConfig(
        name="grok-1-314b",
        arch_type="moe",
        num_layers=2,
        d_model=128,
        vocab_size=512,
        block_pattern=(("attn", "moe"),),
        num_heads=4,
        num_kv_heads=2,
        head_dim=32,
        d_ff=0,
        activation="gelu",
        gated=True,
        num_experts=4,
        experts_per_token=2,
        moe_d_ff=256,
        norm="rmsnorm",
        source="reduced",
    ),
)
