"""recurrentgemma-9b — RG-LRU + local attention, 2:1 [arXiv:2402.19427].

Griffin-style hybrid: repeating (recurrent, recurrent, local-attention)
blocks, 38 layers, d_model 4096, 16 heads MQA (kv=1), GeGLU d_ff 12288,
local attention window 2048, rnn width 4096.
"""
from repro.models.config import ArchConfig, register

CONFIG = register(
    ArchConfig(
        name="recurrentgemma-9b",
        arch_type="hybrid",
        num_layers=38,
        d_model=4096,
        vocab_size=256_000,
        block_pattern=(("rglru", "mlp"), ("rglru", "mlp"), ("attn_window", "mlp")),
        num_heads=16,
        num_kv_heads=1,
        head_dim=256,
        window=2048,
        d_ff=12288,
        activation="gelu",
        gated=True,
        rnn_width=4096,
        rnn_conv=4,
        norm="rmsnorm",
        source="arXiv:2402.19427 (RecurrentGemma / Griffin)",
    ),
    ArchConfig(
        name="recurrentgemma-9b",
        arch_type="hybrid",
        num_layers=3,
        d_model=256,
        vocab_size=512,
        block_pattern=(("rglru", "mlp"), ("rglru", "mlp"), ("attn_window", "mlp")),
        num_heads=4,
        num_kv_heads=1,
        head_dim=64,
        window=64,
        d_ff=512,
        activation="gelu",
        gated=True,
        rnn_width=256,
        rnn_conv=4,
        norm="rmsnorm",
        source="reduced",
    ),
)
