"""yi-9b — llama-architecture dense GQA [arXiv:2403.04652].

48 layers, d_model 4096, 32 heads GQA kv=4 (head_dim 128), SwiGLU d_ff 11008,
vocab 64000.
"""
from repro.models.config import ArchConfig, register

CONFIG = register(
    ArchConfig(
        name="yi-9b",
        arch_type="dense",
        num_layers=48,
        d_model=4096,
        vocab_size=64_000,
        block_pattern=(("attn", "mlp"),),
        num_heads=32,
        num_kv_heads=4,
        head_dim=128,
        d_ff=11008,
        activation="silu",
        gated=True,
        norm="rmsnorm",
        source="arXiv:2403.04652 (Yi-9B)",
    ),
    ArchConfig(
        name="yi-9b",
        arch_type="dense",
        num_layers=2,
        d_model=256,
        vocab_size=512,
        block_pattern=(("attn", "mlp"),),
        num_heads=4,
        num_kv_heads=2,
        head_dim=64,
        d_ff=512,
        activation="silu",
        gated=True,
        norm="rmsnorm",
        source="reduced",
    ),
)
