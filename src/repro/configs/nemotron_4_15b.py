"""nemotron-4-15b — GQA + squared-ReLU MLP [arXiv:2402.16819].

32 layers, d_model 6144, 48 heads GQA kv=8 (head_dim 128), non-gated
squared-ReLU d_ff 24576, vocab 256000, layernorm.
"""
from repro.models.config import ArchConfig, register

CONFIG = register(
    ArchConfig(
        name="nemotron-4-15b",
        arch_type="dense",
        num_layers=32,
        d_model=6144,
        vocab_size=256_000,
        block_pattern=(("attn", "mlp"),),
        num_heads=48,
        num_kv_heads=8,
        head_dim=128,
        d_ff=24576,
        activation="relu2",
        gated=False,
        norm="layernorm",
        source="arXiv:2402.16819 (Nemotron-4 15B)",
    ),
    ArchConfig(
        name="nemotron-4-15b",
        arch_type="dense",
        num_layers=2,
        d_model=256,
        vocab_size=512,
        block_pattern=(("attn", "mlp"),),
        num_heads=4,
        num_kv_heads=2,
        head_dim=64,
        d_ff=512,
        activation="relu2",
        gated=False,
        norm="layernorm",
        source="reduced",
    ),
)
