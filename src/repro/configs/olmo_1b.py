"""olmo-1b — non-parametric LayerNorm [arXiv:2402.00838].

16 layers, d_model 2048, 16 heads MHA (kv=16), SwiGLU d_ff 8192,
vocab 50304, non-parametric LayerNorm (no scale/bias).
"""
from repro.models.config import ArchConfig, register

CONFIG = register(
    ArchConfig(
        name="olmo-1b",
        arch_type="dense",
        num_layers=16,
        d_model=2048,
        vocab_size=50_304,
        block_pattern=(("attn", "mlp"),),
        num_heads=16,
        num_kv_heads=16,
        head_dim=128,
        d_ff=8192,
        activation="silu",
        gated=True,
        norm="nonparam_ln",
        tie_embeddings=True,
        source="arXiv:2402.00838 (OLMo-1B)",
    ),
    ArchConfig(
        name="olmo-1b",
        arch_type="dense",
        num_layers=2,
        d_model=256,
        vocab_size=512,
        block_pattern=(("attn", "mlp"),),
        num_heads=4,
        num_kv_heads=4,
        head_dim=64,
        d_ff=512,
        activation="silu",
        gated=True,
        norm="nonparam_ln",
        tie_embeddings=True,
        source="reduced",
    ),
)
