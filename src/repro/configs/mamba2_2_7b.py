"""mamba2-2.7b — SSD (state-space duality) [arXiv:2405.21060].

Attention-free: 64 pure-SSD blocks, d_model 2560, d_state 128, no FFN
(Mamba-2 folds the MLP into the expanded SSD block, d_inner = 2*d_model).
"""
from repro.models.config import ArchConfig, register

CONFIG = register(
    ArchConfig(
        name="mamba2-2.7b",
        arch_type="ssm",
        num_layers=64,
        d_model=2560,
        vocab_size=50280,
        block_pattern=(("ssd", None),),
        ssm_state=128,
        ssm_head_dim=64,
        ssm_expand=2,
        ssm_conv=4,
        ssm_chunk=128,
        norm="rmsnorm",
        source="arXiv:2405.21060 (Mamba-2, SSD)",
    ),
    ArchConfig(
        name="mamba2-2.7b",
        arch_type="ssm",
        num_layers=2,
        d_model=256,
        vocab_size=512,
        block_pattern=(("ssd", None),),
        ssm_state=32,
        ssm_head_dim=32,
        ssm_expand=2,
        ssm_conv=4,
        ssm_chunk=32,
        norm="rmsnorm",
        source="reduced",
    ),
)
