"""internvl2-1b — InternViT + Qwen2-0.5B language decoder [arXiv:2404.16821].

VLM: the vision tower (InternViT-300M) + MLP projector are STUBBED —
input_specs() provides projected patch embeddings of shape
(batch, num_patches, d_model). We implement the language decoder backbone:
24 layers, d_model 896, 14 heads GQA kv=2, d_ff 4864, vocab 151655.
"""
from repro.models.config import ArchConfig, register

CONFIG = register(
    ArchConfig(
        name="internvl2-1b",
        arch_type="vlm",
        num_layers=24,
        d_model=896,
        vocab_size=151_655,
        block_pattern=(("attn", "mlp"),),
        num_heads=14,
        num_kv_heads=2,
        head_dim=64,
        d_ff=4864,
        activation="silu",
        gated=True,
        norm="rmsnorm",
        frontend="vision",
        num_patches=256,
        source="arXiv:2404.16821 (InternVL2-1B: InternViT + InternLM2/Qwen2)",
    ),
    ArchConfig(
        name="internvl2-1b",
        arch_type="vlm",
        num_layers=2,
        d_model=128,
        vocab_size=512,
        block_pattern=(("attn", "mlp"),),
        num_heads=4,
        num_kv_heads=2,
        head_dim=32,
        d_ff=256,
        activation="silu",
        gated=True,
        norm="rmsnorm",
        frontend="vision",
        num_patches=16,
        source="reduced",
    ),
)
