"""Serving launcher: allocation-managed multi-stream serving demo.

Serves simulated camera streams on the continuous-batching engine first (the
measurement phase — the paper's empirical profiling step), then plans the
fleet with the resource manager from the *measured* per-stream tokens/sec
and reports cost, throughput, and SLO attainment. CPU-sized by default
(reduced configs); the same flow drives full configs on real slices.
"""
from __future__ import annotations

import argparse
import json

import jax
import jax.numpy as jnp

import numpy as np

from repro.core.tpu_catalog import (LLMStream, plan_tpu_fleet,
                                    streams_from_measured)
from repro.models import model as M
from repro.models.config import get_config, list_archs
from repro.serving import (ContinuousBatchingEngine, Request, ServingEngine,
                           StreamSimulator)


def _warmup(eng, prompt_len: int, new_tokens: int) -> None:
    """Compile the prefill/decode paths outside the measurement window and
    reset the stats — otherwise one-time jit cost deflates the measured
    rates the fleet planner consumes. The static engine compiles per batch
    shape, so warm it at its full max_batch (the continuous engine always
    prefills B=1 and decodes B=max_slots, so one request covers both)."""
    n = getattr(eng, "max_batch", 1)
    toks = np.zeros(prompt_len, np.int32)
    for i in range(n):
        eng.submit(Request(f"warmup-{i}", toks.copy(),
                           max_new_tokens=new_tokens))
    eng.drain()
    eng.reset_stats()


def serve(arch: str = "olmo-1b", *, n_streams: int = 4, fps: float = 2.0,
          seconds: int = 3, reduced: bool = True,
          dryrun_dir: str | None = None, engine: str = "continuous") -> dict:
    # 1) serve the streams (reduced config on CPU) and measure throughput
    cfg = get_config(arch, reduced=reduced)
    params = M.init_params(cfg, jax.random.PRNGKey(0), jnp.float32)
    if engine == "continuous":
        eng = ContinuousBatchingEngine(cfg, params, max_slots=8,
                                       cache_len=128)
    elif engine == "static":
        eng = ServingEngine(cfg, params, max_batch=8, cache_len=128)
    else:
        raise ValueError(engine)
    _warmup(eng, prompt_len=32, new_tokens=8)
    sim = StreamSimulator(eng, prompt_len=32, new_tokens=8)
    done = []
    for t in range(seconds):
        sim.tick({f"cam-{i}": fps for i in range(n_streams)}, dt_s=1.0)
        done.extend(eng.drain())

    # 2) per-stream measured rates feed the packing machinery (the paper's
    # profile-then-pack loop); streams that served no frames fall back to
    # their nominal fps x tokens-per-frame target
    measured = eng.measured_rates()
    for i in range(n_streams):
        measured.setdefault(f"cam-{i}", fps * 8)

    streams = streams_from_measured(arch, measured)
    plans = {s: plan_tpu_fleet(streams, dryrun_dir=dryrun_dir, strategy=s)
             for s in ("per-stream", "uniform-big", "packed")}
    packed, per_stream = plans["packed"], plans["per-stream"]
    savings = 1.0 - packed["hourly_cost"] / per_stream["hourly_cost"]
    out = {
        "arch": arch,
        "engine": engine,
        "frames_served": len(done),
        "tokens_per_s": round(eng.throughput_tokens_per_s(), 1),
        "measured_stream_tokens_per_s": {k: round(v, 1)
                                         for k, v in sorted(measured.items())},
        "fleet_plans": plans,
        "packed_vs_per_stream_savings": round(savings, 3),
    }
    if isinstance(eng, ContinuousBatchingEngine):
        rep = eng.report()
        out["serving_report"] = {k: round(v, 4) if isinstance(v, float) else v
                                 for k, v in rep.items()}
    return out


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=list_archs(), default="olmo-1b")
    ap.add_argument("--streams", type=int, default=4)
    ap.add_argument("--fps", type=float, default=2.0)
    ap.add_argument("--seconds", type=int, default=3)
    ap.add_argument("--engine", choices=("continuous", "static"),
                    default="continuous")
    ap.add_argument("--dryrun-dir", default=None)
    args = ap.parse_args()
    out = serve(args.arch, n_streams=args.streams, fps=args.fps,
                seconds=args.seconds, dryrun_dir=args.dryrun_dir,
                engine=args.engine)
    print(json.dumps(out, indent=2))


if __name__ == "__main__":
    main()
