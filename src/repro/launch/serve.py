"""Serving launcher: allocation-managed multi-stream serving demo.

Plans a fleet with the resource manager (the paper's contribution), then
serves simulated camera streams on the planned engines and reports cost +
throughput. CPU-sized by default (reduced configs); the same flow drives
full configs on real slices.
"""
from __future__ import annotations

import argparse
import json

import jax
import jax.numpy as jnp

from repro.core.tpu_catalog import LLMStream, plan_tpu_fleet
from repro.models import model as M
from repro.models.config import get_config, list_archs
from repro.serving import ServingEngine, StreamSimulator


def serve(arch: str = "olmo-1b", *, n_streams: int = 4, fps: float = 2.0,
          seconds: int = 3, reduced: bool = True,
          dryrun_dir: str | None = None) -> dict:
    # 1) plan the fleet with the paper's packing machinery
    streams = [LLMStream(f"cam-{i}", arch, tokens_per_s=fps * 8)
               for i in range(n_streams)]
    plans = {s: plan_tpu_fleet(streams, dryrun_dir=dryrun_dir, strategy=s)
             for s in ("per-stream", "uniform-big", "packed")}

    # 2) serve the streams (reduced config on CPU)
    cfg = get_config(arch, reduced=reduced)
    params = M.init_params(cfg, jax.random.PRNGKey(0), jnp.float32)
    engine = ServingEngine(cfg, params, max_batch=8, cache_len=128)
    sim = StreamSimulator(engine, prompt_len=32, new_tokens=8)
    done = []
    for t in range(seconds):
        sim.tick({f"cam-{i}": fps for i in range(n_streams)}, dt_s=1.0)
        done.extend(engine.drain())
    packed, per_stream = plans["packed"], plans["per-stream"]
    savings = 1.0 - packed["hourly_cost"] / per_stream["hourly_cost"]
    return {
        "arch": arch,
        "frames_served": len(done),
        "tokens_per_s": round(engine.throughput_tokens_per_s(), 1),
        "fleet_plans": plans,
        "packed_vs_per_stream_savings": round(savings, 3),
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=list_archs(), default="olmo-1b")
    ap.add_argument("--streams", type=int, default=4)
    ap.add_argument("--fps", type=float, default=2.0)
    ap.add_argument("--seconds", type=int, default=3)
    ap.add_argument("--dryrun-dir", default=None)
    args = ap.parse_args()
    out = serve(args.arch, n_streams=args.streams, fps=args.fps,
                seconds=args.seconds, dryrun_dir=args.dryrun_dir)
    print(json.dumps(out, indent=2))


if __name__ == "__main__":
    main()
