"""Production mesh definitions.

Target hardware: TPU v5e pods — 256 chips per pod in a (16, 16) grid;
multi-pod = 2 pods = 512 chips with a leading "pod" axis.

Defined as functions (never module-level constants) so importing this module
never touches jax device state; the dry-run sets
XLA_FLAGS=--xla_force_host_platform_device_count=512 before first jax use.
"""
from __future__ import annotations

import numpy as np

import jax


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = int(np.prod(shape))
    devices = jax.devices()
    if len(devices) < n:
        raise RuntimeError(
            f"need {n} devices for mesh {shape}, have {len(devices)}; "
            "run under XLA_FLAGS=--xla_force_host_platform_device_count=512")
    dev_array = np.asarray(devices[:n]).reshape(shape)
    return jax.sharding.Mesh(dev_array, axes)


def make_smoke_mesh(shape=(1, 1), axes=("data", "model")) -> jax.sharding.Mesh:
    """Single-device mesh for CPU tests (sharding rules still exercised)."""
    n = int(np.prod(shape))
    dev_array = np.asarray(jax.devices()[:n]).reshape(shape)
    return jax.sharding.Mesh(dev_array, axes)


def data_axes(mesh: jax.sharding.Mesh) -> tuple[str, ...]:
    """Axes used for batch-parallelism on this mesh."""
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def model_axis(mesh: jax.sharding.Mesh) -> str:
    return "model"
