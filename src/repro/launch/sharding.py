"""Sharding rules: parameter / optimizer / batch / cache PartitionSpecs.

Baseline policy (recorded as such in EXPERIMENTS.md §Perf):
  * tensor-parallel over "model": attention heads, FFN hidden, experts,
    SSD inner dim, RG-LRU width, vocab (embedding rows / lm_head cols)
  * batch-parallel over ("pod","data")
  * ``fsdp=True`` additionally shards the non-model major dim of large
    2D+ weights over "data" (needed for >=9B params on 16 GB v5e chips)
  * long-context decode (batch 1): KV-cache sequence axis sharded over the
    data axes instead of batch
Scan-stacked parameters (leading repeat dim) get None prepended.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.data.pipeline import InputShape
from repro.models.config import ArchConfig

Pytree = Any


@dataclasses.dataclass(frozen=True)
class ShardingPolicy:
    fsdp: bool = False                 # shard major dims over "data" as well
    shard_seq_in_long_decode: bool = True
    # perf iteration 1 (grok-1): when experts don't divide the model axis,
    # shard the expert matmul dims instead of replicating. False reproduces
    # the pre-iteration baseline.
    expert_fallback_shard: bool = True
    # perf iteration 3 (yi-9b decode): shard the KV-cache sequence axis over
    # "model" when kv heads don't divide it (False = shard head_dim).
    decode_seq_over_model: bool = False

    @staticmethod
    def for_arch(cfg: ArchConfig) -> "ShardingPolicy":
        big = cfg.param_count() >= 8e9
        return ShardingPolicy(fsdp=big)


def _dp(mesh: Mesh) -> tuple[str, ...]:
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def _fsdp_axis(mesh: Mesh, policy: ShardingPolicy) -> Optional[str]:
    return "data" if (policy.fsdp and "data" in mesh.axis_names) else None


def param_spec(path: str, leaf, mesh: Mesh, policy: ShardingPolicy,
               stacked: bool) -> P:
    """PartitionSpec for one parameter leaf, identified by its key path.

    Every axis assignment is divisibility-checked against the mesh (explicit
    in_shardings reject padding); on failure the rule falls through an
    alternative-dims chain and ultimately replicates. This is what lets odd
    vocabularies (50280, 151655, 504) and grok's 8 experts < 16-way model
    axis lower cleanly.
    """
    fa = _fsdp_axis(mesh, policy)
    name = path.split("/")[-1]
    offset = 1 if stacked else 0
    ndim = leaf.ndim - offset
    shape = leaf.shape[offset:]

    def _ok(dim: int, axis) -> bool:
        if axis is None:
            return True
        axes = axis if isinstance(axis, tuple) else (axis,)
        n = 1
        for a in axes:
            n *= mesh.shape[a]
        return shape[dim] % n == 0

    def out(*axes):
        axes = list(axes) + [None] * (ndim - len(axes))
        used: set = set()
        clean = []
        for d, a in enumerate(axes):
            if a is not None and _ok(d, a) and a not in used:
                clean.append(a)
                used.add(a)
            else:
                clean.append(None)
        if stacked:
            clean = [None] + clean
        return P(*clean)

    def chain(*candidates):
        """First candidate whose every axis divides evenly wins."""
        for cand in candidates:
            full = list(cand) + [None] * (ndim - len(cand))
            if all(a is None or _ok(d, a) for d, a in enumerate(full)):
                return out(*cand)
        return out()

    if name == "embedding":                        # (V, D)
        return chain(("model", fa), (None, "model"))
    if name == "lm_head":                          # (D, V)
        return chain((fa, "model"), ("model", fa))
    if name in ("wq", "wk", "wv", "w1", "w3", "wx", "wgate", "in_proj"):
        if ndim == 3:                              # moe (E, D, F)
            # expert-parallel when E divides the model axis; otherwise shard
            # the matmul dims fully (perf iteration 1: grok's 8 experts on a
            # 16-way model axis must not fall back to replication)
            if policy.expert_fallback_shard:
                return chain(("model", fa, None), (None, fa, "model"),
                             (None, None, "model"), (None, fa, None))
            return chain(("model", fa, None), (fa, None, "model"))
        return chain((fa, "model"), ("model", fa))
    if name in ("wo", "w2", "out_proj"):
        if ndim == 3:                              # moe (E, F, D)
            if policy.expert_fallback_shard:
                return chain(("model", None, fa), (None, "model", fa),
                             (None, "model", None), (None, None, fa))
            return chain(("model", None, fa), (fa, "model", None))
        return chain(("model", fa), (fa, "model"))
    if name in ("wr", "wi"):                       # rg-lru gates (W, W)
        return chain((fa, "model"))
    if name == "router":
        return out()
    if name == "conv_w":
        return chain((None, "model"))
    if name in ("conv_b", "norm_scale", "lam"):
        return chain(("model",))
    if name in ("A_log", "D", "dt_bias", "scale", "bias"):
        return out()
    if name == "step":
        return P()
    return P(*([None] * leaf.ndim))


def _path_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
    return "/".join(parts)


def params_specs(params: Pytree, mesh: Mesh, policy: ShardingPolicy) -> Pytree:
    def spec_of(path, leaf):
        s = _path_str(path)
        stacked = "/scan/" in f"/{s}/"
        # inside the scan group, leaves carry a leading repeat dimension
        return param_spec(s, leaf, mesh, policy, stacked=stacked)
    return jax.tree_util.tree_map_with_path(spec_of, params)


def state_specs(state: Pytree, mesh: Mesh, policy: ShardingPolicy) -> Pytree:
    """Train state {params, opt{m,v,step}} — opt mirrors params."""
    p_spec = params_specs(state["params"], mesh, policy)
    return {
        "params": p_spec,
        "opt": {
            "m": jax.tree.map(lambda s: s, p_spec),
            "v": jax.tree.map(lambda s: s, p_spec),
            "step": P(),
        },
    }


def batch_specs(cfg: ArchConfig, shape: InputShape, mesh: Mesh) -> dict:
    dp = _dp(mesh)
    bp = P(dp) if shape.global_batch > 1 else P(None)
    if shape.kind in ("train", "prefill"):
        specs: dict = {}
        if cfg.frontend == "audio":
            specs["frames"] = P(dp if shape.global_batch > 1 else None,
                                None, None)
        elif cfg.frontend == "vision":
            specs["tokens"] = P(dp if shape.global_batch > 1 else None, None)
            specs["patch_embeds"] = P(dp if shape.global_batch > 1 else None,
                                      None, None)
        else:
            specs["tokens"] = P(dp if shape.global_batch > 1 else None, None)
        if shape.kind == "train":
            specs["labels"] = P(dp if shape.global_batch > 1 else None, None)
        return specs
    return {"token": bp, "pos": P()}


def _cache_leaf_spec(path: str, leaf, cfg: ArchConfig, shape: InputShape,
                     mesh: Mesh, policy: ShardingPolicy) -> P:
    dp = _dp(mesh)
    name = path.split("/")[-1]
    batched = shape.global_batch > 1
    stacked = leaf.ndim > {"k": 4, "v": 4, "state": 4, "conv": 3, "h": 2}.get(name, 99)
    shard_seq = (not batched) and policy.shard_seq_in_long_decode
    # kv heads shard over "model" only when they divide it evenly; otherwise
    # shard head_dim (no padding, contraction becomes a psum)
    msize = mesh.shape["model"]
    kv_axis_on_heads = cfg.num_kv_heads % msize == 0

    def out(*axes):
        axes = list(axes)
        if stacked:
            axes = [None] + axes
        return P(*axes)

    if name in ("k", "v"):       # (B, L, K, hd)
        if kv_axis_on_heads:
            mid = (None, "model", None)        # (L, K, hd)
        elif policy.decode_seq_over_model and leaf.shape[-3] % msize == 0:
            mid = ("model", None, None)        # shard cache seq over model
        else:
            mid = (None, None, "model")        # shard head_dim
        if batched:
            return out(dp, *mid)
        if shard_seq and mid[0] is None:
            return out(None, dp, *mid[1:])
        return out(None, *mid)
    if name == "state":          # ssd (B, H, P, N)
        return out(dp if batched else None, "model", None, None)
    if name == "conv":           # (B, W-1, C)
        return out(dp if batched else None, None, "model")
    if name == "h":              # rglru (B, W)
        return out(dp if batched else None, "model")
    return P(*([None] * leaf.ndim))


def cache_specs(cache: Pytree, cfg: ArchConfig, shape: InputShape, mesh: Mesh,
                policy: ShardingPolicy) -> Pytree:
    def spec_of(path, leaf):
        return _cache_leaf_spec(_path_str(path), leaf, cfg, shape, mesh, policy)
    return jax.tree_util.tree_map_with_path(spec_of, cache)


def to_named(spec_tree: Pytree, mesh: Mesh) -> Pytree:
    return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree,
                        is_leaf=lambda x: isinstance(x, P))
