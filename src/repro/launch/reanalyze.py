"""Recompute roofline fields of dry-run JSONs from their stored (gzipped)
HLO dumps — lets the HLO analyzer evolve without recompiling 80 combos.

Usage: python -m repro.launch.reanalyze [--dir experiments/dryrun]
"""
import argparse
import glob
import gzip
import json
import os

from repro.launch.hlo_analysis import analyze_hlo


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="experiments/dryrun")
    args = ap.parse_args()
    n = 0
    for jpath in sorted(glob.glob(os.path.join(args.dir, "*.json"))):
        with open(jpath) as f:
            rec = json.load(f)
        hp = rec.get("hlo_path")
        if not hp or not os.path.exists(hp):
            continue
        with gzip.open(hp, "rt") as hf:
            rec.update(analyze_hlo(hf.read()))
        with open(jpath, "w") as f:
            json.dump(rec, f, indent=2)
        n += 1
    print(f"reanalyzed {n} records")


if __name__ == "__main__":
    main()
