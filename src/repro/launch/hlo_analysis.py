"""Roofline inputs derived from the compiled HLO, with correct loop accounting.

XLA's ``compiled.cost_analysis()`` counts each ``while`` body ONCE, which
under-reports any scan-over-layers / gradient-accumulation model by the trip
count (verified empirically: an olmo-1b with 16 vs 8 layers reports the same
FLOPs). This module therefore walks the post-SPMD HLO text itself:

  * per-computation symbol table (every instruction line defines name+shape)
  * dot FLOPs = 2 * prod(result dims) * prod(lhs contracting dims)
  * bytes at fusion boundaries (operands + result of each fusion/instruction;
    internals of a fusion are free, matching XLA's fusion cost model)
  * collective bytes per kind (all-gather / all-reduce / reduce-scatter /
    all-to-all / collective-permute), result-size proxy
  * ``while`` trip counts parsed from the loop condition's compare-constant;
    body costs are multiplied by the trip count (nested loops compose)

All numbers are per-device (the HLO module is the per-device SPMD program).
"""
from __future__ import annotations

import re
from typing import Any

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "f16": 2, "bf16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
    "f64": 8, "c64": 8, "c128": 16, "token": 0, "s2": 1, "u2": 1,
    "f8e4m3fn": 1, "f8e5m2": 1,
}

COLLECTIVE_KINDS = ("all-gather", "all-reduce", "reduce-scatter",
                    "all-to-all", "collective-permute")

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(.+)$")
_CALLS_RE = re.compile(r"(?:calls|to_apply|body)=%?([\w.\-]+)")
_COND_RE = re.compile(r"condition=%?([\w.\-]+)")
_OPNAME_RE = re.compile(r"^\(?\s*(?:\(|)(?:[a-z0-9]+\[[0-9,]*\][^ ]*\s+)+([\w\-]+)\(")


def _parse_shapes(text: str) -> list[tuple[str, tuple[int, ...]]]:
    out = []
    for dtype, dims in _SHAPE_RE.findall(text):
        if dtype not in _DTYPE_BYTES:
            continue
        shape = tuple(int(d) for d in dims.split(",") if d)
        out.append((dtype, shape))
    return out


def _nbytes(dtype: str, shape: tuple[int, ...]) -> int:
    n = _DTYPE_BYTES.get(dtype, 0)
    for d in shape:
        n *= d
    return n


def _prod(xs) -> int:
    n = 1
    for x in xs:
        n *= x
    return n


class _Instr:
    __slots__ = ("name", "result_shapes", "op", "operands", "calls", "cond",
                 "line", "is_root")

    def __init__(self, name, result_shapes, op, operands, calls, cond, line,
                 is_root=False):
        self.name = name
        self.result_shapes = result_shapes
        self.op = op
        self.operands = operands
        self.calls = calls
        self.cond = cond
        self.line = line
        self.is_root = is_root


_OP_RE = re.compile(
    r"^(?:\((?P<tuple>[^)]*)\)|(?P<dtype>[a-z0-9]+)\[(?P<dims>[0-9,]*)\][^\s]*)\s+"
    r"(?P<op>[\w\-]+)\((?P<args>.*)$")
_ARG_RE = re.compile(r"%?([\w.\-]+)")


def _operand_names(arg_str: str) -> list[str]:
    """Operand names from an instruction's argument list.

    XLA prints operands typed — ``dot(f32[64,128]{1,0} %Arg_0.1, ...)`` — so
    split on top-level commas (layouts carry commas inside {}) and take each
    argument's trailing name token.
    """
    parts, depth, cur = [], 0, []
    for ch in arg_str:
        if ch in "({[":
            depth += 1
        elif ch in ")}]":
            depth -= 1
        if ch == "," and depth == 0:
            parts.append("".join(cur))
            cur = []
        else:
            cur.append(ch)
    if cur:
        parts.append("".join(cur))
    names = []
    for p in parts:
        toks = _ARG_RE.findall(p)
        if toks:
            names.append(toks[-1])
    return names


_COMMENT_RE = re.compile(r"/\*.*?\*/")


def _parse_computations(hlo: str) -> dict[str, list[_Instr]]:
    comps: dict[str, list[_Instr]] = {}
    cur: list[_Instr] | None = None
    cur_name = None
    entry = None
    for raw in hlo.splitlines():
        line = _COMMENT_RE.sub("", raw).strip()
        if not line:
            continue
        if line.startswith("ENTRY") or (("{" in line) and ("=" not in line.split("{")[0]) and ("(" in line)):
            # computation header: `%name (args) -> type {` or `ENTRY %name ...`
            m = re.match(r"(?:ENTRY\s+)?%?([\w.\-]+)\s*\(", line)
            if m and line.rstrip().endswith("{"):
                cur_name = m.group(1)
                cur = []
                comps[cur_name] = cur
                if line.startswith("ENTRY"):
                    entry = cur_name
                continue
        if line.startswith("}"):
            cur = None
            continue
        if cur is None:
            continue
        m = _DEF_RE.match(line)
        if not m:
            continue
        name, rhs = m.groups()
        is_root = line.lstrip().startswith("ROOT")
        om = _OP_RE.match(rhs)
        if not om:
            continue
        if om.group("tuple") is not None:
            result_shapes = _parse_shapes(om.group("tuple"))
        else:
            dtype = om.group("dtype")
            if dtype not in _DTYPE_BYTES:
                continue
            dims = tuple(int(d) for d in om.group("dims").split(",") if d)
            result_shapes = [(dtype, dims)]
        op = om.group("op")
        args_part = om.group("args")
        # operand names: tokens before the closing paren of the call
        depth = 1
        arg_str = []
        for ch in args_part:
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    break
            arg_str.append(ch)
        arg_str = "".join(arg_str)
        operands = _operand_names(arg_str)
        rest = args_part[len(arg_str):]
        calls = _CALLS_RE.findall(rest)
        cond = _COND_RE.findall(rest)
        comps[cur_name].append(_Instr(name, result_shapes, op, operands,
                                      calls, cond[0] if cond else None, line,
                                      is_root))
    comps["__entry__"] = comps.get(entry, [])
    if entry:
        comps["__entry_name__"] = entry  # type: ignore[assignment]
    return comps


def _symbols(instrs: list[_Instr]) -> dict[str, list[tuple[str, tuple[int, ...]]]]:
    return {i.name: i.result_shapes for i in instrs}


_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")


def _dot_flops(instr: _Instr, sym) -> int:
    # result elements x 2 x contracted size (from lhs operand shape)
    if not instr.result_shapes:
        return 0
    res_elems = sum(_prod(s) for _, s in instr.result_shapes)
    m = _CONTRACT_RE.search(instr.line)
    lhs_shapes = sym.get(instr.operands[0]) if instr.operands else None
    if not m or not lhs_shapes:
        return 2 * res_elems  # fallback: treat as elementwise-ish
    lhs_shape = lhs_shapes[0][1]
    k = 1
    for idx in (int(i) for i in m.group(1).split(",") if i):
        if idx < len(lhs_shape):
            k *= lhs_shape[idx]
    return 2 * res_elems * k


_TRIP_CONST_RE = re.compile(r"constant\((\d+)\)")
_KNOWN_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')


def _trip_count(while_line: str, cond_instrs: list[_Instr]) -> int:
    """Trip count of a while: prefer XLA's known_trip_count backend_config,
    else parse the condition computation's compare-against-constant."""
    m = _KNOWN_TRIP_RE.search(while_line)
    if m:
        return int(m.group(1))
    consts: dict[str, int] = {}
    for i in cond_instrs:
        cm = _TRIP_CONST_RE.search(i.line)
        if cm and i.op == "constant":
            consts[i.name] = int(cm.group(1))
    for i in cond_instrs:
        if i.op == "compare":
            for o in i.operands:
                if o in consts:
                    return consts[o]
    return max(consts.values(), default=1)


def _fusion_bytes(called: list["_Instr"], res_bytes: int) -> int:
    """HLO-level bytes for one fusion call, slice/DUS-aware.

    XLA's fusion cost model charges operand+result at the fusion boundary,
    but a parameter consumed only by dynamic-slice/gather is read at slice
    granularity, and a dynamic-update-slice ROOT writes (and aliases) only
    the update region. Without this, a scan body that slices one layer out
    of the stacked weights gets charged the full stack every trip.
    """
    import re as _re
    sym_c = {i.name: i.result_shapes for i in called}
    consumers: dict[str, list] = {}
    root = None
    for ci in called:
        for o in ci.operands:
            consumers.setdefault(o, []).append(ci)
        if ci.is_root:
            root = ci
    dus_target = None
    if root is not None and root.op == "dynamic-update-slice":
        upd = root.operands[1] if len(root.operands) > 1 else None
        res_eff = sum(_nbytes(d, s) for d, s in sym_c.get(upd, []))
        dus_target = root.operands[0]
    else:
        res_eff = res_bytes
    opnd = 0
    for ci in called:
        if ci.op != "parameter":
            continue
        if ci.name == dus_target:
            continue                      # in-place aliased target
        cons = consumers.get(ci.name, [])
        if cons and all(c.op in ("dynamic-slice", "gather") for c in cons):
            opnd += sum(sum(_nbytes(d, s) for d, s in c.result_shapes)
                        for c in cons)
        else:
            opnd += sum(_nbytes(d, s) for d, s in ci.result_shapes)
    return res_eff + opnd


def analyze_hlo(hlo: str) -> dict[str, Any]:
    comps = _parse_computations(hlo)
    entry_name = comps.get("__entry_name__")
    memo: dict[str, dict] = {}

    def cost_of(comp_name: str) -> dict:
        if comp_name in memo:
            return memo[comp_name]
        instrs = comps.get(comp_name, [])
        sym = _symbols(instrs)
        acc = {"flops": 0, "bytes": 0,
               "coll": {k: 0 for k in COLLECTIVE_KINDS},
               "coll_counts": {k: 0 for k in COLLECTIVE_KINDS}}
        memo[comp_name] = acc  # pre-insert (cycle guard)
        for ins in instrs:
            res_bytes = sum(_nbytes(d, s) for d, s in ins.result_shapes)
            opnd_bytes = 0
            for o in ins.operands:
                shapes = sym.get(o)
                if shapes:
                    opnd_bytes += sum(_nbytes(d, s) for d, s in shapes)
            if ins.op == "dot":
                acc["flops"] += _dot_flops(ins, sym)
                acc["bytes"] += res_bytes + opnd_bytes
            elif ins.op == "convolution":
                acc["flops"] += 2 * sum(_prod(s) for _, s in ins.result_shapes)
                acc["bytes"] += res_bytes + opnd_bytes
            elif ins.op == "fusion":
                sub = cost_of(ins.calls[0]) if ins.calls else {"flops": 0,
                                                               "coll": {}}
                acc["flops"] += sub["flops"]
                for k, v in sub.get("coll", {}).items():
                    acc["coll"][k] += v
                    acc["coll_counts"][k] += sub["coll_counts"][k]
                acc["bytes"] += _fusion_bytes(
                    comps.get(ins.calls[0], []) if ins.calls else [],
                    res_bytes)
            elif ins.op == "while":
                body = cost_of(ins.calls[0]) if ins.calls else None
                trips = _trip_count(ins.line, comps.get(ins.cond, []))
                if body:
                    acc["flops"] += trips * body["flops"]
                    acc["bytes"] += trips * body["bytes"]
                    for k, v in body["coll"].items():
                        acc["coll"][k] += trips * v
                        acc["coll_counts"][k] += trips * body["coll_counts"][k]
            elif ins.op in ("call", "conditional", "custom-call"):
                for c in ins.calls:
                    sub = cost_of(c)
                    acc["flops"] += sub["flops"]
                    acc["bytes"] += sub["bytes"]
                    for k, v in sub["coll"].items():
                        acc["coll"][k] += v
                        acc["coll_counts"][k] += sub["coll_counts"][k]
            elif ins.op in COLLECTIVE_KINDS:
                acc["coll"][ins.op] += res_bytes
                acc["coll_counts"][ins.op] += 1
                acc["bytes"] += res_bytes + opnd_bytes
            elif ins.op in ("parameter", "constant", "get-tuple-element",
                            "tuple", "bitcast"):
                pass                      # no data movement at HLO level
            elif ins.op in ("dynamic-slice", "slice", "gather"):
                # reads only the sliced region (~= result), writes the result
                acc["bytes"] += 2 * res_bytes
            elif ins.op == "dynamic-update-slice":
                # reads + writes the update region only (operand 1), not the
                # full buffer (XLA cost-model semantics; in-place update)
                upd = ins.operands[1] if len(ins.operands) > 1 else None
                upd_bytes = 0
                if upd and sym.get(upd):
                    upd_bytes = sum(_nbytes(d, s) for d, s in sym[upd])
                acc["bytes"] += 2 * upd_bytes
            else:
                # elementwise / reduce / reshape / scatter ...
                acc["bytes"] += res_bytes + opnd_bytes
        return acc

    entry = cost_of(entry_name) if entry_name else {"flops": 0, "bytes": 0,
                                                    "coll": {}, "coll_counts": {}}
    return {
        "flops_per_device": float(entry["flops"]),
        "bytes_per_device": float(entry["bytes"]),
        "collective_bytes_per_device": float(sum(entry["coll"].values())),
        "collectives": {"per_kind_bytes": entry["coll"],
                        "counts": entry["coll_counts"]},
    }


def summarize_compiled(lowered, compiled) -> dict[str, Any]:
    """All roofline inputs for one dry-run combo (per-device numbers)."""
    cost = compiled.cost_analysis()
    if isinstance(cost, list):
        cost = cost[0]
    mem = compiled.memory_analysis()
    out = analyze_hlo(compiled.as_text())
    out["xla_cost_analysis"] = {
        "flops_loopbody_once": float(cost.get("flops", -1.0)),
        "bytes_loopbody_once": float(cost.get("bytes accessed", -1.0)),
    }
    out["memory"] = {}
    for attr in ("generated_code_size_in_bytes", "argument_size_in_bytes",
                 "output_size_in_bytes", "temp_size_in_bytes",
                 "alias_size_in_bytes"):
        v = getattr(mem, attr, None)
        if v is not None:
            out["memory"][attr] = int(v)
    return out
