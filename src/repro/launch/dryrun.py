import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x input-shape) combination
on the production mesh, print memory/cost analysis, and record the roofline
inputs. No real arrays are ever allocated (ShapeDtypeStruct in, AOT out).

Usage:
  python -m repro.launch.dryrun --arch yi-9b --shape train_4k --mesh pod1
  python -m repro.launch.dryrun --all --mesh pod1 --out experiments/dryrun
"""
import argparse
import functools
import json
import time
import traceback

import jax
import jax.numpy as jnp

from repro.data.pipeline import SHAPES, InputShape, input_specs
from repro.launch import sharding as SH
from repro.launch.hlo_analysis import summarize_compiled
from repro.launch.mesh import make_production_mesh
from repro.models import model as M
from repro.models import steps as ST
from repro.models.config import ArchConfig, get_config, list_archs
from repro.optim import AdamWConfig

# gradient-accumulation factor for train_4k (keeps per-microbatch activation
# memory inside a v5e's HBM; recorded per-arch in EXPERIMENTS.md)
MICROBATCHES = {
    "olmo-1b": 1, "internvl2-1b": 1, "mamba2-2.7b": 2, "hubert-xlarge": 1,
    "yi-9b": 4, "recurrentgemma-9b": 4, "nemotron-4-15b": 4,
    "qwen3-moe-30b-a3b": 4, "moonshot-v1-16b-a3b": 2, "grok-1-314b": 16,
}

LONG_WINDOW = 4096  # sliding-window size for long_500k on quadratic archs


def applicability(cfg: ArchConfig, shape: InputShape) -> str | None:
    """Return a skip reason or None if the combo runs (see DESIGN.md)."""
    if shape.kind == "decode" and cfg.is_encoder:
        return "encoder-only architecture: no decode step"
    return None


def model_options(cfg: ArchConfig, shape: InputShape,
                  ring_cache: bool = False, remat: bool = True,
                  moe_local: bool = False,
                  blockwise_attention: int = 0,
                  gqa_expand_kv: bool = False,
                  moe_expert_constraint: bool = False) -> M.ModelOptions:
    window = 0
    if shape.name == "long_500k" and cfg.attention_is_quadratic:
        window = LONG_WINDOW      # sub-quadratic variant (attn=sliding)
    return M.ModelOptions(use_kernels=False, window_override=window,
                          ring_cache=ring_cache,
                          remat=remat and shape.kind == "train",
                          moe_local_dispatch=moe_local,
                          blockwise_attention=blockwise_attention,
                          gqa_expand_kv=gqa_expand_kv and shape.kind == "train",
                          moe_expert_shard_constraint=moe_expert_constraint)


def build_lowered(cfg: ArchConfig, shape: InputShape, mesh,
                  moe_shard_map: bool = False,
                  policy: SH.ShardingPolicy | None = None,
                  ring_cache: bool = False,
                  microbatches: int | None = None,
                  moe_local: bool = False,
                  blockwise_attention: int = 0,
                  gqa_expand_kv: bool = False,
                  moe_expert_constraint: bool = False,
                  dtype=jnp.bfloat16):
    """Construct the jitted step for this combo and .lower() it (no compile)."""
    policy = policy or SH.ShardingPolicy.for_arch(cfg)
    opts = model_options(cfg, shape, ring_cache=ring_cache,
                         moe_local=moe_local,
                         blockwise_attention=blockwise_attention,
                         gqa_expand_kv=gqa_expand_kv,
                         moe_expert_constraint=moe_expert_constraint)
    if moe_shard_map:
        import dataclasses as _dc
        dp = tuple(a for a in mesh.axis_names if a in ("pod", "data"))
        opts = _dc.replace(opts, moe_shard_map_mesh=mesh, moe_shard_map_dp=dp)
    key = jax.random.PRNGKey(0)

    batch_sds = input_specs(cfg, shape, dtype=dtype)
    batch_spec = SH.batch_specs(cfg, shape, mesh)
    batch_sh = SH.to_named(batch_spec, mesh)

    if shape.kind == "train":
        mb = microbatches if microbatches is not None else MICROBATCHES.get(cfg.name, 1)
        opt_dtype = jnp.bfloat16 if cfg.param_count() > 1e11 else jnp.float32
        dp_axes = tuple(a for a in mesh.axis_names if a in ("pod", "data"))
        topts = ST.TrainOptions(microbatches=mb,
                                opt=AdamWConfig(state_dtype=opt_dtype),
                                batch_axes=dp_axes if mb > 1 else ())
        state_sds = jax.eval_shape(
            lambda: ST.init_train_state(cfg, key, dtype, topts))
        state_spec = SH.state_specs(state_sds, mesh, policy)
        state_sh = SH.to_named(state_spec, mesh)
        f = functools.partial(ST.train_step, cfg=cfg, opts=opts, topts=topts)
        jitted = jax.jit(f, in_shardings=(state_sh, batch_sh),
                         out_shardings=(state_sh, None))
        return jitted.lower(state_sds, batch_sds), {"microbatches": mb}

    params_sds = jax.eval_shape(lambda: M.init_params(cfg, key, dtype))
    params_spec = SH.params_specs(params_sds, mesh, policy)
    params_sh = SH.to_named(params_spec, mesh)
    dp = tuple(a for a in mesh.axis_names if a in ("pod", "data"))
    dpn = 1
    for a in dp:
        dpn *= mesh.shape[a]
    batch_ax = dp if (shape.global_batch > 1 and
                      shape.global_batch % dpn == 0) else None
    vocab_ax = "model" if cfg.vocab_size % mesh.shape["model"] == 0 else None
    logits_sh = SH.to_named(
        jax.sharding.PartitionSpec(batch_ax, vocab_ax), mesh)

    if shape.kind == "prefill":
        f = functools.partial(ST.prefill_step, cfg=cfg, opts=opts,
                              cache_len=shape.seq_len)
        cache_sds = jax.eval_shape(
            lambda: M.init_cache(cfg, shape.global_batch, shape.seq_len,
                                 dtype, opts))
        cache_spec = SH.cache_specs(cache_sds, cfg, shape, mesh, policy)
        cache_sh = SH.to_named(cache_spec, mesh)
        jitted = jax.jit(f, in_shardings=(params_sh, batch_sh),
                         out_shardings=(logits_sh, cache_sh))
        return jitted.lower(params_sds, batch_sds), {}

    # decode
    cache_sds = jax.eval_shape(
        lambda: M.init_cache(cfg, shape.global_batch, shape.seq_len, dtype,
                             opts))
    cache_spec = SH.cache_specs(cache_sds, cfg, shape, mesh, policy)
    cache_sh = SH.to_named(cache_spec, mesh)
    f = functools.partial(ST.decode_step, cfg=cfg, opts=opts)
    jitted = jax.jit(f, in_shardings=(params_sh, cache_sh, batch_sh),
                     out_shardings=(logits_sh, cache_sh))
    return jitted.lower(params_sds, cache_sds, batch_sds), {}


def run_one(arch: str, shape_name: str, mesh_name: str,
            ring_cache: bool = False, microbatches: int | None = None,
            policy: SH.ShardingPolicy | None = None,
            legacy_expert_sharding: bool = False,
            decode_seq_over_model: bool = False,
            moe_local: bool = False,
            blockwise_attention: int = 0,
            gqa_expand_kv: bool = False,
            moe_expert_constraint: bool = False,
            moe_shard_map: bool = False,
            fsdp_off: bool = False,
            hlo_dir: str | None = None,
            tag: str = "") -> dict:
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    multi_pod = mesh_name == "pod2"
    if policy is None:
        base = SH.ShardingPolicy.for_arch(cfg)
        import dataclasses as _dc
        policy = _dc.replace(
            base,
            fsdp=base.fsdp and not fsdp_off,
            expert_fallback_shard=not legacy_expert_sharding,
            decode_seq_over_model=decode_seq_over_model)
    rec: dict = {
        "arch": arch, "shape": shape_name, "mesh": mesh_name,
        "params": cfg.param_count(), "active_params": cfg.active_param_count(),
        "ring_cache": ring_cache,
        "moe_local": moe_local,
        "blockwise_attention": blockwise_attention,
        "policy": {"fsdp": policy.fsdp,
                   "expert_fallback_shard": policy.expert_fallback_shard,
                   "decode_seq_over_model": policy.decode_seq_over_model},
    }
    reason = applicability(cfg, shape)
    if reason:
        rec["skipped"] = reason
        return rec
    if shape.name == "long_500k" and cfg.attention_is_quadratic:
        rec["attn"] = "sliding"
    t0 = time.monotonic()
    mesh = make_production_mesh(multi_pod=multi_pod)
    with mesh:
        lowered, extra = build_lowered(cfg, shape, mesh, policy=policy,
                                       moe_shard_map=moe_shard_map,
                                       ring_cache=ring_cache,
                                       microbatches=microbatches,
                                       moe_local=moe_local,
                                       blockwise_attention=blockwise_attention,
                                       gqa_expand_kv=gqa_expand_kv,
                                       moe_expert_constraint=moe_expert_constraint)
        rec.update(extra)
        rec["lower_s"] = round(time.monotonic() - t0, 1)
        t1 = time.monotonic()
        compiled = lowered.compile()
        rec["compile_s"] = round(time.monotonic() - t1, 1)
        if hlo_dir:
            import gzip
            os.makedirs(hlo_dir, exist_ok=True)
            hp = os.path.join(hlo_dir,
                              f"{tag}{arch}_{shape_name}_{mesh_name}.hlo.gz")
            with gzip.open(hp, "wt") as hf:
                hf.write(compiled.as_text())
            rec["hlo_path"] = hp
        rec.update(summarize_compiled(lowered, compiled))
        print(f"--- {arch} x {shape_name} x {mesh_name} ---")
        print(compiled.memory_analysis())
        ca = compiled.cost_analysis()
        if isinstance(ca, list):
            ca = ca[0]
        print({k: ca[k] for k in ("flops", "bytes accessed") if k in ca})
    return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=list_archs())
    ap.add_argument("--shape", choices=list(SHAPES))
    ap.add_argument("--mesh", choices=["pod1", "pod2"], default="pod1")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--ring-cache", action="store_true")
    ap.add_argument("--microbatches", type=int, default=None)
    ap.add_argument("--legacy-expert-sharding", action="store_true",
                    help="pre-iteration-1 baseline behaviour (experts "
                         "replicate when E %% model_axis != 0)")
    ap.add_argument("--decode-seq-over-model", action="store_true",
                    help="perf iteration 3: shard KV-cache seq over model")
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--skip-existing", action="store_true")
    args = ap.parse_args()

    combos = ([(a, s) for a in list_archs() for s in SHAPES]
              if args.all else [(args.arch, args.shape)])
    os.makedirs(args.out, exist_ok=True)
    n_ok = n_skip = n_fail = 0
    for arch, shape in combos:
        tag = "ring_" if args.ring_cache else ""
        path = os.path.join(args.out, f"{tag}{arch}_{shape}_{args.mesh}.json")
        if args.skip_existing and os.path.exists(path):
            continue
        try:
            rec = run_one(arch, shape, args.mesh, ring_cache=args.ring_cache,
                          microbatches=args.microbatches,
                          legacy_expert_sharding=args.legacy_expert_sharding,
                          decode_seq_over_model=args.decode_seq_over_model,
                          hlo_dir=os.path.join(args.out, "hlo"), tag=tag)
            if "skipped" in rec:
                n_skip += 1
            else:
                n_ok += 1
        except Exception as e:
            rec = {"arch": arch, "shape": shape, "mesh": args.mesh,
                   "error": f"{type(e).__name__}: {e}",
                   "traceback": traceback.format_exc()}
            n_fail += 1
            print(f"FAIL {arch} x {shape} x {args.mesh}: {e}")
        with open(path, "w") as f:
            json.dump(rec, f, indent=2)
    print(f"dry-run done: {n_ok} ok, {n_skip} skipped, {n_fail} failed")
    if n_fail:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
