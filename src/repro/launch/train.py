"""Training launcher: end-to-end driver that trains a (reduced or full)
config on the synthetic pipeline with the production sharding rules.

On CPU (tests/examples) use --reduced with a small mesh; on a real pod the
same script runs with --mesh pod1/pod2.
"""
from __future__ import annotations

import argparse
import functools
import json
import time

import jax
import jax.numpy as jnp

from repro.checkpoint import save_checkpoint
from repro.data.pipeline import InputShape, SHAPES, make_batch
from repro.launch import sharding as SH
from repro.launch.mesh import make_production_mesh, make_smoke_mesh
from repro.models import model as M
from repro.models import steps as ST
from repro.models.config import get_config, list_archs
from repro.optim import AdamWConfig


def train(arch: str, *, reduced: bool = True, steps: int = 20,
          batch: int = 8, seq: int = 256, microbatches: int = 1,
          mesh=None, log_every: int = 5, checkpoint_path: str | None = None,
          dtype=jnp.float32, seed: int = 0) -> dict:
    cfg = get_config(arch, reduced=reduced)
    shape = InputShape("custom_train", seq, batch, "train")
    mesh = mesh or make_smoke_mesh()
    policy = SH.ShardingPolicy.for_arch(cfg)
    opts = M.ModelOptions(remat=True)
    topts = ST.TrainOptions(microbatches=microbatches,
                            opt=AdamWConfig(),
                            schedule_total=max(steps, 2), schedule_warmup=max(steps // 10, 1))

    with mesh:
        state = ST.init_train_state(cfg, jax.random.PRNGKey(seed), dtype, topts)
        state_spec = SH.state_specs(state, mesh, policy)
        state_sh = SH.to_named(state_spec, mesh)
        batch_sh = SH.to_named(SH.batch_specs(cfg, shape, mesh), mesh)
        state = jax.device_put(state, state_sh)
        f = functools.partial(ST.train_step, cfg=cfg, opts=opts, topts=topts)
        step_fn = jax.jit(f, in_shardings=(state_sh, batch_sh),
                          out_shardings=(state_sh, None))

        history = []
        t0 = time.monotonic()
        for i in range(steps):
            b = make_batch(cfg, shape, seed=seed + i, dtype=dtype)
            state, metrics = step_fn(state, b)
            loss = float(metrics["loss"])
            history.append(loss)
            if i % log_every == 0 or i == steps - 1:
                print(f"step {i:5d}  loss {loss:.4f}  "
                      f"grad_norm {float(metrics['grad_norm']):.3f}")
        wall = time.monotonic() - t0

        if checkpoint_path:
            save_checkpoint(checkpoint_path, state,
                            meta={"arch": arch, "steps": steps,
                                  "final_loss": history[-1]})
    return {"arch": arch, "steps": steps, "first_loss": history[0],
            "final_loss": history[-1], "wall_s": round(wall, 1),
            "loss_history": history}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=list_archs(), default="olmo-1b")
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--full", dest="reduced", action="store_false")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--checkpoint", default=None)
    ap.add_argument("--mesh", choices=["smoke", "pod1", "pod2"], default="smoke")
    args = ap.parse_args()
    mesh = (make_smoke_mesh() if args.mesh == "smoke"
            else make_production_mesh(multi_pod=args.mesh == "pod2"))
    rec = train(args.arch, reduced=args.reduced, steps=args.steps,
                batch=args.batch, seq=args.seq,
                microbatches=args.microbatches, mesh=mesh,
                checkpoint_path=args.checkpoint)
    print(json.dumps({k: v for k, v in rec.items() if k != "loss_history"},
                     indent=2))


if __name__ == "__main__":
    main()
