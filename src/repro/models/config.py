"""Architecture configuration: one dataclass drives the whole model zoo.

A model is a stack of blocks; each block is (mixer, ffn) where
mixer ∈ {"attn", "attn_window", "ssd", "rglru"} and ffn ∈ {"mlp", "moe", None}.
``block_pattern`` is cycled to ``num_layers`` (RecurrentGemma's 2:1
recurrent:local-attention pattern, Mamba-2's pure-SSD stack, etc.).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

MIXERS = ("attn", "attn_window", "ssd", "rglru")
FFNS = ("mlp", "moe", None)


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    arch_type: str                       # dense | moe | ssm | hybrid | vlm | audio
    num_layers: int
    d_model: int
    vocab_size: int
    block_pattern: tuple[tuple[str, Optional[str]], ...]

    # attention
    num_heads: int = 0
    num_kv_heads: int = 0
    head_dim: int = 0
    window: int = 0                      # sliding/local attention window
    rope_theta: float = 10_000.0
    causal: bool = True                  # False => encoder (HuBERT)

    # ffn
    d_ff: int = 0
    activation: str = "silu"             # silu | gelu | relu2 (squared ReLU)
    gated: bool = True                   # SwiGLU/GeGLU-style gating

    # norms
    norm: str = "rmsnorm"                # rmsnorm | layernorm | nonparam_ln

    # moe
    num_experts: int = 0
    experts_per_token: int = 0
    moe_d_ff: int = 0
    capacity_factor: float = 1.25

    # ssm (Mamba-2 SSD)
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    ssm_conv: int = 4
    ssm_chunk: int = 128

    # rg-lru (RecurrentGemma)
    rnn_width: int = 0
    rnn_conv: int = 4

    # modality frontend (stubbed: input_specs provides embeddings)
    frontend: str = "none"               # none | vision | audio
    num_patches: int = 256               # vision prefix length

    # training
    tie_embeddings: bool = False

    source: str = ""                     # paper / model-card citation

    def __post_init__(self):
        for mixer, ffn in self.block_pattern:
            assert mixer in MIXERS, mixer
            assert ffn in FFNS, ffn
        if self.num_heads:
            assert self.head_dim > 0
        if any(f == "moe" for _, f in self.block_pattern):
            assert self.num_experts > 0 and self.experts_per_token > 0

    @property
    def layer_kinds(self) -> tuple[tuple[str, Optional[str]], ...]:
        """block kind per layer, pattern cycled to num_layers."""
        p = self.block_pattern
        return tuple(p[i % len(p)] for i in range(self.num_layers))

    @property
    def is_encoder(self) -> bool:
        return not self.causal

    @property
    def has_attention(self) -> bool:
        return any(m.startswith("attn") for m, _ in self.block_pattern)

    @property
    def attention_is_quadratic(self) -> bool:
        """True if any attention mixer has an unbounded (full) window."""
        return any(m == "attn" for m, _ in self.block_pattern)

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    def param_count(self) -> int:
        """Analytic parameter count (embedding + blocks + head)."""
        n = self.vocab_size * self.d_model           # embed
        if not self.tie_embeddings and self.vocab_size:
            n += self.vocab_size * self.d_model      # lm head
        D = self.d_model
        for mixer, ffn in self.layer_kinds:
            if mixer in ("attn", "attn_window"):
                n += D * self.num_heads * self.head_dim          # q
                n += 2 * D * self.num_kv_heads * self.head_dim   # k, v
                n += self.num_heads * self.head_dim * D          # o
            elif mixer == "ssd":
                di, hs = self.d_inner, self.ssm_heads
                n += D * (2 * di + 2 * self.ssm_state + hs)      # in_proj (x,z,B,C,dt)
                n += self.ssm_conv * (di + 2 * self.ssm_state)   # conv
                n += 3 * hs                                      # A, D, dt_bias
                n += di * D                                      # out_proj
            elif mixer == "rglru":
                W = self.rnn_width
                n += D * 2 * W                                   # in (x, gate)
                n += self.rnn_conv * W                           # conv
                n += 2 * W * W                                   # r, i gates
                n += W                                           # lambda
                n += W * D                                       # out
            if ffn == "mlp":
                mult = 3 if self.gated else 2
                n += mult * D * self.d_ff
            elif ffn == "moe":
                mult = 3 if self.gated else 2
                n += self.num_experts * mult * D * self.moe_d_ff
                n += D * self.num_experts                        # router
        # norms (rmsnorm scales)
        if self.norm != "nonparam_ln":
            n += (2 * self.num_layers + 1) * D
        return n

    def active_param_count(self) -> int:
        """Parameters touched per token (MoE: only routed experts)."""
        if self.num_experts == 0:
            return self.param_count()
        n = self.param_count()
        mult = 3 if self.gated else 2
        n_moe_layers = sum(1 for _, f in self.layer_kinds if f == "moe")
        full = n_moe_layers * self.num_experts * mult * self.d_model * self.moe_d_ff
        act = n_moe_layers * self.experts_per_token * mult * self.d_model * self.moe_d_ff
        return n - full + act


_REGISTRY: dict[str, "ArchConfig"] = {}
_REDUCED: dict[str, "ArchConfig"] = {}


def register(config: ArchConfig, reduced: ArchConfig) -> ArchConfig:
    _REGISTRY[config.name] = config
    _REDUCED[config.name] = reduced
    return config


def get_config(name: str, reduced: bool = False) -> ArchConfig:
    _ensure_loaded()
    table = _REDUCED if reduced else _REGISTRY
    if name not in table:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(table)}")
    return table[name]


def list_archs() -> list[str]:
    _ensure_loaded()
    return sorted(_REGISTRY)


def _ensure_loaded() -> None:
    if _REGISTRY:
        return
    import importlib
    for mod in ("mamba2_2_7b", "recurrentgemma_9b", "internvl2_1b",
                "qwen3_moe_30b_a3b", "yi_9b", "nemotron_4_15b",
                "hubert_xlarge", "moonshot_v1_16b_a3b", "olmo_1b",
                "grok_1_314b"):
        importlib.import_module(f"repro.configs.{mod}")
