"""Shared layers: norms, RoPE, GQA attention (full/sliding, causal/bidir,
cached decode), MLPs. Pure functions over parameter dicts (pytrees)."""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

from repro.models.config import ArchConfig

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------

def init_norm(cfg: ArchConfig, key, dtype):
    if cfg.norm == "rmsnorm":
        return {"scale": jnp.ones((cfg.d_model,), dtype)}
    if cfg.norm == "layernorm":
        return {"scale": jnp.ones((cfg.d_model,), dtype),
                "bias": jnp.zeros((cfg.d_model,), dtype)}
    if cfg.norm == "nonparam_ln":
        return {}
    raise ValueError(cfg.norm)


def apply_norm(params, x, cfg: ArchConfig):
    xf = x.astype(jnp.float32)
    if cfg.norm == "rmsnorm":
        var = jnp.mean(xf * xf, axis=-1, keepdims=True)
        out = xf * jax.lax.rsqrt(var + 1e-6)
        return (out * params["scale"].astype(jnp.float32)).astype(x.dtype)
    # layernorm / nonparam_ln
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    out = (xf - mu) * jax.lax.rsqrt(var + 1e-5)
    if cfg.norm == "layernorm":
        out = out * params["scale"].astype(jnp.float32) + params["bias"].astype(jnp.float32)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float) -> jnp.ndarray:
    """x: (..., S, H, hd); positions: (..., S). Rotates pairs (even, odd)."""
    hd = x.shape[-1]
    half = hd // 2
    freqs = jnp.exp(-math.log(theta) * jnp.arange(half, dtype=jnp.float32) / half)
    angles = positions[..., :, None].astype(jnp.float32) * freqs  # (..., S, half)
    cos = jnp.cos(angles)[..., None, :]
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    xr1 = x1 * cos - x2 * sin
    xr2 = x2 * cos + x1 * sin
    return jnp.concatenate([xr1, xr2], axis=-1).astype(x.dtype)


# ---------------------------------------------------------------------------
# Attention
# ---------------------------------------------------------------------------

def init_attention(cfg: ArchConfig, key, dtype):
    D, H, K, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    k1, k2, k3, k4 = jax.random.split(key, 4)
    s_in = 1.0 / math.sqrt(D)
    s_out = 1.0 / math.sqrt(H * hd) / math.sqrt(2 * cfg.num_layers)
    return {
        "wq": (jax.random.normal(k1, (D, H * hd)) * s_in).astype(dtype),
        "wk": (jax.random.normal(k2, (D, K * hd)) * s_in).astype(dtype),
        "wv": (jax.random.normal(k3, (D, K * hd)) * s_in).astype(dtype),
        "wo": (jax.random.normal(k4, (H * hd, D)) * s_out).astype(dtype),
    }


def _split_heads(x, n, hd):
    return x.reshape(*x.shape[:-1], n, hd)


def attention_blockwise(q, k, v, cfg: ArchConfig, *, window: int = 0,
                        block: int = 512):
    """Online-softmax attention scanning KV blocks (the flash-attention
    algorithm expressed in XLA ops — perf iteration for the memory term).

    Never materializes the (S, T) score matrix: one (S, block) tile lives at
    a time, and the scan body is rematerialized so the backward pass stores
    only the (m, l, acc) carries per block instead of all score tiles.
    """
    B, S, H, hd = q.shape
    T, K = k.shape[1], k.shape[2]
    G = H // K
    block = min(block, T)
    assert T % block == 0, (T, block)
    nb = T // block
    qg = q.reshape(B, S, K, G, hd)
    scale = 1.0 / math.sqrt(hd)
    kb = jnp.moveaxis(k.reshape(B, nb, block, K, hd), 1, 0)
    vb = jnp.moveaxis(v.reshape(B, nb, block, K, hd), 1, 0)
    q_idx = jnp.arange(S)

    def body(carry, inp):
        m, l, acc = carry
        j, kj, vj = inp
        s = jnp.einsum("bskgh,btkh->bkgst", qg, kj).astype(jnp.float32) * scale
        k_idx = j * block + jnp.arange(block)
        mask = jnp.ones((S, block), bool)
        if cfg.causal:
            mask &= k_idx[None, :] <= q_idx[:, None]
        if window > 0:
            mask &= k_idx[None, :] > q_idx[:, None] - window
        s = jnp.where(mask[None, None, None], s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, -1, keepdims=True))
        p = jnp.exp(s - m_new)
        alpha = jnp.exp(m - m_new)
        l_new = l * alpha + jnp.sum(p, -1, keepdims=True)
        acc_new = acc * alpha[..., 0][..., None] + jnp.einsum(
            "bkgst,btkh->bkgsh", p.astype(vj.dtype), vj).astype(jnp.float32)
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((B, K, G, S, 1), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, K, G, S, 1), jnp.float32)
    a0 = jnp.zeros((B, K, G, S, hd), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(
        jax.checkpoint(body), (m0, l0, a0),
        (jnp.arange(nb), kb, vb))
    l = jnp.where(l == 0.0, 1.0, l)
    out = (acc / l).astype(q.dtype)                      # (B,K,G,S,hd)
    return jnp.moveaxis(out.reshape(B, K * G, S, hd), 1, 2).reshape(B, S, H, hd)


def attention_full(params, x, cfg: ArchConfig, *, window: int = 0,
                   positions: Optional[jnp.ndarray] = None,
                   use_flash: bool = False, blockwise: int = 0,
                   expand_kv: bool = False):
    """Full-sequence attention (train / prefill). Returns (out, (k, v)).

    ``expand_kv`` repeats K/V onto every query head before the score einsum
    (mathematically identical for GQA). Rationale: when kv_heads does not
    divide the model axis (grok: 8 vs 16), GSPMD cannot shard the
    (B,K,G,S,T) score tensor on its head group dim and replicates it;
    expanding to H query heads (48 % 16 == 0) restores sharding at the cost
    of G x larger (but tiny) K/V activations.
    """
    B, S, D = x.shape
    H, K, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    if positions is None:
        positions = jnp.arange(S)[None, :]
    q = _split_heads(x @ params["wq"], H, hd)
    k = _split_heads(x @ params["wk"], K, hd)
    v = _split_heads(x @ params["wv"], K, hd)
    if cfg.causal:  # encoders (HuBERT) use absolute embeddings upstream; rope for decoders
        q = rope(q, positions, cfg.rope_theta)
        k = rope(k, positions, cfg.rope_theta)
    if expand_kv and K < H:
        cfg = __import__("dataclasses").replace(cfg, num_kv_heads=H)
        G = H // K
        k = jnp.repeat(k, G, axis=2)
        v = jnp.repeat(v, G, axis=2)
        K = H

    if use_flash:
        from repro.kernels import ops as kops
        out = kops.flash_attention(q, k, v, causal=cfg.causal, window=window)
    elif blockwise > 0:
        out = attention_blockwise(q, k, v, cfg, window=window, block=blockwise)
    else:
        G = H // K
        qg = q.reshape(B, S, K, G, hd)
        scores = jnp.einsum("bskgh,btkh->bkgst", qg, k) / math.sqrt(hd)
        srange = jnp.arange(S)
        mask = jnp.ones((S, S), dtype=bool)
        if cfg.causal:
            mask &= srange[None, :] <= srange[:, None]
        if window > 0:
            mask &= srange[None, :] > srange[:, None] - window
        scores = jnp.where(mask[None, None, None, :, :], scores, NEG_INF)
        w = jax.nn.softmax(scores.astype(jnp.float32), axis=-1).astype(x.dtype)
        out = jnp.einsum("bkgst,btkh->bskgh", w, v).reshape(B, S, H * hd)
    return out.reshape(B, S, H * hd) @ params["wo"], (k, v)


def attention_decode(params, x, cache_k, cache_v, pos, cfg: ArchConfig, *,
                     window: int = 0):
    """One-token decode. x: (B, 1, D); cache_[kv]: (B, S_max, K, hd);
    pos: scalar int32 — current write position, or (B,) int32 for per-row
    positions (continuous batching: each slot decodes at its own depth).
    Returns (out, new_k, new_v)."""
    B, _, D = x.shape
    H, K, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    S_max = cache_k.shape[1]
    q = _split_heads(x @ params["wq"], H, hd)
    k = _split_heads(x @ params["wk"], K, hd)
    v = _split_heads(x @ params["wv"], K, hd)
    per_row = jnp.ndim(pos) == 1
    posb = pos[:, None] if per_row else jnp.full((B, 1), pos)
    q = rope(q, posb, cfg.rope_theta)
    k = rope(k, posb, cfg.rope_theta)
    if per_row:
        rows = jnp.arange(B)
        cache_k = cache_k.at[rows, pos].set(k[:, 0].astype(cache_k.dtype))
        cache_v = cache_v.at[rows, pos].set(v[:, 0].astype(cache_v.dtype))
    else:
        cache_k = jax.lax.dynamic_update_slice(
            cache_k, k.astype(cache_k.dtype), (0, pos, 0, 0))
        cache_v = jax.lax.dynamic_update_slice(
            cache_v, v.astype(cache_v.dtype), (0, pos, 0, 0))
    G = H // K
    qg = q.reshape(B, 1, K, G, hd)
    scores = jnp.einsum("bskgh,btkh->bkgst", qg, cache_k) / math.sqrt(hd)
    trange = jnp.arange(S_max)
    mask = trange[None, :] <= posb                        # (B, S_max)
    if window > 0:
        mask &= trange[None, :] > posb - window
    scores = jnp.where(mask[:, None, None, None, :], scores, NEG_INF)
    w = jax.nn.softmax(scores.astype(jnp.float32), axis=-1).astype(x.dtype)
    out = jnp.einsum("bkgst,btkh->bskgh", w, cache_v).reshape(B, 1, H * hd)
    return out @ params["wo"], cache_k, cache_v


def attention_decode_ring(params, x, cache_k, cache_v, pos, cfg: ArchConfig):
    """One-token decode against a ring (window-sized) KV cache of length L.

    Slot = position % L. Because the ring holds exactly the last L positions,
    the only masking needed is "slot already written" (arange(L) <= pos, which
    is all-true once pos >= L). Keys are RoPE'd at their absolute position at
    write time, so relative phases are correct. ``pos`` may be scalar or (B,)
    for per-row decode depths (continuous batching).
    """
    B, _, D = x.shape
    H, K, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    L = cache_k.shape[1]
    q = _split_heads(x @ params["wq"], H, hd)
    k = _split_heads(x @ params["wk"], K, hd)
    v = _split_heads(x @ params["wv"], K, hd)
    per_row = jnp.ndim(pos) == 1
    posb = pos[:, None] if per_row else jnp.full((B, 1), pos)
    q = rope(q, posb, cfg.rope_theta)
    k = rope(k, posb, cfg.rope_theta)
    slot = jax.lax.rem(pos, L)
    if per_row:
        rows = jnp.arange(B)
        cache_k = cache_k.at[rows, slot].set(k[:, 0].astype(cache_k.dtype))
        cache_v = cache_v.at[rows, slot].set(v[:, 0].astype(cache_v.dtype))
    else:
        cache_k = jax.lax.dynamic_update_slice(
            cache_k, k.astype(cache_k.dtype), (0, slot, 0, 0))
        cache_v = jax.lax.dynamic_update_slice(
            cache_v, v.astype(cache_v.dtype), (0, slot, 0, 0))
    G = H // K
    qg = q.reshape(B, 1, K, G, hd)
    scores = jnp.einsum("bskgh,btkh->bkgst", qg, cache_k) / math.sqrt(hd)
    mask = jnp.arange(L)[None, :] <= posb                 # (B, L)
    scores = jnp.where(mask[:, None, None, None, :], scores, NEG_INF)
    w = jax.nn.softmax(scores.astype(jnp.float32), axis=-1).astype(x.dtype)
    out = jnp.einsum("bkgst,btkh->bskgh", w, cache_v).reshape(B, 1, H * hd)
    return out @ params["wo"], cache_k, cache_v


# ---------------------------------------------------------------------------
# MLP
# ---------------------------------------------------------------------------

def init_mlp(cfg: ArchConfig, key, dtype, d_ff: Optional[int] = None):
    D = cfg.d_model
    F = d_ff if d_ff is not None else cfg.d_ff
    k1, k2, k3 = jax.random.split(key, 3)
    s_in = 1.0 / math.sqrt(D)
    s_out = 1.0 / math.sqrt(F) / math.sqrt(2 * cfg.num_layers)
    p = {"w1": (jax.random.normal(k1, (D, F)) * s_in).astype(dtype),
         "w2": (jax.random.normal(k2, (F, D)) * s_out).astype(dtype)}
    if cfg.gated:
        p["w3"] = (jax.random.normal(k3, (D, F)) * s_in).astype(dtype)
    return p


def _act(x, kind: str):
    if kind == "silu":
        return jax.nn.silu(x)
    if kind == "gelu":
        return jax.nn.gelu(x)
    if kind == "relu2":
        r = jax.nn.relu(x)
        return r * r
    raise ValueError(kind)


def apply_mlp(params, x, cfg: ArchConfig):
    h = _act(x @ params["w1"], cfg.activation)
    if cfg.gated:
        h = h * (x @ params["w3"])
    return h @ params["w2"]


# ---------------------------------------------------------------------------
# Embedding / head
# ---------------------------------------------------------------------------

def init_embed(cfg: ArchConfig, key, dtype):
    k1, k2 = jax.random.split(key)
    p = {"embedding": (jax.random.normal(k1, (cfg.vocab_size, cfg.d_model))
                       * 0.02).astype(dtype)}
    if not cfg.tie_embeddings:
        p["lm_head"] = (jax.random.normal(k2, (cfg.d_model, cfg.vocab_size))
                        / math.sqrt(cfg.d_model)).astype(dtype)
    return p


def embed_tokens(params, tokens, cfg: ArchConfig):
    return params["embedding"][tokens]


def unembed(params, x, cfg: ArchConfig):
    if cfg.tie_embeddings:
        return x @ params["embedding"].T
    return x @ params["lm_head"]
