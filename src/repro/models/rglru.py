"""RG-LRU recurrent block (RecurrentGemma / Griffin, arXiv:2402.19427).

Real-gated linear recurrent unit:
    r_t = sigmoid(W_r x_t);  i_t = sigmoid(W_i x_t)
    a_t = exp(-c * softplus(Lambda) * r_t)          (c = 8)
    h_t = a_t h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t)
Full-sequence form runs as an associative scan (log-depth on TPU);
decode is the single-step recurrence. The block wraps the RG-LRU with the
Griffin recurrent-block structure: linear in, causal conv, RG-LRU, GeLU-gated
output projection.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.models.config import ArchConfig

RG_C = 8.0


def init_rglru(cfg: ArchConfig, key, dtype):
    D, W = cfg.d_model, cfg.rnn_width
    k1, k2, k3, k4, k5, k6, k7 = jax.random.split(key, 7)
    s_in = 1.0 / math.sqrt(D)
    s_w = 1.0 / math.sqrt(W)
    # Lambda init so that a in [0.9, 0.999] at r=1 (Griffin appendix)
    u = jax.random.uniform(k6, (W,), minval=0.9 ** 2, maxval=0.999 ** 2)
    lam = jnp.log(jnp.expm1(-jnp.log(u) / (2 * RG_C)))
    return {
        "wx": (jax.random.normal(k1, (D, W)) * s_in).astype(dtype),
        "wgate": (jax.random.normal(k2, (D, W)) * s_in).astype(dtype),
        "conv_w": (jax.random.normal(k3, (cfg.rnn_conv, W)) *
                   (1.0 / math.sqrt(cfg.rnn_conv))).astype(dtype),
        "conv_b": jnp.zeros((W,), dtype),
        "wr": (jax.random.normal(k4, (W, W)) * s_w).astype(dtype),
        "wi": (jax.random.normal(k5, (W, W)) * s_w).astype(dtype),
        "lam": lam.astype(jnp.float32),
        "wo": (jax.random.normal(k7, (W, D)) * s_w /
               math.sqrt(2 * cfg.num_layers)).astype(dtype),
    }


def _causal_conv(x, w, b):
    W = w.shape[0]
    out = jnp.zeros_like(x)
    for i in range(W):
        shift = W - 1 - i
        if shift == 0:
            out = out + x * w[i]
        else:
            out = out + jnp.pad(x, ((0, 0), (shift, 0), (0, 0)))[:, :-shift] * w[i]
    return out + b


def _gates(params, xc):
    r = jax.nn.sigmoid((xc @ params["wr"]).astype(jnp.float32))
    i = jax.nn.sigmoid((xc @ params["wi"]).astype(jnp.float32))
    log_a = -RG_C * jax.nn.softplus(params["lam"]) * r          # (B,S,W) fp32
    a = jnp.exp(log_a)
    gated_in = jnp.sqrt(jnp.clip(1.0 - a * a, 1e-12)) * (i * xc.astype(jnp.float32))
    return a, gated_in


def rglru_scan_ref(a, b):
    """h_t = a_t h_{t-1} + b_t via associative scan over axis 1 (seq)."""
    def combine(c1, c2):
        a1, b1 = c1
        a2, b2 = c2
        return a1 * a2, b1 * a2 + b2
    a_out, b_out = jax.lax.associative_scan(combine, (a, b), axis=1)
    return b_out


def rglru_forward(params, x, cfg: ArchConfig, use_kernel: bool = False):
    """Full-sequence recurrent block. x: (B,S,D) -> (B,S,D)."""
    gate = jax.nn.gelu(x @ params["wgate"])
    xw = x @ params["wx"]
    xc = _causal_conv(xw, params["conv_w"], params["conv_b"])
    a, gated_in = _gates(params, xc)
    if use_kernel:
        from repro.kernels import ops as kops
        h = kops.rglru_scan(a, gated_in)
    else:
        h = rglru_scan_ref(a, gated_in)
    y = h.astype(x.dtype) * gate
    return y @ params["wo"]


def rglru_init_cache(cfg: ArchConfig, batch: int, dtype):
    W = cfg.rnn_width
    return {
        "h": jnp.zeros((batch, W), jnp.float32),
        "conv": jnp.zeros((batch, cfg.rnn_conv - 1, W), dtype),
    }


def rglru_step(params, x, cache, cfg: ArchConfig):
    """One-token decode. x: (B,1,D)."""
    B = x.shape[0]
    gate = jax.nn.gelu(x[:, 0] @ params["wgate"])
    xw = x[:, 0] @ params["wx"]
    hist = jnp.concatenate([cache["conv"], xw[:, None, :]], axis=1)
    xc = jnp.einsum("bwc,wc->bc", hist, params["conv_w"]) + params["conv_b"]
    a, gated_in = _gates(params, xc[:, None, :])
    a, gated_in = a[:, 0], gated_in[:, 0]
    h = a * cache["h"] + gated_in
    y = h.astype(x.dtype) * gate
    return (y @ params["wo"])[:, None, :], {"h": h, "conv": hist[:, 1:]}
