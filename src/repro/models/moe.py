"""Token-choice top-k Mixture-of-Experts with capacity-based scatter dispatch.

Baseline dispatch is the GShard/MaxText-style capacity pattern expressed with
scatter/gather (token -> expert slot), which XLA turns into the expected
all-to-all when experts are sharded over the "model" mesh axis. The router
aux (load-balance) loss follows Switch/GShard: E * sum_e f_e * P_e.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.models.config import ArchConfig


def init_moe(cfg: ArchConfig, key, dtype):
    D, E, F = cfg.d_model, cfg.num_experts, cfg.moe_d_ff
    k1, k2, k3, k4 = jax.random.split(key, 4)
    s_in = 1.0 / math.sqrt(D)
    s_out = 1.0 / math.sqrt(F) / math.sqrt(2 * cfg.num_layers)
    p = {
        "router": (jax.random.normal(k1, (D, E)) * s_in).astype(dtype),
        "w1": (jax.random.normal(k2, (E, D, F)) * s_in).astype(dtype),
        "w2": (jax.random.normal(k3, (E, F, D)) * s_out).astype(dtype),
    }
    if cfg.gated:
        p["w3"] = (jax.random.normal(k4, (E, D, F)) * s_in).astype(dtype)
    return p


def _act(x, kind):
    if kind == "silu":
        return jax.nn.silu(x)
    if kind == "gelu":
        return jax.nn.gelu(x)
    r = jax.nn.relu(x)
    return r * r


def apply_moe_local(params, x, cfg: ArchConfig):
    """Per-sequence dispatch (perf iteration 2).

    The global dispatch below computes slot positions with a cumsum over the
    flattened (T*K, E) one-hot across ALL tokens; with tokens sharded over
    the data axis GSPMD implements that sequential dependency by gathering
    routing state globally (measured: the dominant collective in MoE
    prefill). Here positions are computed per sequence — every op keeps the
    batch dim, so routing stays local to the data shard and the only
    cross-shard traffic is the unavoidable token<->expert all-to-all at the
    expert matmul. Capacity becomes per-sequence: C = ceil(S*K/E * cf).
    """
    B, S, D = x.shape
    E, K = cfg.num_experts, cfg.experts_per_token
    logits = (x @ params["router"]).astype(jnp.float32)          # (B,S,E)
    probs = jax.nn.softmax(logits, axis=-1)
    top_w, top_ids = jax.lax.top_k(probs, K)                     # (B,S,K)
    top_w = top_w / jnp.sum(top_w, axis=-1, keepdims=True)

    onehot = jax.nn.one_hot(top_ids, E, dtype=jnp.float32)       # (B,S,K,E)
    f_e = jnp.mean(jnp.sum(onehot, axis=2), axis=(0, 1))
    p_e = jnp.mean(probs, axis=(0, 1))
    aux = E * jnp.sum(f_e * p_e) / K

    C = int(math.ceil(S * K / E * cfg.capacity_factor))
    C = max(4, -(-C // 4) * 4)

    ohf = onehot.reshape(B, S * K, E)
    pos_all = jnp.cumsum(ohf, axis=1) - ohf
    pos = jnp.sum(pos_all * ohf, axis=-1).astype(jnp.int32)      # (B, S*K)
    ids_f = top_ids.reshape(B, S * K)
    w_f = top_w.reshape(B, S * K)
    within = pos < C
    dest = jnp.where(within, ids_f * C + pos, E * C)             # (B, S*K)

    token_of = jnp.repeat(jnp.arange(S), K)                      # (S*K,)
    slots = E * C + 1
    flat_dest = (dest + jnp.arange(B)[:, None] * slots).reshape(-1)
    token_idx = (token_of[None, :] + jnp.arange(B)[:, None] * S).reshape(-1)
    xf = x.reshape(B * S, D)
    buf = jnp.zeros((B * slots, D), x.dtype)
    buf = buf.at[flat_dest].add(xf[token_idx] *
                                within.reshape(-1)[:, None].astype(x.dtype))
    expert_in = buf.reshape(B, slots, D)[:, : E * C].reshape(B, E, C, D)

    h = _act(jnp.einsum("becd,edf->becf", expert_in, params["w1"]),
             cfg.activation)
    if cfg.gated:
        h = h * jnp.einsum("becd,edf->becf", expert_in, params["w3"])
    out_slots = jnp.einsum("becf,efd->becd", h, params["w2"])
    out_slots = out_slots.reshape(B, E * C, D)
    out_slots = jnp.concatenate(
        [out_slots, jnp.zeros((B, 1, D), out_slots.dtype)], axis=1)

    gathered = jnp.take_along_axis(out_slots, dest[..., None], axis=1)
    gathered = gathered * (w_f * within).astype(x.dtype)[..., None]
    out = jnp.zeros((B * S, D), x.dtype).at[token_idx].add(
        gathered.reshape(-1, D))
    return out.reshape(B, S, D), aux.astype(jnp.float32)


def apply_moe(params, x, cfg: ArchConfig, local_dispatch: bool = False,
              expert_shard_constraint: bool = False):
    """x: (B, S, D) -> (out (B, S, D), aux_loss scalar).

    ``expert_shard_constraint`` (perf iteration B4) pins the dispatch buffer
    and expert outputs to P("model") on the expert dim: tokens are
    replicated over the model axis, so each shard materializes only its own
    experts' slots and the combine reduces with one psum of (T, D) instead
    of all-reducing (E*C, D) buffers. Requires E %% model_axis == 0.
    """
    if local_dispatch:
        return apply_moe_local(params, x, cfg)
    B, S, D = x.shape
    E, K = cfg.num_experts, cfg.experts_per_token
    T = B * S
    xf = x.reshape(T, D)

    logits = (xf @ params["router"]).astype(jnp.float32)          # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    top_w, top_ids = jax.lax.top_k(probs, K)                      # (T, K)
    top_w = top_w / jnp.sum(top_w, axis=-1, keepdims=True)        # renormalize

    # load-balance aux loss (computed before capacity drop, as in GShard)
    onehot_full = jax.nn.one_hot(top_ids, E, dtype=jnp.float32)   # (T, K, E)
    f_e = jnp.mean(jnp.sum(onehot_full, axis=1), axis=0)          # fraction per expert
    p_e = jnp.mean(probs, axis=0)
    aux = E * jnp.sum(f_e * p_e) / K

    # capacity
    C = int(math.ceil(T * K / E * cfg.capacity_factor))
    C = max(4, -(-C // 4) * 4)

    # position of each (t, k) routing entry within its expert (row-major t, k)
    oh = onehot_full.reshape(T * K, E)
    pos_in_e = (jnp.cumsum(oh, axis=0) - oh)                      # entries before me
    pos = jnp.sum(pos_in_e * oh, axis=-1).astype(jnp.int32)       # (T*K,)
    ids_flat = top_ids.reshape(T * K)
    w_flat = top_w.reshape(T * K)
    within = pos < C
    dest = jnp.where(within, ids_flat * C + pos, E * C)           # overflow slot

    # dispatch: expert_in[e, c] = x_t for the entry routed there
    token_of_entry = jnp.repeat(jnp.arange(T), K)
    buf = jnp.zeros((E * C + 1, D), dtype=x.dtype)
    buf = buf.at[dest].add(xf[token_of_entry] *
                           within[:, None].astype(x.dtype))
    expert_in = buf[: E * C].reshape(E, C, D)
    if expert_shard_constraint:
        from jax.sharding import PartitionSpec as P
        expert_in = jax.lax.with_sharding_constraint(
            expert_in, P("model", None, None))

    # expert computation (E sharded over the "model" axis -> local matmuls)
    h = _act(jnp.einsum("ecd,edf->ecf", expert_in, params["w1"]), cfg.activation)
    if cfg.gated:
        h = h * jnp.einsum("ecd,edf->ecf", expert_in, params["w3"])
    out_slots = jnp.einsum("ecf,efd->ecd", h, params["w2"])
    if expert_shard_constraint:
        from jax.sharding import PartitionSpec as P
        out_slots = jax.lax.with_sharding_constraint(
            out_slots, P("model", None, None))
    out_slots = out_slots.reshape(E * C, D)
    out_slots = jnp.concatenate([out_slots, jnp.zeros((1, D), out_slots.dtype)])

    # combine: weighted gather back to tokens
    gathered = out_slots[dest] * (w_flat * within).astype(x.dtype)[:, None]
    out = jnp.zeros((T, D), x.dtype).at[token_of_entry].add(gathered)
    return out.reshape(B, S, D), aux.astype(jnp.float32)


def apply_moe_shard_map(params, x, cfg: ArchConfig, mesh,
                        dp_axes: tuple = ("data",)):
    """Expert-parallel MoE with explicit shard_map (perf iteration B5).

    Layout: tokens sharded over the data axes and replicated over "model";
    expert weights sharded over "model" on the expert dim. Each device
    routes its local tokens, dispatches ONLY to the experts it owns, runs
    them locally, and the weighted partial outputs are combined with a
    single psum over "model" — the (E*C, D) buffer all-reduce of the GSPMD
    formulation disappears by construction. Requires E % model_axis == 0.
    """
    from jax.sharding import PartitionSpec as P
    from jax.experimental.shard_map import shard_map

    E, K = cfg.num_experts, cfg.experts_per_token
    msize = mesh.shape["model"]
    assert E % msize == 0, (E, msize)
    E_loc = E // msize

    def body(router, w1, w2, w3, xl):
        # xl: (B_loc, S, D) local tokens; w*: (E_loc, ...) local experts
        m = jax.lax.axis_index("model")
        B, S, D = xl.shape
        T = B * S
        xf = xl.reshape(T, D)
        logits = (xf @ router).astype(jnp.float32)
        probs = jax.nn.softmax(logits, axis=-1)
        top_w, top_ids = jax.lax.top_k(probs, K)
        top_w = top_w / jnp.sum(top_w, axis=-1, keepdims=True)

        onehot = jax.nn.one_hot(top_ids, E, dtype=jnp.float32)
        f_e = jnp.mean(jnp.sum(onehot, axis=1), axis=0)
        p_e = jnp.mean(probs, axis=0)
        aux = E * jnp.sum(f_e * p_e) / K
        aux = jax.lax.pmean(aux, dp_axes[0] if len(dp_axes) == 1 else dp_axes)

        C = int(math.ceil(T * K / E * cfg.capacity_factor))
        C = max(4, -(-C // 4) * 4)
        oh = onehot.reshape(T * K, E)
        pos = jnp.sum((jnp.cumsum(oh, axis=0) - oh) * oh, axis=-1).astype(jnp.int32)
        ids_flat = top_ids.reshape(T * K)
        w_flat = top_w.reshape(T * K)
        within = pos < C

        # my experts: ids in [m*E_loc, (m+1)*E_loc)
        local_id = ids_flat - m * E_loc
        mine = (local_id >= 0) & (local_id < E_loc) & within
        dest = jnp.where(mine, local_id * C + pos, E_loc * C)
        token_of = jnp.repeat(jnp.arange(T), K)
        buf = jnp.zeros((E_loc * C + 1, D), x.dtype)
        buf = buf.at[dest].add(xf[token_of] * mine[:, None].astype(x.dtype))
        expert_in = buf[: E_loc * C].reshape(E_loc, C, D)

        h = _act(jnp.einsum("ecd,edf->ecf", expert_in, w1), cfg.activation)
        if w3 is not None:
            h = h * jnp.einsum("ecd,edf->ecf", expert_in, w3)
        out_slots = jnp.einsum("ecf,efd->ecd", h, w2).reshape(E_loc * C, D)
        out_slots = jnp.concatenate(
            [out_slots, jnp.zeros((1, D), out_slots.dtype)])

        gathered = out_slots[dest] * (w_flat * mine).astype(x.dtype)[:, None]
        partial = jnp.zeros((T, D), x.dtype).at[token_of].add(gathered)
        out = jax.lax.psum(partial, "model")       # the only cross-model traffic
        return out.reshape(B, S, D), aux

    bp = P(dp_axes, None, None)
    w3 = params.get("w3")
    fn = shard_map(
        body, mesh=mesh,
        in_specs=(P(None, None), P("model", None, None),
                  P("model", None, None),
                  P("model", None, None) if w3 is not None else P(None),
                  bp),
        out_specs=(bp, P()),
        check_rep=False)
    return fn(params["router"], params["w1"], params["w2"], w3, x)
