"""Model assembly: blocks -> stack (scan-over-layers) -> LM / encoder.

The layer stack is grouped into ``n_full`` repeats of the config's block
pattern (period p) plus ``rem`` leftover layers. The repeats run under one
``lax.scan`` whose xs are the stacked per-repeat parameters (and, when
decoding, the stacked per-repeat caches, which are threaded back out as ys).
Compile cost is therefore O(period + rem) block bodies regardless of depth.

Modes:
  forward_train  — full-sequence, returns logits over all positions
  prefill        — full-sequence, returns last-position logits + cache
  decode_step    — one token with cache
"""
from __future__ import annotations

import dataclasses
import functools
import math
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.models import layers, moe, rglru, ssm
from repro.models.config import ArchConfig

Pytree = Any


@dataclasses.dataclass(frozen=True)
class ModelOptions:
    """Execution options orthogonal to the architecture."""

    use_kernels: bool = False          # Pallas kernels (TPU) vs jnp reference
    window_override: int = 0           # force sliding window (long_500k on dense)
    ring_cache: bool = False           # window-sized ring KV cache (optimized)
    remat: bool = True                 # rematerialize blocks under scan
    moe_local_dispatch: bool = False   # per-sequence MoE dispatch (perf iter 2)
    blockwise_attention: int = 0       # kv-block size for online-softmax attention (perf; 0 = off)
    gqa_expand_kv: bool = False        # expand KV to all query heads so score
                                       # tensors shard when kv_heads < model axis
    moe_expert_shard_constraint: bool = False  # pin dispatch buffers expert-sharded (perf B4)
    moe_shard_map_mesh: Any = None     # Mesh => explicit expert-parallel shard_map MoE (perf B5)
    moe_shard_map_dp: tuple = ("data",)


def _pattern_layout(cfg: ArchConfig) -> tuple[int, int]:
    p = len(cfg.block_pattern)
    return cfg.num_layers // p, cfg.num_layers % p


def effective_window(cfg: ArchConfig, kind_mixer: str, opts: ModelOptions) -> int:
    if kind_mixer == "attn_window":
        return cfg.window
    if kind_mixer == "attn" and opts.window_override > 0:
        return opts.window_override
    return 0


# ---------------------------------------------------------------------------
# Block init / apply
# ---------------------------------------------------------------------------

def init_block(cfg: ArchConfig, kind, key, dtype):
    mixer, ffn = kind
    keys = jax.random.split(key, 4)
    p = {"norm1": layers.init_norm(cfg, keys[0], dtype)}
    if mixer in ("attn", "attn_window"):
        p["mixer"] = layers.init_attention(cfg, keys[1], dtype)
    elif mixer == "ssd":
        p["mixer"] = ssm.init_ssd(cfg, keys[1], dtype)
    elif mixer == "rglru":
        p["mixer"] = rglru.init_rglru(cfg, keys[1], dtype)
    if ffn is not None:
        p["norm2"] = layers.init_norm(cfg, keys[2], dtype)
        p["ffn"] = (moe.init_moe(cfg, keys[3], dtype) if ffn == "moe"
                    else layers.init_mlp(cfg, keys[3], dtype))
    return p


def init_block_cache(cfg: ArchConfig, kind, batch: int, cache_len: int, dtype,
                     opts: ModelOptions):
    mixer, _ = kind
    if mixer in ("attn", "attn_window"):
        w = effective_window(cfg, mixer, opts)
        L = cache_len
        if w > 0 and (opts.ring_cache or mixer == "attn_window"):
            L = min(cache_len, w)
        K, hd = cfg.num_kv_heads, cfg.head_dim
        return {"k": jnp.zeros((batch, L, K, hd), dtype),
                "v": jnp.zeros((batch, L, K, hd), dtype)}
    if mixer == "ssd":
        return ssm.ssd_init_cache(cfg, batch, dtype)
    if mixer == "rglru":
        return rglru.rglru_init_cache(cfg, batch, dtype)
    return {}


def apply_block_full(params, x, cfg: ArchConfig, kind, opts: ModelOptions,
                     want_cache: bool, cache_len: int = 0):
    """Full-sequence block. Returns (x, aux_loss, cache_or_None)."""
    mixer, ffn = kind
    h = layers.apply_norm(params["norm1"], x, cfg)
    cache = None
    if mixer in ("attn", "attn_window"):
        w = effective_window(cfg, mixer, opts)
        out, (k, v) = layers.attention_full(
            params["mixer"], h, cfg, window=w, use_flash=opts.use_kernels,
            blockwise=opts.blockwise_attention,
            expand_kv=opts.gqa_expand_kv)
        if want_cache:
            S = x.shape[1]
            L = cache_len
            if w > 0 and (opts.ring_cache or mixer == "attn_window"):
                L = min(cache_len, w)
                # keep the last L positions, aligned to ring slots
                k = _ring_from_prefill(k, L, S)
                v = _ring_from_prefill(v, L, S)
                cache = {"k": k, "v": v}
            else:
                pad = L - S
                cache = {"k": jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0))),
                         "v": jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))}
    elif mixer == "ssd":
        out = ssm.ssd_forward(params["mixer"], h, cfg, use_kernel=opts.use_kernels)
        if want_cache:
            cache = _ssd_cache_from_prefill(params["mixer"], h, cfg)
    elif mixer == "rglru":
        out = rglru.rglru_forward(params["mixer"], h, cfg,
                                  use_kernel=opts.use_kernels)
        if want_cache:
            cache = _rglru_cache_from_prefill(params["mixer"], h, cfg)
    else:
        raise ValueError(mixer)
    x = x + out
    aux = jnp.zeros((), jnp.float32)
    if ffn is not None:
        h2 = layers.apply_norm(params["norm2"], x, cfg)
        if ffn == "moe":
            if opts.moe_shard_map_mesh is not None:
                out2, aux = moe.apply_moe_shard_map(
                    params["ffn"], h2, cfg, opts.moe_shard_map_mesh,
                    dp_axes=opts.moe_shard_map_dp)
            else:
                out2, aux = moe.apply_moe(
                    params["ffn"], h2, cfg,
                    local_dispatch=opts.moe_local_dispatch,
                    expert_shard_constraint=opts.moe_expert_shard_constraint)
        else:
            out2 = layers.apply_mlp(params["ffn"], h2, cfg)
        x = x + out2
    return x, aux, cache


def _ring_from_prefill(k, L, S):
    """Arrange the last L of S prefill keys into ring order (slot = pos % L)."""
    if S <= L:
        return jnp.pad(k, ((0, 0), (0, L - S), (0, 0), (0, 0)))
    last = k[:, S - L:]                      # positions S-L .. S-1
    # position p sits in slot p % L; rotate accordingly
    shift = (S - L) % L
    return jnp.roll(last, shift, axis=1)


def _ssd_cache_from_prefill(mixer_params, h, cfg: ArchConfig):
    """Final SSM state after a prefill: rerun projections and take the last
    chunk state (cheap relative to the block itself; avoids threading state
    out of ssd_forward)."""
    B, S, D = h.shape
    di, H, P, N = cfg.d_inner, cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state
    zxbcdt = h @ mixer_params["in_proj"]
    _, xBC, dt = ssm._split_proj(cfg, zxbcdt)
    xBC = jax.nn.silu(ssm._causal_conv(xBC, mixer_params["conv_w"],
                                       mixer_params["conv_b"]))
    xin = xBC[..., :di].reshape(B, S, H, P).astype(jnp.float32)
    Bm = xBC[..., di: di + ssm.N_GROUPS * N].reshape(B, S, ssm.N_GROUPS, N)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + mixer_params["dt_bias"])
    A = -jnp.exp(mixer_params["A_log"])
    dA = dt * A                                                   # (B,S,H)
    cs = jnp.cumsum(dA, axis=1)
    decay_to_end = jnp.exp(cs[:, -1:, :] - cs)                    # (B,S,H)
    Bh = jnp.repeat(Bm, H // ssm.N_GROUPS, axis=2).astype(jnp.float32)
    state = jnp.einsum("bshn,bsh,bsh,bshp->bhpn", Bh, dt, decay_to_end, xin)
    conv_src = (h @ mixer_params["in_proj"])[..., di: di + di + 2 * ssm.N_GROUPS * N]
    conv_state = conv_src[:, S - (cfg.ssm_conv - 1):, :]
    return {"state": state, "conv": conv_state}


def _rglru_cache_from_prefill(mixer_params, h, cfg: ArchConfig):
    xw = h @ mixer_params["wx"]
    xc = rglru._causal_conv(xw, mixer_params["conv_w"], mixer_params["conv_b"])
    a, gated_in = rglru._gates(mixer_params, xc)
    hseq = rglru.rglru_scan_ref(a, gated_in)
    S = h.shape[1]
    return {"h": hseq[:, -1], "conv": xw[:, S - (cfg.rnn_conv - 1):, :]}


def apply_block_decode(params, x, cache, pos, cfg: ArchConfig, kind,
                       opts: ModelOptions):
    """One-token block. Returns (x, new_cache)."""
    mixer, ffn = kind
    h = layers.apply_norm(params["norm1"], x, cfg)
    if mixer in ("attn", "attn_window"):
        w = effective_window(cfg, mixer, opts)
        L = cache["k"].shape[1]
        if w > 0 and L <= w:
            # ring cache: holds exactly the last L positions
            out, ck, cv = layers.attention_decode_ring(
                params["mixer"], h, cache["k"], cache["v"], pos, cfg)
        else:
            # full-length cache (window masking if any)
            out, ck, cv = layers.attention_decode(
                params["mixer"], h, cache["k"], cache["v"], pos, cfg, window=w)
        new_cache = {"k": ck, "v": cv}
    elif mixer == "ssd":
        out, new_cache = ssm.ssd_step(params["mixer"], h, cache, cfg)
    elif mixer == "rglru":
        out, new_cache = rglru.rglru_step(params["mixer"], h, cache, cfg)
    else:
        raise ValueError(mixer)
    x = x + out
    if ffn is not None:
        h2 = layers.apply_norm(params["norm2"], x, cfg)
        if ffn == "moe":
            if opts.moe_shard_map_mesh is not None:
                out2, _ = moe.apply_moe_shard_map(
                    params["ffn"], h2, cfg, opts.moe_shard_map_mesh,
                    dp_axes=opts.moe_shard_map_dp)
            else:
                out2, _ = moe.apply_moe(
                    params["ffn"], h2, cfg,
                    local_dispatch=opts.moe_local_dispatch,
                    expert_shard_constraint=opts.moe_expert_shard_constraint)
        else:
            out2 = layers.apply_mlp(params["ffn"], h2, cfg)
        x = x + out2
    return x, new_cache


# ---------------------------------------------------------------------------
# Full model
# ---------------------------------------------------------------------------

def _stack_trees(trees):
    return jax.tree.map(lambda *xs: jnp.stack(xs), *trees)


def init_params(cfg: ArchConfig, key, dtype=jnp.float32) -> Pytree:
    n_full, rem = _pattern_layout(cfg)
    p = len(cfg.block_pattern)
    kinds = cfg.layer_kinds
    k_embed, k_blocks, k_final = jax.random.split(key, 3)
    params: dict = {"embed": layers.init_embed(cfg, k_embed, dtype),
                    "final_norm": layers.init_norm(cfg, k_final, dtype)}
    bkeys = jax.random.split(k_blocks, cfg.num_layers)
    scan_params = []
    for j in range(p):
        per_repeat = [init_block(cfg, kinds[r * p + j], bkeys[r * p + j], dtype)
                      for r in range(n_full)]
        if per_repeat:
            scan_params.append(_stack_trees(per_repeat))
    params["scan"] = tuple(scan_params)
    params["rem"] = tuple(
        init_block(cfg, kinds[n_full * p + i], bkeys[n_full * p + i], dtype)
        for i in range(rem))
    return params


def init_cache(cfg: ArchConfig, batch: int, cache_len: int, dtype,
               opts: ModelOptions) -> Pytree:
    n_full, rem = _pattern_layout(cfg)
    p = len(cfg.block_pattern)
    kinds = cfg.layer_kinds
    scan_caches = []
    for j in range(p):
        per_repeat = [init_block_cache(cfg, kinds[r * p + j], batch, cache_len,
                                       dtype, opts) for r in range(n_full)]
        if per_repeat:
            scan_caches.append(_stack_trees(per_repeat))
    return {
        "scan": tuple(scan_caches),
        "rem": tuple(init_block_cache(cfg, kinds[n_full * p + i], batch,
                                      cache_len, dtype, opts)
                     for i in range(rem)),
    }


def insert_cache_slot(cache: Pytree, one: Pytree, slot) -> Pytree:
    """Write a single-request cache (batch dim of size 1) into row ``slot``
    of a batched cache of the same cache_len/options.

    Scan caches carry a leading repeat dim — (repeat, batch, ...) leaves —
    while rem caches are (batch, ...); the batch axis is 1 resp. 0. ``slot``
    may be a traced int32, so this is jittable (the continuous-batching
    engine admits a prefilled request into a free slot without re-prefilling
    the rest of the pool).
    """
    def at_axis(axis):
        def upd(big, small):
            start = [0] * big.ndim
            start[axis] = slot
            return jax.lax.dynamic_update_slice(
                big, small.astype(big.dtype), tuple(start))
        return upd
    return {"scan": jax.tree.map(at_axis(1), cache["scan"], one["scan"]),
            "rem": jax.tree.map(at_axis(0), cache["rem"], one["rem"])}


def _sin_positions(S: int, D: int, dtype):
    pos = jnp.arange(S)[:, None].astype(jnp.float32)
    div = jnp.exp(-math.log(10_000.0) * jnp.arange(0, D, 2) / D)
    pe = jnp.zeros((S, D), jnp.float32)
    pe = pe.at[:, 0::2].set(jnp.sin(pos * div))
    pe = pe.at[:, 1::2].set(jnp.cos(pos * div[: (D + 1) // 2]))
    return pe.astype(dtype)


def embed_inputs(params, batch: dict, cfg: ArchConfig) -> jnp.ndarray:
    """Frontend handling: tokens / vision prefix / audio frames -> (B,S,D)."""
    if cfg.frontend == "audio":
        x = batch["frames"]
        # encoder: absolute (sinusoidal) positions stand in for the conv
        # positional embedding of the stubbed frontend
        return x + _sin_positions(x.shape[1], x.shape[2], x.dtype)[None]
    if cfg.frontend == "vision":
        tok = layers.embed_tokens(params["embed"], batch["tokens"], cfg)
        return jnp.concatenate([batch["patch_embeds"].astype(tok.dtype), tok],
                               axis=1)
    return layers.embed_tokens(params["embed"], batch["tokens"], cfg)


def apply_stack_full(params, x, cfg: ArchConfig, opts: ModelOptions,
                     want_cache: bool, cache_len: int = 0):
    n_full, rem = _pattern_layout(cfg)
    p = len(cfg.block_pattern)
    kinds = cfg.layer_kinds
    aux0 = jnp.zeros((), jnp.float32)
    cache = {"scan": (), "rem": ()}

    if n_full > 0:
        def body(carry, xs_params):
            h, aux = carry
            caches = []
            for j in range(p):
                h, aux_j, c = apply_block_full(xs_params[j], h, cfg, kinds[j],
                                               opts, want_cache, cache_len)
                aux = aux + aux_j
                caches.append(c if c is not None else {})
            return (h, aux), tuple(caches)

        if opts.remat:
            body = jax.checkpoint(body)
        (x, aux0), scan_caches = jax.lax.scan(body, (x, aux0), params["scan"])
        if want_cache:
            cache["scan"] = scan_caches

    rem_caches = []
    for i in range(rem):
        kind = kinds[n_full * p + i]
        x, aux_i, c = apply_block_full(params["rem"][i], x, cfg, kind, opts,
                                       want_cache, cache_len)
        aux0 = aux0 + aux_i
        rem_caches.append(c if c is not None else {})
    if want_cache:
        cache["rem"] = tuple(rem_caches)
    return x, aux0, (cache if want_cache else None)


def forward_hidden(params, batch: dict, cfg: ArchConfig, opts: ModelOptions):
    """Embed + stack + final norm. Returns (hidden (B,S,D), aux)."""
    x = embed_inputs(params, batch, cfg)
    x, aux, _ = apply_stack_full(params, x, cfg, opts, want_cache=False)
    x = layers.apply_norm(params["final_norm"], x, cfg)
    return x, aux


MOE_AUX_WEIGHT = 0.01


def loss_fn(params, batch: dict, cfg: ArchConfig, opts: ModelOptions):
    """Cross-entropy LM / masked-prediction loss. labels < 0 are ignored."""
    hidden, aux = forward_hidden(params, batch, cfg, opts)
    logits = layers.unembed(params["embed"], hidden, cfg).astype(jnp.float32)
    labels = batch["labels"]
    valid = labels >= 0
    safe = jnp.where(valid, labels, 0)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, safe[..., None], axis=-1)[..., 0]
    nll = (logz - gold) * valid
    n = jnp.maximum(jnp.sum(valid), 1)
    loss = jnp.sum(nll) / n
    total = loss + MOE_AUX_WEIGHT * aux
    return total, {"ce_loss": loss, "aux_loss": aux,
                   "tokens": n.astype(jnp.float32)}


def prefill(params, batch: dict, cfg: ArchConfig, opts: ModelOptions,
            cache_len: int):
    """Full-sequence prefill. Returns (last-position logits (B,V), cache)."""
    x = embed_inputs(params, batch, cfg)
    x, _, cache = apply_stack_full(params, x, cfg, opts, want_cache=True,
                                   cache_len=cache_len)
    x = layers.apply_norm(params["final_norm"], x, cfg)
    last = x[:, -1]
    logits = layers.unembed(params["embed"], last[:, None], cfg)[:, 0]
    return logits.astype(jnp.float32), cache


def decode_step(params, token, pos, cache, cfg: ArchConfig,
                opts: ModelOptions):
    """One decode step. token: (B,) int32; pos: scalar int32.
    Returns (logits (B,V), new cache)."""
    n_full, rem = _pattern_layout(cfg)
    p = len(cfg.block_pattern)
    kinds = cfg.layer_kinds
    x = layers.embed_tokens(params["embed"], token[:, None], cfg)

    new_cache = {"scan": (), "rem": ()}
    if n_full > 0:
        def body(h, xs):
            params_j, cache_j = xs
            new_cs = []
            for j in range(p):
                h, c = apply_block_decode(params_j[j], h, cache_j[j], pos,
                                          cfg, kinds[j], opts)
                new_cs.append(c)
            return h, tuple(new_cs)

        x, scan_caches = jax.lax.scan(body, x, (params["scan"], cache["scan"]))
        new_cache["scan"] = scan_caches

    rem_caches = []
    for i in range(rem):
        kind = kinds[n_full * p + i]
        x, c = apply_block_decode(params["rem"][i], x, cache["rem"][i], pos,
                                  cfg, kind, opts)
        rem_caches.append(c)
    new_cache["rem"] = tuple(rem_caches)

    x = layers.apply_norm(params["final_norm"], x, cfg)
    logits = layers.unembed(params["embed"], x, cfg)[:, 0]
    return logits.astype(jnp.float32), new_cache
