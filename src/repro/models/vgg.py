"""The paper's analysis programs — VGG16 [11] and ZF [12] — in pure jnp.

These are the actual per-frame compute the paper's streams run (object
detection backbones). The examples use them to emulate frame analysis cost;
the resource-model coefficients in core/workload.py describe their measured
cloud footprint. Input size is configurable (default 64x64 for CPU-friendly
examples; 224 reproduces the canonical architectures).
"""
from __future__ import annotations

import math
from typing import Sequence

import jax
import jax.numpy as jnp

# layout entries: (out_channels, kernel, stride) or 'M' = 2x2 maxpool
def _c(ch, k=3, s=1):
    return (ch, k, s)

VGG16_LAYOUT: Sequence = (_c(64), _c(64), "M", _c(128), _c(128), "M",
                          _c(256), _c(256), _c(256), "M",
                          _c(512), _c(512), _c(512), "M",
                          _c(512), _c(512), _c(512), "M")
# ZFNet: 7x7/2 and 5x5/2 early convs shrink the spatial extent fast
ZF_LAYOUT: Sequence = (_c(96, 7, 2), "M", _c(256, 5, 2), "M",
                       _c(384), _c(384), _c(256), "M")


def _conv(x, w, b, stride: int = 1):
    out = jax.lax.conv_general_dilated(
        x, w, window_strides=(stride, stride), padding="SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"))
    return out + b


def _maxpool(x):
    return jax.lax.reduce_window(x, -jnp.inf, jax.lax.max,
                                 (1, 2, 2, 1), (1, 2, 2, 1), "VALID")


def init_convnet(key, layout: Sequence, *, in_channels: int = 3,
                 num_classes: int = 1000, input_hw: int = 64,
                 fc_width: int = 512, dtype=jnp.float32) -> dict:
    params: dict = {"conv": [], "fc": []}
    c_in = in_channels
    hw = input_hw
    keys = iter(jax.random.split(key, len(layout) + 3))
    for item in layout:
        if item == "M":
            hw //= 2
            continue
        ch, ksz, stride = item
        k = next(keys)
        w = jax.random.normal(k, (ksz, ksz, c_in, ch)) / math.sqrt(ksz * ksz * c_in)
        params["conv"].append({"w": w.astype(dtype),
                               "b": jnp.zeros((ch,), dtype),
                               "stride": stride})
        hw = -(-hw // stride)
        c_in = ch
    flat = hw * hw * c_in
    for width in (fc_width, fc_width, num_classes):
        k = next(keys)
        w = jax.random.normal(k, (flat, width)) / math.sqrt(flat)
        params["fc"].append({"w": w.astype(dtype),
                             "b": jnp.zeros((width,), dtype)})
        flat = width
    return params


def apply_convnet(params: dict, x: jnp.ndarray, layout: Sequence) -> jnp.ndarray:
    """x: (B, H, W, C) -> logits (B, num_classes)."""
    ci = 0
    for item in layout:
        if item == "M":
            x = _maxpool(x)
        else:
            p = params["conv"][ci]
            x = jax.nn.relu(_conv(x, p["w"], p["b"], stride=p["stride"]))
            ci += 1
    x = x.reshape(x.shape[0], -1)
    for i, p in enumerate(params["fc"]):
        x = x @ p["w"] + p["b"]
        if i < len(params["fc"]) - 1:
            x = jax.nn.relu(x)
    return x


def init_vgg16(key, **kw):
    return init_convnet(key, VGG16_LAYOUT, **kw)


def apply_vgg16(params, x):
    return apply_convnet(params, x, VGG16_LAYOUT)


def init_zf(key, **kw):
    return init_convnet(key, ZF_LAYOUT, **kw)


def apply_zf(params, x):
    return apply_convnet(params, x, ZF_LAYOUT)


def flops_per_frame(layout: Sequence, input_hw: int, in_channels: int = 3,
                    fc_width: int = 512, num_classes: int = 1000) -> int:
    """Analytic conv+fc FLOPs — VGG16 is ~16x ZF at 224px, matching the
    relative CPU coefficients in core/workload.py."""
    total = 0
    hw, c_in = input_hw, in_channels
    for item in layout:
        if item == "M":
            hw //= 2
            continue
        ch, ksz, stride = item
        hw = -(-hw // stride)
        total += 2 * ksz * ksz * c_in * ch * hw * hw
        c_in = ch
    flat = hw * hw * c_in
    for width in (fc_width, fc_width, num_classes):
        total += 2 * flat * width
        flat = width
    return total
