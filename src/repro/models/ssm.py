"""Mamba-2 SSD block (state-space duality, arXiv:2405.21060).

Full-sequence form uses the chunked SSD algorithm: quadratic attention-like
compute inside chunks of length ``ssm_chunk`` plus a linear inter-chunk state
recurrence — this is the TPU-friendly form (MXU-aligned chunk matmuls).
Decode is the classic SSM state update (constant memory, no KV cache).
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.models.config import ArchConfig

N_GROUPS = 1  # B/C projection groups (Mamba-2 default for these sizes)


def init_ssd(cfg: ArchConfig, key, dtype):
    D = cfg.d_model
    di = cfg.d_inner
    H = cfg.ssm_heads
    N = cfg.ssm_state
    conv_ch = di + 2 * N_GROUPS * N
    k1, k2, k3, k4, k5 = jax.random.split(key, 5)
    s_in = 1.0 / math.sqrt(D)
    proj_out = 2 * di + 2 * N_GROUPS * N + H          # z, x, B, C, dt
    return {
        "in_proj": (jax.random.normal(k1, (D, proj_out)) * s_in).astype(dtype),
        "conv_w": (jax.random.normal(k2, (cfg.ssm_conv, conv_ch)) *
                   (1.0 / math.sqrt(cfg.ssm_conv))).astype(dtype),
        "conv_b": jnp.zeros((conv_ch,), dtype),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, H)).astype(jnp.float32),
        "D": jnp.ones((H,), jnp.float32),
        "dt_bias": (jax.random.uniform(k3, (H,), minval=math.log(1e-3),
                                       maxval=math.log(1e-1))).astype(jnp.float32),
        "norm_scale": jnp.ones((di,), dtype),
        "out_proj": (jax.random.normal(k4, (di, D)) *
                     (1.0 / math.sqrt(di)) / math.sqrt(2 * cfg.num_layers)).astype(dtype),
    }


def _causal_conv(x, w, b):
    """Depthwise causal conv via shifted adds. x: (B,S,C); w: (W,C)."""
    W = w.shape[0]
    out = jnp.zeros_like(x)
    for i in range(W):
        shift = W - 1 - i
        if shift == 0:
            out = out + x * w[i]
        else:
            out = out + jnp.pad(x, ((0, 0), (shift, 0), (0, 0)))[:, :-shift] * w[i]
    return out + b


def _split_proj(cfg: ArchConfig, zxbcdt):
    di, N, H = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
    g = N_GROUPS
    z = zxbcdt[..., :di]
    xBC = zxbcdt[..., di: di + di + 2 * g * N]
    dt = zxbcdt[..., di + di + 2 * g * N:]
    return z, xBC, dt


def ssd_scan_ref(x, dt, A, Bm, Cm, chunk: int):
    """Chunked SSD (pure jnp oracle). x: (b,s,h,p); dt: (b,s,h); A: (h,);
    Bm, Cm: (b,s,g,n). Returns y: (b,s,h,p)."""
    b, s, h, p = x.shape
    g, n = Bm.shape[2], Bm.shape[3]
    assert s % chunk == 0, (s, chunk)
    nc = s // chunk
    L = chunk
    rep = h // g

    xc = x.reshape(b, nc, L, h, p)
    dtc = dt.reshape(b, nc, L, h)
    Bh = jnp.repeat(Bm.reshape(b, nc, L, g, n), rep, axis=3)       # (b,nc,L,h,n)
    Ch = jnp.repeat(Cm.reshape(b, nc, L, g, n), rep, axis=3)

    dA = dtc * A                                                    # (b,nc,L,h)
    cs = jnp.cumsum(dA, axis=2)                                     # inclusive cumsum

    # intra-chunk (attention-like): contribution of position j<=i within chunk
    diff = cs[:, :, :, None, :] - cs[:, :, None, :, :]              # (b,nc,i,j,h)
    tri = jnp.tril(jnp.ones((L, L), bool))
    Lmat = jnp.where(tri[None, None, :, :, None], jnp.exp(diff), 0.0)
    y_diag = jnp.einsum("bclhn,bcshn,bclsh,bcsh,bcshp->bclhp",
                        Ch, Bh, Lmat, dtc, xc)

    # chunk-final states
    decay_to_end = jnp.exp(cs[:, :, -1:, :] - cs)                   # (b,nc,L,h)
    states = jnp.einsum("bcshn,bcsh,bcsh,bcshp->bchpn",
                        Bh, dtc, decay_to_end, xc)

    # inter-chunk recurrence over nc
    chunk_decay = jnp.exp(cs[:, :, -1, :])                          # (b,nc,h)

    def step(carry, inp):
        st_prev = carry
        dec, st = inp
        st_new = st_prev * dec[:, :, None, None] + st
        return st_new, st_prev

    init = jnp.zeros((b, h, p, n), x.dtype)
    _, prev_states = jax.lax.scan(
        step, init,
        (jnp.moveaxis(chunk_decay, 1, 0), jnp.moveaxis(states, 1, 0)))
    prev_states = jnp.moveaxis(prev_states, 0, 1)                   # (b,nc,h,p,n)

    y_off = jnp.einsum("bclhn,bchpn,bclh->bclhp",
                       Ch, prev_states, jnp.exp(cs))
    return (y_diag + y_off).reshape(b, s, h, p)


def ssd_forward(params, x, cfg: ArchConfig, use_kernel: bool = False):
    """Full-sequence Mamba-2 block. x: (B,S,D) -> (B,S,D)."""
    B, S, D = x.shape
    di, H, P, N = cfg.d_inner, cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state
    zxbcdt = x @ params["in_proj"]
    z, xBC, dt = _split_proj(cfg, zxbcdt)
    xBC = jax.nn.silu(_causal_conv(xBC, params["conv_w"], params["conv_b"]))
    xin = xBC[..., :di].reshape(B, S, H, P)
    Bm = xBC[..., di: di + N_GROUPS * N].reshape(B, S, N_GROUPS, N)
    Cm = xBC[..., di + N_GROUPS * N:].reshape(B, S, N_GROUPS, N)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"])
    A = -jnp.exp(params["A_log"])
    # causal right-padding to a chunk multiple (padding never affects the past)
    pad = (-S) % cfg.ssm_chunk
    if pad:
        padf = lambda a: jnp.pad(a, [(0, 0), (0, pad)] +
                                 [(0, 0)] * (a.ndim - 2))
        xin_p, dt_p, Bm_p, Cm_p = map(padf, (xin, dt, Bm, Cm))
    else:
        xin_p, dt_p, Bm_p, Cm_p = xin, dt, Bm, Cm
    if use_kernel:
        from repro.kernels import ops as kops
        y = kops.ssd_scan(xin_p, dt_p, A, Bm_p, Cm_p, cfg.ssm_chunk)
    else:
        y = ssd_scan_ref(xin_p.astype(jnp.float32), dt_p, A,
                         Bm_p.astype(jnp.float32), Cm_p.astype(jnp.float32),
                         cfg.ssm_chunk).astype(x.dtype)
    if pad:
        y = y[:, :S]
    y = y + params["D"].astype(x.dtype)[None, None, :, None] * xin
    y = y.reshape(B, S, di) * jax.nn.silu(z)
    # gated RMSNorm
    yf = y.astype(jnp.float32)
    y = (yf * jax.lax.rsqrt(jnp.mean(yf * yf, -1, keepdims=True) + 1e-6)
         ).astype(x.dtype) * params["norm_scale"]
    return y @ params["out_proj"]


def ssd_init_cache(cfg: ArchConfig, batch: int, dtype):
    di, H, P, N = cfg.d_inner, cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state
    conv_ch = di + 2 * N_GROUPS * N
    return {
        "state": jnp.zeros((batch, H, P, N), jnp.float32),
        "conv": jnp.zeros((batch, cfg.ssm_conv - 1, conv_ch), dtype),
    }


def ssd_step(params, x, cache, cfg: ArchConfig):
    """One-token decode. x: (B,1,D) -> (out (B,1,D), new cache)."""
    B = x.shape[0]
    di, H, P, N = cfg.d_inner, cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state
    zxbcdt = x[:, 0] @ params["in_proj"]
    z, xBC, dt = _split_proj(cfg, zxbcdt)
    # conv over (cached last W-1 inputs, current)
    hist = jnp.concatenate([cache["conv"], xBC[:, None, :]], axis=1)  # (B,W,C)
    conv_out = jnp.einsum("bwc,wc->bc", hist, params["conv_w"]) + params["conv_b"]
    xBC_c = jax.nn.silu(conv_out)
    new_conv = hist[:, 1:]

    xin = xBC_c[..., :di].reshape(B, H, P)
    Bm = xBC_c[..., di: di + N_GROUPS * N].reshape(B, N_GROUPS, N)
    Cm = xBC_c[..., di + N_GROUPS * N:].reshape(B, N_GROUPS, N)
    rep = H // N_GROUPS
    Bh = jnp.repeat(Bm, rep, axis=1).astype(jnp.float32)              # (B,H,N)
    Ch = jnp.repeat(Cm, rep, axis=1).astype(jnp.float32)

    dt = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"])  # (B,H)
    A = -jnp.exp(params["A_log"])
    dA = jnp.exp(dt * A)                                              # (B,H)
    st = cache["state"] * dA[:, :, None, None] + jnp.einsum(
        "bh,bhn,bhp->bhpn", dt, Bh, xin.astype(jnp.float32))
    y = jnp.einsum("bhn,bhpn->bhp", Ch, st).astype(x.dtype)
    y = y + params["D"].astype(x.dtype)[None, :, None] * xin
    y = y.reshape(B, di) * jax.nn.silu(z)
    yf = y.astype(jnp.float32)
    y = (yf * jax.lax.rsqrt(jnp.mean(yf * yf, -1, keepdims=True) + 1e-6)
         ).astype(x.dtype) * params["norm_scale"]
    out = (y @ params["out_proj"])[:, None, :]
    return out, {"state": st, "conv": new_conv}
