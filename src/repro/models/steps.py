"""Step functions: train (with microbatch gradient accumulation), prefill,
decode. These are the functions the launcher jits with shardings and the
dry-run lowers."""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.models import model as M
from repro.models.config import ArchConfig
from repro.optim import AdamWConfig, adamw_init, adamw_update, cosine_schedule

Pytree = Any


@dataclasses.dataclass(frozen=True)
class TrainOptions:
    microbatches: int = 1            # gradient-accumulation steps per batch
    opt: AdamWConfig = AdamWConfig()
    schedule_total: int = 10_000
    schedule_warmup: int = 100
    # mesh axes carrying the batch dim. When set, the microbatch reshape is
    # sharding-constrained so the *per-microbatch* batch dim stays on the
    # data axes (otherwise GSPMD may leave microbatch activations replicated
    # -- measured on grok-1 train: every score tensor carried a full
    # unsharded batch inside the accumulation loop).
    batch_axes: tuple = ()


def init_train_state(cfg: ArchConfig, key, dtype, topts: TrainOptions):
    params = M.init_params(cfg, key, dtype)
    opt_state = adamw_init(params, topts.opt)
    return {"params": params, "opt": opt_state}


def _split_microbatches(batch: dict, n: int, batch_axes=()) -> dict:
    """(B, ...) -> (n, B/n, ...) for every array with a batch dimension.

    With ``batch_axes``, constrain the result so the new per-microbatch
    batch dim (dim 1) carries the data-parallel axes and the microbatch
    dim (dim 0) is replicated (scanned over).
    """
    from jax.sharding import PartitionSpec as P

    def split(x):
        if x.ndim == 0:
            return jnp.broadcast_to(x, (n,))
        B = x.shape[0]
        assert B % n == 0, f"batch {B} not divisible by {n} microbatches"
        out = x.reshape(n, B // n, *x.shape[1:])
        if batch_axes:
            spec = P(None, batch_axes, *([None] * (out.ndim - 2)))
            out = jax.lax.with_sharding_constraint(out, spec)
        return out
    return jax.tree.map(split, batch)


def train_step(state: Pytree, batch: dict, cfg: ArchConfig,
               opts: M.ModelOptions, topts: TrainOptions):
    """One optimizer step; grads averaged over ``topts.microbatches``."""
    params = state["params"]
    grad_fn = jax.value_and_grad(M.loss_fn, has_aux=True)

    if topts.microbatches <= 1:
        (loss, metrics), grads = grad_fn(params, batch, cfg, opts)
    else:
        mb = _split_microbatches(batch, topts.microbatches,
                                 topts.batch_axes)

        def body(carry, mb_i):
            g_acc, l_acc = carry
            (l, _), g = grad_fn(params, mb_i, cfg, opts)
            g_acc = jax.tree.map(jnp.add, g_acc, g)
            return (g_acc, l_acc + l), None

        g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
        (grads, loss_sum), _ = jax.lax.scan(body, (g0, jnp.zeros((), jnp.float32)), mb)
        k = 1.0 / topts.microbatches
        grads = jax.tree.map(lambda g: g * k, grads)
        loss = loss_sum * k
        metrics = {}

    lr_scale = cosine_schedule(state["opt"]["step"],
                               warmup=topts.schedule_warmup,
                               total=topts.schedule_total)
    new_params, new_opt, opt_metrics = adamw_update(
        params, grads, state["opt"], topts.opt, lr_scale)
    out_metrics = {"loss": loss, **opt_metrics}
    for k_, v in (metrics or {}).items():
        out_metrics[k_] = v
    return {"params": new_params, "opt": new_opt}, out_metrics


def prefill_step(params: Pytree, batch: dict, cfg: ArchConfig,
                 opts: M.ModelOptions, cache_len: int):
    return M.prefill(params, batch, cfg, opts, cache_len)


def decode_step(params: Pytree, cache: Pytree, batch: dict, cfg: ArchConfig,
                opts: M.ModelOptions):
    """``batch["pos"]`` may be a scalar (lock-step batch) or a (B,) vector of
    per-slot positions (continuous batching)."""
    logits, new_cache = M.decode_step(params, batch["token"], batch["pos"],
                                      cache, cfg, opts)
    return logits, new_cache


def prefill_into_slot_step(params: Pytree, cache: Pytree, batch: dict, slot,
                           cfg: ArchConfig, opts: M.ModelOptions,
                           cache_len: int):
    """Prefill ONE request (leading batch dim of 1) and insert its KV/state
    into row ``slot`` of an existing batched cache — the admission primitive
    of continuous batching: a new request joins a running pool without
    re-prefilling the other slots. Returns (last-position logits (V,),
    updated batched cache)."""
    logits, one = M.prefill(params, batch, cfg, opts, cache_len)
    return logits[0], M.insert_cache_slot(cache, one, slot)


def make_jitted_train_step(cfg: ArchConfig, opts: M.ModelOptions,
                           topts: TrainOptions, **jit_kwargs):
    f = functools.partial(train_step, cfg=cfg, opts=opts, topts=topts)
    return jax.jit(f, **jit_kwargs)


def make_jitted_prefill(cfg: ArchConfig, opts: M.ModelOptions, cache_len: int,
                        **jit_kwargs):
    f = functools.partial(prefill_step, cfg=cfg, opts=opts, cache_len=cache_len)
    return jax.jit(f, **jit_kwargs)


def make_jitted_decode(cfg: ArchConfig, opts: M.ModelOptions, **jit_kwargs):
    f = functools.partial(decode_step, cfg=cfg, opts=opts)
    return jax.jit(f, **jit_kwargs)


def make_jitted_prefill_into_slot(cfg: ArchConfig, opts: M.ModelOptions,
                                  cache_len: int, **jit_kwargs):
    f = functools.partial(prefill_into_slot_step, cfg=cfg, opts=opts,
                          cache_len=cache_len)
    return jax.jit(f, **jit_kwargs)
