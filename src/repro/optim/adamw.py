"""AdamW with global-norm clipping. Optimizer-state dtype is configurable so
very large models (grok-1-314b) can keep m/v in bf16 on a single pod — the
dtype choice is recorded in the dry-run report."""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

Pytree = Any


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    state_dtype: Any = jnp.float32       # bf16 option for memory-bound archs


def adamw_init(params: Pytree, cfg: AdamWConfig) -> Pytree:
    zeros = lambda p: jnp.zeros_like(p, dtype=cfg.state_dtype)
    return {
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def global_norm(tree: Pytree) -> jnp.ndarray:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in leaves))


def adamw_update(params: Pytree, grads: Pytree, state: Pytree,
                 cfg: AdamWConfig, lr_scale: jnp.ndarray | float = 1.0):
    """Returns (new_params, new_state, metrics)."""
    step = state["step"] + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / (gnorm + 1e-9))
    grads = jax.tree.map(lambda g: g.astype(jnp.float32) * scale, grads)

    b1c = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** step.astype(jnp.float32)
    lr = cfg.lr * lr_scale

    def upd(p, g, m, v):
        m32, v32 = m.astype(jnp.float32), v.astype(jnp.float32)
        m_new = cfg.b1 * m32 + (1 - cfg.b1) * g
        v_new = cfg.b2 * v32 + (1 - cfg.b2) * g * g
        mhat = m_new / b1c
        vhat = v_new / b2c
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps) + cfg.weight_decay * p.astype(jnp.float32)
        p_new = p.astype(jnp.float32) - lr * delta
        return (p_new.astype(p.dtype), m_new.astype(cfg.state_dtype),
                v_new.astype(cfg.state_dtype))

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(state["m"])
    flat_v = jax.tree.leaves(state["v"])
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = jax.tree.unflatten(treedef, [o[0] for o in out])
    new_m = jax.tree.unflatten(treedef, [o[1] for o in out])
    new_v = jax.tree.unflatten(treedef, [o[2] for o in out])
    new_state = {"m": new_m, "v": new_v, "step": step}
    return new_p, new_state, {"grad_norm": gnorm}
