"""Learning-rate schedules (pure functions of the step counter)."""
from __future__ import annotations

import jax.numpy as jnp


def cosine_schedule(step, *, warmup: int = 100, total: int = 10_000,
                    min_ratio: float = 0.1):
    """Linear warmup then cosine decay to min_ratio. Returns a scale in (0,1]."""
    step = step.astype(jnp.float32) if hasattr(step, "astype") else float(step)
    warm = jnp.minimum(1.0, step / max(warmup, 1))
    frac = jnp.clip((step - warmup) / max(total - warmup, 1), 0.0, 1.0)
    cos = 0.5 * (1.0 + jnp.cos(jnp.pi * frac))
    return warm * (min_ratio + (1 - min_ratio) * cos)
