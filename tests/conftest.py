import os

# Tests must see the real single CPU device (the 512-device override is
# strictly dryrun.py's; see the brief).
assert "xla_force_host_platform_device_count" not in os.environ.get("XLA_FLAGS", "")

import sys
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
