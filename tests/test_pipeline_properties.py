"""Property tests for content-aware pipeline demand and crop consolidation.

``hypothesis`` is optional (same pattern as test_repair_properties.py):
when missing, seeded random instances exercise the same invariants. Over
random pipelines, random pipeline fleets, and times of day:

* a camera's stage demands sum exactly to its effective demand, and with
  every activation pinned to 1.0 the effective demand is
  ``source_fps * sum(rate_share)`` at *any* density;
* effective demand is monotone in scene density, and activations stay
  clipped to [0, 1] (negative/overdriven densities included);
* ``consolidated_ffd`` (keep-the-cheaper) never costs more than packing
  the per-camera stage view — on every generated instance;
* no stage item is ever packed onto a bin violating its own per-stage
  requirement, recomputed here from the pipeline spec and scene density
  (not read back from the planner's cache);
* pooled crop chunks conserve the pooled demand up to the milli-fps
  truncation, never exceed the stage's per-worker cap, keep static ids
  all day, and one pool's chunks never share a spot market (they reuse
  the ``#k`` replica anti-affinity grammar).
"""
import numpy as np
import pytest

try:
    import hypothesis.strategies as st
    from hypothesis import given, settings
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

from repro.core import Stream, fig6_catalog, validate
from repro.core import geo
from repro.core.markets import (mixed_plan, replica_group,
                                spot_affinity_violations)
from repro.core.strategies import consolidated_ffd, ffd_greedy
from repro.core.workload import (PIPELINES, PROGRAMS, AnalysisPipeline,
                                 PipelineStage, requirement_for,
                                 scaled_program)
from repro.sim.demand import PipelineCameraSpec, PipelineFleet, rush_hour_fps

CAMERAS = tuple(sorted(geo.CAMERAS))
CATALOG = fig6_catalog()
TYPES = {t.name: t for t in CATALOG.types}


def _random_pipeline(rng) -> AnalysisPipeline:
    n_stages = int(rng.integers(1, 5))
    stages = [PipelineStage("detect", PROGRAMS["ZF"])]   # always-on head
    for j in range(1, n_stages):
        prog = PROGRAMS["VGG16" if rng.random() < 0.5 else "ZF"]
        stages.append(PipelineStage(
            f"stage{j}", prog,
            rate_share=round(float(rng.uniform(0.05, 1.0)), 3),
            pixel_share=float(rng.choice([1.0, 0.5, 0.25, 0.125])),
            activation_floor=round(float(rng.uniform(0.0, 0.3)), 3),
            activation_gain=round(float(rng.uniform(0.0, 1.5)), 3),
            consolidatable=bool(rng.random() < 0.5)))
    return AnalysisPipeline("rand", tuple(stages))


def _random_specs(rng, n: int) -> tuple[PipelineCameraSpec, ...]:
    specs = []
    for i in range(n):
        cam = CAMERAS[int(rng.integers(0, len(CAMERAS)))]
        pipe = "roi_plate" if rng.random() < 0.35 else "roi_vehicle"
        lo, hi = sorted((round(float(rng.uniform(0.0, 1.0)), 3),
                         round(float(rng.uniform(0.0, 1.0)), 3)))
        specs.append(PipelineCameraSpec(
            f"cam-{cam}-{i}", cam, pipe,
            fps=round(float(rng.uniform(0.5, 4.0)), 3),
            base_density=lo, peak_density=hi))
    return tuple(specs)


# -- pipeline demand model ----------------------------------------------------

def _check_stage_demand_sums(seed: int) -> None:
    rng = np.random.default_rng(seed)
    pipe = _random_pipeline(rng)
    fps = round(float(rng.uniform(0.5, 6.0)), 3)
    for density in (0.0, 0.05, 0.3, 1.0):
        rates = pipe.stage_rates(fps, density)
        assert len(rates) == len(pipe.stages)
        assert sum(f for _, f in rates) == \
            pytest.approx(pipe.effective_fps(fps, density))
    # pin every activation at 1.0: effective demand is density-independent
    # and exactly the rate-share-weighted capture rate
    pinned = AnalysisPipeline("pinned", tuple(
        PipelineStage(s.name, s.program, rate_share=s.rate_share,
                      pixel_share=s.pixel_share)
        for s in pipe.stages))
    want = fps * sum(s.rate_share for s in pinned.stages)
    for density in (0.0, 0.4, 1.0):
        assert pinned.effective_fps(fps, density) == pytest.approx(want)


def _check_monotone_in_density(seed: int) -> None:
    rng = np.random.default_rng(seed)
    pipe = _random_pipeline(rng)
    fps = round(float(rng.uniform(0.5, 6.0)), 3)
    densities = sorted(float(rng.uniform(0.0, 1.0)) for _ in range(8))
    effs = [pipe.effective_fps(fps, d) for d in densities]
    assert all(a <= b + 1e-12 for a, b in zip(effs, effs[1:])), \
        f"effective demand not monotone in density: {effs}"
    for s in pipe.stages:                  # clipped even off the [0,1] range
        for d in (-5.0, -0.1, 0.0, 1.0, 3.0):
            assert 0.0 <= s.activation(d) <= 1.0


def test_stage_demands_sum_to_stream_demand_seeded():
    for seed in range(25):
        _check_stage_demand_sums(seed)


def test_effective_demand_monotone_in_density_seeded():
    for seed in range(25):
        _check_monotone_in_density(seed)


def test_stock_pipelines_shape():
    """The reference pipelines keep the structure the scenarios rely on:
    an always-on full-frame detector plus consolidatable crop stages."""
    for name, pipe in PIPELINES.items():
        head = pipe.stages[0]
        assert head.activation(0.0) == 1.0 and head.pixel_share == 1.0
        assert any(s.consolidatable for s in pipe.stages)
        for s in pipe.stages[1:]:
            assert s.activation(0.0) < s.activation(1.0)   # content-driven
            prog = s.resolved_program()
            base = s.program
            # crop scaling shrinks per-fps terms, never the model bases
            assert prog.gpu_mem_base_gib == base.gpu_mem_base_gib
            assert prog.gpu_frac_per_fps == pytest.approx(
                base.gpu_frac_per_fps * s.pixel_share)
        assert pipe.effective_fps(2.0, 0.0) < pipe.effective_fps(2.0, 1.0)


def test_scaled_program_is_cached_per_pixel_share():
    """Requirement classes factorize by id(program): repeated calls must
    return the same object, and pixel_share=1.0 is the base itself."""
    base = PROGRAMS["VGG16"]
    assert scaled_program(base, 1.0) is base
    assert scaled_program(base, 0.25) is scaled_program(base, 0.25)
    assert scaled_program(base, 0.25) is not scaled_program(base, 0.5)
    with pytest.raises(ValueError):
        scaled_program(base, 0.0)


# -- consolidation never loses ------------------------------------------------

def _check_consolidation_never_worse(seed: int, n: int, t_h: float) -> None:
    rng = np.random.default_rng(seed)
    specs = _random_specs(rng, n)
    stages = PipelineFleet(specs, consolidate=False).streams_at(t_h)
    pooled = PipelineFleet(specs, consolidate=True).streams_at(t_h)
    plan = consolidated_ffd(stages, CATALOG, pooled)
    validate(plan.problem, plan.solution)
    base = ffd_greedy(stages, CATALOG)
    assert plan.hourly_cost <= base.hourly_cost + 1e-9, \
        (f"consolidated plan ${plan.hourly_cost:.4f} beats "
         f"${base.hourly_cost:.4f} stage packing")


def test_consolidation_never_worse_seeded():
    for seed in range(12):
        _check_consolidation_never_worse(seed, n=6 + seed % 10,
                                         t_h=float(seed % 24))


# -- per-stage requirements hold on every packed bin --------------------------

def _expected_stage_fps(spec: PipelineCameraSpec, stage: PipelineStage,
                        t_h: float, width_h: float = 1.5) -> float:
    dens = rush_hour_fps(geo.local_hour(t_h, spec.camera),
                         spec.base_density, spec.peak_density,
                         width_h=width_h)
    return round(stage.stage_fps(spec.fps, dens), 3)


def _check_stage_requirements_on_bins(seed: int, t_h: float) -> None:
    rng = np.random.default_rng(seed)
    specs = _random_specs(rng, 10)
    by_sid = {s.stream_id: s for s in specs}
    fleet = PipelineFleet(specs, consolidate=False)
    streams = fleet.streams_at(t_h)
    plan = ffd_greedy(streams, CATALOG)
    validate(plan.problem, plan.solution)
    checked = 0
    for b in plan.solution.bins:
        choice = plan.problem.choices[b.choice]
        itype = TYPES[choice.type_name]
        for i in b.items:
            item = plan.problem.items[i]
            sid, _, stage_name = item.key.rpartition("::")
            spec = by_sid[sid]
            stage = next(s for s in PIPELINES[spec.pipeline].stages
                         if s.name == stage_name)
            # the demand layer emitted the activation-weighted stage rate
            fps = _expected_stage_fps(spec, stage, t_h)
            want = requirement_for(stage.resolved_program(), fps, itype)
            assert want is not None, \
                f"{item.key} packed onto {choice.key} it cannot run on"
            assert item.requirements[b.choice] == tuple(want)
            checked += 1
    assert checked == len(streams)


def test_stage_requirements_hold_on_every_bin_seeded():
    for seed, t_h in enumerate((0.0, 3.5, 8.25, 12.0, 17.75, 23.0)):
        _check_stage_requirements_on_bins(seed, t_h)


# -- pooled chunks: conservation, caps, stability, anti-affinity --------------

def _pool_views(specs, t_h):
    on = PipelineFleet(specs, consolidate=True).streams_at(t_h)
    off = PipelineFleet(specs, consolidate=False).streams_at(t_h)
    chunks = [s for s in on if s.stream_id.startswith("pool::")]
    return on, off, chunks


def _check_pool_invariants(seed: int) -> None:
    rng = np.random.default_rng(seed)
    specs = _random_specs(rng, 12)
    ids0 = None
    for t_h in (0.0, 6.5, 9.0, 13.25, 21.0):
        on, off, chunks = _pool_views(specs, t_h)
        ids = [s.stream_id for s in on]
        if ids0 is None:
            ids0 = ids
        assert ids == ids0, "pooled ids must be static across the day"
        # group chunks by pool prefix; compare against the pooled stage
        # rates of the unconsolidated view
        by_pool: dict[str, list[Stream]] = {}
        for s in chunks:
            by_pool.setdefault(replica_group(s.stream_id), []).append(s)
        pooled_total: dict[str, float] = {}
        for s in off:
            sid, _, stage_name = s.stream_id.rpartition("::")
            spec = by_sid_lookup(specs, sid)
            stage = next(st_ for st_ in PIPELINES[spec.pipeline].stages
                         if st_.name == stage_name)
            if stage.consolidatable:
                key = (f"pool::{spec.pipeline}.{stage_name}"
                       f"@{spec.camera}")
                pooled_total[key] = pooled_total.get(key, 0.0) + s.fps
        assert set(by_pool) == set(pooled_total)
        for key, members in by_pool.items():
            spec0 = next(sp for sp in specs
                         if key.endswith(f"@{sp.camera}")
                         and key.startswith(f"pool::{sp.pipeline}."))
            stage = next(st_ for st_ in PIPELINES[spec0.pipeline].stages
                         if f".{st_.name}@" in key)
            cap = stage.cap_fps()
            m = len(members)
            total = pooled_total[key]
            got = sum(s.fps for s in members)
            # conservation up to the milli-fps floor per chunk
            assert got <= total + 1e-6
            assert got >= total - m * 1e-3 - 1e-6
            for s in members:
                assert s.fps <= cap + 1e-9, \
                    f"chunk {s.stream_id} over the {cap} fps pool cap"
                assert s.program is stage.resolved_program()


def by_sid_lookup(specs, sid):
    for sp in specs:
        if sp.stream_id == sid:
            return sp
    raise KeyError(sid)


def test_pool_invariants_seeded():
    for seed in range(10):
        _check_pool_invariants(seed)


def test_pool_chunks_respect_spot_anti_affinity():
    """Chunks of one pool reuse the ``#k`` replica grammar, so the mixed
    planner must never co-locate two of them on a single spot market."""
    specs = tuple(PipelineCameraSpec(f"cam-nyc-{i}", "nyc", "roi_vehicle",
                                     fps=4.0, base_density=1.0,
                                     peak_density=1.0)
                  for i in range(24))
    pooled = PipelineFleet(specs, consolidate=True).streams_at(9.0)
    chunks = [s for s in pooled if s.stream_id.startswith("pool::")]
    assert len(chunks) >= 2, "need a multi-chunk pool to test anti-affinity"
    assert len({replica_group(s.stream_id) for s in chunks}) == 1
    res = mixed_plan(pooled, CATALOG,
                     multipliers={loc: 0.4 for loc in CATALOG.locations})
    assert spot_affinity_violations(res.plan) == []


if HAVE_HYPOTHESIS:
    @given(st.integers(0, 10_000))
    @settings(max_examples=50, deadline=None)
    def test_stage_demands_sum_to_stream_demand(seed):
        _check_stage_demand_sums(seed)

    @given(st.integers(0, 10_000))
    @settings(max_examples=50, deadline=None)
    def test_effective_demand_monotone_in_density(seed):
        _check_monotone_in_density(seed)

    @given(st.integers(0, 10_000), st.integers(2, 16),
           st.floats(0.0, 24.0, allow_nan=False))
    @settings(max_examples=25, deadline=None)
    def test_consolidation_never_worse(seed, n, t_h):
        _check_consolidation_never_worse(seed, n, t_h)

    @given(st.integers(0, 10_000), st.floats(0.0, 24.0, allow_nan=False))
    @settings(max_examples=20, deadline=None)
    def test_stage_requirements_hold_on_every_bin(seed, t_h):
        _check_stage_requirements_on_bins(seed, t_h)

    @given(st.integers(0, 10_000))
    @settings(max_examples=20, deadline=None)
    def test_pool_invariants(seed):
        _check_pool_invariants(seed)
