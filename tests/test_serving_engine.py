"""Continuous-batching engine tests: greedy-token equivalence with the
static engine, slot reuse within one drain, deadline (EDF) admission, the
prefill-into-slot model step, and stats sanity."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import model as M
from repro.models.config import get_config
from repro.models.steps import make_jitted_prefill, make_jitted_prefill_into_slot
from repro.serving import (ContinuousBatchingEngine, Request, ServingEngine,
                           StreamSimulator)

CACHE_LEN = 48
PROMPT_LEN = 16


def _setup(arch="olmo-1b", seed=0):
    cfg = get_config(arch, reduced=True)
    params = M.init_params(cfg, jax.random.PRNGKey(seed), jnp.float32)
    return cfg, params


def _mixed_requests(cfg, n, seed=0):
    rng = np.random.default_rng(seed)
    return [(rng.integers(0, cfg.vocab_size, PROMPT_LEN).astype(np.int32),
             3 + (i % 4)) for i in range(n)]


# batch-independent mixers only: capacity-limited MoE routing depends on
# batch composition under either engine (see engine.py docstring)
@pytest.mark.parametrize("arch", [
    "olmo-1b", "mamba2-2.7b",
    pytest.param("recurrentgemma-9b", marks=pytest.mark.slow),
])
def test_continuous_matches_static_greedy_tokens(arch):
    cfg, params = _setup(arch)
    reqs = _mixed_requests(cfg, 6)

    static = ServingEngine(cfg, params, max_batch=3, cache_len=CACHE_LEN)
    for i, (t, m) in enumerate(reqs):
        static.submit(Request(f"r{i}", t.copy(), max_new_tokens=m))
    sdone = {r.request_id: r.output for r in static.drain()}

    cont = ContinuousBatchingEngine(cfg, params, max_slots=3,
                                    cache_len=CACHE_LEN)
    for i, (t, m) in enumerate(reqs):
        cont.submit(Request(f"r{i}", t.copy(), max_new_tokens=m))
    cdone = {r.request_id: r.output for r in cont.drain()}

    assert set(sdone) == set(cdone)
    for k in sdone:
        np.testing.assert_array_equal(sdone[k], cdone[k])


def test_finished_slot_reused_within_drain():
    cfg, params = _setup()
    eng = ContinuousBatchingEngine(cfg, params, max_slots=2,
                                   cache_len=CACHE_LEN)
    rng = np.random.default_rng(0)
    toks = lambda: rng.integers(0, cfg.vocab_size, PROMPT_LEN).astype(np.int32)
    eng.submit(Request("short", toks(), max_new_tokens=2))
    eng.submit(Request("long", toks(), max_new_tokens=8))
    eng.submit(Request("queued", toks(), max_new_tokens=4))

    done1 = eng.step()        # admits short+long; short retires (2 tokens)
    assert [r.request_id for r in done1] == ["short"]
    freed = eng._slot_req.index(None)
    eng.step()                # queued admitted into the freed slot mid-decode
    assert eng._slot_req[freed] is not None
    assert eng._slot_req[freed].request_id == "queued"
    assert eng._slot_req[1 - freed].request_id == "long"

    done = done1 + eng.drain()
    assert sorted(r.request_id for r in done) == ["long", "queued", "short"]
    assert eng.stats["prefills"] == 3


def test_deadline_aware_admission_is_edf():
    cfg, params = _setup()
    eng = ContinuousBatchingEngine(cfg, params, max_slots=1,
                                   cache_len=CACHE_LEN)
    rng = np.random.default_rng(1)
    toks = lambda: rng.integers(0, cfg.vocab_size, PROMPT_LEN).astype(np.int32)
    eng.submit(Request("lazy", toks(), max_new_tokens=2, deadline_s=60.0))
    eng.submit(Request("urgent", toks(), max_new_tokens=2, deadline_s=0.01))
    done = eng.drain()
    # urgent was submitted later but has the earlier deadline -> served first
    assert [r.request_id for r in done] == ["urgent", "lazy"]


def test_prefill_into_slot_matches_batched_prefill():
    """Admitting requests one-by-one into a pooled cache produces the same
    logits and cache as prefilling them together as one batch."""
    cfg, params = _setup()
    opts = M.ModelOptions(remat=False)
    rng = np.random.default_rng(2)
    toks = rng.integers(0, cfg.vocab_size, (2, PROMPT_LEN)).astype(np.int32)

    prefill = make_jitted_prefill(cfg, opts, CACHE_LEN)
    logits_b, cache_b = prefill(params, {"tokens": jnp.asarray(toks)})

    slot_prefill = make_jitted_prefill_into_slot(cfg, opts, CACHE_LEN)
    cache = M.init_cache(cfg, 2, CACHE_LEN, jnp.float32, opts)
    logits0, cache = slot_prefill(params, cache,
                                  {"tokens": jnp.asarray(toks[:1])}, 0)
    logits1, cache = slot_prefill(params, cache,
                                  {"tokens": jnp.asarray(toks[1:])}, 1)

    np.testing.assert_allclose(np.asarray(logits_b[0]), np.asarray(logits0),
                               atol=1e-5, rtol=1e-5)
    np.testing.assert_allclose(np.asarray(logits_b[1]), np.asarray(logits1),
                               atol=1e-5, rtol=1e-5)
    for got, want in zip(jax.tree.leaves(cache), jax.tree.leaves(cache_b)):
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   atol=1e-5, rtol=1e-5)


def test_stats_monotonic_and_report_sane():
    cfg, params = _setup()
    eng = ContinuousBatchingEngine(cfg, params, max_slots=2,
                                   cache_len=CACHE_LEN)
    sim = StreamSimulator(eng, prompt_len=PROMPT_LEN, new_tokens=3)
    prev = dict(eng.stats)
    for _ in range(3):
        sim.tick({"fast": 2.0, "slow": 0.5}, dt_s=1.0)
        while eng.queue or eng.active_slots():
            eng.step()
            for k in ("requests", "tokens_generated", "decode_steps",
                      "prefills"):
                assert eng.stats[k] >= prev[k], f"{k} decreased"
            assert eng.stats["wall_s"] >= prev["wall_s"]
            prev = dict(eng.stats)

    rep = eng.report()
    assert rep["requests"] == eng.stats["requests"] > 0
    assert rep["tokens_per_s"] >= 0.0
    assert 0.0 <= rep["slo_attainment"] <= 1.0
    assert 0.0 <= rep["p50_latency_s"] <= rep["p99_latency_s"]
    assert 0.0 < rep["slot_occupancy"] <= 1.0


def test_submit_rejects_oversized_request():
    cfg, params = _setup()
    eng = ContinuousBatchingEngine(cfg, params, max_slots=1, cache_len=16)
    toks = np.zeros(12, np.int32)
    with pytest.raises(ValueError):
        eng.submit(Request("big", toks, max_new_tokens=8))


def test_report_with_no_completions_never_raises():
    """Percentiles of an empty completion list are None, not an error."""
    cfg, params = _setup()
    eng = ContinuousBatchingEngine(cfg, params, max_slots=2,
                                   cache_len=CACHE_LEN)
    rep = eng.report()
    assert rep["requests"] == 0
    assert rep["tokens_per_s"] == 0.0
    assert rep["p50_latency_s"] is None
    assert rep["p99_latency_s"] is None
    # no completions = no evidence: None, not a perfect 1.0 (a drift
    # detector reading 1.0 off an idle engine would mask real regressions)
    assert rep["slo_attainment"] is None
    assert rep["slot_occupancy"] == 0.0
    assert eng.measured_rates() == {}


def test_measured_rates_per_stream_export():
    cfg, params = _setup()
    eng = ContinuousBatchingEngine(cfg, params, max_slots=2,
                                   cache_len=CACHE_LEN)
    rng = np.random.default_rng(4)
    toks = lambda: rng.integers(0, cfg.vocab_size, PROMPT_LEN).astype(np.int32)
    for i in range(3):
        eng.submit(Request(f"r{i}", toks(), max_new_tokens=4,
                           stream_id=f"cam-{i % 2}"))
    eng.drain()
    rates = eng.measured_rates()
    assert set(rates) == {"cam-0", "cam-1"}
    assert all(r > 0 for r in rates.values())
    # per-stream tallies account for every generated token; rates are per
    # active window, and streams submitted together share the full run, so
    # each stream's tokens reconstruct from its own window span
    total = sum(rates[sid] * (w[1] - w[0])
                for sid, w in eng._stream_window.items())
    assert total == pytest.approx(eng.stats["tokens_generated"])
    eng.reset_stats()
    assert eng.measured_rates() == {}


def test_measured_rates_late_joiner_not_underestimated():
    """Regression: rates used to divide by *total* wall time, so a stream
    that joined late looked slower than it served — phantom drift. Rates
    are now over each stream's own active window."""
    cfg, params = _setup()
    eng = ContinuousBatchingEngine(cfg, params, max_slots=2,
                                   cache_len=CACHE_LEN)
    rng = np.random.default_rng(11)
    toks = lambda: rng.integers(0, cfg.vocab_size, PROMPT_LEN).astype(np.int32)
    # early stream runs alone for a while
    eng.submit(Request("r0", toks(), max_new_tokens=12, stream_id="early"))
    eng.drain()
    wall_before_join = eng.stats["wall_s"]
    assert wall_before_join > 0
    # late joiner arrives after the early traffic is done
    eng.submit(Request("r1", toks(), max_new_tokens=12, stream_id="late"))
    eng.drain()
    rates = eng.measured_rates()
    # same work, same decode cost: the late joiner's rate must reflect its
    # own window, not be diluted by the time before it existed
    first, last = eng._stream_window["late"]
    assert first >= wall_before_join
    late_tokens = eng._stream_tokens["late"]
    stale_rate = late_tokens / eng.stats["wall_s"]   # the old, buggy math
    assert rates["late"] == pytest.approx(late_tokens / (last - first))
    assert rates["late"] > stale_rate


def test_windowed_rates_delta_export():
    """windowed_rates() reports tokens/s since the previous poll — the
    streaming export a drift detector samples — and drains to empty."""
    cfg, params = _setup()
    eng = ContinuousBatchingEngine(cfg, params, max_slots=2,
                                   cache_len=CACHE_LEN)
    rng = np.random.default_rng(12)
    toks = lambda: rng.integers(0, cfg.vocab_size, PROMPT_LEN).astype(np.int32)
    eng.submit(Request("r0", toks(), max_new_tokens=6, stream_id="cam-0"))
    eng.drain()
    first = eng.windowed_rates()
    assert set(first) == {"cam-0"}
    assert first["cam-0"] > 0
    # no new tokens since the poll: empty, not a repeat of old traffic
    assert eng.windowed_rates() == {}
    eng.submit(Request("r1", toks(), max_new_tokens=6, stream_id="cam-1"))
    eng.drain()
    second = eng.windowed_rates()
    assert set(second) == {"cam-1"}


def test_windowed_rates_consecutive_polls_partition_exactly():
    """Two consecutive polls split the completion stream with no token
    counted twice and none dropped: rate x span per window recovers the
    per-stream token deltas, and the windows sum to the lifetime tally."""
    cfg, params = _setup()
    eng = ContinuousBatchingEngine(cfg, params, max_slots=2,
                                   cache_len=CACHE_LEN)
    rng = np.random.default_rng(21)
    toks = lambda: rng.integers(0, cfg.vocab_size, PROMPT_LEN).astype(np.int32)

    eng.submit(Request("r0", toks(), max_new_tokens=6, stream_id="cam-0"))
    eng.drain()
    wall_0 = eng._rate_snapshot[0]
    first = eng.windowed_rates()
    wall_1, tokens_1 = eng._rate_snapshot

    eng.submit(Request("r1", toks(), max_new_tokens=4, stream_id="cam-0"))
    eng.submit(Request("r2", toks(), max_new_tokens=5, stream_id="cam-1"))
    eng.drain()
    second = eng.windowed_rates()
    wall_2, tokens_2 = eng._rate_snapshot

    span_1, span_2 = wall_1 - wall_0, wall_2 - wall_1
    # window 1: only cam-0 traffic, and rate x span is its exact tally
    assert set(first) == {"cam-0"}
    assert first["cam-0"] * span_1 == pytest.approx(tokens_1["cam-0"])
    # window 2 carries exactly the deltas since the first poll
    assert set(second) == {"cam-0", "cam-1"}
    assert second["cam-0"] * span_2 == pytest.approx(
        tokens_2["cam-0"] - tokens_1["cam-0"])
    assert second["cam-1"] * span_2 == pytest.approx(tokens_2["cam-1"])
    # partition exactness: the two windows reassemble the lifetime tally
    for sid in ("cam-0", "cam-1"):
        assert (first.get(sid, 0.0) * span_1 + second.get(sid, 0.0) * span_2
                == pytest.approx(tokens_2[sid]))


def test_windowed_rates_empty_window_is_empty_dict():
    """A poll window with no completions must return {} — silence is "no
    data" for the drift detector, never a fleet of zero-rate streams."""
    cfg, params = _setup()
    eng = ContinuousBatchingEngine(cfg, params, max_slots=2,
                                   cache_len=CACHE_LEN)
    # before any traffic at all (wall clock never advanced)
    assert eng.windowed_rates() == {}
    rng = np.random.default_rng(22)
    eng.submit(Request("r0", rng.integers(0, cfg.vocab_size, PROMPT_LEN)
                       .astype(np.int32), max_new_tokens=4,
                       stream_id="cam-0"))
    eng.drain()
    assert set(eng.windowed_rates()) == {"cam-0"}
    # idle window: {} (not {"cam-0": 0.0}) even though the stream is known
    assert eng.windowed_rates() == {}
    assert eng.windowed_rates() == {}


def test_windowed_rates_departing_stream_lands_in_final_window():
    """A stream retiring mid-window is attributed to the window covering
    its completion, then disappears from later windows entirely."""
    cfg, params = _setup()
    eng = ContinuousBatchingEngine(cfg, params, max_slots=2,
                                   cache_len=CACHE_LEN)
    rng = np.random.default_rng(23)
    toks = lambda: rng.integers(0, cfg.vocab_size, PROMPT_LEN).astype(np.int32)
    # "departs" retires after 2 tokens while "stays" keeps decoding past it
    eng.submit(Request("d0", toks(), max_new_tokens=2, stream_id="departs"))
    eng.submit(Request("s0", toks(), max_new_tokens=8, stream_id="stays"))
    eng.drain()
    window = eng.windowed_rates()
    # the departed stream's final tokens are in this window...
    assert set(window) == {"departs", "stays"}
    span = eng._rate_snapshot[0]
    assert window["departs"] * span == pytest.approx(
        eng._stream_tokens["departs"])
    # ...and it is absent (not zero) from every window after its departure
    eng.submit(Request("s1", toks(), max_new_tokens=3, stream_id="stays"))
    eng.drain()
    assert set(eng.windowed_rates()) == {"stays"}


class _CollectingEngine:
    """submit()-only stand-in so StreamSimulator runs without a model."""

    def __init__(self):
        self.requests = []

    def submit(self, req):
        self.requests.append(req)


def test_tick_fractional_fps_accumulates_exactly():
    eng = _CollectingEngine()
    sim = StreamSimulator(eng, prompt_len=4, new_tokens=2, vocab=100)
    for _ in range(4):
        sim.tick({"half": 0.5}, dt_s=1.0)
    assert len(eng.requests) == 2          # 0.5 fps * 4 s = 2 frames exactly
    for _ in range(8):
        sim.tick({"half": 0.5, "quarter": 0.25}, dt_s=1.0)
    by_stream = {}
    for r in eng.requests:
        by_stream[r.stream_id] = by_stream.get(r.stream_id, 0) + 1
    assert by_stream == {"half": 6, "quarter": 2}
    # the frame period is the deadline budget
    assert eng.requests[-1].deadline_s in (2.0, 4.0)
