"""Exactness/invariant tests for the MDMC vector-bin-packing solver.

``hypothesis`` is optional (see DESIGN.md, Testing): when missing, seeded
random instances below exercise the same invariants (solver == brute force
on tiny instances, solver <= every heuristic, validate() on all solutions).
"""
import numpy as np
import pytest

try:
    import hypothesis.strategies as st
    from hypothesis import given, settings
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

from repro.core.heuristics import (cheapest_instance_first,
                                   first_fit_decreasing, lowest_price_first)
from repro.core.packing import Choice, Infeasible, Item, Problem, validate
from repro.core.solver import brute_force, solve


def _random_problem(rng, max_items=6, max_choices=3, ndim=2):
    n_choices = int(rng.integers(1, max_choices + 1))
    choices = []
    for c in range(n_choices):
        cap = tuple(float(rng.uniform(1.0, 10.0)) for _ in range(ndim))
        choices.append(Choice(key=f"c{c}", type_name=f"t{c}", location="x",
                              capacity=cap,
                              price=round(float(rng.uniform(0.1, 5.0)), 3)))
    n_items = int(rng.integers(1, max_items + 1))
    items = []
    for i in range(n_items):
        reqs = []
        for c in range(n_choices):
            if rng.random() < 0.5:
                req = tuple(round(float(rng.uniform(0.0, 6.0)), 3)
                            for _ in range(ndim))
                # keep compatible only if it fits an empty bin
                if all(r <= k for r, k in zip(req, choices[c].capacity)):
                    reqs.append(req)
                else:
                    reqs.append(None)
            else:
                reqs.append(None)
        items.append(Item(key=f"i{i}", requirements=tuple(reqs)))
    return Problem(choices=tuple(choices), items=tuple(items))


def _feasible(problem):
    return all(it.compatible() for it in problem.items)


def _check_bnb_matches_brute_force(problem):
    """The BnB solver is exact: equals exhaustive search on small inputs."""
    if not _feasible(problem):
        with pytest.raises(Infeasible):
            solve(problem)
        return
    sol, stats = solve(problem)
    ref = brute_force(problem)
    validate(problem, sol)
    validate(problem, ref)
    assert stats.optimal
    assert sol.cost == pytest.approx(ref.cost, abs=1e-6)


def _check_solver_invariants(problem):
    """Coverage, capacity, cost accounting; BnB never worse than greedy."""
    if not _feasible(problem):
        return
    sol, _ = solve(problem)
    validate(problem, sol)
    for heur in (first_fit_decreasing, lowest_price_first,
                 cheapest_instance_first):
        h = heur(problem)
        validate(problem, h)
        assert sol.cost <= h.cost + 1e-9, f"BnB worse than {h.note}"


def _check_capacity_never_exceeded(problem):
    """The 90%-cap rule is encoded in the capacities; packing must respect
    them in every dimension (validate() raises otherwise)."""
    if not _feasible(problem):
        return
    for heur in (first_fit_decreasing, lowest_price_first):
        sol = heur(problem)
        for b in sol.bins:
            used = b.used(problem)
            cap = problem.choices[b.choice].capacity
            assert all(u <= c + 1e-6 for u, c in zip(used, cap))


def test_bnb_matches_brute_force_seeded():
    rng = np.random.default_rng(0)
    for _ in range(40):
        _check_bnb_matches_brute_force(_random_problem(rng))


def test_solver_invariants_seeded():
    rng = np.random.default_rng(1)
    for _ in range(25):
        _check_solver_invariants(
            _random_problem(rng, max_items=10, max_choices=4, ndim=3))


def test_capacity_never_exceeded_seeded():
    rng = np.random.default_rng(2)
    for _ in range(25):
        _check_capacity_never_exceeded(_random_problem(rng, max_items=8))


if HAVE_HYPOTHESIS:
    @st.composite
    def problems(draw, max_items=6, max_choices=3, ndim=2):
        n_choices = draw(st.integers(1, max_choices))
        choices = []
        for c in range(n_choices):
            cap = tuple(draw(st.floats(1.0, 10.0)) for _ in range(ndim))
            price = draw(st.floats(0.1, 5.0))
            choices.append(Choice(key=f"c{c}", type_name=f"t{c}",
                                  location="x", capacity=cap,
                                  price=round(price, 3)))
        n_items = draw(st.integers(1, max_items))
        items = []
        for i in range(n_items):
            reqs = []
            for c in range(n_choices):
                if draw(st.booleans()):
                    req = tuple(round(draw(st.floats(0.0, 6.0)), 3)
                                for _ in range(ndim))
                    if all(r <= k for r, k in zip(req, choices[c].capacity)):
                        reqs.append(req)
                    else:
                        reqs.append(None)
                else:
                    reqs.append(None)
            items.append(Item(key=f"i{i}", requirements=tuple(reqs)))
        return Problem(choices=tuple(choices), items=tuple(items))

    @given(problems())
    @settings(max_examples=120, deadline=None)
    def test_bnb_matches_brute_force(problem):
        _check_bnb_matches_brute_force(problem)

    @given(problems(max_items=10, max_choices=4, ndim=3))
    @settings(max_examples=60, deadline=None)
    def test_solver_invariants(problem):
        _check_solver_invariants(problem)

    @given(problems(max_items=8))
    @settings(max_examples=60, deadline=None)
    def test_capacity_never_exceeded(problem):
        _check_capacity_never_exceeded(problem)


@pytest.mark.slow
def test_solver_scales_to_paper_sizes():
    """Fig. 6-sized problems (24 streams x 30+ choices) solve within budget."""
    from repro.core import fig6_catalog, Stream, build_problem
    from repro.core.workload import PROGRAMS
    from repro.core import geo
    cams = list(geo.CAMERAS)
    streams = [Stream(f"zf{i}", PROGRAMS["ZF"], fps=1.0,
                      camera=cams[i % len(cams)]) for i in range(24)]
    problem = build_problem(streams, fig6_catalog(), target_fps=1.0,
                            rtt_filter=True)
    sol, stats = solve(problem, time_budget_s=20.0)
    validate(problem, sol)
    assert sol.cost > 0
