"""Fleet simulator: determinism, frame conservation under preemption,
adaptive-vs-static outcomes, boot-delay service windows, demand generators,
the pluggable replan trigger, and the serving-measurement calibration path."""
import dataclasses

import pytest

from repro.core import AdaptiveManager, ResourceManager, Stream, fig6_catalog
from repro.core import geo
from repro.core.workload import PROGRAMS
from repro.sim import (CameraSpec, DiurnalFleet, EventQueue, FleetSimulator,
                       FlashCrowd, Ledger, MixShift, PoissonChurn,
                       PredictiveEWMAPolicy, ReactivePolicy, RepairPolicy,
                       SCENARIOS, ScheduledPolicy, ServiceCalibration,
                       SimConfig, StaticPeakPolicy, peak_streams,
                       rush_hour_fps)


def _run(scenario, policy_cls=ReactivePolicy, **kw):
    cat = scenario.catalog()
    if policy_cls is StaticPeakPolicy:
        policy = StaticPeakPolicy(ResourceManager(cat),
                                  scenario.peak_streams())
    else:
        policy = policy_cls(ResourceManager(cat), **kw)
    return FleetSimulator(scenario.demand, policy, cat,
                          scenario.config).run()


# -- event queue -------------------------------------------------------------

def test_event_queue_orders_by_time_then_insertion():
    q = EventQueue()
    q.push(2.0, "b")
    q.push(1.0, "a")
    q.push(1.0, "c")         # same time as "a", inserted later
    q.push(0.5, "d")
    kinds = [q.pop().kind for _ in range(len(q))]
    assert kinds == ["d", "a", "c", "b"]


# -- demand ------------------------------------------------------------------

def test_local_hour_follows_longitude():
    # Tokyo (lon ~139.7) is ~9.3 solar hours ahead of UTC
    assert geo.local_hour(0.0, "tokyo") == pytest.approx(139.69 / 15.0)
    # New York is behind UTC
    assert geo.local_hour(12.0, "nyc") < 12.0
    assert 0.0 <= geo.local_hour(23.9, "sydney") < 24.0


def test_diurnal_curve_peaks_at_local_rush_hour():
    base, peak = 0.2, 6.0
    assert rush_hour_fps(8.5, base, peak) == pytest.approx(peak)
    assert rush_hour_fps(3.0, base, peak) < 0.3
    # a Tokyo camera peaks when it is 8:30 *in Tokyo*, not 8:30 UTC
    fleet = DiurnalFleet((CameraSpec("s", "tokyo", "ZF", base, peak),))
    utc_of_tokyo_morning = (8.5 - geo.utc_offset_hours("tokyo")) % 24
    utc_of_tokyo_midday = (12.5 - geo.utc_offset_hours("tokyo")) % 24
    at_peak = fleet.streams_at(utc_of_tokyo_morning)[0].fps
    at_midday = fleet.streams_at(utc_of_tokyo_midday)[0].fps
    assert at_peak > 5.5 > at_midday


def test_poisson_churn_is_seeded_and_bounded():
    base = DiurnalFleet((CameraSpec("s", "nyc", "ZF", 0.2, 2.0),))
    tpl = (CameraSpec("extra", "london", "ZF", 0.3, 1.0),)
    a = PoissonChurn(base, templates=tpl, horizon_h=24.0, seed=3)
    b = PoissonChurn(base, templates=tpl, horizon_h=24.0, seed=3)
    counts_a = [len(a.streams_at(t)) for t in range(24)]
    counts_b = [len(b.streams_at(t)) for t in range(24)]
    assert counts_a == counts_b
    assert max(counts_a) > 1          # some churn camera showed up
    assert min(counts_a) >= 1         # the base camera never disappears


def test_flash_crowd_scales_only_matching_cameras_and_caps():
    base = DiurnalFleet((CameraSpec("a", "london", "ZF", 1.0, 1.0),
                         CameraSpec("b", "nyc", "ZF", 1.0, 1.0)))
    fc = FlashCrowd(base, start_h=10.0, duration_h=2.0, multiplier=100.0,
                    cameras=frozenset({"london"}), cap_fps=12.0)
    inside = {s.stream_id: s.fps for s in fc.streams_at(11.0)}
    outside = {s.stream_id: s.fps for s in fc.streams_at(13.0)}
    assert inside["a"] == 12.0 and inside["b"] == 1.0
    assert outside["a"] == 1.0


def test_flash_crowd_respects_program_feasibility_ceiling():
    """A boosted VGG16 stream must stay plannable: its GPU profile tops out
    near 2.8 fps, far below the generic cap (was an Infeasible crash)."""
    base = DiurnalFleet((CameraSpec("v", "london", "VGG16", 1.0, 1.0),))
    fc = FlashCrowd(base, start_h=10.0, duration_h=2.0, multiplier=8.0)
    boosted = fc.streams_at(11.0)[0]
    assert boosted.fps <= boosted.program.max_gpu_fps()
    # the planner can still place it
    ResourceManager(fig6_catalog()).plan([boosted], "FFD")


def test_mix_shift_swaps_program_at_night_only():
    base = DiurnalFleet(tuple(CameraSpec(f"s{i}", "london", "ZF", 0.2, 2.0)
                              for i in range(20)))
    ms = MixShift(base, night_program="VGG16", fraction=0.5)
    utc_midnight_london = (0.0 - geo.utc_offset_hours("london")) % 24
    night = ms.streams_at(utc_midnight_london)
    noon = ms.streams_at((12.0 - geo.utc_offset_hours("london")) % 24)
    assert any(s.program.name == "VGG16" for s in night)
    assert any(s.program.name == "ZF" for s in night)
    assert all(s.program.name == "ZF" for s in noon)


def test_peak_streams_scan_catches_the_rush_hour():
    fleet = DiurnalFleet((CameraSpec("s", "nyc", "ZF", 0.2, 6.0),))
    peaks = peak_streams(fleet, 24.0, step_h=0.5)
    assert len(peaks) == 1
    assert peaks[0].fps > 5.5


# -- simulator core ----------------------------------------------------------

def test_deterministic_ledger_under_fixed_seed():
    totals = [
        _run(SCENARIOS["rush_hour"](n_streams=16, seed=11)).totals()
        for _ in range(2)
    ]
    assert totals[0] == totals[1]
    spot = [
        _run(SCENARIOS["spot_heavy"](n_streams=16, seed=11)).totals()
        for _ in range(2)
    ]
    assert spot[0] == spot[1]


@pytest.mark.parametrize("name", sorted(SCENARIOS))
def test_every_scenario_is_deterministic(name):
    """Determinism smoke over the whole catalog: every registered scenario
    factory builds at a small size, runs two ticks, and yields identical
    per-tick ledger rows (exact floats, via ``Ledger.signature()``) across
    two same-seed runs. Catches RNG-split regressions — a generator that
    consumes draws from a shared stream depending on incidental state (the
    PR 3 walk/preemption split) breaks this before it can corrupt a
    benchmark baseline."""
    # two decision intervals of the scenario's own tick (flash_crowd runs
    # at dt=0.5, the rest at 1.0)
    dt_h = SCENARIOS[name](n_streams=16, seed=11).config.dt_h

    def once():
        sc = SCENARIOS[name](n_streams=16, duration_h=2 * dt_h, seed=11)
        return _run(sc)
    a, b = once(), once()
    assert len(a.records) == 2
    assert a.signature() == b.signature()


def test_adaptive_beats_static_peak_within_slo_budget():
    # the acceptance bars are defined at fleet scale (>=100 streams): small
    # fleets amortize boot windows over proportionally fewer frames
    sc = SCENARIOS["rush_hour"](n_streams=108)
    static = _run(sc, StaticPeakPolicy)
    react = _run(sc, ReactivePolicy)
    assert react.total_cost < 0.7 * static.total_cost, \
        "adaptive must save >=30% vs static peak provisioning"
    assert static.slo_attainment() - react.slo_attainment() <= 0.02, \
        "adaptive SLO must stay within 2% of static"


def test_spot_preemptions_conserve_frames_and_replay_streams():
    sc = SCENARIOS["spot_heavy"](n_streams=108)
    led = _run(sc)
    assert led.preemptions > 0, "spot-heavy scenario must preempt"
    for r in led.records:
        assert r.frames_demanded == pytest.approx(
            r.frames_analyzed + r.frames_dropped)
    # preempted capacity is replaced: service recovers to near-full
    assert led.slo_attainment() > 0.9
    assert led.frames_analyzed > 0


def test_flash_crowd_scenario_with_churn_runs_end_to_end():
    """Camera churn (arrivals force replans) + the 8x regional spike drive a
    full simulated day without losing conservation."""
    sc = SCENARIOS["flash_crowd"](n_streams=12)
    led = _run(sc)
    assert len(led.records) == int(sc.config.duration_h / sc.config.dt_h)
    assert max(r.streams for r in led.records) > 12   # churn arrived
    for r in led.records:
        assert r.frames_demanded == pytest.approx(
            r.frames_analyzed + r.frames_dropped)


def test_steady_scenario_keeps_plan_stable():
    led = _run(SCENARIOS["steady"](n_streams=12))
    # constant demand: after the initial placement nothing migrates
    assert sum(r.migrations for r in led.records[2:]) == 0
    assert led.slo_attainment() > 0.99


def test_boot_delay_drops_only_the_boot_window():
    class Constant:
        def streams_at(self, t):
            return [Stream("cam", PROGRAMS["ZF"], fps=1.0, camera="nyc")]

    cfg = SimConfig(duration_h=3.0, dt_h=1.0, boot_delay_h=0.5, seed=0)
    cat = fig6_catalog()
    led = FleetSimulator(Constant(), ReactivePolicy(ResourceManager(cat)),
                         cat, cfg).run()
    # tick 0: the only instance spends half the tick booting
    # (frame counts are fps x seconds: 1 fps x 0.5 h = 1800 frames)
    assert led.records[0].frames_dropped == pytest.approx(1800.0)
    # afterwards the plan is stable and nothing drops
    assert led.records[1].frames_dropped == pytest.approx(0.0)
    assert led.records[2].frames_dropped == pytest.approx(0.0)


def test_ledger_rejects_nonconserving_ticks():
    from repro.sim.ledger import TickRecord
    led = Ledger()
    bad = TickRecord(t=0, cost=1.0, frames_demanded=2.0, frames_analyzed=1.0,
                     frames_dropped=0.5, migrations=0, preemptions=0,
                     instances_live=1, streams=1)
    with pytest.raises(ValueError):
        led.add_tick(bad, {})


def test_repair_policy_cuts_migrations_on_rush_hour():
    """The min-migration policy must not churn more than full FFD replanning
    on the same seeded day, at comparable cost."""
    sc = SCENARIOS["rush_hour"](n_streams=24)
    react = _run(sc)
    rep = _run(sc, RepairPolicy)
    assert rep.migrations < react.migrations
    assert rep.total_cost < 1.25 * react.total_cost
    for r in rep.records:
        assert r.frames_demanded == pytest.approx(
            r.frames_analyzed + r.frames_dropped)


def test_repair_defrags_reach_the_ledger():
    """defrag_ratio=1.0 fires the escape hatch on every cost regression;
    the fleet ledger must record those events per tick and in totals()."""
    sc = SCENARIOS["rush_hour"](n_streams=24)
    led = _run(sc, RepairPolicy, defrag_ratio=1.0)
    assert led.defrags > 0
    assert led.totals()["defrags"] == led.defrags
    assert sum(r.defrags for r in led.records) == led.defrags
    # the pure-repair run never defrags by default at this scale
    led2 = _run(sc, RepairPolicy, defrag_ratio=None)
    assert led2.defrags == 0


def test_churn_storm_scenario_runs_end_to_end():
    """Arrivals, departures and preemptions in one scenario: conservation
    holds and the repair policy still serves the overwhelming majority."""
    sc = SCENARIOS["churn_storm"](n_streams=18, duration_h=12.0)
    led = _run(sc, RepairPolicy)
    assert len(led.records) == int(sc.config.duration_h / sc.config.dt_h)
    assert max(r.streams for r in led.records) > 18      # churn arrived
    assert led.slo_attainment() > 0.9


# -- adaptive hooks ----------------------------------------------------------

def test_replan_trigger_gates_voluntary_replans():
    calls = []

    def never(t, streams, plan):
        calls.append(t)
        return False

    mgr = AdaptiveManager(ResourceManager(fig6_catalog()), strategy="FFD",
                          replan_trigger=never)
    streams = [Stream("s", PROGRAMS["ZF"], fps=2.0, camera="nyc")]
    cheaper = [Stream("s", PROGRAMS["ZF"], fps=0.2, camera="nyc")]
    mgr.step(0, streams)
    mgr.step(1, cheaper)       # in-place feasible; trigger says don't bother
    assert [e.action for e in mgr.history()] == ["replan", "keep"]
    assert calls == [1]
    # force bypasses the trigger (spot preemption replay)
    mgr.step(2, cheaper, force=True)
    assert mgr.history()[-1].action == "forced-replan"


def test_new_stream_forces_replan():
    mgr = AdaptiveManager(ResourceManager(fig6_catalog()), strategy="FFD")
    s0 = [Stream("a", PROGRAMS["ZF"], fps=1.0, camera="nyc")]
    mgr.step(0, s0)
    arrived = s0 + [Stream("b", PROGRAMS["ZF"], fps=1.0, camera="nyc")]
    assert not mgr._plan_feasible_for(mgr.current, arrived)
    mgr.step(1, arrived)
    assert mgr.history()[-1].action == "forced-replan"


def test_scheduled_policy_replans_on_cadence():
    sc = SCENARIOS["rush_hour"](n_streams=8)
    led = _run(sc, ScheduledPolicy, every_h=6.0)
    assert led.total_cost > 0
    # predictive runs too, and reports forecast-driven migrations
    led_p = _run(sc, PredictiveEWMAPolicy)
    assert led_p.total_cost > 0


# -- calibration path --------------------------------------------------------

class _StubEngine:
    """Duck-typed serving engine: measured_rates() export only."""

    def __init__(self, rates):
        self._rates = rates

    def measured_rates(self):
        return dict(self._rates)


def test_calibration_caps_analyzed_frames():
    class Constant:
        def streams_at(self, t):
            return [Stream("cam", PROGRAMS["ZF"], fps=1.0, camera="nyc")]

    # engine sustains 4 tokens/s at 8 tokens/frame -> 0.5 frames/s cap
    calib = ServiceCalibration.from_engine(_StubEngine({"cam": 4.0}))
    assert calib.frame_rate_cap("cam") == pytest.approx(0.5)
    assert calib.frame_rate_cap("never-measured") == pytest.approx(0.5)

    cfg = SimConfig(duration_h=2.0, dt_h=1.0, boot_delay_h=0.0)
    cat = fig6_catalog()
    led = FleetSimulator(Constant(), ReactivePolicy(ResourceManager(cat)),
                         cat, cfg, calibration=calib).run()
    for r in led.records:
        # 1 fps demanded for 1 h = 3600 frames; capped at 0.5 frames/s
        assert r.frames_analyzed == pytest.approx(1800.0)
        assert r.frames_dropped == pytest.approx(1800.0)


def test_measured_rates_feed_packing_items():
    from repro.core.tpu_catalog import streams_from_engine
    eng = _StubEngine({"cam-1": 30.0, "cam-0": 60.0})
    items = streams_from_engine("olmo-1b", eng)
    assert [s.stream_id for s in items] == ["cam-0", "cam-1"]
    assert items[0].tokens_per_s == 60.0
    calib = ServiceCalibration.from_engine(eng)
    packed = calib.packing_streams("olmo-1b")
    assert {s.stream_id for s in packed} == {"cam-0", "cam-1"}

def test_service_calibration_edge_conventions():
    """Uncalibrated stream with no default -> inf (never caps); an explicit
    default covers unmeasured streams; from_engine with no traffic stays
    fully uncalibrated."""
    import math

    bare = ServiceCalibration()
    assert bare.default_rate is None
    assert bare.frame_rate_cap("anything") == math.inf

    with_default = ServiceCalibration(rates_tokens_per_s={"cam": 16.0},
                                      default_rate=8.0)
    assert with_default.frame_rate_cap("cam") == pytest.approx(2.0)
    assert with_default.frame_rate_cap("unmeasured") == pytest.approx(1.0)

    idle = ServiceCalibration.from_engine(_StubEngine({}))
    assert idle.rates_tokens_per_s == {}
    assert idle.default_rate is None
    assert idle.frame_rate_cap("cam") == math.inf


def test_ewma_policy_evicts_departed_stream_state():
    """Regression: forecast state leaked for departed streams, so a camera
    that rejoined inherited a stale trend (and state grew without bound
    under churn). Departures must drop state; a rejoin starts fresh."""
    cat = fig6_catalog()
    pol = PredictiveEWMAPolicy(ResourceManager(cat))

    def s(fps):
        return Stream("cam", PROGRAMS["ZF"], fps=fps, camera="nyc")

    other = Stream("other", PROGRAMS["ZF"], fps=1.0, camera="nyc")
    # build a strong upward trend on "cam"
    for fps in (1.0, 3.0, 5.0):
        pol.forecast([s(fps), other])
    assert pol._trend["cam"] > 0
    # "cam" departs: its state must be evicted, the survivor's kept
    pol.forecast([other])
    assert "cam" not in pol._prev_fps
    assert "cam" not in pol._trend
    assert "other" in pol._prev_fps
    # rejoin at a low rate: a fresh trend, not the stale climb -> the
    # forecast is the demanded rate, not an extrapolated ramp
    out = pol.forecast([s(1.0), other])
    rejoined = next(x for x in out if x.stream_id == "cam")
    assert rejoined.fps == pytest.approx(1.0)
    assert pol._trend["cam"] == pytest.approx(0.0)
