"""Arc-flow formulation tests, including the paper's sidebar example.

``hypothesis`` is optional (see DESIGN.md, Testing): the property tests run
when it is installed; deterministic seeded sweeps below cover the same
invariants either way.
"""
import numpy as np

try:
    import hypothesis.strategies as st
    from hypothesis import given, settings
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

from repro.core.arcflow import (ArcFlowGraph, IntItem, build_graph, compress,
                                max_items_per_bin, min_bins_from_patterns,
                                patterns, quantize)


def sidebar_example():
    """Truck (7,3); boxes A(5,1)x1, B(3,1)x1, C(2,1)x2 — Fig. in sidebar."""
    items = [IntItem((5, 1), 1, "A"), IntItem((3, 1), 1, "B"),
             IntItem((2, 1), 2, "C")]
    return build_graph((7, 3), items)


def test_sidebar_graph_patterns():
    g = sidebar_example()
    pats = set(patterns(g))
    # A+C fits (7,2); B+2C fits (7,3); A+B does not (8 > 7)
    assert (1, 0, 1) in pats
    assert (0, 1, 2) in pats
    assert (1, 1, 0) not in pats
    assert max(sum(p) for p in pats) == 3


def test_sidebar_min_bins():
    g = sidebar_example()
    # all four boxes: A+C in one truck, B+C in another -> 2 trucks
    assert min_bins_from_patterns(g) == 2


def test_compression_preserves_patterns():
    g = sidebar_example()
    gc = compress(g)
    assert set(patterns(g)) == set(patterns(gc))
    assert len(gc.nodes) <= len(g.nodes)


def _check_patterns_respect_capacity_and_demand(raw_items, cap):
    items = [IntItem((w, h), d, f"i{i}")
             for i, (w, h, d) in enumerate(raw_items)]
    g = build_graph(cap, items)
    for pat in patterns(g, limit=2000):
        used = [0, 0]
        for count, item in zip(pat, items):
            assert count <= item.demand
            used[0] += count * item.vector[0]
            used[1] += count * item.vector[1]
        assert used[0] <= cap[0] and used[1] <= cap[1]


def _check_compression_equivalence(raw_items, cap):
    items = [IntItem((w, h), d, f"i{i}")
             for i, (w, h, d) in enumerate(raw_items)]
    g = build_graph(cap, items)
    gc = compress(g)
    assert set(patterns(g, limit=5000)) == set(patterns(gc, limit=5000))


def _random_instances(n, seed=0):
    """Deterministic (raw_items, cap) instances mirroring the hypothesis
    strategy: up to 4 items with vectors in [1,5]^2, demand in [1,2]."""
    rng = np.random.default_rng(seed)
    out = []
    for _ in range(n):
        k = int(rng.integers(1, 5))
        raw = [(int(rng.integers(1, 6)), int(rng.integers(1, 6)),
                int(rng.integers(1, 3))) for _ in range(k)]
        cap = (int(rng.integers(4, 10)), int(rng.integers(4, 10)))
        out.append((raw, cap))
    return out


def test_patterns_respect_capacity_and_demand_seeded():
    for raw, cap in _random_instances(40, seed=1):
        _check_patterns_respect_capacity_and_demand(raw, cap)


def test_compression_equivalence_seeded():
    for raw, cap in _random_instances(25, seed=2):
        if cap[0] < 5 or cap[1] < 5:
            continue
        _check_compression_equivalence(raw, cap)


if HAVE_HYPOTHESIS:
    @given(st.lists(st.tuples(st.integers(1, 5), st.integers(1, 5),
                              st.integers(1, 2)), min_size=1, max_size=4),
           st.tuples(st.integers(4, 9), st.integers(4, 9)))
    @settings(max_examples=60, deadline=None)
    def test_patterns_respect_capacity_and_demand(raw_items, cap):
        _check_patterns_respect_capacity_and_demand(raw_items, cap)

    @given(st.lists(st.tuples(st.integers(1, 5), st.integers(1, 5),
                              st.integers(1, 2)), min_size=1, max_size=4),
           st.tuples(st.integers(5, 9), st.integers(5, 9)))
    @settings(max_examples=40, deadline=None)
    def test_compression_equivalence(raw_items, cap):
        _check_compression_equivalence(raw_items, cap)


def test_min_bins_matches_exact_solver():
    """Single-choice instances: arc-flow covering == BnB bin count."""
    from repro.core.packing import Choice, Item, Problem
    from repro.core.solver import solve

    cap = (7, 3)
    raw = [((5, 1), 1), ((3, 1), 1), ((2, 1), 2), ((4, 2), 2)]
    items_af = [IntItem(v, d, str(i)) for i, (v, d) in enumerate(raw)]
    g = build_graph(cap, items_af)
    af_bins = min_bins_from_patterns(g)

    choices = (Choice("c", "t", "x", (7.0, 3.0), 1.0),)
    items = []
    k = 0
    for (v, d) in raw:
        for _ in range(d):
            items.append(Item(f"i{k}", ((float(v[0]), float(v[1])),)))
            k += 1
    sol, _ = solve(Problem(choices=choices, items=tuple(items)))
    assert len(sol.bins) == af_bins


def test_quantize_is_conservative():
    vecs, cap_int = quantize([(1.01, 0.5)], (8.0, 4.0), levels=8)
    # ceil: 1.01/8*8 -> 2 levels (conservative rounding up)
    assert vecs[0][0] >= 2
    assert cap_int == (8, 8)
