"""Observability layer: telemetry hub, trace spans, drift detection, the
drifting-service ground truth, and the recalibrating policy closing the
profile→pack→observe loop end to end on a small drifting scene."""
import math

import pytest

from repro.core.manager import ResourceManager
from repro.core.workload import PROGRAMS, Stream
from repro.obs import (DriftConfig, DriftDetector, DriftingService,
                       MetricPoint, RateShift, RecalibratingPolicy,
                       TelemetryHub, Tracer)
from repro.sim import (FleetSimulator, RepairPolicy, SCENARIOS,
                       ServiceCalibration, SimConfig)
from repro.core import fig6_catalog
from repro.sim.cluster import Cluster
from repro.core.strategies import Plan


# -- telemetry hub -----------------------------------------------------------

def test_hub_emit_subscribe_and_series():
    hub = TelemetryHub()
    seen = []
    hub.subscribe(seen.append)
    hub.emit(0.0, "fleet.cost.usd", 1.5)
    hub.emit(1.0, "fleet.cost.usd", 2.5, market="spot")
    hub.emit(1.0, "fleet.slo", 0.99)
    # push side: subscribers got every point synchronously, in order
    assert [p.name for p in seen] == ["fleet.cost.usd", "fleet.cost.usd",
                                      "fleet.slo"]
    assert seen[1].attr("market") == "spot"
    assert seen[1].attr("missing") is None
    # pull side: latest/series/names over the same stream
    assert hub.latest("fleet.cost.usd") == 2.5
    assert hub.latest("never") is None
    assert hub.series("fleet.cost.usd") == [(0.0, 1.5), (1.0, 2.5)]
    assert hub.names() == ["fleet.cost.usd", "fleet.slo"]
    rows = hub.to_rows()
    assert rows[1] == {"t": 1.0, "name": "fleet.cost.usd", "value": 2.5,
                       "attrs": {"market": "spot"}}


def test_metric_points_are_frozen_and_hashable():
    import dataclasses
    p = MetricPoint(0.0, "x", 1.0, (("k", "v"),))
    assert p in {p}
    with pytest.raises(dataclasses.FrozenInstanceError):
        p.value = 2.0  # type: ignore[misc]


# -- tracer ------------------------------------------------------------------

def test_tracer_nests_spans_by_call_stack():
    tr = Tracer()
    with tr.span("recalibrate", t=14.0, rel_error=0.65) as outer:
        with tr.span("replan.decide", t=14.0) as inner:
            inner.attrs["action"] = "forced-replan"
    assert len(tr.spans) == 1
    root = tr.spans[0]
    assert root.name == "recalibrate"
    assert root.attrs["rel_error"] == 0.65
    assert [c.name for c in root.children] == ["replan.decide"]
    assert root.children[0].attrs["action"] == "forced-replan"
    assert root.wall_ms >= root.children[0].wall_ms >= 0.0
    # find() is depth-first across roots and children
    assert len(tr.find("replan.decide")) == 1
    rows = tr.to_rows()
    assert [(r["name"], r["depth"]) for r in rows] == [
        ("recalibrate", 0), ("replan.decide", 1)]


# -- drift detector ----------------------------------------------------------

def _calib(rates, default=None):
    return ServiceCalibration(rates_tokens_per_s=rates, default_rate=default)


def test_detector_fires_after_hold_ticks_and_resets():
    det = DriftDetector(DriftConfig(rel_threshold=0.25, hold_ticks=3))
    cal = _calib({"a": 100.0})
    for k, t in enumerate((0.0, 1.0)):
        v = det.observe(t, {"a": 40.0}, cal)     # 60% error
        assert v.drifting and not v.fired and v.streak == k + 1
    v = det.observe(2.0, {"a": 40.0}, cal)
    assert v.fired and v.streak == 3
    assert v.rel_error == pytest.approx(0.6)
    det.reset()
    assert det.streak == 0
    # healthy measurements keep the streak at zero
    v = det.observe(3.0, {"a": 100.0}, cal)
    assert not v.drifting and v.streak == 0
    assert len(det.history) == 4


def test_detector_streak_resets_on_healthy_window():
    det = DriftDetector(DriftConfig(rel_threshold=0.25, hold_ticks=3))
    cal = _calib({"a": 100.0})
    det.observe(0.0, {"a": 40.0}, cal)
    det.observe(1.0, {"a": 40.0}, cal)
    v = det.observe(2.0, {"a": 100.0}, cal)      # one good window
    assert v.streak == 0
    v = det.observe(3.0, {"a": 40.0}, cal)       # must re-earn the hold
    assert v.streak == 1 and not v.fired


def test_detector_empty_measurement_is_no_evidence():
    """An idle engine (measured_rates() == {}) must neither reset nor grow
    the streak — and must never fire."""
    det = DriftDetector(DriftConfig(rel_threshold=0.25, hold_ticks=2))
    cal = _calib({"a": 100.0})
    det.observe(0.0, {"a": 40.0}, cal)
    v = det.observe(1.0, {}, cal)
    assert v.n_streams == 0 and not v.fired
    assert v.streak == 1                         # preserved, not grown
    v = det.observe(2.0, {"a": 40.0}, cal)
    assert v.streak == 2 and v.fired


def test_detector_skips_unprofiled_and_tiny_rates():
    det = DriftDetector(DriftConfig(rel_threshold=0.25, hold_ticks=1))
    cal = _calib({"a": 100.0, "z": 0.0})         # z: zero calibrated rate
    v = det.observe(0.0, {"a": 100.0, "b": 5.0, "z": 7.0}, cal)
    # b has no calibration and no default; z is below min_rate: both skipped
    assert v.n_streams == 1 and not v.drifting
    # with a default, an unprofiled stream does participate
    v = det.observe(1.0, {"b": 5.0}, _calib({}, default=50.0))
    assert v.n_streams == 1 and v.drifting


# -- drifting service (ground truth + probe) ---------------------------------

def test_drifting_service_shifts_compose_and_scope():
    svc = DriftingService(
        {"a": 80.0, "b": 80.0}, tokens_per_frame=8.0,
        shifts=(RateShift(at_h=6.0, factor=0.5),
                RateShift(at_h=12.0, factor=0.5, streams=frozenset({"a"}))))
    assert svc.measure(0.0) == {"a": 80.0, "b": 80.0}
    assert svc.measure(6.0) == {"a": 40.0, "b": 40.0}    # at_h inclusive
    assert svc.measure(13.0) == {"a": 20.0, "b": 40.0}   # scoped shift
    assert svc.frame_rate_cap("a", 13.0) == pytest.approx(2.5)
    assert svc.frame_rate_cap("unknown", 13.0) == math.inf
    cal0 = svc.initial_calibration()
    assert cal0.rates_tokens_per_s == {"a": 80.0, "b": 80.0}
    assert cal0.default_rate == pytest.approx(80.0)
    assert svc.calibration_at(13.0).rates_tokens_per_s["a"] == 20.0


# -- cluster telemetry hooks -------------------------------------------------

def test_cluster_lifecycle_reaches_telemetry():
    hub = TelemetryHub()
    cl = Cluster(boot_delay_h=0.05, telemetry=hub)
    inst = cl._boot(1.0, "m4@us-east", "m4.xlarge", "us-east", 0.2)
    cl.terminate(inst.instance_id, 2.0)
    cl.terminate(inst.instance_id, 3.0)          # later never re-emits
    boots = [p for p in hub.points if p.name == "cluster.instance.boot"]
    terms = [p for p in hub.points if p.name == "cluster.instance.terminate"]
    assert len(boots) == 1 and len(terms) == 1
    assert boots[0].attr("location") == "us-east"
    assert terms[0].t == 2.0
    assert terms[0].attr("preempted") == "False"


# -- recalibrating policy end to end -----------------------------------------

def _drift_run(online: bool):
    sc = SCENARIOS["drifting_scene"](n_streams=24, duration_h=24.0, seed=0)
    cat = sc.catalog()
    inner = RepairPolicy(ResourceManager(cat), migration_budget=8,
                         defrag_ratio=1.25)
    cfg = DriftConfig() if online else DriftConfig(rel_threshold=math.inf)
    policy = RecalibratingPolicy(inner, sc.service,
                                 detector=DriftDetector(cfg))
    ledger = FleetSimulator(sc.demand, policy, cat, sc.config,
                            service=sc.service,
                            telemetry=policy.telemetry).run()
    return policy, ledger


def test_recalibration_closes_the_loop_on_drifting_scene():
    policy, ledger = _drift_run(online=True)
    # the regression lands at t=12; hold_ticks=3 -> fires by t=15
    assert len(policy.recalibrations) >= 1
    fired = policy.recalibrations[0]
    assert 12.0 <= fired <= 12.0 + policy.detector.config.hold_ticks
    # the ledger recorded the recalibration and the error it saw
    assert ledger.recalibrations == len(policy.recalibrations)
    assert ledger.calib_max_rel_error > 0.25
    rec = next(r for r in ledger.records if r.recalibrations)
    assert rec.t >= fired
    # the event trace flags exactly the drift-forced replans
    flagged = [e for e in policy.adaptive.events if e.recalibration]
    assert len(flagged) == len(policy.recalibrations)
    assert all(e.action == "forced-replan" for e in flagged)
    # telemetry streamed the loop live; the trace nested the forced replan
    assert policy.telemetry.latest("drift.recalibrations") == 1.0
    assert policy.telemetry.series("fleet.cost.usd")
    recal_spans = policy.tracer.find("recalibrate")
    assert len(recal_spans) == 1
    assert recal_spans[0].children[0].name == "replan.decide"
    # after adopting the measured rates the detector sees ~zero error
    assert policy.last_drift is not None
    assert policy.last_drift.rel_error < 0.01


def test_online_recalibration_beats_stale_profile():
    """The benchmark gate in miniature: same truth caps both arms, so the
    recalibrated arm must be cheaper without serving fewer frames (beyond
    replan boot transients)."""
    _, stale = _drift_run(online=False)
    _, online = _drift_run(online=True)
    assert stale.recalibrations == 0
    assert online.total_cost < stale.total_cost
    assert online.slo_attainment() >= stale.slo_attainment() - 0.005
    assert online.frames_demanded == pytest.approx(stale.frames_demanded)


def test_recalibrating_policy_clamps_planned_rates():
    svc = DriftingService({"cam": 16.0}, tokens_per_frame=8.0)  # 2 fps cap
    cat = fig6_catalog()
    policy = RecalibratingPolicy(RepairPolicy(ResourceManager(cat)), svc)
    clamped = policy._clamped(
        [Stream("cam", PROGRAMS["ZF"], fps=6.0, camera="nyc"),
         Stream("slow", PROGRAMS["ZF"], fps=1.0, camera="nyc")])
    assert clamped[0].fps == pytest.approx(2.0)
    assert clamped[1].fps == pytest.approx(1.0)   # under the cap: untouched
    plan = policy.decide(0, clamped)
    assert isinstance(plan, Plan)


# -- subscriber isolation (hub) and error finalization (tracer) --------------

def test_hub_isolates_raising_subscriber():
    """One raising consumer must not abort the emit nor starve later
    subscribers; the failure is recorded and delivery continues."""
    hub = TelemetryHub()
    before, after = [], []

    def bomb(point):
        raise RuntimeError("closed file")

    hub.subscribe(before.append)
    hub.subscribe(bomb)
    hub.subscribe(after.append)
    p1 = hub.emit(0.0, "fleet.cost.usd", 1.0)
    p2 = hub.emit(1.0, "fleet.cost.usd", 2.0)
    # every subscriber after the bomb still saw every point, in order
    assert before == [p1, p2]
    assert after == [p1, p2]
    # the hub's own stream is unaffected
    assert hub.series("fleet.cost.usd") == [(0.0, 1.0), (1.0, 2.0)]
    # and each failed delivery was recorded (t, subscriber, error)
    assert len(hub.subscriber_failures) == 2
    t, who, err = hub.subscriber_failures[0]
    assert t == 0.0
    assert "bomb" in who
    assert "RuntimeError: closed file" in err


def test_tracer_finalizes_span_when_body_raises():
    """A failing body still finalizes its span — error attr set, span
    attached to its parent, exception re-raised — and the stack stays
    intact for subsequent spans."""
    tr = Tracer()
    with pytest.raises(ValueError, match="solver blew up"):
        with tr.span("recalibrate", t=3.0):
            with tr.span("replan.decide", t=3.0):
                raise ValueError("solver blew up")
    # both spans finalized: the failed child is attached under its parent
    assert len(tr.spans) == 1
    root = tr.spans[0]
    assert root.name == "recalibrate"
    assert [c.name for c in root.children] == ["replan.decide"]
    assert root.children[0].attrs["error"] == "ValueError: solver blew up"
    # the parent saw the exception propagate through it too
    assert root.attrs["error"] == "ValueError: solver blew up"
    assert root.wall_ms >= root.children[0].wall_ms >= 0.0
    # stack integrity: the tracer is reusable and nesting starts at root
    with tr.span("replan.decide", t=4.0):
        pass
    assert [s.name for s in tr.spans] == ["recalibrate", "replan.decide"]
    assert tr.spans[1].children == []
    assert "error" not in tr.spans[1].attrs


def test_tracer_explicit_error_attr_wins_over_finalizer():
    tr = Tracer()
    with pytest.raises(RuntimeError):
        with tr.span("replan.decide") as sp:
            sp.attrs["error"] = "already diagnosed"
            raise RuntimeError("later failure")
    assert tr.spans[0].attrs["error"] == "already diagnosed"
