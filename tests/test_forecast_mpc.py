"""Forecasting + MPC tests (ISSUE 10), and the three time-unit bugfix
regressions that motivated them.

``hypothesis`` is optional (see DESIGN.md, Testing): when missing, seeded
random cases exercise the same invariants.

* ``PredictiveEWMAPolicy`` forecasts are a function of the demand *path*,
  not the control-loop period: the same linear ramp sampled at dt=1.0 and
  dt=0.5 yields the same trend state and the same forecasts (this test
  fails against the pre-fix per-tick units);
* ``LookaheadBid`` picks the same bids whether the simulator ticks hourly
  or every five minutes (the reclaim penalty is a dollar cost, not a
  per-tick rate);
* ``ScheduledPolicy`` resets its cadence phase and plan state per run: a
  reused policy's second run is bit-identical to a fresh policy's;
* ``AdaptiveManager.hold_until`` suppresses voluntary adoption only — and
  only until the deadline;
* ``SeasonalForecaster`` reproduces a pure-seasonal demand exactly, keeps
  residuals at zero on repeating days, and falls back to current rates on
  cold buckets;
* ``MPCPolicy`` never provisions below current demand, bounds its
  envelope by the feasibility caps, and collapses to the reactive policy
  (bit-identical ledger) when the forecaster is cold.
"""
import dataclasses
import math
import random

import numpy as np
import pytest

try:
    import hypothesis.strategies as st
    from hypothesis import given, settings
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

from repro.core import ResourceManager, Stream, fig6_catalog
from repro.core.adaptive import AdaptiveManager
from repro.core.markets import SPOT, MarketQuote
from repro.core.workload import PROGRAMS
from repro.sim import (FleetSimulator, LookaheadBid, MPCConfig, MPCPolicy,
                       PredictiveEWMAPolicy, ReactivePolicy, ScheduledPolicy,
                       SeasonalForecaster)
from repro.sim.demand import CameraSpec, DiurnalFleet
from repro.sim.scenarios import follow_the_sun, rush_hour


# ---------------------------------------------------------------- EWMA bugfix

def _ramp(t: float) -> list[Stream]:
    # one stream on a linear ramp: slope exactly 1 frame/s per hour
    return [Stream(stream_id="s0", program=PROGRAMS["ZF"], fps=2.0 + t)]


def test_ewma_forecast_is_dt_invariant():
    """The headline regression: the same demand path sampled at dt=1.0 and
    dt=0.5 must produce the same trend (frames/s per hour) and the same
    forecasts. Pre-fix, trend was frames/s per *tick* and the lead was in
    ticks, so the half-step schedule forecast roughly half the ramp."""
    hourly = PredictiveEWMAPolicy(ResourceManager(fig6_catalog()))
    halved = PredictiveEWMAPolicy(ResourceManager(fig6_catalog()))
    for t in (0.0, 1.0, 2.0):
        out_h = hourly.forecast(_ramp(t), 1.0)
    for t in (0.0, 0.5, 1.0, 1.5, 2.0):
        out_2 = halved.forecast(_ramp(t), 0.5)
    # same wall-clock endpoint, same trend units -> same smoothed slope
    # (approx, not exact: fractional decay goes through float pow)
    assert halved._trend["s0"] == pytest.approx(hourly._trend["s0"],
                                                rel=1e-12)
    assert out_2[0].fps == pytest.approx(out_h[0].fps, abs=1e-3)
    # and the trend really is the ramp slope in fps/hour, partially smoothed
    assert 0.0 < hourly._trend["s0"] <= 1.0


def test_ewma_dt_one_matches_legacy_form():
    """At the legacy 1-hour tick the decay/gain pair must be exactly
    ``1 - alpha`` / ``alpha`` — bit-identical goldens depend on it."""
    pol = PredictiveEWMAPolicy(ResourceManager(fig6_catalog()), alpha=0.3)
    pol.forecast(_ramp(0.0), 1.0)
    pol.forecast(_ramp(1.0), 1.0)
    # one update from zero state at trend 1.0: ewma == alpha exactly
    assert pol._trend["s0"] == 0.3


def test_ewma_lead_ticks_alias():
    pol = PredictiveEWMAPolicy(ResourceManager(fig6_catalog()), lead_ticks=3)
    assert pol.lead_h == 3.0 and pol.lead_ticks == 3.0
    pol.lead_ticks = 1.5
    assert pol.lead_h == 1.5
    # lead_h wins when both are passed
    pol2 = PredictiveEWMAPolicy(ResourceManager(fig6_catalog()),
                                lead_h=2.5, lead_ticks=4)
    assert pol2.lead_h == 2.5


def test_ewma_policy_resets_on_time_reversal():
    pol = PredictiveEWMAPolicy(ResourceManager(fig6_catalog()))
    for t in (0.0, 1.0, 2.0):
        pol.decide(t, _ramp(t))
    assert pol._trend["s0"] > 0
    pol.decide(0.0, _ramp(0.0))           # a new run begins
    assert pol._trend.get("s0", 0.0) == 0.0


# ----------------------------------------------------------- LookaheadBid fix

def _spot_quote(price: float, vol: float) -> MarketQuote:
    return MarketQuote(type_name="g2.2xlarge", location="us-east",
                       market=SPOT, price=price, ondemand_price=1.0,
                       volatility=vol)


@pytest.mark.parametrize("price,vol", [(0.2, 0.1), (0.3, 0.3), (0.6, 0.5),
                                       (0.9, 0.15)])
def test_lookahead_bid_is_dt_invariant(price, vol):
    """The reclaim penalty is the dollar cost of one reclaim and the
    expected-price model runs over a fixed horizon, so bid choices must not
    move with the control-loop period."""
    q = _spot_quote(price, vol)
    strat = LookaheadBid()
    assert strat.bid(q, (), 1.0) == strat.bid(q, (), 1.0 / 12.0)
    assert strat.bid(q, (), 1.0) == strat.bid(q, (), 4.0)


def test_lookahead_reclaim_cost_is_flat_dollars():
    strat = LookaheadBid(boot_delay_h=0.1, slo_weight=2.0)
    assert strat.reclaim_cost(_spot_quote(0.3, 0.2)) == \
        pytest.approx(2.0 * 1.0 * 0.1)


# ------------------------------------------------- ScheduledPolicy run reset

def test_scheduled_policy_two_runs_are_deterministic():
    sc = rush_hour(36)
    cat = sc.catalog()
    reused = ScheduledPolicy(ResourceManager(cat), every_h=6.0)
    led1 = FleetSimulator(sc.demand, reused, cat, sc.config).run()
    led2 = FleetSimulator(sc.demand, reused, cat, sc.config).run()
    fresh = ScheduledPolicy(ResourceManager(cat), every_h=6.0)
    led_f = FleetSimulator(sc.demand, fresh, cat, sc.config).run()
    assert led2.signature() == led_f.signature()
    assert led1.signature() == led_f.signature()


# ----------------------------------------------------------------- hold_until

def _streams(fps: float) -> list[Stream]:
    return [Stream(stream_id=f"s{i}", program=PROGRAMS["ZF"], fps=fps)
            for i in range(6)]


def test_hold_until_suppresses_voluntary_adoption_only():
    am = AdaptiveManager(ResourceManager(fig6_catalog()), strategy="FFD")
    am.step(0, _streams(8.0))
    expensive = am.current.hourly_cost
    am.hold_until = 5.0
    am.step(1, _streams(0.5))             # far cheaper candidate exists
    assert am.events[-1].action == "keep"
    assert am.current.hourly_cost == expensive
    # forced replans pass through the hold
    am.step(2, _streams(0.5), force=True)
    assert am.events[-1].action == "forced-replan"
    am.step(3, _streams(8.0))             # re-inflate, still holding
    am.hold_until = 5.0
    am.step(4, _streams(0.5))
    assert am.events[-1].action == "keep"
    am.step(5, _streams(0.5))             # deadline reached: adopt
    assert am.events[-1].action == "replan"
    assert am.current.hourly_cost < expensive


# ----------------------------------------------------------------- forecaster

def _tiny_fleet() -> DiurnalFleet:
    # one stream per (program, camera) class, so class means are exact
    return DiurnalFleet((CameraSpec("a", "nyc", "ZF", 0.5, 4.0),
                         CameraSpec("b", "london", "ZF", 0.3, 2.0),
                         CameraSpec("c", "nyc", "VGG16", 0.1, 1.5)))


def test_forecaster_reproduces_pure_seasonal_exactly():
    """Two identical days through a daily-period forecaster: every bucket
    holds two equal observations, so the fitted mean — and therefore the
    forecast — equals the demand exactly, and every residual is 0.0."""
    demand = _tiny_fleet()
    fc = SeasonalForecaster(period_h=24.0)
    fc.warmup(demand, 48.0)
    assert all(r == 0.0 for r in fc._resid.values())
    # forecasts queried on the observation grid (bucket granularity is the
    # model's resolution — off-grid hours forecast their bucket's value)
    for t in (0.0, 5.0, 13.0, 23.0):
        cols = demand.columns_at(t)
        pred, known = fc.forecast_fps(t, cols)
        assert known.all()
        np.testing.assert_array_equal(pred, np.asarray(cols.fps))
        assert fc.coverage(t, cols) == 1.0


def test_forecaster_residuals_stay_near_zero_on_repeats():
    demand = _tiny_fleet()
    fc = SeasonalForecaster(period_h=24.0)
    fc.warmup(demand, 24.0 * 5)           # five identical days
    scale = max(float(np.max(demand.columns_at(t).fps))
                for t in range(24)) or 1.0
    assert all(abs(r) <= 1e-12 * scale for r in fc._resid.values())


def test_forecaster_cold_start_falls_back_to_current():
    fc = SeasonalForecaster()
    streams = [Stream(stream_id="x", program=PROGRAMS["ZF"], fps=3.3)]
    pred, known = fc.forecast_fps(5.0, streams)
    assert not known.any()
    assert pred[0] == 3.3
    assert fc.coverage(5.0, streams) == 0.0


def test_forecaster_object_and_columnar_paths_agree():
    demand = _tiny_fleet()
    fc_cols = SeasonalForecaster(period_h=24.0)
    fc_objs = SeasonalForecaster(period_h=24.0)
    for t in range(24):
        fc_cols.observe(float(t), demand.columns_at(float(t)))
        fc_objs.observe(float(t), list(demand.streams_at(float(t))))
    for t in (2.0, 11.0, 19.0):
        cols = demand.columns_at(t)
        objs = list(demand.streams_at(t))
        pc, kc = fc_cols.forecast_fps(t, cols)
        po, ko = fc_objs.forecast_fps(t, objs)
        order = np.argsort([s.stream_id for s in objs])
        corder = np.argsort(list(cols.ids))
        np.testing.assert_allclose(np.asarray(pc)[corder], po[order],
                                   rtol=1e-12)
        assert kc.all() and ko.all()


def test_forecaster_live_scale_tracks_hotter_day():
    class Hub:
        def __init__(self):
            self.fns = []

        def subscribe(self, fn):
            self.fns.append(fn)

    class Point:
        def __init__(self, t, name, value):
            self.t, self.name, self.value = t, name, value

    fc = SeasonalForecaster(period_h=24.0)
    demand = _tiny_fleet()
    fc.warmup(demand, 24.0)
    hub = Hub()
    fc.attach_hub(hub)
    # day 1 through the hub primes the fleet curve (each bucket's first
    # observation has nothing to compare against, so the scale stays 1.0);
    # day 2 runs 1.5x hot and the live scale follows
    base = [float(np.asarray(demand.columns_at(float(t)).fps).sum())
            for t in range(24)]
    for t in range(7):
        for fn in hub.fns:
            fn(Point(float(t), "fleet.frames.demanded", base[t] * 3600.0))
    assert fc.live_scale() == 1.0
    for t in range(24, 31):
        for fn in hub.fns:
            fn(Point(float(t), "fleet.frames.demanded",
                     base[t % 24] * 1.5 * 3600.0))
    assert fc.live_scale() == pytest.approx(1.5)


# ------------------------------------------------------------------------ MPC

def test_mpc_envelope_never_below_current_demand():
    sc = follow_the_sun(24)
    fc = SeasonalForecaster()
    fc.warmup(sc.demand, 24.0)
    pol = MPCPolicy(ResourceManager(sc.catalog()), forecaster=fc)
    for t in (0.0, 6.0, 7.0, 12.0, 18.0, 23.0):
        cols = sc.demand.columns_at(t)
        cur = np.asarray(cols.fps)
        for lead in (0.0, 1.0, 2.0):
            env, n_pre = pol._envelope(t, cols, cur, lead)
            assert (env >= cur).all()
            # bounded by the feasibility caps (above current demand)
            caps = pol._caps(cols)
            assert (env <= np.maximum(caps, cur) + 1e-9).all()
            assert n_pre == int(np.count_nonzero(env > cur + 1e-9))
            if lead == 0.0:
                assert n_pre == 0 and (env == cur).all()


def test_mpc_cold_start_is_bit_identical_to_reactive():
    """With a cold forecaster the envelope degenerates to current demand;
    configured at the reactive policy's own hysteresis/cadence the whole
    run must be bit-identical to ``ReactivePolicy``."""
    sc = rush_hour(36)
    cat = sc.catalog()
    led_r = FleetSimulator(sc.demand, ReactivePolicy(ResourceManager(cat)),
                           cat, sc.config).run()
    pol = MPCPolicy(ResourceManager(cat),
                    config=MPCConfig(savings_threshold=0.10,
                                     cadence_candidates=(1.0,)))
    led_m = FleetSimulator(sc.demand, pol, cat, sc.config).run()
    assert led_m.signature() == led_r.signature()
    assert led_m.totals()["preboots"] == 0


def test_mpc_nonspot_exposes_no_bids():
    """Regression: a non-None ``bids`` attribute flips the cluster into
    market-aware reconciliation (no ``spot_fraction`` booking), silently
    repricing a pure on-demand policy's whole fleet."""
    pol = MPCPolicy(ResourceManager(fig6_catalog()))
    assert pol.bids is None
    spot = MPCPolicy(ResourceManager(fig6_catalog()), spot=True)
    assert spot.bids == {}


def test_mpc_warm_run_prebooks_and_resets_per_run():
    sc = follow_the_sun(24)
    cat = sc.catalog()
    fc = SeasonalForecaster()
    fc.warmup(sc.demand, 24.0)
    pol = MPCPolicy(ResourceManager(cat), forecaster=fc,
                    config=MPCConfig(slo_floor=0.999))
    led1 = FleetSimulator(sc.demand, pol, cat, sc.config).run()
    assert led1.totals()["preboots"] > 0
    # forecast error was scored against realized demand at least once
    assert led1.totals()["forecast_max_rel_error"] >= 0.0
    led2 = FleetSimulator(sc.demand, pol, cat, sc.config).run()
    assert led2.signature() == led1.signature()


# ------------------------------------------------ property-style invariants

def _random_fps_cases():
    rng = random.Random(7)
    return [[round(rng.uniform(0.1, 8.0), 3) for _ in range(5)]
            for _ in range(20)]


if HAVE_HYPOTHESIS:
    @given(st.lists(st.floats(min_value=0.1, max_value=8.0,
                              allow_nan=False), min_size=1, max_size=8))
    @settings(max_examples=50, deadline=None)
    def test_forecaster_constant_demand_is_forecast_verbatim(fps):
        _check_constant_demand(fps)
else:
    @pytest.mark.parametrize("fps", _random_fps_cases())
    def test_forecaster_constant_demand_is_forecast_verbatim(fps):
        _check_constant_demand(fps)


def _check_constant_demand(fps):
    """Constant per-class demand observed twice forecasts verbatim (two
    equal observations average exactly), for any rates."""
    streams = [Stream(stream_id=f"s{i}", program=PROGRAMS["ZF"], fps=f,
                      camera=f"cam{i}")
               for i, f in enumerate(fps)]
    fc = SeasonalForecaster(period_h=24.0)
    fc.observe(3.0, streams)
    fc.observe(27.0, streams)
    pred, known = fc.forecast_fps(51.0, streams)
    assert known.all()
    assert pred.tolist() == [s.fps for s in streams]
