"""The HLO analyzer must account for scan (while-loop) trip counts — the
whole point of replacing XLA's cost_analysis, which counts loop bodies once."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.launch.hlo_analysis import analyze_hlo


def _compiled_text(f, *args):
    return jax.jit(f).lower(*args).compile().as_text()


def test_plain_matmul_flops():
    a = jnp.zeros((64, 128), jnp.float32)
    b = jnp.zeros((128, 32), jnp.float32)
    txt = _compiled_text(lambda x, y: x @ y, a, b)
    got = analyze_hlo(txt)["flops_per_device"]
    want = 2 * 64 * 32 * 128
    assert got == pytest.approx(want, rel=0.01)


def test_scan_multiplies_flops_by_trips():
    a = jnp.zeros((64, 64), jnp.float32)

    def f(x):
        def body(c, _):
            return c @ a, None
        out, _ = jax.lax.scan(body, x, None, length=10)
        return out

    txt = _compiled_text(f, jnp.ones((64, 64), jnp.float32))
    got = analyze_hlo(txt)["flops_per_device"]
    want = 10 * 2 * 64 * 64 * 64
    assert got == pytest.approx(want, rel=0.05)


def test_nested_scans_compose():
    a = jnp.zeros((32, 32), jnp.float32)

    def f(x):
        def outer(c, _):
            def inner(ci, _):
                return ci @ a, None
            ci, _ = jax.lax.scan(inner, c, None, length=4)
            return ci, None
        out, _ = jax.lax.scan(outer, x, None, length=3)
        return out

    txt = _compiled_text(f, jnp.ones((32, 32), jnp.float32))
    got = analyze_hlo(txt)["flops_per_device"]
    want = 3 * 4 * 2 * 32 * 32 * 32
    assert got == pytest.approx(want, rel=0.05)


def test_layer_count_now_scales_flops():
    """Regression for the bug that motivated the analyzer: with scan-over-
    layers, 2x layers must give ~2x flops."""
    w = jnp.zeros((2, 64, 64), jnp.float32)    # 2 stacked layers
    w8 = jnp.zeros((8, 64, 64), jnp.float32)   # 8 stacked layers

    def run(ws, x):
        def body(c, wi):
            return jnp.tanh(c @ wi), None
        out, _ = jax.lax.scan(body, x, ws)
        return out

    x = jnp.ones((64, 64), jnp.float32)
    f2 = analyze_hlo(_compiled_text(run, w, x))["flops_per_device"]
    f8 = analyze_hlo(_compiled_text(run, w8, x))["flops_per_device"]
    assert f8 == pytest.approx(4 * f2, rel=0.05)


def test_bytes_grow_with_trips():
    a = jnp.ones((256, 256), jnp.float32)

    def f(x, n):
        def body(c, _):
            return c * 1.5, None
        out, _ = jax.lax.scan(body, x, None, length=n)
        return out

    b4 = analyze_hlo(_compiled_text(lambda x: f(x, 4), a))["bytes_per_device"]
    b16 = analyze_hlo(_compiled_text(lambda x: f(x, 16), a))["bytes_per_device"]
    assert b16 > 2.5 * b4
