"""Vectorized-vs-scalar parity: the packed planning path changes nothing.

The packed (columnwise) ``build_problem``, the array-based FFD, and the
batched demand evaluation are pure performance refactors — every test here
asserts *bit-identical* outputs against the scalar (pre-refactor) path,
which stays reachable through ``repro.core.packed.scalar_mode()``:

* problems: same choices, same item keys, same requirement tuples;
* plans: same bins (choice key + member keys, in order) at the same cost,
  for fresh FFD, for the repair planner's seeded-bins delta pass, and for
  randomized fleets (hypothesis when available, seeded fallback otherwise);
* demand: ``DiurnalFleet`` batched evaluation emits identical streams,
  and ``PipelineFleet`` (content-aware stage emission, with and without
  crop consolidation) emits identical stage items at every hour;
* ledgers: full seeded ``rush_hour`` and ``spot_heavy`` simulation runs
  produce identical per-tick records and totals — and so do the pipeline
  scenarios ``roi_day`` and ``consolidated_city``, whose demand items are
  *stages* (``sid::stage`` / ``pool::...#k``), not streams.
"""
import numpy as np
import pytest

try:
    import hypothesis.strategies as st
    from hypothesis import given, settings
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

from repro.core import ResourceManager, Stream, fig6_catalog, validate
from repro.core import geo
from repro.core import packed
from repro.core.repair import RepairConfig, repair_plan
from repro.core.strategies import build_problem, ffd_greedy
from repro.core.workload import PROGRAMS
from repro.sim import FleetSimulator, ReactivePolicy, RepairPolicy, SCENARIOS

CAMERAS = tuple(sorted(geo.CAMERAS))
CATALOG = fig6_catalog()


def _plan_sig(plan):
    return plan.signature()


def _random_fleet(rng, n: int) -> list[Stream]:
    out = []
    for i in range(n):
        cam = CAMERAS[int(rng.integers(0, len(CAMERAS)))]
        if rng.random() < 0.25:
            fps = round(float(rng.uniform(0.1, 1.5)), 3)
            out.append(Stream(f"vgg-{i}", PROGRAMS["VGG16"], fps, camera=cam))
        else:
            fps = round(float(rng.uniform(0.2, 6.0)), 3)
            out.append(Stream(f"zf-{i}", PROGRAMS["ZF"], fps, camera=cam))
    return out


# -- problem construction ----------------------------------------------------

def test_packed_problem_matches_scalar_itemwise():
    streams = _random_fleet(np.random.default_rng(0), 60)
    pa = build_problem(streams, CATALOG, rtt_filter=True, packed=True)
    pb = build_problem(streams, CATALOG, rtt_filter=True, packed=False)
    assert [c.key for c in pa.choices] == [c.key for c in pb.choices]
    for ia, ib in zip(pa.items, pb.items):
        assert ia.key == ib.key
        assert tuple(ia.requirements) == tuple(ib.requirements)


def test_packed_problem_shares_class_tuples():
    """Items of one (program, fps, camera) class share one requirements
    tuple — the O(classes x choices) construction the packed path relies on."""
    streams = [Stream(f"s{i}", PROGRAMS["ZF"], 2.0, camera="nyc")
               for i in range(5)]
    p = build_problem(streams, CATALOG, rtt_filter=True)
    assert packed.get_packed(p) is not None
    first = p.items[0].requirements
    assert all(it.requirements is first for it in p.items[1:])


def test_packed_problem_respects_target_fps_and_filters():
    streams = _random_fleet(np.random.default_rng(1), 30)
    for kw in ({"target_fps": 1.0, "rtt_filter": True},
               {"gpu_only": True}, {"cpu_only": True},
               {"locations": ["us-east-1", "eu-west-1"]}):
        pa = build_problem(streams, CATALOG, packed=True, **kw)
        pb = build_problem(streams, CATALOG, packed=False, **kw)
        assert [c.key for c in pa.choices] == [c.key for c in pb.choices]
        assert all(tuple(a.requirements) == tuple(b.requirements)
                   for a, b in zip(pa.items, pb.items))


# -- FFD plans ---------------------------------------------------------------

def _assert_ffd_parity(streams):
    plan_p = ffd_greedy(streams, CATALOG)
    with packed.scalar_mode():
        plan_s = ffd_greedy(streams, CATALOG)
    validate(plan_p.problem, plan_p.solution)
    assert _plan_sig(plan_p) == _plan_sig(plan_s)


def test_ffd_parity_seeded_fleets():
    for seed in range(8):
        rng = np.random.default_rng(seed)
        _assert_ffd_parity(_random_fleet(rng, int(rng.integers(5, 120))))


def test_ffd_parity_equal_size_interleaved_classes():
    """Night-time degenerate order: many cameras at the same base rate give
    thousands of equal-norm-size single-item runs — the case the opening
    rule compresses by requirement group."""
    streams = [Stream(f"s{i}", PROGRAMS["ZF"], 0.2,
                      camera=CAMERAS[i % len(CAMERAS)]) for i in range(96)]
    _assert_ffd_parity(streams)


def test_repair_delta_parity_seeded():
    """The repair planner's seeded-bins FFD delta pass (kept bins first,
    then new) is bit-identical packed vs scalar, including its ledger."""
    rng = np.random.default_rng(3)
    before = _random_fleet(rng, 80)
    after = before[10:] + _random_fleet(np.random.default_rng(4), 15)
    cfg = RepairConfig(migration_budget=8, defrag_ratio=1.25)

    prev_p = ffd_greedy(before, CATALOG)
    res_p = repair_plan(after, CATALOG, previous=prev_p, config=cfg)
    with packed.scalar_mode():
        prev_s = ffd_greedy(before, CATALOG)
        res_s = repair_plan(after, CATALOG, previous=prev_s, config=cfg)
    assert _plan_sig(res_p.plan) == _plan_sig(res_s.plan)
    assert (res_p.migrations, res_p.evicted, res_p.consolidated,
            res_p.arrivals, res_p.departures, res_p.kept, res_p.defrag) == \
           (res_s.migrations, res_s.evicted, res_s.consolidated,
            res_s.arrivals, res_s.departures, res_s.kept, res_s.defrag)


if HAVE_HYPOTHESIS:
    @settings(max_examples=25, deadline=None)
    @given(st.integers(min_value=0, max_value=10_000),
           st.integers(min_value=1, max_value=150))
    def test_ffd_parity_property(seed, n):
        _assert_ffd_parity(_random_fleet(np.random.default_rng(seed), n))


# -- batched demand ----------------------------------------------------------

def test_batched_demand_matches_scalar():
    sc = SCENARIOS["mega_city"](n_streams=200)
    for t in np.arange(0.0, 24.0, 1.5):
        a = sc.demand.streams_at(float(t))
        with packed.scalar_mode():
            b = sc.demand.streams_at(float(t))
        assert a == b


@pytest.mark.parametrize("name", ["roi_day", "consolidated_city"])
def test_pipeline_batched_demand_matches_scalar(name):
    """PipelineFleet's columnar stage emission (activation arrays, pooled
    chunk split) equals the scalar per-camera loop item for item — ids,
    programs, and milli-fps rates — at every hour, pooling included."""
    sc = SCENARIOS[name](n_streams=60)
    for t in np.arange(0.0, 24.0, 1.5):
        a = sc.demand.streams_at(float(t))
        with packed.scalar_mode():
            b = sc.demand.streams_at(float(t))
        assert a == b


def test_pipeline_stage_ffd_parity():
    """FFD over stage items (including multi-chunk pools at peak density)
    is bit-identical packed vs scalar — stage requirement classes factor
    through the same ``class_requirement_columns`` path as streams."""
    for name, t_h in (("roi_day", 8.5), ("consolidated_city", 17.5),
                      ("consolidated_city", 3.0)):
        sc = SCENARIOS[name](n_streams=48)
        _assert_ffd_parity(sc.demand.streams_at(t_h))


# -- end-to-end ledgers ------------------------------------------------------

def _ledger_sig(ledger):
    return ledger.signature()


def _run_scenario(name, policy_cls, n_streams=48):
    sc = SCENARIOS[name](n_streams=n_streams)
    cat = sc.catalog()
    policy = policy_cls(ResourceManager(cat))
    return FleetSimulator(sc.demand, policy, cat, sc.config).run()


@pytest.mark.parametrize("name,policy_cls", [
    ("rush_hour", ReactivePolicy),
    ("spot_heavy", ReactivePolicy),
    ("spot_heavy", RepairPolicy),
    ("roi_day", ReactivePolicy),
    ("consolidated_city", ReactivePolicy),
])
def test_ledger_parity_seeded_runs(name, policy_cls):
    led_p = _run_scenario(name, policy_cls)
    with packed.scalar_mode():
        led_s = _run_scenario(name, policy_cls)
    assert _ledger_sig(led_p) == _ledger_sig(led_s)


def test_mega_city_scenario_smoke():
    """mega_city is registered, spans >= 6 regions, and a small instance of
    it simulates cleanly on the packed path with frames conserved."""
    sc = SCENARIOS["mega_city"](n_streams=120, duration_h=6.0)
    streams = sc.demand.streams_at(12.0)
    regions = {geo.nearest_region(s.camera, CATALOG.locations)
               for s in streams}
    assert len(regions) >= 6
    led = _run_scenario("mega_city", ReactivePolicy, n_streams=120)
    assert all(abs(r.frames_demanded - r.frames_analyzed - r.frames_dropped)
               < 1e-6 for r in led.records)
    assert led.slo_attainment() > 0.9
