"""Exporter bridge: JSONL metric export, Chrome-trace export, and the
Counter/Gauge/Histogram aggregation layer — every round trip lossless."""
import io
import json

import pytest

from repro.obs import (Counter, Gauge, Histogram, JsonlMetricExporter,
                       MetricAggregator, TelemetryHub, Tracer, chrome_trace,
                       hub_with_exporters, load_jsonl_metrics,
                       spans_from_chrome_trace, write_chrome_trace)


# -- JSONL metric export -----------------------------------------------------

def _emit_some(hub):
    hub.emit(0.0, "fleet.cost.usd", 12.5)
    hub.emit(0.5, "drift.rel_error", 1 / 3, region="ap-northeast-1")
    hub.emit(1.0, "fleet.slo", 0.987654321012345678)   # needs full precision
    hub.emit(1.0, "fleet.instances.live", 7.0, market="spot", region="x")


def test_jsonl_export_roundtrips_exactly(tmp_path):
    path = tmp_path / "metrics.jsonl"
    hub = TelemetryHub()
    exporter = JsonlMetricExporter(path)
    hub.subscribe(exporter)
    _emit_some(hub)
    exporter.close()
    assert exporter.written == 4
    # bit-exact round trip, attrs included
    assert load_jsonl_metrics(path) == hub.points
    # and the file is plain JSONL any external tool can read
    rows = [json.loads(line) for line in path.read_text().splitlines()]
    assert rows[1]["attrs"] == {"region": "ap-northeast-1"}
    assert rows[2]["value"] == 0.987654321012345678


def test_jsonl_export_is_incremental_and_takes_file_objects():
    buf = io.StringIO()
    hub = TelemetryHub()
    hub.subscribe(JsonlMetricExporter(buf))
    hub.emit(0.0, "a", 1.0)
    # already on the sink after one emit — no buffering, tail-able mid-run
    assert buf.getvalue().count("\n") == 1
    hub.emit(1.0, "b", 2.0)
    assert load_jsonl_metrics(io.StringIO(buf.getvalue())) == hub.points


def test_jsonl_exporter_context_manager_closes_owned_file(tmp_path):
    path = tmp_path / "m.jsonl"
    hub = TelemetryHub()
    with JsonlMetricExporter(path) as exporter:
        hub.subscribe(exporter)
        hub.emit(0.0, "a", 1.0)
    assert exporter._fh.closed
    # a closed sink raises inside the subscriber; the hub isolates it
    hub.emit(1.0, "b", 2.0)
    assert len(hub.subscriber_failures) == 1
    assert len(hub.points) == 2


# -- Chrome-trace export -----------------------------------------------------

def _traced():
    tr = Tracer()
    with tr.span("recalibrate", t=14.0, regions="ap-northeast-1") as sp:
        with tr.span("replan.decide", t=14.0) as inner:
            inner.attrs["action"] = "forced-replan"
            inner.attrs["migrations"] = 8
        sp.attrs["plan_cost_usd_per_h"] = 36.7
    with tr.span("replan.decide", t=15.0):
        pass
    return tr


def _spans_equal(a, b):
    return (a.name == b.name and a.t == b.t and a.wall_ms == b.wall_ms
            and a.attrs == b.attrs and len(a.children) == len(b.children)
            and all(_spans_equal(x, y)
                    for x, y in zip(a.children, b.children)))


def test_chrome_trace_roundtrips_span_trees(tmp_path):
    tr = _traced()
    path = tmp_path / "trace.json"
    n_events = write_chrome_trace(path, tr)
    assert n_events == 6                       # 3 spans x paired B/E
    rebuilt = spans_from_chrome_trace(path)
    assert len(rebuilt) == len(tr.spans)
    assert all(_spans_equal(x, y) for x, y in zip(rebuilt, tr.spans))


def test_chrome_trace_event_stream_is_viewer_valid():
    doc = chrome_trace(_traced())
    events = doc["traceEvents"]
    assert doc["displayTimeUnit"] == "ms"
    # B/E discipline: nesting balanced, timestamps monotone per track,
    # children contained within their parent's [B, E] window
    stack = []
    for e in events:
        if e["ph"] == "B":
            if stack:
                assert e["ts"] >= stack[-1][1]           # starts after parent
            stack.append((e["name"], e["ts"]))
        else:
            name, ts_b = stack.pop()
            assert name == e["name"]
            assert e["ts"] >= ts_b
    assert not stack
    # exact values ride in args, not in the synthesized timeline
    begins = [e["args"] for e in events if e["ph"] == "B"]
    assert begins[0]["t"] == 14.0                         # recalibrate
    assert begins[1]["attrs"]["migrations"] == 8          # nested replan


def test_chrome_trace_reader_rejects_unbalanced_documents():
    doc = chrome_trace(_traced())
    with pytest.raises(ValueError, match="unbalanced"):
        spans_from_chrome_trace({"traceEvents": doc["traceEvents"][:-1]})
    swapped = {"traceEvents": [
        {"ph": "B", "name": "a", "args": {}},
        {"ph": "E", "name": "b"}]}
    with pytest.raises(ValueError, match="unbalanced"):
        spans_from_chrome_trace(swapped)


# -- aggregation layer -------------------------------------------------------

def test_histogram_percentiles_are_exact_nearest_rank():
    h = Histogram("replan.wall_ms")
    assert h.percentile(0.5) is None
    for v in [5.0, 1.0, 9.0, 3.0, 7.0]:        # unsorted on purpose
        h.observe(v)
    assert h.percentile(0.0) == 1.0
    assert h.percentile(0.5) == 5.0
    assert h.percentile(1.0) == 9.0
    s = h.summary()
    assert s["count"] == 5 and s["min"] == 1.0 and s["max"] == 9.0
    assert s["mean"] == pytest.approx(5.0)
    assert s["p50"] == 5.0 and s["p99"] == 9.0


def test_counter_and_gauge_semantics():
    c = Counter("fleet.preemptions")
    c.observe(2.0)
    c.observe(3.0)
    assert c.summary() == {"kind": "counter", "total": 5.0, "points": 2}
    g = Gauge("fleet.instances.live")
    g.observe(4.0, t=0.0)
    g.observe(6.0, t=1.0)
    assert g.summary() == {"kind": "gauge", "value": 6.0, "t": 1.0,
                           "points": 2}


def test_aggregator_routes_by_name_and_rejects_type_conflicts():
    hub = TelemetryHub()
    agg = MetricAggregator(hub)
    hist = agg.histogram("replan.wall_ms")
    gauge = agg.gauge("fleet.slo")
    hub.emit(0.0, "replan.wall_ms", 4.0)
    hub.emit(0.0, "fleet.slo", 0.99)
    hub.emit(0.0, "unregistered.metric", 1.0)   # passes through untouched
    hub.emit(1.0, "replan.wall_ms", 8.0)
    assert hist.values == [4.0, 8.0]
    assert gauge.value == 0.99
    # re-registering the same kind returns the same instrument
    assert agg.histogram("replan.wall_ms") is hist
    with pytest.raises(ValueError, match="already registered"):
        agg.counter("replan.wall_ms")
    summary = agg.summary()
    assert set(summary) == {"replan.wall_ms", "fleet.slo"}
    assert summary["replan.wall_ms"]["p50"] == 4.0   # nearest rank of 2
    assert summary["replan.wall_ms"]["p99"] == 8.0


def test_hub_with_exporters_wiring(tmp_path):
    path = tmp_path / "m.jsonl"
    hub, exporter, agg = hub_with_exporters(path)
    hub.emit(0.0, "replan.wall_ms", 2.5)
    hub.emit(0.0, "fleet.slo", 0.9)
    exporter.close()
    assert load_jsonl_metrics(path) == hub.points
    assert agg.instruments["replan.wall_ms"].values == [2.5]
    # no path: aggregation only
    hub2, exporter2, agg2 = hub_with_exporters(None, histograms=("x",))
    assert exporter2 is None
    hub2.emit(0.0, "x", 1.0)
    assert agg2.instruments["x"].values == [1.0]
