"""Exact reproduction of Fig. 3 (the paper's central table): all nine cells —
instance counts, dollar figures, and the Fail — plus the derived savings
(61% / 36% / 3%) and the >50% headline claim."""
import pytest

from repro.core import (FIG3_SCENARIOS, ResourceManager, fig3_catalog,
                        make_streams)

EXPECTED = {
    # (scenario, strategy): (cost, non_gpu, gpu)  — None = Fail
    (1, "ST1"): (1.676, 4, 0),
    (1, "ST2"): (0.650, 0, 1),
    (1, "ST3"): (0.650, 0, 1),
    (2, "ST1"): (0.419, 1, 0),
    (2, "ST2"): (0.650, 0, 1),
    (2, "ST3"): (0.419, 1, 0),
    (3, "ST1"): None,
    (3, "ST2"): (7.150, 0, 11),
    (3, "ST3"): (6.919, 1, 10),
}


@pytest.fixture(scope="module")
def manager():
    return ResourceManager(fig3_catalog())


@pytest.mark.parametrize("scenario,strategy", sorted(EXPECTED))
def test_fig3_cell(manager, scenario, strategy):
    streams = make_streams(FIG3_SCENARIOS[scenario])
    plan = manager.plan_or_fail(streams, strategy)
    expected = EXPECTED[(scenario, strategy)]
    if expected is None:
        assert plan is None, "scenario 3 must be infeasible on CPUs only"
        return
    cost, n_cpu, n_gpu = expected
    s = plan.summary()
    assert s["hourly_cost"] == pytest.approx(cost, abs=1e-3)
    assert s["non_gpu_instances"] == n_cpu
    assert s["gpu_instances"] == n_gpu
    assert s["optimal"], "paper-scale instances must be solved to optimality"


def test_savings_match_paper(manager):
    # scenario 1: ST3 saves 61% vs ST1
    s1 = make_streams(FIG3_SCENARIOS[1])
    st1 = manager.plan(s1, "ST1").hourly_cost
    st3 = manager.plan(s1, "ST3").hourly_cost
    assert round(100 * (1 - st3 / st1)) == 61
    # scenario 2: ST3 saves 36% vs ST2
    s2 = make_streams(FIG3_SCENARIOS[2])
    st2 = manager.plan(s2, "ST2").hourly_cost
    st3 = manager.plan(s2, "ST3").hourly_cost
    assert round(100 * (1 - st3 / st2)) == 36
    # scenario 3: ST3 saves 3% vs ST2
    s3 = make_streams(FIG3_SCENARIOS[3])
    st2 = manager.plan(s3, "ST2").hourly_cost
    st3 = manager.plan(s3, "ST3").hourly_cost
    assert round(100 * (1 - st3 / st2)) == 3


def test_headline_over_50_percent(manager):
    """'Experiments demonstrate more than 50% cost reduction.'"""
    s1 = make_streams(FIG3_SCENARIOS[1])
    st1 = manager.plan(s1, "ST1").hourly_cost
    st3 = manager.plan(s1, "ST3").hourly_cost
    assert 1 - st3 / st1 > 0.50


def test_summary_gpu_classification_uses_catalog_not_names():
    """Regression: _key_is_gpu must read the catalog's has_gpu flag. The old
    name-prefix heuristic (startswith("g"/"p"/"NC")) called a CPU type named
    "granite.2xl" a GPU and a GPU type named "accel.xl" a CPU."""
    from repro.core import Catalog, InstanceType, Stream
    from repro.core.workload import PROGRAMS

    adversarial = Catalog(types=(
        InstanceType("granite.2xl", (8.0, 15.0, 0.0, 0.0),
                     {"us-east-1": 0.419}, has_gpu=False),
        InstanceType("accel.xl", (8.0, 15.0, 1.0, 4.0),
                     {"us-east-1": 0.650}, has_gpu=True),
    ))
    mgr = ResourceManager(adversarial)
    # the ZF stream at 8 fps only fits the GPU type; the VGG16 stream no
    # longer fits that instance's remaining GPU memory, and a CPU instance
    # is cheaper than opening a second GPU — the optimal plan uses one each
    streams = [Stream("cpu-cam", PROGRAMS["VGG16"], fps=0.4),
               Stream("gpu-cam", PROGRAMS["ZF"], fps=8.0)]
    s = mgr.plan(streams, "ST3").summary()
    assert s["gpu_instances"] == 1
    assert s["non_gpu_instances"] == 1


def test_gpu_speedup_claims():
    """GPU accelerates up to ~16x at high frame rates; <5% at the lowest."""
    from repro.core.workload import ZF, VGG16
    assert 15.0 <= ZF.max_gpu_fps() / ZF.max_cpu_fps(7.2) <= 17.0
    assert ZF.gpu_speedup(0.2) - 1.0 < 0.05          # low fps: <5% benefit
    assert VGG16.gpu_speedup(0.25) - 1.0 < 0.05
    assert ZF.gpu_speedup(16.0) > 15.0               # high fps: ~16x
