"""Per-architecture smoke tests (REDUCED variants, CPU): one train step with
finite loss + correct shapes; prefill+decode consistency for decoders."""
import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.data.pipeline import InputShape, make_batch
from repro.models import layers
from repro.models import model as M
from repro.models.config import get_config, list_archs
from repro.models.steps import (TrainOptions, decode_step, init_train_state,
                                prefill_step, train_step)

ARCHS = list_archs()
KEY = jax.random.PRNGKey(0)

# heaviest reduced configs on CPU (deep block patterns / MoE dispatch);
# their train-step parametrizations run under -m slow
HEAVY_ARCHS = {"recurrentgemma-9b", "grok-1-314b", "mamba2-2.7b",
               "moonshot-v1-16b-a3b", "hubert-xlarge", "qwen3-moe-30b-a3b"}


def _arch_params(archs, heavy=HEAVY_ARCHS):
    return [pytest.param(a, marks=pytest.mark.slow) if a in heavy else a
            for a in archs]


def test_all_ten_archs_registered():
    assert len(ARCHS) == 10
    expected = {"mamba2-2.7b", "recurrentgemma-9b", "internvl2-1b",
                "qwen3-moe-30b-a3b", "yi-9b", "nemotron-4-15b",
                "hubert-xlarge", "moonshot-v1-16b-a3b", "olmo-1b",
                "grok-1-314b"}
    assert set(ARCHS) == expected


@pytest.mark.parametrize("arch", ARCHS)
def test_reduced_constraints(arch):
    cfg = get_config(arch, reduced=True)
    assert cfg.num_layers <= 3
    assert cfg.d_model <= 512
    assert cfg.num_experts <= 4


@pytest.mark.parametrize("arch", ARCHS)
def test_full_config_sizes(arch):
    """Full configs match the assignment (spot totals per arch)."""
    cfg = get_config(arch)
    expected = {
        "mamba2-2.7b": (64, 2560, 50280), "recurrentgemma-9b": (38, 4096, 256000),
        "internvl2-1b": (24, 896, 151655), "qwen3-moe-30b-a3b": (48, 2048, 151936),
        "yi-9b": (48, 4096, 64000), "nemotron-4-15b": (32, 6144, 256000),
        "hubert-xlarge": (48, 1280, 504), "moonshot-v1-16b-a3b": (48, 2048, 163840),
        "olmo-1b": (16, 2048, 50304), "grok-1-314b": (64, 6144, 131072),
    }[arch]
    assert (cfg.num_layers, cfg.d_model, cfg.vocab_size) == expected


def test_param_counts_plausible():
    """Analytic parameter totals land near the models' nameplate sizes."""
    approx = {
        "mamba2-2.7b": (2.3e9, 3.2e9), "yi-9b": (8e9, 10e9),
        "olmo-1b": (1.0e9, 1.4e9), "grok-1-314b": (2.6e11, 3.6e11),
        "qwen3-moe-30b-a3b": (2.6e10, 3.4e10),
        # assignment specifies 48L x 64e x d_ff 1408 -> ~28B total (the HF
        # card's 16B uses 27 layers; we implement the assignment exactly)
        "moonshot-v1-16b-a3b": (2.4e10, 3.2e10),
        "nemotron-4-15b": (1.3e10, 1.8e10),
        "recurrentgemma-9b": (8e9, 11e9), "hubert-xlarge": (0.8e9, 1.3e9),
    }
    for arch, (lo, hi) in approx.items():
        n = get_config(arch).param_count()
        assert lo <= n <= hi, f"{arch}: {n:.2e} not in [{lo:.1e},{hi:.1e}]"


@pytest.mark.parametrize("arch", _arch_params(
    ARCHS, heavy=HEAVY_ARCHS | {"internvl2-1b", "nemotron-4-15b"}))
def test_train_step_smoke(arch):
    """One forward/train step on CPU: output shapes + no NaNs."""
    cfg = get_config(arch, reduced=True)
    opts = M.ModelOptions(remat=False)
    shape = InputShape("smoke", 64, 2, "train")
    batch = make_batch(cfg, shape, seed=0)
    state = init_train_state(cfg, KEY, jnp.float32, TrainOptions())
    step = jax.jit(functools.partial(train_step, cfg=cfg, opts=opts,
                                     topts=TrainOptions()))
    new_state, metrics = step(state, batch)
    assert np.isfinite(float(metrics["loss"]))
    assert np.isfinite(float(metrics["grad_norm"]))
    # params updated and still finite
    leaf = jax.tree.leaves(new_state["params"])[0]
    assert np.isfinite(np.asarray(leaf)).all()


@pytest.mark.parametrize("arch", _arch_params(
    ARCHS, heavy=set(ARCHS) - {"olmo-1b"}))
def test_microbatched_train_matches_shapes(arch):
    cfg = get_config(arch, reduced=True)
    opts = M.ModelOptions(remat=False)
    shape = InputShape("smoke", 64, 4, "train")
    batch = make_batch(cfg, shape, seed=0)
    topts = TrainOptions(microbatches=2)
    state = init_train_state(cfg, KEY, jnp.float32, topts)
    step = jax.jit(functools.partial(train_step, cfg=cfg, opts=opts,
                                     topts=topts))
    _, metrics = step(state, batch)
    assert np.isfinite(float(metrics["loss"]))


@pytest.mark.parametrize("arch", _arch_params(
    [a for a in ARCHS if get_config(a, reduced=True).causal],
    heavy={"recurrentgemma-9b", "grok-1-314b", "qwen3-moe-30b-a3b",
           "mamba2-2.7b", "moonshot-v1-16b-a3b"}))
def test_prefill_decode_consistency(arch):
    """Decode from a prefill cache == full forward (capacity drops disabled)."""
    cfg = dataclasses.replace(get_config(arch, reduced=True),
                              capacity_factor=8.0)
    opts = M.ModelOptions(remat=False)
    params = M.init_params(cfg, KEY, jnp.float32)
    S = 33
    batch = make_batch(cfg, InputShape("t", S, 2, "prefill"), seed=3)

    hidden, _ = M.forward_hidden(params, batch, cfg, opts)
    want = layers.unembed(params["embed"], hidden[:, -1:], cfg)[:, 0]

    if cfg.frontend == "vision":
        pre = {"tokens": batch["tokens"][:, :-1],
               "patch_embeds": batch["patch_embeds"]}
        pos = cfg.num_patches + batch["tokens"].shape[1] - 1
    else:
        pre = {"tokens": batch["tokens"][:, :-1]}
        pos = batch["tokens"].shape[1] - 1
    last_tok = batch["tokens"][:, -1]
    _, cache = M.prefill(params, pre, cfg, opts, cache_len=S + 8)
    got, _ = M.decode_step(params, last_tok, jnp.asarray(pos), cache, cfg, opts)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=5e-4, rtol=5e-4)


def test_sliding_window_ring_cache_matches_full():
    """Dense arch with window_override: ring cache decode == full-cache decode
    with window masking (the long_500k optimized vs baseline paths)."""
    cfg = get_config("yi-9b", reduced=True)
    S, W = 40, 16
    params = M.init_params(cfg, KEY, jnp.float32)
    batch = make_batch(cfg, InputShape("t", S, 2, "prefill"), seed=5)
    pre = {"tokens": batch["tokens"][:, :-1]}
    last = batch["tokens"][:, -1]
    pos = jnp.asarray(S - 1)

    o_full = M.ModelOptions(remat=False, window_override=W, ring_cache=False)
    o_ring = M.ModelOptions(remat=False, window_override=W, ring_cache=True)
    _, c_full = M.prefill(params, pre, cfg, o_full, cache_len=S + 8)
    _, c_ring = M.prefill(params, pre, cfg, o_ring, cache_len=S + 8)
    lf, _ = M.decode_step(params, last, pos, c_full, cfg, o_full)
    lr, _ = M.decode_step(params, last, pos, c_ring, cfg, o_ring)
    np.testing.assert_allclose(np.asarray(lr), np.asarray(lf),
                               atol=5e-4, rtol=5e-4)


@pytest.mark.slow
def test_multi_step_decode_ring():
    """Several consecutive ring-cache decode steps stay consistent with the
    full-cache window decode (single-step variant above runs by default)."""
    cfg = get_config("yi-9b", reduced=True)
    S, W, steps = 24, 8, 6
    params = M.init_params(cfg, KEY, jnp.float32)
    batch = make_batch(cfg, InputShape("t", S, 2, "prefill"), seed=7)
    pre = {"tokens": batch["tokens"]}
    o_full = M.ModelOptions(remat=False, window_override=W, ring_cache=False)
    o_ring = M.ModelOptions(remat=False, window_override=W, ring_cache=True)
    _, c_full = M.prefill(params, pre, cfg, o_full, cache_len=S + steps)
    _, c_ring = M.prefill(params, pre, cfg, o_ring, cache_len=S + steps)
    tok = batch["tokens"][:, -1]
    for i in range(steps):
        pos = jnp.asarray(S + i)
        lf, c_full = M.decode_step(params, tok, pos, c_full, cfg, o_full)
        lr, c_ring = M.decode_step(params, tok, pos, c_ring, cfg, o_ring)
        np.testing.assert_allclose(np.asarray(lr), np.asarray(lf),
                                   atol=1e-3, rtol=1e-3)
        tok = jnp.argmax(lf, -1).astype(jnp.int32)
