"""Location-aware strategies (Fig. 6): NL vs ARMVAC vs GCL."""
import pytest

from repro.core import ResourceManager, Stream, fig6_catalog
from repro.core import geo
from repro.core.workload import PROGRAMS


@pytest.fixture(scope="module")
def setup():
    cat = fig6_catalog()
    mgr = ResourceManager(cat)
    streams = [Stream(f"zf-{c}", PROGRAMS["ZF"], fps=1.0, camera=c)
               for c in geo.CAMERAS]
    return mgr, streams


@pytest.mark.parametrize("fps", [0.2, 1.0, 5.0, 10.0, 20.0])
def test_ordering_gcl_best(setup, fps):
    """GCL <= min(ARMVAC, NL) at every target frame rate (paper Fig. 6)."""
    mgr, streams = setup
    nl = mgr.plan(streams, "NL", target_fps=fps).hourly_cost
    armvac = mgr.plan(streams, "ARMVAC", target_fps=fps).hourly_cost
    gcl = mgr.plan(streams, "GCL", target_fps=fps).hourly_cost
    assert gcl <= armvac + 1e-9
    assert gcl <= nl + 1e-9


def test_gcl_savings_magnitudes(setup):
    """Paper: GCL saves up to 56% vs NL and up to 31% vs ARMVAC, with the
    ARMVAC gap concentrated in the 1-20 fps mid-band."""
    mgr, streams = setup
    best_vs_nl = 0.0
    best_vs_armvac_mid = 0.0
    for fps in (0.2, 1.0, 2.0, 5.0, 10.0):
        nl = mgr.plan(streams, "NL", target_fps=fps).hourly_cost
        armvac = mgr.plan(streams, "ARMVAC", target_fps=fps).hourly_cost
        gcl = mgr.plan(streams, "GCL", target_fps=fps).hourly_cost
        best_vs_nl = max(best_vs_nl, 1 - gcl / nl)
        if 1.0 <= fps <= 20.0:
            best_vs_armvac_mid = max(best_vs_armvac_mid, 1 - gcl / armvac)
    assert best_vs_nl >= 0.50, "headline >50% savings vs nearest-location"
    assert best_vs_armvac_mid >= 0.31, "mid-band gap vs ARMVAC (paper: 31%)"


def test_high_fps_strategies_converge(setup):
    """At high frame rates few locations qualify, so the three strategies
    nearly agree (paper: ARMVAC 'performs well' for >20 fps)."""
    mgr, streams = setup
    nl = mgr.plan(streams, "NL", target_fps=20.0).hourly_cost
    gcl = mgr.plan(streams, "GCL", target_fps=20.0).hourly_cost
    assert (nl - gcl) / nl < 0.10


def test_rtt_feasibility_respected(setup):
    """No stream may be placed outside its RTT circle."""
    mgr, streams = setup
    fps = 10.0
    plan = mgr.plan(streams, "GCL", target_fps=fps)
    for b in plan.solution.bins:
        loc = plan.problem.choices[b.choice].location
        for i in b.items:
            cam = plan.problem.items[i].key.split("-", 1)[1]
            assert geo.max_fps(cam, loc) >= fps


def test_rtt_feasibility_at_exact_boundary():
    """fps * rtt == RTT_BUDGET_MS is feasible (the circle includes its rim);
    any frame rate strictly above it is not."""
    cam, region = "london", "eu-west-1"
    boundary_fps = geo.max_fps(cam, region)
    assert boundary_fps * geo.rtt_ms(cam, region) == pytest.approx(
        geo.RTT_BUDGET_MS)
    regions = list(geo.DATACENTERS)
    assert region in geo.feasible_regions(cam, boundary_fps, regions)
    assert region not in geo.feasible_regions(
        cam, boundary_fps * (1 + 1e-12), regions)


def test_geo_model():
    # nearer datacenter -> lower RTT -> higher achievable fps
    assert geo.rtt_ms("nyc", "us-east-1") < geo.rtt_ms("nyc", "ap-northeast-1")
    assert geo.max_fps("tokyo", "ap-northeast-1") > geo.max_fps("tokyo", "eu-west-1")
    # circles shrink with target fps
    all_regions = list(geo.DATACENTERS)
    low = geo.feasible_regions("london", 0.2, all_regions)
    high = geo.feasible_regions("london", 20.0, all_regions)
    assert set(high) <= set(low)
    assert len(high) < len(low)
