"""Property tests for the min-migration repair planner (core/repair.py).

``hypothesis`` is optional (see DESIGN.md, Testing): when missing, seeded
random fleets below exercise the same invariants. For random fleets, churn
(arrivals, departures, fps drift) and preemption replays:

* repair output is always a valid Plan (``validate`` passes) covering every
  demanded stream — no stream is lost;
* add-only churn moves nothing: arrivals are placed, placements stay put;
* unaffected streams never move (only the perturbed bin's members may);
* repair migrations never exceed the churn a full FFD replan would cause;
* the defrag escape hatch reproduces the fresh FFD plan exactly.
"""
import numpy as np
import pytest

try:
    import hypothesis.strategies as st
    from hypothesis import given, settings
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

from repro.core import (RepairConfig, ResourceManager, Stream,
                        count_plan_migrations, fig6_catalog, plan_assignment,
                        repair_plan, validate)
from repro.core import geo
from repro.core.workload import PROGRAMS

CAMERAS = tuple(sorted(geo.CAMERAS))
CATALOG = fig6_catalog()


def _random_fleet(rng, n: int) -> list[Stream]:
    out = []
    for i in range(n):
        cam = CAMERAS[int(rng.integers(0, len(CAMERAS)))]
        if rng.random() < 0.25:
            fps = round(float(rng.uniform(0.1, 1.5)), 3)
            out.append(Stream(f"vgg-{i}", PROGRAMS["VGG16"], fps, camera=cam))
        else:
            fps = round(float(rng.uniform(0.2, 6.0)), 3)
            out.append(Stream(f"zf-{i}", PROGRAMS["ZF"], fps, camera=cam))
    return out


def _churn(rng, streams: list[Stream], *, drop_p: float, n_add: int,
           drift_p: float) -> list[Stream]:
    import dataclasses
    out = []
    for s in streams:
        if rng.random() < drop_p:
            continue                          # departure
        if rng.random() < drift_p:            # demand drift
            hi = 1.5 if s.program.name == "VGG16" else 6.0
            fps = round(float(np.clip(s.fps * rng.uniform(0.5, 2.0),
                                      0.1, hi)), 3)
            s = dataclasses.replace(s, fps=fps)
        out.append(s)
    base = len(streams)
    for j in range(n_add):
        cam = CAMERAS[int(rng.integers(0, len(CAMERAS)))]
        fps = round(float(rng.uniform(0.2, 4.0)), 3)
        out.append(Stream(f"zf-new-{base + j}", PROGRAMS["ZF"], fps,
                          camera=cam))
    return out


def _check_repair_invariants(seed: int, n: int, drop_p: float, n_add: int,
                             drift_p: float) -> None:
    rng = np.random.default_rng(seed)
    old_streams = _random_fleet(rng, n)
    old = repair_plan(old_streams, CATALOG).plan
    validate(old.problem, old.solution)

    new_streams = _churn(rng, old_streams, drop_p=drop_p, n_add=n_add,
                         drift_p=drift_p)
    if not new_streams:
        return
    res = repair_plan(new_streams, CATALOG, previous=old)

    # valid plan, every stream covered, none lost
    validate(res.plan.problem, res.plan.solution)
    placed = {res.plan.problem.items[i].key
              for b in res.plan.solution.bins for i in b.items}
    assert placed == {s.stream_id for s in new_streams}

    # no bin is packed past its capacity in any dimension
    from repro.core.packing import residuals
    for r in residuals(res.plan.problem, res.plan.solution.bins):
        assert all(v >= -1e-6 for v in r)

    # structural accounting: every stream is kept, evicted, or an arrival;
    # migrations are the final per-stream diff, so an evicted stream packed
    # back where it came from is not a move
    assert res.kept + res.evicted + res.arrivals == len(new_streams)
    assert res.migrations <= res.evicted + res.consolidated

    # repair never churns more than a full FFD replan would
    fresh = repair_plan(new_streams, CATALOG).plan
    ffd_churn = count_plan_migrations(old, fresh)
    assert res.migrations <= ffd_churn, \
        f"repair moved {res.migrations} > full-FFD churn {ffd_churn}"


def _check_add_only_moves_nothing(seed: int, n: int, n_add: int) -> None:
    rng = np.random.default_rng(seed)
    old_streams = _random_fleet(rng, n)
    old = repair_plan(old_streams, CATALOG).plan
    new_streams = _churn(rng, old_streams, drop_p=0.0, n_add=n_add,
                         drift_p=0.0)
    res = repair_plan(new_streams, CATALOG, previous=old)
    assert res.migrations == 0 and res.evicted == 0
    assert res.arrivals == n_add
    before = plan_assignment(old)
    after = plan_assignment(res.plan)
    for s in old_streams:
        assert after[s.stream_id] == before[s.stream_id], \
            f"unaffected stream {s.stream_id} moved"


def test_repair_invariants_seeded():
    for seed in range(20):
        _check_repair_invariants(seed, n=12 + seed % 9, drop_p=0.2,
                                 n_add=3, drift_p=0.5)


def test_add_only_churn_moves_nothing_seeded():
    for seed in range(10):
        _check_add_only_moves_nothing(seed, n=10 + seed, n_add=4)


def test_unaffected_streams_never_move_on_single_overload():
    """Grow one stream until its bin overflows: only members of that bin may
    move; every stream in every other bin keeps its exact placement."""
    import dataclasses
    rng = np.random.default_rng(7)
    streams = _random_fleet(rng, 18)
    old = repair_plan(streams, CATALOG).plan
    before = plan_assignment(old)
    # pick a ZF stream sharing a bin with at least one other stream
    by_bin = {}
    for b in old.solution.bins:
        keys = [old.problem.items[i].key for i in b.items]
        for k in keys:
            by_bin[k] = keys
    victim = next(s for s in streams
                  if s.program.name == "ZF" and len(by_bin[s.stream_id]) > 1)
    bin_members = set(by_bin[victim.stream_id])
    grown = [dataclasses.replace(s, fps=6.0) if s.stream_id == victim.stream_id
             else s for s in streams]
    res = repair_plan(grown, CATALOG, previous=old)
    after = plan_assignment(res.plan)
    for s in streams:
        if s.stream_id not in bin_members:
            assert after[s.stream_id] == before[s.stream_id], \
                f"stream {s.stream_id} outside the overloaded bin moved"


def test_departed_streams_release_capacity_and_bins():
    rng = np.random.default_rng(3)
    streams = _random_fleet(rng, 16)
    old = repair_plan(streams, CATALOG).plan
    survivors = streams[::2]
    res = repair_plan(survivors, CATALOG, previous=old)
    assert res.departures == len(streams) - len(survivors)
    assert res.migrations == 0, "departures alone must not move survivors"
    assert res.plan.hourly_cost <= old.hourly_cost + 1e-9
    placed = {res.plan.problem.items[i].key
              for b in res.plan.solution.bins for i in b.items}
    assert placed == {s.stream_id for s in survivors}


def test_emptied_bin_does_not_count_survivors_as_migrations():
    """Regression: when departures empty a whole bin, the later bins of the
    same choice key shift ordinal — but their streams stay on their
    instances (sticky reconcile), so repair must report zero migrations."""
    streams = [Stream(f"zf-{i}", PROGRAMS["ZF"], fps=5.0, camera="nyc")
               for i in range(18)]
    old = repair_plan(streams, CATALOG).plan
    first_bin = old.solution.bins[0]
    gone = {old.problem.items[i].key for i in first_bin.items}
    assert len(old.solution.bins) > 1, "need several bins of one key"
    survivors = [s for s in streams if s.stream_id not in gone]
    res = repair_plan(survivors, CATALOG, previous=old)
    assert res.departures == len(gone)
    assert res.migrations == 0
    assert res.plan.hourly_cost < old.hourly_cost


def test_defrag_hatch_reproduces_fresh_ffd():
    """defrag_ratio=1.0 forces the hatch whenever repair costs at least the
    fresh plan — the result must be exactly the fresh FFD solution."""
    rng = np.random.default_rng(11)
    streams = _random_fleet(rng, 14)
    old = repair_plan(streams, CATALOG).plan
    shrunk = _churn(rng, streams, drop_p=0.5, n_add=0, drift_p=0.0)
    if not shrunk:
        shrunk = streams[:2]
    res = repair_plan(shrunk, CATALOG, previous=old,
                      config=RepairConfig(defrag_ratio=1.0))
    fresh = repair_plan(shrunk, CATALOG).plan
    assert res.defrag
    assert res.plan.hourly_cost == pytest.approx(fresh.hourly_cost)
    assert plan_assignment(res.plan) == plan_assignment(fresh)


def test_migration_budget_caps_consolidation():
    """After heavy departures the fleet is fragmented; consolidation spends
    at most the budget and every move must reduce cost (bins close)."""
    rng = np.random.default_rng(5)
    streams = _random_fleet(rng, 24)
    old = repair_plan(streams, CATALOG).plan
    survivors = streams[::3]
    free = repair_plan(survivors, CATALOG, previous=old)
    for budget in (0, 2, 6, len(survivors)):
        res = repair_plan(survivors, CATALOG, previous=old,
                          config=RepairConfig(migration_budget=budget))
        assert res.consolidated <= budget
        assert res.migrations <= budget
        assert res.plan.hourly_cost <= free.plan.hourly_cost + 1e-9, \
            "consolidation must never cost more than not consolidating"


def test_repair_strategy_entry_through_resource_manager():
    """STRATEGIES["REPAIR"] plans fresh without a previous plan and repairs
    incrementally when ResourceManager.plan forwards one."""
    rng = np.random.default_rng(9)
    streams = _random_fleet(rng, 10)
    mgr = ResourceManager(CATALOG)
    fresh = mgr.plan(streams, "REPAIR")
    assert fresh.strategy == "REPAIR"
    validate(fresh.problem, fresh.solution)
    grown = streams + [Stream(f"zf-extra-{j}", PROGRAMS["ZF"], fps=1.0,
                              camera=CAMERAS[j]) for j in range(3)]
    repaired = mgr.plan(grown, "REPAIR", previous=fresh)
    validate(repaired.problem, repaired.solution)
    before, after = plan_assignment(fresh), plan_assignment(repaired)
    assert all(after[s.stream_id] == before[s.stream_id] for s in streams)


def test_repair_policy_survives_preemption_storm():
    """End-to-end: repair planning under seeded spot preemptions loses no
    frames (the ledger's conservation check raises otherwise) and records
    fewer migrations than full FFD replanning."""
    from repro.sim import FleetSimulator, ReactivePolicy, RepairPolicy, SCENARIOS
    sc = SCENARIOS["spot_heavy"](n_streams=36, duration_h=12.0, seed=4)
    cat = sc.catalog()
    ffd = FleetSimulator(sc.demand, ReactivePolicy(ResourceManager(cat)),
                         cat, sc.config).run()
    rep = FleetSimulator(sc.demand, RepairPolicy(ResourceManager(cat)),
                         cat, sc.config).run()
    assert rep.preemptions > 0 or ffd.preemptions > 0
    for r in rep.records:
        assert r.frames_demanded == pytest.approx(
            r.frames_analyzed + r.frames_dropped)
    assert rep.migrations < ffd.migrations
    assert rep.slo_attainment() > 0.85


if HAVE_HYPOTHESIS:
    @given(st.integers(0, 10_000), st.integers(6, 24),
           st.floats(0.0, 0.4), st.integers(0, 6), st.floats(0.0, 0.8))
    @settings(max_examples=40, deadline=None)
    def test_repair_invariants(seed, n, drop_p, n_add, drift_p):
        _check_repair_invariants(seed, n, drop_p, n_add, drift_p)

    @given(st.integers(0, 10_000), st.integers(6, 20), st.integers(1, 8))
    @settings(max_examples=30, deadline=None)
    def test_add_only_churn_moves_nothing(seed, n, n_add):
        _check_add_only_moves_nothing(seed, n, n_add)
