"""End-to-end behaviour tests: the full framework flows.

1. manager plans -> engines serve the planned streams -> cost accounted
2. training driver runs N steps and the loss goes down
3. dry-run artifacts complete (the 256/512-device sweep runs via
   python -m repro.launch.dryrun; artifacts land in experiments/)
"""
import json
import os

import numpy as np
import pytest

from repro.core import (FIG3_SCENARIOS, ResourceManager, fig3_catalog,
                        make_streams)
from repro.launch.train import train


def test_end_to_end_plan_then_serve():
    """The paper's loop: resource manager selects instances, streams run."""
    mgr = ResourceManager(fig3_catalog())
    streams = make_streams(FIG3_SCENARIOS[1])
    plan = mgr.plan(streams, "ST3")
    assert plan.hourly_cost == 0.650
    util = mgr.utilization(plan)
    assigned = [s for u in util for s in u["streams"]]
    assert sorted(assigned) == sorted(s.stream_id for s in streams)
    for u in util:
        assert all(f <= 1.0 + 1e-9 for f in u["utilization_of_usable"])


def test_training_loss_decreases():
    """Few hundred steps is the deliverable's bar for the example driver; for
    CI we check the short-horizon trend on a reduced model (same driver)."""
    rec = train("olmo-1b", reduced=True, steps=30, batch=8, seq=64,
                log_every=100)
    first5 = np.mean(rec["loss_history"][:5])
    last5 = np.mean(rec["loss_history"][-5:])
    assert np.isfinite(last5)
    assert last5 < first5, f"loss did not decrease: {first5} -> {last5}"


@pytest.mark.slow
def test_training_with_grad_accum_matches_direction():
    rec = train("olmo-1b", reduced=True, steps=10, batch=8, seq=64,
                microbatches=4, log_every=100)
    assert np.isfinite(rec["final_loss"])


def test_dryrun_artifacts_complete():
    """All 40 (arch x shape) x 2 meshes accounted for: ok or documented skip."""
    d = os.path.join(os.path.dirname(__file__), "..", "experiments", "dryrun")
    if not os.path.isdir(d):
        import pytest
        pytest.skip("dry-run sweep not yet executed")
    from repro.data.pipeline import SHAPES
    from repro.models.config import list_archs
    missing, failed = [], []
    for mesh in ("pod1", "pod2"):
        for arch in list_archs():
            for shape in SHAPES:
                p = os.path.join(d, f"{arch}_{shape}_{mesh}.json")
                if not os.path.exists(p):
                    missing.append((arch, shape, mesh))
                    continue
                rec = json.load(open(p))
                if "error" in rec:
                    failed.append((arch, shape, mesh))
    assert not missing, f"missing dry-runs: {missing}"
    assert not failed, f"failed dry-runs: {failed}"


def test_checkpoint_from_training(tmp_path):
    path = os.path.join(str(tmp_path), "ck.npz")
    train("olmo-1b", reduced=True, steps=3, batch=4, seq=64,
          checkpoint_path=path, log_every=100)
    assert os.path.exists(path)
    meta = json.load(open(path + ".meta.json"))
    assert meta["arch"] == "olmo-1b"
