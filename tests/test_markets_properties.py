"""Property tests for the spot-market/bidding subsystem (core/markets.py,
sim/bidding.py, and the market-aware cluster).

``hypothesis`` is optional (see DESIGN.md, Testing): when missing, seeded
random fleets and walks exercise the same invariants.

* bid >= price => never preempted that tick: ``SpotMarket.outbid`` reclaims
  *exactly* the underwater instances, nothing else, with no randomness;
* anti-affinity: no stream's replicas co-resident on one spot market —
  after a fresh mixed plan, after min-migration mixed repairs under churn,
  and on every per-tick plan of a simulated preemption storm;
* a mixed plan never costs more per hour than the on-demand-only plan of
  the same problem;
* frames are conserved (demanded == analyzed + dropped, every tick) under
  mass preemption, and preempted capacity is replayed;
* the price walk is exogenous: two simulators under one seed observe the
  identical price series regardless of bidding policy (the RNG-split
  guarantee — bid-based reclaims consume no randomness).
"""
import dataclasses
import math

import numpy as np
import pytest

try:
    import hypothesis.strategies as st
    from hypothesis import given, settings
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

from repro.core import MixedConfig, ResourceManager, Stream, fig6_catalog
from repro.core import geo
from repro.core.markets import (MarketQuote, SPOT, mixed_plan, quotes,
                                replica_group, spot_affinity_violations)
from repro.core.workload import PROGRAMS
from repro.sim import (FixedMarginBid, FleetSimulator, LookaheadBid,
                       ReactivePolicy, RepairPolicy, SCENARIOS,
                       SpotBidPolicy)
from repro.sim.cluster import SimInstance, SpotMarket

CAMERAS = tuple(sorted(geo.CAMERAS))
CATALOG = fig6_catalog()


def _replicated_fleet(rng, n_groups: int, replicas: int = 2) -> list[Stream]:
    out = []
    for i in range(n_groups):
        cam = CAMERAS[int(rng.integers(0, len(CAMERAS)))]
        prog = "VGG16" if rng.random() < 0.25 else "ZF"
        hi = 1.5 if prog == "VGG16" else 6.0
        fps = round(float(rng.uniform(0.2, hi)) / replicas, 3)
        for k in range(replicas):
            out.append(Stream(f"{prog.lower()}-{i}#{k}", PROGRAMS[prog],
                              fps, camera=cam))
    return out


def _multipliers(rng) -> dict[str, float]:
    return {r: round(float(rng.uniform(0.2, 0.9)), 4)
            for r in CATALOG.locations}


# -- bid >= price => never preempted that tick -------------------------------


def _check_outbid_is_exactly_underwater(seed: int) -> None:
    rng = np.random.default_rng(seed)
    market = SpotMarket(CATALOG.locations, seed=seed)
    for _ in range(int(rng.integers(1, 8))):
        market.step(1.0)
    insts = []
    underwater = set()
    for j, region in enumerate(CATALOG.locations):
        price = round(float(rng.uniform(0.3, 3.0)), 3)
        inst = SimInstance(instance_id=f"i{j}", type_name="t",
                          location=region, price=price, market=SPOT)
        rate = market.spot_rate(inst)
        mode = int(rng.integers(0, 3))
        if mode == 0:
            inst.bid = rate                    # bid == price: safe
        elif mode == 1:
            inst.bid = rate * float(rng.uniform(1.0, 2.0))   # above: safe
        else:
            inst.bid = rate * float(rng.uniform(0.2, 0.999))  # underwater
            underwater.add(inst.instance_id)
        insts.append(inst)
    assert set(market.outbid(insts)) == underwater


def test_outbid_reclaims_exactly_the_underwater_bids_seeded():
    for seed in range(25):
        _check_outbid_is_exactly_underwater(seed)


def test_bid_at_ondemand_cap_is_never_preempted_in_simulation():
    """The walk's multiplier is clipped below 1.0x on-demand, so a policy
    bidding the on-demand cap (huge fixed margin) must never be outbid over
    a whole simulated day — bid >= price at every tick."""
    sc = SCENARIOS["spot_bidder"](n_streams=24, duration_h=12.0, seed=3)
    pol = SpotBidPolicy(ResourceManager(sc.catalog()),
                        bidding=FixedMarginBid(10.0))
    led = FleetSimulator(sc.demand, pol, sc.catalog(), sc.config).run()
    assert led.outbids == 0 and led.preemptions == 0
    assert led.cost_spot > 0, "the mixed plan must actually use spot"


# -- anti-affinity invariant -------------------------------------------------


def _check_anti_affinity_plan_and_repair(seed: int, n_groups: int) -> None:
    rng = np.random.default_rng(seed)
    streams = _replicated_fleet(rng, n_groups)
    mults = _multipliers(rng)
    cfg = MixedConfig()
    res = mixed_plan(streams, CATALOG, mults, config=cfg)
    assert spot_affinity_violations(res.plan) == []

    # churn: drop some groups, drift rates, add new replica groups
    survivors = [s for s in streams
                 if int(rng.integers(0, 5)) > 0]
    drifted = [dataclasses.replace(
        s, fps=round(min(s.fps * float(rng.uniform(0.5, 2.0)), 3.0), 3))
        if rng.random() < 0.5 else s for s in survivors]
    arrivals = _replicated_fleet(np.random.default_rng(seed + 1), 2)
    new = drifted + [dataclasses.replace(s, stream_id="new-" + s.stream_id)
                     for s in arrivals]
    mults2 = _multipliers(rng)
    rep = mixed_plan(new, CATALOG, mults2, previous=res.plan, config=cfg)
    assert spot_affinity_violations(rep.plan) == []
    # every demanded stream is placed exactly once (validate ran inside,
    # but coverage against the *demand* is the planner's contract)
    placed = {rep.plan.problem.items[i].key
              for b in rep.plan.solution.bins for i in b.items}
    assert placed == {s.stream_id for s in new}
    _assert_floor(rep.plan, new, cfg)


def _assert_floor(plan, streams, cfg) -> None:
    """At most (1 - floor_frac) of every class on spot capacity."""
    spot_items = {i for b in plan.solution.bins
                  if plan.problem.choices[b.choice].market == SPOT
                  for i in b.items}
    by_class: dict[tuple, list[int]] = {}
    for i, s in enumerate(streams):
        by_class.setdefault(cfg.stream_class(s), []).append(i)
    for members in by_class.values():
        floor = math.ceil(cfg.floor_frac * len(members))
        on_spot = sum(1 for i in members if i in spot_items)
        assert on_spot <= len(members) - floor, \
            "on-demand floor violated after repair"


def test_repair_re_establishes_floor_after_replica_departure():
    """Regression: when a group's on-demand replica departs, the surviving
    replica becomes the class floor and must be moved *off* spot by the
    next repair — min-migration never outranks the reclaim-proof floor."""
    rng = np.random.default_rng(21)
    # one group per camera so every (program, camera) class is one group:
    # after the departure each class is a singleton the floor fully covers
    streams = [Stream(f"zf-{j}#{k}", PROGRAMS["ZF"], 1.5,
                      camera=CAMERAS[j])
               for j in range(8) for k in range(2)]
    cfg = MixedConfig()
    mults = _multipliers(rng)
    res = mixed_plan(streams, CATALOG, mults, config=cfg)
    # drop every '#0' replica: each survivor is now a singleton class whose
    # floor (ceil(0.5 * 1) = 1) covers it entirely
    survivors = [s for s in streams if s.stream_id.endswith("#1")]
    rep = mixed_plan(survivors, CATALOG, mults, previous=res.plan,
                     config=cfg)
    spot_keys = {rep.plan.problem.items[i].key
                 for b in rep.plan.solution.bins
                 if rep.plan.problem.choices[b.choice].market == SPOT
                 for i in b.items}
    assert spot_keys == set(), \
        f"floored streams left on spot after repair: {sorted(spot_keys)}"
    _assert_floor(rep.plan, survivors, cfg)


def test_anti_affinity_holds_after_plan_and_repair_seeded():
    for seed in range(15):
        _check_anti_affinity_plan_and_repair(seed, n_groups=6 + seed % 7)


def test_anti_affinity_holds_through_preemption_storm():
    """Zero-margin bids go underwater whenever a region's walk ticks up —
    a mass-preemption storm. Every per-tick plan must keep each group's
    replicas off any single spot market, and the storm must not lose
    frames (conservation is asserted by the ledger on every tick)."""
    sc = SCENARIOS["spot_bidder"](n_streams=32, duration_h=24.0, seed=5)
    cat = sc.catalog()
    pol = SpotBidPolicy(ResourceManager(cat), bidding=FixedMarginBid(0.0))
    plans = []
    orig = pol.adaptive.step

    def recording_step(t, streams, **kw):
        plan = orig(t, streams, **kw)
        plans.append(plan)
        return plan

    pol.adaptive.step = recording_step
    led = FleetSimulator(sc.demand, pol, cat, sc.config).run()
    assert led.outbids > 5, "zero-margin bidding must storm"
    assert plans, "no plans recorded"
    for plan in plans:
        assert spot_affinity_violations(plan) == []
    assert led.slo_attainment() > 0.8


# -- mixed cost <= on-demand-only cost ---------------------------------------


def _check_mixed_never_beats_itself(seed: int, n_groups: int) -> None:
    rng = np.random.default_rng(seed)
    streams = _replicated_fleet(rng, n_groups)
    mults = _multipliers(rng)
    res = mixed_plan(streams, CATALOG, mults)
    assert res.ondemand_cost is not None
    assert res.plan.hourly_cost <= res.ondemand_cost + 1e-9, \
        (f"mixed plan ${res.plan.hourly_cost}/h costs more than "
         f"on-demand-only ${res.ondemand_cost}/h")
    # the floor really holds: at most (1 - floor_frac) of each class on spot
    spot_items = {i for b in res.plan.solution.bins
                  if res.plan.problem.choices[b.choice].market == SPOT
                  for i in b.items}
    by_class: dict[tuple, list[int]] = {}
    cfg = MixedConfig()
    for i, s in enumerate(streams):
        by_class.setdefault(cfg.stream_class(s), []).append(i)
    for members in by_class.values():
        floor = math.ceil(cfg.floor_frac * len(members))
        on_spot = sum(1 for i in members if i in spot_items)
        assert on_spot <= len(members) - floor


def test_mixed_cost_never_exceeds_ondemand_only_seeded():
    for seed in range(15):
        _check_mixed_never_beats_itself(seed, n_groups=5 + seed % 8)


# -- conservation under mass preemption --------------------------------------


def test_frames_conserved_under_mass_preemption():
    sc = SCENARIOS["spot_bidder"](n_streams=24, duration_h=24.0, seed=9)
    cat = sc.catalog()
    pol = SpotBidPolicy(ResourceManager(cat), bidding=FixedMarginBid(0.0))
    led = FleetSimulator(sc.demand, pol, cat, sc.config).run()
    assert led.outbids > 0 and led.preemptions >= led.outbids
    for r in led.records:
        assert r.frames_demanded == pytest.approx(
            r.frames_analyzed + r.frames_dropped)
        assert r.cost == pytest.approx(r.cost_ondemand + r.cost_spot)
    assert led.frames_analyzed > 0


# -- exogenous prices: the RNG-split guarantee -------------------------------


def test_price_series_identical_across_bidding_policies():
    """Regression for the walk/preemption RNG split: how many instances a
    policy rents — and whether its reclaims are hazard draws or bid
    crossings — must not perturb the price series. Three very different
    policies under one seed must observe the identical walk, tick for
    tick."""
    sc = SCENARIOS["spot_heavy"](n_streams=24, duration_h=12.0, seed=7)
    cat = sc.catalog()
    sims = [FleetSimulator(sc.demand, pol, cat, sc.config)
            for pol in (ReactivePolicy(ResourceManager(cat)),
                        RepairPolicy(ResourceManager(cat)),
                        SpotBidPolicy(ResourceManager(cat),
                                      bidding=LookaheadBid()))]
    for s in sims:
        s.run()
    histories = [s.market.price_history for s in sims]
    assert histories[0] == histories[1] == histories[2]
    assert len(histories[0]) == int(sc.config.duration_h) + 1


# -- quote math --------------------------------------------------------------


def _check_quote_math(price: float, vol: float, dt: float) -> None:
    q = MarketQuote("t", "r", SPOT, price, price / 0.35, vol)
    p_lo = q.preempt_probability(price * 1.05, dt)
    p_hi = q.preempt_probability(price * 1.60, dt)
    assert 0.0 <= p_hi <= p_lo <= 1.0, "hazard must fall as margin grows"
    assert q.preempt_probability(price, dt) == pytest.approx(0.5)
    # expected payment conditional on survival is below the bid and at
    # least a shade under the current price (truncation pulls it down)
    for bid in (price * 1.05, price * 1.6):
        pay = q.expected_payment(bid, dt)
        assert 0.0 < pay <= bid + 1e-12
    eff_lo = q.effective_price(price * 1.02, dt, preempt_penalty=price)
    eff_hi = q.effective_price(price * 1.60, dt, preempt_penalty=price)
    assert eff_hi <= eff_lo + 1e-9, \
        "with a preemption penalty, more head-room must not cost more"


def test_quote_hazard_and_payment_seeded():
    rng = np.random.default_rng(0)
    for _ in range(25):
        _check_quote_math(float(rng.uniform(0.1, 3.0)),
                          float(rng.uniform(0.05, 0.5)),
                          float(rng.uniform(0.25, 4.0)))


def test_quotes_sheet_covers_both_markets():
    mults = {"us-east-1": 0.4}
    sheet = quotes(CATALOG, mults)
    spot = [q for q in sheet if q.market == SPOT]
    assert {q.location for q in spot} == {"us-east-1"}
    for q in spot:
        assert q.price == pytest.approx(q.ondemand_price * 0.4)
        assert q.key.endswith("!spot")
    # on-demand quotes exist for every catalog choice
    assert len(sheet) == len(CATALOG.choices()) + len(spot)


def test_replica_group_parsing():
    assert replica_group("zf-nyc-3#1") == "zf-nyc-3"
    assert replica_group("plain-stream") == "plain-stream"


if HAVE_HYPOTHESIS:
    @given(st.integers(0, 10_000))
    @settings(max_examples=30, deadline=None)
    def test_outbid_exactly_underwater(seed):
        _check_outbid_is_exactly_underwater(seed)

    @given(st.integers(0, 10_000), st.integers(4, 12))
    @settings(max_examples=25, deadline=None)
    def test_anti_affinity_plan_and_repair(seed, n_groups):
        _check_anti_affinity_plan_and_repair(seed, n_groups)

    @given(st.integers(0, 10_000), st.integers(4, 12))
    @settings(max_examples=25, deadline=None)
    def test_mixed_cost_never_exceeds_ondemand(seed, n_groups):
        _check_mixed_never_beats_itself(seed, n_groups)

    @given(st.floats(0.1, 3.0), st.floats(0.05, 0.5), st.floats(0.25, 4.0))
    @settings(max_examples=40, deadline=None)
    def test_quote_math(price, vol, dt):
        _check_quote_math(price, vol, dt)
