"""Sharding-rule tests: divisibility on the production mesh shapes (validated
against a lightweight stand-in mesh so no 256-device runtime is needed) and a
real end-to-end jit on a 1x1 mesh exercising the same code path."""
import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.data.pipeline import SHAPES, InputShape
from repro.launch import sharding as SH
from repro.launch.mesh import make_smoke_mesh
from repro.models import model as M
from repro.models.config import get_config, list_archs
from repro.models.steps import TrainOptions, init_train_state, train_step

KEY = jax.random.PRNGKey(0)


class FakeMesh:
    """Duck-typed mesh: spec construction only needs .shape and .axis_names."""

    def __init__(self, shape: dict):
        self.shape = shape
        self.axis_names = tuple(shape)


PODS = [FakeMesh({"data": 16, "model": 16}),
        FakeMesh({"pod": 2, "data": 16, "model": 16})]


def _axis_size(mesh, axis):
    if axis is None:
        return 1
    axes = axis if isinstance(axis, tuple) else (axis,)
    n = 1
    for a in axes:
        n *= mesh.shape[a]
    return n


@pytest.mark.parametrize("arch", list_archs())
@pytest.mark.parametrize("mesh", PODS, ids=["pod1", "pod2"])
def test_param_specs_divisible(arch, mesh):
    """Every sharded parameter dim divides evenly on the production meshes
    (this is exactly what explicit in_shardings require at lower time)."""
    cfg = get_config(arch)                      # FULL config
    policy = SH.ShardingPolicy.for_arch(cfg)
    params = jax.eval_shape(lambda: M.init_params(cfg, KEY, jnp.bfloat16))
    specs = SH.params_specs(params, mesh, policy)

    def check(path, leaf, spec):
        for d, axis in enumerate(spec):
            if axis is None:
                continue
            n = _axis_size(mesh, axis)
            assert leaf.shape[d] % n == 0, (path, leaf.shape, spec)

    jax.tree_util.tree_map_with_path(
        lambda p, l, s: check(p, l, s), params, specs,
        is_leaf=lambda x: hasattr(x, "shape"))


@pytest.mark.parametrize("arch", ["yi-9b", "mamba2-2.7b", "recurrentgemma-9b",
                                  "grok-1-314b"])
@pytest.mark.parametrize("shape_name", ["decode_32k", "long_500k"])
def test_cache_specs_divisible(arch, shape_name):
    cfg = get_config(arch)
    mesh = PODS[0]
    shape = SHAPES[shape_name]
    policy = SH.ShardingPolicy.for_arch(cfg)
    from repro.launch.dryrun import model_options
    opts = model_options(cfg, shape)
    cache = jax.eval_shape(lambda: M.init_cache(cfg, shape.global_batch,
                                                shape.seq_len, jnp.bfloat16,
                                                opts))
    specs = SH.cache_specs(cache, cfg, shape, mesh, policy)

    def check(path, leaf, spec):
        for d, axis in enumerate(spec):
            if axis is None:
                continue
            n = _axis_size(mesh, axis)
            assert leaf.shape[d] % n == 0, (path, leaf.shape, spec)

    jax.tree_util.tree_map_with_path(
        lambda p, l, s: check(p, l, s), cache, specs,
        is_leaf=lambda x: hasattr(x, "shape"))


def test_large_archs_use_fsdp():
    assert SH.ShardingPolicy.for_arch(get_config("grok-1-314b")).fsdp
    assert SH.ShardingPolicy.for_arch(get_config("yi-9b")).fsdp
    assert not SH.ShardingPolicy.for_arch(get_config("olmo-1b")).fsdp


def test_sharded_train_step_runs_on_smoke_mesh():
    """The full sharded-jit path executes on a 1x1 mesh (CPU)."""
    cfg = get_config("qwen3-moe-30b-a3b", reduced=True)
    mesh = make_smoke_mesh()
    policy = SH.ShardingPolicy()
    opts = M.ModelOptions(remat=False)
    topts = TrainOptions()
    shape = InputShape("t", 64, 2, "train")
    from repro.data.pipeline import make_batch
    with mesh:
        state = init_train_state(cfg, KEY, jnp.float32, topts)
        state_sh = SH.to_named(SH.state_specs(state, mesh, policy), mesh)
        batch_sh = SH.to_named(SH.batch_specs(cfg, shape, mesh), mesh)
        state = jax.device_put(state, state_sh)
        f = functools.partial(train_step, cfg=cfg, opts=opts, topts=topts)
        step = jax.jit(f, in_shardings=(state_sh, batch_sh),
                       out_shardings=(state_sh, None))
        batch = make_batch(cfg, shape, seed=0)
        _, metrics = step(state, batch)
        assert np.isfinite(float(metrics["loss"]))
