"""Per-kernel shape/dtype sweeps: pallas (interpret=True) vs pure-jnp oracle."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.flash_attention import flash_attention
from repro.kernels.rglru_scan import rglru_scan
from repro.kernels.ssd_scan import ssd_scan

RNG = np.random.default_rng(42)


def _tol(dtype):
    return dict(atol=2e-2, rtol=2e-2) if dtype == jnp.bfloat16 else \
        dict(atol=2e-5, rtol=2e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("B,S,H,hd,K,T,causal,window", [
    (2, 128, 4, 64, 2, 128, True, 0),      # GQA causal
    (1, 256, 4, 64, 1, 256, True, 64),     # MQA sliding window
    (2, 128, 4, 64, 4, 256, True, 0),      # decode-ish: T > S
    (1, 128, 2, 32, 2, 128, False, 0),     # encoder (bidirectional)
    pytest.param(1, 512, 8, 128, 2, 512, True, 128,    # bigger window
                 marks=pytest.mark.slow),
])
def test_flash_attention(dtype, B, S, H, hd, K, T, causal, window):
    q = jnp.asarray(RNG.standard_normal((B, S, H, hd)), dtype)
    k = jnp.asarray(RNG.standard_normal((B, T, K, hd)), dtype)
    v = jnp.asarray(RNG.standard_normal((B, T, K, hd)), dtype)
    out = flash_attention(q, k, v, causal=causal, window=window, bq=64, bk=64)
    want = ref.flash_attention_ref(q, k, v, causal=causal, window=window)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32), **_tol(dtype))


@pytest.mark.parametrize("b,s,h,p,g,n,L", [
    (2, 128, 4, 32, 1, 32, 32),
    (1, 256, 2, 64, 1, 64, 64),
    (1, 64, 4, 16, 2, 16, 16),             # 2 B/C groups
    pytest.param(1, 256, 8, 64, 1, 128, 128,   # production-like state size
                 marks=pytest.mark.slow),
])
def test_ssd_scan_kernel(b, s, h, p, g, n, L):
    x = jnp.asarray(RNG.standard_normal((b, s, h, p)), jnp.float32)
    dt = jnp.asarray(RNG.uniform(0.001, 0.1, (b, s, h)), jnp.float32)
    A = jnp.asarray(-RNG.uniform(0.5, 2.0, (h,)), jnp.float32)
    B = jnp.asarray(RNG.standard_normal((b, s, g, n)), jnp.float32)
    C = jnp.asarray(RNG.standard_normal((b, s, g, n)), jnp.float32)
    out = ssd_scan(x, dt, A, B, C, L)
    want = ref.ssd_scan_ref(x, dt, A, B, C, L)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               atol=3e-5, rtol=3e-5)


@pytest.mark.slow
def test_ssd_chunked_equals_sequential():
    """The chunked SSD algorithm == the O(S) state recurrence definition."""
    b, s, h, p, g, n = 2, 128, 4, 32, 1, 32
    x = jnp.asarray(RNG.standard_normal((b, s, h, p)), jnp.float32)
    dt = jnp.asarray(RNG.uniform(0.001, 0.1, (b, s, h)), jnp.float32)
    A = jnp.asarray(-RNG.uniform(0.5, 2.0, (h,)), jnp.float32)
    B = jnp.asarray(RNG.standard_normal((b, s, g, n)), jnp.float32)
    C = jnp.asarray(RNG.standard_normal((b, s, g, n)), jnp.float32)
    for chunk in (16, 32, 64, 128):
        got = ref.ssd_scan_ref(x, dt, A, B, C, chunk)
        want = ref.ssd_scan_naive(x, dt, A, B, C)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   atol=3e-5, rtol=3e-5)


@pytest.mark.parametrize("B,S,W,bs,bw", [
    pytest.param(2, 128, 512, 64, 128, marks=pytest.mark.slow),
    pytest.param(1, 256, 256, 128, 256, marks=pytest.mark.slow),
    (3, 64, 128, 64, 128),
    pytest.param(1, 512, 1024, 128, 512, marks=pytest.mark.slow),
])
def test_rglru_scan_kernel(B, S, W, bs, bw):
    a = jnp.asarray(RNG.uniform(0.7, 0.999, (B, S, W)), jnp.float32)
    b = jnp.asarray(RNG.standard_normal((B, S, W)), jnp.float32)
    out = rglru_scan(a, b, block_seq=bs, block_w=bw)
    want = ref.rglru_scan_ref(a, b)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               atol=2e-5, rtol=2e-5)


def test_rglru_scan_matches_python_loop():
    B, S, W = 1, 37, 8
    a = np.asarray(RNG.uniform(0.5, 0.999, (B, S, W)), np.float32)
    b = np.asarray(RNG.standard_normal((B, S, W)), np.float32)
    h = np.zeros((B, W), np.float32)
    want = np.zeros_like(a)
    for t in range(S):
        h = a[:, t] * h + b[:, t]
        want[:, t] = h
    got = ref.rglru_scan_ref(jnp.asarray(a), jnp.asarray(b))
    np.testing.assert_allclose(np.asarray(got), want, atol=1e-5, rtol=1e-5)


def test_ops_wrappers_jit():
    from repro.kernels import ops
    q = jnp.asarray(RNG.standard_normal((1, 128, 2, 64)), jnp.float32)
    k = jnp.asarray(RNG.standard_normal((1, 128, 2, 64)), jnp.float32)
    out = ops.flash_attention(q, k, k, causal=True)
    assert out.shape == q.shape
