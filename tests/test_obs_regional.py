"""Per-region live drift and per-group recalibration: windowed probes,
one detector streak per region, partial calibration merge, and the repair
scope that keeps healthy regions' placements out of the blast radius."""
import pytest

from repro.core.manager import ResourceManager
from repro.core.repair import RepairConfig, repair_plan
from repro.core.workload import PROGRAMS, Stream
from repro.obs import (DriftConfig, DriftingService, EngineWindowProbe,
                       RateShift, RegionalDriftDetector,
                       RegionalRecalibratingPolicy, WindowedServiceProbe,
                       camera_region_groups)
from repro.sim import FleetSimulator, RepairPolicy, SCENARIOS
from repro.sim.ledger import ServiceCalibration


def _calib(rates, default=None):
    return ServiceCalibration(tokens_per_frame=8.0, rates_tokens_per_s=rates,
                              default_rate=default)


# -- windowed probe ----------------------------------------------------------

def test_windowed_probe_time_averages_over_the_poll_window():
    svc = DriftingService({"a": 64.0},
                          shifts=(RateShift(at_h=12.0, factor=0.25),))
    probe = WindowedServiceProbe(svc)
    assert probe.measure(11.0) == {"a": 64.0}          # first poll: snapshot
    assert probe.measure(11.5) == {"a": 64.0}          # pre-shift window
    # window [11.5, 12.5] straddles the shift: half at 64, half at 16
    assert probe.measure(12.5)["a"] == pytest.approx(40.0)
    # next window is fully post-shift: full magnitude one poll later
    assert probe.measure(13.5)["a"] == pytest.approx(16.0)


def test_windowed_probe_forwards_service_identity():
    svc = DriftingService({"a": 64.0}, tokens_per_frame=4.0)
    probe = WindowedServiceProbe(svc)
    assert probe.tokens_per_frame == 4.0
    assert probe.initial_calibration().rates_tokens_per_s == {"a": 64.0}


def test_mean_rates_integrates_piecewise_exactly():
    svc = DriftingService({"a": 100.0, "b": 10.0},
                          shifts=(RateShift(12.0, 0.5, frozenset({"a"})),
                                  RateShift(14.0, 0.2, frozenset({"a"}))))
    # [10, 15]: 2h at 100, 2h at 50, 1h at 10 -> 310/5 = 62; b untouched
    rates = svc.mean_rates(10.0, 15.0)
    assert rates["a"] == pytest.approx(62.0)
    assert rates["b"] == pytest.approx(10.0)
    # degenerate window falls back to the instantaneous snapshot
    assert svc.mean_rates(13.0, 13.0)["a"] == pytest.approx(50.0)


# -- engine bridge -----------------------------------------------------------

class _FakeEngine:
    def __init__(self, windowed, lifetime=None):
        self._windowed = windowed
        self._lifetime = lifetime if lifetime is not None else dict(windowed)

    def windowed_rates(self):
        return dict(self._windowed)

    def measured_rates(self):
        return dict(self._lifetime)


def test_engine_window_probe_merges_regions_and_tracks_groups():
    probe = EngineWindowProbe({
        "us-east-1": _FakeEngine({"cam-a": 60.0}),
        "ap-northeast-1": _FakeEngine({"cam-b": 12.0}),
    }, tokens_per_frame=8.0)
    measured = probe.measure(1.0)
    assert measured == {"cam-a": 60.0, "cam-b": 12.0}
    assert probe.group_of("cam-a") == "us-east-1"
    assert probe.group_of("cam-b") == "ap-northeast-1"
    assert probe.group_of("never-seen") == "unknown"
    calib = probe.initial_calibration()
    assert calib.rates_tokens_per_s == {"cam-a": 60.0, "cam-b": 12.0}
    assert calib.default_rate == pytest.approx(36.0)


# -- per-group detection -----------------------------------------------------

def test_regional_detector_fires_only_the_drifted_group():
    det = RegionalDriftDetector(
        lambda sid: "tokyo" if sid.startswith("t") else "nyc",
        DriftConfig(rel_threshold=0.25, hold_ticks=2))
    calib = _calib({"t1": 64.0, "t2": 64.0, "n1": 64.0})
    healthy = {"n1": 64.0}
    drifted = {"t1": 12.8, "t2": 12.8}
    v1 = det.observe(0.0, {**healthy, **drifted}, calib)
    assert not v1.fired and v1.verdicts["tokyo"].streak == 1
    v2 = det.observe(1.0, {**healthy, **drifted}, calib)
    assert v2.fired_groups == ("tokyo",)
    assert v2.verdicts["nyc"].streak == 0
    # the aggregate error is stream-weighted: (0.8 * 2 + 0 * 1) / 3
    assert v2.rel_error == pytest.approx(0.8 * 2 / 3)
    assert v2.max_rel_error == pytest.approx(0.8)
    assert v2.fired and v2.drifting and v2.streak == 2
    assert det.fired_groups() == ("tokyo",)
    # per-group reset clears only that group's streak
    det.reset("tokyo")
    v3 = det.observe(2.0, {**healthy, **drifted}, calib)
    assert v3.verdicts["tokyo"].streak == 1 and not v3.fired


def test_regional_detector_absent_group_keeps_its_streak():
    """A region idle this window (no measurements) is no evidence — its
    streak must survive, same convention as the fleet-wide detector."""
    det = RegionalDriftDetector(lambda sid: sid[0],
                                DriftConfig(hold_ticks=3),
                                groups=("a", "b"))
    calib = _calib({"a1": 64.0, "b1": 64.0})
    det.observe(0.0, {"a1": 12.8}, calib)
    det.observe(1.0, {"a1": 12.8}, calib)
    v = det.observe(2.0, {"b1": 64.0}, calib)      # a silent, b healthy
    assert v.verdicts["a"].streak == 2 and v.verdicts["a"].n_streams == 0
    v = det.observe(3.0, {"a1": 12.8, "b1": 64.0}, calib)
    assert v.fired_groups == ("a",)                # streak resumed at 3


def test_regional_detector_dilution_vs_partition():
    """The failure mode the per-group split exists for: one region's 0.8
    error diluted across three regions stays under a 0.3 fleet threshold
    forever, while the partitioned detector fires."""
    from repro.obs import DriftDetector
    cfg = DriftConfig(rel_threshold=0.3, hold_ticks=2)
    calib = _calib({f"{g}{i}": 64.0 for g in "abc" for i in range(4)})
    measured = {f"{g}{i}": (12.8 if g == "a" else 64.0)
                for g in "abc" for i in range(4)}
    fleet, regional = DriftDetector(cfg), RegionalDriftDetector(
        lambda sid: sid[0], cfg)
    for t in range(4):
        fv = fleet.observe(float(t), measured, calib)
        rv = regional.observe(float(t), measured, calib)
    assert not fv.fired and fv.rel_error == pytest.approx(0.8 / 3)
    assert rv.fired_groups == ("a",)


# -- scoped repair -----------------------------------------------------------

def _streams(n, camera, fps, prefix):
    return [Stream(f"{prefix}-{i}", PROGRAMS["ZF"], fps=fps, camera=camera)
            for i in range(n)]


def test_repair_scope_restricts_consolidation_and_defrag():
    from repro.core import fig6_catalog
    cat = fig6_catalog()
    before = _streams(9, "nyc", 6.0, "ny") + _streams(9, "tokyo", 6.0, "tk")
    first = repair_plan(before, cat).plan
    # tokyo's demand collapses: its bins now have closable slack, and so
    # would any unscoped consolidation pass see them
    after = _streams(9, "nyc", 6.0, "ny") + _streams(9, "tokyo", 0.5, "tk")
    scope = frozenset(s.stream_id for s in after if s.camera == "tokyo")
    cfg = RepairConfig(migration_budget=18, defrag_ratio=None)
    unscoped = repair_plan(after, cat, previous=first, config=cfg)
    scoped = repair_plan(after, cat, previous=first, config=cfg, scope=scope)
    assert scoped.plan.solution.cost <= unscoped.plan.solution.cost + 1e-9
    # scoped consolidation moved only tokyo streams
    moved_scoped = _moved(first, scoped.plan)
    assert moved_scoped and moved_scoped <= scope
    # the unscoped pass is free to touch nyc placements too
    assert _moved(first, unscoped.plan) >= moved_scoped


def _moved(old, new):
    from repro.core.repair import plan_assignment
    a, b = plan_assignment(old), plan_assignment(new)
    return {k for k, v in b.items() if k in a and a[k] != v}


def test_repair_scope_skips_defrag_hatch():
    from repro.core import fig6_catalog
    cat = fig6_catalog()
    before = _streams(12, "nyc", 6.0, "ny")
    first = repair_plan(before, cat).plan
    after = _streams(12, "nyc", 0.5, "ny")
    # no budget, aggressive hatch: the unscoped repair defrags wholesale
    cfg = RepairConfig(migration_budget=None, defrag_ratio=1.05)
    unscoped = repair_plan(after, cat, previous=first, config=cfg)
    assert unscoped.defrag
    scoped = repair_plan(after, cat, previous=first, config=cfg,
                         scope=frozenset(s.stream_id for s in after))
    assert not scoped.defrag


# -- per-group recalibration end to end --------------------------------------

def test_regional_policy_recalibrates_only_the_fired_group():
    sc = SCENARIOS["regional_drift"](n_streams=24, duration_h=24.0)
    cat = sc.catalog()
    policy = RegionalRecalibratingPolicy(
        RepairPolicy(ResourceManager(cat), migration_budget=6,
                     defrag_ratio=1.25),
        sc.service, group_of=sc.groups.__getitem__)
    ledger = FleetSimulator(sc.demand, policy, cat, sc.config,
                            service=sc.service,
                            telemetry=policy.telemetry).run()
    # exactly one recalibration, scoped to the drifted region
    assert len(policy.recal_groups) == 1
    t_fired, groups = policy.recal_groups[0]
    assert groups == ("ap-northeast-1",)
    assert policy.regional.fired_groups() == ("ap-northeast-1",)
    # healthy regions kept their startup profile; the drifted group's
    # streams adopted the measured (regressed) rates
    for sid, g in sc.groups.items():
        rate = policy.calibration.rates_tokens_per_s[sid]
        truth = sc.service.rates_at(23.0)[sid]
        if g == "ap-northeast-1":
            assert rate == pytest.approx(truth)
        else:
            assert rate == pytest.approx(
                sc.service.initial_calibration().rates_tokens_per_s[sid])
    # the ledger recorded it and per-region telemetry was emitted
    assert ledger.totals()["recalibrations"] == 1
    regions = {p.attr("region") for p in policy.telemetry.points
               if p.name == "drift.rel_error" and p.attr("region")}
    assert regions == set(sc.groups.values())


def test_camera_region_groups_maps_streams_by_camera():
    streams = [Stream("a", PROGRAMS["ZF"], fps=1.0, camera="nyc"),
               Stream("b", PROGRAMS["ZF"], fps=1.0, camera="tokyo"),
               Stream("c", PROGRAMS["ZF"], fps=1.0, camera=None)]
    groups = camera_region_groups(streams)
    assert groups["a"] == "us-east-1"
    assert groups["b"] == "ap-northeast-1"
    assert groups["c"] == "unknown"


def test_regional_drift_scenario_shape():
    sc = SCENARIOS["regional_drift"](n_streams=12)
    assert sc.groups is not None and len(sc.groups) == 12
    assert sorted(set(sc.groups.values())) == [
        "ap-northeast-1", "eu-west-1", "us-east-1"]
    drifted = {sid for sid, g in sc.groups.items()
               if g == "ap-northeast-1"}
    # the regression is scoped to exactly the drifted region's streams
    (shift,) = sc.service.shifts
    assert shift.streams == drifted
    post = sc.service.rates_at(shift.at_h)
    pre = sc.service.rates_at(0.0)
    for sid in sc.groups:
        if sid in drifted:
            assert post[sid] == pytest.approx(pre[sid] * shift.factor)
        else:
            assert post[sid] == pre[sid]
