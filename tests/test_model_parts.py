"""Unit tests for model components."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings
    import hypothesis.strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

from repro.models import layers, moe, rglru, ssm
from repro.models.config import get_config

KEY = jax.random.PRNGKey(0)
RNG = np.random.default_rng(0)


# ---------------- norms ----------------

def test_rmsnorm_unit_scale():
    cfg = get_config("yi-9b", reduced=True)
    p = layers.init_norm(cfg, KEY, jnp.float32)
    x = jnp.asarray(RNG.standard_normal((2, 8, cfg.d_model)), jnp.float32)
    y = layers.apply_norm(p, x, cfg)
    rms = np.sqrt(np.mean(np.asarray(y) ** 2, -1))
    np.testing.assert_allclose(rms, 1.0, atol=1e-3)


def test_nonparam_ln_has_no_params():
    cfg = get_config("olmo-1b", reduced=True)
    assert layers.init_norm(cfg, KEY, jnp.float32) == {}
    x = jnp.asarray(RNG.standard_normal((2, 4, cfg.d_model)), jnp.float32)
    y = layers.apply_norm({}, x, cfg)
    np.testing.assert_allclose(np.mean(np.asarray(y), -1), 0.0, atol=1e-5)
    np.testing.assert_allclose(np.std(np.asarray(y), -1), 1.0, atol=1e-3)


# ---------------- rope ----------------

def test_rope_preserves_norm():
    x = jnp.asarray(RNG.standard_normal((1, 6, 2, 16)), jnp.float32)
    pos = jnp.arange(6)[None]
    y = layers.rope(x, pos, 10_000.0)
    np.testing.assert_allclose(np.linalg.norm(np.asarray(y), axis=-1),
                               np.linalg.norm(np.asarray(x), axis=-1),
                               atol=1e-5)


def test_rope_relative_phase():
    """q.k after rope depends only on relative distance."""
    hd = 32
    q = jnp.asarray(RNG.standard_normal((1, 1, 1, hd)), jnp.float32)
    k = jnp.asarray(RNG.standard_normal((1, 1, 1, hd)), jnp.float32)
    def dot_at(pq, pk):
        qr = layers.rope(q, jnp.asarray([[pq]]), 1e4)
        kr = layers.rope(k, jnp.asarray([[pk]]), 1e4)
        return float(jnp.sum(qr * kr))
    assert dot_at(3, 1) == pytest.approx(dot_at(10, 8), abs=1e-4)
    assert dot_at(5, 5) == pytest.approx(dot_at(0, 0), abs=1e-4)


# ---------------- attention ----------------

def test_gqa_matches_mha_when_repeated():
    """GQA with kv heads repeated == full MHA on the same tensors."""
    cfg = get_config("yi-9b", reduced=True)      # 4 heads, kv=2
    p = layers.init_attention(cfg, KEY, jnp.float32)
    # build an MHA-equivalent by repeating kv projections
    G = cfg.num_heads // cfg.num_kv_heads
    hd = cfg.head_dim
    wk = p["wk"].reshape(cfg.d_model, cfg.num_kv_heads, hd)
    wk_full = jnp.repeat(wk, G, axis=1).reshape(cfg.d_model, -1)
    wv = p["wv"].reshape(cfg.d_model, cfg.num_kv_heads, hd)
    wv_full = jnp.repeat(wv, G, axis=1).reshape(cfg.d_model, -1)
    cfg_mha = dataclasses.replace(cfg, num_kv_heads=cfg.num_heads)
    p_mha = dict(p, wk=wk_full, wv=wv_full)
    x = jnp.asarray(RNG.standard_normal((2, 16, cfg.d_model)), jnp.float32)
    out_gqa, _ = layers.attention_full(p, x, cfg)
    out_mha, _ = layers.attention_full(p_mha, x, cfg_mha)
    np.testing.assert_allclose(np.asarray(out_gqa), np.asarray(out_mha),
                               atol=1e-5, rtol=1e-5)


def test_sliding_window_masks_past():
    """With window w, token t must not see anything before t-w+1: moving the
    distant past must not change the output."""
    cfg = get_config("recurrentgemma-9b", reduced=True)
    w = cfg.window  # 64
    p = layers.init_attention(cfg, KEY, jnp.float32)
    S = 96
    x1 = np.asarray(RNG.standard_normal((1, S, cfg.d_model)), np.float32)
    x2 = x1.copy()
    x2[0, :16] += 10.0                      # mutate far past
    o1, _ = layers.attention_full(p, jnp.asarray(x1), cfg, window=w)
    o2, _ = layers.attention_full(p, jnp.asarray(x2), cfg, window=w)
    np.testing.assert_allclose(np.asarray(o1)[0, -1], np.asarray(o2)[0, -1],
                               atol=1e-4)


# ---------------- moe ----------------

def test_moe_balance_loss_bounds():
    cfg = get_config("qwen3-moe-30b-a3b", reduced=True)
    p = moe.init_moe(cfg, KEY, jnp.float32)
    x = jnp.asarray(RNG.standard_normal((2, 32, cfg.d_model)), jnp.float32)
    out, aux = moe.apply_moe(p, x, cfg)
    assert out.shape == x.shape
    # perfectly balanced aux == 1.0; can't be below
    assert float(aux) >= 1.0 - 1e-3


def test_moe_capacity_drops_tokens():
    """With capacity_factor → 0+ every token is dropped → output == 0."""
    cfg = dataclasses.replace(get_config("qwen3-moe-30b-a3b", reduced=True),
                              capacity_factor=1e-9)
    p = moe.init_moe(cfg, KEY, jnp.float32)
    x = jnp.asarray(RNG.standard_normal((1, 64, cfg.d_model)), jnp.float32)
    out, _ = moe.apply_moe(p, x, cfg)
    # capacity is rounded up to >=4 slots; most tokens must drop
    assert np.mean(np.abs(np.asarray(out))) < np.mean(np.abs(np.asarray(x)))


def test_moe_is_token_independent():
    """Permuting tokens permutes outputs (router is per-token)."""
    cfg = dataclasses.replace(get_config("qwen3-moe-30b-a3b", reduced=True),
                              capacity_factor=8.0)
    p = moe.init_moe(cfg, KEY, jnp.float32)
    x = jnp.asarray(RNG.standard_normal((1, 16, cfg.d_model)), jnp.float32)
    out1, _ = moe.apply_moe(p, x, cfg)
    perm = np.asarray(RNG.permutation(16))
    out2, _ = moe.apply_moe(p, x[:, perm], cfg)
    np.testing.assert_allclose(np.asarray(out1)[:, perm], np.asarray(out2),
                               atol=2e-5, rtol=2e-5)


# ---------------- ssd ----------------

def _check_ssd_chunk_invariance(b, chunk_a, chunk_b):
    """SSD output must not depend on the chunk size."""
    rng = np.random.default_rng(b)
    s, h, p_, g, n = 64, 2, 16, 1, 16
    x = jnp.asarray(rng.standard_normal((b, s, h, p_)), jnp.float32)
    dt = jnp.asarray(rng.uniform(0.001, 0.1, (b, s, h)), jnp.float32)
    A = jnp.asarray(-rng.uniform(0.5, 2.0, (h,)), jnp.float32)
    B = jnp.asarray(rng.standard_normal((b, s, g, n)), jnp.float32)
    C = jnp.asarray(rng.standard_normal((b, s, g, n)), jnp.float32)
    ya = ssm.ssd_scan_ref(x, dt, A, B, C, chunk_a)
    yb = ssm.ssd_scan_ref(x, dt, A, B, C, chunk_b)
    np.testing.assert_allclose(np.asarray(ya), np.asarray(yb),
                               atol=3e-5, rtol=3e-5)


@pytest.mark.parametrize("b,chunk_a,chunk_b",
                         [(1, 16, 32), (2, 32, 16), (3, 16, 16)])
def test_ssd_chunk_invariance_seeded(b, chunk_a, chunk_b):
    _check_ssd_chunk_invariance(b, chunk_a, chunk_b)


if HAVE_HYPOTHESIS:
    @given(st.integers(1, 3), st.sampled_from([16, 32]),
           st.sampled_from([16, 32]))
    @settings(max_examples=10, deadline=None)
    def test_ssd_chunk_invariance(b, chunk_a, chunk_b):
        _check_ssd_chunk_invariance(b, chunk_a, chunk_b)


def test_ssd_block_causality():
    cfg = get_config("mamba2-2.7b", reduced=True)
    p = ssm.init_ssd(cfg, KEY, jnp.float32)
    S = 64
    x1 = np.asarray(RNG.standard_normal((1, S, cfg.d_model)), np.float32)
    x2 = x1.copy()
    x2[0, S // 2:] += 5.0                    # mutate the future
    y1 = ssm.ssd_forward(p, jnp.asarray(x1), cfg)
    y2 = ssm.ssd_forward(p, jnp.asarray(x2), cfg)
    np.testing.assert_allclose(np.asarray(y1)[0, : S // 2],
                               np.asarray(y2)[0, : S // 2], atol=1e-4)


@pytest.mark.slow
def test_ssd_decode_matches_forward():
    """Step-by-step ssd_step == full-sequence ssd_forward."""
    cfg = get_config("mamba2-2.7b", reduced=True)
    p = ssm.init_ssd(cfg, KEY, jnp.float32)
    S = 16
    x = jnp.asarray(RNG.standard_normal((2, S, cfg.d_model)), jnp.float32)
    full = np.asarray(ssm.ssd_forward(p, x, cfg))
    cache = ssm.ssd_init_cache(cfg, 2, jnp.float32)
    got = []
    for t in range(S):
        y, cache = ssm.ssd_step(p, x[:, t:t + 1], cache, cfg)
        got.append(np.asarray(y)[:, 0])
    got = np.stack(got, 1)
    np.testing.assert_allclose(got, full, atol=2e-4, rtol=2e-4)


# ---------------- rg-lru ----------------

@pytest.mark.slow
def test_rglru_decode_matches_forward():
    cfg = get_config("recurrentgemma-9b", reduced=True)
    p = rglru.init_rglru(cfg, KEY, jnp.float32)
    S = 12
    x = jnp.asarray(RNG.standard_normal((2, S, cfg.d_model)), jnp.float32)
    full = np.asarray(rglru.rglru_forward(p, x, cfg))
    cache = rglru.rglru_init_cache(cfg, 2, jnp.float32)
    got = []
    for t in range(S):
        y, cache = rglru.rglru_step(p, x[:, t:t + 1], cache, cfg)
        got.append(np.asarray(y)[:, 0])
    got = np.stack(got, 1)
    np.testing.assert_allclose(got, full, atol=2e-4, rtol=2e-4)


def test_rglru_gate_stability():
    """|a_t| < 1 always (the recurrence cannot blow up)."""
    cfg = get_config("recurrentgemma-9b", reduced=True)
    p = rglru.init_rglru(cfg, KEY, jnp.float32)
    x = jnp.asarray(RNG.standard_normal((1, 32, cfg.d_model)) * 10, jnp.float32)
    xw = x @ p["wx"]
    xc = rglru._causal_conv(xw, p["conv_w"], p["conv_b"])
    a, _ = rglru._gates(p, xc)
    # a = exp(-c*softplus(lam)*r) can round to exactly 1.0 in f32 when the
    # recurrence gate saturates (r ~ 0); it must never exceed 1.
    assert float(jnp.max(a)) <= 1.0
    assert float(jnp.mean(a)) < 1.0
    assert float(jnp.min(a)) >= 0.0


# ---------------- perf-iteration variants ----------------

@pytest.mark.slow
def test_moe_local_dispatch_matches_global():
    """Per-sequence dispatch (perf iter 2) == global dispatch when capacity
    is ample (same routing, same experts, same weights)."""
    cfg = dataclasses.replace(get_config("qwen3-moe-30b-a3b", reduced=True),
                              capacity_factor=8.0)
    p = moe.init_moe(cfg, KEY, jnp.float32)
    x = jnp.asarray(RNG.standard_normal((3, 32, cfg.d_model)), jnp.float32)
    o_g, a_g = moe.apply_moe(p, x, cfg, local_dispatch=False)
    o_l, a_l = moe.apply_moe(p, x, cfg, local_dispatch=True)
    np.testing.assert_allclose(np.asarray(o_g), np.asarray(o_l),
                               atol=1e-6, rtol=1e-6)
    assert float(a_g) == pytest.approx(float(a_l), abs=1e-6)


def test_blockwise_attention_matches_reference():
    cfg = get_config("yi-9b", reduced=True)
    p = layers.init_attention(cfg, KEY, jnp.float32)
    x = jnp.asarray(RNG.standard_normal((2, 128, cfg.d_model)), jnp.float32)
    o_ref, _ = layers.attention_full(p, x, cfg)
    for block in (32, 64, 128):
        o_bw, _ = layers.attention_full(p, x, cfg, blockwise=block)
        np.testing.assert_allclose(np.asarray(o_bw), np.asarray(o_ref),
                                   atol=2e-5, rtol=2e-5)


@pytest.mark.slow
def test_blockwise_attention_grad_matches():
    cfg = get_config("yi-9b", reduced=True)
    p = layers.init_attention(cfg, KEY, jnp.float32)
    x = jnp.asarray(RNG.standard_normal((1, 64, cfg.d_model)), jnp.float32)

    def loss(params, blockwise):
        o, _ = layers.attention_full(params, x, cfg, blockwise=blockwise)
        return jnp.sum(o * o)

    g_ref = jax.grad(loss)(p, 0)
    g_bw = jax.grad(loss)(p, 32)
    for a, b in zip(jax.tree.leaves(g_ref), jax.tree.leaves(g_bw)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=5e-5, rtol=5e-5)


def test_blockwise_attention_window():
    cfg = get_config("recurrentgemma-9b", reduced=True)
    p = layers.init_attention(cfg, KEY, jnp.float32)
    x = jnp.asarray(RNG.standard_normal((2, 128, cfg.d_model)), jnp.float32)
    o_ref, _ = layers.attention_full(p, x, cfg, window=cfg.window)
    o_bw, _ = layers.attention_full(p, x, cfg, window=cfg.window, blockwise=32)
    np.testing.assert_allclose(np.asarray(o_bw), np.asarray(o_ref),
                               atol=2e-5, rtol=2e-5)


# ---------------- paper's analysis programs (VGG16 / ZF) ----------------

@pytest.mark.slow
def test_vgg_and_zf_forward():
    from repro.models import vgg
    key = jax.random.PRNGKey(0)
    x = jnp.asarray(RNG.standard_normal((2, 64, 64, 3)), jnp.float32)
    pv = vgg.init_vgg16(key, input_hw=64, num_classes=10)
    out = vgg.apply_vgg16(pv, x)
    assert out.shape == (2, 10)
    assert np.isfinite(np.asarray(out)).all()
    pz = vgg.init_zf(key, input_hw=64, num_classes=10)
    out = vgg.apply_zf(pz, x)
    assert out.shape == (2, 10)
    assert np.isfinite(np.asarray(out)).all()


def test_vgg_zf_relative_cost_matches_workload_model():
    """VGG16 is several times more expensive than ZF per frame — consistent
    with the CPU coefficients (16 vs 7.2 cores/fps) in core/workload.py."""
    from repro.models import vgg
    fv = vgg.flops_per_frame(vgg.VGG16_LAYOUT, 224)
    fz = vgg.flops_per_frame(vgg.ZF_LAYOUT, 224)
    assert 1.5 < fv / fz < 30


def test_moe_shard_map_matches_global():
    """Explicit expert-parallel shard_map MoE (perf iter B5) == the global
    dispatch when capacity is ample."""
    from repro.launch.mesh import make_smoke_mesh
    cfg = dataclasses.replace(get_config("qwen3-moe-30b-a3b", reduced=True),
                              capacity_factor=8.0)
    p = moe.init_moe(cfg, KEY, jnp.float32)
    x = jnp.asarray(RNG.standard_normal((2, 32, cfg.d_model)), jnp.float32)
    mesh = make_smoke_mesh()
    with mesh:
        o1, a1 = moe.apply_moe(p, x, cfg)
        o2, a2 = jax.jit(lambda p_, x_: moe.apply_moe_shard_map(
            p_, x_, cfg, mesh))(p, x)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2),
                               atol=1e-6, rtol=1e-6)
    assert float(a1) == pytest.approx(float(a2), abs=1e-6)
