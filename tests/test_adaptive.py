"""Adaptive runtime management [6,14]: rush-hour demand swings."""
from repro.core import (AdaptiveManager, ResourceManager, Stream,
                        fig3_catalog)
from repro.core.workload import PROGRAMS


def rush_hour_fps(t: int) -> float:
    """Demand profile: quiet nights (0.2 fps), rush-hour peaks (6 fps)."""
    if t % 24 in (8, 9, 17, 18):
        return 6.0
    if t % 24 in (7, 10, 16, 19):
        return 2.0
    return 0.2


def make_streams(fps: float):
    return [Stream(f"cam{i}", PROGRAMS["ZF"], fps=fps) for i in range(4)]


def test_adaptive_tracks_demand():
    mgr = AdaptiveManager(ResourceManager(fig3_catalog()), strategy="ST3")
    costs = []
    for t in range(48):
        plan = mgr.step(t, make_streams(rush_hour_fps(t)))
        costs.append(plan.hourly_cost)
    # cheap at night, more expensive at peak
    assert min(costs) < max(costs)
    # static provisioning for the peak would cost max(costs) all day
    static_cost = max(costs) * 48
    assert mgr.total_cost() < 0.6 * static_cost, \
        "adaptive must beat peak-static provisioning by a wide margin"


def test_forced_replan_on_spike():
    mgr = AdaptiveManager(ResourceManager(fig3_catalog()), strategy="ST3")
    mgr.step(0, make_streams(0.2))
    mgr.step(1, make_streams(6.0))     # current plan cannot serve 6 fps
    kinds = [e.action for e in mgr.events]
    assert kinds[0] == "replan"
    assert kinds[1] == "forced-replan"


def test_hysteresis_avoids_thrash():
    mgr = AdaptiveManager(ResourceManager(fig3_catalog()), strategy="ST3",
                          savings_threshold=0.10)
    mgr.step(0, make_streams(1.0))
    # tiny demand decrease: savings below threshold -> keep
    mgr.step(1, make_streams(0.98))
    assert mgr.events[1].action == "keep"
    assert mgr.events[1].migrations == 0
