"""Adaptive runtime management [6,14]: rush-hour demand swings."""
from repro.core import (AdaptiveManager, ResourceManager, Stream,
                        fig3_catalog)
from repro.core.workload import PROGRAMS


def rush_hour_fps(t: int) -> float:
    """Demand profile: quiet nights (0.2 fps), rush-hour peaks (6 fps)."""
    if t % 24 in (8, 9, 17, 18):
        return 6.0
    if t % 24 in (7, 10, 16, 19):
        return 2.0
    return 0.2


def make_streams(fps: float):
    return [Stream(f"cam{i}", PROGRAMS["ZF"], fps=fps) for i in range(4)]


def test_adaptive_tracks_demand():
    mgr = AdaptiveManager(ResourceManager(fig3_catalog()), strategy="ST3")
    costs = []
    for t in range(48):
        plan = mgr.step(t, make_streams(rush_hour_fps(t)))
        costs.append(plan.hourly_cost)
    # cheap at night, more expensive at peak
    assert min(costs) < max(costs)
    # static provisioning for the peak would cost max(costs) all day
    static_cost = max(costs) * 48
    assert mgr.total_cost() < 0.6 * static_cost, \
        "adaptive must beat peak-static provisioning by a wide margin"


def test_forced_replan_on_spike():
    mgr = AdaptiveManager(ResourceManager(fig3_catalog()), strategy="ST3")
    mgr.step(0, make_streams(0.2))
    mgr.step(1, make_streams(6.0))     # current plan cannot serve 6 fps
    kinds = [e.action for e in mgr.events]
    assert kinds[0] == "replan"
    assert kinds[1] == "forced-replan"


def test_hysteresis_avoids_thrash():
    mgr = AdaptiveManager(ResourceManager(fig3_catalog()), strategy="ST3",
                          savings_threshold=0.10)
    mgr.step(0, make_streams(1.0))
    # tiny demand decrease: savings below threshold -> keep
    mgr.step(1, make_streams(0.98))
    assert mgr.events[1].action == "keep"
    assert mgr.events[1].migrations == 0
    # kept plan means the current plan object is unchanged
    assert mgr.current is mgr.step(2, make_streams(0.98))


def _mini_plan(assignment: dict[str, int]):
    """Tiny synthetic Plan: stream key -> choice index (0 or 1)."""
    from repro.core.packing import Bin, Choice, Item, Problem, Solution
    from repro.core.strategies import Plan

    choices = (Choice("cA", "tA", "x", (10.0,), 1.0),
               Choice("cB", "tB", "x", (10.0,), 2.0))
    items = tuple(Item(k, ((1.0,), (1.0,))) for k in assignment)
    bins: dict[int, Bin] = {}
    for i, c in enumerate(assignment.values()):
        bins.setdefault(c, Bin(choice=c, items=[])).items.append(i)
    cost = sum(choices[b.choice].price for b in bins.values())
    sol = Solution(bins=list(bins.values()), cost=cost, note="mini")
    return Plan(solution=sol,
                problem=Problem(choices=choices, items=items),
                strategy="ST3")


def test_count_migrations():
    from repro.core.adaptive import _count_migrations

    old = _mini_plan({"a": 0, "b": 0, "c": 1})
    assert _count_migrations(old, _mini_plan({"a": 0, "b": 0, "c": 1})) == 0
    # one stream moves to a different instance
    assert _count_migrations(old, _mini_plan({"a": 0, "b": 1, "c": 1})) == 1
    # everything moves
    assert _count_migrations(old, _mini_plan({"a": 1, "b": 1, "c": 0})) == 3
    # a brand-new stream counts as a migration (it must be placed)
    assert _count_migrations(
        old, _mini_plan({"a": 0, "b": 0, "c": 1, "d": 0})) == 1
    # a departed stream does not
    assert _count_migrations(old, _mini_plan({"a": 0, "b": 0})) == 0


def test_total_cost_integrates_rush_hour_trace():
    """total_cost == the per-tick integral of the applied plan's hourly cost
    over a 48h rush-hour fps trace (1 tick = 1 hour)."""
    mgr = AdaptiveManager(ResourceManager(fig3_catalog()), strategy="ST3")
    integral = 0.0
    for t in range(48):
        plan = mgr.step(t, make_streams(rush_hour_fps(t)))
        integral += plan.hourly_cost
    assert len(mgr.events) == 48
    assert mgr.total_cost() == sum(e.hourly_cost for e in mgr.events)
    assert mgr.total_cost() == integral
    # the trace forces at least one replan in each direction of the swing
    kinds = {e.action for e in mgr.events}
    assert "forced-replan" in kinds and "keep" in kinds


def test_forced_replan_restores_feasibility():
    """After a forced replan on infeasible demand growth, the new plan must
    itself be feasible for the demanded rates."""
    mgr = AdaptiveManager(ResourceManager(fig3_catalog()), strategy="ST3")
    mgr.step(0, make_streams(0.2))
    spike = make_streams(6.0)
    plan = mgr.step(1, spike)
    assert mgr.events[1].action == "forced-replan"
    assert mgr.events[1].migrations > 0
    assert mgr._plan_feasible_for(plan, spike)
