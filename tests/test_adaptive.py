"""Adaptive runtime management [6,14]: rush-hour demand swings."""
import pytest

from repro.core import (AdaptiveManager, ResourceManager, Stream,
                        fig3_catalog)
from repro.core.workload import PROGRAMS


def rush_hour_fps(t: int) -> float:
    """Demand profile: quiet nights (0.2 fps), rush-hour peaks (6 fps)."""
    if t % 24 in (8, 9, 17, 18):
        return 6.0
    if t % 24 in (7, 10, 16, 19):
        return 2.0
    return 0.2


def make_streams(fps: float):
    return [Stream(f"cam{i}", PROGRAMS["ZF"], fps=fps) for i in range(4)]


def test_adaptive_tracks_demand():
    mgr = AdaptiveManager(ResourceManager(fig3_catalog()), strategy="ST3")
    costs = []
    for t in range(48):
        plan = mgr.step(t, make_streams(rush_hour_fps(t)))
        costs.append(plan.hourly_cost)
    # cheap at night, more expensive at peak
    assert min(costs) < max(costs)
    # static provisioning for the peak would cost max(costs) all day
    static_cost = max(costs) * 48
    assert mgr.total_cost() < 0.6 * static_cost, \
        "adaptive must beat peak-static provisioning by a wide margin"


def test_forced_replan_on_spike():
    mgr = AdaptiveManager(ResourceManager(fig3_catalog()), strategy="ST3")
    mgr.step(0, make_streams(0.2))
    mgr.step(1, make_streams(6.0))     # current plan cannot serve 6 fps
    kinds = [e.action for e in mgr.events]
    assert kinds[0] == "replan"
    assert kinds[1] == "forced-replan"


def test_hysteresis_avoids_thrash():
    mgr = AdaptiveManager(ResourceManager(fig3_catalog()), strategy="ST3",
                          savings_threshold=0.10)
    mgr.step(0, make_streams(1.0))
    # tiny demand decrease: savings below threshold -> keep
    mgr.step(1, make_streams(0.98))
    assert mgr.events[1].action == "keep"
    assert mgr.events[1].migrations == 0
    # kept plan means the current plan object is unchanged
    assert mgr.current is mgr.step(2, make_streams(0.98))


def _mini_plan(assignment: dict[str, int]):
    """Tiny synthetic Plan: stream key -> choice index (0 or 1)."""
    from repro.core.packing import Bin, Choice, Item, Problem, Solution
    from repro.core.strategies import Plan

    choices = (Choice("cA", "tA", "x", (10.0,), 1.0),
               Choice("cB", "tB", "x", (10.0,), 2.0))
    items = tuple(Item(k, ((1.0,), (1.0,))) for k in assignment)
    bins: dict[int, Bin] = {}
    for i, c in enumerate(assignment.values()):
        bins.setdefault(c, Bin(choice=c, items=[])).items.append(i)
    cost = sum(choices[b.choice].price for b in bins.values())
    sol = Solution(bins=list(bins.values()), cost=cost, note="mini")
    return Plan(solution=sol,
                problem=Problem(choices=choices, items=items),
                strategy="ST3")


def test_count_migrations():
    from repro.core.adaptive import _count_migrations

    old = _mini_plan({"a": 0, "b": 0, "c": 1})
    assert _count_migrations(old, _mini_plan({"a": 0, "b": 0, "c": 1})) == 0
    # one stream moves to a different instance
    assert _count_migrations(old, _mini_plan({"a": 0, "b": 1, "c": 1})) == 1
    # everything moves
    assert _count_migrations(old, _mini_plan({"a": 1, "b": 1, "c": 0})) == 3
    # a brand-new stream is an arrival, not a migration: it has no prior
    # placement, so placing it is a boot — nothing physically moves
    assert _count_migrations(
        old, _mini_plan({"a": 0, "b": 0, "c": 1, "d": 0})) == 0
    # ...and an arrival alongside a real move counts exactly the move
    assert _count_migrations(
        old, _mini_plan({"a": 0, "b": 1, "c": 1, "d": 0})) == 1
    # a departed stream does not migrate either
    assert _count_migrations(old, _mini_plan({"a": 0, "b": 0})) == 0


def test_total_cost_integrates_rush_hour_trace():
    """total_cost == the per-tick integral of the applied plan's hourly cost
    over a 48h rush-hour fps trace (1 tick = 1 hour)."""
    mgr = AdaptiveManager(ResourceManager(fig3_catalog()), strategy="ST3")
    integral = 0.0
    for t in range(48):
        plan = mgr.step(t, make_streams(rush_hour_fps(t)))
        integral += plan.hourly_cost
    assert len(mgr.events) == 48
    assert mgr.total_cost() == sum(e.hourly_cost for e in mgr.events)
    assert mgr.total_cost() == integral
    # the trace forces at least one replan in each direction of the swing
    kinds = {e.action for e in mgr.events}
    assert "forced-replan" in kinds and "keep" in kinds


def test_forced_replan_restores_feasibility():
    """After a forced replan on infeasible demand growth, the new plan must
    itself be feasible for the demanded rates."""
    mgr = AdaptiveManager(ResourceManager(fig3_catalog()), strategy="ST3")
    mgr.step(0, make_streams(0.2))
    spike = make_streams(6.0)
    plan = mgr.step(1, spike)
    assert mgr.events[1].action == "forced-replan"
    assert mgr.events[1].migrations > 0
    assert mgr._plan_feasible_for(plan, spike)


# -- _plan_feasible_for edge cases -------------------------------------------

def test_plan_feasible_for_ignores_departed_streams():
    """A departed stream leaves spare capacity behind; the plan stays
    feasible for the survivors and the manager keeps it."""
    mgr = AdaptiveManager(ResourceManager(fig3_catalog()), strategy="ST3")
    plan = mgr.step(0, make_streams(1.0))
    survivors = make_streams(1.0)[:2]
    assert mgr._plan_feasible_for(plan, survivors)
    assert mgr.step(1, survivors) is plan
    assert mgr.events[1].action == "keep"


def test_plan_feasible_for_requirement_none_mid_plan():
    """A stream whose new rate no longer fits its instance type at all
    (requirement_for returns None) makes the plan infeasible: ZF at 8 fps
    needs 57.6 cores — no CPU instance can run it."""
    mgr = AdaptiveManager(ResourceManager(fig3_catalog()), strategy="ST1")
    plan = mgr.step(0, make_streams(0.4))     # ST1 places on CPU instances
    hot = make_streams(8.0)
    assert not mgr._plan_feasible_for(plan, hot)


def test_plan_feasible_for_capacity_overflow_mid_plan():
    """Rates that still *individually* fit the type but overflow the shared
    bin make the plan infeasible (fits() fails, not requirement None)."""
    mgr = AdaptiveManager(ResourceManager(fig3_catalog()), strategy="ST3")
    plan = mgr.step(0, make_streams(0.2))
    warm = make_streams(0.9)                  # each fits alone; sum does not
    if mgr._plan_feasible_for(plan, warm):
        pytest.skip("packing left enough head-room; not an overflow case")
    mgr.step(1, warm)
    assert mgr.events[1].action == "forced-replan"


def test_plan_feasible_for_unplaced_stream_and_force_flag():
    mgr = AdaptiveManager(ResourceManager(fig3_catalog()), strategy="ST3")
    plan = mgr.step(0, make_streams(0.2))
    # a stream the plan never placed -> infeasible (churn arrival)
    arrived = make_streams(0.2) + [Stream("newcam", PROGRAMS["ZF"], fps=0.2)]
    assert not mgr._plan_feasible_for(plan, arrived)
    # force=True bypasses the feasibility check entirely: same demand, yet
    # the step is a forced replan (spot preemption replay path)
    same = make_streams(0.2)
    assert mgr._plan_feasible_for(plan, same)
    mgr.step(1, same, force=True)
    assert mgr.events[1].action == "forced-replan"


# -- repair mode -------------------------------------------------------------

def test_repair_mode_keeps_placements_on_forced_replan():
    """strategy="REPAIR": a forced replan with unchanged demand is a no-op
    placement-wise — zero migrations, same assignment."""
    from repro.core import plan_assignment

    mgr = AdaptiveManager(ResourceManager(fig3_catalog()), strategy="REPAIR")
    streams = make_streams(1.0)
    plan = mgr.step(0, streams)
    before = plan_assignment(plan)
    after = mgr.step(1, make_streams(1.0), force=True)
    assert mgr.events[1].action == "forced-replan"
    assert mgr.events[1].migrations == 0
    assert not mgr.events[1].defrag
    assert plan_assignment(after) == before


def test_repair_mode_records_defrag_event():
    from repro.core import RepairConfig

    mgr = AdaptiveManager(ResourceManager(fig3_catalog()), strategy="REPAIR",
                          repair=RepairConfig(defrag_ratio=1.0))
    mgr.step(0, make_streams(6.0))
    # demand collapse: repaired cost >= fresh cost -> the hatch fires
    mgr.step(1, make_streams(0.2), force=True)
    assert mgr.events[1].action == "forced-replan"
    assert mgr.events[1].defrag
    assert mgr.defrags() == 1
    assert mgr.total_migrations() == mgr.events[1].migrations
