"""Data pipeline, optimizer, checkpointing, serving engine, TPU catalog."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import restore_checkpoint, save_checkpoint
from repro.data.pipeline import InputShape, SHAPES, input_specs, make_batch
from repro.models import model as M
from repro.models.config import get_config
from repro.optim import AdamWConfig, adamw_init, adamw_update, cosine_schedule

KEY = jax.random.PRNGKey(0)


# ---------------- data ----------------

def test_shapes_match_assignment():
    assert SHAPES["train_4k"].seq_len == 4096
    assert SHAPES["train_4k"].global_batch == 256
    assert SHAPES["prefill_32k"].seq_len == 32768
    assert SHAPES["prefill_32k"].global_batch == 32
    assert SHAPES["decode_32k"].global_batch == 128
    assert SHAPES["long_500k"].seq_len == 524288
    assert SHAPES["long_500k"].global_batch == 1


def test_batch_determinism():
    cfg = get_config("yi-9b", reduced=True)
    shape = InputShape("t", 32, 2, "train")
    b1 = make_batch(cfg, shape, seed=7)
    b2 = make_batch(cfg, shape, seed=7)
    np.testing.assert_array_equal(np.asarray(b1["tokens"]),
                                  np.asarray(b2["tokens"]))
    b3 = make_batch(cfg, shape, seed=8)
    assert not np.array_equal(np.asarray(b1["tokens"]),
                              np.asarray(b3["tokens"]))


def test_vlm_batch_masks_patch_labels():
    cfg = get_config("internvl2-1b", reduced=True)
    shape = InputShape("t", 64, 2, "train")
    b = make_batch(cfg, shape, seed=0)
    labels = np.asarray(b["labels"])
    assert (labels[:, : cfg.num_patches] == -100).all()
    assert b["tokens"].shape[1] == 64 - cfg.num_patches


def test_input_specs_match_batches():
    for arch in ("yi-9b", "internvl2-1b", "hubert-xlarge"):
        cfg = get_config(arch, reduced=True)
        shape = InputShape("t", 64, 2, "train")
        specs = input_specs(cfg, shape, dtype=jnp.float32)
        batch = make_batch(cfg, shape, seed=0)
        assert set(specs) == set(batch)
        for k in specs:
            assert specs[k].shape == batch[k].shape, k


# ---------------- optimizer ----------------

def test_adamw_optimizes_quadratic():
    params = {"w": jnp.asarray([5.0, -3.0])}
    cfg = AdamWConfig(lr=0.3, weight_decay=0.0)
    state = adamw_init(params, cfg)
    loss = lambda p: jnp.sum(p["w"] ** 2)
    for _ in range(100):
        g = jax.grad(loss)(params)
        params, state, _ = adamw_update(params, g, state, cfg)
    assert float(loss(params)) < 1e-2


def test_grad_clip_bounds_update():
    params = {"w": jnp.zeros(4)}
    cfg = AdamWConfig(lr=1.0, clip_norm=1.0, weight_decay=0.0)
    state = adamw_init(params, cfg)
    huge = {"w": jnp.full(4, 1e6)}
    _, _, m = adamw_update(params, huge, state, cfg)
    assert float(m["grad_norm"]) == pytest.approx(2e6, rel=1e-3)


def test_cosine_schedule_shape():
    assert float(cosine_schedule(jnp.asarray(0), warmup=10, total=100)) == 0.0
    mid = float(cosine_schedule(jnp.asarray(10), warmup=10, total=100))
    assert mid == pytest.approx(1.0, abs=1e-6)
    end = float(cosine_schedule(jnp.asarray(100), warmup=10, total=100))
    assert end == pytest.approx(0.1, abs=1e-6)


# ---------------- checkpoint ----------------

def test_checkpoint_roundtrip(tmp_path):
    cfg = get_config("olmo-1b", reduced=True)
    params = M.init_params(cfg, KEY, jnp.float32)
    path = os.path.join(tmp_path, "ckpt.npz")
    save_checkpoint(path, params, meta={"arch": cfg.name})
    like = jax.tree.map(lambda x: jnp.zeros_like(x), params)
    restored = restore_checkpoint(path, like)
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert os.path.exists(path + ".meta.json")


# ---------------- serving ----------------

@pytest.mark.slow
def test_serving_engine_matches_manual_decode():
    """Engine greedy decode == manual prefill+decode loop."""
    cfg = get_config("olmo-1b", reduced=True)
    params = M.init_params(cfg, KEY, jnp.float32)
    opts = M.ModelOptions(remat=False)
    from repro.serving import Request, ServingEngine
    eng = ServingEngine(cfg, params, max_batch=2, cache_len=64, opts=opts)
    rng = np.random.default_rng(0)
    toks = rng.integers(0, cfg.vocab_size, 16).astype(np.int32)
    eng.submit(Request("r0", toks, max_new_tokens=5))
    done = eng.drain()
    got = done[0].output

    # manual reference
    logits, cache = M.prefill(params, {"tokens": jnp.asarray(toks)[None]},
                              cfg, opts, cache_len=64)
    want = []
    tok = jnp.argmax(logits, -1).astype(jnp.int32)
    for i in range(5):
        want.append(int(tok[0]))
        logits, cache = M.decode_step(params, tok, jnp.asarray(16 + i),
                                      cache, cfg, opts)
        tok = jnp.argmax(logits, -1).astype(jnp.int32)
    assert list(got) == want


def test_stream_simulator_rates():
    cfg = get_config("olmo-1b", reduced=True)
    params = M.init_params(cfg, KEY, jnp.float32)
    from repro.serving import ServingEngine, StreamSimulator
    eng = ServingEngine(cfg, params, max_batch=4, cache_len=48)
    sim = StreamSimulator(eng, prompt_len=8, new_tokens=2)
    n = sim.tick({"a": 3.0, "b": 1.0}, dt_s=2.0)
    assert n == 8                       # 3*2 + 1*2 frames
    done = eng.drain()
    assert len(done) == 8
    assert eng.stats["requests"] == 8


# ---------------- tpu catalog (beyond-paper) ----------------

def test_tpu_fleet_packing_dominates():
    from repro.core.tpu_catalog import LLMStream, plan_tpu_fleet
    streams = ([LLMStream(f"s{i}", "olmo-1b", tokens_per_s=40)
                for i in range(6)] +
               [LLMStream(f"b{i}", "yi-9b", tokens_per_s=30)
                for i in range(4)])
    per = plan_tpu_fleet(streams, strategy="per-stream")["hourly_cost"]
    uni = plan_tpu_fleet(streams, strategy="uniform-big")["hourly_cost"]
    packed = plan_tpu_fleet(streams, strategy="packed")["hourly_cost"]
    assert packed <= per and packed <= uni
    assert 1 - packed / per > 0.30      # the paper-style savings carry over


def test_tpu_requirements_scale_with_rate():
    from repro.core.tpu_catalog import LLMStream
    lo = LLMStream("a", "yi-9b", tokens_per_s=10).requirement()
    hi = LLMStream("b", "yi-9b", tokens_per_s=100).requirement()
    assert hi[0] > lo[0]                # compute scales with tokens/s
    assert hi[1] == lo[1]               # resident memory does not
