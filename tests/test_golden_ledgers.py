"""Golden-ledger regressions: the PR 2-4 benchmark numbers, pinned in tier-1.

``spot_heavy`` and ``rush_hour`` under the reactive and repair policies
(exactly the ``benchmarks/replan_churn.py`` configuration: 108 streams,
24 h, seed 0, repair with a 36-move budget and a 2.0 defrag ratio) are the
headline results the README quotes. Until now they were gated only in CI
benchmark jobs — a market/packing refactor that shifted a single packing
decision, one RNG draw, or one billed cent would sail through tier-1.
These tests pin the ledger totals **to the cent** (indeed to the exact
rounded-float totals), so any silent drift fails the suite.

PR 9 adds ``mega_city`` (1k-stream instance: the vectorized demand +
packed-planner path) and the content-aware ``roi_day`` pipeline scenario
(stage emission, density-driven activation) to the pinned set. The
pre-existing rows are **unchanged by the pipeline refactor** — stage
emission is a new demand model, not a change to stream demand — and the
new ``stage_items_peak``/``pooled_items_peak`` ledger columns are additive
(identically zero on every stream-demand scenario).

If a change legitimately moves these numbers, re-derive the goldens with
the snippet in each table's docstring and update README/docs in the same
commit — that is the point: drift must be loud and reviewed.
"""
import pytest

from repro.core.manager import ResourceManager
from repro.sim import FleetSimulator, ReactivePolicy, RepairPolicy, SCENARIOS

N_STREAMS = 108
DURATION_H = 24.0
SEED = 0

# mega_city is pinned at a 1k-stream instance (the 10k default belongs to
# the scale_sweep CI job, not tier-1)
N_OVERRIDE = {"mega_city": 1000}

# Golden totals as of PR 5 (identical to the PR 2-4 values; the new
# cost_ondemand/cost_spot/outbids ledger columns are additive), extended in
# PR 9 with the mega_city and roi_day rows. Regenerate:
#   PYTHONPATH=src python - <<'EOF'
#   from repro.core.manager import ResourceManager
#   from repro.sim import FleetSimulator, ReactivePolicy, RepairPolicy, SCENARIOS
#   for name, n in (("spot_heavy", 108), ("rush_hour", 108),
#                   ("roi_day", 108), ("mega_city", 1000)):
#       sc = SCENARIOS[name](n_streams=n, duration_h=24.0, seed=0)
#       cat = sc.catalog()
#       for label, pol in (("reactive", ReactivePolicy(ResourceManager(cat))),
#                          ("repair", RepairPolicy(ResourceManager(cat),
#                                                  migration_budget=36,
#                                                  defrag_ratio=2.0))):
#           print(name, label,
#                 FleetSimulator(sc.demand, pol, cat, sc.config).run().totals())
#   EOF
GOLDEN = {
    ("spot_heavy", "reactive"): {
        "ticks": 24,
        "total_cost": 224.922253,
        "frames_demanded": 11349752.4,
        "frames_analyzed": 10327841.223973,
        "frames_dropped": 1021911.176027,
        "slo_attainment": 0.909962,
        "migrations": 1588,
        "preemptions": 67,
        "defrags": 0,
    },
    ("spot_heavy", "repair"): {
        "ticks": 24,
        "total_cost": 216.247657,
        "frames_demanded": 11349752.4,
        "frames_analyzed": 10388353.893343,
        "frames_dropped": 961398.506657,
        "slo_attainment": 0.915293,
        "migrations": 584,
        "preemptions": 31,
        "defrags": 0,
    },
    ("rush_hour", "reactive"): {
        "ticks": 24,
        "total_cost": 440.07255,
        "frames_demanded": 11349752.4,
        "frames_analyzed": 11093271.66,
        "frames_dropped": 256480.74,
        "slo_attainment": 0.977402,
        "migrations": 1411,
        "preemptions": 0,
        "defrags": 0,
    },
    ("rush_hour", "repair"): {
        "ticks": 24,
        "total_cost": 407.8672,
        "frames_demanded": 11349752.4,
        "frames_analyzed": 11187993.06,
        "frames_dropped": 161759.34,
        "slo_attainment": 0.985748,
        "migrations": 408,
        "preemptions": 0,
        "defrags": 0,
    },
    # PR 9: content-aware pipelines. 108 cameras capture at a constant
    # 2 fps; the 252 demand items are *stages* (sid::stage) whose heavy
    # crop models activate with the diurnal scene-density curve — pinned
    # so the endogenous-demand math (activation clipping, milli-fps
    # rounding, stage requirement classes) cannot drift silently.
    ("roi_day", "reactive"): {
        "ticks": 24,
        "total_cost": 671.6444,
        "frames_demanded": 21641904.0,
        "frames_analyzed": 21405161.7,
        "frames_dropped": 236742.3,
        "slo_attainment": 0.989061,
        "migrations": 1905,
        "preemptions": 0,
        "defrags": 0,
        "stage_items_peak": 252,
        "pooled_items_peak": 0,
    },
    ("roi_day", "repair"): {
        "ticks": 24,
        "total_cost": 728.8338,
        "frames_demanded": 21641904.0,
        "frames_analyzed": 21590226.9,
        "frames_dropped": 51677.1,
        "slo_attainment": 0.997612,
        "migrations": 25,
        "preemptions": 0,
        "defrags": 0,
        "stage_items_peak": 252,
        "pooled_items_peak": 0,
    },
    # PR 9: the mega_city demand pipeline (vectorized diurnal + night mix
    # shift + EU flash crowd through the packed planner), pinned at a
    # 1k-stream instance so tier-1 guards the path the scale_sweep CI job
    # measures at 10k.
    ("mega_city", "reactive"): {
        "ticks": 24,
        "total_cost": 2606.7518,
        "frames_demanded": 62381354.4,
        "frames_analyzed": 61384287.24,
        "frames_dropped": 997067.16,
        "slo_attainment": 0.984017,
        "migrations": 14582,
        "preemptions": 0,
        "defrags": 0,
        "stage_items_peak": 0,
        "pooled_items_peak": 0,
    },
}

# instance-hours by location/type/market — the placement fingerprint; a
# packing-order change shows up here even when the dollar total survives
GOLDEN_HOURS = {
    ("spot_heavy", "repair"): {
        "ap-south-1/g3.8xlarge/spot": 13.811112,
        "us-east-1/c4.2xlarge/ondemand": 1.05,
        "us-east-1/g2.2xlarge/ondemand": 22.35,
        "us-east-1/g2.2xlarge/spot": 87.938125,
        "us-east-1/g3.8xlarge/ondemand": 20.05,
        "us-east-1/g3.8xlarge/spot": 96.885748,
    },
    ("rush_hour", "repair"): {
        "ap-south-1/g3.8xlarge/ondemand": 14.05,
        "us-east-1/c4.2xlarge/ondemand": 1.05,
        "us-east-1/g2.2xlarge/ondemand": 119.7,
        "us-east-1/g3.8xlarge/ondemand": 126.55,
    },
    # stage items pack per stage class: cheap full-frame detectors fill
    # CPU boxes while the pixel-share-scaled crop stages share GPUs — a
    # change to stage requirement classes moves hours between these rows
    # even if the dollar total happens to survive
    ("roi_day", "repair"): {
        "us-east-1/c4.2xlarge/ondemand": 75.1,
        "us-east-1/c4.8xlarge/ondemand": 24.0,
        "us-east-1/g2.2xlarge/ondemand": 764.0,
        "us-east-1/g3.8xlarge/ondemand": 72.0,
    },
}


def _run(scenario_name: str, policy_name: str):
    sc = SCENARIOS[scenario_name](
        n_streams=N_OVERRIDE.get(scenario_name, N_STREAMS),
        duration_h=DURATION_H, seed=SEED)
    cat = sc.catalog()
    if policy_name == "reactive":
        pol = ReactivePolicy(ResourceManager(cat))
    else:
        pol = RepairPolicy(ResourceManager(cat),
                           migration_budget=N_STREAMS // 3,
                           defrag_ratio=2.0)
    return FleetSimulator(sc.demand, pol, cat, sc.config).run()


@pytest.mark.parametrize("scenario,policy", sorted(GOLDEN))
def test_ledger_totals_match_golden(scenario, policy):
    led = _run(scenario, policy)
    totals = led.totals()
    golden = GOLDEN[(scenario, policy)]
    mismatched = {k: (totals[k], v) for k, v in golden.items()
                  if totals[k] != v}
    assert not mismatched, \
        f"{scenario}/{policy} ledger drifted from PR 2-4 goldens: {mismatched}"
    # the new spend-split columns must account for every cent
    assert totals["cost_ondemand"] + totals["cost_spot"] == \
        pytest.approx(totals["total_cost"], abs=5e-6)
    # legacy (hazard-governed) spot: no bid-based reclaims possible
    assert totals["outbids"] == 0
    # the PR-6 telemetry columns are additive too: without a recalibrating
    # policy they stay identically zero on the golden scenarios
    assert totals["recalibrations"] == 0
    assert totals["calib_max_rel_error"] == 0.0
    # and the PR-10 forecasting columns: without an MPC policy no capacity
    # is pre-booted and no forecast error is ever scored
    assert totals["preboots"] == 0
    assert totals["forecast_max_rel_error"] == 0.0
    if (scenario, policy) in GOLDEN_HOURS:
        assert totals["instance_hours"] == GOLDEN_HOURS[(scenario, policy)]
