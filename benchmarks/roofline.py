"""Roofline analysis over the dry-run records (deliverable g).

Per (arch x shape x mesh):
    compute term    = HLO_FLOPs_per_device / peak_FLOP/s
    memory term     = HLO_bytes_per_device / HBM_bw
    collective term = collective_bytes_per_device / link_bw
    MODEL_FLOPS     = 6*N*D (train) or 2*N_active*D (inference) per device
    ratio           = MODEL_FLOPS / HLO_FLOPs (useful-compute fraction)

Hardware: TPU v5e — 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI
(x4 links per chip on the 2D torus; we report per-link worst case).
"""
from __future__ import annotations

import json
import os
from typing import Optional

from repro.data.pipeline import SHAPES
from repro.models.config import get_config

PEAK_FLOPS = 197e12
HBM_BW = 819e9
LINK_BW = 50e9

DRYRUN_DIR = os.path.join(os.path.dirname(__file__), "..", "experiments",
                          "dryrun")


def model_flops_per_device(arch: str, shape_name: str, chips: int) -> float:
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    n_act = cfg.active_param_count()
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n_act * tokens / chips
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n_act * tokens / chips
    # decode: one token per sequence
    return 2.0 * n_act * shape.global_batch / chips


def load_record(arch: str, shape: str, mesh: str,
                dryrun_dir: str = DRYRUN_DIR,
                prefix: str = "") -> Optional[dict]:
    path = os.path.join(dryrun_dir, f"{prefix}{arch}_{shape}_{mesh}.json")
    if not os.path.exists(path):
        return None
    with open(path) as f:
        return json.load(f)


def roofline_row(rec: dict) -> Optional[dict]:
    if "skipped" in rec or "error" in rec:
        return None
    chips = 512 if rec["mesh"] == "pod2" else 256
    flops = rec["flops_per_device"]
    byts = rec["bytes_per_device"]
    coll = rec["collective_bytes_per_device"]
    t_compute = flops / PEAK_FLOPS
    t_memory = byts / HBM_BW
    t_coll = coll / LINK_BW
    terms = {"compute": t_compute, "memory": t_memory, "collective": t_coll}
    dominant = max(terms, key=terms.get)
    mf = model_flops_per_device(rec["arch"], rec["shape"], chips)
    return {
        "arch": rec["arch"], "shape": rec["shape"], "mesh": rec["mesh"],
        "attn": rec.get("attn", "full"),
        "compute_s": t_compute, "memory_s": t_memory, "collective_s": t_coll,
        "dominant": dominant,
        "model_flops_per_device": mf,
        "useful_ratio": mf / flops if flops else 0.0,
        "flops_per_device": flops,
        "bytes_per_device": byts,
        "collective_bytes_per_device": coll,
        "hbm_per_device_gib": sum(rec.get("memory", {}).get(k, 0) for k in
                                  ("argument_size_in_bytes",
                                   "temp_size_in_bytes",
                                   "output_size_in_bytes")) / chips / 2**30,
    }


def full_table(mesh: str = "pod1", dryrun_dir: str = DRYRUN_DIR,
               prefix: str = "") -> list[dict]:
    from repro.models.config import list_archs
    rows = []
    for arch in list_archs():
        for shape in SHAPES:
            rec = load_record(arch, shape, mesh, dryrun_dir, prefix)
            if rec is None:
                continue
            if "skipped" in rec:
                rows.append({"arch": arch, "shape": shape, "mesh": mesh,
                             "skipped": rec["skipped"]})
                continue
            row = roofline_row(rec)
            if row:
                rows.append(row)
    return rows


def format_table(rows: list[dict]) -> str:
    hdr = (f"{'arch':<22}{'shape':<13}{'attn':<8}{'compute_s':>10}"
           f"{'memory_s':>10}{'collect_s':>10}  {'dominant':<11}"
           f"{'useful':>7}{'hbm/dev':>9}")
    lines = [hdr, "-" * len(hdr)]
    for r in rows:
        if "skipped" in r:
            lines.append(f"{r['arch']:<22}{r['shape']:<13}SKIP: {r['skipped']}")
            continue
        lines.append(
            f"{r['arch']:<22}{r['shape']:<13}{r['attn']:<8}"
            f"{r['compute_s']:>10.4f}{r['memory_s']:>10.4f}"
            f"{r['collective_s']:>10.4f}  {r['dominant']:<11}"
            f"{r['useful_ratio']:>7.2f}{r['hbm_per_device_gib']:>8.2f}G")
    return "\n".join(lines)


def main() -> None:
    for mesh in ("pod1", "pod2"):
        rows = full_table(mesh)
        if rows:
            print(f"\n===== roofline ({mesh}: "
                  f"{512 if mesh == 'pod2' else 256} chips) =====")
            print(format_table(rows))


if __name__ == "__main__":
    main()
