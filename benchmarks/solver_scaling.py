"""Benchmark: solver scaling — exact BnB wall time and node counts vs problem
size, plus heuristic gap (replaces the paper's Gurobi timing discussion)."""
from __future__ import annotations

import time

from repro.core import ResourceManager, Stream, build_problem, fig6_catalog
from repro.core import geo
from repro.core.heuristics import first_fit_decreasing, lowest_price_first
from repro.core.solver import solve
from repro.core.workload import PROGRAMS


def run() -> list[dict]:
    cat = fig6_catalog()
    cams = list(geo.CAMERAS)
    rows = []
    for n in (6, 12, 24, 48):
        streams = [Stream(f"zf{i}", PROGRAMS["ZF"],
                          fps=0.5 + (i % 4) * 0.25,
                          camera=cams[i % len(cams)]) for i in range(n)]
        problem = build_problem(streams, cat, target_fps=None, rtt_filter=True)
        t0 = time.perf_counter()
        sol, stats = solve(problem, time_budget_s=20.0)
        us = (time.perf_counter() - t0) * 1e6
        ffd = first_fit_decreasing(problem)
        lpf = lowest_price_first(problem)
        gap_ffd = (ffd.cost - sol.cost) / sol.cost
        gap_lpf = (lpf.cost - sol.cost) / sol.cost
        rows.append({
            "name": f"solver_n{n}", "us_per_call": us,
            "derived": (f"${sol.cost:.2f} nodes={stats.nodes} "
                        f"optimal={stats.optimal} "
                        f"ffd_gap={100 * gap_ffd:.0f}% "
                        f"greedy_gap={100 * gap_lpf:.0f}%"),
        })
    return rows
