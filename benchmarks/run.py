"""Benchmark harness — one module per paper table/figure (+ beyond-paper).

Prints ``name,us_per_call,derived`` CSV per the repository convention, and a
roofline summary (from the dry-run artifacts) at the end.
"""
from __future__ import annotations

import sys


def main() -> None:
    from benchmarks import (adaptive_runtime, continuous_vs_static,
                            fig3_cpu_gpu, fig6_location, kernel_sweep,
                            roofline, solver_scaling, speedup_table,
                            table1_catalog, tpu_fleet)

    suites = [
        ("fig3 (CPU/GPU selection)", fig3_cpu_gpu.run),
        ("table1 (price disparity)", table1_catalog.run),
        ("fig6 (location strategies)", fig6_location.run),
        ("speedup (GPU vs fps)", speedup_table.run),
        ("adaptive (rush hour)", adaptive_runtime.run),
        ("solver scaling", solver_scaling.run),
        ("tpu fleet (beyond-paper)", tpu_fleet.run),
        ("continuous vs static batching (beyond-paper)",
         continuous_vs_static.run),
        ("pallas kernels (interpret-mode validation)", kernel_sweep.run),
    ]
    print("name,us_per_call,derived")
    mismatches = 0
    for title, fn in suites:
        print(f"# --- {title} ---")
        for row in fn():
            ok = row.get("match_paper")
            tail = "" if ok is None else ("  [MATCHES PAPER]" if ok
                                          else "  [MISMATCH]")
            if ok is False:
                mismatches += 1
            print(f"{row['name']},{row['us_per_call']:.1f},"
                  f"\"{row['derived']}{tail}\"")

    # roofline summary appendix (not CSV — table form)
    try:
        rows = roofline.full_table("pod1")
        if rows:
            print("\n# --- roofline (single pod, 256 chips; "
                  "full table in EXPERIMENTS.md) ---")
            print(roofline.format_table(rows))
    except Exception as e:                      # dry-run not executed yet
        print(f"# roofline skipped: {e}")

    if mismatches:
        print(f"# WARNING: {mismatches} cells mismatch the paper")
        sys.exit(1)


if __name__ == "__main__":
    main()
