"""Benchmark harness — one module per paper table/figure (+ beyond-paper).

Prints ``name,us_per_call,derived`` CSV per the repository convention, and a
roofline summary (from the dry-run artifacts) at the end. ``--only <suite>``
runs a single suite (e.g. ``--only fleet_sim`` as a CI smoke job) instead of
the full sweep; ``--list`` shows the suite keys.
"""
from __future__ import annotations

import argparse
import os
import sys

# Allow `python benchmarks/run.py` from the repo root without PYTHONPATH
# gymnastics: the harness needs the repo root (for `benchmarks.*`) and src/
# (for `repro.*`) importable.
_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
for _p in (_ROOT, os.path.join(_ROOT, "src")):
    if _p not in sys.path:
        sys.path.insert(0, _p)


# (key, title, module under benchmarks/). Modules import lazily so that
# `--only fleet_sim` (the CI smoke job) neither pays for nor breaks on the
# jax-heavy suites it does not run.
_SUITES: list[tuple[str, str, str]] = [
    ("fig3", "fig3 (CPU/GPU selection)", "fig3_cpu_gpu"),
    ("table1", "table1 (price disparity)", "table1_catalog"),
    ("fig6", "fig6 (location strategies)", "fig6_location"),
    ("speedup", "speedup (GPU vs fps)", "speedup_table"),
    ("adaptive", "adaptive (rush hour)", "adaptive_runtime"),
    ("solver", "solver scaling", "solver_scaling"),
    ("tpu_fleet", "tpu fleet (beyond-paper)", "tpu_fleet"),
    ("continuous", "continuous vs static batching (beyond-paper)",
     "continuous_vs_static"),
    ("fleet_sim", "fleet simulator (beyond-paper)", "fleet_sim"),
    ("replan_churn", "replan churn: REPAIR vs FFD full replan (beyond-paper)",
     "replan_churn"),
    ("spot_bidding", "spot bidding: mixed plans vs on-demand-only "
     "(beyond-paper)", "spot_bidding"),
    ("drift_recalibration", "drift recalibration: online vs stale profile "
     "(beyond-paper)", "drift_recalibration"),
    ("scale_sweep", "scale sweep: 100/1k/10k streams, packed vs scalar "
     "(beyond-paper)", "scale_sweep"),
    ("columnar_sweep", "columnar sweep: 1M-stream day, columnar vs object "
     "event loop (beyond-paper)", "columnar_sweep"),
    ("obs_export", "observability exporters + per-group recalibration "
     "(beyond-paper)", "obs_export"),
    ("pipeline_consolidation", "content-aware pipelines: crop consolidation "
     "vs per-camera stages (beyond-paper)", "pipeline_consolidation"),
    ("forecast_mpc", "seasonal forecast + MPC autoscaling vs reactive "
     "(beyond-paper)", "forecast_mpc"),
    ("kernels", "pallas kernels (interpret-mode validation)",
     "kernel_sweep"),
]


def main() -> None:
    import importlib

    suites = _SUITES
    keys = [k for k, _, _ in suites]
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--only", default=None, metavar="SUITE",
                    help="run a single suite instead of the full sweep "
                         f"(one of: {', '.join(keys)})")
    ap.add_argument("--list", action="store_true", help="list suite keys")
    args = ap.parse_args()
    if args.list:
        print("\n".join(keys))
        return
    if args.only is not None:
        if args.only not in keys:
            # a typo must fail loudly with the catalog, never run nothing
            ap.error(f"unknown suite {args.only!r}; known suites: "
                     f"{', '.join(keys)}")
        suites = [s for s in suites if s[0] == args.only]

    print("name,us_per_call,derived")
    mismatches = 0
    for _, title, mod in suites:
        print(f"# --- {title} ---")
        run_fn = importlib.import_module(f"benchmarks.{mod}").run
        for row in run_fn():
            ok = row.get("match_paper")
            tail = "" if ok is None else ("  [MATCHES PAPER]" if ok
                                          else "  [MISMATCH]")
            if ok is False:
                mismatches += 1
            print(f"{row['name']},{row['us_per_call']:.1f},"
                  f"\"{row['derived']}{tail}\"")

    # roofline summary appendix (not CSV — table form; full sweeps only)
    if args.only is None:
        from benchmarks import roofline
        try:
            rows = roofline.full_table("pod1")
            if rows:
                print("\n# --- roofline (single pod, 256 chips; "
                      "full table in EXPERIMENTS.md) ---")
                print(roofline.format_table(rows))
        except Exception as e:                  # dry-run not executed yet
            print(f"# roofline skipped: {e}")

    if mismatches:
        print(f"# WARNING: {mismatches} cells mismatch the paper")
        sys.exit(1)


if __name__ == "__main__":
    main()
