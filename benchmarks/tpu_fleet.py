"""Benchmark (BEYOND-PAPER): the paper's packing machinery allocating TPU v5e
slices to LLM serving streams, with requirement vectors derived from the
compiled dry-run. Strategies mirror the paper's ST1/ST2/ST3 comparison."""
from __future__ import annotations

import os
import time

from repro.core.tpu_catalog import LLMStream, plan_tpu_fleet

DRYRUN = os.path.join(os.path.dirname(__file__), "..", "experiments", "dryrun")


def run() -> list[dict]:
    streams = (
        [LLMStream(f"edge{i}", "olmo-1b", tokens_per_s=60) for i in range(8)]
        + [LLMStream(f"mid{i}", "yi-9b", tokens_per_s=40) for i in range(5)]
        + [LLMStream(f"ssm{i}", "mamba2-2.7b", tokens_per_s=80)
           for i in range(4)]
        + [LLMStream(f"moe{i}", "qwen3-moe-30b-a3b", tokens_per_s=50)
           for i in range(2)]
    )
    dr = DRYRUN if os.path.isdir(DRYRUN) else None
    rows = []
    costs = {}
    for st in ("per-stream", "uniform-big", "packed"):
        t0 = time.perf_counter()
        plan = plan_tpu_fleet(streams, dryrun_dir=dr, strategy=st)
        us = (time.perf_counter() - t0) * 1e6
        costs[st] = plan["hourly_cost"]
        rows.append({"name": f"tpu_fleet_{st}", "us_per_call": us,
                     "derived": f"${plan['hourly_cost']:.2f}/h "
                                f"{plan['instances']}"})
    sav = 1 - costs["packed"] / costs["per-stream"]
    rows.append({"name": "tpu_fleet_savings", "us_per_call": 0.0,
                 "derived": f"{100 * sav:.0f}% vs per-stream "
                            f"(paper's CPU/GPU result transfers to TPU slices)"})
    return rows
