"""Benchmark (BEYOND-PAPER): observability v2 — exporters and per-group drift.

Three gates over the new ``repro.obs`` surface:

1. **Per-group vs fleet-wide recalibration** (``regional_drift``): a
   three-region fleet whose serving rates regress in *one* region. Both
   arms run the identical seeded scenario, the same live
   ``windowed_rates()``-semantics probe, and the same repair-mode inner
   policy; the only difference is the loop's granularity:

   * **fleet-wide** — PR-6-style ``RecalibratingPolicy``: one detector
     over the fleet mean (the regression diluted to ~0.27, just above the
     0.25 threshold), re-profiles everything, unscoped repair;
   * **per-group** — ``RegionalRecalibratingPolicy``: one detector per
     region, re-profiles only the fired region's streams, repair scoped to
     the bins hosting them.

   Accepted when only the drifted region's detector fires, and the
   per-group arm matches or beats fleet-wide cost with *strictly fewer*
   migrations — fleet-wide consolidation spends its budget closing
   healthy-region tail bins (and colonizing the drifted region's freed
   capacity), which is exactly the disruption scoping exists to prevent.

2. **Lossless exports**: the JSONL metric file read back equals the hub's
   point stream exactly, and the Chrome-trace document reconstructs the
   tracer's span trees exactly (names, simulated times, wall-clock
   durations, attrs, nesting).

3. **Telemetry overhead**: the full ``mega_city`` day (24h x 10k streams)
   with the hub + JSONL exporter + aggregator attached must cost < 5%
   wall-clock over the same run with telemetry off (interleaved min-of-3).

``--out`` writes the summary JSON (uploaded as a CI artifact); ``--smoke``
exits non-zero on any violated bar.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import tempfile
import time

# runnable as `python benchmarks/obs_export.py` from the repo root
_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
for _p in (_ROOT, os.path.join(_ROOT, "src")):
    if _p not in sys.path:
        sys.path.insert(0, _p)

from repro.core.manager import ResourceManager
from repro.obs import (RecalibratingPolicy, RegionalRecalibratingPolicy,
                       Tracer, WindowedServiceProbe, hub_with_exporters,
                       load_jsonl_metrics, spans_from_chrome_trace,
                       write_chrome_trace)
from repro.sim import FleetSimulator, ReactivePolicy, RepairPolicy, SCENARIOS

N_STREAMS = 96
DURATION_H = 24.0
SEED = 0
SHIFT_AT_H = 12.0              # when regional_drift's regression lands
DRIFTED_REGION = "ap-northeast-1"
MIGRATION_BUDGET = N_STREAMS // 8

OVERHEAD_DURATION_H = 24.0     # the full mega_city day (matches the README
                               # row; the columnar loop made a 6h slice so
                               # fast that ~50ms of exporter I/O dominated)
OVERHEAD_STREAMS = 10_000

# acceptance bars
MAX_OVERHEAD = 0.05            # telemetry-on wall-clock vs telemetry-off
TIME_BUDGET_S = 90.0


def _conserved(ledger) -> bool:
    return all(abs(r.frames_demanded - r.frames_analyzed - r.frames_dropped)
               < 1e-6 * max(1.0, r.frames_demanded) for r in ledger.records)


def _spans_equal(a, b) -> bool:
    return (a.name == b.name and a.t == b.t and a.wall_ms == b.wall_ms
            and a.attrs == b.attrs and len(a.children) == len(b.children)
            and all(_spans_equal(x, y)
                    for x, y in zip(a.children, b.children)))


def _arm(sc, cat, regional: bool, jsonl_path=None):
    """One policy arm; identical probe semantics and inner policy both ways —
    only the detection/recalibration granularity differs."""
    inner = RepairPolicy(ResourceManager(cat),
                         migration_budget=MIGRATION_BUDGET,
                         defrag_ratio=1.25)
    hub, exporter, agg = hub_with_exporters(
        jsonl_path, histograms=("replan.wall_ms", "fleet.slo"))
    if regional:
        policy = RegionalRecalibratingPolicy(
            inner, sc.service, group_of=sc.groups.__getitem__,
            telemetry=hub, tracer=Tracer())
    else:
        policy = RecalibratingPolicy(
            inner, sc.service, probe=WindowedServiceProbe(sc.service),
            telemetry=hub, tracer=Tracer())
    ledger = FleetSimulator(sc.demand, policy, cat, sc.config,
                            service=sc.service, telemetry=hub).run()
    if exporter is not None:
        exporter.close()
    return policy, ledger, hub, agg


def compare(workdir: str) -> dict:
    sc = SCENARIOS["regional_drift"](n_streams=N_STREAMS,
                                     duration_h=DURATION_H, seed=SEED)
    cat = sc.catalog()
    jsonl_path = os.path.join(workdir, "regional_metrics.jsonl")
    trace_path = os.path.join(workdir, "regional_trace.json")

    t0 = time.perf_counter()
    fleet_policy, fleet, _, _ = _arm(sc, cat, regional=False)
    reg_policy, reg, hub, agg = _arm(sc, cat, regional=True,
                                     jsonl_path=jsonl_path)
    elapsed = time.perf_counter() - t0

    # -- export round-trips (gate 2) -------------------------------------
    loaded = load_jsonl_metrics(jsonl_path)
    jsonl_ok = loaded == hub.points
    write_chrome_trace(trace_path, reg_policy.tracer)
    rebuilt = spans_from_chrome_trace(trace_path)
    trace_ok = (len(rebuilt) == len(reg_policy.tracer.spans)
                and all(_spans_equal(x, y)
                        for x, y in zip(rebuilt, reg_policy.tracer.spans)))

    # -- per-region firing map (gate 1) ----------------------------------
    fired_ever = reg_policy.regional.fired_groups()
    per_region_err = {
        g: round(max((v.rel_error for v in det.history), default=0.0), 4)
        for g, det in sorted(reg_policy.regional.detectors.items())}
    fired_at = (reg_policy.recalibrations[0]
                if reg_policy.recalibrations else None)
    dt = sc.config.dt_h
    wall = agg.instruments["replan.wall_ms"].summary()

    ft, rt = fleet.totals(), reg.totals()
    return {
        "scenario": "regional_drift",
        "n_streams": N_STREAMS,
        "duration_h": DURATION_H,
        "seed": SEED,
        "shift_at_h": SHIFT_AT_H,
        "drifted_region": DRIFTED_REGION,
        "migration_budget": MIGRATION_BUDGET,
        "hold_ticks": reg_policy.regional.config.hold_ticks,
        "fleet_wide": ft,
        "per_group": rt,
        "fleet_recalibrations": len(fleet_policy.recalibrations),
        "group_recalibrations": reg_policy.recal_groups,
        "fired_groups": list(fired_ever),
        "per_region_max_rel_error": per_region_err,
        "fired_at_h": fired_at,
        "detect_latency_ticks": (None if fired_at is None
                                 else round((fired_at - SHIFT_AT_H) / dt, 3)),
        "cost_delta": round(rt["total_cost"] - ft["total_cost"], 4),
        "migrations_delta": rt["migrations"] - ft["migrations"],
        "slo_delta": round(reg.slo_attainment() - fleet.slo_attainment(), 6),
        "jsonl_points": len(loaded),
        "jsonl_roundtrip": jsonl_ok,
        "trace_spans": len(rebuilt),
        "trace_roundtrip": trace_ok,
        "replan_wall_ms": {k: wall.get(k) for k in
                           ("count", "p50", "p95", "p99")},
        "frames_conserved": _conserved(fleet) and _conserved(reg),
        "elapsed_s": round(elapsed, 2),
    }


def overhead() -> dict:
    """Telemetry-on vs telemetry-off wall clock on a mega_city slice."""
    def once(telemetry: bool) -> float:
        sc = SCENARIOS["mega_city"](n_streams=OVERHEAD_STREAMS,
                                    duration_h=OVERHEAD_DURATION_H, seed=SEED)
        cat = sc.catalog()
        policy = ReactivePolicy(ResourceManager(cat))
        if telemetry:
            with tempfile.TemporaryDirectory() as tmp:
                hub, exporter, agg = hub_with_exporters(
                    os.path.join(tmp, "mega.jsonl"))
                t0 = time.perf_counter()
                FleetSimulator(sc.demand, policy, cat, sc.config,
                               telemetry=hub).run()
                wall = time.perf_counter() - t0
                exporter.close()
            return wall
        t0 = time.perf_counter()
        FleetSimulator(sc.demand, policy, cat, sc.config).run()
        return time.perf_counter() - t0

    once(False)                                  # warm caches once
    # interleaved min-of-3: scheduler/thermal noise on ~2 s runs is larger
    # than the actual hub cost (a few hundred emits), so pair the samples
    # and let min() strip the noise from both arms symmetrically
    samples = [(once(False), once(True)) for _ in range(3)]
    t_off = min(s[0] for s in samples)
    t_on = min(s[1] for s in samples)
    rel = (t_on - t_off) / t_off if t_off > 0 else 0.0
    return {"streams": OVERHEAD_STREAMS, "duration_h": OVERHEAD_DURATION_H,
            "wall_off_s": round(t_off, 3), "wall_on_s": round(t_on, 3),
            "overhead": round(rel, 4)}


def check_acceptance(r: dict, o: dict, total_elapsed: float) -> list[str]:
    """Returns a list of violated acceptance bars (empty = pass)."""
    bad = []
    if r["fired_groups"] != [r["drifted_region"]]:
        bad.append(f"fired regions {r['fired_groups']} != "
                   f"[{r['drifted_region']}] (only the drifted region "
                   "should fire)")
    if r["fleet_recalibrations"] < 1:
        bad.append("fleet-wide baseline never recalibrated "
                   "(comparison would be vacuous)")
    if r["fired_at_h"] is None:
        bad.append("per-group detector never fired")
    elif r["detect_latency_ticks"] > r["hold_ticks"] + 1:
        # windowed probe: a mid-window shift reaches full magnitude one
        # window later than the instantaneous probe sees it
        bad.append(f"detection latency {r['detect_latency_ticks']} ticks "
                   f"> hold_ticks+1 = {r['hold_ticks'] + 1}")
    if r["cost_delta"] > 0:
        bad.append(f"per-group cost exceeds fleet-wide by {r['cost_delta']}")
    if r["migrations_delta"] >= 0:
        bad.append(f"per-group migrations not strictly fewer "
                   f"(delta {r['migrations_delta']:+d})")
    if not r["jsonl_roundtrip"]:
        bad.append("JSONL metric export did not round-trip losslessly")
    if not r["trace_roundtrip"]:
        bad.append("Chrome-trace export did not round-trip losslessly")
    if not r["replan_wall_ms"]["count"]:
        bad.append("replan.wall_ms histogram is empty")
    if not r["frames_conserved"]:
        bad.append("ledger frame conservation violated")
    if o["overhead"] > MAX_OVERHEAD:
        bad.append(f"telemetry overhead {o['overhead']:.1%} "
                   f"> {MAX_OVERHEAD:.0%}")
    if total_elapsed > TIME_BUDGET_S:
        bad.append(f"suite took {total_elapsed:.1f}s > {TIME_BUDGET_S:.0f}s")
    return bad


def _collect() -> tuple[dict, dict, list[str], float]:
    t0 = time.perf_counter()
    with tempfile.TemporaryDirectory() as workdir:
        r = compare(workdir)
    o = overhead()
    total_elapsed = time.perf_counter() - t0
    return r, o, check_acceptance(r, o, total_elapsed), total_elapsed


def run() -> list[dict]:
    """Harness entry (benchmarks/run.py): CSV rows with acceptance flags."""
    r, o, violations, total_elapsed = _collect()
    return [{
        "name": "obs_export_regional_drift",
        "us_per_call": r["elapsed_s"] * 1e6,
        "derived": (f"fired {','.join(r['fired_groups'])} "
                    f"t={r['fired_at_h']} cost "
                    f"{r['fleet_wide']['total_cost']:.2f}->"
                    f"{r['per_group']['total_cost']:.2f} "
                    f"migrations {r['fleet_wide']['migrations']}->"
                    f"{r['per_group']['migrations']}"),
        "match_paper": not violations,
    }, {
        "name": "obs_export_roundtrip",
        "us_per_call": r["elapsed_s"] * 1e6,
        "derived": (f"jsonl {r['jsonl_points']} pts "
                    f"{'ok' if r['jsonl_roundtrip'] else 'LOSSY'}; "
                    f"trace {r['trace_spans']} spans "
                    f"{'ok' if r['trace_roundtrip'] else 'LOSSY'}"),
        "match_paper": r["jsonl_roundtrip"] and r["trace_roundtrip"],
    }, {
        "name": "obs_export_overhead",
        "us_per_call": o["wall_on_s"] * 1e6,
        "derived": (f"mega_city {o['duration_h']}h telemetry "
                    f"{o['wall_off_s']}s->{o['wall_on_s']}s "
                    f"({o['overhead']:+.1%})"),
        "match_paper": o["overhead"] <= MAX_OVERHEAD,
    }, {
        "name": "obs_export_acceptance",
        "us_per_call": total_elapsed * 1e6,
        "derived": "all bars met" if not violations else "; ".join(violations),
        "match_paper": not violations,
    }]


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="run the acceptance gates and exit non-zero on any "
                         "violated bar (CI gate)")
    ap.add_argument("--out", default=None,
                    help="write the summary JSON here")
    args = ap.parse_args(argv)

    r, o, violations, total_elapsed = _collect()

    print(f"regional_drift  regression in {r['drifted_region']} at "
          f"t={r['shift_at_h']}h; per-group detector fired "
          f"{r['fired_groups']} at t={r['fired_at_h']}h "
          f"(+{r['detect_latency_ticks']} ticks, hold={r['hold_ticks']})")
    print(f"  cost fleet-wide {r['fleet_wide']['total_cost']:.2f} vs "
          f"per-group {r['per_group']['total_cost']:.2f} "
          f"({r['cost_delta']:+.2f})  migrations "
          f"{r['fleet_wide']['migrations']} vs "
          f"{r['per_group']['migrations']} ({r['migrations_delta']:+d})  "
          f"SLO {r['slo_delta']:+.4f}")
    print(f"  exports: jsonl {r['jsonl_points']} points "
          f"roundtrip={r['jsonl_roundtrip']}; chrome trace "
          f"{r['trace_spans']} spans roundtrip={r['trace_roundtrip']}; "
          f"replan wall_ms p99={r['replan_wall_ms']['p99']}")
    print(f"  overhead: mega_city {o['duration_h']}h x {o['streams']} "
          f"streams {o['wall_off_s']}s -> {o['wall_on_s']}s "
          f"({o['overhead']:+.1%}, bar {MAX_OVERHEAD:.0%})")

    summary = {"result": r, "overhead": o, "violations": violations,
               "elapsed_s": round(total_elapsed, 2),
               "bars": {"max_overhead": MAX_OVERHEAD,
                        "max_detect_latency_ticks": r["hold_ticks"] + 1,
                        "time_budget_s": TIME_BUDGET_S}}
    if args.out:
        os.makedirs(os.path.dirname(os.path.abspath(args.out)), exist_ok=True)
        with open(args.out, "w") as f:
            json.dump(summary, f, indent=2, sort_keys=True)
        print(f"summary written to {args.out}")

    if violations:
        print("ACCEPTANCE " + ("FAILED" if args.smoke else "bars violated")
              + ":\n  " + "\n  ".join(violations))
        return 1 if args.smoke else 0
    print(f"acceptance ok in {total_elapsed:.1f}s "
          f"(budget {TIME_BUDGET_S:.0f}s)")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
