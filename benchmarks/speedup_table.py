"""Benchmark: GPU speedup vs frame rate (paper: 'up to 16 times' at the
highest rates, '<5%' at the lowest) — the fact driving CPU/GPU choice."""
from __future__ import annotations

from repro.core.workload import VGG16, ZF


def run() -> list[dict]:
    rows = []
    for prog in (VGG16, ZF):
        for fps in (0.2, 0.25, 0.5, 1.0, 2.0, 4.0, 8.0, 16.0):
            if fps > prog.max_gpu_fps():
                continue
            sp = prog.gpu_speedup(fps)
            rows.append({"name": f"speedup_{prog.name}_{fps}fps",
                         "us_per_call": 0.0,
                         "derived": f"{sp:.2f}x"})
        peak = prog.max_gpu_fps() / prog.max_cpu_fps(7.2)
        rows.append({"name": f"speedup_{prog.name}_peak", "us_per_call": 0.0,
                     "derived": f"{peak:.1f}x (paper: up to 16x)"})
    return rows
