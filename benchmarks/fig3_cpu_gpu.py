"""Benchmark: Fig. 3 — CPU/GPU instance selection, 3 scenarios x 3 strategies.

Emits the full table (instance counts, hourly cost, savings) and checks every
cell against the paper's published numbers.
"""
from __future__ import annotations

import time

from repro.core import (FIG3_SCENARIOS, ResourceManager, fig3_catalog,
                        make_streams)

PAPER = {
    (1, "ST1"): ("4/-", 1.676, 0.0), (1, "ST2"): ("-/1", 0.650, 0.61),
    (1, "ST3"): ("-/1", 0.650, 0.61),
    (2, "ST1"): ("1/-", 0.419, 0.36), (2, "ST2"): ("-/1", 0.650, 0.0),
    (2, "ST3"): ("1/-", 0.419, 0.36),
    (3, "ST1"): ("Fail", None, None), (3, "ST2"): ("-/11", 7.150, 0.0),
    (3, "ST3"): ("1/10", 6.919, 0.03),
}


def run() -> list[dict]:
    mgr = ResourceManager(fig3_catalog())
    rows = []
    for sc, spec in FIG3_SCENARIOS.items():
        streams = make_streams(spec)
        costs = {}
        for st in ("ST1", "ST2", "ST3"):
            t0 = time.perf_counter()
            plan = mgr.plan_or_fail(streams, st)
            us = (time.perf_counter() - t0) * 1e6
            if plan is None:
                rows.append({"name": f"fig3_s{sc}_{st}", "us_per_call": us,
                             "derived": "Fail", "match_paper":
                             PAPER[(sc, st)][1] is None})
                costs[st] = None
                continue
            s = plan.summary()
            costs[st] = s["hourly_cost"]
            want = PAPER[(sc, st)]
            derived = (f"${s['hourly_cost']:.3f} "
                       f"cpu={s['non_gpu_instances']} gpu={s['gpu_instances']}")
            rows.append({
                "name": f"fig3_s{sc}_{st}", "us_per_call": us,
                "derived": derived,
                "match_paper": (want[1] is not None and
                                abs(s["hourly_cost"] - want[1]) < 1e-3),
            })
        # savings rows (vs the strategy the paper compares against)
        base = {1: "ST1", 2: "ST2", 3: "ST2"}[sc]
        if costs.get("ST3") and costs.get(base):
            sav = 1 - costs["ST3"] / costs[base]
            rows.append({"name": f"fig3_s{sc}_savings", "us_per_call": 0.0,
                         "derived": f"{100 * sav:.0f}% vs {base}",
                         "match_paper": True})
    return rows
