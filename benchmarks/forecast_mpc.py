"""Benchmark (BEYOND-PAPER): seasonal forecasting + model-predictive
autoscaling vs the reactive baseline.

Arms on three scenario days (fixed seeds, identical demand per arm):

* **reactive** — ``ReactivePolicy``: replan when infeasible or when a
  fresh plan saves >= 10%; capacity always trails demand by one boot
  window, and on ``spot_heavy`` it rides hazard-preempted spot capacity.
* **mpc** — ``SeasonalForecaster`` warmed on the *previous* day (every
  scenario's demand is a pure seeded function of time, so replaying
  yesterday is legitimate history) + ``MPCPolicy`` in mixed-market mode
  with no on-demand floor: each tick plans the forecast envelope
  (pre-booting capacity ahead of ramps), co-optimizes boot lead / replan
  cadence / bid aggressiveness every 6 h from forecast plan costs, and
  bids spot capacity via ``LookaheadBid`` so reclaims price the real
  boot-window SLO loss. A live ``TelemetryHub`` feeds realized fleet
  demand back into the forecaster's scale correction during the run.

Scenarios: ``follow_the_sun`` (108 worldwide streams, rotating peaks +
night program shift), ``spot_heavy`` (108 US streams, 85% spot with an
0.12/h reclaim hazard), ``mega_city`` (1000 streams at benchmark scale:
diurnal + mix shift + a 4x EU evening flash crowd the forecast must
pre-boot for).

Acceptance (asserted here and in CI via ``--smoke``): on every scenario
the MPC arm's cost is <= the reactive arm's and its SLO attainment is
>= reactive − 0.005; the MPC arm pre-boots on every scenario
(``preboots > 0`` — the forecast is actually driving capacity ahead of
demand); frames are conserved in both arms; and the whole suite finishes
in under 120 s. ``--out`` writes the summary JSON (uploaded as a CI
artifact).
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

# runnable as `python benchmarks/forecast_mpc.py` from the repo root
_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
for _p in (_ROOT, os.path.join(_ROOT, "src")):
    if _p not in sys.path:
        sys.path.insert(0, _p)

from repro.core.manager import ResourceManager
from repro.obs import TelemetryHub
from repro.sim import (FleetSimulator, MPCConfig, MPCPolicy, ReactivePolicy,
                       SeasonalForecaster)
from repro.sim.scenarios import follow_the_sun, mega_city, spot_heavy

SEED = 0
SCENARIO_ARMS = (("follow_the_sun", follow_the_sun, 108),
                 ("spot_heavy", spot_heavy, 108),
                 ("mega_city", mega_city, 1000))

# acceptance bars (ISSUE 10): cost no worse than reactive, SLO within the
# tolerance below reactive (it lands well above in practice), the forecast
# actually pre-booting, and a CI wall-clock budget
MAX_SLO_LOSS = 0.005
TIME_BUDGET_S = 120.0

# one MPC configuration for all three scenarios — the point of the
# co-optimizer is that lead/cadence/bids adapt per scenario on their own
MPC_CFG = MPCConfig(slo_floor=0.999)
WARMUP_H = 24.0


def _conserved(ledger) -> bool:
    return all(abs(r.frames_demanded - r.frames_analyzed - r.frames_dropped)
               < 1e-6 * max(1.0, r.frames_demanded) for r in ledger.records)


def _summarize(ledger, elapsed: float) -> dict:
    return {"totals": ledger.totals(),
            "slo": ledger.slo_attainment(),
            "frames_conserved": _conserved(ledger),
            "elapsed_s": round(elapsed, 2)}


def _run_scenario(factory, n_streams: int) -> dict:
    sc = factory(n_streams, seed=SEED)
    cat = sc.catalog()

    t0 = time.perf_counter()
    led_r = FleetSimulator(sc.demand, ReactivePolicy(ResourceManager(cat)),
                           cat, sc.config).run()
    reactive = _summarize(led_r, time.perf_counter() - t0)

    t0 = time.perf_counter()
    forecaster = SeasonalForecaster()
    forecaster.warmup(sc.demand, WARMUP_H)       # "yesterday's" demand
    policy = MPCPolicy(ResourceManager(cat), forecaster=forecaster,
                       spot=True, floor_frac=0.0, config=MPC_CFG)
    hub = TelemetryHub()                          # live feature source
    policy.attach_telemetry(hub)
    led_m = FleetSimulator(sc.demand, policy, cat, sc.config,
                           telemetry=hub).run()
    mpc = _summarize(led_m, time.perf_counter() - t0)
    mpc["chosen"] = {"lead_h": policy.lead_h, "cadence_h": policy.cadence_h,
                     "slo_weight": policy.bidding.slo_weight}

    return {"reactive": reactive, "mpc": mpc,
            "cost_reduction": round(
                1.0 - mpc["totals"]["total_cost"]
                / reactive["totals"]["total_cost"], 4),
            "slo_delta": round(mpc["slo"] - reactive["slo"], 6)}


def compare_arms() -> dict:
    return {name: _run_scenario(fab, n) for name, fab, n in SCENARIO_ARMS}


def check_acceptance(arms: dict, total_elapsed: float) -> list[str]:
    """Returns a list of violated acceptance bars (empty = pass)."""
    bad = []
    for name, res in arms.items():
        m, r = res["mpc"], res["reactive"]
        if m["totals"]["total_cost"] > r["totals"]["total_cost"]:
            bad.append(f"{name}: mpc cost ${m['totals']['total_cost']:.2f} "
                       f"> reactive ${r['totals']['total_cost']:.2f}")
        if m["slo"] < r["slo"] - MAX_SLO_LOSS:
            bad.append(f"{name}: mpc SLO {m['slo']:.6f} more than "
                       f"{MAX_SLO_LOSS} below reactive {r['slo']:.6f}")
        if m["totals"]["preboots"] <= 0:
            bad.append(f"{name}: mpc never pre-booted capacity")
        for arm in ("mpc", "reactive"):
            if not res[arm]["frames_conserved"]:
                bad.append(f"{name}/{arm}: frame conservation violated")
    if total_elapsed > TIME_BUDGET_S:
        bad.append(f"suite took {total_elapsed:.1f}s > {TIME_BUDGET_S:.0f}s")
    return bad


def run() -> list[dict]:
    """Harness entry (benchmarks/run.py): CSV rows with acceptance flags."""
    t0 = time.perf_counter()
    arms = compare_arms()
    violations = check_acceptance(arms, time.perf_counter() - t0)
    rows = []
    for name, res in arms.items():
        m, r = res["mpc"], res["reactive"]
        rows.append({
            "name": f"forecast_mpc_{name}",
            "us_per_call": m["elapsed_s"] * 1e6,
            "derived": (f"{res['cost_reduction']:.1%} cheaper "
                        f"SLO {m['slo']:.4f} vs {r['slo']:.4f} "
                        f"preboots {m['totals']['preboots']} "
                        f"lead {m['chosen']['lead_h']:g}h"),
            "match_paper": (m["totals"]["total_cost"]
                            <= r["totals"]["total_cost"]
                            and m["slo"] >= r["slo"] - MAX_SLO_LOSS
                            and m["totals"]["preboots"] > 0),
        })
    rows.append({
        "name": "forecast_mpc_acceptance",
        "us_per_call": (time.perf_counter() - t0) * 1e6,
        "derived": "all bars met" if not violations else "; ".join(violations),
        "match_paper": not violations,
    })
    return rows


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="run the acceptance comparison and exit non-zero "
                         "on any violated bar (CI gate)")
    ap.add_argument("--out", default=None,
                    help="write the summary JSON here")
    args = ap.parse_args(argv)

    t0 = time.perf_counter()
    arms = compare_arms()
    total_elapsed = time.perf_counter() - t0
    violations = check_acceptance(arms, total_elapsed)

    for name, res in arms.items():
        m, r = res["mpc"], res["reactive"]
        print(f"{name:16s} reactive ${r['totals']['total_cost']:8.2f} "
              f"SLO {r['slo']:.4f}  [{r['elapsed_s']}s]")
        print(f"{'':16s} mpc      ${m['totals']['total_cost']:8.2f} "
              f"SLO {m['slo']:.4f}  ({res['cost_reduction']:.1%} cheaper, "
              f"SLO {res['slo_delta']:+.4f})  "
              f"preboots {m['totals']['preboots']}  "
              f"lead {m['chosen']['lead_h']:g}h "
              f"cadence {m['chosen']['cadence_h']:g}h  [{m['elapsed_s']}s]")

    summary = {"arms": arms, "violations": violations,
               "elapsed_s": round(total_elapsed, 2),
               "bars": {"max_cost_ratio": 1.0,
                        "max_slo_loss": MAX_SLO_LOSS,
                        "min_preboots": 1,
                        "time_budget_s": TIME_BUDGET_S}}
    if args.out:
        os.makedirs(os.path.dirname(os.path.abspath(args.out)) or ".",
                    exist_ok=True)
        with open(args.out, "w") as f:
            json.dump(summary, f, indent=2, sort_keys=True)
        print(f"summary written to {args.out}")

    if violations:
        print("ACCEPTANCE " + ("FAILED" if args.smoke else "bars violated")
              + ":\n  " + "\n  ".join(violations))
        return 1 if args.smoke else 0
    print(f"acceptance ok in {total_elapsed:.1f}s "
          f"(budget {TIME_BUDGET_S:.0f}s)")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
