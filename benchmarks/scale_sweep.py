"""Benchmark (BEYOND-PAPER): 100 -> 1k -> 10k stream scale sweep.

Gates the vectorized planning stack (packed ``build_problem`` + batched
demand + array FFD) against the scalar (pre-refactor) path:

* **speedup**: demand evaluation + ``build_problem`` at 10k streams must be
  >= 20x faster packed than scalar (measured over representative ticks of
  the ``mega_city`` day);
* **parity**: plans and ledgers must be *bit-identical* between the two
  paths — full 24 h ledger at 100 streams, plans at night/peak/flash ticks
  plus a 6 h ledger at 1k streams;
* **wall-clock**: the 24 h x 10k-stream ``mega_city`` run under the
  reactive policy must finish in < 120 s.

``main()`` writes a JSON summary (CI uploads it as an artifact) and exits
non-zero if any gate fails; ``run()`` returns the harness row format.
"""
from __future__ import annotations

import argparse
import json
import sys
import time

import os

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
for _p in (_ROOT, os.path.join(_ROOT, "src")):
    if _p not in sys.path:
        sys.path.insert(0, _p)

from repro.core import packed
from repro.core.manager import ResourceManager
from repro.core.strategies import build_problem, ffd_greedy
from repro.sim import FleetSimulator, ReactivePolicy, SCENARIOS

SIZES = (100, 1_000, 10_000)
SPEEDUP_TICKS = tuple(float(t) for t in range(24))   # the whole simulated day
SPEEDUP_FLOOR = 20.0
WALL_BUDGET_S = 120.0
PARITY_PLAN_TICKS = (3.0, 8.5, 17.5)


def _pipeline_time(scenario, catalog, ticks) -> float:
    """Seconds for demand evaluation + problem construction over ``ticks``."""
    total = 0.0
    for t in ticks:
        t0 = time.perf_counter()
        streams = scenario.demand.streams_at(t)
        build_problem(streams, catalog, rtt_filter=True)
        total += time.perf_counter() - t0
    return total


def _simulate(scenario):
    cat = scenario.catalog()
    policy = ReactivePolicy(ResourceManager(cat))
    return FleetSimulator(scenario.demand, policy, cat, scenario.config).run()


def run() -> list[dict]:
    rows = []
    summary: dict = {"sizes": {}, "parity": {}, "gates": {}}

    # -- speedup sweep: packed vs scalar demand + build_problem ------------
    for n in SIZES:
        sc = SCENARIOS["mega_city"](n_streams=n)
        cat = sc.catalog()
        _pipeline_time(sc, cat, SPEEDUP_TICKS)          # warm caches
        # best of 2: the packed pass is cheap enough that scheduler noise
        # dominates a single sample, and the gate should measure the code
        t_packed = min(_pipeline_time(sc, cat, SPEEDUP_TICKS)
                       for _ in range(2))
        sc_s = SCENARIOS["mega_city"](n_streams=n)
        with packed.scalar_mode():
            # warm the scalar side's shared demand memos too (MixShift
            # selection, churn schedules) so both paths are measured warm;
            # a second full scalar build pass would double the job's cost
            # for noise the gate margin does not need
            for t in SPEEDUP_TICKS:
                sc_s.demand.streams_at(t)
            t_scalar = _pipeline_time(sc_s, sc_s.catalog(), SPEEDUP_TICKS)
        speedup = t_scalar / t_packed if t_packed > 0 else float("inf")
        summary["sizes"][str(n)] = {
            "packed_s": round(t_packed, 4), "scalar_s": round(t_scalar, 4),
            "speedup": round(speedup, 1), "ticks": len(SPEEDUP_TICKS)}
        gate = speedup >= SPEEDUP_FLOOR if n == 10_000 else None
        rows.append({
            "name": f"scale_sweep_build_{n}",
            "us_per_call": t_packed / len(SPEEDUP_TICKS) * 1e6,
            "derived": f"demand+build {n} streams: packed {t_packed:.2f}s "
                       f"scalar {t_scalar:.2f}s ({speedup:.1f}x"
                       f"{f', gate >={SPEEDUP_FLOOR:.0f}x' if gate is not None else ''})",
            "match_paper": gate,
        })
        if n == 10_000:
            summary["gates"]["speedup_10k"] = bool(gate)

    # -- parity at 100 streams: full 24h ledgers bit-identical -------------
    t0 = time.perf_counter()
    led_p = _simulate(SCENARIOS["mega_city"](n_streams=100))
    with packed.scalar_mode():
        led_s = _simulate(SCENARIOS["mega_city"](n_streams=100))
    ok100 = led_p.signature() == led_s.signature()
    us = (time.perf_counter() - t0) * 1e6
    summary["parity"]["ledger_100"] = bool(ok100)
    rows.append({"name": "scale_sweep_parity_100", "us_per_call": us,
                 "derived": "24h ledger bit-identical packed vs scalar"
                 if ok100 else "LEDGER MISMATCH at 100 streams",
                 "match_paper": ok100})

    # -- parity at 1k streams: plans at key ticks + 6h ledger --------------
    t0 = time.perf_counter()
    sc = SCENARIOS["mega_city"](n_streams=1_000)
    cat = sc.catalog()
    ok_plans = True
    for t in PARITY_PLAN_TICKS:
        streams = sc.demand.streams_at(t)
        sig_p = ffd_greedy(streams, cat).signature()
        with packed.scalar_mode():
            sig_s = ffd_greedy(sc.demand.streams_at(t), cat).signature()
        ok_plans = ok_plans and sig_p == sig_s
    led_p = _simulate(SCENARIOS["mega_city"](n_streams=1_000, duration_h=6.0))
    with packed.scalar_mode():
        led_s = _simulate(SCENARIOS["mega_city"](n_streams=1_000,
                                                 duration_h=6.0))
    ok1k = ok_plans and led_p.signature() == led_s.signature()
    us = (time.perf_counter() - t0) * 1e6
    summary["parity"]["plans_and_ledger_1k"] = bool(ok1k)
    rows.append({"name": "scale_sweep_parity_1k", "us_per_call": us,
                 "derived": f"plans at t={PARITY_PLAN_TICKS} + 6h ledger "
                            "bit-identical packed vs scalar"
                 if ok1k else "PLAN/LEDGER MISMATCH at 1k streams",
                 "match_paper": ok1k})
    summary["gates"]["parity"] = bool(ok100 and ok1k)

    # -- the mega_city day at full scale -----------------------------------
    sc = SCENARIOS["mega_city"]()
    t0 = time.perf_counter()
    led = _simulate(sc)
    wall = time.perf_counter() - t0
    ok_wall = wall < WALL_BUDGET_S
    summary["mega_city"] = {
        "streams": 10_000, "duration_h": sc.config.duration_h,
        "wall_s": round(wall, 1), "budget_s": WALL_BUDGET_S,
        "total_cost": round(led.total_cost, 2),
        "slo_attainment": round(led.slo_attainment(), 4),
        "migrations": led.migrations,
        "peak_instances": max(r.instances_live for r in led.records),
    }
    summary["gates"]["wall_clock"] = bool(ok_wall)
    rows.append({
        "name": "scale_sweep_mega_city", "us_per_call": wall * 1e6,
        "derived": f"24h x 10k streams in {wall:.1f}s (budget "
                   f"{WALL_BUDGET_S:.0f}s) ${led.total_cost:.0f} "
                   f"SLO {led.slo_attainment():.4f} "
                   f"peak {summary['mega_city']['peak_instances']} instances",
        "match_paper": ok_wall,
    })

    run._summary = summary          # stashed for main()'s JSON artifact
    return rows


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default=None, metavar="JSON",
                    help="write the machine-readable summary here")
    args = ap.parse_args()

    t0 = time.perf_counter()
    rows = run()
    failed = [r["name"] for r in rows if r.get("match_paper") is False]
    for r in rows:
        tag = {True: "  [OK]", False: "  [FAIL]"}.get(r.get("match_paper"), "")
        print(f"{r['name']:28s} {r['derived']}{tag}")
    summary = run._summary
    summary["total_s"] = round(time.perf_counter() - t0, 1)
    if args.out:
        with open(args.out, "w") as f:
            json.dump(summary, f, indent=2, sort_keys=True)
        print(f"summary written to {args.out}")
    if failed:
        print(f"GATES FAILED: {', '.join(failed)}")
        sys.exit(1)
    print(f"acceptance ok in {summary['total_s']}s")


if __name__ == "__main__":
    main()
