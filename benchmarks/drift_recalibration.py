"""Benchmark (BEYOND-PAPER): online recalibration vs a stale startup profile.

The paper profiles serving throughput once and packs from that calibration
forever. ``drifting_scene`` breaks that assumption: at mid-day the fleet's
*true* serving rates regress to 35% of the startup profile
(``obs.DriftingService``). Both arms run the identical seeded scenario with
the truth capping analyzed frames, so neither can over-serve:

* **stale** — ``RecalibratingPolicy`` with an infinite drift threshold:
  profiles once at startup, never recalibrates, keeps renting capacity the
  service can no longer absorb (same code path as the online arm, belief
  frozen);
* **online** — the default ``DriftDetector`` (25% mean relative error held
  3 ticks) re-profiles on firing and forces a min-migration repair replan
  packed to the measured sustainable rates.

Acceptance (asserted here and in CI via ``--smoke``): the detector fires
within ``hold_ticks`` ticks of the injected regression, online recalibration
saves >= 8% total cost vs stale, SLO attainment drops by at most 0.005
(boot-window transients of the consolidation replan — the truth cap keeps
served frames equal otherwise), frame conservation holds on both ledgers,
and the whole suite finishes in under 60 s. ``--out`` writes the summary
JSON (uploaded as a CI artifact).
"""
from __future__ import annotations

import argparse
import json
import math
import os
import sys
import time

# runnable as `python benchmarks/drift_recalibration.py` from the repo root
_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
for _p in (_ROOT, os.path.join(_ROOT, "src")):
    if _p not in sys.path:
        sys.path.insert(0, _p)

from repro.core.manager import ResourceManager
from repro.obs import (DriftConfig, DriftDetector, RecalibratingPolicy,
                       TelemetryHub, Tracer)
from repro.sim import FleetSimulator, RepairPolicy, SCENARIOS

N_STREAMS = 72
DURATION_H = 24.0
SEED = 0
SHIFT_AT_H = 12.0          # when drifting_scene's regression lands

# acceptance bars
MIN_SAVINGS = 0.08         # online total cost <= 92% of stale
MAX_SLO_LOSS = 0.005       # replan boot transients; truth caps both arms
TIME_BUDGET_S = 60.0


def _conserved(ledger) -> bool:
    return all(abs(r.frames_demanded - r.frames_analyzed - r.frames_dropped)
               < 1e-6 * max(1.0, r.frames_demanded) for r in ledger.records)


def _arm(sc, cat, online: bool):
    """One policy arm over the scenario; identical code path both ways —
    the stale arm just carries a detector that can never fire."""
    inner = RepairPolicy(ResourceManager(cat),
                         migration_budget=N_STREAMS // 3,
                         defrag_ratio=1.25)
    cfg = DriftConfig() if online else DriftConfig(rel_threshold=math.inf)
    policy = RecalibratingPolicy(inner, sc.service,
                                 detector=DriftDetector(cfg),
                                 telemetry=TelemetryHub(), tracer=Tracer())
    ledger = FleetSimulator(sc.demand, policy, cat, sc.config,
                            service=sc.service,
                            telemetry=policy.telemetry).run()
    return policy, ledger


def compare() -> dict:
    sc = SCENARIOS["drifting_scene"](n_streams=N_STREAMS,
                                     duration_h=DURATION_H, seed=SEED)
    cat = sc.catalog()
    t0 = time.perf_counter()
    stale_policy, stale = _arm(sc, cat, online=False)
    online_policy, online = _arm(sc, cat, online=True)
    elapsed = time.perf_counter() - t0
    hold = online_policy.detector.config.hold_ticks
    fired_at = (online_policy.recalibrations[0]
                if online_policy.recalibrations else None)
    dt = sc.config.dt_h
    return {
        "scenario": "drifting_scene",
        "n_streams": N_STREAMS,
        "duration_h": DURATION_H,
        "seed": SEED,
        "shift_at_h": SHIFT_AT_H,
        "hold_ticks": hold,
        "stale": stale.totals(),
        "online": online.totals(),
        "fired_at_h": fired_at,
        "detect_latency_ticks": (None if fired_at is None
                                 else round((fired_at - SHIFT_AT_H) / dt, 3)),
        "recalibrations": len(online_policy.recalibrations),
        "cost_savings": round(1.0 - online.total_cost / stale.total_cost, 4),
        "slo_delta": round(online.slo_attainment()
                           - stale.slo_attainment(), 6),
        "telemetry_points": len(online_policy.telemetry.points),
        "trace_spans": len(online_policy.tracer.spans),
        "frames_conserved": _conserved(stale) and _conserved(online),
        "elapsed_s": round(elapsed, 2),
    }


def check_acceptance(r: dict, total_elapsed: float) -> list[str]:
    """Returns a list of violated acceptance bars (empty = pass)."""
    bad = []
    if r["fired_at_h"] is None:
        bad.append("drift detector never fired")
    elif r["detect_latency_ticks"] > r["hold_ticks"]:
        bad.append(f"detection latency {r['detect_latency_ticks']} ticks "
                   f"> hold_ticks {r['hold_ticks']}")
    if r["cost_savings"] < MIN_SAVINGS:
        bad.append(f"cost savings {r['cost_savings']:.1%} "
                   f"< {MIN_SAVINGS:.0%}")
    if r["slo_delta"] < -MAX_SLO_LOSS:
        bad.append(f"SLO delta {r['slo_delta']:+.4f} "
                   f"< -{MAX_SLO_LOSS}")
    if not r["frames_conserved"]:
        bad.append("ledger frame conservation violated")
    if total_elapsed > TIME_BUDGET_S:
        bad.append(f"suite took {total_elapsed:.1f}s > {TIME_BUDGET_S:.0f}s")
    return bad


def run() -> list[dict]:
    """Harness entry (benchmarks/run.py): CSV rows with acceptance flags."""
    t0 = time.perf_counter()
    r = compare()
    violations = check_acceptance(r, time.perf_counter() - t0)
    return [{
        "name": "drift_recalibration_drifting_scene",
        "us_per_call": r["elapsed_s"] * 1e6,
        "derived": (f"fired t={r['fired_at_h']} "
                    f"(+{r['detect_latency_ticks']} ticks) "
                    f"cost {r['stale']['total_cost']:.2f}->"
                    f"{r['online']['total_cost']:.2f} "
                    f"({r['cost_savings']:.1%} saved) "
                    f"SLO {r['slo_delta']:+.4f} "
                    f"recals {r['recalibrations']}"),
        "match_paper": not violations,
    }, {
        "name": "drift_recalibration_acceptance",
        "us_per_call": (time.perf_counter() - t0) * 1e6,
        "derived": "all bars met" if not violations else "; ".join(violations),
        "match_paper": not violations,
    }]


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="run the acceptance comparison and exit non-zero "
                         "on any violated bar (CI gate)")
    ap.add_argument("--out", default=None,
                    help="write the summary JSON here")
    args = ap.parse_args(argv)

    t0 = time.perf_counter()
    r = compare()
    total_elapsed = time.perf_counter() - t0
    violations = check_acceptance(r, total_elapsed)

    print(f"drifting_scene  regression at t={r['shift_at_h']}h, detector "
          f"fired at t={r['fired_at_h']}h "
          f"(+{r['detect_latency_ticks']} ticks, "
          f"hold={r['hold_ticks']})")
    print(f"  cost {r['stale']['total_cost']:.2f} -> "
          f"{r['online']['total_cost']:.2f} "
          f"({r['cost_savings']:.1%} saved)  "
          f"SLO {r['stale']['slo_attainment']:.4f} -> "
          f"{r['online']['slo_attainment']:.4f} "
          f"({r['slo_delta']:+.4f})  "
          f"recals {r['recalibrations']}  "
          f"conserved={r['frames_conserved']}  [{r['elapsed_s']}s]")
    print(f"  telemetry points {r['telemetry_points']}  "
          f"trace spans {r['trace_spans']}")

    summary = {"result": r, "violations": violations,
               "elapsed_s": round(total_elapsed, 2),
               "bars": {"min_cost_savings": MIN_SAVINGS,
                        "max_slo_loss": MAX_SLO_LOSS,
                        "max_detect_latency_ticks": r["hold_ticks"],
                        "time_budget_s": TIME_BUDGET_S}}
    if args.out:
        os.makedirs(os.path.dirname(os.path.abspath(args.out)), exist_ok=True)
        with open(args.out, "w") as f:
            json.dump(summary, f, indent=2, sort_keys=True)
        print(f"summary written to {args.out}")

    if violations:
        print("ACCEPTANCE " + ("FAILED" if args.smoke else "bars violated")
              + ":\n  " + "\n  ".join(violations))
        return 1 if args.smoke else 0
    print(f"acceptance ok in {total_elapsed:.1f}s "
          f"(budget {TIME_BUDGET_S:.0f}s)")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
