"""Benchmark: Table I — catalog price disparities across locations (the fact
motivating location optimization: the same instance can cost 60%+ more)."""
from __future__ import annotations

from repro.core import table1_catalog


def run() -> list[dict]:
    rows = []
    cat = table1_catalog()
    worst = 0.0
    for t in cat.types:
        lo_loc, lo = t.cheapest_location()
        hi_loc = max(t.prices, key=t.prices.__getitem__)
        hi = t.prices[hi_loc]
        disparity = hi / lo - 1
        worst = max(worst, disparity)
        rows.append({"name": f"table1_{t.name}", "us_per_call": 0.0,
                     "derived": (f"${lo:.3f}@{lo_loc} .. ${hi:.3f}@{hi_loc} "
                                 f"(+{100 * disparity:.0f}%)")})
    rows.append({"name": "table1_max_disparity", "us_per_call": 0.0,
                 "derived": f"{100 * worst:.0f}% (paper: 'can exceed 60%')"})
    return rows
