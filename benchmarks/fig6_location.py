"""Benchmark: Fig. 6 — cost vs target frame rate for NL / ARMVAC / GCL
(+ our beyond-paper ARMVAC+), worldwide camera set.
"""
from __future__ import annotations

import time

from repro.core import ResourceManager, Stream, fig6_catalog
from repro.core import geo
from repro.core.packing import Infeasible
from repro.core.workload import PROGRAMS

FPS_SWEEP = (0.2, 0.5, 1.0, 2.0, 5.0, 10.0, 15.0, 20.0, 25.0)


def run() -> list[dict]:
    mgr = ResourceManager(fig6_catalog())
    streams = [Stream(f"zf-{c}", PROGRAMS["ZF"], fps=1.0, camera=c)
               for c in geo.CAMERAS]
    rows = []
    best_vs_nl = 0.0
    best_vs_armvac = 0.0
    for fps in FPS_SWEEP:
        costs = {}
        for st in ("NL", "ARMVAC", "ARMVAC+", "GCL"):
            t0 = time.perf_counter()
            try:
                costs[st] = mgr.plan(streams, st, target_fps=fps).hourly_cost
            except Infeasible:
                costs[st] = None
            us = (time.perf_counter() - t0) * 1e6
            rows.append({"name": f"fig6_fps{fps}_{st}", "us_per_call": us,
                         "derived": ("Fail" if costs[st] is None
                                     else f"${costs[st]:.3f}")})
        if costs["GCL"] and costs["NL"]:
            best_vs_nl = max(best_vs_nl, 1 - costs["GCL"] / costs["NL"])
        if costs["GCL"] and costs["ARMVAC"]:
            best_vs_armvac = max(best_vs_armvac,
                                 1 - costs["GCL"] / costs["ARMVAC"])
    rows.append({"name": "fig6_max_savings_vs_NL", "us_per_call": 0.0,
                 "derived": f"{100 * best_vs_nl:.0f}% (paper: up to 56%)"})
    rows.append({"name": "fig6_max_savings_vs_ARMVAC", "us_per_call": 0.0,
                 "derived": f"{100 * best_vs_armvac:.0f}% (paper: up to 31%)"})
    return rows
