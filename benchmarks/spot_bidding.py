"""Benchmark (BEYOND-PAPER): spot bidding — mixed on-demand/spot plans vs
the on-demand-only baseline.

Arms on ``spot_heavy`` (24h x 108 streams, fixed seed, random spot boots
disabled so *all* spot capacity comes from bids):

* on-demand-only — ``ReactivePolicy``, every instance at list price;
* ``SpotBidPolicy`` under three bidding strategies: fixed-margin,
  percentile-of-history, and the lookahead policy that minimizes the
  expected effective price (spot payment vs preemption boot-window loss).

Both arms replay the identical seeded demand and price walk (prices are
exogenous — the walk never depends on the policy; asserted in tier-1).

Acceptance (asserted here and in CI via ``--smoke``): the lookahead mixed
plan is >= 15% cheaper than on-demand-only with an SLO no more than 0.5%
worse; packed-vs-scalar ledger parity holds for the ``spot_bidder``
scenario at 100 and 1k streams (bit-identical ledger signatures); and the
whole suite finishes in under 60 s. ``--out`` writes the summary JSON
(uploaded as a CI artifact).
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import os
import sys
import time

# runnable as `python benchmarks/spot_bidding.py` from the repo root
_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
for _p in (_ROOT, os.path.join(_ROOT, "src")):
    if _p not in sys.path:
        sys.path.insert(0, _p)

from repro.core import packed as packed_mod
from repro.core.manager import ResourceManager
from repro.core.markets import spot_affinity_violations
from repro.sim import (FixedMarginBid, FleetSimulator, LookaheadBid,
                       PercentileBid, ReactivePolicy, SCENARIOS,
                       SpotBidPolicy)

N_STREAMS = 108
DURATION_H = 24.0
SEED = 0

# acceptance bars (ISSUE 5): cost reduction vs on-demand-only and the SLO
# ceiling for the gated (lookahead) policy, plus a wall-clock budget
MIN_REDUCTION = 0.15
MAX_SLO_DELTA = 0.005
TIME_BUDGET_S = 60.0
PARITY_SIZES = (100, 1000)


def _conserved(ledger) -> bool:
    return all(abs(r.frames_demanded - r.frames_analyzed - r.frames_dropped)
               < 1e-6 * max(1.0, r.frames_demanded) for r in ledger.records)


def _scenario():
    sc = SCENARIOS["spot_heavy"](n_streams=N_STREAMS, duration_h=DURATION_H,
                                 seed=SEED)
    # on-demand-only baseline semantics: no *random* spot boots in either
    # arm — the bidder's spot capacity comes exclusively from its bids
    return dataclasses.replace(
        sc, config=dataclasses.replace(sc.config, spot_fraction=0.0))


def compare_policies() -> dict:
    sc = _scenario()
    cat = sc.catalog()
    t0 = time.perf_counter()
    base = FleetSimulator(sc.demand, ReactivePolicy(ResourceManager(cat)),
                          cat, sc.config).run()
    rows = {"ondemand_only": {
        "totals": base.totals(), "elapsed_s": round(time.perf_counter() - t0, 2)}}
    for bidding in (FixedMarginBid(0.35), PercentileBid(98.0),
                    LookaheadBid()):
        t0 = time.perf_counter()
        pol = SpotBidPolicy(ResourceManager(cat), bidding=bidding)
        led = FleetSimulator(sc.demand, pol, cat, sc.config).run()
        rows[bidding.name] = {
            "totals": led.totals(),
            "cost_reduction": round(1.0 - led.total_cost / base.total_cost, 4),
            "slo_delta": round(base.slo_attainment() - led.slo_attainment(), 6),
            "spot_spend_share": round(led.cost_spot / led.total_cost, 4),
            "outbids": led.outbids,
            "affinity_violations": len(
                spot_affinity_violations(pol.adaptive.current)),
            "frames_conserved": _conserved(led),
            "elapsed_s": round(time.perf_counter() - t0, 2),
        }
    return rows


def parity_check() -> list[dict]:
    """Packed vs scalar ledger parity for mixed plans: run the
    ``spot_bidder`` scenario both ways and compare the full per-tick ledger
    signatures (exact floats). Mixed planning is mode-independent by
    construction; this gate keeps it that way."""
    out = []
    for n in PARITY_SIZES:
        sc = SCENARIOS["spot_bidder"](n_streams=n, duration_h=DURATION_H,
                                      seed=SEED)
        cat = sc.catalog()
        t0 = time.perf_counter()
        led_p = FleetSimulator(sc.demand, SpotBidPolicy(ResourceManager(cat)),
                               cat, sc.config).run()
        with packed_mod.scalar_mode():
            led_s = FleetSimulator(sc.demand,
                                   SpotBidPolicy(ResourceManager(cat)),
                                   cat, sc.config).run()
        out.append({
            "n_streams": n,
            "ledger_parity": led_p.signature() == led_s.signature(),
            "total_cost": led_p.totals()["total_cost"],
            "elapsed_s": round(time.perf_counter() - t0, 2),
        })
    return out


def check_acceptance(policies: dict, parity: list[dict],
                     total_elapsed: float) -> list[str]:
    """Returns a list of violated acceptance bars (empty = pass)."""
    bad = []
    gated = policies["lookahead"]
    if gated["cost_reduction"] < MIN_REDUCTION:
        bad.append(f"lookahead cost reduction {gated['cost_reduction']:.1%} "
                   f"< {MIN_REDUCTION:.0%} vs on-demand-only")
    if gated["slo_delta"] > MAX_SLO_DELTA:
        bad.append(f"lookahead SLO delta {gated['slo_delta']:+.4f} "
                   f"> {MAX_SLO_DELTA:.3f}")
    for name, row in policies.items():
        if name == "ondemand_only":
            continue
        if not row["frames_conserved"]:
            bad.append(f"{name}: ledger frame conservation violated")
        if row["affinity_violations"]:
            bad.append(f"{name}: {row['affinity_violations']} spot "
                       "anti-affinity violations")
    for p in parity:
        if not p["ledger_parity"]:
            bad.append(f"packed vs scalar ledger mismatch at "
                       f"{p['n_streams']} streams")
    if total_elapsed > TIME_BUDGET_S:
        bad.append(f"suite took {total_elapsed:.1f}s > {TIME_BUDGET_S:.0f}s")
    return bad


def run() -> list[dict]:
    """Harness entry (benchmarks/run.py): CSV rows with acceptance flags."""
    t0 = time.perf_counter()
    policies = compare_policies()
    parity = parity_check()
    violations = check_acceptance(policies, parity,
                                  time.perf_counter() - t0)
    rows = []
    for name, row in policies.items():
        if name == "ondemand_only":
            rows.append({"name": "spot_bidding_ondemand_only",
                         "us_per_call": row["elapsed_s"] * 1e6,
                         "derived": f"${row['totals']['total_cost']:.2f}/24h "
                                    f"SLO {row['totals']['slo_attainment']:.4f}"})
            continue
        gated = name == "lookahead"
        ok = (row["frames_conserved"] and not row["affinity_violations"]
              and (not gated
                   or (row["cost_reduction"] >= MIN_REDUCTION
                       and row["slo_delta"] <= MAX_SLO_DELTA)))
        rows.append({
            "name": f"spot_bidding_{name.replace('-', '_')}",
            "us_per_call": row["elapsed_s"] * 1e6,
            "derived": (f"{row['cost_reduction']:.1%} cheaper "
                        f"SLO delta {row['slo_delta']:+.4f} "
                        f"spot share {row['spot_spend_share']:.0%} "
                        f"outbids {row['outbids']}"),
            "match_paper": ok if gated else None,
        })
    for p in parity:
        rows.append({
            "name": f"spot_bidding_parity_{p['n_streams']}",
            "us_per_call": p["elapsed_s"] * 1e6,
            "derived": ("ledger bit-identical packed vs scalar"
                        if p["ledger_parity"] else "PARITY BROKEN"),
            "match_paper": p["ledger_parity"],
        })
    rows.append({
        "name": "spot_bidding_acceptance",
        "us_per_call": (time.perf_counter() - t0) * 1e6,
        "derived": "all bars met" if not violations else "; ".join(violations),
        "match_paper": not violations,
    })
    return rows


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="run the acceptance comparison and exit non-zero "
                         "on any violated bar (CI gate)")
    ap.add_argument("--out", default=None,
                    help="write the summary JSON here")
    args = ap.parse_args(argv)

    t0 = time.perf_counter()
    policies = compare_policies()
    parity = parity_check()
    total_elapsed = time.perf_counter() - t0
    violations = check_acceptance(policies, parity, total_elapsed)

    base_cost = policies["ondemand_only"]["totals"]["total_cost"]
    print(f"on-demand-only  ${base_cost:.2f}/24h "
          f"SLO {policies['ondemand_only']['totals']['slo_attainment']:.4f}")
    for name, row in policies.items():
        if name == "ondemand_only":
            continue
        print(f"{name:18s} ${row['totals']['total_cost']:.2f}/24h "
              f"({row['cost_reduction']:.1%} cheaper)  "
              f"SLO delta {row['slo_delta']:+.4f}  "
              f"spot share {row['spot_spend_share']:.0%}  "
              f"outbids {row['outbids']}  "
              f"conserved={row['frames_conserved']}  [{row['elapsed_s']}s]")
    for p in parity:
        print(f"parity {p['n_streams']:5d} streams: "
              f"{'bit-identical' if p['ledger_parity'] else 'BROKEN'} "
              f"[{p['elapsed_s']}s]")

    summary = {"policies": policies, "parity": parity,
               "violations": violations,
               "elapsed_s": round(total_elapsed, 2),
               "bars": {"min_cost_reduction": MIN_REDUCTION,
                        "max_slo_delta": MAX_SLO_DELTA,
                        "time_budget_s": TIME_BUDGET_S}}
    if args.out:
        os.makedirs(os.path.dirname(os.path.abspath(args.out)) or ".",
                    exist_ok=True)
        with open(args.out, "w") as f:
            json.dump(summary, f, indent=2, sort_keys=True)
        print(f"summary written to {args.out}")

    if violations:
        print("ACCEPTANCE " + ("FAILED" if args.smoke else "bars violated")
              + ":\n  " + "\n  ".join(violations))
        return 1 if args.smoke else 0
    print(f"acceptance ok in {total_elapsed:.1f}s "
          f"(budget {TIME_BUDGET_S:.0f}s)")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
