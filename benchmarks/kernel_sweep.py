"""Benchmark: Pallas kernel validation matrix — max |err| vs the jnp oracle
across shapes (interpret mode on CPU; the kernels are the TPU hot-spot
implementations for attention / SSD / RG-LRU workloads)."""
from __future__ import annotations

import time

import jax.numpy as jnp
import numpy as np

from repro.kernels import ref
from repro.kernels.flash_attention import flash_attention
from repro.kernels.rglru_scan import rglru_scan
from repro.kernels.ssd_scan import ssd_scan


def run() -> list[dict]:
    rng = np.random.default_rng(7)
    rows = []

    for (S, H, hd, K, win) in [(256, 4, 64, 2, 0), (256, 8, 128, 2, 64),
                               (512, 4, 64, 1, 0)]:
        q = jnp.asarray(rng.standard_normal((1, S, H, hd)), jnp.float32)
        k = jnp.asarray(rng.standard_normal((1, S, K, hd)), jnp.float32)
        v = jnp.asarray(rng.standard_normal((1, S, K, hd)), jnp.float32)
        t0 = time.perf_counter()
        out = flash_attention(q, k, v, causal=True, window=win, bq=128, bk=128)
        us = (time.perf_counter() - t0) * 1e6
        err = float(np.max(np.abs(np.asarray(out) - np.asarray(
            ref.flash_attention_ref(q, k, v, causal=True, window=win)))))
        rows.append({"name": f"flash_attn_S{S}_H{H}_K{K}_w{win}",
                     "us_per_call": us, "derived": f"max_err={err:.1e}"})

    for (s, h, p, n, L) in [(256, 4, 64, 64, 64), (128, 8, 32, 128, 128)]:
        x = jnp.asarray(rng.standard_normal((1, s, h, p)), jnp.float32)
        dt = jnp.asarray(rng.uniform(0.001, 0.1, (1, s, h)), jnp.float32)
        A = jnp.asarray(-rng.uniform(0.5, 2, (h,)), jnp.float32)
        B = jnp.asarray(rng.standard_normal((1, s, 1, n)), jnp.float32)
        C = jnp.asarray(rng.standard_normal((1, s, 1, n)), jnp.float32)
        t0 = time.perf_counter()
        out = ssd_scan(x, dt, A, B, C, L)
        us = (time.perf_counter() - t0) * 1e6
        err = float(np.max(np.abs(np.asarray(out) - np.asarray(
            ref.ssd_scan_ref(x, dt, A, B, C, L)))))
        rows.append({"name": f"ssd_scan_S{s}_H{h}_N{n}_chunk{L}",
                     "us_per_call": us, "derived": f"max_err={err:.1e}"})

    for (S, W) in [(256, 512), (512, 256)]:
        a = jnp.asarray(rng.uniform(0.7, 0.999, (1, S, W)), jnp.float32)
        b = jnp.asarray(rng.standard_normal((1, S, W)), jnp.float32)
        t0 = time.perf_counter()
        out = rglru_scan(a, b)
        us = (time.perf_counter() - t0) * 1e6
        err = float(np.max(np.abs(np.asarray(out) -
                                  np.asarray(ref.rglru_scan_ref(a, b)))))
        rows.append({"name": f"rglru_scan_S{S}_W{W}", "us_per_call": us,
                     "derived": f"max_err={err:.1e}"})
    return rows
