"""Benchmark: adaptive runtime management [14] — 48h rush-hour simulation.
Compares adaptive replanning against static peak provisioning."""
from __future__ import annotations

import time

from repro.core import AdaptiveManager, ResourceManager, Stream, fig3_catalog
from repro.core.workload import PROGRAMS


def rush_hour_fps(t: int) -> float:
    if t % 24 in (8, 9, 17, 18):
        return 6.0
    if t % 24 in (7, 10, 16, 19):
        return 2.0
    return 0.2


def run() -> list[dict]:
    mgr = AdaptiveManager(ResourceManager(fig3_catalog()), strategy="ST3")
    t0 = time.perf_counter()
    peak_cost = 0.0
    for t in range(48):
        streams = [Stream(f"cam{i}", PROGRAMS["ZF"], fps=rush_hour_fps(t))
                   for i in range(4)]
        plan = mgr.step(t, streams)
        peak_cost = max(peak_cost, plan.hourly_cost)
    us = (time.perf_counter() - t0) * 1e6 / 48
    adaptive_total = mgr.total_cost()
    static_total = peak_cost * 48
    replans = sum(1 for e in mgr.events if e.action != "keep")
    migrations = sum(e.migrations for e in mgr.events)
    return [
        {"name": "adaptive_48h_total", "us_per_call": us,
         "derived": f"${adaptive_total:.2f} vs static ${static_total:.2f} "
                    f"({100 * (1 - adaptive_total / static_total):.0f}% saved)"},
        {"name": "adaptive_replans", "us_per_call": 0.0,
         "derived": f"{replans} replans, {migrations} stream migrations"},
    ]
