"""Benchmark (BEYOND-PAPER): trace-driven fleet simulation over 24 simulated
hours — static peak provisioning vs adaptive policies on total cost and SLO
attainment, plus spot-market resilience and a determinism check."""
from __future__ import annotations

import time

from repro.core.manager import ResourceManager
from repro.sim import (FleetSimulator, PredictiveEWMAPolicy, ReactivePolicy,
                       SCENARIOS, ScheduledPolicy, StaticPeakPolicy)

N_STREAMS = 108
DURATION_H = 24.0


def _run(scenario, policy):
    t0 = time.perf_counter()
    ledger = FleetSimulator(scenario.demand, policy, scenario.catalog(),
                            scenario.config).run()
    return ledger, (time.perf_counter() - t0) * 1e6


def run() -> list[dict]:
    rows = []
    sc = SCENARIOS["rush_hour"](n_streams=N_STREAMS, duration_h=DURATION_H)
    cat = sc.catalog()

    static, us = _run(sc, StaticPeakPolicy(ResourceManager(cat),
                                           sc.peak_streams()))
    rows.append({"name": "fleet_rush_static_peak", "us_per_call": us,
                 "derived": f"${static.total_cost:.2f}/24h "
                            f"SLO {static.slo_attainment():.4f} "
                            f"({N_STREAMS} streams)"})

    policies = [ReactivePolicy(ResourceManager(cat)),
                ScheduledPolicy(ResourceManager(cat), every_h=6.0),
                PredictiveEWMAPolicy(ResourceManager(cat))]
    reactive_led = None
    for pol in policies:
        led, us = _run(sc, pol)
        if pol.name == "reactive":
            reactive_led = led
        saved = 1 - led.total_cost / static.total_cost
        slo_gap = static.slo_attainment() - led.slo_attainment()
        ok = saved >= 0.30 and slo_gap <= 0.02
        rows.append({
            "name": f"fleet_rush_{pol.name.replace('-', '_')}",
            "us_per_call": us,
            "derived": f"${led.total_cost:.2f}/24h ({100 * saved:.0f}% vs "
                       f"static) SLO {led.slo_attainment():.4f} "
                       f"(gap {100 * slo_gap:.2f}%) "
                       f"{led.migrations} migrations",
            "match_paper": ok if pol.name == "reactive" else None,
        })

    # determinism: the reactive run from the policies loop, replayed under
    # the same seed, must produce identical ledger totals
    led_b, us = _run(sc, ReactivePolicy(ResourceManager(cat)))
    same = reactive_led.totals() == led_b.totals()
    rows.append({"name": "fleet_sim_determinism", "us_per_call": us,
                 "derived": "ledger totals identical across two runs"
                 if same else "NON-DETERMINISTIC LEDGER",
                 "match_paper": same})

    # spot market: cheaper instance-hours, preemptions replayed not lost
    sp = SCENARIOS["spot_heavy"](n_streams=N_STREAMS, duration_h=DURATION_H)
    spot, us = _run(sp, ReactivePolicy(ResourceManager(sp.catalog())))
    conserved = all(abs(r.frames_demanded - r.frames_analyzed
                        - r.frames_dropped) < 1e-6 for r in spot.records)
    rows.append({"name": "fleet_spot_reactive", "us_per_call": us,
                 "derived": f"${spot.total_cost:.2f}/24h "
                            f"SLO {spot.slo_attainment():.4f} "
                            f"{spot.preemptions} preemptions, frames "
                            f"{'conserved' if conserved else 'LOST'}",
                 "match_paper": conserved})

    # follow-the-sun: worldwide fleet, peaks rotate with local rush hours
    fs = SCENARIOS["follow_the_sun"](n_streams=N_STREAMS,
                                     duration_h=DURATION_H)
    sun, us = _run(fs, ReactivePolicy(ResourceManager(fs.catalog())))
    rows.append({"name": "fleet_follow_the_sun_reactive", "us_per_call": us,
                 "derived": f"${sun.total_cost:.2f}/24h "
                            f"SLO {sun.slo_attainment():.4f} "
                            f"{sun.migrations} migrations"})
    return rows
