"""§Perf hillclimb driver: run the optimized variants of the three chosen
(arch x shape) pairs, dump HLO + roofline JSONs into experiments/perf/, and
print before/after tables.

Pairs (chosen from the baseline roofline per the brief):
  A. grok-1-314b x train_4k    — worst roofline cell, collective-bound
  B. qwen3-moe-30b-a3b x prefill_32k — most collective-bound MoE serving shape
  C. yi-9b x decode_32k        — serving-representative; collective-dominant
                                 where decode should be memory-bound

Usage: PYTHONPATH=src python -m benchmarks.perf_iterations [--only A2]
(must run in its own process: forces the 512-device host platform).
"""
from __future__ import annotations

import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")

import argparse
import json

PERF_DIR = os.path.join(os.path.dirname(__file__), "..", "experiments", "perf")

# iteration id -> (arch, shape, mesh, run_one kwargs, hypothesis)
ITERATIONS = {
    # -- pair A: grok train --------------------------------------------------
    "A0": ("grok-1-314b", "train_4k", "pod1",
           dict(legacy_expert_sharding=True),
           "baseline (experts replicate: 8 experts % 16-way model axis != 0)"),
    "A1": ("grok-1-314b", "train_4k", "pod1",
           dict(),
           "shard expert matmul dims (D over data, F over model) instead of "
           "replicating -> gradient all-reduce shrinks by the shard factor"),
    "A2": ("grok-1-314b", "train_4k", "pod1",
           dict(blockwise_attention=512),
           "A1 + blockwise (online-softmax) attention -> stop materializing "
           "S^2 score tensors; memory term drops toward weight traffic"),
    "A3": ("grok-1-314b", "train_4k", "pod1",
           dict(blockwise_attention=512, moe_local=True),
           "A2 + per-sequence MoE dispatch -> routing cumsum stays shard-local"),
    "A4": ("grok-1-314b", "train_4k", "pod1",
           dict(microbatches=4),
           "A1 freed 13.4 GiB/dev of peak temp -> gradient accumulation can "
           "drop 16 -> 4 microbatches; each microbatch re-streams the layer "
           "weights, so weight traffic (the dominant memory term now) "
           "should fall ~4x at 4x the activation footprint"),
    "A5": ("grok-1-314b", "train_4k", "pod1",
           dict(gqa_expand_kv=True),
           "A1 + expand KV onto all 48 query heads: grok's 8 kv heads don't "
           "divide the 16-way model axis, so GSPMD replicates every "
           "(B,K,G,S,S) score tensor across half the axis; 48 heads shard "
           "cleanly -> score traffic should fall ~16x (3 vs 48 heads/dev)"),
    "A6": ("grok-1-314b", "train_4k", "pod1",
           dict(),  # batch_axes constraint is now default in build_lowered
           "A1 + sharding-constrain the microbatch reshape: the HLO showed "
           "f32[16,1,3,4096,4096] score tensors — the full 16-seq microbatch "
           "replicated on the data axis inside the accumulation loop. "
           "Pinning dim1 of (mb, B/mb, S) to the data axes shards all "
           "activations 16x"),
    # -- pair B: qwen3 prefill ----------------------------------------------
    "B0": ("qwen3-moe-30b-a3b", "prefill_32k", "pod1", dict(),
           "baseline (global GShard dispatch: cumsum over all tokens)"),
    "B1": ("qwen3-moe-30b-a3b", "prefill_32k", "pod1",
           dict(moe_local=True),
           "per-sequence dispatch: positions computed per sequence keep "
           "routing local; only the token<->expert all-to-all remains"),
    "B2": ("qwen3-moe-30b-a3b", "prefill_32k", "pod1",
           dict(moe_local=True, blockwise_attention=512),
           "B1 + blockwise attention for the 32k prefill quadratic term"),
    "B4": ("qwen3-moe-30b-a3b", "prefill_32k", "pod1",
           dict(moe_expert_constraint=True),
           "pin the dispatch buffer + expert outputs to P('model') on the "
           "expert dim: tokens are model-replicated, so each shard keeps "
           "only its experts' slots; the scatter-add all-reduce of (E*C,D) "
           "buffers becomes one (T,D) psum at the combine"),
    "B5": ("qwen3-moe-30b-a3b", "prefill_32k", "pod1",
           dict(moe_shard_map=True),
           "explicit expert-parallel shard_map MoE: each model column routes "
           "its (model-replicated) tokens, dispatches only to its own "
           "experts, and one (T,D) psum combines — the GSPMD (E*C,D) "
           "all-reduce cannot exist by construction"),
    # -- pair C: yi decode ----------------------------------------------------
    "C0": ("yi-9b", "decode_32k", "pod1", dict(),
           "baseline (4 kv heads < 16-way model axis -> cache sharded on "
           "head_dim; scores psum over the contracted dim every layer)"),
    "C1": ("yi-9b", "decode_32k", "pod1",
           dict(decode_seq_over_model=True),
           "shard the KV-cache sequence axis over model instead: each shard "
           "attends to its cache slice; only softmax stats + (1,hd) partial "
           "outputs cross the mesh"),
    "B3": ("qwen3-moe-30b-a3b", "prefill_32k", "pod1",
           dict(moe_local=True, fsdp_off=True),
           "B1 + drop FSDP for the serving shape: inference has no optimizer "
           "state, so data-sharding the expert weights' D dim only buys a "
           "d-contraction all-reduce per expert matmul; pure expert+model "
           "sharding fits HBM (params/dev ~3.6G) and removes it"),
    # -- bonus beyond-three iterations ----------------------------------------
    "D1": ("yi-9b", "long_500k", "pod1",
           dict(ring_cache=True),
           "ring (window-sized) KV cache for sliding-window long-context "
           "decode: stop allocating/updating a 500k-deep cache the window "
           "never reads"),
    "E1": ("qwen3-moe-30b-a3b", "train_4k", "pod1",
           dict(moe_local=True, blockwise_attention=512),
           "carry the MoE-local dispatch + blockwise attention wins to the "
           "training shape"),
    "E2": ("qwen3-moe-30b-a3b", "train_4k", "pod1",
           dict(moe_shard_map=True),
           "B5's explicit expert-parallel shard_map MoE under jvp/remat: the "
           "train-shape dispatch all-reduce should vanish the same way"),
    "F1": ("moonshot-v1-16b-a3b", "prefill_32k", "pod1",
           dict(moe_shard_map=True),
           "carry B5 to the other collective-bound MoE serving cell"),
    "G1": ("moonshot-v1-16b-a3b", "train_4k", "pod1",
           dict(moe_shard_map=True),
           "shard_map MoE on moonshot train"),
    "G2": ("qwen3-moe-30b-a3b", "decode_32k", "pod1",
           dict(moe_shard_map=True, decode_seq_over_model=True),
           "shard_map MoE + C1 cache-seq sharding on MoE decode"),
    "G3": ("moonshot-v1-16b-a3b", "decode_32k", "pod1",
           dict(moe_shard_map=True),
           "shard_map MoE on moonshot decode (kv=16 divides the axis, no C1 needed)"),
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated iteration ids (default: all)")
    args = ap.parse_args()
    from repro.launch.dryrun import run_one

    os.makedirs(PERF_DIR, exist_ok=True)
    wanted = args.only.split(",") if args.only else list(ITERATIONS)
    for it in wanted:
        arch, shape, mesh, kw, hypothesis = ITERATIONS[it]
        path = os.path.join(PERF_DIR, f"{it}_{arch}_{shape}.json")
        print(f"=== {it}: {arch} x {shape} ({mesh}) ===")
        print(f"hypothesis: {hypothesis}")
        rec = run_one(arch, shape, mesh, hlo_dir=os.path.join(PERF_DIR, "hlo"),
                      tag=f"{it}_", **kw)
        rec["iteration"] = it
        rec["hypothesis"] = hypothesis
        with open(path, "w") as f:
            json.dump(rec, f, indent=2)
        print(f"flops/dev {rec['flops_per_device']:.3e}  "
              f"bytes/dev {rec['bytes_per_device']:.3e}  "
              f"coll/dev {rec['collective_bytes_per_device']:.3e}")


if __name__ == "__main__":
    main()
