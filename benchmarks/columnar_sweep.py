"""Benchmark (BEYOND-PAPER): continent-scale columnar fleet-state gate.

Gates the struct-of-arrays event loop (columnar demand + array placement
ledger + batched event processing, see ``repro.sim.fleet``) against the
object path it replaced:

* **parity**: full-day ledgers of the ``continent_scale`` shape must be
  *bit-identical* between ``FleetSimulator(columnar=True)`` and
  ``columnar=False`` at 1k and 10k streams (``Ledger.signature()``
  equality — every record and every total, to the bit);
* **spot parity**: the same equality on a spot-heavy variant (preemption /
  outbid batches landing mid-interval) at 1k streams;
* **wall-clock**: the 24 h x 1,000,000-stream ``continent_scale`` day under
  the reactive policy must finish within ``WALL_BUDGET_S``.

``main()`` writes a JSON summary (CI uploads it as an artifact) and exits
non-zero if any gate fails; ``run()`` returns the harness row format.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
for _p in (_ROOT, os.path.join(_ROOT, "src")):
    if _p not in sys.path:
        sys.path.insert(0, _p)

import dataclasses

from repro.core.manager import ResourceManager
from repro.sim import FleetSimulator, ReactivePolicy, SCENARIOS

PARITY_SIZES = (1_000, 10_000)
SCALE_STREAMS = 1_000_000
WALL_BUDGET_S = 600.0


def _simulate(scenario, columnar):
    cat = scenario.catalog()
    policy = ReactivePolicy(ResourceManager(cat))
    return FleetSimulator(scenario.demand, policy, cat, scenario.config,
                          columnar=columnar).run()


def run() -> list[dict]:
    rows = []
    summary: dict = {"parity": {}, "gates": {}}

    # -- parity: columnar vs object ledgers, bit for bit -------------------
    for n in PARITY_SIZES:
        t0 = time.perf_counter()
        sc = SCENARIOS["continent_scale"](n_streams=n)
        led_c = _simulate(sc, columnar=True)
        led_o = _simulate(sc, columnar=False)
        ok = led_c.signature() == led_o.signature()
        us = (time.perf_counter() - t0) * 1e6
        summary["parity"][f"ledger_{n}"] = bool(ok)
        rows.append({
            "name": f"columnar_parity_{n}", "us_per_call": us,
            "derived": f"24h ledger bit-identical columnar vs object "
                       f"({n} streams)" if ok
                       else f"LEDGER MISMATCH at {n} streams",
            "match_paper": ok})

    # -- parity under preemption batches: spot-heavy variant ---------------
    t0 = time.perf_counter()
    sc = SCENARIOS["continent_scale"](n_streams=1_000)
    sc = dataclasses.replace(sc, config=dataclasses.replace(
        sc.config, spot_fraction=0.7, preempt_hazard_per_h=0.15))
    led_c = _simulate(sc, columnar=True)
    led_o = _simulate(sc, columnar=False)
    ok_spot = led_c.signature() == led_o.signature()
    ok_spot = ok_spot and led_c.preemptions > 0   # the gate must exercise them
    us = (time.perf_counter() - t0) * 1e6
    summary["parity"]["ledger_1k_spot"] = bool(ok_spot)
    rows.append({
        "name": "columnar_parity_1k_spot", "us_per_call": us,
        "derived": f"24h spot ledger bit-identical with "
                   f"{led_c.preemptions} preemptions" if ok_spot
                   else "SPOT LEDGER MISMATCH (or no preemptions) at 1k",
        "match_paper": ok_spot})
    all_parity = all(summary["parity"].values())
    summary["gates"]["parity"] = bool(all_parity)

    # -- the continent_scale day at full scale -----------------------------
    sc = SCENARIOS["continent_scale"](n_streams=SCALE_STREAMS)
    t0 = time.perf_counter()
    led = _simulate(sc, columnar=True)
    wall = time.perf_counter() - t0
    ok_wall = wall < WALL_BUDGET_S
    summary["continent_scale"] = {
        "streams": SCALE_STREAMS, "duration_h": sc.config.duration_h,
        "wall_s": round(wall, 1), "budget_s": WALL_BUDGET_S,
        "total_cost": round(led.total_cost, 2),
        "slo_attainment": round(led.slo_attainment(), 4),
        "migrations": led.migrations,
        "peak_instances": max(r.instances_live for r in led.records),
    }
    summary["gates"]["wall_clock"] = bool(ok_wall)
    rows.append({
        "name": "columnar_continent_scale", "us_per_call": wall * 1e6,
        "derived": f"24h x 1M streams in {wall:.1f}s (budget "
                   f"{WALL_BUDGET_S:.0f}s) ${led.total_cost:.0f} "
                   f"SLO {led.slo_attainment():.4f} "
                   f"peak {summary['continent_scale']['peak_instances']} "
                   f"instances",
        "match_paper": ok_wall,
    })

    run._summary = summary          # stashed for main()'s JSON artifact
    return rows


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default=None, metavar="JSON",
                    help="write the machine-readable summary here")
    args = ap.parse_args()

    t0 = time.perf_counter()
    rows = run()
    failed = [r["name"] for r in rows if r.get("match_paper") is False]
    for r in rows:
        tag = {True: "  [OK]", False: "  [FAIL]"}.get(r.get("match_paper"), "")
        print(f"{r['name']:28s} {r['derived']}{tag}")
    summary = run._summary
    summary["total_s"] = round(time.perf_counter() - t0, 1)
    if args.out:
        with open(args.out, "w") as f:
            json.dump(summary, f, indent=2, sort_keys=True)
        print(f"summary written to {args.out}")
    if failed:
        print(f"GATES FAILED: {', '.join(failed)}")
        sys.exit(1)
    print(f"acceptance ok in {summary['total_s']}s")


if __name__ == "__main__":
    main()
