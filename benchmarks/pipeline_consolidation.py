"""Benchmark (BEYOND-PAPER): content-aware pipeline demand — cross-camera
crop consolidation vs per-camera stage packing.

Arms on ``consolidated_city`` (24h x 120 pipeline cameras over four US
cities, fixed seed, identical density curves):

* consolidation **off** — every camera's crop-classify stage is its own
  demand item; the planner pays one model load (GPU memory base + host
  feed cores) per camera;
* consolidation **on** — each city's crop stages pool onto shared GPU
  workers (``pool::roi_vehicle.classify@nyc#k``), chunk counts pinned at
  peak density so the pooled ids are stable all day.

Both arms replay the identical seeded day under ``ReactivePolicy``; the
only difference is the demand-side view of the same analysis work.

Acceptance (asserted here and in CI via ``--smoke``): consolidation-on is
>= 15% cheaper than consolidation-off at an equal-or-better SLO; frames
are conserved in both arms; packed-vs-scalar ledger parity holds on the
pipeline scenarios at 100 and 1000 streams (bit-identical signatures); and
the whole suite finishes in under 60 s. The 100-stream parity point runs
the full 24 h day; the 1000-stream point runs a 1 h slice — the scalar
baseline's opening rule rescans every remaining item per opened bin, so a
full scalar day at 1000 streams costs minutes by design (it is the thing
the packed path exists to beat). ``--out`` writes the summary JSON
(uploaded as a CI artifact).
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

# runnable as `python benchmarks/pipeline_consolidation.py` from the repo root
_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
for _p in (_ROOT, os.path.join(_ROOT, "src")):
    if _p not in sys.path:
        sys.path.insert(0, _p)

from repro.core import packed as packed_mod
from repro.core.manager import ResourceManager
from repro.sim import FleetSimulator, ReactivePolicy
from repro.sim.scenarios import consolidated_city, roi_day

N_STREAMS = 120
DURATION_H = 24.0
SEED = 0

# acceptance bars (ISSUE 9): the consolidation saving and the SLO floor,
# plus parity points (streams, hours) and a wall-clock budget
MIN_REDUCTION = 0.15
PARITY_POINTS = ((100, 24.0), (1000, 1.0))
TIME_BUDGET_S = 60.0


def _conserved(ledger) -> bool:
    return all(abs(r.frames_demanded - r.frames_analyzed - r.frames_dropped)
               < 1e-6 * max(1.0, r.frames_demanded) for r in ledger.records)


def _run_arm(consolidate: bool) -> dict:
    sc = consolidated_city(n_streams=N_STREAMS, duration_h=DURATION_H,
                           seed=SEED, consolidate=consolidate)
    cat = sc.catalog()
    t0 = time.perf_counter()
    led = FleetSimulator(sc.demand, ReactivePolicy(ResourceManager(cat)),
                         cat, sc.config).run()
    return {"totals": led.totals(),
            "slo": led.slo_attainment(),
            "frames_conserved": _conserved(led),
            "elapsed_s": round(time.perf_counter() - t0, 2)}


def compare_arms() -> dict:
    on, off = _run_arm(True), _run_arm(False)
    return {"consolidate_on": on, "consolidate_off": off,
            "cost_reduction": round(
                1.0 - on["totals"]["total_cost"]
                / off["totals"]["total_cost"], 4),
            "slo_delta": round(off["slo"] - on["slo"], 6)}


def parity_check() -> list[dict]:
    """Packed vs scalar ledger parity for pipeline demand: run ``roi_day``
    both ways and compare full per-tick ledger signatures (exact floats).
    Stage emission, activation math, and pooling are mode-independent by
    construction; this gate keeps them that way."""
    out = []
    for n, hours in PARITY_POINTS:
        sc = roi_day(n_streams=n, duration_h=hours, seed=SEED)
        cat = sc.catalog()
        t0 = time.perf_counter()
        led_p = FleetSimulator(sc.demand,
                               ReactivePolicy(ResourceManager(cat)),
                               cat, sc.config).run()
        with packed_mod.scalar_mode():
            led_s = FleetSimulator(sc.demand,
                                   ReactivePolicy(ResourceManager(cat)),
                                   cat, sc.config).run()
        out.append({
            "n_streams": n,
            "duration_h": hours,
            "ledger_parity": led_p.signature() == led_s.signature(),
            "total_cost": led_p.totals()["total_cost"],
            "elapsed_s": round(time.perf_counter() - t0, 2),
        })
    return out


def check_acceptance(arms: dict, parity: list[dict],
                     total_elapsed: float) -> list[str]:
    """Returns a list of violated acceptance bars (empty = pass)."""
    bad = []
    if arms["cost_reduction"] < MIN_REDUCTION:
        bad.append(f"consolidation saving {arms['cost_reduction']:.1%} "
                   f"< {MIN_REDUCTION:.0%} vs unconsolidated")
    if arms["slo_delta"] > 0:
        bad.append(f"consolidated SLO {arms['consolidate_on']['slo']:.6f} "
                   f"worse than unconsolidated "
                   f"{arms['consolidate_off']['slo']:.6f}")
    for name in ("consolidate_on", "consolidate_off"):
        if not arms[name]["frames_conserved"]:
            bad.append(f"{name}: ledger frame conservation violated")
    if arms["consolidate_on"]["totals"]["pooled_items_peak"] <= 0:
        bad.append("consolidate_on arm never emitted a pooled chunk")
    for p in parity:
        if not p["ledger_parity"]:
            bad.append(f"packed vs scalar ledger mismatch at "
                       f"{p['n_streams']} streams")
    if total_elapsed > TIME_BUDGET_S:
        bad.append(f"suite took {total_elapsed:.1f}s > {TIME_BUDGET_S:.0f}s")
    return bad


def run() -> list[dict]:
    """Harness entry (benchmarks/run.py): CSV rows with acceptance flags."""
    t0 = time.perf_counter()
    arms = compare_arms()
    parity = parity_check()
    violations = check_acceptance(arms, parity, time.perf_counter() - t0)
    on, off = arms["consolidate_on"], arms["consolidate_off"]
    rows = [
        {"name": "pipeline_consolidation_off",
         "us_per_call": off["elapsed_s"] * 1e6,
         "derived": f"${off['totals']['total_cost']:.2f}/24h "
                    f"SLO {off['slo']:.4f} "
                    f"stage items {off['totals']['stage_items_peak']}"},
        {"name": "pipeline_consolidation_on",
         "us_per_call": on["elapsed_s"] * 1e6,
         "derived": (f"{arms['cost_reduction']:.1%} cheaper "
                     f"SLO delta {-arms['slo_delta']:+.4f} "
                     f"pooled chunks {on['totals']['pooled_items_peak']}"),
         "match_paper": (arms["cost_reduction"] >= MIN_REDUCTION
                         and arms["slo_delta"] <= 0
                         and on["frames_conserved"]
                         and off["frames_conserved"])},
    ]
    for p in parity:
        rows.append({
            "name": f"pipeline_parity_{p['n_streams']}",
            "us_per_call": p["elapsed_s"] * 1e6,
            "derived": ("ledger bit-identical packed vs scalar"
                        if p["ledger_parity"] else "PARITY BROKEN"),
            "match_paper": p["ledger_parity"],
        })
    rows.append({
        "name": "pipeline_consolidation_acceptance",
        "us_per_call": (time.perf_counter() - t0) * 1e6,
        "derived": "all bars met" if not violations else "; ".join(violations),
        "match_paper": not violations,
    })
    return rows


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="run the acceptance comparison and exit non-zero "
                         "on any violated bar (CI gate)")
    ap.add_argument("--out", default=None,
                    help="write the summary JSON here")
    args = ap.parse_args(argv)

    t0 = time.perf_counter()
    arms = compare_arms()
    parity = parity_check()
    total_elapsed = time.perf_counter() - t0
    violations = check_acceptance(arms, parity, total_elapsed)

    on, off = arms["consolidate_on"], arms["consolidate_off"]
    print(f"consolidation off  ${off['totals']['total_cost']:.2f}/24h "
          f"SLO {off['slo']:.4f}  "
          f"stage items {off['totals']['stage_items_peak']}  "
          f"[{off['elapsed_s']}s]")
    print(f"consolidation on   ${on['totals']['total_cost']:.2f}/24h "
          f"({arms['cost_reduction']:.1%} cheaper)  "
          f"SLO {on['slo']:.4f}  "
          f"pooled chunks {on['totals']['pooled_items_peak']}  "
          f"[{on['elapsed_s']}s]")
    for p in parity:
        print(f"parity {p['n_streams']:5d} streams: "
              f"{'bit-identical' if p['ledger_parity'] else 'BROKEN'} "
              f"[{p['elapsed_s']}s]")

    summary = {"arms": arms, "parity": parity, "violations": violations,
               "elapsed_s": round(total_elapsed, 2),
               "bars": {"min_cost_reduction": MIN_REDUCTION,
                        "max_slo_delta": 0.0,
                        "time_budget_s": TIME_BUDGET_S}}
    if args.out:
        os.makedirs(os.path.dirname(os.path.abspath(args.out)) or ".",
                    exist_ok=True)
        with open(args.out, "w") as f:
            json.dump(summary, f, indent=2, sort_keys=True)
        print(f"summary written to {args.out}")

    if violations:
        print("ACCEPTANCE " + ("FAILED" if args.smoke else "bars violated")
              + ":\n  " + "\n  ".join(violations))
        return 1 if args.smoke else 0
    print(f"acceptance ok in {total_elapsed:.1f}s "
          f"(budget {TIME_BUDGET_S:.0f}s)")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
